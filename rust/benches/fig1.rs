//! Figure 1 reproduction: (left) test loss vs tokens processed for each
//! compressor; (right) per-worker w2s bytes (normalized by model size) to
//! reach a target test loss. Runs the full distributed stack (4 workers,
//! PJRT grad service) once per compressor and persists the reports for
//! fig2/ablation benches.
//!
//! Run:  `cargo bench --bench fig1 [-- --steps 150 --short]`

use efmuon::config::TrainConfig;
use efmuon::exp;
use efmuon::metrics::CsvWriter;
use efmuon::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP fig1: run `make artifacts` first");
        return Ok(());
    }
    // --short: the G.5 half-budget variant
    let short = args.bool("short", false);
    let steps = args.usize("steps", if short { 75 } else { 150 }).unwrap();
    let base = TrainConfig {
        workers: args.usize("workers", 4).unwrap(),
        steps,
        // bidirectional / pipelined variants of the sweep: --server-comp
        // compresses the EF21-P broadcast, --round-mode async:N pipelines
        server_comp: args.str("server-comp", "id"),
        round_mode: args.str("round-mode", "sync"),
        beta: 0.9,
        lr: args.f64("lr", 0.02).unwrap(),
        warmup: steps / 20 + 1,
        corpus_tokens: 1_500_000,
        eval_every: (steps / 15).max(1),
        eval_batches: 3,
        seed: args.u64("seed", 0).unwrap(),
        ..TrainConfig::default()
    };

    std::fs::create_dir_all("results")?;
    let tag = if short { "_short" } else { "" };
    let cache = format!("results/fig1_reports{tag}.json");
    // the sweep costs ~20 min on this testbed; reuse the persisted runs
    // unless --fresh is given (or the cached sweep covers different specs)
    let cached = if args.bool("fresh", false) {
        None
    } else {
        exp::load_reports(&cache).ok().filter(|rs| {
            let want = exp::figure_specs();
            rs.len() == want.len()
                && rs.iter().zip(want).all(|(r, w)| r.config_comp == w.spec())
        })
    };
    let reports = match cached {
        Some(rs) => {
            eprintln!("(reusing {} cached runs from {cache}; pass --fresh to rerun)", rs.len());
            rs
        }
        None => {
            let rs = exp::figure_sweep(&base, exp::figure_specs())?;
            exp::save_reports(&cache, &rs)?;
            rs
        }
    };

    // left panel CSV
    let mut csv = CsvWriter::create(
        &format!("results/fig1_left{tag}.csv"),
        &["compressor", "tokens", "eval_loss"],
    )?;
    for (spec, tokens, loss) in exp::fig1_left_rows(&reports) {
        csv.row(&[spec, tokens.to_string(), format!("{loss:.5}")])?;
    }
    csv.flush()?;

    // target: the paper picks a "strong loss threshold" that every
    // competitive configuration reaches within the budget; with our short
    // default budget that is the worst final loss across the sweep (each
    // config then reaches it at a different token/byte cost)
    let target = args.f64("target", 0.0).unwrap() as f32;
    let target = if target > 0.0 {
        target
    } else {
        reports
            .iter()
            .map(|r| r.final_eval_loss)
            .fold(f32::MIN, f32::max)
            * 1.002
    };

    println!("\n== Figure 1 (left): final losses ==");
    for r in &reports {
        println!("{:>16}: {:.4}", r.config_comp, r.final_eval_loss);
    }
    println!("\n== Figure 1 (right): cost to reach eval loss {target:.4} ==");
    let rows = exp::tradeoff_rows(&reports, target);
    let mut csv = CsvWriter::create(
        &format!("results/fig1_right{tag}.csv"),
        &["compressor", "reached", "tokens", "relative_bytes"],
    )?;
    for r in &rows {
        println!(
            "{:>16}  reached={}  tokens={:>10}  bytes/model={:.4}",
            r.spec, r.reached, r.tokens_to_target, r.relative_bytes_to_target
        );
        csv.row(&[
            r.spec.clone(),
            r.reached.to_string(),
            r.tokens_to_target.to_string(),
            format!("{:.5}", r.relative_bytes_to_target),
        ])?;
    }
    csv.flush()?;

    println!("\n== communication savings vs uncompressed (paper: up to 7x) ==");
    for (spec, x) in exp::savings_vs_id(&rows) {
        println!("{spec:>16}  {x:.2}x");
    }
    println!("\nwritten to results/fig1_left{tag}.csv, results/fig1_right{tag}.csv");
    Ok(())
}
