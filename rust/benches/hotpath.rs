//! Hot-path microbenchmarks (the §Perf instrument): per-layer costs of
//! everything on the request path — matmul throughput (single-thread vs
//! threaded), Newton–Schulz, compressors, codecs, and a full end-to-end
//! coordinator round (threaded leader/worker vs the sequential reference
//! driver) on the synthetic backend.
//!
//! Emits `BENCH_hotpath.json` at the repo root (name/median_s/GFLOP/s per
//! entry) so the perf trajectory is tracked across PRs.
//!
//! Run: `cargo bench --bench hotpath [-- --iters 30]`

use efmuon::compress::{codec, parse_spec};
use efmuon::dist::cluster::{Cluster, ClusterCfg};
use efmuon::dist::coordinator::{Coordinator, CoordinatorCfg};
use efmuon::dist::fault::FaultPolicy;
use efmuon::dist::sched::{SchedSpec, ShardDelayPlan};
use efmuon::dist::net::{spawn_loopback_workers, NetCfg, NetHub};
use efmuon::dist::service::GradService;
use efmuon::dist::{RoundMode, TransportMode};
use efmuon::funcs::{MatrixQuadratic, Objective, Quadratics, Stacked};
use efmuon::linalg::matmul::{matmul_into_reference, matmul_into_with_threads};
use efmuon::linalg::ns::newton_schulz;
use efmuon::linalg::Matrix;
use efmuon::lmo::LmoKind;
use efmuon::opt::ef21::Ef21MuonSeq;
use efmuon::opt::{LayerGeometry, Schedule};
use efmuon::results::{Record, Store};
use efmuon::runtime::ModelRuntime;
use efmuon::spec::CompSpec;
use efmuon::trace::{TraceAgg, Tracer};
use efmuon::util::cli::Args;
use efmuon::util::json::{Json, JsonObj};
use efmuon::util::rng::Rng;
use efmuon::util::timer::{bench_fn, BenchResult};

/// One emitted benchmark record.
struct Entry {
    result: BenchResult,
    gflops: Option<f64>,
    /// Per-round wire bytes (w2s per worker, s2w broadcast) for the
    /// coordinator-round entries, so BENCH_hotpath.json tracks both
    /// communication directions across PRs.
    comm: Option<(usize, usize)>,
    /// Per-round host memory traffic for the cluster-round entries:
    /// (bytes deep-copied, snapshot assemblies). `bench_gate.py` gates on
    /// the byte counter — a regression here means the zero-copy gradient
    /// path started cloning again.
    cloned: Option<(u64, u64)>,
    /// Fault counters for the round entries: (stragglers, respawns,
    /// partial_rounds). The bench runs fault-free, so `bench_gate.py`
    /// fails the run if any of these is nonzero — a worker stalling long
    /// enough to trip a deadline inside a benchmark is itself a perf bug.
    faults: Option<(u64, u64, u64)>,
    /// Per-round parameter-board bytes for the cluster entries: what one
    /// steady-state round reads from the board at its stored snapshot
    /// width. `bench_gate.py` checks each bf16 entry against its matched
    /// f32 entry (must be <= 0.55x).
    shipped: Option<u64>,
    /// Transport counters for the round entries: (reconnects,
    /// heartbeat_misses). Like the fault counters, the bench runs
    /// fault-free, so `bench_gate.py` fails the run if either is nonzero —
    /// a link flapping or a heartbeat going missing inside a benchmark is
    /// itself a perf bug.
    net: Option<(u64, u64)>,
    /// Scheduler counters for the cluster-round entries: (steals,
    /// epochs_ahead_max). Balanced benches run lock-step, so
    /// `bench_gate.py` fails the run if either is nonzero there; the
    /// `imbalanced` entries are exempt (running ahead is their point).
    sched: Option<(u64, u64)>,
}

fn push(entries: &mut Vec<Entry>, result: BenchResult, flops: Option<f64>) {
    let gflops = flops.map(|f| f / result.median_s / 1e9);
    match gflops {
        Some(g) => println!("{}   [{g:.2} GFLOP/s]", result.report()),
        None => println!("{}", result.report()),
    }
    entries.push(Entry {
        result,
        gflops,
        comm: None,
        cloned: None,
        faults: None,
        shipped: None,
        net: None,
        sched: None,
    });
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let iters = args.usize("iters", 30).unwrap();
    let mut rng = Rng::new(0);
    let mut entries: Vec<Entry> = Vec::new();
    // per-phase counts from the traced round entry, appended to the
    // results store alongside the timing summaries
    let mut trace_agg: Option<TraceAgg> = None;
    let cores = efmuon::util::threads::num_threads();
    println!("hot-path bench: {cores} thread(s) available, {iters} iters\n");

    // ---- matmul throughput (512x128x512: the mlp_proj-shaped contraction),
    //      single-thread baseline vs the row-partitioned threaded kernel
    {
        let a = Matrix::randn(512, 128, 1.0, &mut rng);
        let b = Matrix::randn(128, 512, 1.0, &mut rng);
        let mut c = Matrix::zeros(512, 512);
        let flops = 2.0 * 512.0 * 128.0 * 512.0;
        let r1 = bench_fn("matmul 512x128x512 (1 thread)", 3, iters, || {
            matmul_into_with_threads(&a, &b, std::hint::black_box(&mut c), 1);
        });
        push(&mut entries, r1, Some(flops));
        let rn = bench_fn(
            &format!("matmul 512x128x512 ({cores} threads)"),
            3,
            iters,
            || {
                matmul_into_with_threads(&a, &b, std::hint::black_box(&mut c), cores);
            },
        );
        let speedup = entries[entries.len() - 1].result.median_s / rn.median_s;
        push(&mut entries, rn, Some(flops));
        println!("  -> threaded speedup: {speedup:.2}x over 1 thread");
    }

    // ---- NS-sized matmul: the packed register-tiled microkernel vs the
    //      scalar reference it is bit-identical to (see
    //      rust/src/linalg/matmul.rs). The microkernel entries carry
    //      GFLOP/s gated by bench_gate.py; the printed speedup is the
    //      single-thread acceptance (>= 1.5x on >= 256^2 products).
    {
        for (n, its) in [(256usize, iters), (512usize, iters.min(10))] {
            let a = Matrix::randn(n, n, 1.0, &mut rng);
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let mut c = Matrix::zeros(n, n);
            let flops = 2.0 * (n as f64).powi(3);
            let r_ref = bench_fn(
                &format!("matmul {n}x{n}x{n} scalar reference (1 thread)"),
                2,
                its,
                || {
                    matmul_into_reference(&a, &b, std::hint::black_box(&mut c));
                },
            );
            let ref_s = r_ref.median_s;
            push(&mut entries, r_ref, Some(flops));
            let r_mk = bench_fn(
                &format!("matmul {n}x{n}x{n} microkernel (1 thread)"),
                2,
                its,
                || {
                    matmul_into_with_threads(&a, &b, std::hint::black_box(&mut c), 1);
                },
            );
            let speed = ref_s / r_mk.median_s;
            push(&mut entries, r_mk, Some(flops));
            println!("  -> microkernel single-thread speedup: {speed:.2}x over scalar reference");
        }
    }

    // ---- Newton–Schulz: native (workspace arena, threaded matmul inside)
    //      vs the Pallas/PJRT artifact
    {
        let g = Matrix::randn(128, 512, 1.0, &mut rng);
        let r = bench_fn("newton_schulz 128x512 (native rust)", 2, iters, || {
            std::hint::black_box(newton_schulz(&g, 5));
        });
        push(&mut entries, r, None);
        if let Ok(rt) = ModelRuntime::load("artifacts") {
            if rt.has_ns_for(128, 512) {
                let r = bench_fn("newton_schulz 128x512 (pallas/pjrt)", 2, iters, || {
                    std::hint::black_box(rt.ns_orthogonalize(&g).unwrap().unwrap());
                });
                push(&mut entries, r, None);
            }
        } else {
            eprintln!("  (no artifacts; skipping PJRT NS bench)");
        }
    }

    // ---- compressors on a hidden-layer-sized residual
    let x = Matrix::randn(128, 512, 1.0, &mut rng);
    for spec in ["top:0.1", "top:0.1+nat", "rank:0.1", "rank:0.1+nat", "nat",
                 "svdtop:4", "coltop:0.1"] {
        let mut c = parse_spec(spec).unwrap();
        let mut rng2 = Rng::new(1);
        let r = bench_fn(&format!("compress {spec} 128x512"), 2, iters, || {
            std::hint::black_box(c.compress(&x, &mut rng2));
        });
        push(&mut entries, r, None);
    }

    // ---- codec roundtrip
    {
        let mut c = parse_spec("top:0.1+nat").unwrap();
        let mut rng2 = Rng::new(2);
        let msg = c.compress(&x, &mut rng2);
        let r = bench_fn("codec encode+decode top:0.1+nat", 2, iters, || {
            let bytes = codec::encode(&msg);
            std::hint::black_box(codec::decode(&bytes).unwrap());
        });
        push(&mut entries, r, None);
    }

    // ---- full coordinator round on the synthetic backend (protocol
    //      overhead: channels + EF21 state + compression, no PJRT)
    {
        let q = Quadratics::new(4, 4096, 0.5, 0.1, &mut Rng::new(3));
        let x0 = q.init(&mut Rng::new(3));
        let svc = GradService::spawn_objective(Box::new(q), 3);
        let mut coord = Coordinator::spawn(
            x0,
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }],
            svc.handle(),
            CoordinatorCfg {
                n_workers: 4,
                worker_comp: CompSpec::Top { frac: 0.1, nat: false },
                server_comp: CompSpec::Id,
                beta: 0.9,
                schedule: Schedule::constant(0.01),
                transport: TransportMode::Encoded,
                round_mode: RoundMode::Sync,
                seed: 3,
                use_ns_artifact: false,
                fault: FaultPolicy::off(),
                fault_plan: None,
                start_step: 0,
                tracer: Tracer::Noop,
            },
        )?;
        let r = bench_fn("coordinator round (4 workers, d=4096)", 3, iters, || {
            coord.round().unwrap();
        });
        push(&mut entries, r, None);
        let s = coord.round()?;
        let m = coord.meter();
        let e = entries.last_mut().unwrap();
        e.comm = Some((s.w2s_bytes_per_worker, s.s2w_bytes));
        e.faults = Some((m.stragglers(), m.respawns(), m.partial_rounds()));
        e.net = Some((m.reconnects(), m.heartbeat_misses()));
    }

    // ---- the same round with a live tracer, ring drained per round like
    //      the train loop does. bench_gate.py pairs this entry with its
    //      untraced twin above (", traced" suffix) and fails the run if
    //      stamping costs more than the threshold (acceptance: <5%).
    {
        let q = Quadratics::new(4, 4096, 0.5, 0.1, &mut Rng::new(3));
        let x0 = q.init(&mut Rng::new(3));
        let svc = GradService::spawn_objective(Box::new(q), 3);
        let (tracer, ring) = Tracer::ring(efmuon::train::TRACE_RING_CAP);
        let mut coord = Coordinator::spawn(
            x0,
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }],
            svc.handle(),
            CoordinatorCfg {
                n_workers: 4,
                worker_comp: CompSpec::Top { frac: 0.1, nat: false },
                server_comp: CompSpec::Id,
                beta: 0.9,
                schedule: Schedule::constant(0.01),
                transport: TransportMode::Encoded,
                round_mode: RoundMode::Sync,
                seed: 3,
                use_ns_artifact: false,
                fault: FaultPolicy::off(),
                fault_plan: None,
                start_step: 0,
                tracer,
            },
        )?;
        let mut agg = TraceAgg::default();
        let r = bench_fn("coordinator round (4 workers, d=4096), traced", 3, iters, || {
            coord.round().unwrap();
            agg.absorb(&ring.drain());
        });
        push(&mut entries, r, None);
        agg.absorb(&ring.drain());
        agg.dropped = ring.dropped();
        trace_agg = Some(agg);
        let n = entries.len();
        let base = entries
            .iter()
            .find(|e| e.result.name == "coordinator round (4 workers, d=4096)")
            .map(|e| e.result.median_s)
            .unwrap_or(f64::NAN);
        println!(
            "  -> traced round overhead: {:+.2}% over untraced",
            (entries[n - 1].result.median_s / base - 1.0) * 100.0
        );
    }

    // ---- the same coordinator round over loopback TCP (dist::net):
    //      length-prefixed frames + heartbeats + per-link supervisor
    //      threads on top of the identical compute loop. The delta vs the
    //      in-process channel entry is the transport overhead
    //      (EXPERIMENTS.md §Loopback transport overhead); the fault/net
    //      counters must all stay zero in a fault-free bench.
    {
        let q = Quadratics::new(4, 4096, 0.5, 0.1, &mut Rng::new(3));
        let x0 = q.init(&mut Rng::new(3));
        let svc = GradService::spawn_objective(Box::new(q), 3);
        let handle = svc.handle();
        let hub = NetHub::bind(NetCfg { listen: "127.0.0.1:0".into(), ..NetCfg::default() })?;
        let workers = spawn_loopback_workers(4, hub.local_addr(), &handle, None);
        let mut coord = Coordinator::spawn_net(
            x0,
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }],
            handle,
            CoordinatorCfg {
                n_workers: 4,
                worker_comp: CompSpec::Top { frac: 0.1, nat: false },
                server_comp: CompSpec::Id,
                beta: 0.9,
                schedule: Schedule::constant(0.01),
                transport: TransportMode::Encoded,
                round_mode: RoundMode::Sync,
                seed: 3,
                use_ns_artifact: false,
                fault: FaultPolicy::off(),
                fault_plan: None,
                start_step: 0,
                tracer: Tracer::Noop,
            },
            hub,
        )?;
        let r = bench_fn("coordinator round over loopback tcp (4 workers, d=4096)", 3, iters, || {
            coord.round().unwrap();
        });
        push(&mut entries, r, None);
        let s = coord.round()?;
        let m = coord.meter();
        let e = entries.last_mut().unwrap();
        e.comm = Some((s.w2s_bytes_per_worker, s.s2w_bytes));
        e.faults = Some((m.stragglers(), m.respawns(), m.partial_rounds()));
        e.net = Some((m.reconnects(), m.heartbeat_misses()));
        let base = entries
            .iter()
            .find(|e| e.result.name == "coordinator round (4 workers, d=4096)")
            .map(|e| e.result.median_s)
            .unwrap_or(f64::NAN);
        let n = entries.len();
        println!(
            "  -> loopback tcp overhead: {:+.2}% over in-process channels",
            (entries[n - 1].result.median_s / base - 1.0) * 100.0
        );
        drop(coord); // sends stop frames, joins the hub's link threads
        for w in workers {
            let _ = w.join();
        }
    }

    // ---- bidirectional compression + async pipelining: the same synthetic
    //      deployment under (s2w id vs top:0.1) x (sync vs async:1). The
    //      JSON rows carry per-round wire bytes in both directions; the
    //      async row measures what one round of lookahead buys in latency.
    {
        let mut bench_round = |name: &str, server_comp: CompSpec, mode: RoundMode| -> anyhow::Result<()> {
            let q = Quadratics::new(4, 4096, 0.5, 0.1, &mut Rng::new(3));
            let x0 = q.init(&mut Rng::new(3));
            let svc = GradService::spawn_objective(Box::new(q), 3);
            let mut coord = Coordinator::spawn(
                x0,
                vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }],
                svc.handle(),
                CoordinatorCfg {
                    n_workers: 4,
                    worker_comp: CompSpec::Top { frac: 0.1, nat: false },
                    server_comp,
                    beta: 0.9,
                    schedule: Schedule::constant(0.01),
                    transport: TransportMode::Encoded,
                    round_mode: mode,
                    seed: 3,
                    use_ns_artifact: false,
                    fault: FaultPolicy::off(),
                    fault_plan: None,
                    start_step: 0,
                    tracer: Tracer::Noop,
                },
            )?;
            let r = bench_fn(name, 3, iters, || {
                coord.round().unwrap();
            });
            push(&mut entries, r, None);
            // sample one round's wire bytes (async: the absorbed round may
            // trail the issued one, so take the drained stats instead)
            let s = coord.round()?;
            let drained = coord.drain()?;
            let w2s = if s.absorbed_step.is_some() {
                s.w2s_bytes_per_worker
            } else {
                drained.first().map(|d| d.w2s_bytes_per_worker).unwrap_or(0)
            };
            let m = coord.meter();
            let e = entries.last_mut().unwrap();
            e.comm = Some((w2s, s.s2w_bytes));
            e.faults = Some((m.stragglers(), m.respawns(), m.partial_rounds()));
            e.net = Some((m.reconnects(), m.heartbeat_misses()));
            Ok(())
        };
        let s2w_comp = CompSpec::Top { frac: 0.1, nat: false };
        bench_round("coordinator round s2w=top:0.1 sync (4 workers, d=4096)", s2w_comp, RoundMode::Sync)?;
        bench_round(
            "coordinator round s2w=top:0.1 async:1 (4 workers, d=4096)",
            s2w_comp,
            RoundMode::Async { lookahead: 1 },
        )?;
        let n = entries.len();
        let sync_s = entries[n - 2].result.median_s;
        let async_s = entries[n - 1].result.median_s;
        println!("  -> async:1 round speedup: {:.2}x over sync (>1 = pipelining is faster)", sync_s / async_s);
    }

    // ---- threaded leader/worker vs the sequential reference driver on a
    //      grad-heavy matrix objective (spectral LMO, RankK uplink): the
    //      dist deployment overlaps the 4 workers' gradient + compression
    //      work across OS threads; the sequential driver runs them one
    //      after another (plus its per-step loss/grad-norm telemetry).
    {
        let mk = || MatrixQuadratic::new(4, 192, 192, 0.0, &mut Rng::new(4));
        let geom = vec![LayerGeometry { lmo: LmoKind::Spectral, radius_mult: 1.0 }];
        let cfg_iters = iters.min(10);

        let q_seq = mk();
        let mut seq = Ef21MuonSeq::new(
            &q_seq,
            geom.clone(),
            "rank:0.2",
            "id",
            0.9,
            Schedule::constant(0.01),
            false,
            4,
        )
        .map_err(anyhow::Error::msg)?;
        let r_seq = bench_fn("ef21 round, sequential driver (4 workers, 192x192)", 2, cfg_iters, || {
            std::hint::black_box(seq.step(&q_seq));
        });
        push(&mut entries, r_seq, None);

        let q_dist = mk();
        let x0 = q_dist.init(&mut Rng::new(4));
        let svc = GradService::spawn_objective(Box::new(q_dist), 4);
        let mut coord = Coordinator::spawn(
            x0,
            geom,
            svc.handle(),
            CoordinatorCfg {
                n_workers: 4,
                worker_comp: CompSpec::Rank { frac: 0.2, nat: false },
                server_comp: CompSpec::Id,
                beta: 0.9,
                schedule: Schedule::constant(0.01),
                transport: TransportMode::Counted,
                round_mode: RoundMode::Sync,
                seed: 4,
                use_ns_artifact: false,
                fault: FaultPolicy::off(),
                fault_plan: None,
                start_step: 0,
                tracer: Tracer::Noop,
            },
        )?;
        let r_dist = bench_fn("ef21 round, threaded coordinator (4 workers, 192x192)", 2, cfg_iters, || {
            coord.round().unwrap();
        });
        let seq_s = entries[entries.len() - 1].result.median_s;
        let speed = seq_s / r_dist.median_s;
        push(&mut entries, r_dist, None);
        let m = coord.meter();
        let e = entries.last_mut().unwrap();
        e.faults = Some((m.stragglers(), m.respawns(), m.partial_rounds()));
        e.net = Some((m.reconnects(), m.heartbeat_misses()));
        println!("  -> threaded coordinator round: {speed:.2}x vs sequential driver");
    }

    // ---- multi-coordinator layer sharding: the same 4-layer separable
    //      workload under 1 / 2 / 4 shard coordinators. Each shard leader
    //      runs on its own OS thread with its own worker pool, so the
    //      cluster round's wall time trends toward the max over shards
    //      instead of the sum over layers; wire bytes are aggregated
    //      per-shard sums (identical across shard counts — sharding
    //      repartitions the work, not the algorithm).
    {
        let cfg_iters = iters.min(10);
        let mut shard_times: Vec<(usize, f64)> = Vec::new();
        // the bf16 rows re-run the 2- and 4-shard deployments with the
        // parameter board stored at half width; bench_gate.py checks each
        // bf16 row's board bytes against its matched f32 row (<= 0.55x)
        for (shards, bf16) in
            [(1usize, false), (2, false), (4, false), (2, true), (4, true)]
        {
            let mut rng4 = Rng::new(4);
            let parts: Vec<Box<dyn Objective>> = (0..4)
                .map(|_| {
                    Box::new(MatrixQuadratic::new(4, 192, 192, 0.0, &mut rng4))
                        as Box<dyn Objective>
                })
                .collect();
            let stack = Stacked::new(parts).map_err(anyhow::Error::msg)?;
            let x0 = stack.init(&mut Rng::new(4));
            let svc = GradService::spawn_objective(Box::new(stack), 4);
            let mut cluster = Cluster::spawn(
                x0,
                vec![LayerGeometry { lmo: LmoKind::Spectral, radius_mult: 1.0 }; 4],
                svc.handle(),
                ClusterCfg {
                    shards,
                    workers_per_shard: 4,
                    worker_comp: CompSpec::Rank { frac: 0.2, nat: false },
                    server_comp: CompSpec::Id,
                    beta: 0.9,
                    schedule: Schedule::constant(0.01),
                    transport: TransportMode::Counted,
                    round_mode: RoundMode::Sync,
                    seed: 4,
                    use_ns_artifact: false,
                    fault: FaultPolicy::off(),
                    fault_plan: None,
                    start_step: 0,
                    snap_bf16: bf16,
                    sched: SchedSpec::off(),
                    shard_delay: None,
                    tracer: Tracer::Noop,
                },
            )?;
            let name = if bf16 {
                format!("cluster round ({shards} shard(s), 4x192x192, 4 workers, bf16 board)")
            } else {
                format!("cluster round ({shards} shard(s), 4x192x192, 4 workers)")
            };
            let r = bench_fn(&name, 2, cfg_iters, || {
                cluster.round().unwrap();
            });
            if !bf16 {
                shard_times.push((shards, r.median_s));
            }
            push(&mut entries, r, None);
            // sample one round's aggregated per-shard wire bytes (sync mode:
            // the absorbed round is the issued one) and its host memory
            // traffic: totals() diffs isolate what ONE steady-state round
            // deep-copies (snapshot assemblies + the root's seal) — the
            // zero-copy acceptance is assemblies == shards, not workers x
            // shards, and bytes flat at (shards + 1) x model for multi-shard
            let m0 = cluster.meter().totals();
            let s = cluster.round()?;
            let m1 = cluster.meter().totals();
            let per_round_cloned = m1.bytes_cloned - m0.bytes_cloned;
            let per_round_asm = m1.snap_assembled - m0.snap_assembled;
            let per_round_shipped = m1.snap_bytes_shipped - m0.snap_bytes_shipped;
            println!(
                "  -> {shards}-shard round memory traffic: {per_round_cloned} bytes cloned, \
                 {per_round_asm} snapshot assemblies, {per_round_shipped} board bytes"
            );
            let e = entries.last_mut().unwrap();
            e.comm = Some((s.w2s_bytes_per_worker, s.s2w_bytes));
            e.cloned = Some((per_round_cloned, per_round_asm));
            e.faults = Some((m1.stragglers, m1.respawns, m1.partial_rounds));
            e.shipped = Some(per_round_shipped);
            e.net = Some((m1.reconnects, m1.heartbeat_misses));
            e.sched = Some((m1.steals, m1.epochs_ahead_max));
        }
        if let Some(&(_, base)) = shard_times.first() {
            for &(shards, t) in &shard_times[1..] {
                println!(
                    "  -> cluster {shards}-shard round speedup: {:.2}x over 1 shard",
                    base / t
                );
            }
        }
    }

    // ---- imbalanced shards: the bounded-epoch scheduler's acceptance
    //      entry. A rotating 15 ms delay (round r stalls shard r % 4) makes
    //      every lock-step round pay the full delay, while a window of 1
    //      overlaps each victim's stall with the other shards' next round —
    //      the windowed median must come in strictly below its lock-step
    //      mate (bench_gate.py pairs the two entries by name).
    {
        let cfg_iters = iters.min(10);
        let delay_ms = 15;
        // cover warmup + timed rounds with slack so every measured round
        // sees the rotating stall
        let delayed_rounds = 2 + cfg_iters + 8;
        let mut pair_times: Vec<f64> = Vec::new();
        for sched in [SchedSpec::off(), SchedSpec::parse("window:1").unwrap()] {
            let mut rng5 = Rng::new(4);
            let parts: Vec<Box<dyn Objective>> = (0..8)
                .map(|_| {
                    Box::new(MatrixQuadratic::new(2, 96, 96, 0.0, &mut rng5))
                        as Box<dyn Objective>
                })
                .collect();
            let stack = Stacked::new(parts).map_err(anyhow::Error::msg)?;
            let x0 = stack.init(&mut Rng::new(4));
            let svc = GradService::spawn_objective(Box::new(stack), 4);
            let mut cluster = Cluster::spawn(
                x0,
                vec![LayerGeometry { lmo: LmoKind::Spectral, radius_mult: 1.0 }; 8],
                svc.handle(),
                ClusterCfg {
                    shards: 4,
                    workers_per_shard: 2,
                    worker_comp: CompSpec::Id,
                    server_comp: CompSpec::Id,
                    beta: 0.9,
                    schedule: Schedule::constant(0.01),
                    transport: TransportMode::Counted,
                    round_mode: RoundMode::Sync,
                    seed: 4,
                    use_ns_artifact: false,
                    fault: FaultPolicy::off(),
                    fault_plan: None,
                    start_step: 0,
                    snap_bf16: false,
                    sched,
                    shard_delay: Some(std::sync::Arc::new(ShardDelayPlan::alternating(
                        4,
                        delayed_rounds,
                        delay_ms,
                    ))),
                    tracer: Tracer::Noop,
                },
            )?;
            let name = if sched.is_off() {
                "cluster round (4 shards, imbalanced, lock-step)"
            } else {
                "cluster round (4 shards, imbalanced, window:1)"
            };
            let r = bench_fn(name, 2, cfg_iters, || {
                cluster.round().unwrap();
            });
            pair_times.push(r.median_s);
            push(&mut entries, r, None);
            let m = cluster.meter().totals();
            let e = entries.last_mut().unwrap();
            e.faults = Some((m.stragglers, m.respawns, m.partial_rounds));
            e.net = Some((m.reconnects, m.heartbeat_misses));
            e.sched = Some((m.steals, m.epochs_ahead_max));
        }
        if let [lockstep, windowed] = pair_times[..] {
            println!(
                "  -> imbalanced 4-shard round: window:1 {:.2}x vs lock-step",
                lockstep / windowed
            );
        }
    }

    // ---- PJRT grad step (the dominant cost of a real round)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = ModelRuntime::load("artifacts")?;
        let params = rt.manifest.load_init_params().unwrap();
        let corpus = efmuon::data::Corpus::zipf_markov(100_000, rt.manifest.vocab, 1);
        let shard = efmuon::data::Shard::new(&corpus, 0, 1, rt.manifest.seq_len);
        let mut rng3 = Rng::new(4);
        let (toks, tgts) = shard.sample_batch(rt.manifest.batch, &mut rng3);
        let r = bench_fn("pjrt grad step (micro, batch 8)", 1, iters.min(10), || {
            std::hint::black_box(rt.grad(&params, &toks, &tgts).unwrap());
        });
        push(&mut entries, r, None);
        let r = bench_fn("pjrt eval step (micro, batch 8)", 1, iters.min(10), || {
            std::hint::black_box(rt.eval_loss(&params, &toks, &tgts).unwrap());
        });
        push(&mut entries, r, None);
    }

    // ---- machine-readable record at the repo root
    let out_path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_hotpath.json"
    } else {
        "BENCH_hotpath.json"
    };
    let arr: Vec<Json> = entries
        .iter()
        .map(|e| {
            let mut o = JsonObj::new()
                .put("name", e.result.name.as_str())
                .put("median_s", e.result.median_s)
                .put("mad_s", e.result.mad_s)
                .put("min_s", e.result.min_s)
                .put("iters", e.result.iters);
            if let Some(g) = e.gflops {
                o = o.put("gflops", g);
            }
            if let Some((w2s, s2w)) = e.comm {
                o = o.put("w2s_bytes_per_round", w2s).put("s2w_bytes_per_round", s2w);
            }
            if let Some((bytes, asm)) = e.cloned {
                o = o
                    .put("bytes_cloned_per_round", bytes)
                    .put("assemblies_per_round", asm);
            }
            if let Some((stragglers, respawns, partial)) = e.faults {
                o = o
                    .put("stragglers", stragglers)
                    .put("respawns", respawns)
                    .put("partial_rounds", partial);
            }
            if let Some(shipped) = e.shipped {
                o = o.put("snap_bytes_shipped_per_round", shipped);
            }
            if let Some((reconnects, misses)) = e.net {
                o = o.put("reconnects", reconnects).put("heartbeat_misses", misses);
            }
            if let Some((steals, ahead)) = e.sched {
                o = o.put("steals", steals).put("epochs_ahead_max", ahead);
            }
            o.build()
        })
        .collect();
    let doc = JsonObj::new()
        .put("bench", "hotpath")
        .put("threads", cores)
        .put("entries", Json::Arr(arr))
        .build();
    std::fs::write(out_path, doc.to_string())?;
    println!("\nwrote {out_path} ({} entries)", entries.len());

    // ---- append this run to the experiment history (results/results.jsonl
    //      at the repo root, rendered by `efmuon results` and trend-gated by
    //      `bench_gate.py --results`)
    let mut rec = Record::new("hotpath");
    for e in &entries {
        rec = rec.timing(&e.result);
    }
    if let Some(agg) = &trace_agg {
        rec = rec.trace(agg);
    }
    let store = Store::open_default();
    store.append(&rec)?;
    println!("appended run to {}", store.path().display());

    Ok(())
}
