//! Hot-path microbenchmarks (the §Perf instrument): per-layer costs of
//! everything on the request path — compressors, codecs, LMOs (native NS vs
//! the Pallas/PJRT artifact), matmul throughput, and a full end-to-end
//! coordinator round on the synthetic backend.
//!
//! Run: `cargo bench --bench hotpath [-- --iters 30]`

use efmuon::compress::{codec, parse_spec};
use efmuon::dist::coordinator::{Coordinator, CoordinatorCfg};
use efmuon::dist::service::GradService;
use efmuon::dist::TransportMode;
use efmuon::funcs::{Objective, Quadratics};
use efmuon::linalg::matmul::matmul;
use efmuon::linalg::ns::newton_schulz;
use efmuon::linalg::Matrix;
use efmuon::lmo::LmoKind;
use efmuon::opt::{LayerGeometry, Schedule};
use efmuon::runtime::ModelRuntime;
use efmuon::util::cli::Args;
use efmuon::util::rng::Rng;
use efmuon::util::timer::bench_fn;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let iters = args.usize("iters", 30);
    let mut rng = Rng::new(0);
    let mut results = Vec::new();

    // ---- matmul throughput (512x128x512: the mlp_proj-shaped contraction)
    {
        let a = Matrix::randn(512, 128, 1.0, &mut rng);
        let b = Matrix::randn(128, 512, 1.0, &mut rng);
        let flops = 2.0 * 512.0 * 128.0 * 512.0;
        let r = bench_fn("matmul 512x128x512 (native)", 3, iters, || {
            std::hint::black_box(matmul(&a, &b));
        });
        println!("{}   [{:.2} GFLOP/s]", r.report(), flops / r.median_s / 1e9);
        results.push(r);
    }

    // ---- Newton–Schulz: native vs Pallas/PJRT artifact
    {
        let g = Matrix::randn(128, 512, 1.0, &mut rng);
        let r = bench_fn("newton_schulz 128x512 (native rust)", 2, iters, || {
            std::hint::black_box(newton_schulz(&g, 5));
        });
        println!("{}", r.report());
        if let Ok(rt) = ModelRuntime::load("artifacts") {
            if rt.has_ns_for(128, 512) {
                let r = bench_fn("newton_schulz 128x512 (pallas/pjrt)", 2, iters, || {
                    std::hint::black_box(rt.ns_orthogonalize(&g).unwrap().unwrap());
                });
                println!("{}", r.report());
            }
        } else {
            eprintln!("  (no artifacts; skipping PJRT NS bench)");
        }
    }

    // ---- compressors on a hidden-layer-sized residual
    let x = Matrix::randn(128, 512, 1.0, &mut rng);
    for spec in ["top:0.1", "top:0.1+nat", "rank:0.1", "rank:0.1+nat", "nat",
                 "svdtop:4", "coltop:0.1"] {
        let mut c = parse_spec(spec).unwrap();
        let mut rng2 = Rng::new(1);
        let r = bench_fn(&format!("compress {spec} 128x512"), 2, iters, || {
            std::hint::black_box(c.compress(&x, &mut rng2));
        });
        println!("{}", r.report());
    }

    // ---- codec roundtrip
    {
        let mut c = parse_spec("top:0.1+nat").unwrap();
        let mut rng2 = Rng::new(2);
        let msg = c.compress(&x, &mut rng2);
        let r = bench_fn("codec encode+decode top:0.1+nat", 2, iters, || {
            let bytes = codec::encode(&msg);
            std::hint::black_box(codec::decode(&bytes).unwrap());
        });
        println!("{}", r.report());
    }

    // ---- full coordinator round on the synthetic backend (protocol
    //      overhead: channels + EF21 state + compression, no PJRT)
    {
        let q = Quadratics::new(4, 4096, 0.5, 0.1, &mut Rng::new(3));
        let x0 = q.init(&mut Rng::new(3));
        let svc = GradService::spawn_objective(Box::new(q), 3);
        let mut coord = Coordinator::spawn(
            x0,
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }],
            svc.handle(),
            CoordinatorCfg {
                n_workers: 4,
                worker_comp: "top:0.1".into(),
                server_comp: "id".into(),
                beta: 0.9,
                schedule: Schedule::constant(0.01),
                transport: TransportMode::Encoded,
                seed: 3,
                use_ns_artifact: false,
            },
        )?;
        let r = bench_fn("coordinator round (4 workers, d=4096)", 3, iters, || {
            coord.round().unwrap();
        });
        println!("{}", r.report());
    }

    // ---- PJRT grad step (the dominant cost of a real round)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = ModelRuntime::load("artifacts")?;
        let params = rt.manifest.load_init_params().unwrap();
        let corpus = efmuon::data::Corpus::zipf_markov(100_000, rt.manifest.vocab, 1);
        let shard = efmuon::data::Shard::new(&corpus, 0, 1, rt.manifest.seq_len);
        let mut rng3 = Rng::new(4);
        let (toks, tgts) = shard.sample_batch(rt.manifest.batch, &mut rng3);
        let r = bench_fn("pjrt grad step (micro, batch 8)", 1, iters.min(10), || {
            std::hint::black_box(rt.grad(&params, &toks, &tgts).unwrap());
        });
        println!("{}", r.report());
        let r = bench_fn("pjrt eval step (micro, batch 8)", 1, iters.min(10), || {
            std::hint::black_box(rt.eval_loss(&params, &toks, &tgts).unwrap());
        });
        println!("{}", r.report());
    }

    Ok(())
}
