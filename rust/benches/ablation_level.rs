//! Appendix G.4 reproduction: compression-level ablation — final loss and
//! per-round cost as the TopK / RankK level varies.
//!
//! Run: `cargo bench --bench ablation_level [-- --steps 60 --family rank]`

use efmuon::config::TrainConfig;
use efmuon::exp::level_ablation;
use efmuon::metrics::{render_table, CsvWriter};
use efmuon::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP ablation_level: run `make artifacts` first");
        return Ok(());
    }
    let steps = args.usize("steps", 60).unwrap();
    let family = args.str("family", "rank");
    let base = TrainConfig {
        workers: 4,
        steps,
        beta: 0.9,
        lr: 0.02,
        warmup: steps / 10 + 1,
        corpus_tokens: 800_000,
        eval_every: steps,
        eval_batches: 3,
        ..TrainConfig::default()
    };
    let levels = [0.05, 0.1, 0.15, 0.2];
    let rows = level_ablation(&base, &family, &levels)?;
    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/ablation_level.csv",
        &["family", "level", "final_eval_loss", "relative_bytes"],
    )?;
    let mut table = Vec::new();
    for (lv, loss, rel) in &rows {
        table.push(vec![
            format!("{family}:{lv}"),
            format!("{loss:.4}"),
            format!("{rel:.4}"),
        ]);
        csv.row(&[
            family.clone(),
            format!("{lv}"),
            format!("{loss:.5}"),
            format!("{rel:.5}"),
        ])?;
    }
    csv.flush()?;
    println!("== G.4 compression-level ablation ({family}, {steps} steps) ==\n");
    println!(
        "{}",
        render_table(&["spec", "final eval loss", "bytes/round ÷ dense"], &table)
    );
    // shape: cost must be monotone in level
    for w in rows.windows(2) {
        assert!(w[0].2 <= w[1].2 + 1e-9, "cost not monotone in level");
    }
    println!("written to results/ablation_level.csv");
    Ok(())
}
