//! Table 2 reproduction: communication cost per round (bytes) per
//! compressor, normalized to the identity compressor — computed from the
//! exact wire codec over the model's layer table, printed next to the
//! paper's reported values.
//!
//! Run: `cargo bench --bench table2`

use efmuon::exp::{paper_compressor_specs, table2_rows};
use efmuon::metrics::{render_table, CsvWriter};
use efmuon::model::{micro_preset_shapes, Manifest};

/// Paper Table 2 values (their serialization: f32 + u32 indices, Natural
/// at 16-bit granularity; ours bit-packs Natural at 9 bits and uses
/// minimal-width indices — see EXPERIMENTS.md for the mapping).
fn paper_value(spec: &str) -> Option<f64> {
    Some(match spec {
        "id" => 1.0,
        "nat" => 0.5,
        "rank:0.2" => 0.2687,
        "rank:0.15" => 0.2019,
        "rank:0.15+nat" => 0.1010,
        "rank:0.1" => 0.1335,
        "rank:0.1+nat" => 0.0667,
        "rank:0.05" => 0.0667,
        "top:0.2" => 0.3625,
        "top:0.15" => 0.2718,
        "top:0.15+nat" => 0.1969,
        "top:0.1" => 0.1812,
        "top:0.1+nat" => 0.1312,
        "top:0.05" => 0.0906,
        _ => return None,
    })
}

fn main() -> anyhow::Result<()> {
    let shapes = match Manifest::load("artifacts") {
        Ok(m) => m.layer_shapes(),
        Err(_) => {
            eprintln!("(artifacts missing; using micro preset layer table)");
            micro_preset_shapes()
        }
    };
    let rows = table2_rows(&shapes, paper_compressor_specs())?;
    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/table2.csv",
        &["compressor", "bytes_per_round", "relative", "paper_relative"],
    )?;
    let mut table = Vec::new();
    for r in &rows {
        let paper = paper_value(&r.spec);
        table.push(vec![
            r.spec.clone(),
            r.bytes_per_round.to_string(),
            format!("{:.4}", r.relative),
            paper.map(|p| format!("{p:.4}")).unwrap_or_default(),
        ]);
        csv.row(&[
            r.spec.clone(),
            r.bytes_per_round.to_string(),
            format!("{:.6}", r.relative),
            paper.map(|p| format!("{p:.4}")).unwrap_or_default(),
        ])?;
    }
    csv.flush()?;
    println!("== Table 2: communication cost per round (w2s) ==\n");
    println!(
        "{}",
        render_table(
            &["Compressor", "Bytes/round", "Relative (ours)", "Relative (paper)"],
            &table
        )
    );
    // shape assertions: the qualitative ordering of the paper must hold
    let rel = |s: &str| rows.iter().find(|r| r.spec == s).unwrap().relative;
    assert!(rel("rank:0.15+nat") < rel("rank:0.15"));
    assert!(rel("top:0.15+nat") < rel("top:0.15"));
    assert!(rel("rank:0.1") < rel("top:0.1"));
    assert!(rel("top:0.05") < rel("top:0.1"));
    println!("ordering checks passed (who-is-cheaper matches the paper).");
    println!("written to results/table2.csv");
    Ok(())
}
