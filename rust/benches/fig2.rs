//! Figure 2 reproduction: the token-efficiency vs communication-cost
//! trade-off scatter at a target test loss. Reuses the runs persisted by
//! the fig1 bench when present (run `cargo bench --bench fig1` first);
//! otherwise runs a reduced sweep itself.
//!
//! Run: `cargo bench --bench fig2 [-- --target 4.2]`

use efmuon::config::TrainConfig;
use efmuon::exp;
use efmuon::metrics::{render_table, CsvWriter};
use efmuon::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let reports = match exp::load_reports("results/fig1_reports.json") {
        Ok(r) if !r.is_empty() => {
            eprintln!("(reusing {} runs from results/fig1_reports.json)", r.len());
            r
        }
        _ => {
            if !std::path::Path::new("artifacts/manifest.json").exists() {
                eprintln!("SKIP fig2: run `make artifacts` first");
                return Ok(());
            }
            eprintln!("(no fig1 results; running a reduced sweep)");
            let steps = args.usize("steps", 100).unwrap();
            let base = TrainConfig {
                workers: 4,
                steps,
                beta: 0.9,
                lr: 0.02,
                warmup: steps / 20 + 1,
                corpus_tokens: 1_000_000,
                eval_every: (steps / 12).max(1),
                eval_batches: 3,
                ..TrainConfig::default()
            };
            exp::figure_sweep(&base, exp::figure_specs())?
        }
    };

    // same threshold protocol as fig1: the worst final loss in the sweep
    let target = args.f64("target", 0.0).unwrap() as f32;
    let target = if target > 0.0 {
        target
    } else {
        reports
            .iter()
            .map(|r| r.final_eval_loss)
            .fold(f32::MIN, f32::max)
            * 1.002
    };

    let rows = exp::tradeoff_rows(&reports, target);
    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/fig2.csv",
        &["compressor", "tokens_to_target", "relative_bytes_to_target"],
    )?;
    let mut table = Vec::new();
    for r in &rows {
        if r.reached {
            csv.row(&[
                r.spec.clone(),
                r.tokens_to_target.to_string(),
                format!("{:.5}", r.relative_bytes_to_target),
            ])?;
        }
        table.push(vec![
            r.spec.clone(),
            if r.reached { r.tokens_to_target.to_string() } else { "—".into() },
            if r.reached {
                format!("{:.4}", r.relative_bytes_to_target)
            } else {
                "—".into()
            },
            format!("{:.4}", r.final_loss),
        ]);
    }
    csv.flush()?;
    println!("== Figure 2: trade-off at target eval loss {target:.4} ==\n");
    println!(
        "{}",
        render_table(
            &["compressor", "tokens to target", "w2s bytes ÷ model", "final loss"],
            &table
        )
    );
    println!("(paper shape: compression trades slightly more tokens for far fewer bytes)");
    println!("written to results/fig2.csv");
    Ok(())
}
