//! Appendix G.3 reproduction: learning-rate (radius) ablation at a fixed
//! compressor — final eval loss as a function of the base radius.
//!
//! Run: `cargo bench --bench ablation_lr [-- --steps 60 --comp top:0.15+nat]`

use efmuon::config::TrainConfig;
use efmuon::exp::lr_ablation;
use efmuon::metrics::{render_table, CsvWriter};
use efmuon::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP ablation_lr: run `make artifacts` first");
        return Ok(());
    }
    let steps = args.usize("steps", 60).unwrap();
    let base = TrainConfig {
        workers: 4,
        steps,
        worker_comp: args.str("comp", "top:0.15+nat"),
        beta: 0.9,
        warmup: steps / 10 + 1,
        corpus_tokens: 800_000,
        eval_every: steps, // final eval only
        eval_batches: 3,
        ..TrainConfig::default()
    };
    let lrs = [0.005, 0.01, 0.02, 0.04, 0.08];
    let rows = lr_ablation(&base, &lrs)?;
    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create("results/ablation_lr.csv", &["lr", "final_eval_loss"])?;
    let mut table = Vec::new();
    for (lr, loss) in &rows {
        table.push(vec![format!("{lr}"), format!("{loss:.4}")]);
        csv.row(&[format!("{lr}"), format!("{loss:.5}")])?;
    }
    csv.flush()?;
    println!(
        "== G.3 learning-rate ablation ({} @ {steps} steps) ==\n",
        base.worker_comp
    );
    println!("{}", render_table(&["radius (lr)", "final eval loss"], &table));
    // shape: the sweep must contain an interior optimum or a plateau —
    // i.e. the largest lr must not be the (unique) best
    let best = rows
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!("best radius: {} (loss {:.4})", best.0, best.1);
    println!("written to results/ablation_lr.csv");
    Ok(())
}
