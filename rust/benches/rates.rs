//! Table 1 validation: the paper proves O(K^{-1/2}) deterministic and
//! O(K^{-1/4}) stochastic rates for min_k ‖∇f(X^k)‖⋆. We run EF21-Muon
//! with the theory schedules over a K-sweep on synthetic objectives
//! (smooth quadratics + (L⁰,L¹)-smooth cosh) and fit the log–log slope.
//!
//! Run: `cargo bench --bench rates [-- --seed 123]`

use efmuon::exp::{rate_validation, rates_text};
use efmuon::metrics::CsvWriter;
use efmuon::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let seed = args.u64("seed", 123).unwrap();
    let rows = rate_validation(seed)?;
    println!("== Table 1 (empirical): convergence-rate fits ==\n");
    println!("{}", rates_text(&rows));
    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/rates.csv",
        &["setting", "theory_slope", "fitted_slope", "r2"],
    )?;
    for r in &rows {
        csv.row(&[
            r.setting.clone(),
            format!("{}", r.theory_slope),
            format!("{:.4}", r.fitted_slope),
            format!("{:.4}", r.r2),
        ])?;
    }
    csv.flush()?;

    // shape assertions: deterministic must decay near -1/2 and strictly
    // faster than the stochastic fit
    let det = rows[0].fitted_slope;
    let sto = rows[2].fitted_slope;
    assert!(det < -0.3, "deterministic slope {det} too flat");
    assert!(det < sto + 0.05, "det {det} should be steeper than stoch {sto}");
    println!("slope ordering matches theory. written to results/rates.csv");
    Ok(())
}
