//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! This crate exists so the L3 rust stack builds and tests in environments
//! without `libxla`: it mirrors the exact API surface `efmuon::runtime`
//! uses, and every entry point returns a descriptive error at runtime.
//! Because [`PjRtClient::cpu`] fails, `ModelRuntime::load` fails, and all
//! artifact-dependent tests/benches take their existing "artifacts missing"
//! skip paths. Swapping this path dependency for the real `xla_extension`
//! crate re-enables PJRT execution with zero source changes.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT/XLA runtime unavailable: efmuon was built against the offline \
         `xla` stub (no libxla in this environment); point the `xla` path \
         dependency at xla_extension to execute HLO artifacts"
            .to_string(),
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
    }
}
