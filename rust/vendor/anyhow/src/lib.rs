//! Offline, dependency-free shim of the `anyhow` crate exposing exactly the
//! subset efmuon uses: [`Error`], [`Result`], the [`Context`] trait, and the
//! `anyhow!` / `bail!` macros. Drop-in: swap this path dependency for the
//! real crates.io `anyhow` and nothing in the workspace changes.

use std::fmt;

/// A context-chained error value. Like `anyhow::Error` it deliberately does
/// NOT implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` impl below coherent.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from anything printable (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// Outermost message (no chain).
    pub fn to_msg(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // flatten the std source chain into our own
        let mut msgs = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut tail: Option<Box<Error>> = None;
        for m in msgs.into_iter().rev() {
            tail = Some(Box::new(Error { msg: m, source: tail }));
        }
        Error { msg: e.to_string(), source: tail }
    }
}

/// `anyhow::Result` with the usual defaulted error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!`: build an [`Error`] from a message, a format string, or any
/// `Display` expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `bail!`: early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn conversion_and_context() {
        let e: Error = io_err().into();
        assert_eq!(e.to_msg(), "gone");
        let r: Result<()> = Err(io_err()).with_context(|| "reading x");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Err(anyhow!("plain"))
        }
        assert_eq!(inner(true).unwrap_err().to_msg(), "failed with code 7");
        assert_eq!(inner(false).unwrap_err().to_msg(), "plain");
        let s = String::from("owned");
        assert_eq!(anyhow!(s).to_msg(), "owned");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_msg(), "missing");
    }
}
