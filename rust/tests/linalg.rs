//! Property-based integration tests over the linear-algebra substrate.

use efmuon::linalg::matmul::{matmul, matmul_at, matmul_bt};
use efmuon::linalg::ns::{newton_schulz, NS_STEPS};
use efmuon::linalg::qr::orthonormalize;
use efmuon::linalg::svd::{jacobi_svd, low_rank_approx, top_singular, truncated_reconstruct};
use efmuon::linalg::{norms, Matrix};
use efmuon::util::proptest::check;
use efmuon::util::rng::Rng;

#[test]
fn prop_matmul_associativity_with_vectors() {
    // (A·B)·x == A·(B·x) within f32 tolerance
    check("matmul-assoc", 40, 11, |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 24);
        let n = g.usize_in(1, 24);
        let a = g.matrix_of(m, k);
        let b = g.matrix_of(k, n);
        let x = g.matrix_of(n, 1);
        let lhs = matmul(&matmul(&a, &b), &x);
        let rhs = matmul(&a, &matmul(&b, &x));
        let scale = 1.0 + lhs.max_abs();
        if lhs.max_abs_diff(&rhs) / scale < 1e-3 {
            Ok(())
        } else {
            Err(format!("diff {}", lhs.max_abs_diff(&rhs)))
        }
    });
}

#[test]
fn prop_transposed_matmul_variants_agree() {
    check("matmul-transposed", 40, 12, |g| {
        let a = g.matrix(1, 20);
        let rows_b = g.usize_in(1, 20);
        let b = g.matrix_of(rows_b, a.cols);
        let direct = matmul(&a, &b.transpose());
        let fused = matmul_bt(&a, &b);
        if direct.max_abs_diff(&fused) < 1e-3 * (1.0 + direct.max_abs()) {
            let cols_c = g.usize_in(1, 10);
            let c = g.matrix_of(a.rows, cols_c);
            let at1 = matmul(&a.transpose(), &c);
            let at2 = matmul_at(&a, &c);
            if at1.max_abs_diff(&at2) < 1e-3 * (1.0 + at1.max_abs()) {
                return Ok(());
            }
        }
        Err("transposed variants disagree".into())
    });
}

#[test]
fn prop_svd_reconstruction_and_ordering() {
    check("jacobi-svd", 25, 13, |g| {
        let a = g.matrix(1, 14);
        let (u, s, v) = jacobi_svd(&a);
        let r = truncated_reconstruct(&u, &s, &v, s.len());
        if r.max_abs_diff(&a) > 2e-3 * (1.0 + a.max_abs()) {
            return Err(format!("reconstruction err {}", r.max_abs_diff(&a)));
        }
        for w in s.windows(2) {
            if w[0] < w[1] - 1e-4 {
                return Err("singular values not sorted".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eckart_young_rank1() {
    // power-iteration rank-1 approximation residual ≈ sigma_2
    check("eckart-young", 20, 14, |g| {
        let a = g.matrix(2, 12);
        let mut rng = Rng::new(g.case as u64);
        let (sigma, u, v) = top_singular(&a, 300, &mut rng);
        let (_, s, _) = jacobi_svd(&a);
        if s[0] < 1e-4 {
            return Ok(()); // effectively zero matrix
        }
        // degenerate top spectrum makes power iteration slow; skip ties
        if s.len() > 1 && (s[0] - s[1]).abs() / s[0] < 0.05 {
            return Ok(());
        }
        if (sigma - s[0]).abs() / s[0] > 2e-2 {
            return Err(format!("sigma {sigma} vs {}", s[0]));
        }
        let mut resid = a.clone();
        for i in 0..a.rows {
            for j in 0..a.cols {
                resid.data[i * a.cols + j] -= sigma * u[i] * v[j];
            }
        }
        let r = norms::spectral_exact(&resid);
        let expected = if s.len() > 1 { s[1] as f64 } else { 0.0 };
        if (r - expected).abs() <= 5e-2 * (1.0 + expected) {
            Ok(())
        } else {
            Err(format!("residual {r} vs sigma2 {expected}"))
        }
    });
}

#[test]
fn prop_qr_orthonormal() {
    check("qr", 30, 15, |g| {
        let m = g.usize_in(2, 30);
        let n = g.usize_in(1, m.min(10));
        let a = g.matrix_of(m, n);
        let q = orthonormalize(&a);
        let qtq = matmul_at(&q, &q);
        for i in 0..n {
            for j in 0..n {
                let target = if i == j {
                    // zeroed columns (rank deficiency) give 0 on diagonal
                    if qtq.at(i, i) < 0.5 { 0.0 } else { 1.0 }
                } else {
                    0.0
                };
                if (qtq.at(i, j) - target).abs() > 1e-3 {
                    return Err(format!("QtQ[{i},{j}] = {}", qtq.at(i, j)));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_low_rank_projection_never_expands() {
    check("lowrank-contract", 25, 16, |g| {
        let a = g.matrix(2, 16);
        let r = g.usize_in(1, a.rows.min(a.cols));
        let mut rng = Rng::new(g.case as u64 + 99);
        let (q, b) = low_rank_approx(&a, r, 2, &mut rng);
        let rec = matmul(&q, &b);
        let err = rec.sub(&a).norm2_sq();
        if err <= a.norm2_sq() * (1.0 + 1e-6) {
            Ok(())
        } else {
            Err(format!("expansion: {err} > {}", a.norm2_sq()))
        }
    });
}

#[test]
fn prop_ns_bounds_singular_values() {
    check("newton-schulz", 12, 17, |g| {
        let m = g.usize_in(4, 24);
        let n = g.usize_in(4, 24);
        let a = g.matrix_of(m, n);
        if a.norm2() < 1e-3 {
            return Ok(());
        }
        let o = newton_schulz(&a, NS_STEPS);
        if !o.is_finite() {
            return Err("non-finite output".into());
        }
        let (_, s, _) = jacobi_svd(&o);
        for &sv in &s {
            // near-zero input singular values stay near zero; others land
            // in the Muon band
            if sv > 0.05 && !(0.3..1.7).contains(&sv) {
                return Err(format!("sv {sv} out of band"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_norm_inequalities() {
    // spectral <= frobenius <= nuclear <= sqrt(r) * frobenius
    check("norm-chain", 30, 18, |g| {
        let a = g.matrix(1, 12);
        let sp = norms::spectral_exact(&a);
        let fr = norms::fro(&a);
        let nu = norms::nuclear_exact(&a);
        let r = a.rows.min(a.cols) as f64;
        let tol = 1e-3 * (1.0 + fr);
        if sp <= fr + tol && fr <= nu + tol && nu <= r.sqrt() * fr + tol {
            Ok(())
        } else {
            Err(format!("chain violated: sp={sp} fr={fr} nu={nu}"))
        }
    });
}

#[test]
fn prop_dual_norm_holder() {
    // |<A,B>| <= ||A||_* ||B|| for (nuclear, spectral) and (l1, linf)
    check("holder", 30, 19, |g| {
        let m = g.usize_in(1, 10);
        let n = g.usize_in(1, 10);
        let a = g.matrix_of(m, n);
        let b = g.matrix_of(m, n);
        let inner = a.dot(&b).abs();
        let tol = 1e-3 * (1.0 + inner);
        if inner > norms::nuclear_exact(&a) * norms::spectral_exact(&b) + tol {
            return Err("nuclear/spectral Hölder violated".into());
        }
        if inner > norms::l1(&a) * norms::linf(&b) + tol {
            return Err("l1/linf Hölder violated".into());
        }
        Ok(())
    });
}

#[test]
fn ns_aligns_with_nuclear_norm() {
    // <G, NS(G)> ≈ ||G||_nuclear (the LMO pairing identity, NS-approximate)
    let mut rng = Rng::new(77);
    let g = Matrix::randn(16, 12, 1.0, &mut rng);
    let o = newton_schulz(&g, NS_STEPS);
    let inner = g.dot(&o);
    let nuc = norms::nuclear_exact(&g);
    assert!(inner > 0.6 * nuc, "inner {inner} vs nuclear {nuc}");
    assert!(inner < 1.4 * nuc, "inner {inner} vs nuclear {nuc}");
}
