//! End-to-end smoke test: full distributed EF21-Muon training through the
//! PJRT artifacts for a handful of steps. Requires `make artifacts`.

use efmuon::config::TrainConfig;
use efmuon::train::train;

fn artifacts_dir() -> Option<String> {
    for candidate in ["artifacts", "../artifacts"] {
        if std::path::Path::new(candidate).join("manifest.json").exists() {
            return Some(candidate.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

#[test]
fn compressed_training_descends_and_meters_bytes() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = TrainConfig {
        artifacts: dir,
        workers: 2,
        steps: 16,
        worker_comp: "top:0.25+nat".into(),
        server_comp: "id".into(),
        beta: 0.9,
        lr: 0.015,
        warmup: 3,
        corpus_tokens: 300_000,
        eval_every: 4,
        eval_batches: 2,
        use_ns_artifact: true,
        full_codec: true, // exercise the real wire codec end-to-end
        seed: 7,
        ..TrainConfig::default()
    };
    let report = train(&cfg).unwrap();
    assert_eq!(report.steps, 16);
    // loss must drop from ~ln(256)=5.545 within a few steps (compressed
    // estimators take a few rounds to catch up, so compare best-so-far)
    let first = report.curve.first().unwrap().eval_loss;
    let best = report
        .curve
        .iter()
        .map(|p| p.eval_loss)
        .fold(f32::INFINITY, f32::min);
    assert!(first > 5.0, "init eval {first}");
    assert!(best < first - 0.05, "{first} -> best {best}");
    // compressed uplink must be well below one model per step
    let per_step = report.total_w2s_bytes_per_worker as f64
        / report.steps as f64
        / report.model_bytes as f64;
    assert!(per_step < 0.25, "w2s per step = {per_step} of model size");
    // uncompressed downlink ≈ 1 model per step
    let s2w_per_step =
        report.total_s2w_bytes as f64 / report.steps as f64 / report.model_bytes as f64;
    assert!((s2w_per_step - 1.0).abs() < 0.1, "s2w per step = {s2w_per_step}");
}

#[test]
fn uncompressed_equals_gluon_costs() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = TrainConfig {
        artifacts: dir,
        workers: 2,
        steps: 3,
        worker_comp: "id".into(),
        server_comp: "id".into(),
        corpus_tokens: 300_000,
        eval_every: 10,
        eval_batches: 1,
        seed: 3,
        ..TrainConfig::default()
    };
    let report = train(&cfg).unwrap();
    let per_step = report.total_w2s_bytes_per_worker as f64
        / report.steps as f64
        / report.model_bytes as f64;
    // dense: one model per step (+ tiny headers)
    assert!((per_step - 1.0).abs() < 0.01, "{per_step}");
}
