//! Property tests over every compressor spec the codec grammar can parse
//! (see `compress::parse_spec`), on ragged layer shapes — wide, tall,
//! single-row/column and 1×1, with adversarial value patterns from
//! `util::proptest::Gen` (zeros, huge/tiny scales, magnitude ties):
//!
//! 1. wire-codec exactness: `decode(encode(msg)) == msg` and
//!    `len == wire_bytes()` for every spec;
//! 2. lossless chain: for lossless specs the full
//!    compress→encode→decode→decode chain reproduces the input bit-for-bit;
//! 3. contraction bounds (Definition 1): per-instance analytic bounds for
//!    the deterministic compressors, in-expectation bounds (mean over
//!    repetitions) for the randomized ones.

use efmuon::compress::quantize::ScaledSign;
use efmuon::compress::{codec, contraction_ratio, parse_spec};
use efmuon::linalg::Matrix;
use efmuon::util::proptest::{check, Gen};
use efmuon::util::rng::Rng;

/// Every spec family × representative parameters of the codec grammar.
const ALL_SPECS: &[&str] = &[
    "id",
    "nat",
    "top:0.15",
    "top:0.3+nat",
    "top:1",
    "rank:0.3",
    "rank:0.3+nat",
    "rank:1",
    "drop:0.35",
    "damp:0.6",
    "damp:1",
    "svdtop:1",
    "svdtop:2",
    "coltop:0.2",
    "coltop:1",
    "sign",
    "qsgd:1",
    "qsgd:7",
    "qsgd:127",
    "randk:0.2",
    "randk:1",
    "bf16",
];

/// Specs whose compression is the identity map (the codec round-trip is
/// exact for *every* spec; for these the whole chain is lossless).
const LOSSLESS_SPECS: &[&str] = &["id", "damp:1", "top:1", "randk:1"];

/// A ragged shape: mostly random dims, with forced extremes (vectors,
/// single cells, wide/tall strips) cycled in by case index.
fn ragged_shape(g: &mut Gen) -> (usize, usize) {
    const EXTREMES: &[(usize, usize)] = &[(1, 1), (1, 29), (23, 1), (2, 31), (31, 2)];
    if g.case % 3 == 0 {
        EXTREMES[g.case / 3 % EXTREMES.len()]
    } else {
        (g.usize_in(1, 20), g.usize_in(1, 20))
    }
}

#[test]
fn prop_codec_roundtrip_every_spec_ragged() {
    check("codec-ragged", 15, 71, |g| {
        let (m, n) = ragged_shape(g);
        let x = g.matrix_of(m, n);
        let mut rng = Rng::new(4000 + g.case as u64);
        for spec in ALL_SPECS {
            let mut c = parse_spec(spec).unwrap();
            let msg = c.compress(&x, &mut rng);
            let bytes = codec::encode(&msg);
            if bytes.len() != msg.wire_bytes() {
                return Err(format!(
                    "{spec} on {m}x{n}: encoded {} bytes != wire_bytes {}",
                    bytes.len(),
                    msg.wire_bytes()
                ));
            }
            let back = codec::decode(&bytes).map_err(|e| format!("{spec} on {m}x{n}: {e}"))?;
            if back != msg {
                return Err(format!("{spec} on {m}x{n}: codec roundtrip mismatch"));
            }
            if back.shape() != (m, n) {
                return Err(format!("{spec} on {m}x{n}: shape {:?}", back.shape()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lossless_chain_is_exact() {
    check("lossless-chain", 15, 72, |g| {
        let (m, n) = ragged_shape(g);
        let x = g.matrix_of(m, n);
        let mut rng = Rng::new(5000 + g.case as u64);
        for spec in LOSSLESS_SPECS {
            let mut c = parse_spec(spec).unwrap();
            let msg = c.compress(&x, &mut rng);
            let wire = codec::decode(&codec::encode(&msg))
                .map_err(|e| format!("{spec}: {e}"))?;
            let decoded = wire.decode();
            if decoded.data != x.data {
                return Err(format!("{spec} on {m}x{n}: chain is not bit-exact"));
            }
        }
        Ok(())
    });
}

/// Per-instance contraction bound `‖C(x)−x‖² ≤ bound·‖x‖²` for the
/// deterministic compressors (tight analytic α where one exists).
fn det_ratio_bound(spec: &str, x: &Matrix) -> Option<f64> {
    let numel = x.numel() as f64;
    let frac_k = |f: f64, d: f64| ((f * d).ceil()).clamp(1.0, d);
    match spec {
        "id" | "damp:1" | "top:1" => Some(0.0),
        "damp:0.6" => Some(0.16 + 1e-4), // (1-γ)²
        "top:0.15" => Some(1.0 - frac_k(0.15, numel) / numel),
        // TopK then Natural on survivors: dropped mass + per-entry rounding
        // error (≤ the entry itself) never exceeds the input energy
        "top:0.3+nat" => Some(1.0),
        "coltop:0.2" => {
            let cols = x.cols as f64;
            Some(1.0 - frac_k(0.2, cols) / cols)
        }
        "coltop:1" => Some(0.0 + 1e-9),
        "sign" => Some(1.0 - ScaledSign::alpha(x) + 1e-3),
        // orthogonal projection / truncated SVD: residual ≤ input (f32 slack)
        "rank:0.3" | "rank:1" | "svdtop:1" | "svdtop:2" => Some(1.0 + 1e-3),
        // nearest-level rounding with 0 on the grid: per-entry error ≤ |v|
        "qsgd:1" | "qsgd:7" | "qsgd:127" => Some(1.0),
        // RTNE cast: per-entry relative error ≤ 2⁻⁸
        "bf16" => Some((1.0f64 / 256.0).powi(2) + 1e-9),
        _ => None,
    }
}

#[test]
fn prop_deterministic_contraction_bounds() {
    check("det-contraction", 15, 73, |g| {
        let (m, n) = ragged_shape(g);
        let x = g.matrix_of(m, n);
        let mut rng = Rng::new(6000 + g.case as u64);
        for spec in ALL_SPECS {
            let bound = match det_ratio_bound(spec, &x) {
                Some(b) => b,
                None => continue,
            };
            let mut c = parse_spec(spec).unwrap();
            let ratio = contraction_ratio(&x, &c.compress(&x, &mut rng).decode());
            if ratio > bound + 1e-6 {
                return Err(format!("{spec} on {m}x{n}: ratio {ratio} > bound {bound}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_qsgd_error_within_half_step() {
    check("qsgd-halfstep", 15, 74, |g| {
        let (m, n) = ragged_shape(g);
        let x = g.matrix_of(m, n);
        let mut rng = Rng::new(6500 + g.case as u64);
        for (spec, levels) in [("qsgd:1", 1.0f32), ("qsgd:7", 7.0), ("qsgd:127", 127.0)] {
            let mut c = parse_spec(spec).unwrap();
            let y = c.compress(&x, &mut rng).decode();
            let scale = x.max_abs();
            let half = scale / levels / 2.0;
            for (a, b) in x.data.iter().zip(&y.data) {
                if (a - b).abs() > half + 1e-5 * scale.max(1.0) {
                    return Err(format!("{spec} on {m}x{n}: |{a} - {b}| > half-step {half}"));
                }
            }
        }
        Ok(())
    });
}

/// In-expectation bounds for the randomized compressors: mean contraction
/// ratio over repeated draws vs the analytic α (generous sampling slack —
/// the property must be robust, not a statistics exam).
#[test]
fn prop_randomized_contraction_in_expectation() {
    check("rand-contraction", 10, 75, |g| {
        let (m, n) = ragged_shape(g);
        let x = g.matrix_of(m, n);
        if x.norm2_sq() == 0.0 {
            return Ok(()); // ratio is defined as 0 on zero input
        }
        let numel = x.numel() as f64;
        let mut rng = Rng::new(7000 + g.case as u64);
        let mean = |spec: &str, reps: usize, rng: &mut Rng| -> f64 {
            let mut c = parse_spec(spec).unwrap();
            (0..reps)
                .map(|_| contraction_ratio(&x, &c.compress(&x, rng).decode()))
                .sum::<f64>()
                / reps as f64
        };

        // Natural: E ratio ≤ 1/8 (Horváth et al.; the worst single value,
        // v = (4/3)·2^k, attains exactly 1/8) + sampling slack
        let nat = mean("nat", 40, &mut rng);
        if nat > 1.0 / 8.0 + 0.08 {
            return Err(format!("nat on {m}x{n}: mean ratio {nat}"));
        }

        // Dropout: E ratio = 1 − p exactly
        let drop = mean("drop:0.35", 200, &mut rng);
        if (drop - 0.65).abs() > 0.15 {
            return Err(format!("drop:0.35 on {m}x{n}: mean ratio {drop}"));
        }

        // RandK: E ratio = 1 − k/d in any coordinate-separable norm
        let k = (0.2 * numel).ceil().clamp(1.0, numel);
        let randk = mean("randk:0.2", 80, &mut rng);
        if randk > 1.0 - k / numel + 0.2 {
            return Err(format!("randk:0.2 on {m}x{n}: mean ratio {randk}"));
        }

        // RankK+Natural: no tight closed form (rounding enters through both
        // factors); must still contract on average, with headroom
        let rknat = mean("rank:0.3+nat", 30, &mut rng);
        if rknat > 1.0 + 0.2 {
            return Err(format!("rank:0.3+nat on {m}x{n}: mean ratio {rknat}"));
        }
        Ok(())
    });
}
