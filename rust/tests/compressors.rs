//! Compressor contract tests: Definition 1 contraction in each compressor's
//! declared norm family, exact wire-codec roundtrips, and the analytic α
//! formulas from paper §D.

use efmuon::compress::{codec, contraction_ratio, parse_spec, Compressor, NormFamily, Payload};
use efmuon::linalg::{norms, Matrix};
use efmuon::util::proptest::check;
use efmuon::util::rng::Rng;

const ALL_SPECS: &[&str] = &[
    "id",
    "nat",
    "top:0.1",
    "top:0.25",
    "top:0.25+nat",
    "rank:0.2",
    "rank:0.2+nat",
    "drop:0.6",
    "damp:0.7",
    "svdtop:2",
    "coltop:0.3",
    "sign",
    "qsgd:4",
    "randk:0.25",
    "bf16",
];

/// E‖C(X)−X‖₂² ≤ (1−α)‖X‖₂² with the analytic α per compressor (where one
/// exists); for randomized compressors we average over repetitions.
#[test]
fn prop_euclidean_contraction() {
    check("contraction", 20, 21, |g| {
        let m = g.usize_in(3, 18);
        let n = g.usize_in(3, 18);
        let x = g.matrix_of(m, n);
        if x.norm2_sq() == 0.0 {
            return Ok(());
        }
        let mut rng = Rng::new(1000 + g.case as u64);
        for spec in ALL_SPECS {
            let mut c = parse_spec(spec).unwrap();
            let reps = 30;
            let mean_ratio: f64 = (0..reps)
                .map(|_| contraction_ratio(&x, &c.compress(&x, &mut rng).decode()))
                .sum::<f64>()
                / reps as f64;
            // every compressor must satisfy ratio <= 1 (+ sampling slack)
            if mean_ratio > 1.0 + 0.25 {
                return Err(format!("{spec}: mean ratio {mean_ratio}"));
            }
        }
        Ok(())
    });
}

#[test]
fn topk_exact_alpha() {
    // deterministic TopK: ratio <= 1 - k/d exactly
    let mut rng = Rng::new(31);
    for _ in 0..20 {
        let x = Matrix::randn(11, 13, 1.0, &mut rng);
        let mut c = parse_spec("top:0.2").unwrap();
        let y = c.compress(&x, &mut rng).decode();
        let d = 11.0 * 13.0;
        let k = (0.2f64 * d).ceil();
        assert!(contraction_ratio(&x, &y) <= 1.0 - k / d + 1e-9);
    }
}

#[test]
fn dropout_alpha_is_p() {
    let mut rng = Rng::new(32);
    let x = Matrix::randn(10, 10, 1.0, &mut rng);
    let mut c = parse_spec("drop:0.4").unwrap();
    let n = 6000;
    let mean: f64 = (0..n)
        .map(|_| contraction_ratio(&x, &c.compress(&x, &mut rng).decode()))
        .sum::<f64>()
        / n as f64;
    assert!((mean - 0.6).abs() < 0.03, "mean {mean}");
}

#[test]
fn natural_alpha_bound() {
    // Horváth et al: alpha = 8/9 ⇒ ratio <= 1/9
    let mut rng = Rng::new(33);
    let x = Matrix::randn(30, 30, 2.0, &mut rng);
    let mut c = parse_spec("nat").unwrap();
    let n = 40;
    let mean: f64 = (0..n)
        .map(|_| contraction_ratio(&x, &c.compress(&x, &mut rng).decode()))
        .sum::<f64>()
        / n as f64;
    assert!(mean <= 1.0 / 9.0 + 0.01, "mean {mean}");
}

#[test]
fn svdtop_contraction_in_schatten_norms() {
    // Definition 10: contraction w.r.t. spectral, nuclear AND frobenius
    let mut rng = Rng::new(34);
    for _ in 0..10 {
        let x = Matrix::randn(9, 7, 1.0, &mut rng);
        let mut c = parse_spec("svdtop:3").unwrap();
        let y = c.compress(&x, &mut rng).decode();
        let diff = y.sub(&x);
        assert!(norms::spectral_exact(&diff) <= norms::spectral_exact(&x) + 1e-4);
        assert!(norms::nuclear_exact(&diff) <= norms::nuclear_exact(&x) + 1e-4);
        assert!(norms::fro(&diff) <= norms::fro(&x) + 1e-6);
    }
}

#[test]
fn coltop_contraction_in_l2q_norms() {
    // Definition 13: contraction in mixed l_{2,q} norms (q = 1, 2)
    let mut rng = Rng::new(35);
    for _ in 0..10 {
        let x = Matrix::randn(8, 12, 1.0, &mut rng);
        let mut c = parse_spec("coltop:0.25").unwrap();
        let y = c.compress(&x, &mut rng).decode();
        let diff = y.sub(&x);
        for q in [1.0, 2.0] {
            assert!(
                norms::lpq(&diff, 2.0, q) <= norms::lpq(&x, 2.0, q) + 1e-5,
                "q={q}"
            );
        }
    }
}

#[test]
fn prop_codec_roundtrip_and_size() {
    check("codec", 30, 22, |g| {
        let m = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let x = g.matrix_of(m, n);
        let mut rng = Rng::new(2000 + g.case as u64);
        for spec in ALL_SPECS {
            let mut c = parse_spec(spec).unwrap();
            let msg = c.compress(&x, &mut rng);
            let bytes = codec::encode(&msg);
            if bytes.len() != msg.wire_bytes() {
                return Err(format!(
                    "{spec}: encoded {} != wire_bytes {}",
                    bytes.len(),
                    msg.wire_bytes()
                ));
            }
            let back = codec::decode(&bytes).map_err(|e| format!("{spec}: {e}"))?;
            if back != msg {
                return Err(format!("{spec}: roundtrip mismatch"));
            }
        }
        Ok(())
    });
}

#[test]
fn large_matrix_uses_u32_indices() {
    // numel > 65536 forces 4-byte indices; below, 2-byte
    let mut rng = Rng::new(36);
    let small = Matrix::randn(64, 64, 1.0, &mut rng);
    let large = Matrix::randn(300, 300, 1.0, &mut rng);
    let mut c = parse_spec("top:0.01").unwrap();
    let ms = c.compress(&small, &mut rng);
    let ml = c.compress(&large, &mut rng);
    if let (Payload::Sparse { idx: is_, .. }, Payload::Sparse { idx: il, .. }) =
        (&ms.payload, &ml.payload)
    {
        let per_small = (ms.wire_bytes() - efmuon::compress::HEADER_BYTES) as f64 / is_.len() as f64;
        let per_large = (ml.wire_bytes() - efmuon::compress::HEADER_BYTES) as f64 / il.len() as f64;
        assert!((per_small - 6.0).abs() < 1e-9, "{per_small}");
        assert!((per_large - 8.0).abs() < 1e-9, "{per_large}");
    } else {
        panic!("expected sparse payloads");
    }
}

#[test]
fn families_declared() {
    assert_eq!(parse_spec("top:0.1").unwrap().family(), NormFamily::Euclidean);
    assert_eq!(parse_spec("svdtop:1").unwrap().family(), NormFamily::Primal);
    assert_eq!(parse_spec("damp:0.5").unwrap().family(), NormFamily::Primal);
    assert!(parse_spec("id").unwrap().is_identity());
    assert!(!parse_spec("nat").unwrap().is_identity());
}

#[test]
fn rank_plus_nat_cheaper_than_rank() {
    let mut rng = Rng::new(37);
    let x = Matrix::randn(64, 96, 1.0, &mut rng);
    let b1 = parse_spec("rank:0.2").unwrap().compress(&x, &mut rng).wire_bytes();
    let b2 = parse_spec("rank:0.2+nat").unwrap().compress(&x, &mut rng).wire_bytes();
    assert!(b2 < b1, "{b2} vs {b1}");
    // 9-bit natural packing: values shrink ~3.5x
    let ratio = b2 as f64 / b1 as f64;
    assert!(ratio < 0.4, "ratio {ratio}");
}

#[test]
fn decode_never_panics_on_garbage() {
    // fuzz: random byte strings and truncations of valid messages must
    // yield Err, never a panic or an out-of-bounds decode
    let mut rng = Rng::new(99);
    for _ in 0..2000 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let _ = codec::decode(&bytes); // must not panic
    }
    // truncations of a real message
    let x = Matrix::randn(9, 9, 1.0, &mut rng);
    let mut c = parse_spec("top:0.2+nat").unwrap();
    let full = codec::encode(&c.compress(&x, &mut rng));
    for cut in 0..full.len() {
        let _ = codec::decode(&full[..cut]); // must not panic
    }
}

/// Paper §D.1 "compression via norm selection": LMO directions under
/// certain norms are *naturally compressed* objects — the nuclear-ball LMO
/// is rank-1 ((m+n+1) floats instead of m·n), the ℓ1-ball LMO is 1-sparse.
#[test]
fn lmo_induced_compression_costs() {
    use efmuon::lmo::{Lmo, LmoKind};
    let mut rng = Rng::new(40);
    let g = Matrix::randn(40, 60, 1.0, &mut rng);

    // nuclear LMO -> exactly rank 1
    let z = Lmo::new(LmoKind::NuclearRank1).step(&g, 1.0, &mut rng);
    let (_, s, _) = efmuon::linalg::svd::jacobi_svd(&z);
    assert!(s[1] < 1e-4 * s[0].max(1e-12), "rank>1: s={:?}", &s[..2]);
    // factored wire cost beats dense by ~ mn/(m+n)
    let dense = 40 * 60 * 4;
    let factored = (40 + 60 + 1) * 4;
    assert!(factored * 20 < dense);

    // l1 LMO -> exactly one nonzero
    let z = Lmo::new(LmoKind::L1Top1).step(&g, 1.0, &mut rng);
    assert_eq!(z.data.iter().filter(|v| **v != 0.0).count(), 1);
}

#[test]
fn compressed_value_survives_transport_exactly() {
    // what the worker's EF21 state adds (msg.decode()) must equal what the
    // server decodes after the real wire roundtrip — bit for bit
    let mut rng = Rng::new(38);
    let x = Matrix::randn(33, 17, 1.0, &mut rng);
    for spec in ALL_SPECS {
        let mut c = parse_spec(spec).unwrap();
        let msg = c.compress(&x, &mut rng);
        let local = msg.decode();
        let wire = codec::decode(&codec::encode(&msg)).unwrap().decode();
        assert_eq!(local.data, wire.data, "{spec}");
    }
}
