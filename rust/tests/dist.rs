//! Distributed-stack integration: threaded coordinator vs the sequential
//! reference implementation, transport-mode equivalence, byte metering,
//! async round pipelining, and fault tolerance (fail-stop errors surface
//! cleanly; under a [`FaultPolicy`] stragglers are skipped at the deadline,
//! their late uplinks land, and dead workers respawn within budget).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use efmuon::dist::coordinator::{Coordinator, CoordinatorCfg};
use efmuon::dist::fault::{FaultKind, FaultPlan, FaultPolicy};
use efmuon::dist::service::GradService;
use efmuon::dist::{RoundMode, TransportMode};
use efmuon::funcs::{Objective, Quadratics};
use efmuon::linalg::matrix::{Layers, Matrix};
use efmuon::lmo::LmoKind;
use efmuon::opt::ef21::Ef21MuonSeq;
use efmuon::opt::{LayerGeometry, Schedule};
use efmuon::spec::CompSpec;
use efmuon::trace::Tracer;
use efmuon::util::rng::Rng;

fn geom() -> Vec<LayerGeometry> {
    vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }]
}

/// Parse a compressor spec string (test-side boundary).
fn comp(s: &str) -> CompSpec {
    CompSpec::parse(s).unwrap()
}

fn mk_coord(q: Quadratics, spec: &str, mode: TransportMode, beta: f32) -> (Coordinator, GradService) {
    let mut rng = Rng::new(61);
    let x0 = q.init(&mut rng);
    let n = q.num_workers();
    let svc = GradService::spawn_objective(Box::new(q), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp(spec),
            server_comp: CompSpec::Id,
            beta,
            schedule: Schedule::constant(0.03),
            transport: mode,
            round_mode: RoundMode::Sync,
            seed: 5,
            use_ns_artifact: false,
            fault: FaultPolicy::off(),
            fault_plan: None,
            start_step: 0,
            tracer: Tracer::Noop,
        },
    )
    .unwrap();
    (coord, svc)
}

#[test]
fn counted_and_encoded_transport_agree() {
    // deterministic gradients + same seeds ⇒ identical trajectories under
    // both transports (the codec must be lossless)
    let mut rng = Rng::new(62);
    let mk = || Quadratics::new(3, 10, 0.5, 0.0, &mut Rng::new(62));
    let _ = &mut rng;
    let (mut a, _svc_a) = mk_coord(mk(), "top:0.3+nat", TransportMode::Counted, 1.0);
    let (mut b, _svc_b) = mk_coord(mk(), "top:0.3+nat", TransportMode::Encoded, 1.0);
    for _ in 0..20 {
        let sa = a.round().unwrap();
        let sb = b.round().unwrap();
        assert_eq!(sa.w2s_bytes_per_worker, sb.w2s_bytes_per_worker);
        assert_eq!(sa.s2w_bytes, sb.s2w_bytes);
    }
    for (pa, pb) in a.params().iter().zip(b.params()) {
        assert_eq!(pa.data, pb.data, "trajectory diverged between transports");
    }
}

#[test]
fn threaded_matches_sequential_reference() {
    // same objective/seed/config: the threaded coordinator must follow the
    // sequential state machine exactly (deterministic compressors)
    let mk = || Quadratics::new(4, 8, 0.5, 0.0, &mut Rng::new(63));
    let q_seq = mk();
    // NOTE: sequential driver inits x0 via obj.init with seed 5 -> replicate
    let mut seq = Ef21MuonSeq::new(
        &q_seq,
        geom(),
        "top:0.25",
        "id",
        1.0,
        Schedule::constant(0.03),
        false,
        5,
    )
    .unwrap();

    let q_dist = mk();
    let mut rng5 = Rng::new(5);
    let x0 = q_dist.init(&mut rng5);
    assert_eq!(x0[0].data, seq.params()[0].data, "identical init required");
    let n = q_dist.num_workers();
    let svc = GradService::spawn_objective(Box::new(q_dist), 5);
    let mut coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp("top:0.25"),
            server_comp: CompSpec::Id,
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Encoded,
            round_mode: RoundMode::Sync,
            seed: 5,
            use_ns_artifact: false,
            fault: FaultPolicy::off(),
            fault_plan: None,
            start_step: 0,
            tracer: Tracer::Noop,
        },
    )
    .unwrap();

    for k in 0..25 {
        let s = seq.step(&q_seq);
        let d = coord.round().unwrap();
        assert_eq!(s.w2s_bytes, d.w2s_bytes_per_worker, "step {k}: bytes");
        assert_eq!(d.absorbed_step, Some(k), "sync absorbs the issued round");
        let diff = seq.params()[0].max_abs_diff(&coord.params()[0]);
        assert!(diff < 1e-6, "step {k}: params diverged by {diff}");
    }
}

#[test]
fn byte_meters_accumulate_correctly() {
    let q = Quadratics::new(3, 100, 0.5, 0.0, &mut Rng::new(64));
    let (mut coord, _svc) = mk_coord(q, "top:0.1", TransportMode::Counted, 1.0);
    let mut expect_w2s = 0u64;
    let mut expect_s2w = 0u64;
    for _ in 0..10 {
        let s = coord.round().unwrap();
        expect_w2s += s.w2s_bytes_per_worker as u64;
        expect_s2w += s.s2w_bytes as u64;
    }
    assert_eq!(coord.meter().w2s(), expect_w2s);
    assert_eq!(coord.meter().s2w(), expect_s2w);
    assert_eq!(coord.meter().rounds_issued(), 10);
    assert_eq!(coord.meter().rounds_absorbed(), 10);
    // 3 workers: aggregate = 3x per-worker
    assert_eq!(coord.meter().w2s_all(), 3 * expect_w2s);
    // the serializable snapshot mirrors every counter
    let snap = coord.meter().snapshot();
    assert_eq!(snap.w2s_per_worker, expect_w2s);
    assert_eq!(snap.w2s_all, 3 * expect_w2s);
    assert_eq!(snap.s2w_total, expect_s2w);
    assert_eq!(snap.rounds_issued, 10);
    assert_eq!(snap.rounds_absorbed, 10);
}

#[test]
fn compressed_run_converges_with_stochastic_gradients() {
    let q = Quadratics::new(4, 12, 0.5, 0.3, &mut Rng::new(65));
    let (mut coord, _svc) = mk_coord(q, "rank:0.4", TransportMode::Counted, 0.5);
    let first = coord.eval().unwrap();
    for _ in 0..400 {
        coord.round().unwrap();
    }
    let last = coord.eval().unwrap();
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn eval_is_deterministic_given_params() {
    let q = Quadratics::new(2, 6, 0.5, 0.0, &mut Rng::new(66));
    let (coord, _svc) = mk_coord(q, "id", TransportMode::Counted, 1.0);
    let a = coord.eval().unwrap();
    let b = coord.eval().unwrap();
    assert_eq!(a, b);
}

// ---------------------------------------------------------------------------
// Async pipelined rounds
// ---------------------------------------------------------------------------

fn mk_async(lookahead: usize, seed_obj: u64) -> (Coordinator, GradService) {
    let q = Quadratics::new(3, 10, 0.5, 0.0, &mut Rng::new(seed_obj));
    let x0 = q.init(&mut Rng::new(61));
    let n = q.num_workers();
    let svc = GradService::spawn_objective(Box::new(q), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp("top:0.3"),
            server_comp: comp("top:0.5"),
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Counted,
            round_mode: RoundMode::Async { lookahead },
            seed: 5,
            use_ns_artifact: false,
            fault: FaultPolicy::off(),
            fault_plan: None,
            start_step: 0,
            tracer: Tracer::Noop,
        },
    )
    .unwrap();
    (coord, svc)
}

#[test]
fn async_pipeline_fills_and_drains() {
    let (mut coord, _svc) = mk_async(2, 67);
    // the first two calls only issue (nothing absorbed yet)
    let s0 = coord.round().unwrap();
    assert_eq!(s0.absorbed_step, None);
    assert!(s0.train_loss.is_nan());
    assert_eq!(s0.w2s_bytes_per_worker, 0);
    assert_eq!(coord.pending_rounds(), 1);
    let s1 = coord.round().unwrap();
    assert_eq!(s1.absorbed_step, None);
    assert_eq!(coord.pending_rounds(), 2);
    // from the third call on, the absorbed round trails the issued by 2
    let s2 = coord.round().unwrap();
    assert_eq!(s2.step, 2);
    assert_eq!(s2.absorbed_step, Some(0));
    assert!(s2.train_loss.is_finite());
    assert!(s2.w2s_bytes_per_worker > 0);
    assert_eq!(coord.pending_rounds(), 2);
    // drain lands the two in-flight rounds in order
    let drained = coord.drain().unwrap();
    assert_eq!(drained.len(), 2);
    assert_eq!(drained[0].absorbed_step, Some(1));
    assert_eq!(drained[1].absorbed_step, Some(2));
    assert_eq!(coord.pending_rounds(), 0);
    assert_eq!(coord.meter().rounds_issued(), 3);
    assert_eq!(coord.meter().rounds_absorbed(), 3);
}

#[test]
fn async_runs_are_deterministic() {
    // reply arrival order must not influence the trajectory: two identical
    // async runs produce bit-identical parameters and meters
    let run = || -> (Vec<f32>, u64, u64) {
        let (mut coord, _svc) = mk_async(1, 68);
        coord.run(30).unwrap();
        (
            coord.params()[0].data.clone(),
            coord.meter().w2s(),
            coord.meter().s2w(),
        )
    };
    let (pa, wa, sa) = run();
    let (pb, wb, sb) = run();
    assert_eq!(pa, pb, "async trajectory must be deterministic");
    assert_eq!(wa, wb);
    assert_eq!(sa, sb);
}

#[test]
fn run_reports_every_round_once() {
    let (mut coord, _svc) = mk_async(1, 69);
    let stats = coord.run(12).unwrap();
    // 12 per-call entries + 1 drained tail entry
    assert_eq!(stats.len(), 13);
    let absorbed: Vec<usize> = stats.iter().filter_map(|s| s.absorbed_step).collect();
    assert_eq!(absorbed, (0..12).collect::<Vec<_>>(), "each round absorbed exactly once");
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Wraps [`Quadratics`] and panics inside gradient evaluation for one
/// worker once that worker has been called `panic_after` times — simulating
/// a crash mid-round (or mid-init for `panic_after = 0`).
struct PanicObjective {
    inner: Quadratics,
    panic_worker: usize,
    panic_after: usize,
    calls: AtomicUsize,
}

impl PanicObjective {
    fn new(panic_worker: usize, panic_after: usize, seed: u64) -> Self {
        PanicObjective {
            inner: Quadratics::new(3, 8, 0.5, 0.0, &mut Rng::new(seed)),
            panic_worker,
            panic_after,
            calls: AtomicUsize::new(0),
        }
    }
}

impl Objective for PanicObjective {
    fn num_workers(&self) -> usize {
        self.inner.num_workers()
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        self.inner.layer_shapes()
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        self.inner.loss(x)
    }

    fn loss_j(&self, j: usize, x: &[Matrix]) -> f64 {
        self.inner.loss_j(j, x)
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        if j == self.panic_worker {
            let seen = self.calls.fetch_add(1, Ordering::SeqCst);
            if seen >= self.panic_after {
                panic!("injected fault in worker {j}");
            }
        }
        self.inner.grad_j(j, x)
    }

    fn init(&self, rng: &mut Rng) -> Layers {
        self.inner.init(rng)
    }
}

fn mk_fault_coord(obj: PanicObjective, mode: RoundMode) -> anyhow::Result<(Coordinator, GradService)> {
    let x0 = obj.init(&mut Rng::new(70));
    let n = obj.num_workers();
    let svc = GradService::spawn_objective(Box::new(obj), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp("top:0.3"),
            server_comp: CompSpec::Id,
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Counted,
            round_mode: mode,
            seed: 5,
            use_ns_artifact: false,
            fault: FaultPolicy::off(),
            fault_plan: None,
            start_step: 0,
            tracer: Tracer::Noop,
        },
    )?;
    Ok((coord, svc))
}

#[test]
fn worker_panic_mid_round_surfaces_clean_error() {
    // worker 1: 1 init call + 2 good rounds, then panics in round 2. The
    // leader must return Err from run() — not hang on the dead worker, not
    // poison the channel for the survivors.
    let obj = PanicObjective::new(1, 3, 71);
    let (mut coord, _svc) = mk_fault_coord(obj, RoundMode::Sync).unwrap();
    let err = coord.run(10).expect_err("run must fail once worker 1 dies");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 1"), "error should name the worker: {msg}");
    // the coordinator stays usable as a value (Drop joins cleanly) and
    // further rounds keep failing fast instead of hanging
    assert!(coord.round().is_err());
}

#[test]
fn worker_panic_mid_round_surfaces_in_async_mode() {
    let obj = PanicObjective::new(2, 4, 72);
    let (mut coord, _svc) = mk_fault_coord(obj, RoundMode::Async { lookahead: 1 }).unwrap();
    let err = coord.run(10).expect_err("async run must fail once worker 2 dies");
    assert!(format!("{err:#}").contains("worker 2"));
}

#[test]
fn worker_panic_during_init_fails_spawn() {
    let obj = PanicObjective::new(0, 0, 73);
    let err = match mk_fault_coord(obj, RoundMode::Sync) {
        Err(e) => e,
        Ok(_) => panic!("spawn must fail when a worker dies during init"),
    };
    assert!(format!("{err:#}").contains("worker 0"), "{err:#}");
}

#[test]
fn async_worker_death_mid_flight_fails_drain_promptly() {
    // with rounds in flight, a dead worker must surface from drain() as a
    // clean Err (its panic guard queues a Failed reply), never a hang
    let obj = PanicObjective::new(1, 2, 74);
    let (mut coord, _svc) =
        mk_fault_coord(obj, RoundMode::Async { lookahead: 2 }).unwrap();
    // two issuing calls fill the pipeline without absorbing anything;
    // worker 1's panic (its round-1 gradient) happens while both rounds
    // are still in flight
    assert_eq!(coord.round().unwrap().absorbed_step, None);
    assert_eq!(coord.round().unwrap().absorbed_step, None);
    let err = coord.drain().expect_err("drain must surface the dead worker");
    assert!(format!("{err:#}").contains("worker 1"), "{err:#}");
    // the failure latches: later rounds fail fast instead of re-entering
    // the protocol against a dead pool
    assert!(coord.round().is_err());
}

// ---------------------------------------------------------------------------
// Fault policy: straggler deadlines, quorum absorption, respawn
// ---------------------------------------------------------------------------

fn mk_policy_coord(
    workers: usize,
    dim: usize,
    policy: &str,
    plan: Option<FaultPlan>,
    mode: RoundMode,
) -> (Coordinator, GradService) {
    let q = Quadratics::new(workers, dim, 0.5, 0.0, &mut Rng::new(75));
    let x0 = q.init(&mut Rng::new(75));
    let n = q.num_workers();
    let svc = GradService::spawn_objective(Box::new(q), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp("top:0.3"),
            server_comp: CompSpec::Id,
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Counted,
            round_mode: mode,
            seed: 5,
            use_ns_artifact: false,
            fault: FaultPolicy::parse(policy).unwrap(),
            fault_plan: plan.map(Arc::new),
            start_step: 0,
            tracer: Tracer::Noop,
        },
    )
    .unwrap();
    (coord, svc)
}

#[test]
fn respawn_relaunches_dead_worker_and_run_completes() {
    // worker 1 crashes at round 3; with a respawn budget the run must
    // complete: the crash round absorbs over the quorum (not a straggler —
    // a corpse can't be late) and the replacement serves every later round
    let plan = FaultPlan::new().with(1, 3, FaultKind::Panic);
    let (mut coord, _svc) = mk_policy_coord(
        3,
        8,
        "deadline:0,quorum:1,respawns:2,backoff:0",
        Some(plan),
        RoundMode::Sync,
    );
    coord.run(10).unwrap();
    let m = coord.meter();
    assert_eq!(m.respawns(), 1);
    assert_eq!(m.stragglers(), 0, "a crash is not a straggler");
    assert_eq!(m.partial_rounds(), 1, "only the crash round absorbs partially");
    assert!(coord.params()[0].data.iter().all(|v| v.is_finite()));
    assert!(coord.eval().unwrap().is_finite());
}

#[test]
fn respawn_budget_exhausted_is_terminal() {
    // the same id crashing twice against a budget of one must latch a
    // terminal error that names the worker and the consumed budget
    let plan = FaultPlan::new()
        .with(1, 2, FaultKind::Panic)
        .with(1, 5, FaultKind::Panic);
    let (mut coord, _svc) = mk_policy_coord(
        3,
        8,
        "deadline:0,quorum:1,respawns:1,backoff:0",
        Some(plan),
        RoundMode::Sync,
    );
    let err = coord.run(10).expect_err("second crash exceeds the budget");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 1") && msg.contains("respawn"), "{msg}");
    assert_eq!(coord.meter().respawns(), 1);
    assert!(coord.round().is_err(), "terminal failure latches");
}

#[test]
fn straggler_is_skipped_then_its_late_uplink_lands() {
    // deadline 200ms, injected delay 300ms: round 2 absorbs without
    // worker 2 (one straggler, one partial round), and worker 2 wakes well
    // before round 3's deadline (~2 deadlines after round 2's broadcast) —
    // so its late round-2 uplink folds into the estimator and every later
    // round is full again; 100ms of scheduler margin on both sides
    let plan = FaultPlan::new().with(2, 2, FaultKind::DelayMs(300));
    let (mut coord, _svc) = mk_policy_coord(
        3,
        8,
        "deadline:200,quorum:0.5,respawns:0,backoff:0",
        Some(plan),
        RoundMode::Sync,
    );
    coord.run(6).unwrap();
    let m = coord.meter();
    assert_eq!(m.stragglers(), 1);
    assert_eq!(m.partial_rounds(), 1);
    assert_eq!(m.respawns(), 0);
    // the late uplink is metered into the aggregate direction: all
    // 3 workers x 6 rounds of uplink bytes are accounted for even though
    // one of them arrived after its round absorbed
    assert_eq!(m.w2s_all(), 3 * m.w2s(), "late uplink bytes must be metered");
    assert!(coord.eval().unwrap().is_finite());
}

#[test]
fn dropped_reply_is_skipped_and_never_owed_forever() {
    // a Drop fault never replies at all (federated non-participation): the
    // round absorbs over the quorum and the run completes; the missing
    // uplink shows up as exactly one worker-round of bytes never sent
    let plan = FaultPlan::new().with(0, 1, FaultKind::Drop);
    let (mut coord, _svc) = mk_policy_coord(
        3,
        8,
        "deadline:150,quorum:0.5,respawns:0,backoff:0",
        Some(plan),
        RoundMode::Sync,
    );
    coord.run(5).unwrap();
    let m = coord.meter();
    assert_eq!(m.stragglers(), 1);
    assert_eq!(m.partial_rounds(), 1);
    let per_round = m.w2s() / 5;
    assert_eq!(
        m.w2s_all(),
        3 * m.w2s() - per_round,
        "exactly one worker-round of uplink bytes is missing"
    );
}
