//! Distributed-stack integration: threaded coordinator vs the sequential
//! reference implementation, transport-mode equivalence, byte metering.

use efmuon::dist::coordinator::{Coordinator, CoordinatorCfg};
use efmuon::dist::service::GradService;
use efmuon::dist::TransportMode;
use efmuon::funcs::{Objective, Quadratics};
use efmuon::lmo::LmoKind;
use efmuon::opt::ef21::Ef21MuonSeq;
use efmuon::opt::{LayerGeometry, Schedule};
use efmuon::util::rng::Rng;

fn geom() -> Vec<LayerGeometry> {
    vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }]
}

fn mk_coord(q: Quadratics, spec: &str, mode: TransportMode, beta: f32) -> (Coordinator, GradService) {
    let mut rng = Rng::new(61);
    let x0 = q.init(&mut rng);
    let n = q.num_workers();
    let svc = GradService::spawn_objective(Box::new(q), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: spec.into(),
            server_comp: "id".into(),
            beta,
            schedule: Schedule::constant(0.03),
            transport: mode,
            seed: 5,
            use_ns_artifact: false,
        },
    )
    .unwrap();
    (coord, svc)
}

#[test]
fn counted_and_encoded_transport_agree() {
    // deterministic gradients + same seeds ⇒ identical trajectories under
    // both transports (the codec must be lossless)
    let mut rng = Rng::new(62);
    let mk = || Quadratics::new(3, 10, 0.5, 0.0, &mut Rng::new(62));
    let _ = &mut rng;
    let (mut a, _svc_a) = mk_coord(mk(), "top:0.3+nat", TransportMode::Counted, 1.0);
    let (mut b, _svc_b) = mk_coord(mk(), "top:0.3+nat", TransportMode::Encoded, 1.0);
    for _ in 0..20 {
        let sa = a.round().unwrap();
        let sb = b.round().unwrap();
        assert_eq!(sa.w2s_bytes_per_worker, sb.w2s_bytes_per_worker);
    }
    for (pa, pb) in a.params().iter().zip(b.params()) {
        assert_eq!(pa.data, pb.data, "trajectory diverged between transports");
    }
}

#[test]
fn threaded_matches_sequential_reference() {
    // same objective/seed/config: the threaded coordinator must follow the
    // sequential state machine exactly (deterministic compressors)
    let mk = || Quadratics::new(4, 8, 0.5, 0.0, &mut Rng::new(63));
    let q_seq = mk();
    // NOTE: sequential driver inits x0 via obj.init with seed 5 -> replicate
    let mut seq = Ef21MuonSeq::new(
        &q_seq,
        geom(),
        "top:0.25",
        "id",
        1.0,
        Schedule::constant(0.03),
        false,
        5,
    )
    .unwrap();

    let q_dist = mk();
    let mut rng5 = Rng::new(5);
    let x0 = q_dist.init(&mut rng5);
    assert_eq!(x0[0].data, seq.params()[0].data, "identical init required");
    let n = q_dist.num_workers();
    let svc = GradService::spawn_objective(Box::new(q_dist), 5);
    let mut coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: "top:0.25".into(),
            server_comp: "id".into(),
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Encoded,
            seed: 5,
            use_ns_artifact: false,
        },
    )
    .unwrap();

    for k in 0..25 {
        let s = seq.step(&q_seq);
        let d = coord.round().unwrap();
        assert_eq!(s.w2s_bytes, d.w2s_bytes_per_worker, "step {k}: bytes");
        let diff = seq.params()[0].max_abs_diff(&coord.params()[0]);
        assert!(diff < 1e-6, "step {k}: params diverged by {diff}");
    }
}

#[test]
fn byte_meters_accumulate_correctly() {
    let q = Quadratics::new(3, 100, 0.5, 0.0, &mut Rng::new(64));
    let (mut coord, _svc) = mk_coord(q, "top:0.1", TransportMode::Counted, 1.0);
    let mut expect_w2s = 0u64;
    let mut expect_s2w = 0u64;
    for _ in 0..10 {
        let s = coord.round().unwrap();
        expect_w2s += s.w2s_bytes_per_worker as u64;
        expect_s2w += s.s2w_bytes as u64;
    }
    assert_eq!(coord.meter().w2s(), expect_w2s);
    assert_eq!(coord.meter().s2w(), expect_s2w);
    // 3 workers: aggregate = 3x per-worker
    assert_eq!(
        coord.meter().w2s_all.load(std::sync::atomic::Ordering::Relaxed),
        3 * expect_w2s
    );
}

#[test]
fn compressed_run_converges_with_stochastic_gradients() {
    let q = Quadratics::new(4, 12, 0.5, 0.3, &mut Rng::new(65));
    let (mut coord, _svc) = mk_coord(q, "rank:0.4", TransportMode::Counted, 0.5);
    let first = coord.eval().unwrap();
    for _ in 0..400 {
        coord.round().unwrap();
    }
    let last = coord.eval().unwrap();
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn eval_is_deterministic_given_params() {
    let q = Quadratics::new(2, 6, 0.5, 0.0, &mut Rng::new(66));
    let (coord, _svc) = mk_coord(q, "id", TransportMode::Counted, 1.0);
    let a = coord.eval().unwrap();
    let b = coord.eval().unwrap();
    assert_eq!(a, b);
}
