//! Distributed-stack integration: threaded coordinator vs the sequential
//! reference implementation, transport-mode equivalence, byte metering,
//! async round pipelining, and fault injection (a worker that panics
//! mid-round must surface a clean `Err`, never a hang).

use std::sync::atomic::{AtomicUsize, Ordering};

use efmuon::dist::coordinator::{Coordinator, CoordinatorCfg};
use efmuon::dist::service::GradService;
use efmuon::dist::{RoundMode, TransportMode};
use efmuon::funcs::{Objective, Quadratics};
use efmuon::linalg::matrix::{Layers, Matrix};
use efmuon::lmo::LmoKind;
use efmuon::opt::ef21::Ef21MuonSeq;
use efmuon::opt::{LayerGeometry, Schedule};
use efmuon::spec::CompSpec;
use efmuon::util::rng::Rng;

fn geom() -> Vec<LayerGeometry> {
    vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }]
}

/// Parse a compressor spec string (test-side boundary).
fn comp(s: &str) -> CompSpec {
    CompSpec::parse(s).unwrap()
}

fn mk_coord(q: Quadratics, spec: &str, mode: TransportMode, beta: f32) -> (Coordinator, GradService) {
    let mut rng = Rng::new(61);
    let x0 = q.init(&mut rng);
    let n = q.num_workers();
    let svc = GradService::spawn_objective(Box::new(q), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp(spec),
            server_comp: CompSpec::Id,
            beta,
            schedule: Schedule::constant(0.03),
            transport: mode,
            round_mode: RoundMode::Sync,
            seed: 5,
            use_ns_artifact: false,
        },
    )
    .unwrap();
    (coord, svc)
}

#[test]
fn counted_and_encoded_transport_agree() {
    // deterministic gradients + same seeds ⇒ identical trajectories under
    // both transports (the codec must be lossless)
    let mut rng = Rng::new(62);
    let mk = || Quadratics::new(3, 10, 0.5, 0.0, &mut Rng::new(62));
    let _ = &mut rng;
    let (mut a, _svc_a) = mk_coord(mk(), "top:0.3+nat", TransportMode::Counted, 1.0);
    let (mut b, _svc_b) = mk_coord(mk(), "top:0.3+nat", TransportMode::Encoded, 1.0);
    for _ in 0..20 {
        let sa = a.round().unwrap();
        let sb = b.round().unwrap();
        assert_eq!(sa.w2s_bytes_per_worker, sb.w2s_bytes_per_worker);
        assert_eq!(sa.s2w_bytes, sb.s2w_bytes);
    }
    for (pa, pb) in a.params().iter().zip(b.params()) {
        assert_eq!(pa.data, pb.data, "trajectory diverged between transports");
    }
}

#[test]
fn threaded_matches_sequential_reference() {
    // same objective/seed/config: the threaded coordinator must follow the
    // sequential state machine exactly (deterministic compressors)
    let mk = || Quadratics::new(4, 8, 0.5, 0.0, &mut Rng::new(63));
    let q_seq = mk();
    // NOTE: sequential driver inits x0 via obj.init with seed 5 -> replicate
    let mut seq = Ef21MuonSeq::new(
        &q_seq,
        geom(),
        "top:0.25",
        "id",
        1.0,
        Schedule::constant(0.03),
        false,
        5,
    )
    .unwrap();

    let q_dist = mk();
    let mut rng5 = Rng::new(5);
    let x0 = q_dist.init(&mut rng5);
    assert_eq!(x0[0].data, seq.params()[0].data, "identical init required");
    let n = q_dist.num_workers();
    let svc = GradService::spawn_objective(Box::new(q_dist), 5);
    let mut coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp("top:0.25"),
            server_comp: CompSpec::Id,
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Encoded,
            round_mode: RoundMode::Sync,
            seed: 5,
            use_ns_artifact: false,
        },
    )
    .unwrap();

    for k in 0..25 {
        let s = seq.step(&q_seq);
        let d = coord.round().unwrap();
        assert_eq!(s.w2s_bytes, d.w2s_bytes_per_worker, "step {k}: bytes");
        assert_eq!(d.absorbed_step, Some(k), "sync absorbs the issued round");
        let diff = seq.params()[0].max_abs_diff(&coord.params()[0]);
        assert!(diff < 1e-6, "step {k}: params diverged by {diff}");
    }
}

#[test]
fn byte_meters_accumulate_correctly() {
    let q = Quadratics::new(3, 100, 0.5, 0.0, &mut Rng::new(64));
    let (mut coord, _svc) = mk_coord(q, "top:0.1", TransportMode::Counted, 1.0);
    let mut expect_w2s = 0u64;
    let mut expect_s2w = 0u64;
    for _ in 0..10 {
        let s = coord.round().unwrap();
        expect_w2s += s.w2s_bytes_per_worker as u64;
        expect_s2w += s.s2w_bytes as u64;
    }
    assert_eq!(coord.meter().w2s(), expect_w2s);
    assert_eq!(coord.meter().s2w(), expect_s2w);
    assert_eq!(coord.meter().rounds_issued(), 10);
    assert_eq!(coord.meter().rounds_absorbed(), 10);
    // 3 workers: aggregate = 3x per-worker
    assert_eq!(coord.meter().w2s_all(), 3 * expect_w2s);
    // the serializable snapshot mirrors every counter
    let snap = coord.meter().snapshot();
    assert_eq!(snap.w2s_per_worker, expect_w2s);
    assert_eq!(snap.w2s_all, 3 * expect_w2s);
    assert_eq!(snap.s2w_total, expect_s2w);
    assert_eq!(snap.rounds_issued, 10);
    assert_eq!(snap.rounds_absorbed, 10);
}

#[test]
fn compressed_run_converges_with_stochastic_gradients() {
    let q = Quadratics::new(4, 12, 0.5, 0.3, &mut Rng::new(65));
    let (mut coord, _svc) = mk_coord(q, "rank:0.4", TransportMode::Counted, 0.5);
    let first = coord.eval().unwrap();
    for _ in 0..400 {
        coord.round().unwrap();
    }
    let last = coord.eval().unwrap();
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn eval_is_deterministic_given_params() {
    let q = Quadratics::new(2, 6, 0.5, 0.0, &mut Rng::new(66));
    let (coord, _svc) = mk_coord(q, "id", TransportMode::Counted, 1.0);
    let a = coord.eval().unwrap();
    let b = coord.eval().unwrap();
    assert_eq!(a, b);
}

// ---------------------------------------------------------------------------
// Async pipelined rounds
// ---------------------------------------------------------------------------

fn mk_async(lookahead: usize, seed_obj: u64) -> (Coordinator, GradService) {
    let q = Quadratics::new(3, 10, 0.5, 0.0, &mut Rng::new(seed_obj));
    let x0 = q.init(&mut Rng::new(61));
    let n = q.num_workers();
    let svc = GradService::spawn_objective(Box::new(q), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp("top:0.3"),
            server_comp: comp("top:0.5"),
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Counted,
            round_mode: RoundMode::Async { lookahead },
            seed: 5,
            use_ns_artifact: false,
        },
    )
    .unwrap();
    (coord, svc)
}

#[test]
fn async_pipeline_fills_and_drains() {
    let (mut coord, _svc) = mk_async(2, 67);
    // the first two calls only issue (nothing absorbed yet)
    let s0 = coord.round().unwrap();
    assert_eq!(s0.absorbed_step, None);
    assert!(s0.train_loss.is_nan());
    assert_eq!(s0.w2s_bytes_per_worker, 0);
    assert_eq!(coord.pending_rounds(), 1);
    let s1 = coord.round().unwrap();
    assert_eq!(s1.absorbed_step, None);
    assert_eq!(coord.pending_rounds(), 2);
    // from the third call on, the absorbed round trails the issued by 2
    let s2 = coord.round().unwrap();
    assert_eq!(s2.step, 2);
    assert_eq!(s2.absorbed_step, Some(0));
    assert!(s2.train_loss.is_finite());
    assert!(s2.w2s_bytes_per_worker > 0);
    assert_eq!(coord.pending_rounds(), 2);
    // drain lands the two in-flight rounds in order
    let drained = coord.drain().unwrap();
    assert_eq!(drained.len(), 2);
    assert_eq!(drained[0].absorbed_step, Some(1));
    assert_eq!(drained[1].absorbed_step, Some(2));
    assert_eq!(coord.pending_rounds(), 0);
    assert_eq!(coord.meter().rounds_issued(), 3);
    assert_eq!(coord.meter().rounds_absorbed(), 3);
}

#[test]
fn async_runs_are_deterministic() {
    // reply arrival order must not influence the trajectory: two identical
    // async runs produce bit-identical parameters and meters
    let run = || -> (Vec<f32>, u64, u64) {
        let (mut coord, _svc) = mk_async(1, 68);
        coord.run(30).unwrap();
        (
            coord.params()[0].data.clone(),
            coord.meter().w2s(),
            coord.meter().s2w(),
        )
    };
    let (pa, wa, sa) = run();
    let (pb, wb, sb) = run();
    assert_eq!(pa, pb, "async trajectory must be deterministic");
    assert_eq!(wa, wb);
    assert_eq!(sa, sb);
}

#[test]
fn run_reports_every_round_once() {
    let (mut coord, _svc) = mk_async(1, 69);
    let stats = coord.run(12).unwrap();
    // 12 per-call entries + 1 drained tail entry
    assert_eq!(stats.len(), 13);
    let absorbed: Vec<usize> = stats.iter().filter_map(|s| s.absorbed_step).collect();
    assert_eq!(absorbed, (0..12).collect::<Vec<_>>(), "each round absorbed exactly once");
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Wraps [`Quadratics`] and panics inside gradient evaluation for one
/// worker once that worker has been called `panic_after` times — simulating
/// a crash mid-round (or mid-init for `panic_after = 0`).
struct PanicObjective {
    inner: Quadratics,
    panic_worker: usize,
    panic_after: usize,
    calls: AtomicUsize,
}

impl PanicObjective {
    fn new(panic_worker: usize, panic_after: usize, seed: u64) -> Self {
        PanicObjective {
            inner: Quadratics::new(3, 8, 0.5, 0.0, &mut Rng::new(seed)),
            panic_worker,
            panic_after,
            calls: AtomicUsize::new(0),
        }
    }
}

impl Objective for PanicObjective {
    fn num_workers(&self) -> usize {
        self.inner.num_workers()
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        self.inner.layer_shapes()
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        self.inner.loss(x)
    }

    fn loss_j(&self, j: usize, x: &[Matrix]) -> f64 {
        self.inner.loss_j(j, x)
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        if j == self.panic_worker {
            let seen = self.calls.fetch_add(1, Ordering::SeqCst);
            if seen >= self.panic_after {
                panic!("injected fault in worker {j}");
            }
        }
        self.inner.grad_j(j, x)
    }

    fn init(&self, rng: &mut Rng) -> Layers {
        self.inner.init(rng)
    }
}

fn mk_fault_coord(obj: PanicObjective, mode: RoundMode) -> anyhow::Result<(Coordinator, GradService)> {
    let x0 = obj.init(&mut Rng::new(70));
    let n = obj.num_workers();
    let svc = GradService::spawn_objective(Box::new(obj), 5);
    let coord = Coordinator::spawn(
        x0,
        geom(),
        svc.handle(),
        CoordinatorCfg {
            n_workers: n,
            worker_comp: comp("top:0.3"),
            server_comp: CompSpec::Id,
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Counted,
            round_mode: mode,
            seed: 5,
            use_ns_artifact: false,
        },
    )?;
    Ok((coord, svc))
}

#[test]
fn worker_panic_mid_round_surfaces_clean_error() {
    // worker 1: 1 init call + 2 good rounds, then panics in round 2. The
    // leader must return Err from run() — not hang on the dead worker, not
    // poison the channel for the survivors.
    let obj = PanicObjective::new(1, 3, 71);
    let (mut coord, _svc) = mk_fault_coord(obj, RoundMode::Sync).unwrap();
    let err = coord.run(10).expect_err("run must fail once worker 1 dies");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 1"), "error should name the worker: {msg}");
    // the coordinator stays usable as a value (Drop joins cleanly) and
    // further rounds keep failing fast instead of hanging
    assert!(coord.round().is_err());
}

#[test]
fn worker_panic_mid_round_surfaces_in_async_mode() {
    let obj = PanicObjective::new(2, 4, 72);
    let (mut coord, _svc) = mk_fault_coord(obj, RoundMode::Async { lookahead: 1 }).unwrap();
    let err = coord.run(10).expect_err("async run must fail once worker 2 dies");
    assert!(format!("{err:#}").contains("worker 2"));
}

#[test]
fn worker_panic_during_init_fails_spawn() {
    let obj = PanicObjective::new(0, 0, 73);
    let err = match mk_fault_coord(obj, RoundMode::Sync) {
        Err(e) => e,
        Ok(_) => panic!("spawn must fail when a worker dies during init"),
    };
    assert!(format!("{err:#}").contains("worker 0"), "{err:#}");
}
