//! Parallel hot-path kernels: the threaded matmul must agree with the
//! naive reference on ragged shapes, be bit-identical at every thread
//! count, and the workspace-arena paths (Newton–Schulz, LMO steps) must be
//! allocation-free once warm and bit-stable — so distributed runs are
//! reproducible regardless of the host's core count.

use efmuon::linalg::matmul::{matmul_bt_into_ws, matmul_into_with_threads};
use efmuon::linalg::ns::{newton_schulz, newton_schulz_ws, NS_STEPS};
use efmuon::linalg::workspace::Workspace;
use efmuon::linalg::Matrix;
use efmuon::lmo::{Lmo, LmoKind};
use efmuon::util::rng::Rng;
use efmuon::util::threads;

fn naive(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0f64;
            for k in 0..a.cols {
                s += a.at(i, k) as f64 * b.at(k, j) as f64;
            }
            c.set(i, j, s as f32);
        }
    }
    c
}

/// Ragged shapes: 1×1, prime dims, tall, wide, and bigger-than-one-tile.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (2, 3, 1),
    (7, 11, 13),
    (31, 37, 29),
    (1, 257, 1),
    (257, 1, 63),
    (128, 3, 128),
    (3, 128, 200),
    (97, 101, 103),
    (130, 70, 260),
];

#[test]
fn threaded_matmul_matches_naive_on_ragged_shapes() {
    let mut rng = Rng::new(90);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let want = naive(&a, &b);
        for nt in [1, 2, 4, 16] {
            let mut c = Matrix::zeros(m, n);
            matmul_into_with_threads(&a, &b, &mut c, nt);
            let diff = c.max_abs_diff(&want);
            assert!(diff < 1e-3 * (k as f32).sqrt(), "{m}x{k}x{n} nt={nt}: {diff}");
        }
    }
}

#[test]
fn threaded_matmul_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(91);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut base = Matrix::zeros(m, n);
        matmul_into_with_threads(&a, &b, &mut base, 1);
        for nt in [2, 3, 5, 8, 64] {
            let mut c = Matrix::zeros(m, n);
            matmul_into_with_threads(&a, &b, &mut c, nt);
            assert_eq!(
                c.data, base.data,
                "{m}x{k}x{n}: thread count {nt} changed bits"
            );
        }
    }
}

#[test]
fn matmul_bt_workspace_agrees_with_reference() {
    let mut rng = Rng::new(92);
    let mut ws = Workspace::new();
    for &(m, k) in &[(3usize, 5usize), (40, 40), (64, 129)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(m + 1, k, 1.0, &mut rng);
        let mut c = Matrix::zeros(m, m + 1);
        matmul_bt_into_ws(&a, &b, &mut c, &mut ws);
        let want = naive(&a, &b.transpose());
        assert!(c.max_abs_diff(&want) < 1e-2, "{m}x{k}");
    }
}

#[test]
fn newton_schulz_bit_identical_across_thread_counts() {
    // The distributed deployment must be reproducible on any host: NS is
    // the only heavy spectral kernel, so pin its bits across thread counts.
    // (Process-global override; this test owns all mutations of it.)
    let mut rng = Rng::new(93);
    for &(m, n) in &[(16, 16), (8, 64), (96, 24), (128, 512)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        threads::set_threads(1);
        let base = newton_schulz(&g, NS_STEPS);
        for nt in [2, 4, 8] {
            threads::set_threads(nt);
            let o = newton_schulz(&g, NS_STEPS);
            assert_eq!(o.data, base.data, "{m}x{n}: NS bits changed at {nt} threads");
        }
        threads::set_threads(0);
    }
}

#[test]
fn newton_schulz_workspace_is_allocation_free_when_warm() {
    let mut rng = Rng::new(94);
    let g = Matrix::randn(64, 96, 1.0, &mut rng);
    let mut ws = Workspace::new();
    let first = newton_schulz_ws(&g, NS_STEPS, &mut ws);
    ws.give(first);
    let warm = ws.fresh_allocs();
    for _ in 0..10 {
        let o = newton_schulz_ws(&g, NS_STEPS, &mut ws);
        ws.give(o);
    }
    assert_eq!(
        ws.fresh_allocs(),
        warm,
        "the 5-iteration quintic loop must not allocate once the arena is warm"
    );
}

#[test]
fn newton_schulz_ws_matches_plain() {
    let mut rng = Rng::new(95);
    for &(m, n) in &[(12, 12), (6, 30), (48, 16)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let plain = newton_schulz(&g, NS_STEPS);
        let mut ws = Workspace::new();
        let via_ws = newton_schulz_ws(&g, NS_STEPS, &mut ws);
        assert_eq!(plain.data, via_ws.data, "{m}x{n}");
        // warm arena must not change the numbers either
        let again = newton_schulz_ws(&g, NS_STEPS, &mut ws);
        assert_eq!(plain.data, again.data, "{m}x{n} (warm)");
    }
}

#[test]
fn lmo_step_ws_is_allocation_free_and_matches_step() {
    let mut rng = Rng::new(96);
    for kind in [
        LmoKind::Spectral,
        LmoKind::SignLInf,
        LmoKind::L1Top1,
        LmoKind::Euclidean,
        LmoKind::ColNorm,
    ] {
        let lmo = Lmo::new(kind);
        let g = Matrix::randn(24, 40, 1.0, &mut rng);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let plain = lmo.step(&g, 0.5, &mut r1);
        let mut ws = Workspace::new();
        let via = lmo.step_ws(&g, 0.5, &mut r2, &mut ws);
        assert_eq!(plain.data, via.data, "{kind:?}");
        ws.give(via);
        let warm = ws.fresh_allocs();
        for _ in 0..4 {
            let mut rr = Rng::new(7);
            let s = lmo.step_ws(&g, 0.5, &mut rr, &mut ws);
            ws.give(s);
        }
        assert_eq!(ws.fresh_allocs(), warm, "{kind:?} must reuse the arena");
    }
}
