//! PJRT runtime integration tests. These require `artifacts/` (built by
//! `make artifacts`); they become no-ops with a notice when it is missing
//! so `cargo test` works on a fresh checkout.

use efmuon::linalg::ns::newton_schulz;
use efmuon::linalg::Matrix;
use efmuon::model::Manifest;
use efmuon::runtime::ModelRuntime;
use efmuon::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    for candidate in ["artifacts", "../artifacts"] {
        if std::path::Path::new(candidate).join("manifest.json").exists() {
            return Some(candidate.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

fn load() -> Option<ModelRuntime> {
    artifacts_dir().map(|d| ModelRuntime::load(d).expect("load artifacts"))
}

#[test]
fn manifest_and_params_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let params = m.load_init_params().unwrap();
    assert_eq!(params.len(), m.layers.len());
    let total: usize = params.iter().map(|p| p.numel()).sum();
    assert_eq!(total, m.param_count);
    for p in &params {
        assert!(p.is_finite());
    }
    // GPT-2 init: embeddings std 0.02
    let wte = &params[0];
    let std = (wte.norm2_sq() / wte.numel() as f64).sqrt();
    assert!((std - 0.02).abs() < 0.005, "wte std {std}");
}

#[test]
fn eval_loss_at_init_is_ln_vocab() {
    let Some(rt) = load() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let mut rng = Rng::new(1);
    let corpus = efmuon::data::Corpus::zipf_markov(50_000, m.vocab, 3);
    let shard = efmuon::data::Shard::new(&corpus, 0, 1, m.seq_len);
    let (toks, tgts) = shard.sample_batch(m.batch, &mut rng);
    let loss = rt.eval_loss(&params, &toks, &tgts).unwrap();
    assert!(
        (loss as f64 - (m.vocab as f64).ln()).abs() < 0.2,
        "init loss {loss}"
    );
}

#[test]
fn grad_artifact_descends_and_matches_eval() {
    let Some(rt) = load() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let mut rng = Rng::new(2);
    let corpus = efmuon::data::Corpus::zipf_markov(50_000, m.vocab, 3);
    let shard = efmuon::data::Shard::new(&corpus, 0, 1, m.seq_len);
    let (toks, tgts) = shard.sample_batch(m.batch, &mut rng);

    let (loss, grads) = rt.grad(&params, &toks, &tgts).unwrap();
    let eval = rt.eval_loss(&params, &toks, &tgts).unwrap();
    assert!((loss - eval).abs() < 1e-4, "grad loss {loss} vs eval {eval}");
    assert_eq!(grads.len(), params.len());
    for (g, p) in grads.iter().zip(&params) {
        assert_eq!((g.rows, g.cols), (p.rows, p.cols));
        assert!(g.is_finite());
    }
    // gradient step on the same batch must reduce the loss
    let stepped: Vec<Matrix> = params
        .iter()
        .zip(&grads)
        .map(|(p, g)| {
            let mut q = p.clone();
            q.axpy(-0.5, g);
            q
        })
        .collect();
    let loss2 = rt.eval_loss(&stepped, &toks, &tgts).unwrap();
    assert!(loss2 < loss, "{loss} -> {loss2}");
}

#[test]
fn pjrt_ns_artifact_matches_native_ns() {
    // The L1 Pallas kernel (through PJRT) and the rust-native NS must agree:
    // same coefficients, same normalization.
    let Some(rt) = load() else { return };
    let mut rng = Rng::new(3);
    let shapes: Vec<(usize, usize)> = rt.manifest.ns_hlo.iter().map(|(s, _)| *s).collect();
    assert!(!shapes.is_empty(), "expected NS artifacts");
    for (m, n) in shapes {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let via_pjrt = rt.ns_orthogonalize(&g).expect("artifact exists").unwrap();
        let native = newton_schulz(&g, rt.manifest.ns_steps);
        let diff = via_pjrt.max_abs_diff(&native);
        assert!(diff < 5e-3, "{m}x{n}: pallas vs native diff {diff}");
    }
}

#[test]
fn ns_artifact_covers_all_hidden_shapes() {
    let Some(rt) = load() else { return };
    for l in &rt.manifest.layers {
        if l.group == efmuon::model::Group::Hidden {
            assert!(
                rt.has_ns_for(l.rows, l.cols),
                "no NS artifact for hidden layer {} ({}x{})",
                l.name,
                l.rows,
                l.cols
            );
        }
    }
}

#[test]
fn grad_is_deterministic() {
    let Some(rt) = load() else { return };
    let m = &rt.manifest;
    let params = m.load_init_params().unwrap();
    let mut rng = Rng::new(4);
    let corpus = efmuon::data::Corpus::zipf_markov(30_000, m.vocab, 3);
    let shard = efmuon::data::Shard::new(&corpus, 0, 1, m.seq_len);
    let (toks, tgts) = shard.sample_batch(m.batch, &mut rng);
    let (l1, g1) = rt.grad(&params, &toks, &tgts).unwrap();
    let (l2, g2) = rt.grad(&params, &toks, &tgts).unwrap();
    assert_eq!(l1, l2);
    for (a, b) in g1.iter().zip(&g2) {
        assert_eq!(a.data, b.data);
    }
}
