//! Cluster-layer integration: the balanced layer-partition property on
//! ragged shape sets, root-reducer rollups, pipelined cluster rounds, and
//! fault propagation (a worker dying inside one shard must surface as a
//! clean `Err` from the root, naming the shard — never a hang).
//!
//! The trajectory-level invariants (1-shard golden match, multi-shard ≡
//! independent coordinators, shard-count invariance) live in the scenario
//! harness (`rust/tests/scenario.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use efmuon::dist::cluster::{partition_layers, Cluster, ClusterCfg, ParamBoard};
use efmuon::dist::fault::FaultPolicy;
use efmuon::dist::sched::SchedSpec;
use efmuon::dist::service::{GradService, SharedIds, SnapCache};
use efmuon::dist::{RoundMode, TransportMode};
use efmuon::funcs::{Objective, Quadratics, Stacked};
use efmuon::linalg::matrix::{Layers, Matrix};
use efmuon::lmo::LmoKind;
use efmuon::opt::{LayerGeometry, Schedule};
use efmuon::spec::CompSpec;
use efmuon::trace::Tracer;
use efmuon::util::proptest::check;
use efmuon::util::rng::Rng;

// ---------------------------------------------------------------------------
// Partition properties (ISSUE-3 satellite)
// ---------------------------------------------------------------------------

/// Every balanced partition covers all layers exactly once, leaves no
/// shard empty, keeps ids ascending within a shard, and spreads the load
/// so the heaviest and lightest shards differ by at most one max-layer —
/// on ragged randomly-shaped layer sets.
#[test]
fn partition_covers_and_balances_on_ragged_shapes() {
    check("partition-balanced", 200, 42, |g| {
        let n_layers = g.usize_in(1, 24);
        let shapes: Vec<(usize, usize)> =
            (0..n_layers).map(|_| g.shape(1, 40)).collect();
        let shards = g.usize_in(1, n_layers);
        let p = partition_layers(&shapes, shards).map_err(|e| e.to_string())?;

        if p.len() != shards {
            return Err(format!("expected {shards} shards, got {}", p.len()));
        }
        // coverage: every layer exactly once
        let mut seen: Vec<usize> = p.iter().flatten().copied().collect();
        seen.sort_unstable();
        let expect: Vec<usize> = (0..n_layers).collect();
        if seen != expect {
            return Err(format!("partition does not cover layers exactly once: {seen:?}"));
        }
        for (s, ids) in p.iter().enumerate() {
            if ids.is_empty() {
                return Err(format!("shard {s} is empty"));
            }
            if ids.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("shard {s} ids not ascending: {ids:?}"));
            }
        }
        // balance: max load - min load <= max single-layer numel
        let numel = |i: usize| shapes[i].0 * shapes[i].1;
        let loads: Vec<usize> =
            p.iter().map(|ids| ids.iter().map(|&i| numel(i)).sum()).collect();
        let max_layer = (0..n_layers).map(numel).max().unwrap_or(0);
        let (lo, hi) = (
            *loads.iter().min().expect("non-empty"),
            *loads.iter().max().expect("non-empty"),
        );
        if hi - lo > max_layer {
            return Err(format!(
                "load spread {} exceeds max layer {max_layer} (loads {loads:?}, shapes {shapes:?})",
                hi - lo
            ));
        }
        Ok(())
    });
}

#[test]
fn partition_rejects_more_shards_than_layers() {
    let shapes = vec![(3, 3), (2, 2)];
    let err = partition_layers(&shapes, 5).unwrap_err();
    assert!(err.contains("cannot shard"), "{err}");
    assert!(partition_layers(&shapes, 0).is_err());
}

// ---------------------------------------------------------------------------
// Cluster behavior on the objective backend
// ---------------------------------------------------------------------------

fn three_layer_stack(workers: usize, seed: u64) -> Box<dyn Objective> {
    Box::new(
        Stacked::new(vec![
            Box::new(Quadratics::new(workers, 9, 0.5, 0.0, &mut Rng::new(seed)))
                as Box<dyn Objective>,
            Box::new(Quadratics::new(workers, 7, 0.5, 0.0, &mut Rng::new(seed + 1))),
            Box::new(Quadratics::new(workers, 5, 0.5, 0.0, &mut Rng::new(seed + 2))),
        ])
        .unwrap(),
    )
}

fn spawn_cluster(
    obj: Box<dyn Objective>,
    shards: usize,
    workers: usize,
    mode: RoundMode,
) -> anyhow::Result<(Cluster, GradService)> {
    spawn_cluster_ex(obj, shards, workers, mode, false)
}

fn spawn_cluster_ex(
    obj: Box<dyn Objective>,
    shards: usize,
    workers: usize,
    mode: RoundMode,
    snap_bf16: bool,
) -> anyhow::Result<(Cluster, GradService)> {
    let x0 = obj.init(&mut Rng::new(7));
    let n_layers = obj.layer_shapes().len();
    let svc = GradService::spawn_objective(obj, 7);
    let cluster = Cluster::spawn(
        x0,
        vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; n_layers],
        svc.handle(),
        ClusterCfg {
            shards,
            workers_per_shard: workers,
            worker_comp: CompSpec::Top { frac: 0.3, nat: false },
            server_comp: CompSpec::Top { frac: 0.5, nat: false },
            beta: 1.0,
            schedule: Schedule::constant(0.03),
            transport: TransportMode::Counted,
            round_mode: mode,
            seed: 7,
            use_ns_artifact: false,
            fault: FaultPolicy::off(),
            fault_plan: None,
            start_step: 0,
            snap_bf16,
            sched: SchedSpec::off(),
            shard_delay: None,
            tracer: Tracer::Noop,
        },
    )?;
    Ok((cluster, svc))
}

/// The root rollup: aggregated bytes are per-shard sums, round counters
/// advance in lock-step, and converging losses show the cluster actually
/// optimizes.
#[test]
fn cluster_rollup_and_convergence() {
    let (mut cluster, _svc) = spawn_cluster(three_layer_stack(2, 900), 2, 2, RoundMode::Sync).unwrap();
    assert_eq!(cluster.shards(), 2);
    let first = cluster.eval().unwrap();
    let mut expect_w2s = 0u64;
    let mut expect_s2w = 0u64;
    for k in 0..60 {
        let s = cluster.round().unwrap();
        assert_eq!(s.step, k);
        assert_eq!(s.absorbed_step, Some(k), "sync cluster absorbs what it issues");
        assert_eq!(s.per_shard.len(), 2);
        // the rollup is exactly the per-shard sums
        assert_eq!(
            s.w2s_bytes_per_worker,
            s.per_shard.iter().map(|p| p.w2s_bytes_per_worker).sum::<usize>()
        );
        assert_eq!(s.s2w_bytes, s.per_shard.iter().map(|p| p.s2w_bytes).sum::<usize>());
        assert!(s.train_loss.is_finite());
        expect_w2s += s.w2s_bytes_per_worker as u64;
        expect_s2w += s.s2w_bytes as u64;
    }
    let m = cluster.meter();
    assert_eq!(m.w2s(), expect_w2s);
    assert_eq!(m.s2w(), expect_s2w);
    assert_eq!(m.rounds_issued(), 60);
    assert_eq!(m.rounds_absorbed(), 60);
    assert_eq!(m.w2s_all(), 2 * expect_w2s, "2 workers per shard");
    let last = cluster.eval().unwrap();
    assert!(last < first, "cluster must optimize: {first} -> {last}");
    assert_eq!(cluster.steps_done(), 60);
}

/// Pipelined cluster rounds: the first `lookahead` calls absorb nothing on
/// any shard, drain lands every in-flight round everywhere, and the meters
/// agree that issued == absorbed afterwards.
#[test]
fn cluster_pipeline_fills_and_drains() {
    let (mut cluster, _svc) =
        spawn_cluster(three_layer_stack(2, 901), 3, 2, RoundMode::Async { lookahead: 2 }).unwrap();
    let s0 = cluster.round().unwrap();
    assert_eq!(s0.absorbed_step, None);
    assert!(s0.train_loss.is_nan());
    assert_eq!(s0.w2s_bytes_per_worker, 0);
    let s1 = cluster.round().unwrap();
    assert_eq!(s1.absorbed_step, None);
    let s2 = cluster.round().unwrap();
    assert_eq!(s2.absorbed_step, Some(0), "lookahead 2: round 2 absorbs round 0");
    assert!(s2.train_loss.is_finite());
    let drained = cluster.drain().unwrap();
    assert_eq!(drained.len(), 2);
    assert_eq!(drained[0].absorbed_step, Some(1));
    assert_eq!(drained[1].absorbed_step, Some(2));
    let m = cluster.meter();
    assert_eq!(m.rounds_issued(), 3);
    assert_eq!(m.rounds_absorbed(), 3);
}

// ---------------------------------------------------------------------------
// The zero-copy gradient path (ISSUE-4 tentpole)
// ---------------------------------------------------------------------------

/// A multi-shard round assembles each full-model snapshot exactly once per
/// (shard, round) — not once per worker — every other worker request of the
/// shard reuses the `Arc`'d snapshot, and the clone-byte meters see exactly
/// those assemblies plus the root's per-round seal copy. The 1-shard
/// deployment never assembles and never seals (the golden-matched fast
/// path stays cost-free).
#[test]
fn cluster_assembles_one_snapshot_per_shard_round() {
    let workers = 3usize;
    let rounds = 12u64;
    let (mut cluster, _svc) =
        spawn_cluster(three_layer_stack(workers, 910), 2, workers, RoundMode::Sync).unwrap();
    for _ in 0..rounds {
        cluster.round().unwrap();
    }
    let m = cluster.meter();
    let t = m.totals();
    assert_eq!(t.snap_assembled, 2 * rounds, "assemblies = shards x rounds");
    assert_eq!(
        t.snap_reused,
        2 * rounds * (workers as u64 - 1),
        "every other worker of a shard reuses the round's snapshot"
    );
    for (s, ms) in m.per_shard.iter().enumerate() {
        assert_eq!(ms.snap_assembled, rounds, "shard {s} assembles once per round");
        assert!(ms.bytes_cloned > 0, "shard {s} meters its assembly bytes");
    }
    assert!(m.root_bytes_cloned > 0, "the root's seal copies are metered");
    assert_eq!(
        t.bytes_cloned,
        m.per_shard.iter().map(|ms| ms.bytes_cloned).sum::<u64>() + m.root_bytes_cloned
    );

    // 1-shard control: the owns-all-layers fast path does no snapshot work
    let (mut one, _svc2) =
        spawn_cluster(three_layer_stack(workers, 910), 1, workers, RoundMode::Sync).unwrap();
    for _ in 0..rounds {
        one.round().unwrap();
    }
    let t1 = one.meter().totals();
    assert_eq!(t1.snap_assembled, 0);
    assert_eq!(t1.snap_reused, 0);
    assert_eq!(t1.bytes_cloned, 0);
}

/// Steady-state snapshot assembly is allocation-free: once the cache's
/// retention window has filled, evicted rounds donate their buffers back
/// and every later assembly copies into a pooled buffer.
#[test]
fn snapshot_cache_zero_alloc_steady_state() {
    let obj = three_layer_stack(2, 920);
    let x0 = obj.init(&mut Rng::new(7));
    let model_bytes: u64 = x0.iter().map(|m| m.numel() as u64 * 4).sum();
    let board = Arc::new(ParamBoard::new(x0.clone(), 3));
    let cache = Arc::new(SnapCache::new(3));
    let svc = GradService::spawn_objective(obj, 7);
    let sh = svc
        .handle()
        .for_shard(board.clone(), SharedIds::new(vec![0]), cache.clone());
    let mut h0 = sh.for_worker(0);
    let mut h1 = sh.for_worker(1);
    let own: Layers = vec![x0[0].clone()];
    for step in 0..10usize {
        h0.grad_at(0, &own, step).unwrap();
        h1.grad_at(1, &own, step).unwrap();
    }
    let fresh_warm = cache.fresh_allocs();
    assert!(
        (1..=4).contains(&fresh_warm),
        "warmup allocates at most the retention window + 1 ({fresh_warm})"
    );
    for step in 10..30usize {
        h0.grad_at(0, &own, step).unwrap();
        h1.grad_at(1, &own, step).unwrap();
    }
    assert_eq!(cache.fresh_allocs(), fresh_warm, "steady state is allocation-free");
    assert_eq!(cache.assembled(), 30, "one assembly per round");
    assert_eq!(cache.reused(), 30, "the second worker reuses every round");
    assert_eq!(cache.bytes_assembled(), 30 * model_bytes);
}

/// The bf16 parameter board (ISSUE-7 tentpole): sealing and assembling
/// cross-shard snapshots at half width must halve the board-path byte
/// meters exactly — and on a layer-separable stack, where a shard's own
/// gradient and loss never read the foreign layers, the cast must leave
/// the whole trajectory bit-for-bit identical to the f32 board. (With
/// `snap_bf16` off nothing in this path changes, which every other test in
/// this file — all running bf16-off — pins.)
#[test]
fn bf16_board_halves_snapshot_traffic_and_keeps_separable_trajectories() {
    let run = |bf16: bool| {
        let (mut cluster, _svc) =
            spawn_cluster_ex(three_layer_stack(2, 940), 2, 2, RoundMode::Sync, bf16).unwrap();
        for _ in 0..20 {
            cluster.round().unwrap();
        }
        let m = cluster.meter();
        let t = m.totals();
        let params = cluster.params().unwrap();
        let eval = cluster.eval().unwrap();
        (params, eval, t, m.root_bytes_cloned)
    };
    let (p32, e32, t32, seal32) = run(false);
    let (p16, e16, t16, seal16) = run(true);
    for (li, (a, b)) in p32.iter().zip(&p16).enumerate() {
        let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb, "layer {li}: bf16 board must not perturb a separable trajectory");
    }
    assert_eq!(e32.to_bits(), e16.to_bits(), "eval loss must match bitwise");
    assert!(t32.snap_bytes_shipped > 0 && seal32 > 0, "the f32 run must meter traffic");
    assert_eq!(
        2 * t16.snap_bytes_shipped,
        t32.snap_bytes_shipped,
        "snapshot assembly ships exactly half the bytes"
    );
    assert_eq!(2 * seal16, seal32, "epoch seals write exactly half the bytes");
    // the protocol wire itself is untouched — only the board path shrinks
    assert_eq!(t16.w2s_per_worker, t32.w2s_per_worker);
    assert_eq!(t16.s2w_total, t32.s2w_total);
}

/// Shard-local loss telemetry: over a layer-separable stack the per-shard
/// train losses are disjoint contributions whose rollup (a sum) matches
/// the full-model loss the 1-shard deployment reports — loss-telemetry
/// work no longer buys a full-model evaluation per shard.
#[test]
fn shard_local_loss_matches_full_model_loss() {
    let (mut one, _s1) = spawn_cluster(three_layer_stack(2, 930), 1, 2, RoundMode::Sync).unwrap();
    let (mut three, _s3) = spawn_cluster(three_layer_stack(2, 930), 3, 2, RoundMode::Sync).unwrap();
    for k in 0..10 {
        let a = one.round().unwrap();
        let b = three.round().unwrap();
        // deterministic compressors: the trajectories are shard-count
        // invariant, so the losses differ only by f32 summation order
        assert!(
            (a.train_loss - b.train_loss).abs() <= 1e-4 * (1.0 + a.train_loss.abs()),
            "round {k}: 1-shard loss {} vs 3-shard summed {}",
            a.train_loss,
            b.train_loss
        );
    }
}

/// Wraps a [`Stacked`] objective and panics in one worker's gradient after
/// a call budget — inside whichever shard owns the part being evaluated.
struct PanicStack {
    inner: Box<dyn Objective>,
    panic_worker: usize,
    panic_after: usize,
    calls: AtomicUsize,
}

impl Objective for PanicStack {
    fn num_workers(&self) -> usize {
        self.inner.num_workers()
    }
    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        self.inner.layer_shapes()
    }
    fn loss(&self, x: &[Matrix]) -> f64 {
        self.inner.loss(x)
    }
    fn loss_j(&self, j: usize, x: &[Matrix]) -> f64 {
        self.inner.loss_j(j, x)
    }
    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        if j == self.panic_worker
            && self.calls.fetch_add(1, Ordering::SeqCst) >= self.panic_after
        {
            panic!("injected fault in worker {j}");
        }
        self.inner.grad_j(j, x)
    }
    fn init(&self, rng: &mut Rng) -> Layers {
        self.inner.init(rng)
    }
}

/// A worker panic inside one shard surfaces as a clean `Err` from the root
/// (naming a shard), the cluster latches, and later calls fail fast
/// instead of hanging on the dead shard.
#[test]
fn shard_worker_panic_surfaces_clean_root_error() {
    let obj = PanicStack {
        inner: three_layer_stack(3, 902),
        panic_worker: 1,
        panic_after: 8,
        calls: AtomicUsize::new(0),
    };
    let (mut cluster, _svc) = spawn_cluster(Box::new(obj), 2, 3, RoundMode::Sync).unwrap();
    let mut failed = None;
    for _ in 0..50 {
        if let Err(e) = cluster.round() {
            failed = Some(format!("{e:#}"));
            break;
        }
    }
    let msg = failed.expect("the injected fault must surface within 50 rounds");
    assert!(msg.contains("shard"), "error should name the shard: {msg}");
    // latched: every later call fails fast
    let again = cluster.round().expect_err("latched cluster must fail fast");
    assert!(format!("{again:#}").contains("already failed"));
    assert!(cluster.eval().is_err());
}

/// A worker dying while rounds are pipelined must surface from
/// `Cluster::drain` as a clean shard-named `Err` — never a hang on the
/// dead shard.
#[test]
fn shard_worker_death_mid_flight_fails_drain_promptly() {
    // worker 1's 6th gradient call is necessarily some shard's round-1
    // work (2 inits + 2 round-0 calls precede it in every interleaving),
    // so the panic lands while both issued rounds are still in flight
    let obj = PanicStack {
        inner: three_layer_stack(3, 904),
        panic_worker: 1,
        panic_after: 5,
        calls: AtomicUsize::new(0),
    };
    let (mut cluster, _svc) =
        spawn_cluster(Box::new(obj), 2, 3, RoundMode::Async { lookahead: 2 }).unwrap();
    assert_eq!(cluster.round().unwrap().absorbed_step, None);
    assert_eq!(cluster.round().unwrap().absorbed_step, None);
    let err = cluster.drain().expect_err("drain must surface the dead shard");
    assert!(format!("{err:#}").contains("shard"), "{err:#}");
    assert!(cluster.round().is_err(), "failure latches");
}

/// A worker panic during shard init fails `Cluster::spawn` itself.
#[test]
fn shard_worker_panic_during_init_fails_spawn() {
    let obj = PanicStack {
        inner: three_layer_stack(3, 903),
        panic_worker: 0,
        panic_after: 0,
        calls: AtomicUsize::new(0),
    };
    let err = match spawn_cluster(Box::new(obj), 2, 3, RoundMode::Sync) {
        Err(e) => e,
        Ok(_) => panic!("spawn must fail when a shard's worker dies during init"),
    };
    assert!(format!("{err:#}").contains("shard"), "{err:#}");
}
