//! Typed RunSpec API acceptance tests (ISSUE 5):
//!
//! - `RunSpec → Json → RunSpec` is a lossless identity (and the canonical
//!   JSON text is a fixed point);
//! - every typed sweep table entry round-trips through the string grammar
//!   and builds a compressor whose `name()` matches;
//! - every `Preset` golden-matches its legacy string configuration —
//!   descriptor equality through the `TrainConfig` facade AND bit-identical
//!   first training steps on the threaded deployment;
//! - the preset-built typed path reproduces the sequential Algorithm-3
//!   reference driver;
//! - invalid configs fail at `RunBuilder::build` with field-path messages,
//!   never mid-run.

use efmuon::dist::service::GradService;
use efmuon::dist::RoundMode;
use efmuon::exp;
use efmuon::funcs::{MatrixQuadratic, Objective, Stacked};
use efmuon::model::Group;
use efmuon::spec::{CompSpec, Preset, RunBuilder, RunSpec};
use efmuon::train::{spawn_driver, spawn_seq_driver, Driver};
use efmuon::util::rng::Rng;

// ---------------------------------------------------------------------------
// JSON round trip
// ---------------------------------------------------------------------------

#[test]
fn runspec_json_roundtrip_is_lossless() {
    let specs = vec![
        RunSpec::default(),
        Preset::Muon.spec(),
        Preset::Ef21P.spec(),
        RunBuilder::preset(Preset::Ef21P)
            .steps(42)
            .workers(3)
            .shards(2)
            .round(RoundMode::Async { lookahead: 2 })
            .full_codec(true)
            .log_path("out.jsonl")
            .lr(0.015)
            .warmup(7)
            .min_lr_frac(0.05)
            .beta(0.85)
            .eval_every(6)
            .eval_batches(2)
            .corpus_tokens(123_456)
            .seed(9)
            .artifacts("elsewhere")
            .build()
            .unwrap(),
    ];
    for spec in specs {
        let text = spec.to_json().to_string();
        let back = RunSpec::from_json(&text).unwrap();
        assert_eq!(back, spec, "round trip of: {text}");
        // canonical text is a fixed point of the round trip
        assert_eq!(back.to_json().to_string(), text);
    }
}

#[test]
fn train_config_facade_is_lossless() {
    for p in Preset::ALL {
        let spec = p.spec();
        let rebuilt = RunBuilder::from_config(&spec.to_train_config()).build().unwrap();
        assert_eq!(rebuilt, spec, "{p}: RunSpec -> TrainConfig -> RunSpec");
    }
}

// ---------------------------------------------------------------------------
// Typed sweep tables
// ---------------------------------------------------------------------------

#[test]
fn sweep_tables_round_trip_through_the_grammar() {
    let all = exp::paper_compressor_specs()
        .iter()
        .chain(exp::figure_specs())
        .chain(exp::s2w_specs());
    for c in all {
        let s = c.spec();
        let parsed = CompSpec::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert_eq!(parsed, *c, "{s}: parse(spec()) identity");
        assert_eq!(parsed.build().name(), s, "{s}: built compressor name");
    }
}

// ---------------------------------------------------------------------------
// Preset golden matches
// ---------------------------------------------------------------------------

const ROUNDS: usize = 6;

/// A small layer-separable workload with one layer per parameter group
/// (hidden / embed / vector shapes), noise-free so the sequential and
/// threaded deployments agree exactly.
fn mk_stack(workers: usize) -> Box<dyn Objective> {
    Box::new(
        Stacked::new(vec![
            Box::new(MatrixQuadratic::new(workers, 8, 6, 0.0, &mut Rng::new(3100)))
                as Box<dyn Objective>,
            Box::new(MatrixQuadratic::new(workers, 6, 4, 0.0, &mut Rng::new(3101))),
            Box::new(MatrixQuadratic::new(workers, 4, 3, 0.0, &mut Rng::new(3102))),
        ])
        .unwrap(),
    )
}

const GROUPS: [Group; 3] = [Group::Hidden, Group::Embed, Group::Vector];

/// Drive `ROUNDS` rounds of the deployment a spec describes on the
/// synthetic stack; return the flattened final parameters.
fn drive(spec: &RunSpec) -> Vec<f32> {
    let obj = mk_stack(spec.workers);
    let x0 = obj.init(&mut Rng::new(spec.seed));
    let geometry = spec.geom.for_groups(GROUPS);
    let svc = GradService::spawn_objective(obj, spec.seed);
    let mut drv = spawn_driver(spec, x0, geometry, svc.handle()).unwrap();
    for _ in 0..ROUNDS {
        drv.round().unwrap();
    }
    drv.drain().unwrap();
    drv.params()
        .unwrap()
        .iter()
        .flat_map(|m| m.data.iter().copied())
        .collect()
}

/// Each preset pinned to a short run shape (small steps so the golden runs
/// are fast; everything else is the preset's own pinned combination).
fn short(p: Preset) -> RunSpec {
    RunBuilder::preset(p)
        .workers(2)
        .steps(ROUNDS)
        .lr(0.02)
        .use_ns_artifact(false)
        .seed(11)
        .build()
        .unwrap()
}

#[test]
fn presets_golden_match_their_legacy_string_configs() {
    for p in Preset::ALL {
        let typed = short(p);
        // (1) descriptor equality: the legacy string config parses back to
        // exactly the preset's pinned combination
        let rebuilt = RunBuilder::from_config(&typed.to_train_config()).build().unwrap();
        assert_eq!(rebuilt, typed, "{p}: descriptors through the string facade");
        // (2) run equality: the deployment built from the preset and the
        // one built from the legacy strings produce bit-identical first
        // training steps
        let a = drive(&typed);
        let b = drive(&rebuilt);
        assert_eq!(a, b, "{p}: trajectories bit-identical");
    }
}

#[test]
fn preset_deployment_matches_sequential_reference() {
    // the typed path must still BE Algorithm 3: for a compressing preset,
    // the threaded coordinator built from the spec reproduces the
    // sequential reference driver built from the same spec
    for p in [Preset::Gluon, Preset::Ef21P] {
        let spec = short(p);
        let dist = drive(&spec);

        let obj = mk_stack(spec.workers);
        let geometry = spec.geom.for_groups(GROUPS);
        let mut seq = spawn_seq_driver(&spec, obj, geometry).unwrap();
        for _ in 0..ROUNDS {
            seq.round().unwrap();
        }
        let golden: Vec<f32> = seq
            .params()
            .unwrap()
            .iter()
            .flat_map(|m| m.data.iter().copied())
            .collect();
        let max_diff = golden
            .iter()
            .zip(&dist)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "{p}: diverged from the reference by {max_diff}");
    }
}

#[test]
fn recovered_baselines_send_dense_traffic_and_ef21_compresses() {
    // the recovery claim, measured: Muon/Scion/Gluon presets (compression
    // off) broadcast and uplink dense bytes; the EF21 presets uplink
    // strictly fewer
    let dense_w2s = {
        let spec = short(Preset::Scion);
        let obj = mk_stack(spec.workers);
        let x0 = obj.init(&mut Rng::new(spec.seed));
        let geometry = spec.geom.for_groups(GROUPS);
        let svc = GradService::spawn_objective(obj, spec.seed);
        let mut drv = spawn_driver(&spec, x0, geometry, svc.handle()).unwrap();
        for _ in 0..ROUNDS {
            drv.round().unwrap();
        }
        drv.w2s()
    };
    let comp_spec = short(Preset::Ef21Muon);
    let obj = mk_stack(comp_spec.workers);
    let x0 = obj.init(&mut Rng::new(comp_spec.seed));
    let geometry = comp_spec.geom.for_groups(GROUPS);
    let svc = GradService::spawn_objective(obj, comp_spec.seed);
    let mut drv = spawn_driver(&comp_spec, x0, geometry, svc.handle()).unwrap();
    for _ in 0..ROUNDS {
        drv.round().unwrap();
    }
    assert!(
        drv.w2s() < dense_w2s,
        "ef21-muon must uplink fewer bytes: {} vs dense {dense_w2s}",
        drv.w2s()
    );
}

// ---------------------------------------------------------------------------
// Eager validation
// ---------------------------------------------------------------------------

#[test]
fn builder_rejects_bad_overrides_of_a_good_preset() {
    let err = RunBuilder::preset(Preset::Ef21P)
        .steps(0)
        .worker_comp("rank:-1")
        .build()
        .unwrap_err();
    assert!(err.mentions("steps"), "{err}");
    assert!(err.mentions("worker_comp"), "{err}");
    // the message carries field paths, not just a blob
    let msg = err.to_string();
    assert!(msg.contains("steps: must be >= 1"), "{msg}");
}
