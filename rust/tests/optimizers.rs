//! Optimizer integration tests: EF21-Muon convergence across the config
//! matrix (compressors × geometries × smoothness regimes), protocol-state
//! invariants under randomized configurations, and the divergence story.

use efmuon::funcs::{CoshObjective, Logistic, MatrixQuadratic, Objective, Quadratics, ThreeQuadratics};
use efmuon::lmo::LmoKind;
use efmuon::opt::ef21::{state_consistency, Ef21MuonSeq};
use efmuon::opt::{LayerGeometry, Schedule};
use efmuon::util::proptest::check;
use efmuon::util::rng::Rng;

fn geom(kind: LmoKind) -> Vec<LayerGeometry> {
    vec![LayerGeometry { lmo: kind, radius_mult: 1.0 }]
}

#[allow(clippy::too_many_arguments)]
fn run(
    obj: &dyn Objective,
    kind: LmoKind,
    wspec: &str,
    sspec: &str,
    beta: f32,
    lr: f64,
    stochastic: bool,
    steps: usize,
) -> (f64, f64) {
    let mut opt = Ef21MuonSeq::new(
        obj,
        geom(kind),
        wspec,
        sspec,
        beta,
        Schedule::constant(lr),
        stochastic,
        9,
    )
    .unwrap();
    let trace = opt.run(obj, steps);
    (trace[0].grad_norm2, trace.last().unwrap().grad_norm2)
}

#[test]
fn convergence_matrix_compressors() {
    let mut rng = Rng::new(51);
    let q = Quadratics::new(4, 16, 0.7, 0.0, &mut rng);
    for spec in ["id", "top:0.3", "rank:0.3", "nat", "top:0.3+nat", "drop:0.7"] {
        let (g0, gk) = run(&q, LmoKind::Euclidean, spec, "id", 1.0, 0.03, false, 900);
        assert!(gk < 1e-2 * g0, "{spec}: {g0} -> {gk}");
    }
}

#[test]
fn convergence_with_bidirectional_compression() {
    // EF21-P on the downlink too (Theorem 3 setting)
    let mut rng = Rng::new(52);
    let q = Quadratics::new(3, 12, 0.5, 0.0, &mut rng);
    let (g0, gk) = run(&q, LmoKind::Euclidean, "top:0.3", "top:0.5", 1.0, 0.02, false, 1500);
    assert!(gk < 5e-2 * g0, "{g0} -> {gk}");
}

#[test]
fn convergence_sign_lmo() {
    // ℓ∞ geometry (the paper's embedding-layer oracle)
    let mut rng = Rng::new(53);
    let q = Quadratics::new(3, 10, 0.5, 0.0, &mut rng);
    let (g0, gk) = run(&q, LmoKind::SignLInf, "top:0.4", "id", 1.0, 0.01, false, 1500);
    // sign steps with constant radius stall in a neighborhood; still must
    // shrink the gradient substantially
    assert!(gk < 0.1 * g0, "{g0} -> {gk}");
}

#[test]
fn convergence_spectral_lmo_on_matrix_objective() {
    // Muon geometry on a matrix-valued problem, with RankK compression
    let mut rng = Rng::new(54);
    let mq = MatrixQuadratic::new(3, 12, 8, 0.0, &mut rng);
    let geometry = vec![LayerGeometry { lmo: LmoKind::Spectral, radius_mult: 1.0 }];
    let mut opt = Ef21MuonSeq::new(
        &mq,
        geometry,
        "rank:0.4",
        "id",
        1.0,
        Schedule::warmup_cosine(0.05, 10, 600, 0.05),
        false,
        5,
    )
    .unwrap();
    let trace = opt.run(&mq, 600);
    let g0 = trace[0].grad_norm2;
    let gk = trace.last().unwrap().grad_norm2;
    assert!(gk < 0.05 * g0, "{g0} -> {gk}");
}

#[test]
fn stochastic_momentum_reduces_estimator_variance() {
    // Role of Momentum (§3): M_j = (1-β)M_j + β∇f_j(·;ξ) reduces the
    // variance of the gradient estimator vs using raw stochastic gradients
    // (β = 1). With a small radius (little iterate drift → little momentum
    // lag) the estimator error must shrink by roughly a factor of β.
    let mut rng = Rng::new(55);
    let q = Quadratics::new(4, 16, 0.5, 0.6, &mut rng);
    let estimator_err = |beta: f32| {
        let mut opt = Ef21MuonSeq::new(
            &q,
            geom(LmoKind::Euclidean),
            "id",
            "id",
            beta,
            Schedule::constant(5e-4), // tiny radius: isolate variance effect
            true,
            13,
        )
        .unwrap();
        opt.run(&q, 300);
        // mean squared error of worker momentum vs exact local gradient
        let mut err = 0.0f64;
        for wkr in &opt.workers {
            let exact = q.grad_j(wkr.id, &wkr.w);
            err += wkr.m[0].sub(&exact[0]).norm2_sq();
        }
        err / opt.workers.len() as f64
    };
    let with_momentum = estimator_err(0.1);
    let without = estimator_err(1.0);
    assert!(
        with_momentum < 0.35 * without,
        "momentum {with_momentum} vs none {without}"
    );
}

#[test]
fn generalized_smooth_objective_converges() {
    // cosh objective ((L0,L1)-smooth): theory schedule from Thm 4
    let mut rng = Rng::new(56);
    let obj = CoshObjective::new(3, 8, &mut rng);
    let (g0, gk) = run(&obj, LmoKind::SignLInf, "top:0.5", "id", 1.0, 0.01, false, 2000);
    assert!(gk < 1e-2 * g0, "{g0} -> {gk}");
}

#[test]
fn logistic_regression_end_to_end() {
    let mut rng = Rng::new(57);
    let obj = Logistic::new(4, 40, 8, 0.6, 0.05, &mut rng);
    let mut opt = Ef21MuonSeq::new(
        &obj,
        geom(LmoKind::Euclidean),
        "top:0.25",
        "id",
        0.8,
        Schedule::constant(0.05),
        true,
        21,
    )
    .unwrap();
    let l0 = obj.loss(opt.params());
    let trace = opt.run(&obj, 800);
    let lk = trace.last().unwrap().loss;
    assert!(lk < 0.8 * l0, "loss {l0} -> {lk}");
}

#[test]
fn prop_protocol_state_invariants() {
    // across random configs: server W == worker W, server G == avg worker G
    check("ef21-invariants", 12, 58, |g| {
        let mut rng = Rng::new(300 + g.case as u64);
        let q = Quadratics::new(g.usize_in(1, 5), g.usize_in(2, 12), 1.0, 0.2, &mut rng);
        let specs = ["id", "top:0.3", "rank:0.5", "nat", "drop:0.5"];
        let wspec = specs[g.usize_in(0, specs.len() - 1)];
        let sspec = ["id", "top:0.5"][g.usize_in(0, 1)];
        let beta = g.f64_in(0.1, 1.0) as f32;
        let mut opt = Ef21MuonSeq::new(
            &q,
            geom(LmoKind::Euclidean),
            wspec,
            sspec,
            beta,
            Schedule::constant(0.01),
            true,
            g.case as u64,
        )
        .map_err(|e| e.to_string())?;
        for _ in 0..8 {
            opt.step(&q);
            state_consistency(&opt)?;
        }
        Ok(())
    });
}

#[test]
fn prop_w2s_bytes_monotone_in_sparsity() {
    check("bytes-monotone", 10, 59, |g| {
        let mut rng = Rng::new(400 + g.case as u64);
        let q = Quadratics::new(2, g.usize_in(50, 200), 0.5, 0.0, &mut rng);
        let frac_lo = g.f64_in(0.05, 0.3);
        let frac_hi = frac_lo * 2.0;
        let bytes = |frac: f64| {
            let mut opt = Ef21MuonSeq::new(
                &q,
                geom(LmoKind::Euclidean),
                &format!("top:{frac}"),
                "id",
                1.0,
                Schedule::constant(0.01),
                false,
                7,
            )
            .unwrap();
            opt.step(&q).w2s_bytes
        };
        if bytes(frac_lo) < bytes(frac_hi) {
            Ok(())
        } else {
            Err(format!("bytes not monotone at {frac_lo} vs {frac_hi}"))
        }
    });
}

#[test]
fn smoothness_probe_distinguishes_regimes() {
    // quadratics are globally L-smooth (L¹ ≈ 0); cosh is (L⁰,L¹)-smooth
    // with L¹ > 0 — the empirical signature the paper's §B builds on
    let mut rng = Rng::new(60);
    let quad = Quadratics::new(2, 8, 0.5, 0.0, &mut rng);
    let est_q =
        efmuon::exp::smoothness_probe(&quad, LmoKind::Euclidean, 0.05, 150, 3).unwrap();
    let cosh = CoshObjective::new(2, 8, &mut rng);
    let est_c =
        efmuon::exp::smoothness_probe(&cosh, LmoKind::Euclidean, 0.05, 150, 3).unwrap();
    // quadratics: slope statistically indistinguishable from 0 (fit noise);
    // cosh: positive slope — smoothness grows with the gradient norm
    assert!(
        est_q[0].l1.abs() < 0.2,
        "quadratic L1 should be ~0, got {}",
        est_q[0].l1
    );
    assert!(est_c[0].l1 > 0.05, "cosh L1 {} should be positive", est_c[0].l1);
    assert!(
        est_c[0].l1 > est_q[0].l1 + 0.05,
        "cosh L1 {} should exceed quadratic {}",
        est_c[0].l1,
        est_q[0].l1
    );
    assert!(est_c[0].r2 > 0.5, "cosh fit r2 {}", est_c[0].r2);
}

#[test]
fn divergence_demo_story_holds() {
    let (diverged, converged) = efmuon::exp::divergence::run_demo(60, &mut Vec::new()).unwrap();
    assert!(diverged, "naive DCGD must diverge on the Beznosikov example");
    assert!(converged, "EF21-Muon must converge on it");
}

#[test]
fn three_quadratics_naive_growth_is_exponential() {
    // quantitative check of the (1+γ)² per-step growth factor
    let obj = ThreeQuadratics::new();
    let (naive, _, _) = efmuon::exp::divergence::traces(40).unwrap();
    let ratio = naive[30] / naive[20];
    let expected = (1.1f64).powi(2 * 10);
    assert!(
        ratio > 0.5 * expected && ratio < 2.0 * expected,
        "growth {ratio} vs theory {expected}"
    );
    let _ = obj;
}
