//! LMO / sharp-operator identities from paper §C, enforced for every oracle:
//!   ⟨G, LMO_{B(0,t)}(G)⟩ = −t‖G‖⋆          (pairing identity)
//!   ‖LMO_{B(0,t)}(G)‖ = t                   (the step saturates the ball)
//!   G♯ = −‖G‖⋆·LMO_{B(0,1)}(G),  ‖G♯‖ = ‖G‖⋆

use efmuon::linalg::{norms, Matrix};
use efmuon::lmo::{Lmo, LmoKind, SpectralEngine};
use efmuon::util::proptest::check;
use efmuon::util::rng::Rng;

fn exact_kinds() -> Vec<(LmoKind, f64)> {
    // (kind, tolerance multiplier)
    vec![
        (LmoKind::SignLInf, 1e-4),
        (LmoKind::L1Top1, 1e-4),
        (LmoKind::Euclidean, 1e-4),
        (LmoKind::ColNorm, 1e-3),
        (LmoKind::NuclearRank1, 3e-2), // power iteration
    ]
}

/// Primal norm of a step, matched to the kind's ball.
fn ball_norm(kind: LmoKind, z: &Matrix) -> f64 {
    match kind {
        LmoKind::Spectral => norms::spectral_exact(z),
        LmoKind::SignLInf => norms::linf(z),
        LmoKind::L1Top1 => norms::l1(z),
        LmoKind::Euclidean => norms::fro(z),
        LmoKind::NuclearRank1 => norms::nuclear_exact(z),
        LmoKind::ColNorm => norms::max_col_l2(z),
    }
}

#[test]
fn prop_pairing_identity() {
    check("lmo-pairing", 25, 41, |g| {
        let m = g.usize_in(2, 12);
        let n = g.usize_in(2, 12);
        let x = g.matrix_of(m, n);
        if x.norm2() < 1e-3 {
            return Ok(());
        }
        let t = g.f64_in(0.1, 3.0) as f32;
        let mut rng = Rng::new(g.case as u64 + 5);
        for (kind, tol) in exact_kinds() {
            let lmo = Lmo::new(kind);
            let z = lmo.step(&x, t, &mut rng);
            let lhs = x.dot(&z);
            let rhs = -(t as f64) * lmo.dual_norm(&x, &mut rng);
            let scale = 1.0 + rhs.abs();
            if (lhs - rhs).abs() / scale > tol.max(1e-4) * 10.0 {
                return Err(format!("{kind:?}: <G,Z>={lhs} vs -t||G||*={rhs}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_step_saturates_ball() {
    check("lmo-ball", 25, 42, |g| {
        let m = g.usize_in(2, 12);
        let n = g.usize_in(2, 12);
        let x = g.matrix_of(m, n);
        if x.norm2() < 1e-3 {
            return Ok(());
        }
        let t = 1.5f32;
        let mut rng = Rng::new(g.case as u64 + 6);
        for (kind, tol) in exact_kinds() {
            // sign LMO with zero entries doesn't saturate exactly; skip the
            // adversarial sparse cases for the saturation check
            if kind == LmoKind::SignLInf && x.data.iter().any(|v| *v == 0.0) {
                continue;
            }
            let lmo = Lmo::new(kind);
            let z = lmo.step(&x, t, &mut rng);
            let nrm = ball_norm(kind, &z);
            if (nrm - t as f64).abs() > tol * 30.0 + 1e-3 {
                return Err(format!("{kind:?}: ||Z|| = {nrm}, want {t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharp_operator_identities() {
    check("sharp", 20, 43, |g| {
        let m = g.usize_in(2, 10);
        let n = g.usize_in(2, 10);
        let x = g.matrix_of(m, n);
        if x.norm2() < 1e-3 {
            return Ok(());
        }
        let mut rng = Rng::new(g.case as u64 + 7);
        for (kind, tol) in exact_kinds() {
            let lmo = Lmo::new(kind);
            let sharp = lmo.sharp(&x, &mut rng);
            let dual = lmo.dual_norm(&x, &mut rng);
            // ||G#|| = ||G||* (primal norm of sharp equals dual norm)
            let nrm = ball_norm(kind, &sharp);
            if (nrm - dual).abs() / (1.0 + dual) > tol * 30.0 {
                return Err(format!("{kind:?}: ||G#||={nrm} vs ||G||*={dual}"));
            }
            // <G, G#> = ||G||*^2
            let inner = x.dot(&sharp);
            if (inner - dual * dual).abs() / (1.0 + dual * dual) > tol * 30.0 {
                return Err(format!("{kind:?}: <G,G#>={inner} vs {}", dual * dual));
            }
        }
        Ok(())
    });
}

#[test]
fn spectral_ns_vs_exact_svd_engine() {
    // the NS engine approximates the exact polar LMO
    let mut rng = Rng::new(44);
    for &(m, n) in &[(12, 12), (8, 20), (20, 8)] {
        let x = Matrix::randn(m, n, 1.0, &mut rng);
        let ns = Lmo { kind: LmoKind::Spectral, ns_steps: 5, engine: SpectralEngine::Native };
        let exact = Lmo { kind: LmoKind::Spectral, ns_steps: 5, engine: SpectralEngine::ExactSvd };
        let a = ns.step(&x, 1.0, &mut rng);
        let b = exact.step(&x, 1.0, &mut rng);
        let cos = a.dot(&b) / (a.norm2() * b.norm2());
        assert!(cos > 0.97, "{m}x{n}: cos {cos}");
        // pairing identity holds approximately for the NS engine
        let lhs = x.dot(&a);
        let rhs = -norms::nuclear_exact(&x);
        assert!((lhs - rhs).abs() / rhs.abs() < 0.35, "{lhs} vs {rhs}");
    }
}

#[test]
fn spectral_exact_pairing_is_tight() {
    let mut rng = Rng::new(45);
    let x = Matrix::randn(9, 6, 1.0, &mut rng);
    let lmo = Lmo { kind: LmoKind::Spectral, ns_steps: 5, engine: SpectralEngine::ExactSvd };
    let z = lmo.step(&x, 2.0, &mut rng);
    let lhs = x.dot(&z);
    let rhs = -2.0 * norms::nuclear_exact(&x);
    assert!((lhs - rhs).abs() < 1e-3 * rhs.abs(), "{lhs} vs {rhs}");
    assert!((norms::spectral_exact(&z) - 2.0).abs() < 1e-3);
}

#[test]
fn zero_gradient_gives_zero_step() {
    let z = Matrix::zeros(4, 4);
    let mut rng = Rng::new(46);
    for (kind, _) in exact_kinds() {
        let step = Lmo::new(kind).step(&z, 1.0, &mut rng);
        assert!(step.norm2() < 1e-6, "{kind:?}");
    }
}
