//! Non-finite propagation suite (DESIGN.md §Non-finite values policy).
//!
//! A diverging run must *look* diverged: Inf/NaN entering the hot path has
//! to propagate to the output (or map through a documented total function),
//! never panic, and never be silently zeroed. These tests pin that contract
//! across the layers that historically broke it — the blocked matmul's
//! `aik == 0.0` skip branch masked `0·Inf`/`0·NaN`, and `partial_cmp`
//! sorts panicked on the first NaN singular value.

use efmuon::compress::quantize::{bf16_decode, bf16_encode};
use efmuon::compress::{codec, parse_spec, Compressor};
use efmuon::linalg::matmul::{matmul_into_reference, matmul_into_with_threads};
use efmuon::linalg::ns::{newton_schulz, NS_STEPS};
use efmuon::linalg::Matrix;
use efmuon::lmo::{Lmo, LmoKind, SpectralEngine};
use efmuon::util::rng::Rng;

/// Sprinkle non-finite values into an otherwise random matrix.
fn poisoned(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::randn(rows, cols, 1.0, &mut rng);
    let n = m.data.len();
    m.data[0] = f32::INFINITY;
    m.data[n / 3] = f32::NEG_INFINITY;
    m.data[n / 2] = f32::NAN;
    m.data[2 * n / 3] = -0.0;
    m.data[n - 1] = 0.0;
    m
}

/// The microkernel must agree with the scalar reference loop *bitwise* on
/// non-finite inputs at every thread count: identical NaN payloads,
/// identical signed zeros/infinities. This is the integration-scale twin of
/// the unit test in `linalg/matmul.rs` — sized to cross the 256-wide column
/// block so the packed edge/interior tiles and the parallel row split all
/// see the poison.
#[test]
fn blocked_matmul_matches_reference_bitwise_on_poison() {
    let a = poisoned(67, 301, 41);
    let b = poisoned(301, 259, 42);
    let mut want = Matrix::zeros(67, 259);
    matmul_into_reference(&a, &b, &mut want);
    assert!(
        want.data.iter().any(|v| v.is_nan()),
        "poison must reach the output (0·Inf = NaN), not be skipped"
    );
    for threads in [1usize, 2, 3, 8] {
        let mut got = Matrix::zeros(67, 259);
        matmul_into_with_threads(&a, &b, &mut got, threads);
        for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "threads={threads} entry {i}: {g} vs {w}"
            );
        }
    }
}

/// Newton–Schulz on a poisoned gradient: no panic, NaN reaches the output
/// (the spectral norm estimate is NaN, so the whole iterate is), and the
/// result is deterministic run-to-run.
#[test]
fn newton_schulz_propagates_nonfinite() {
    let g = poisoned(24, 16, 43);
    let o1 = newton_schulz(&g, NS_STEPS);
    assert_eq!(o1.rows, 24);
    assert_eq!(o1.cols, 16);
    assert!(
        o1.data.iter().any(|v| v.is_nan()),
        "NaN input must surface in the NS output"
    );
    let o2 = newton_schulz(&g, NS_STEPS);
    let bits = |m: &Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&o1), bits(&o2), "NS must stay deterministic under NaN");
}

/// The LMO step never panics on non-finite gradients, for both spectral
/// engines. The ExactSvd path is the historical `partial_cmp().unwrap()`
/// crash site (`linalg/svd.rs`): a NaN singular value used to abort the run
/// instead of reporting a diverged step.
#[test]
fn lmo_step_survives_nonfinite_gradients() {
    let g = poisoned(12, 9, 44);
    let mut rng = Rng::new(7);
    for engine in [SpectralEngine::Native, SpectralEngine::ExactSvd] {
        let lmo = Lmo { kind: LmoKind::Spectral, ns_steps: NS_STEPS, engine };
        let step = lmo.step(&g, 0.5, &mut rng);
        assert_eq!((step.rows, step.cols), (12, 9), "{engine:?}");
    }
    // sign(·) is a *total* map: ±Inf carries a sign (→ ∓t) and NaN
    // compares false both ways (→ 0, a feasible point) — documented in
    // DESIGN.md §Non-finite values policy, not an accidental zeroing.
    let lmo = Lmo::new(LmoKind::SignLInf);
    let mut g2 = Matrix::zeros(1, 3);
    g2.data.copy_from_slice(&[f32::INFINITY, f32::NEG_INFINITY, f32::NAN]);
    let s = lmo.step(&g2, 0.5, &mut rng);
    assert_eq!(s.data, vec![-0.5, 0.5, 0.0]);
}

/// bf16 is a pure truncation of the f32 exponent range, so the codec must
/// round-trip Inf, NaN and signed zero exactly — through the raw
/// encode/decode pair and through the full wire codec.
#[test]
fn bf16_codec_roundtrips_nonfinite() {
    // raw pair: Inf/−Inf/−0.0 are exact, NaN stays NaN (payload may be
    // quieted, but it must never become a number or an infinity)
    assert_eq!(bf16_decode(bf16_encode(f32::INFINITY)), f32::INFINITY);
    assert_eq!(bf16_decode(bf16_encode(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    assert!(bf16_decode(bf16_encode(f32::NAN)).is_nan());
    assert_eq!(
        bf16_decode(bf16_encode(-0.0)).to_bits(),
        (-0.0f32).to_bits(),
        "signed zero must survive"
    );
    assert_eq!(bf16_decode(bf16_encode(1.5)), 1.5);

    // full wire path: compress → encode → decode → widen
    let mut x = Matrix::zeros(2, 3);
    x.data
        .copy_from_slice(&[f32::INFINITY, f32::NEG_INFINITY, f32::NAN, -0.0, 1.5, -2.25]);
    let mut rng = Rng::new(9);
    let mut c = parse_spec("bf16").unwrap();
    let back = codec::decode(&codec::encode(&c.compress(&x, &mut rng))).unwrap().decode();
    assert_eq!(back.data[0], f32::INFINITY);
    assert_eq!(back.data[1], f32::NEG_INFINITY);
    assert!(back.data[2].is_nan());
    assert_eq!(back.data[3].to_bits(), (-0.0f32).to_bits());
    assert_eq!(back.data[4], 1.5);
    assert_eq!(back.data[5], -2.25);
}
