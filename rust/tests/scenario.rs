//! Deterministic scenario harness for the bidirectional-compression +
//! async-round + layer-sharding matrix: every scenario is one distributed
//! deployment shape (workers × w2s compressor × s2w compressor), driven
//! across {sync, async:0, async:1} × {Counted, Encoded} × {1..S shards}
//! on the objective backend.
//!
//! Locked-down invariants:
//!   (a) sync ≡ async:0 — bit-equal trajectories and identical meters;
//!   (b) Counted ≡ Encoded — identical wire bytes in BOTH directions and
//!       bit-equal trajectories (the codec is lossless and exact);
//!   (c) the threaded coordinator reproduces the sequential reference
//!       driver (the PR-1 golden trajectory) for every scenario, including
//!       non-`id` server compressors;
//!   (d) a non-`id` `server_comp` spends strictly fewer s2w wire bytes
//!       than `id` at matched final loss (the ISSUE-2 acceptance bar);
//!   (e) a 1-shard `Cluster` is bit-identical to the single `Coordinator`
//!       (trajectory, per-round bytes, meters) for every scenario and
//!       round mode — the ISSUE-3 golden match;
//!   (f) a multi-shard `Cluster` over a layer-separable stack reproduces
//!       independent per-part coordinators bit-for-bit (any compressor,
//!       including RNG-consuming rank/nat specs);
//!   (g) for deterministic compressors, the trajectory is invariant in the
//!       shard count across every round mode and transport;
//!   (h) the fault axis (ISSUE 6): a fault-free run with the fault policy
//!       enabled — quorum 1.0 lock-step anchor, or a partial quorum whose
//!       deadline never fires — is bit-identical to the policy-off run with
//!       all fault counters zero, for coordinators and clusters alike; an
//!       injected panic + straggler complete the run with exact meter
//!       counts; a checkpointed run killed mid-way resumes to the same
//!       final step with a finite eval loss.
//!   (i) the socket axis (ISSUE 9): a loopback TCP deployment is
//!       bit-identical to the in-memory channel run — trajectory, wire
//!       bytes in both directions, meters, eval — for sync and pipelined
//!       rounds and both transport modes, with zero transport counters on
//!       a healthy link; a chaos run over a flaky link (dropped broadcast
//!       frame + worker panic + a late joiner claiming freed id slots)
//!       completes with exact reconnect/respawn counters and a finite
//!       eval.
//!   (j) the sched axis (ISSUE 10): a bounded-epoch window with stealing
//!       disabled is bit-identical to the lock-step cluster — trajectory,
//!       per-round bytes, meters, eval — for every window, round mode and
//!       shard count, with zero steals and `epochs_ahead_max <= window`;
//!       an injected persistently slow shard under `steal:T` migrates
//!       exactly one layer (donor keeps its floor) and the run stays
//!       bitwise on the lock-step trajectory — migration moves state, not
//!       arithmetic.

use std::sync::Arc;

use efmuon::dist::cluster::{totals_consistent, Cluster};
use efmuon::dist::coordinator::Coordinator;
use efmuon::dist::fault::{FaultKind, FaultPlan, FaultPolicy};
use efmuon::dist::net::{spawn_loopback_workers, FlakyKind, FlakyPlan, NetCfg, NetHub};
use efmuon::dist::sched::{SchedSpec, ShardDelayPlan};
use efmuon::dist::service::GradService;
use efmuon::dist::{RoundMode, TransportMode};
use efmuon::funcs::{Objective, Quadratics, Stacked};
use efmuon::linalg::matrix::Layers;
use efmuon::lmo::LmoKind;
use efmuon::opt::ef21::Ef21MuonSeq;
use efmuon::opt::{LayerGeometry, ScheduleKind};
use efmuon::spec::{RunBuilder, RunSpec, SchedulePlan};
use efmuon::trace::{Phase, TraceAgg, Tracer};
use efmuon::train::{
    checkpoint, spawn_driver, spawn_driver_at, spawn_driver_traced, Driver, CHECKPOINT_STEM,
    TRACE_RING_CAP,
};
use efmuon::util::rng::Rng;

/// One deployment shape of the scenario table.
#[derive(Clone, Copy)]
struct Scenario {
    name: &'static str,
    workers: usize,
    dim: usize,
    w2s: &'static str,
    s2w: &'static str,
}

/// The scenario table: worker counts × w2s compressors × s2w compressors.
/// Kept deterministic (noise 0, beta 1) so bit-equality assertions hold.
const SCENARIOS: &[Scenario] = &[
    Scenario { name: "dense-both", workers: 2, dim: 8, w2s: "id", s2w: "id" },
    Scenario { name: "w2s-only", workers: 3, dim: 10, w2s: "top:0.3", s2w: "id" },
    Scenario { name: "s2w-only", workers: 2, dim: 12, w2s: "id", s2w: "top:0.5" },
    Scenario { name: "bidir-top", workers: 3, dim: 10, w2s: "top:0.3", s2w: "top:0.5" },
    Scenario { name: "bidir-mixed", workers: 4, dim: 12, w2s: "rank:0.4", s2w: "top:0.25" },
    Scenario { name: "bidir-nat", workers: 2, dim: 9, w2s: "top:0.3+nat", s2w: "nat" },
];

const ROUNDS: usize = 15;
const SEED: u64 = 5;

fn geom() -> Vec<LayerGeometry> {
    vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }]
}

fn objective(sc: &Scenario) -> Quadratics {
    // the objective seed is derived from the scenario shape so every run of
    // the same scenario sees the identical function
    let seed = 900 + sc.workers as u64 * 31 + sc.dim as u64;
    Quadratics::new(sc.workers, sc.dim, 0.5, 0.0, &mut Rng::new(seed))
}

/// All layers' data, concatenated (the trajectory fingerprint).
fn flatten(layers: &Layers) -> Vec<f32> {
    layers.iter().flat_map(|m| m.data.iter().copied()).collect()
}

/// Everything one run produces that the invariants compare.
struct RunTrace {
    /// Final server parameters (all layers, flattened).
    params: Vec<f32>,
    /// Per issued round: s2w broadcast bytes.
    s2w: Vec<usize>,
    /// Per absorbed round (in absorption order): w2s bytes per worker.
    w2s: Vec<usize>,
    /// Cumulative meters at the end.
    meter_w2s: u64,
    meter_s2w: u64,
    eval: f32,
}

/// The constant-radius plan every scenario uses (warmup 0 + min_lr_frac 1
/// materializes to exactly the constant schedule, bit for bit).
const FLAT: SchedulePlan =
    SchedulePlan { lr: 0.03, warmup: 0, min_lr_frac: 1.0, kind: ScheduleKind::WarmupCosine };

/// The typed spec of one scenario run — the scenario harness goes through
/// the same `RunBuilder` → `spawn_driver` path as `efmuon train`, so the
/// golden trajectories also lock the spec plumbing itself. Every scenario
/// contract knob (beta 1.0, seed, no NS artifact, full-codec iff Encoded)
/// is encoded HERE and only here — the coordinator and cluster runners
/// share it, so their golden comparisons can't desynchronize.
#[allow(clippy::too_many_arguments)]
fn scenario_spec(
    sc: &Scenario,
    shards: usize,
    mode: RoundMode,
    transport: TransportMode,
    rounds: usize,
    plan: SchedulePlan,
) -> RunSpec {
    let mut b = RunBuilder::new()
        .workers(sc.workers)
        .shards(shards)
        .steps(rounds)
        .worker_comp(sc.w2s)
        .server_comp(sc.s2w)
        .round(mode)
        .beta(1.0)
        .lr(plan.lr)
        .warmup(plan.warmup)
        .min_lr_frac(plan.min_lr_frac)
        .seed(SEED)
        .use_ns_artifact(false);
    if transport == TransportMode::Encoded {
        b = b.full_codec(true);
    }
    b.build().unwrap()
}

fn run_scenario(sc: &Scenario, mode: RoundMode, transport: TransportMode, rounds: usize) -> RunTrace {
    run_scenario_sched(sc, mode, transport, rounds, FLAT)
}

fn run_scenario_sched(
    sc: &Scenario,
    mode: RoundMode,
    transport: TransportMode,
    rounds: usize,
    plan: SchedulePlan,
) -> RunTrace {
    let spec = scenario_spec(sc, 1, mode, transport, rounds, plan);
    let q = objective(sc);
    let x0 = q.init(&mut Rng::new(SEED));
    let svc = GradService::spawn_objective(Box::new(q), SEED);
    let mut drv = spawn_driver(&spec, x0, geom(), svc.handle()).unwrap();
    let mut s2w = Vec::new();
    let mut w2s = Vec::new();
    let mut record = |s: &efmuon::train::DriveRound| {
        // per-call entries carry the issued broadcast's bytes; drained-tail
        // entries carry 0 (their broadcast was metered when issued)
        if s.s2w_bytes > 0 {
            s2w.push(s.s2w_bytes);
        }
        if s.absorbed_step.is_some() {
            w2s.push(s.w2s_bytes_per_worker);
        }
    };
    for _ in 0..rounds {
        record(&drv.round().unwrap());
    }
    for s in drv.drain().unwrap() {
        record(&s);
    }
    drop(record);
    RunTrace {
        params: flatten(&drv.params().unwrap()),
        s2w,
        w2s,
        meter_w2s: drv.w2s(),
        meter_s2w: drv.s2w(),
        eval: drv.eval().unwrap(),
    }
}

/// Run a [`Cluster`] over an arbitrary objective and collect the same
/// trace the coordinator runs produce (per-round byte streams filter the
/// drained-tail entries identically).
#[allow(clippy::too_many_arguments)]
fn run_cluster_obj(
    obj: Box<dyn Objective>,
    workers: usize,
    n_layers: usize,
    w2s: &'static str,
    s2w: &'static str,
    shards: usize,
    mode: RoundMode,
    transport: TransportMode,
    rounds: usize,
    plan: SchedulePlan,
) -> (RunTrace, Vec<Vec<usize>>) {
    let x0 = obj.init(&mut Rng::new(SEED));
    let svc = GradService::spawn_objective(obj, SEED);
    // same contract knobs as every coordinator scenario (dim is unused by
    // the spec — the objective is supplied by the caller)
    let sc = Scenario { name: "cluster", workers, dim: 0, w2s, s2w };
    let spec = scenario_spec(&sc, shards, mode, transport, rounds, plan);
    let mut cluster = Cluster::spawn(
        x0,
        vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; n_layers],
        svc.handle(),
        spec.cluster_cfg(),
    )
    .unwrap();
    let stats = cluster.run(rounds).unwrap();
    let mut s2wv = Vec::new();
    let mut w2sv = Vec::new();
    for s in &stats {
        if s.s2w_bytes > 0 {
            s2wv.push(s.s2w_bytes);
        }
        if s.absorbed_step.is_some() {
            w2sv.push(s.w2s_bytes_per_worker);
        }
    }
    let meter = cluster.meter();
    assert!(totals_consistent(&meter), "cluster meter rollup inconsistent");
    let partition = cluster.partition().to_vec();
    let trace = RunTrace {
        params: flatten(&cluster.params().unwrap()),
        s2w: s2wv,
        w2s: w2sv,
        meter_w2s: meter.w2s(),
        meter_s2w: meter.s2w(),
        eval: cluster.eval().unwrap(),
    };
    (trace, partition)
}

/// The scenario objective boxed for the cluster runner.
fn run_cluster_scenario(
    sc: &Scenario,
    shards: usize,
    mode: RoundMode,
    transport: TransportMode,
    rounds: usize,
) -> RunTrace {
    let q = objective(sc);
    run_cluster_obj(
        Box::new(q),
        sc.workers,
        1,
        sc.w2s,
        sc.s2w,
        shards,
        mode,
        transport,
        rounds,
        FLAT,
    )
    .0
}

/// (a) `RoundMode::Sync` and `RoundMode::Async { lookahead: 0 }` must be
/// bit-equal: same trajectory, same wire bytes, same meters.
#[test]
fn sync_equals_async0_bitwise() {
    for sc in SCENARIOS {
        let sync = run_scenario(sc, RoundMode::Sync, TransportMode::Counted, ROUNDS);
        let async0 =
            run_scenario(sc, RoundMode::Async { lookahead: 0 }, TransportMode::Counted, ROUNDS);
        assert_eq!(sync.params, async0.params, "{}: trajectory", sc.name);
        assert_eq!(sync.s2w, async0.s2w, "{}: s2w bytes", sc.name);
        assert_eq!(sync.w2s, async0.w2s, "{}: w2s bytes", sc.name);
        assert_eq!(sync.meter_w2s, async0.meter_w2s, "{}: w2s meter", sc.name);
        assert_eq!(sync.meter_s2w, async0.meter_s2w, "{}: s2w meter", sc.name);
    }
}

/// (b) `Counted` and `Encoded` transports must agree on wire bytes in both
/// directions and on the trajectory — for sync and pipelined rounds alike.
#[test]
fn counted_equals_encoded_both_directions() {
    for sc in SCENARIOS {
        for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
            let c = run_scenario(sc, mode, TransportMode::Counted, ROUNDS);
            let e = run_scenario(sc, mode, TransportMode::Encoded, ROUNDS);
            let tag = format!("{} / {}", sc.name, mode.spec());
            assert_eq!(c.s2w, e.s2w, "{tag}: s2w bytes per round");
            assert_eq!(c.w2s, e.w2s, "{tag}: w2s bytes per round");
            assert_eq!(c.meter_s2w, e.meter_s2w, "{tag}: s2w meter");
            assert_eq!(c.meter_w2s, e.meter_w2s, "{tag}: w2s meter");
            assert_eq!(c.params, e.params, "{tag}: trajectory");
        }
    }
}

/// (c) The threaded sync coordinator reproduces the sequential reference
/// driver — the golden trajectory the dist stack was locked to in PR 1 —
/// for every scenario, including active EF21-P server compressors.
#[test]
fn coordinator_matches_sequential_golden() {
    for sc in SCENARIOS {
        let q_seq = objective(sc);
        let mut seq = Ef21MuonSeq::new(
            &q_seq,
            geom(),
            sc.w2s,
            sc.s2w,
            1.0,
            FLAT.materialize(ROUNDS),
            false,
            SEED,
        )
        .unwrap();
        let mut golden_w2s = Vec::new();
        let mut golden_s2w = Vec::new();
        for _ in 0..ROUNDS {
            let s = seq.step(&q_seq);
            golden_w2s.push(s.w2s_bytes);
            golden_s2w.push(s.s2w_bytes);
        }

        let dist = run_scenario(sc, RoundMode::Sync, TransportMode::Encoded, ROUNDS);
        assert_eq!(dist.w2s, golden_w2s, "{}: w2s bytes vs golden", sc.name);
        assert_eq!(dist.s2w, golden_s2w, "{}: s2w bytes vs golden", sc.name);
        let max_diff: f32 = seq.params()[0]
            .data
            .iter()
            .zip(&dist.params)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(
            max_diff < 1e-6,
            "{}: trajectory diverged from golden by {max_diff}",
            sc.name
        );
    }
}

/// (d) Acceptance: with everything else matched, a non-`id` `server_comp`
/// spends strictly fewer s2w wire bytes than `id` while reaching the same
/// final loss (within 1e-3) on the objective backend.
#[test]
fn compressed_s2w_saves_bytes_at_matched_loss() {
    let dense = Scenario { name: "accept-id", workers: 3, dim: 12, w2s: "top:0.3", s2w: "id" };
    let comp = Scenario { name: "accept-top", workers: 3, dim: 12, w2s: "top:0.3", s2w: "top:0.5" };
    // decaying radius: both runs converge to the optimum's neighborhood, so
    // their final losses match to well under the 1e-3 bar
    let rounds = 600;
    let plan =
        SchedulePlan { lr: 0.05, warmup: 0, min_lr_frac: 0.02, kind: ScheduleKind::WarmupCosine };
    let a = run_scenario_sched(&dense, RoundMode::Sync, TransportMode::Counted, rounds, plan);
    let b = run_scenario_sched(&comp, RoundMode::Sync, TransportMode::Counted, rounds, plan);
    assert!(
        b.meter_s2w < a.meter_s2w,
        "compressed s2w must be strictly cheaper: {} vs {}",
        b.meter_s2w,
        a.meter_s2w
    );
    let gap = (a.eval - b.eval).abs();
    assert!(
        gap < 1e-3,
        "final losses must match within 1e-3: id={} top={} (gap {gap})",
        a.eval,
        b.eval
    );
    // the w2s direction is untouched by the server compressor choice
    assert_eq!(a.meter_w2s, b.meter_w2s);
}

// ---------------------------------------------------------------------------
// The shards axis (ISSUE 3): multi-coordinator layer sharding
// ---------------------------------------------------------------------------

/// (e) Golden match: a 1-shard [`Cluster`] must be bit-identical to the
/// single [`Coordinator`] — trajectory, per-round wire bytes in both
/// directions, cumulative meters, and eval — for every scenario and round
/// mode. This is the ISSUE-3 acceptance bar: the cluster layer adds
/// topology, never arithmetic.
#[test]
fn cluster_one_shard_matches_coordinator_bitwise() {
    for sc in SCENARIOS {
        for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
            let coord = run_scenario(sc, mode, TransportMode::Counted, ROUNDS);
            let clus = run_cluster_scenario(sc, 1, mode, TransportMode::Counted, ROUNDS);
            let tag = format!("{} / {}", sc.name, mode.spec());
            assert_eq!(coord.params, clus.params, "{tag}: trajectory");
            assert_eq!(coord.s2w, clus.s2w, "{tag}: s2w bytes per round");
            assert_eq!(coord.w2s, clus.w2s, "{tag}: w2s bytes per round");
            assert_eq!(coord.meter_w2s, clus.meter_w2s, "{tag}: w2s meter");
            assert_eq!(coord.meter_s2w, clus.meter_s2w, "{tag}: s2w meter");
            assert_eq!(coord.eval, clus.eval, "{tag}: eval");
        }
    }
}

/// Two-part layer-separable stack used by the multi-shard scenarios. Each
/// part gets its own seed so an identical standalone copy can be built for
/// the independent-coordinator comparison.
fn stacked_parts(workers: usize) -> Vec<Quadratics> {
    vec![
        Quadratics::new(workers, 12, 0.5, 0.0, &mut Rng::new(2100)),
        Quadratics::new(workers, 10, 0.5, 0.0, &mut Rng::new(2101)),
    ]
}

/// (f) A 2-shard cluster over a layer-separable stack must reproduce two
/// *independent* single-part coordinators bit-for-bit — per-shard
/// trajectories, per-round bytes, and meters — including RNG-consuming
/// compressors (rank + nat), because each shard derives exactly the
/// per-layer/per-worker streams a standalone deployment of its slice
/// would.
#[test]
fn cluster_shards_match_independent_coordinators() {
    let workers = 3;
    for (w2s, s2w) in [("top:0.3", "top:0.5"), ("rank:0.4+nat", "nat")] {
        let stack = Stacked::new(
            stacked_parts(workers)
                .into_iter()
                .map(|q| Box::new(q) as Box<dyn Objective>)
                .collect(),
        )
        .unwrap();
        let x0_full = stack.init(&mut Rng::new(SEED));
        let shapes = stack.layer_shapes();

        let svc = GradService::spawn_objective(Box::new(stack), SEED);
        let sc = Scenario { name: "stack", workers, dim: 0, w2s, s2w };
        let spec = scenario_spec(&sc, 2, RoundMode::Sync, TransportMode::Counted, ROUNDS, FLAT);
        let mut cluster = Cluster::spawn(
            x0_full.clone(),
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; shapes.len()],
            svc.handle(),
            spec.cluster_cfg(),
        )
        .unwrap();
        // sizes 12 > 10: the greedy partition puts layer 0 on shard 0 and
        // layer 1 on shard 1
        assert_eq!(cluster.partition(), &[vec![0], vec![1]]);
        let stats = cluster.run(ROUNDS).unwrap();
        let full_params = cluster.params().unwrap();
        let meter = cluster.meter();

        for (shard, part) in stacked_parts(workers).into_iter().enumerate() {
            let x0_s: Layers = vec![x0_full[shard].clone()];
            let n = part.num_workers();
            let svc_s = GradService::spawn_objective(Box::new(part), SEED);
            let sc_solo = Scenario { name: "stack-solo", workers: n, dim: 0, w2s, s2w };
            let solo_spec = scenario_spec(&sc_solo, 1, RoundMode::Sync, TransportMode::Counted, ROUNDS, FLAT);
            let mut coord = Coordinator::spawn(
                x0_s,
                vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }],
                svc_s.handle(),
                solo_spec.coordinator_cfg(),
            )
            .unwrap();
            let solo = coord.run(ROUNDS).unwrap();
            let tag = format!("{w2s}/{s2w} shard {shard}");
            for (k, (c, s)) in stats.iter().zip(&solo).enumerate() {
                assert_eq!(
                    c.per_shard[shard].w2s_bytes_per_worker, s.w2s_bytes_per_worker,
                    "{tag}: round {k} w2s bytes"
                );
                assert_eq!(
                    c.per_shard[shard].s2w_bytes, s.s2w_bytes,
                    "{tag}: round {k} s2w bytes"
                );
            }
            assert_eq!(
                full_params[shard].data, coord.params()[0].data,
                "{tag}: trajectory"
            );
            assert_eq!(meter.per_shard[shard].w2s_per_worker, coord.meter().w2s(), "{tag}: w2s meter");
            assert_eq!(meter.per_shard[shard].s2w_total, coord.meter().s2w(), "{tag}: s2w meter");
            assert_eq!(meter.per_shard[shard].w2s_all, coord.meter().w2s_all(), "{tag}: w2s_all meter");
        }
    }
}

/// (g) The full shards axis: for deterministic compressors over a
/// layer-separable stack, the trajectory, wire bytes and meters are
/// invariant in the shard count across every round mode and transport —
/// and identical reruns are bit-equal (determinism under concurrent shard
/// threads and pipelined rounds).
#[test]
fn cluster_trajectory_invariant_across_shards_modes_transports() {
    let workers = 2;
    let mk = || -> Box<dyn Objective> {
        Box::new(
            Stacked::new(vec![
                Box::new(Quadratics::new(workers, 8, 0.5, 0.0, &mut Rng::new(2200)))
                    as Box<dyn Objective>,
                Box::new(Quadratics::new(workers, 6, 0.5, 0.0, &mut Rng::new(2201))),
                Box::new(Quadratics::new(workers, 4, 0.5, 0.0, &mut Rng::new(2202))),
            ])
            .unwrap(),
        )
    };
    for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 0 }, RoundMode::Async { lookahead: 1 }] {
        let (reference, _) = run_cluster_obj(
            mk(),
            workers,
            3,
            "top:0.3",
            "top:0.5",
            1,
            mode,
            TransportMode::Counted,
            ROUNDS,
            FLAT,
        );
        for shards in [1usize, 2, 3] {
            for transport in [TransportMode::Counted, TransportMode::Encoded] {
                let (t, partition) = run_cluster_obj(
                    mk(),
                    workers,
                    3,
                    "top:0.3",
                    "top:0.5",
                    shards,
                    mode,
                    transport,
                    ROUNDS,
                    FLAT,
                );
                let tag = format!("{} shards / {} / {:?}", shards, mode.spec(), transport);
                // coverage: the partition owns every layer exactly once
                let mut owned: Vec<usize> = partition.iter().flatten().copied().collect();
                owned.sort_unstable();
                assert_eq!(owned, vec![0, 1, 2], "{tag}: partition coverage");
                assert_eq!(reference.params, t.params, "{tag}: trajectory");
                assert_eq!(reference.meter_w2s, t.meter_w2s, "{tag}: w2s meter");
                assert_eq!(reference.meter_s2w, t.meter_s2w, "{tag}: s2w meter");
                assert_eq!(reference.eval, t.eval, "{tag}: eval");
            }
        }
        // determinism: an identical rerun is bit-equal (concurrent shard
        // threads + pipelining never leak scheduling into the trajectory)
        let (a, _) = run_cluster_obj(
            mk(), workers, 3, "top:0.3", "top:0.5", 3, mode,
            TransportMode::Counted, ROUNDS, FLAT,
        );
        let (b, _) = run_cluster_obj(
            mk(), workers, 3, "top:0.3", "top:0.5", 3, mode,
            TransportMode::Counted, ROUNDS, FLAT,
        );
        assert_eq!(a.params, b.params, "{}: rerun determinism", mode.spec());
        assert_eq!(a.w2s, b.w2s);
        assert_eq!(a.s2w, b.s2w);
    }
}

/// Pipelined rounds converge too: async:1 lands within a small tolerance
/// of the sync final loss once the radius decays (staleness costs a bit of
/// transient, not the limit), and the pipeline drains fully.
#[test]
fn async_converges_near_sync() {
    let sc = Scenario { name: "async-conv", workers: 3, dim: 12, w2s: "top:0.3", s2w: "top:0.5" };
    let rounds = 600;
    let plan =
        SchedulePlan { lr: 0.05, warmup: 0, min_lr_frac: 0.02, kind: ScheduleKind::WarmupCosine };
    let sync = run_scenario_sched(&sc, RoundMode::Sync, TransportMode::Counted, rounds, plan);
    let pipe = run_scenario_sched(&sc, RoundMode::Async { lookahead: 1 }, TransportMode::Counted, rounds, plan);
    // every issued round was absorbed by the end (run() drains)
    assert_eq!(pipe.w2s.len(), rounds);
    let gap = (sync.eval - pipe.eval).abs();
    assert!(gap < 1e-2, "async:1 final loss {} vs sync {} (gap {gap})", pipe.eval, sync.eval);
}

// ---------------------------------------------------------------------------
// The tracer axis (ISSUE 8): tracer-on ≡ tracer-off, bitwise
// ---------------------------------------------------------------------------

/// Tracer-on must be bit-identical to tracer-off — trajectory, per-round
/// bytes in both directions, meters, eval — for every scenario and round
/// mode, because `Tracer::Noop` is the golden anchor: stamping reads a
/// clock and pushes into a ring, and neither may ever participate in the
/// arithmetic. The live ring must ALSO have seen the exact round
/// lifecycle: one broadcast and one full absorb per round, one uplink per
/// worker per round, zero fault-phase events in a fault-free run, zero
/// overflow drops.
#[test]
fn tracer_on_matches_tracer_off_bitwise() {
    for sc in SCENARIOS {
        for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
            let off = run_scenario(sc, mode, TransportMode::Counted, ROUNDS);

            let spec = scenario_spec(sc, 1, mode, TransportMode::Counted, ROUNDS, FLAT);
            let q = objective(sc);
            let x0 = q.init(&mut Rng::new(SEED));
            let svc = GradService::spawn_objective(Box::new(q), SEED);
            let (tracer, ring) = Tracer::ring(TRACE_RING_CAP);
            let mut drv = spawn_driver_traced(&spec, x0, geom(), svc.handle(), 0, tracer).unwrap();
            let mut s2w = Vec::new();
            let mut w2s = Vec::new();
            let mut record = |s: &efmuon::train::DriveRound| {
                if s.s2w_bytes > 0 {
                    s2w.push(s.s2w_bytes);
                }
                if s.absorbed_step.is_some() {
                    w2s.push(s.w2s_bytes_per_worker);
                }
            };
            for _ in 0..ROUNDS {
                record(&drv.round().unwrap());
            }
            for s in drv.drain().unwrap() {
                record(&s);
            }
            drop(record);

            let tag = format!("{} / {} / traced", sc.name, mode.spec());
            assert_eq!(off.params, flatten(&drv.params().unwrap()), "{tag}: trajectory");
            assert_eq!(off.s2w, s2w, "{tag}: s2w bytes per round");
            assert_eq!(off.w2s, w2s, "{tag}: w2s bytes per round");
            assert_eq!(off.meter_w2s, drv.w2s(), "{tag}: w2s meter");
            assert_eq!(off.meter_s2w, drv.s2w(), "{tag}: s2w meter");
            assert_eq!(off.eval, drv.eval().unwrap(), "{tag}: eval");

            let mut agg = TraceAgg::default();
            agg.absorb(&ring.drain());
            assert_eq!(agg.count(Phase::Broadcast), ROUNDS as u64, "{tag}: broadcasts");
            assert_eq!(agg.count(Phase::Absorb), ROUNDS as u64, "{tag}: absorbs");
            assert_eq!(
                agg.count(Phase::Uplink),
                (ROUNDS * sc.workers) as u64,
                "{tag}: one uplink per worker per round"
            );
            let fault_phases = agg.count(Phase::Quorum)
                + agg.count(Phase::StragglerSkip)
                + agg.count(Phase::LateFold)
                + agg.count(Phase::Respawn);
            assert_eq!(fault_phases, 0, "{tag}: no fault-phase events in a fault-free run");
            assert_eq!(ring.dropped(), 0, "{tag}: ring must not overflow");
        }
    }
}

/// The same identity through the cluster layer: a live tracer threaded to
/// every shard coordinator, the snapshot caches and the root reducer must
/// leave the multi-shard trajectory bit-identical, while the ring records
/// per-shard broadcasts, the root's board seals and the cache assemblies.
#[test]
fn tracer_on_cluster_matches_tracer_off_bitwise() {
    let workers = 3;
    let shards = 2;
    let mk = || -> Box<dyn Objective> {
        Box::new(
            Stacked::new(
                stacked_parts(workers)
                    .into_iter()
                    .map(|q| Box::new(q) as Box<dyn Objective>)
                    .collect(),
            )
            .unwrap(),
        )
    };
    for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
        let (reference, _) = run_cluster_obj(
            mk(),
            workers,
            2,
            "top:0.3",
            "top:0.5",
            shards,
            mode,
            TransportMode::Counted,
            ROUNDS,
            FLAT,
        );
        let obj = mk();
        let x0 = obj.init(&mut Rng::new(SEED));
        let svc = GradService::spawn_objective(obj, SEED);
        let sc = Scenario { name: "cluster-trace", workers, dim: 0, w2s: "top:0.3", s2w: "top:0.5" };
        let spec = scenario_spec(&sc, shards, mode, TransportMode::Counted, ROUNDS, FLAT);
        let mut cfg = spec.cluster_cfg();
        let (tracer, ring) = Tracer::ring(TRACE_RING_CAP);
        cfg.tracer = tracer;
        let mut cluster = Cluster::spawn(
            x0,
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; 2],
            svc.handle(),
            cfg,
        )
        .unwrap();
        cluster.run(ROUNDS).unwrap();
        let meter = cluster.meter();
        let tag = format!("cluster traced / {}", mode.spec());
        assert_eq!(flatten(&cluster.params().unwrap()), reference.params, "{tag}: trajectory");
        assert_eq!(meter.w2s(), reference.meter_w2s, "{tag}: w2s meter");
        assert_eq!(meter.s2w(), reference.meter_s2w, "{tag}: s2w meter");
        assert_eq!(cluster.eval().unwrap(), reference.eval, "{tag}: eval");

        let mut agg = TraceAgg::default();
        agg.absorb(&ring.drain());
        assert_eq!(
            agg.count(Phase::Broadcast),
            (ROUNDS * shards) as u64,
            "{tag}: one broadcast per shard per round"
        );
        assert_eq!(
            agg.count(Phase::Uplink),
            (ROUNDS * shards * workers) as u64,
            "{tag}: per-shard per-worker uplinks"
        );
        assert_eq!(
            agg.count(Phase::BoardSeal),
            ROUNDS as u64,
            "{tag}: the root seals one board epoch per round"
        );
        assert!(
            agg.count(Phase::SnapAssemble) >= 1,
            "{tag}: at least the first snapshot is assembled from scratch"
        );
        assert_eq!(ring.dropped(), 0, "{tag}: ring must not overflow");
    }
}

// ---------------------------------------------------------------------------
// The fault axis (ISSUE 6): deadlines, quorum, respawn, checkpointed recovery
// ---------------------------------------------------------------------------

/// Run one scenario through the coordinator with a fault policy (and an
/// optional injection plan), returning the usual trace plus the fault
/// counters `(stragglers, respawns, partial_rounds)`. The spec goes through
/// the same `RunBuilder` path as every other scenario; only the test-only
/// injection plan is attached to the built config directly (a `FaultPlan`
/// is a harness hook, never part of a spec).
fn run_scenario_fault(
    sc: &Scenario,
    mode: RoundMode,
    rounds: usize,
    policy: &str,
    plan: Option<FaultPlan>,
) -> (RunTrace, (u64, u64, u64)) {
    let spec = scenario_spec(sc, 1, mode, TransportMode::Counted, rounds, FLAT);
    let mut cfg = spec.coordinator_cfg();
    cfg.fault = FaultPolicy::parse(policy).unwrap();
    cfg.fault_plan = plan.map(Arc::new);
    let q = objective(sc);
    let x0 = q.init(&mut Rng::new(SEED));
    let svc = GradService::spawn_objective(Box::new(q), SEED);
    let mut coord = Coordinator::spawn(x0, geom(), svc.handle(), cfg).unwrap();
    let stats = coord.run(rounds).unwrap();
    let mut s2w = Vec::new();
    let mut w2s = Vec::new();
    for s in &stats {
        if s.s2w_bytes > 0 {
            s2w.push(s.s2w_bytes);
        }
        if s.absorbed_step.is_some() {
            w2s.push(s.w2s_bytes_per_worker);
        }
    }
    let m = coord.meter();
    let counts = (m.stragglers(), m.respawns(), m.partial_rounds());
    let trace = RunTrace {
        params: flatten(coord.params()),
        s2w,
        w2s,
        meter_w2s: m.w2s(),
        meter_s2w: m.s2w(),
        eval: coord.eval().unwrap(),
    };
    (trace, counts)
}

/// (h) A fault-free run with the fault policy ENABLED is bit-identical to
/// the policy-off run — trajectory, per-round bytes in both directions,
/// meters, eval — across every scenario and round mode, with all fault
/// counters zero. Two policies lock the two code paths: quorum 1.0 can
/// never absorb below `n` replies regardless of the deadline (the golden
/// lock-step anchor), and a partial quorum whose generous deadline never
/// fires aggregates over every reply (full aggregation ≡ quorum
/// aggregation with all workers present).
#[test]
fn fault_free_policy_on_matches_policy_off_bitwise() {
    const POLICIES: &[&str] = &[
        "deadline:50,quorum:1,respawns:1,backoff:1",
        "deadline:5000,quorum:0.5,respawns:1,backoff:1",
    ];
    for sc in SCENARIOS {
        for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
            let off = run_scenario(sc, mode, TransportMode::Counted, ROUNDS);
            for policy in POLICIES {
                let (on, counts) = run_scenario_fault(sc, mode, ROUNDS, policy, None);
                let tag = format!("{} / {} / {policy}", sc.name, mode.spec());
                assert_eq!(off.params, on.params, "{tag}: trajectory");
                assert_eq!(off.s2w, on.s2w, "{tag}: s2w bytes per round");
                assert_eq!(off.w2s, on.w2s, "{tag}: w2s bytes per round");
                assert_eq!(off.meter_w2s, on.meter_w2s, "{tag}: w2s meter");
                assert_eq!(off.meter_s2w, on.meter_s2w, "{tag}: s2w meter");
                assert_eq!(off.eval, on.eval, "{tag}: eval");
                assert_eq!(counts, (0, 0, 0), "{tag}: fault counters must stay zero");
            }
        }
    }
}

/// (h) The same policy-on ≡ policy-off identity through the cluster layer:
/// the policy is forwarded to every shard coordinator, and a fault-free
/// multi-shard run stays bit-identical with zero rolled-up fault counters.
#[test]
fn fault_free_policy_on_cluster_matches_policy_off_bitwise() {
    let workers = 3;
    let mk = || -> Box<dyn Objective> {
        Box::new(
            Stacked::new(
                stacked_parts(workers)
                    .into_iter()
                    .map(|q| Box::new(q) as Box<dyn Objective>)
                    .collect(),
            )
            .unwrap(),
        )
    };
    for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
        let (reference, _) = run_cluster_obj(
            mk(),
            workers,
            2,
            "top:0.3",
            "top:0.5",
            2,
            mode,
            TransportMode::Counted,
            ROUNDS,
            FLAT,
        );
        let obj = mk();
        let x0 = obj.init(&mut Rng::new(SEED));
        let svc = GradService::spawn_objective(obj, SEED);
        let sc = Scenario { name: "cluster-fault", workers, dim: 0, w2s: "top:0.3", s2w: "top:0.5" };
        let spec = scenario_spec(&sc, 2, mode, TransportMode::Counted, ROUNDS, FLAT);
        let mut cfg = spec.cluster_cfg();
        cfg.fault = FaultPolicy::parse("deadline:5000,quorum:0.5,respawns:1,backoff:1").unwrap();
        let mut cluster = Cluster::spawn(
            x0,
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; 2],
            svc.handle(),
            cfg,
        )
        .unwrap();
        cluster.run(ROUNDS).unwrap();
        let meter = cluster.meter();
        let totals = meter.totals();
        let tag = format!("cluster policy-on / {}", mode.spec());
        assert_eq!(flatten(&cluster.params().unwrap()), reference.params, "{tag}: trajectory");
        assert_eq!(meter.w2s(), reference.meter_w2s, "{tag}: w2s meter");
        assert_eq!(meter.s2w(), reference.meter_s2w, "{tag}: s2w meter");
        assert_eq!(cluster.eval().unwrap(), reference.eval, "{tag}: eval");
        assert_eq!(
            (totals.stragglers, totals.respawns, totals.partial_rounds),
            (0, 0, 0),
            "{tag}: fault counters must stay zero"
        );
    }
}

/// (h) Acceptance: 4 workers, a seeded plan injecting one mid-run panic and
/// one delay-straggler, under a quorum policy with a respawn budget. The
/// run completes with exactly one straggler, one respawn, and two partial
/// rounds — and every round still broadcast and absorbed.
#[test]
fn fault_acceptance_one_panic_one_straggler_exact_counts() {
    let sc = Scenario { name: "fault-accept", workers: 4, dim: 12, w2s: "top:0.3", s2w: "top:0.5" };
    let rounds = 10;
    let plan = FaultPlan::new()
        .with(1, 3, FaultKind::Panic)
        .with(2, 6, FaultKind::DelayMs(300));
    // deadline 200 < delay 300 < 2x deadline: the delayed reply misses its
    // own round's deadline but lands before the NEXT round's, so it is
    // counted late exactly once; the panic is detected via the failure
    // notification well inside the deadline, so it never double-counts as a
    // straggler
    let (trace, (stragglers, respawns, partial)) = run_scenario_fault(
        &sc,
        RoundMode::Sync,
        rounds,
        "deadline:200,quorum:0.5,respawns:2,backoff:0",
        Some(plan),
    );
    assert_eq!(stragglers, 1, "exactly the delayed worker straggles");
    assert_eq!(respawns, 1, "exactly the panicked worker is respawned");
    assert_eq!(partial, 2, "the panic round and the straggler round absorb partially");
    assert_eq!(trace.s2w.len(), rounds, "every round broadcast");
    assert_eq!(trace.w2s.len(), rounds, "every round absorbed");
    assert!(trace.eval.is_finite(), "eval loss must stay finite, got {}", trace.eval);
    assert!(trace.params.iter().all(|v| v.is_finite()), "params must stay finite");
}

/// (h) Checkpointed recovery: a run checkpointed at step `cut` and then
/// dropped (the "kill") resumes from the checkpoint into a fresh driver at
/// the stored step, covers exactly the remaining steps, and finishes with a
/// finite eval loss.
#[test]
fn fault_checkpoint_resume_reaches_final_step() {
    let sc = Scenario { name: "fault-resume", workers: 3, dim: 10, w2s: "top:0.3", s2w: "id" };
    let steps = 10;
    let cut = 6;
    let spec = scenario_spec(&sc, 1, RoundMode::Sync, TransportMode::Counted, steps, FLAT);
    let dir = std::env::temp_dir().join(format!("efmuon-scenario-resume-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let stem = dir.join(CHECKPOINT_STEM);

    // first life: run to the cut, checkpoint, and drop the driver
    let q = objective(&sc);
    let x0 = q.init(&mut Rng::new(SEED));
    let svc = GradService::spawn_objective(Box::new(q), SEED);
    let mut drv = spawn_driver(&spec, x0, geom(), svc.handle()).unwrap();
    for _ in 0..cut {
        drv.round().unwrap();
    }
    drv.drain().unwrap();
    let params = drv.params().unwrap();
    let meta = checkpoint::CheckpointMeta {
        step: cut,
        eval_loss: drv.eval().unwrap() as f64,
        comp: spec.worker_comp.spec(),
        seed: spec.seed,
        shapes: params.iter().map(|p| (p.rows, p.cols)).collect(),
    };
    checkpoint::save(&stem, &params, &meta).unwrap();
    drop(drv);

    // second life: load, spawn at the stored step, run the remainder
    let (restored, loaded) = checkpoint::load(&stem).unwrap();
    assert_eq!(loaded.step, cut, "checkpoint must store the resume step");
    let q2 = objective(&sc);
    let svc2 = GradService::spawn_objective(Box::new(q2), SEED);
    let mut resumed = spawn_driver_at(&spec, restored, geom(), svc2.handle(), loaded.step).unwrap();
    let mut absorbed = Vec::new();
    for _ in loaded.step..steps {
        if let Some(k) = resumed.round().unwrap().absorbed_step {
            absorbed.push(k);
        }
    }
    for s in resumed.drain().unwrap() {
        if let Some(k) = s.absorbed_step {
            absorbed.push(k);
        }
    }
    assert_eq!(
        absorbed,
        (cut..steps).collect::<Vec<_>>(),
        "the resumed run must cover exactly steps {cut}..{steps}"
    );
    let eval = resumed.eval().unwrap();
    assert!(eval.is_finite(), "resumed eval loss must be finite, got {eval}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// The socket axis (ISSUE 9): loopback TCP ≡ channel, flaky links, elastic
// membership
// ---------------------------------------------------------------------------

/// Run one scenario over loopback TCP: bind a hub on a kernel-assigned
/// port, dial `sc.workers` in-process socket workers at it, and drive the
/// run through `Coordinator::spawn_net`. Returns the usual trace plus the
/// transport counters `(reconnects, heartbeat_misses)`.
fn run_scenario_net(
    sc: &Scenario,
    mode: RoundMode,
    transport: TransportMode,
    rounds: usize,
) -> (RunTrace, (u64, u64)) {
    let spec = scenario_spec(sc, 1, mode, transport, rounds, FLAT);
    let q = objective(sc);
    let x0 = q.init(&mut Rng::new(SEED));
    let svc = GradService::spawn_objective(Box::new(q), SEED);
    let handle = svc.handle();
    let hub = NetHub::bind(NetCfg::default()).unwrap();
    let workers = spawn_loopback_workers(sc.workers, hub.local_addr(), &handle, None);
    let mut coord =
        Coordinator::spawn_net(x0, geom(), handle, spec.coordinator_cfg(), hub).unwrap();
    let stats = coord.run(rounds).unwrap();
    let mut s2w = Vec::new();
    let mut w2s = Vec::new();
    for s in &stats {
        if s.s2w_bytes > 0 {
            s2w.push(s.s2w_bytes);
        }
        if s.absorbed_step.is_some() {
            w2s.push(s.w2s_bytes_per_worker);
        }
    }
    let m = coord.meter();
    let net = (m.reconnects(), m.heartbeat_misses());
    let trace = RunTrace {
        params: flatten(coord.params()),
        s2w,
        w2s,
        meter_w2s: m.w2s(),
        meter_s2w: m.s2w(),
        eval: coord.eval().unwrap(),
    };
    // dropping the coordinator stops every link and closes the hub; the
    // dialed workers then end their sessions cleanly
    drop(coord);
    for w in workers {
        w.join().expect("worker thread").expect("worker loop");
    }
    (trace, net)
}

/// (i) Golden anchor: a loopback TCP deployment must be bit-identical to
/// the in-memory channel run — trajectory, per-round wire bytes in both
/// directions, cumulative meters, and eval — for every scenario, both
/// round modes, and both transport modes, with zero reconnects and zero
/// heartbeat misses on a healthy link. The socket hop adds framing and
/// scheduling, never arithmetic: the compute loop behind the link is the
/// unchanged channel-transport worker.
#[test]
fn net_loopback_matches_channel_bitwise() {
    for sc in SCENARIOS {
        for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
            for transport in [TransportMode::Counted, TransportMode::Encoded] {
                let chan = run_scenario(sc, mode, transport, ROUNDS);
                let (net, (reconnects, misses)) = run_scenario_net(sc, mode, transport, ROUNDS);
                let tag = format!("{} / {} / {:?}", sc.name, mode.spec(), transport);
                assert_eq!(chan.params, net.params, "{tag}: trajectory");
                assert_eq!(chan.s2w, net.s2w, "{tag}: s2w bytes per round");
                assert_eq!(chan.w2s, net.w2s, "{tag}: w2s bytes per round");
                assert_eq!(chan.meter_w2s, net.meter_w2s, "{tag}: w2s meter");
                assert_eq!(chan.meter_s2w, net.meter_s2w, "{tag}: s2w meter");
                assert_eq!(chan.eval, net.eval, "{tag}: eval");
                assert_eq!(
                    (reconnects, misses),
                    (0, 0),
                    "{tag}: healthy-link transport counters must stay zero"
                );
            }
        }
    }
}

/// (i) Chaos acceptance: 4 workers over a flaky loopback link. The leader
/// drops worker 1's broadcast frame at step 3 (severing that link — a
/// socket EF21-P worker that missed a broadcast can only rejoin by
/// re-initializing against the current shift), a seeded plan panics the
/// compute of whoever holds slot 2 at step 6 (killing that worker thread
/// for good), and a 5th late-joining worker dials the full deployment,
/// collecting rejects with backoff until a slot frees mid-run. Under a
/// deadline/quorum policy with a respawn budget the run completes: finite
/// eval, every round broadcast and absorbed, exactly 2 respawns /
/// 2 reconnects / 2 partial rounds, zero stragglers (both failures arrive
/// as failure notifications, not deadline misses), zero heartbeat misses
/// — and the late joiner ends the run holding a slot (it returns only on
/// a clean `Stop`), elastic membership absorbing both the departure and
/// the join.
#[test]
fn net_chaos_flaky_link_panic_and_late_joiner_exact_counts() {
    let sc = Scenario { name: "net-chaos", workers: 4, dim: 12, w2s: "top:0.3", s2w: "top:0.5" };
    let rounds = 10;
    let spec = scenario_spec(&sc, 1, RoundMode::Sync, TransportMode::Counted, rounds, FLAT);
    let mut cfg = spec.coordinator_cfg();
    cfg.fault = FaultPolicy::parse("deadline:200,quorum:0.5,respawns:2,backoff:0").unwrap();
    let q = objective(&sc);
    let x0 = q.init(&mut Rng::new(SEED));
    let svc = GradService::spawn_objective(Box::new(q), SEED);
    let handle = svc.handle();
    let flaky = FlakyPlan::new().with(1, 3, FlakyKind::DropFrame);
    let hub = NetHub::bind(NetCfg { flaky: Some(Arc::new(flaky)), ..NetCfg::default() }).unwrap();
    let addr = hub.local_addr();
    let plan = Arc::new(FaultPlan::new().with(2, 6, FaultKind::Panic));
    let crew = spawn_loopback_workers(4, addr, &handle, Some(plan.clone()));
    let mut coord = Coordinator::spawn_net(x0, geom(), handle.clone(), cfg, hub).unwrap();
    // spawned only after `spawn_net` returned, i.e. after all 4 initial
    // slots were claimed by the crew: the late joiner can never hold the
    // doomed slot 2 before the step-6 panic frees it
    let late = spawn_loopback_workers(1, addr, &handle, Some(plan));

    let stats = coord.run(rounds).unwrap();
    let mut s2w = 0usize;
    let mut w2s = 0usize;
    for s in &stats {
        if s.s2w_bytes > 0 {
            s2w += 1;
        }
        if s.absorbed_step.is_some() {
            w2s += 1;
        }
    }
    let m = coord.meter();
    assert_eq!(m.stragglers(), 0, "failure notifications, never deadline misses");
    assert_eq!(m.respawns(), 2, "the severed link and the panicked worker each respawn");
    assert_eq!(m.partial_rounds(), 2, "the drop round and the panic round absorb partially");
    assert_eq!(m.reconnects(), 2, "each freed slot is reclaimed exactly once");
    assert_eq!(m.heartbeat_misses(), 0, "heartbeats flow well inside the leader's read timeout");
    assert_eq!(s2w, rounds, "every round broadcast");
    assert_eq!(w2s, rounds, "every round absorbed");
    let eval = coord.eval().unwrap();
    assert!(eval.is_finite(), "eval loss must stay finite, got {eval}");
    assert!(coord.params().iter().all(|p| p.data.iter().all(|v| v.is_finite())));
    drop(coord);

    // exactly one crew thread died in the injected panic; the other three
    // ended on a clean Stop
    let crew_errs = crew
        .into_iter()
        .map(|w| w.join().expect("crew thread joins"))
        .filter(|r| r.is_err())
        .count();
    assert_eq!(crew_errs, 1, "exactly the panicked worker's loop errors out");
    // the late joiner claimed a freed id slot mid-run and held it to the
    // end — worker_loop returns Ok only after receiving Stop on a session
    for w in late {
        w.join().expect("late joiner thread").expect("late joiner held a slot to the Stop");
    }
}

// ---------------------------------------------------------------------------
// The sched axis (ISSUE 10): bounded-epoch windows + work stealing
// ---------------------------------------------------------------------------

/// Scheduler observables of one windowed cluster run.
struct SchedProbe {
    steals: u64,
    epochs_ahead_max: u64,
    partition_version: u64,
    partition: Vec<Vec<usize>>,
}

/// Run a [`Cluster`] under a scheduler spec (and an optional injected
/// per-shard delay plan — a harness hook on `ClusterCfg`, never part of a
/// spec, exactly like `FaultPlan`). Collects the usual trace plus the
/// scheduler counters.
#[allow(clippy::too_many_arguments)]
fn run_cluster_sched(
    obj: Box<dyn Objective>,
    workers: usize,
    n_layers: usize,
    w2s: &'static str,
    s2w: &'static str,
    shards: usize,
    mode: RoundMode,
    rounds: usize,
    sched: &str,
    delay: Option<ShardDelayPlan>,
) -> (RunTrace, SchedProbe) {
    let x0 = obj.init(&mut Rng::new(SEED));
    let svc = GradService::spawn_objective(obj, SEED);
    let sc = Scenario { name: "cluster-sched", workers, dim: 0, w2s, s2w };
    let mut spec = scenario_spec(&sc, shards, mode, TransportMode::Counted, rounds, FLAT);
    spec.sched = SchedSpec::parse(sched).unwrap();
    let mut cfg = spec.cluster_cfg();
    cfg.shard_delay = delay.map(Arc::new);
    let mut cluster = Cluster::spawn(
        x0,
        vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; n_layers],
        svc.handle(),
        cfg,
    )
    .unwrap();
    let stats = cluster.run(rounds).unwrap();
    let mut s2wv = Vec::new();
    let mut w2sv = Vec::new();
    // placeholders (absorbed_step None, zero bytes) filter out, so the
    // completed-rollup stream is directly comparable to lock-step's
    for s in &stats {
        if s.s2w_bytes > 0 {
            s2wv.push(s.s2w_bytes);
        }
        if s.absorbed_step.is_some() {
            w2sv.push(s.w2s_bytes_per_worker);
        }
    }
    let meter = cluster.meter();
    assert!(totals_consistent(&meter), "cluster meter rollup inconsistent");
    let probe = SchedProbe {
        steals: meter.steals,
        epochs_ahead_max: meter.epochs_ahead_max,
        partition_version: cluster.partition_version(),
        partition: cluster.partition().to_vec(),
    };
    let trace = RunTrace {
        params: flatten(&cluster.params().unwrap()),
        s2w: s2wv,
        w2s: w2sv,
        meter_w2s: meter.w2s(),
        meter_s2w: meter.s2w(),
        eval: cluster.eval().unwrap(),
    };
    (trace, probe)
}

/// (j) Golden anchor: with stealing disabled, every bounded-epoch window —
/// including `window:0` driven through the windowed machinery by an inert
/// steal threshold — must be bit-identical to the lock-step cluster:
/// trajectory, completed-rollup byte streams in both directions, meters,
/// eval. Across shard counts and round modes, with zero steals, an intact
/// version-0 partition, and `epochs_ahead_max` within the window.
#[test]
fn sched_windowed_no_steal_matches_lockstep_bitwise() {
    let workers = 2;
    let mk = || -> Box<dyn Objective> {
        Box::new(
            Stacked::new(vec![
                Box::new(Quadratics::new(workers, 8, 0.5, 0.0, &mut Rng::new(2300)))
                    as Box<dyn Objective>,
                Box::new(Quadratics::new(workers, 6, 0.5, 0.0, &mut Rng::new(2301))),
                Box::new(Quadratics::new(workers, 4, 0.5, 0.0, &mut Rng::new(2302))),
            ])
            .unwrap(),
        )
    };
    // window:0 with a threshold no spread reaches exercises the windowed
    // drive at its lock-step bound; window:1/2 let shards run ahead
    const SCHEDS: &[(&str, u64)] =
        &[("window:0,steal:1000000", 0), ("window:1", 1), ("window:2", 2)];
    for mode in [RoundMode::Sync, RoundMode::Async { lookahead: 1 }] {
        for shards in [2usize, 3] {
            let (reference, _) = run_cluster_sched(
                mk(), workers, 3, "top:0.3", "top:0.5", shards, mode, ROUNDS, "off", None,
            );
            for &(sched, window) in SCHEDS {
                let (t, probe) = run_cluster_sched(
                    mk(), workers, 3, "top:0.3", "top:0.5", shards, mode, ROUNDS, sched, None,
                );
                let tag = format!("{shards} shards / {} / {sched}", mode.spec());
                assert_eq!(reference.params, t.params, "{tag}: trajectory");
                assert_eq!(reference.s2w, t.s2w, "{tag}: s2w bytes per round");
                assert_eq!(reference.w2s, t.w2s, "{tag}: w2s bytes per round");
                assert_eq!(reference.meter_w2s, t.meter_w2s, "{tag}: w2s meter");
                assert_eq!(reference.meter_s2w, t.meter_s2w, "{tag}: s2w meter");
                assert_eq!(reference.eval, t.eval, "{tag}: eval");
                assert_eq!(probe.steals, 0, "{tag}: no steal without imbalance");
                assert_eq!(probe.partition_version, 0, "{tag}: partition untouched");
                assert!(
                    probe.epochs_ahead_max <= window,
                    "{tag}: ahead {} must stay within the window {window}",
                    probe.epochs_ahead_max
                );
            }
        }
    }
}

/// (j) Acceptance: 8 equal layers over 4 shards with shard 0 persistently
/// delayed. Under `window:1,steal:3` the EWMA spread crosses the threshold
/// once the bank is warm, the scheduler migrates exactly one layer off the
/// slow shard (its lightest, layer 0), and never steals again: the donor
/// is at the one-layer floor and stays slowest, so no other shard can be
/// picked. The run stays bitwise on the undelayed lock-step trajectory —
/// params, integer byte streams, meters, eval — because migration ships
/// the server shift and every worker's EF21 error state verbatim, and the
/// pinned deterministic shape (Euclidean LMO, `id` compressors, noise-0
/// quadratics, sync rounds) consumes no RNG a migration could reorder.
#[test]
fn sched_imbalance_steals_exactly_one_layer_bitwise() {
    let workers = 2;
    let rounds = 12;
    let mk = || -> Box<dyn Objective> {
        Box::new(
            Stacked::new(
                (0..8u64)
                    .map(|i| {
                        Box::new(Quadratics::new(workers, 6, 0.5, 0.0, &mut Rng::new(2400 + i)))
                            as Box<dyn Objective>
                    })
                    .collect(),
            )
            .unwrap(),
        )
    };
    let (reference, _) = run_cluster_sched(
        mk(), workers, 8, "id", "id", 4, RoundMode::Sync, rounds, "off", None,
    );
    let (t, probe) = run_cluster_sched(
        mk(),
        workers,
        8,
        "id",
        "id",
        4,
        RoundMode::Sync,
        rounds,
        "window:1,steal:3",
        Some(ShardDelayPlan::constant(0, rounds, 25)),
    );
    assert_eq!(probe.steals, 1, "exactly one steal");
    assert_eq!(probe.partition_version, 1, "one migration bumps the plan version once");
    assert!(probe.epochs_ahead_max <= 1, "ahead stays within the window");
    // 8 equal layers x 4 shards partition as {s, s+4}; the slow shard 0
    // donates its lightest-by-id layer 0 and keeps layer 4 (the floor)
    assert_eq!(probe.partition[0], vec![4], "the donor keeps exactly its floor layer");
    let thief = probe
        .partition
        .iter()
        .position(|lys| lys.contains(&0))
        .expect("some shard adopted layer 0");
    assert_ne!(thief, 0, "the stolen layer moved off the slow shard");
    assert_eq!(probe.partition[thief].len(), 3, "the thief grew by one layer");
    let mut owned: Vec<usize> = probe.partition.iter().flatten().copied().collect();
    owned.sort_unstable();
    assert_eq!(owned, (0..8).collect::<Vec<_>>(), "every layer owned exactly once");
    // bitwise trajectory preservation across the migration (per-round loss
    // scalars regroup across shards, so the comparison is params + the
    // integer byte streams + meters + eval — all partition-invariant)
    assert_eq!(reference.params, t.params, "stolen-layer trajectory preserved bitwise");
    assert_eq!(reference.s2w, t.s2w, "s2w bytes per round");
    assert_eq!(reference.w2s, t.w2s, "w2s bytes per round");
    assert_eq!(reference.meter_w2s, t.meter_w2s, "w2s meter");
    assert_eq!(reference.meter_s2w, t.meter_s2w, "s2w meter");
    assert_eq!(reference.eval, t.eval, "eval");
    assert!(t.eval.is_finite(), "eval loss must stay finite, got {}", t.eval);
}
