//! Wire codec: serialize [`Message`]s to bytes and back. The byte meters in
//! `dist::comm` use [`Message::wire_bytes`]; this module guarantees that
//! number is *real* — `encode` produces exactly `wire_bytes()` bytes and
//! `decode(encode(m)) == m` for every payload kind (tested below and in
//! `rust/tests/compressors.rs`).
//!
//! Layout (little endian):
//!   [0]      payload tag (0=Zero, 1=Dense, 2=Sparse, 3=LowRank) | nat<<7
//!   [1..4]   rows (u24)
//!   [4..7]   cols (u24)
//!   [7..9]   aux: rank (LowRank) — count fields otherwise derived
//!   body     payload-specific
//!
//! Sparse bodies carry a u32 count prefix? No — the count is derived from
//! the remaining length, keeping the header fixed at 9 bytes so byte
//! accounting is trivially auditable.

use super::natural::{nat_code, nat_decode};
use super::{Message, Payload, HEADER_BYTES};
use crate::linalg::matrix::Matrix;

const TAG_ZERO: u8 = 0;
const TAG_DENSE: u8 = 1;
const TAG_SPARSE: u8 = 2;
const TAG_LOWRANK: u8 = 3;
const TAG_SIGN: u8 = 4;
const TAG_QUANT: u8 = 5;
const TAG_BF16: u8 = 6;
const NAT_FLAG: u8 = 0x80;

/// Generic little-endian bit packer for fixed-width codes.
fn pack_bits(codes: &[u16], width: usize, out: &mut Vec<u8>) {
    let mut acc: u32 = 0;
    let mut nbits = 0;
    for &c in codes {
        acc |= (c as u32) << nbits;
        nbits += width;
        while nbits >= 8 {
            out.push((acc & 0xff) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xff) as u8);
    }
}

fn unpack_bits(bytes: &[u8], width: usize, count: usize) -> Vec<u16> {
    let mut codes = Vec::with_capacity(count);
    let mut acc: u32 = 0;
    let mut nbits = 0;
    let mut pos = 0;
    let mask = (1u32 << width) - 1;
    for _ in 0..count {
        while nbits < width {
            acc |= (bytes[pos] as u32) << nbits;
            pos += 1;
            nbits += 8;
        }
        codes.push((acc & mask) as u16);
        acc >>= width;
        nbits -= width;
    }
    codes
}

fn push_u24(out: &mut Vec<u8>, v: usize) {
    assert!(v < (1 << 24), "dimension too large for u24 header");
    out.extend_from_slice(&[(v & 0xff) as u8, ((v >> 8) & 0xff) as u8, ((v >> 16) & 0xff) as u8]);
}

fn read_u24(b: &[u8]) -> usize {
    b[0] as usize | (b[1] as usize) << 8 | (b[2] as usize) << 16
}

/// Pack 9-bit natural codes.
fn pack_nat(vals: &[f32], out: &mut Vec<u8>) {
    let mut acc: u32 = 0;
    let mut nbits = 0;
    for &v in vals {
        acc |= (nat_code(v) as u32) << nbits;
        nbits += 9;
        while nbits >= 8 {
            out.push((acc & 0xff) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xff) as u8);
    }
}

fn unpack_nat(bytes: &[u8], count: usize) -> Vec<f32> {
    let mut vals = Vec::with_capacity(count);
    let mut acc: u32 = 0;
    let mut nbits = 0;
    let mut pos = 0;
    for _ in 0..count {
        while nbits < 9 {
            acc |= (bytes[pos] as u32) << nbits;
            pos += 1;
            nbits += 8;
        }
        vals.push(nat_decode((acc & 0x1ff) as u16));
        acc >>= 9;
        nbits -= 9;
    }
    vals
}

fn push_f32s(vals: &[f32], out: &mut Vec<u8>) {
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn read_f32s(bytes: &[u8], count: usize) -> Vec<f32> {
    (0..count)
        .map(|i| f32::from_le_bytes(bytes[4 * i..4 * i + 4].try_into().unwrap()))
        .collect()
}

fn push_vals(vals: &[f32], nat: bool, out: &mut Vec<u8>) {
    if nat {
        pack_nat(vals, out);
    } else {
        push_f32s(vals, out);
    }
}

fn val_bytes(count: usize, nat: bool) -> usize {
    if nat {
        (count * super::NAT_BITS + 7) / 8
    } else {
        count * 4
    }
}

/// Serialize a message. Produces exactly `msg.wire_bytes()` bytes.
pub fn encode(msg: &Message) -> Vec<u8> {
    let (rows, cols) = msg.shape();
    let mut out = Vec::with_capacity(msg.wire_bytes());
    let (tag, nat, aux) = match &msg.payload {
        Payload::Zero { .. } => (TAG_ZERO, false, 0usize),
        Payload::Dense { nat, .. } => (TAG_DENSE, *nat, 0),
        Payload::Sparse { nat, .. } => (TAG_SPARSE, *nat, 0),
        Payload::LowRank { q, nat, .. } => (TAG_LOWRANK, *nat, q.cols),
        Payload::Sign { .. } => (TAG_SIGN, false, 0),
        Payload::Quant { levels, .. } => (TAG_QUANT, false, *levels as usize),
        Payload::Bf16 { .. } => (TAG_BF16, false, 0),
    };
    out.push(tag | if nat { NAT_FLAG } else { 0 });
    push_u24(&mut out, rows);
    push_u24(&mut out, cols);
    out.extend_from_slice(&(aux as u16).to_le_bytes());
    match &msg.payload {
        Payload::Zero { .. } => {}
        Payload::Dense { m, nat } => push_vals(&m.data, *nat, &mut out),
        Payload::Sparse { rows, cols, idx, vals, nat } => {
            let iw = Message::index_width(rows * cols);
            for &i in idx {
                if iw == 2 {
                    out.extend_from_slice(&(i as u16).to_le_bytes());
                } else {
                    out.extend_from_slice(&i.to_le_bytes());
                }
            }
            push_vals(vals, *nat, &mut out);
        }
        Payload::LowRank { q, b, nat } => {
            push_vals(&q.data, *nat, &mut out);
            push_vals(&b.data, *nat, &mut out);
        }
        Payload::Sign { scale, bits, .. } => {
            out.extend_from_slice(&scale.to_le_bytes());
            out.extend_from_slice(bits);
        }
        Payload::Quant { scale, levels, codes, .. } => {
            out.extend_from_slice(&scale.to_le_bytes());
            pack_bits(codes, crate::compress::quantize::code_bits(*levels), &mut out);
        }
        Payload::Bf16 { codes, .. } => {
            for &c in codes {
                out.extend_from_slice(&c.to_le_bytes());
            }
        }
    }
    debug_assert_eq!(out.len(), msg.wire_bytes(), "codec size mismatch");
    out
}

/// Deserialize. Inverse of [`encode`].
pub fn decode(bytes: &[u8]) -> Result<Message, String> {
    if bytes.len() < HEADER_BYTES {
        return Err("message shorter than header".into());
    }
    let tag = bytes[0] & !NAT_FLAG;
    let nat = bytes[0] & NAT_FLAG != 0;
    let rows = read_u24(&bytes[1..4]);
    let cols = read_u24(&bytes[4..7]);
    let aux = u16::from_le_bytes(bytes[7..9].try_into().unwrap()) as usize;
    let body = &bytes[HEADER_BYTES..];
    // all paths validate body length before slicing, so corrupt/truncated
    // input yields Err, never a panic (fuzzed in rust/tests/compressors.rs)
    let need = |n: usize| -> Result<(), String> {
        if body.len() == n {
            Ok(())
        } else {
            Err(format!("body is {} bytes, expected {n}", body.len()))
        }
    };
    let payload = match tag {
        TAG_ZERO => {
            need(0)?;
            Payload::Zero { rows, cols }
        }
        TAG_DENSE => {
            let count = rows * cols;
            need(val_bytes(count, nat))?;
            let vals = if nat {
                unpack_nat(body, count)
            } else {
                read_f32s(body, count)
            };
            Payload::Dense { m: Matrix::from_vec(rows, cols, vals), nat }
        }
        TAG_SPARSE => {
            let iw = Message::index_width(rows * cols);
            // count derived from total length: len = k*iw + val_bytes(k)
            let k = derive_sparse_count(body.len(), iw, nat)?;
            if k > rows * cols {
                return Err("sparse count exceeds matrix size".into());
            }
            let mut idx = Vec::with_capacity(k);
            for i in 0..k {
                let v = if iw == 2 {
                    u16::from_le_bytes(body[2 * i..2 * i + 2].try_into().unwrap()) as u32
                } else {
                    u32::from_le_bytes(body[4 * i..4 * i + 4].try_into().unwrap())
                };
                if v as usize >= rows * cols {
                    return Err(format!("sparse index {v} out of range"));
                }
                idx.push(v);
            }
            let vb = &body[k * iw..];
            let vals = if nat { unpack_nat(vb, k) } else { read_f32s(vb, k) };
            Payload::Sparse { rows, cols, idx, vals, nat }
        }
        TAG_LOWRANK => {
            let r = aux;
            if r == 0 || r > rows.min(cols).max(1) {
                return Err(format!("implausible rank {r} for {rows}x{cols}"));
            }
            let qn = rows * r;
            need(val_bytes(qn, nat) + val_bytes(r * cols, nat))?;
            let qb = val_bytes(qn, nat);
            let q_vals = if nat {
                unpack_nat(&body[..qb], qn)
            } else {
                read_f32s(&body[..qb], qn)
            };
            let bn = r * cols;
            let b_vals = if nat {
                unpack_nat(&body[qb..], bn)
            } else {
                read_f32s(&body[qb..], bn)
            };
            Payload::LowRank {
                q: Matrix::from_vec(rows, r, q_vals),
                b: Matrix::from_vec(r, cols, b_vals),
                nat,
            }
        }
        TAG_SIGN => {
            let d = rows * cols;
            need(4 + (d + 7) / 8)?;
            let scale = f32::from_le_bytes(body[..4].try_into().unwrap());
            Payload::Sign { rows, cols, scale, bits: body[4..].to_vec() }
        }
        TAG_QUANT => {
            let levels = aux as u8;
            if levels == 0 {
                return Err("quant levels must be >= 1".into());
            }
            let d = rows * cols;
            let width = crate::compress::quantize::code_bits(levels);
            need(4 + (d * width + 7) / 8)?;
            let scale = f32::from_le_bytes(body[..4].try_into().unwrap());
            let codes = unpack_bits(&body[4..], width, d);
            if codes.iter().any(|&c| c > 2 * levels as u16) {
                return Err("quant code out of range".into());
            }
            Payload::Quant { rows, cols, scale, levels, codes }
        }
        TAG_BF16 => {
            let d = rows * cols;
            need(2 * d)?;
            let codes = body
                .chunks_exact(2)
                .map(|b| u16::from_le_bytes(b.try_into().unwrap()))
                .collect();
            Payload::Bf16 { rows, cols, codes }
        }
        t => return Err(format!("unknown payload tag {t}")),
    };
    Ok(Message { payload })
}

fn derive_sparse_count(body_len: usize, iw: usize, nat: bool) -> Result<usize, String> {
    if nat {
        // len = k*iw + ceil(9k/8); solve by scanning (k is at most len/iw)
        for k in (0..=body_len / iw).rev() {
            if k * iw + (k * super::NAT_BITS + 7) / 8 == body_len {
                return Ok(k);
            }
        }
        Err("corrupt sparse+nat body length".into())
    } else {
        if body_len % (iw + 4) != 0 {
            return Err("corrupt sparse body length".into());
        }
        Ok(body_len / (iw + 4))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::parse_spec;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_payloads() {
        let mut rng = Rng::new(101);
        let x = Matrix::randn(17, 23, 1.0, &mut rng);
        for spec in ["id", "nat", "top:0.2", "top:0.2+nat", "rank:0.3",
                     "rank:0.3+nat", "drop:0.5", "svdtop:2", "coltop:0.3",
                     "sign", "qsgd:3", "qsgd:127", "randk:0.2", "bf16"] {
            let mut c = parse_spec(spec).unwrap();
            let msg = c.compress(&x, &mut rng);
            let bytes = encode(&msg);
            assert_eq!(bytes.len(), msg.wire_bytes(), "{spec}: size");
            let back = decode(&bytes).unwrap();
            assert_eq!(back, msg, "{spec}: roundtrip");
        }
    }

    #[test]
    fn nat_packing_roundtrip() {
        let vals: Vec<f32> = vec![0.0, 1.0, -2.0, 0.5, -0.25, 4.0, 8.0];
        let mut out = Vec::new();
        pack_nat(&vals, &mut out);
        assert_eq!(out.len(), (vals.len() * 9 + 7) / 8);
        assert_eq!(unpack_nat(&out, vals.len()), vals);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[9, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
    }
}
