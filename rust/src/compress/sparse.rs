//! Sparsifying compressors: TopK (the canonical Euclidean contractive
//! compressor, Remark 2), optionally composed with Natural compression of
//! the surviving entries (one of the paper's winning combinations), and the
//! column-wise Top_pK compressor (Definition 13).

use super::natural::nat_round;
use super::{Compressor, Message, NormFamily, Payload};
use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// TopK: keep the K = ⌈frac·mn⌉ largest-magnitude entries.
/// Contractive w.r.t. ‖·‖₂ with α = K/(mn).
pub struct TopK {
    pub frac: f64,
    pub nat: bool,
    // scratch index buffer reused across calls (hot-path allocation free)
    scratch: Vec<u32>,
}

impl TopK {
    pub fn new(frac: f64, nat: bool) -> Self {
        assert!(frac > 0.0 && frac <= 1.0);
        TopK { frac, nat, scratch: Vec::new() }
    }

    pub fn k_for(&self, numel: usize) -> usize {
        ((self.frac * numel as f64).ceil() as usize).clamp(1, numel)
    }
}

impl Compressor for TopK {
    fn compress(&mut self, x: &Matrix, rng: &mut Rng) -> Message {
        let numel = x.numel();
        let k = self.k_for(numel);
        self.scratch.clear();
        self.scratch.extend(0..numel as u32);
        let data = &x.data;
        if k < numel {
            // O(n) selection of the k largest by |value|
            self.scratch.select_nth_unstable_by(k - 1, |&a, &b| {
                data[b as usize]
                    .abs()
                    .partial_cmp(&data[a as usize].abs())
                    .unwrap()
            });
        }
        let mut idx: Vec<u32> = self.scratch[..k].to_vec();
        idx.sort_unstable(); // sorted indices compress better / deterministic
        let mut vals: Vec<f32> = idx.iter().map(|&i| data[i as usize]).collect();
        if self.nat {
            for v in vals.iter_mut() {
                *v = nat_round(*v, rng);
            }
        }
        Message {
            payload: Payload::Sparse {
                rows: x.rows,
                cols: x.cols,
                idx,
                vals,
                nat: self.nat,
            },
        }
    }

    fn name(&self) -> String {
        if self.nat {
            format!("top:{}+nat", self.frac)
        } else {
            format!("top:{}", self.frac)
        }
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

/// RandK (unscaled random sparsification, §A.1): keep K = ⌈frac·mn⌉
/// uniformly random entries. Contractive in expectation with α = K/(mn) in
/// ANY norm whose square is coordinate-separable (ℓ2 in particular); unlike
/// TopK it is oblivious to the input, so it composes with secure
/// aggregation — the classical cheap baseline TopK is compared against.
pub struct RandK {
    pub frac: f64,
    scratch: Vec<u32>,
}

impl RandK {
    pub fn new(frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0);
        RandK { frac, scratch: Vec::new() }
    }
}

impl Compressor for RandK {
    fn compress(&mut self, x: &Matrix, rng: &mut Rng) -> Message {
        let numel = x.numel();
        let k = ((self.frac * numel as f64).ceil() as usize).clamp(1, numel);
        // partial Fisher–Yates: first k entries of a random permutation
        self.scratch.clear();
        self.scratch.extend(0..numel as u32);
        for i in 0..k {
            let j = i + rng.below(numel - i);
            self.scratch.swap(i, j);
        }
        let mut idx: Vec<u32> = self.scratch[..k].to_vec();
        idx.sort_unstable();
        let vals: Vec<f32> = idx.iter().map(|&i| x.data[i as usize]).collect();
        Message {
            payload: Payload::Sparse { rows: x.rows, cols: x.cols, idx, vals, nat: false },
        }
    }

    fn name(&self) -> String {
        format!("randk:{}", self.frac)
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

/// Column-wise Top_pK (Definition 13): keep the ⌈frac·n⌉ columns with the
/// largest ℓ2 norm. Contractive w.r.t. any ℓ_{2,q} mixed norm.
pub struct ColTopK {
    pub frac: f64,
}

impl ColTopK {
    pub fn new(frac: f64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0);
        ColTopK { frac }
    }
}

impl Compressor for ColTopK {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        let kcols = ((self.frac * x.cols as f64).ceil() as usize).clamp(1, x.cols);
        let mut col_norms: Vec<(f64, usize)> = (0..x.cols)
            .map(|j| {
                let n = (0..x.rows)
                    .map(|i| (x.at(i, j) as f64).powi(2))
                    .sum::<f64>();
                (n, j)
            })
            .collect();
        col_norms.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut keep: Vec<usize> = col_norms[..kcols].iter().map(|&(_, j)| j).collect();
        keep.sort_unstable();
        let mut idx = Vec::with_capacity(kcols * x.rows);
        let mut vals = Vec::with_capacity(kcols * x.rows);
        for i in 0..x.rows {
            for &j in &keep {
                idx.push((i * x.cols + j) as u32);
                vals.push(x.at(i, j));
            }
        }
        Message {
            payload: Payload::Sparse {
                rows: x.rows,
                cols: x.cols,
                idx,
                vals,
                nat: false,
            },
        }
    }

    fn name(&self) -> String {
        format!("coltop:{}", self.frac)
    }

    fn family(&self) -> NormFamily {
        NormFamily::Primal // contractive in ℓ_{p,q} mixed norms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::contraction_ratio;

    #[test]
    fn topk_keeps_largest() {
        let x = Matrix::from_vec(2, 3, vec![0.1, -5.0, 2.0, 0.05, 3.0, -0.2]);
        let mut c = TopK::new(0.5, false); // k = 3
        let mut rng = Rng::new(0);
        let y = c.compress(&x, &mut rng).decode();
        assert_eq!(y.data, vec![0.0, -5.0, 2.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn topk_contraction_alpha() {
        // exact TopK bound: ||C(x)-x||^2 <= (1 - k/n) ||x||^2
        let mut rng = Rng::new(81);
        for frac in [0.1, 0.3, 0.9] {
            let x = Matrix::randn(20, 30, 1.0, &mut rng);
            let mut c = TopK::new(frac, false);
            let y = c.compress(&x, &mut rng).decode();
            let k = c.k_for(600) as f64;
            assert!(contraction_ratio(&x, &y) <= 1.0 - k / 600.0 + 1e-9);
        }
    }

    #[test]
    fn topk_ties_keep_exactly_k() {
        // all-equal magnitudes: selection must still return exactly k
        let x = Matrix::from_vec(4, 4, vec![1.0; 16]);
        let mut c = TopK::new(0.25, false);
        let mut rng = Rng::new(82);
        let msg = c.compress(&x, &mut rng);
        if let Payload::Sparse { idx, .. } = &msg.payload {
            assert_eq!(idx.len(), 4);
        } else {
            panic!("expected sparse payload");
        }
    }

    #[test]
    fn topk_nat_quantizes_survivors() {
        let mut rng = Rng::new(83);
        let x = Matrix::randn(10, 10, 1.0, &mut rng);
        let mut c = TopK::new(0.2, true);
        let msg = c.compress(&x, &mut rng);
        if let Payload::Sparse { vals, nat, .. } = &msg.payload {
            assert!(*nat);
            for v in vals {
                if *v != 0.0 {
                    assert_eq!(v.to_bits() & 0x007f_ffff, 0);
                }
            }
        } else {
            panic!("expected sparse payload");
        }
    }

    #[test]
    fn coltop_keeps_whole_columns() {
        let mut rng = Rng::new(84);
        let mut x = Matrix::randn(5, 8, 0.1, &mut rng);
        // boost columns 2 and 6
        for i in 0..5 {
            x.set(i, 2, 10.0);
            x.set(i, 6, -9.0);
        }
        let mut c = ColTopK::new(0.25); // 2 columns
        let y = c.compress(&x, &mut rng).decode();
        for i in 0..5 {
            assert_eq!(y.at(i, 2), 10.0);
            assert_eq!(y.at(i, 6), -9.0);
            assert_eq!(y.at(i, 0), 0.0);
        }
    }
}
