//! Contractive compressors (paper Definition 1 and §D) with exact wire-byte
//! accounting.
//!
//! A compressor maps a residual matrix to a [`Message`]: the decoded value
//! used by the EF21 recursions *plus* the exact number of bytes the message
//! occupies on the wire (`codec` implements the actual serialization; the
//! two are tested to agree). Families follow the paper's notation:
//! 𝔹(α) — contractive w.r.t. the layer norm ‖·‖, 𝔹⋆(α) — w.r.t. the dual
//! norm, 𝔹₂(α) — w.r.t. the Euclidean norm.

pub mod codec;
pub mod natural;
pub mod simple;
pub mod sparse;
pub mod lowrank;
pub mod quantize;

use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// Which norm family the contraction inequality (Def. 1) is guaranteed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormFamily {
    /// 𝔹₂(α): Euclidean — TopK, Natural, RankK, dropout, damping.
    Euclidean,
    /// 𝔹(α) in a layer norm (e.g. TopK-SVD in Schatten norms).
    Primal,
    /// 𝔹⋆(α) in the dual norm.
    Dual,
}

/// Serialized-message payload. `nat == true` means values were Natural-
/// quantized (exact powers of two) and travel at 9 bits each.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Nothing transmitted (decodes to zeros).
    Zero { rows: usize, cols: usize },
    /// Full matrix.
    Dense { m: Matrix, nat: bool },
    /// Sparse entries by flat index.
    Sparse {
        rows: usize,
        cols: usize,
        idx: Vec<u32>,
        vals: Vec<f32>,
        nat: bool,
    },
    /// Low-rank factors `q · b` (q: m×r, b: r×n).
    LowRank { q: Matrix, b: Matrix, nat: bool },
    /// Scaled sign (1-bit SGD / signSGD): `scale · sign(x)`, one bit per
    /// entry on the wire.
    Sign { rows: usize, cols: usize, scale: f32, bits: Vec<u8> },
    /// Uniform `levels`-level quantization: value = scale · (code − levels)
    /// / levels; codes are bit-packed at ⌈log2(2·levels+1)⌉ bits.
    Quant {
        rows: usize,
        cols: usize,
        scale: f32,
        levels: u8,
        codes: Vec<u16>,
    },
    /// bf16 cast: each code is the high 16 bits of the f32 (round-to-
    /// nearest-even); decode widens exactly (low mantissa bits zero).
    /// 2 bytes per entry — the snapshot/broadcast wire format.
    Bf16 { rows: usize, cols: usize, codes: Vec<u16> },
}

/// Bits per Natural-compressed value: 1 sign + 8 exponent.
pub const NAT_BITS: usize = 9;
/// Fixed per-message header: payload tag (1B) + rows/cols (2×3B) + aux (2B).
pub const HEADER_BYTES: usize = 9;

#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub payload: Payload,
}

impl Message {
    pub fn shape(&self) -> (usize, usize) {
        match &self.payload {
            Payload::Zero { rows, cols } => (*rows, *cols),
            Payload::Dense { m, .. } => (m.rows, m.cols),
            Payload::Sparse { rows, cols, .. } => (*rows, *cols),
            Payload::LowRank { q, b, .. } => (q.rows, b.cols),
            Payload::Sign { rows, cols, .. } => (*rows, *cols),
            Payload::Quant { rows, cols, .. } => (*rows, *cols),
            Payload::Bf16 { rows, cols, .. } => (*rows, *cols),
        }
    }

    /// Decode to the dense matrix the receiving EF21 state adds in.
    pub fn decode(&self) -> Matrix {
        match &self.payload {
            Payload::Zero { rows, cols } => Matrix::zeros(*rows, *cols),
            Payload::Dense { m, .. } => m.clone(),
            Payload::Sparse { rows, cols, idx, vals, .. } => {
                let mut out = Matrix::zeros(*rows, *cols);
                for (&i, &v) in idx.iter().zip(vals) {
                    out.data[i as usize] = v;
                }
                out
            }
            Payload::LowRank { q, b, .. } => crate::linalg::matmul::matmul(q, b),
            Payload::Sign { rows, cols, scale, bits } => {
                let mut out = Matrix::zeros(*rows, *cols);
                for (i, v) in out.data.iter_mut().enumerate() {
                    let bit = (bits[i / 8] >> (i % 8)) & 1;
                    *v = if bit == 1 { *scale } else { -*scale };
                }
                out
            }
            Payload::Quant { rows, cols, scale, levels, codes } => {
                let mut out = Matrix::zeros(*rows, *cols);
                let l = *levels as f32;
                for (v, &c) in out.data.iter_mut().zip(codes) {
                    *v = scale * (c as f32 - l) / l;
                }
                out
            }
            Payload::Bf16 { rows, cols, codes } => {
                let mut out = Matrix::zeros(*rows, *cols);
                for (v, &c) in out.data.iter_mut().zip(codes) {
                    *v = quantize::bf16_decode(c);
                }
                out
            }
        }
    }

    /// Add the decoded value into `dst` without materializing it
    /// (hot-path variant of [`Message::decode`]).
    pub fn add_into(&self, dst: &mut Matrix) {
        match &self.payload {
            Payload::Zero { .. } => {}
            Payload::Dense { m, .. } => dst.axpy(1.0, m),
            Payload::Sparse { idx, vals, .. } => {
                for (&i, &v) in idx.iter().zip(vals) {
                    dst.data[i as usize] += v;
                }
            }
            Payload::LowRank { q, b, .. } => {
                let qb = crate::linalg::matmul::matmul(q, b);
                dst.axpy(1.0, &qb);
            }
            Payload::Sign { .. } | Payload::Quant { .. } | Payload::Bf16 { .. } => {
                dst.axpy(1.0, &self.decode());
            }
        }
    }

    /// Number of index bytes per sparse entry for a matrix of `numel`
    /// elements (u16 when addressable, else u32).
    pub fn index_width(numel: usize) -> usize {
        if numel <= u16::MAX as usize + 1 {
            2
        } else {
            4
        }
    }

    /// Exact wire size in bytes (header + payload). `codec::encode` produces
    /// exactly this many bytes — asserted in tests.
    pub fn wire_bytes(&self) -> usize {
        let body = match &self.payload {
            Payload::Zero { .. } => 0,
            Payload::Dense { m, nat } => value_bytes(m.numel(), *nat),
            Payload::Sparse { rows, cols, idx, nat, .. } => {
                let iw = Self::index_width(rows * cols);
                idx.len() * iw + value_bytes(idx.len(), *nat)
            }
            Payload::LowRank { q, b, nat } => {
                value_bytes(q.numel(), *nat) + value_bytes(b.numel(), *nat)
            }
            // 4B scale + 1 bit/entry
            Payload::Sign { rows, cols, .. } => 4 + (rows * cols + 7) / 8,
            // 4B scale + packed codes at ceil(log2(2L+1)) bits
            Payload::Quant { rows, cols, levels, .. } => {
                4 + (rows * cols * quantize::code_bits(*levels) + 7) / 8
            }
            // raw u16 codes — exactly half the f32 bytes
            Payload::Bf16 { rows, cols, .. } => 2 * rows * cols,
        };
        HEADER_BYTES + body
    }
}

fn value_bytes(count: usize, nat: bool) -> usize {
    if nat {
        (count * NAT_BITS + 7) / 8
    } else {
        count * 4
    }
}

/// A (possibly randomized) contractive compression operator C: S → S.
pub trait Compressor: Send {
    /// Compress `x`; the EF21 state uses `msg.decode()`, the byte meter
    /// uses `msg.wire_bytes()`.
    fn compress(&mut self, x: &Matrix, rng: &mut Rng) -> Message;

    /// Human-readable spec (round-trips through [`parse_spec`]).
    fn name(&self) -> String;

    /// Norm family of the contraction guarantee.
    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }

    /// `true` for the identity compressor (lets hot paths skip work).
    fn is_identity(&self) -> bool {
        false
    }
}

/// Measured contraction ratio `‖C(x)−x‖² / ‖x‖²` (Euclidean); Definition 1
/// requires its expectation ≤ 1−α.
pub fn contraction_ratio(x: &Matrix, decoded: &Matrix) -> f64 {
    let num = decoded.sub(x).norm2_sq();
    let den = x.norm2_sq();
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Parse a compressor spec string and build the compressor. Grammar:
///
/// ```text
/// spec    := base ("+nat")?
/// base    := "id" | "nat" | "sign" | "bf16" | "top:F" | "rank:F"
///          | "drop:P" | "damp:G" | "svdtop:K" | "coltop:F"
/// ```
///
/// `F` = fraction (0,1], `P` = keep-probability, `G` = damping factor,
/// `K` = integer rank. Examples: `top:0.15+nat`, `rank:0.1`, `id`.
///
/// The grammar itself lives in [`crate::spec::CompSpec`] — the typed,
/// parse-once descriptor every configuration boundary uses; this function
/// is the one-shot convenience for tests and benches.
pub fn parse_spec(spec: &str) -> Result<Box<dyn Compressor>, String> {
    Ok(crate::spec::CompSpec::parse(spec)?.build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip() {
        for s in ["id", "nat", "top:0.15", "top:0.1+nat", "rank:0.2",
                  "rank:0.05+nat", "drop:0.5", "damp:0.8", "svdtop:3",
                  "coltop:0.25", "sign", "qsgd:4", "randk:0.3", "bf16"] {
            let c = parse_spec(s).unwrap();
            assert_eq!(c.name(), s, "name roundtrip for {s}");
        }
    }

    #[test]
    fn spec_errors() {
        for s in ["", "bogus", "top:0", "top:1.5", "top:x", "drop:", "nat+nat",
                  "qsgd:0", "randk:0", "sign+nat", "bf16+nat", "bf16:2"] {
            assert!(parse_spec(s).is_err(), "{s} should fail");
        }
    }

    #[test]
    fn sparse_decode_and_bytes() {
        let msg = Message {
            payload: Payload::Sparse {
                rows: 2,
                cols: 3,
                idx: vec![0, 4],
                vals: vec![1.5, -2.0],
                nat: false,
            },
        };
        let m = msg.decode();
        assert_eq!(m.at(0, 0), 1.5);
        assert_eq!(m.at(1, 1), -2.0);
        assert_eq!(m.norm2_sq(), 1.5f64 * 1.5 + 4.0);
        // 2 entries * (2B idx + 4B val) + header
        assert_eq!(msg.wire_bytes(), HEADER_BYTES + 2 * (2 + 4));
    }

    #[test]
    fn add_into_matches_decode() {
        let msg = Message {
            payload: Payload::LowRank {
                q: Matrix::from_vec(2, 1, vec![1.0, 2.0]),
                b: Matrix::from_vec(1, 2, vec![3.0, 4.0]),
                nat: false,
            },
        };
        let mut dst = Matrix::zeros(2, 2);
        msg.add_into(&mut dst);
        assert_eq!(dst, msg.decode());
    }
}
