//! Low-rank compressors: RankK (Safaryan et al. 2021 — the paper's best
//! performer when combined with Natural compression) and the TopK-SVD
//! compressor of Definition 10 (contractive in every Schatten norm).

use super::natural::nat_quantize;
use super::{Compressor, Message, NormFamily, Payload};
use crate::linalg::matrix::Matrix;
use crate::linalg::svd::{jacobi_svd, low_rank_approx};
use crate::util::rng::Rng;

/// RankK: transmit rank-r factors `Q (m×r), B (r×n)` with
/// `r = ⌈frac·min(m,n)⌉`, computed by a randomized range finder with two
/// power iterations (paper §D Remark 11 allows approximate SVD). For
/// single-column matrices (LayerNorm gains etc.) the factorization is exact
/// and equivalent to dense — the coordinator routes those to TopK instead.
pub struct RankK {
    pub frac: f64,
    pub nat: bool,
    pub power_iters: usize,
}

impl RankK {
    pub fn new(frac: f64, nat: bool) -> Self {
        assert!(frac > 0.0 && frac <= 1.0);
        RankK { frac, nat, power_iters: 2 }
    }

    pub fn rank_for(&self, rows: usize, cols: usize) -> usize {
        let r = rows.min(cols);
        ((self.frac * r as f64).ceil() as usize).clamp(1, r)
    }
}

impl Compressor for RankK {
    fn compress(&mut self, x: &Matrix, rng: &mut Rng) -> Message {
        let r = self.rank_for(x.rows, x.cols);
        let (q, b) = low_rank_approx(x, r, self.power_iters, rng);
        let (q, b) = if self.nat {
            // Natural compression applied to *all components of the low-rank
            // decomposition*, exactly as in the paper's RankK+Natural combo.
            (nat_quantize(&q, rng), nat_quantize(&b, rng))
        } else {
            (q, b)
        };
        Message { payload: Payload::LowRank { q, b, nat: self.nat } }
    }

    fn name(&self) -> String {
        if self.nat {
            format!("rank:{}+nat", self.frac)
        } else {
            format!("rank:{}", self.frac)
        }
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

/// TopK-SVD (Definition 10): exact truncated SVD keeping the K largest
/// singular triples. Contractive w.r.t. every Schatten-p norm with
/// α = 1 − (Σ_{i>K} σᵢ^p / Σ σᵢ^p)^{2/p}. Exact Jacobi SVD — reserve for
/// small/medium layers; RankK is the scalable sibling.
pub struct SvdTopK {
    pub k: usize,
}

impl SvdTopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        SvdTopK { k }
    }
}

impl Compressor for SvdTopK {
    fn compress(&mut self, x: &Matrix, rng: &mut Rng) -> Message {
        // §Perf: full Jacobi on a 128×512 layer costs seconds. When the
        // matrix is much larger than the target rank, first project onto a
        // randomized range (rank k + oversampling, two power iterations —
        // Halko et al.), then run the exact SVD on the small sketch. This
        // is the approximate-SVD route Remark 11 sanctions; the δ-slack is
        // negligible after two power iterations.
        let small = x.rows.min(x.cols);
        let oversample = 8;
        let (u, s, v) = if small > 3 * (self.k + oversample) {
            let q = crate::linalg::svd::range_finder(x, self.k + oversample, 2, rng);
            let sketch = crate::linalg::matmul::matmul_at(&q, x); // (k+p)×n
            let (us, s, v) = jacobi_svd(&sketch);
            (crate::linalg::matmul::matmul(&q, &us), s, v)
        } else {
            jacobi_svd(x)
        };
        let k = self.k.min(s.len());
        // factors: Q = U_k (m×k), B = diag(s_k)·V_kᵀ (k×n)
        let mut q = Matrix::zeros(x.rows, k);
        for i in 0..x.rows {
            for j in 0..k {
                q.data[i * k + j] = u.at(i, j);
            }
        }
        let mut b = Matrix::zeros(k, x.cols);
        for j in 0..k {
            for c in 0..x.cols {
                b.data[j * x.cols + c] = s[j] * v.at(c, j);
            }
        }
        Message { payload: Payload::LowRank { q, b, nat: false } }
    }

    fn name(&self) -> String {
        format!("svdtop:{}", self.k)
    }

    fn family(&self) -> NormFamily {
        NormFamily::Primal // Schatten-norm contractive (incl. spectral, nuclear)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::contraction_ratio;
    use crate::linalg::matmul::matmul;
    use crate::linalg::norms;

    #[test]
    fn rankk_exact_on_low_rank_input() {
        let mut rng = Rng::new(91);
        let l = Matrix::randn(12, 2, 1.0, &mut rng);
        let r = Matrix::randn(2, 9, 1.0, &mut rng);
        let x = matmul(&l, &r);
        let mut c = RankK::new(2.0 / 9.0, false); // rank 2
        let y = c.compress(&x, &mut rng).decode();
        assert!(y.max_abs_diff(&x) < 1e-3);
    }

    #[test]
    fn rankk_contracts() {
        let mut rng = Rng::new(92);
        let x = Matrix::randn(16, 16, 1.0, &mut rng);
        let mut c = RankK::new(0.25, false);
        let y = c.compress(&x, &mut rng).decode();
        let ratio = contraction_ratio(&x, &y);
        assert!(ratio < 1.0, "ratio={ratio}");
    }

    #[test]
    fn svdtop_matches_best_rank_k() {
        // Eckart–Young: truncated SVD is the best rank-k approximation, so
        // its residual must not exceed the randomized RankK residual.
        let mut rng = Rng::new(93);
        let x = Matrix::randn(10, 8, 1.0, &mut rng);
        let mut svdk = SvdTopK::new(3);
        let mut rk = RankK::new(3.0 / 8.0, false);
        let e_svd = svdk.compress(&x, &mut rng).decode().sub(&x).norm2_sq();
        let e_rand = rk.compress(&x, &mut rng).decode().sub(&x).norm2_sq();
        assert!(e_svd <= e_rand + 1e-6, "{e_svd} vs {e_rand}");
    }

    #[test]
    fn svdtop_spectral_alpha() {
        // spectral-norm residual of rank-k truncation equals sigma_{k+1}
        let mut rng = Rng::new(94);
        let x = Matrix::randn(9, 9, 1.0, &mut rng);
        let (_, s, _) = jacobi_svd(&x);
        let mut c = SvdTopK::new(4);
        let y = c.compress(&x, &mut rng).decode();
        let resid = norms::spectral_exact(&y.sub(&x));
        assert!((resid - s[4] as f64).abs() < 1e-3, "{resid} vs {}", s[4]);
    }
}
