//! The simple compressors from paper §D: identity, deterministic damping
//! (Definition 8) and random dropout (Definition 9). Damping/dropout are
//! contractive for *any* norm — useful theoretical baselines.

use super::{Compressor, Message, NormFamily, Payload};
use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// The identity compressor 𝓘 — disables compression; EF21-Muon then reduces
/// exactly to Gluon (and Muon/Scion under the right norms).
pub struct Identity;

impl Compressor for Identity {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        Message { payload: Payload::Dense { m: x.clone(), nat: false } }
    }

    fn name(&self) -> String {
        "id".into()
    }

    fn is_identity(&self) -> bool {
        true
    }
}

/// Deterministic damping `C(x) = γ·x` (Def. 8): contractive with
/// α = 1−(1−γ)² in every norm, but transmits just as many bytes as the
/// identity — the paper's example of "formally a compressor, practically
/// useless" (it is here for completeness + tests).
pub struct Damping {
    pub gamma: f32,
}

impl Damping {
    pub fn new(gamma: f32) -> Self {
        assert!(gamma > 0.0 && gamma < 2.0, "damping gamma must be in (0,2)");
        Damping { gamma }
    }

    pub fn alpha(&self) -> f64 {
        let g = self.gamma as f64;
        1.0 - (1.0 - g) * (1.0 - g)
    }
}

impl Compressor for Damping {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        Message { payload: Payload::Dense { m: x.scaled(self.gamma), nat: false } }
    }

    fn name(&self) -> String {
        format!("damp:{}", self.gamma)
    }

    fn family(&self) -> NormFamily {
        NormFamily::Primal // contractive in any norm
    }
}

/// Random dropout (Def. 9): transmit the whole matrix with probability `p`,
/// nothing otherwise. Contractive with α = p in every norm; expected cost
/// p·dense.
pub struct RandomDropout {
    pub p: f64,
}

impl RandomDropout {
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "dropout p must be in (0,1]");
        RandomDropout { p }
    }
}

impl Compressor for RandomDropout {
    fn compress(&mut self, x: &Matrix, rng: &mut Rng) -> Message {
        if rng.bernoulli(self.p) {
            Message { payload: Payload::Dense { m: x.clone(), nat: false } }
        } else {
            Message { payload: Payload::Zero { rows: x.rows, cols: x.cols } }
        }
    }

    fn name(&self) -> String {
        format!("drop:{}", self.p)
    }

    fn family(&self) -> NormFamily {
        NormFamily::Primal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::contraction_ratio;

    #[test]
    fn identity_is_exact() {
        let mut rng = Rng::new(71);
        let x = Matrix::randn(5, 5, 1.0, &mut rng);
        let msg = Identity.compress(&x, &mut rng);
        assert_eq!(msg.decode(), x);
        assert_eq!(contraction_ratio(&x, &msg.decode()), 0.0);
    }

    #[test]
    fn damping_contraction_exact() {
        let mut rng = Rng::new(72);
        let x = Matrix::randn(6, 3, 1.0, &mut rng);
        let mut c = Damping::new(0.7);
        let y = c.compress(&x, &mut rng).decode();
        let ratio = contraction_ratio(&x, &y);
        assert!((ratio - (1.0 - c.alpha())).abs() < 1e-6);
    }

    #[test]
    fn dropout_expected_contraction() {
        let mut rng = Rng::new(73);
        let x = Matrix::randn(8, 8, 1.0, &mut rng);
        let mut c = RandomDropout::new(0.3);
        let n = 4000;
        let mean: f64 = (0..n)
            .map(|_| contraction_ratio(&x, &c.compress(&x, &mut rng).decode()))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 0.7).abs() < 0.03, "mean ratio {mean}");
    }

    #[test]
    #[should_panic]
    fn damping_rejects_bad_gamma() {
        Damping::new(2.5);
    }
}
