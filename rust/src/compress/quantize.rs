//! Quantizing compressors from the error-feedback literature the paper
//! builds on (§A.1/§A.2):
//!
//! * **ScaledSign** — 1-bit SGD (Seide et al. 2014) / scaled signSGD
//!   (Karimireddy et al. 2019): `C(x) = (‖x‖₁/d)·sign(x)`. Exactly one bit
//!   per entry on the wire. Contractive with
//!   `α = ‖x‖₁² / (d·‖x‖₂²) ∈ (0, 1]` (tight by Cauchy–Schwarz).
//! * **Qsgd** — uniform L-level symmetric quantization (QSGD family,
//!   Alistarh et al. 2017), *deterministic* rounding so the operator is
//!   contractive (the classical unbiased variant is not): entries are
//!   mapped to `scale·j/L`, `j ∈ {−L..L}`, with `scale = ‖x‖∞`.
//!   ⌈log₂(2L+1)⌉ bits per entry.

use super::{Compressor, Message, NormFamily, Payload};
use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// Bits per code for an L-level symmetric quantizer (codes 0..=2L).
pub fn code_bits(levels: u8) -> usize {
    let states = 2 * levels as usize + 1;
    usize::BITS as usize - (states - 1).leading_zeros() as usize
}

/// Round-to-nearest-even bf16 encode: the high 16 bits of the f32 after
/// the RTNE carry. `inf`/`-0.0` are exact (their low 16 bits are zero);
/// values past bf16 range (e.g. `f32::MAX`) round to `inf` per RTNE. NaN
/// bypasses the carry path — the carry could ripple a NaN's truncated
/// payload into the `inf` bit pattern — and instead keeps its sign and
/// top payload bits with the quiet bit forced on.
pub fn bf16_encode(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// Exact widening decode: bf16 → f32 (the low 16 mantissa bits are zero,
/// so decode(encode(x)) == x for every value representable in bf16).
pub fn bf16_decode(c: u16) -> f32 {
    f32::from_bits((c as u32) << 16)
}

/// bf16 cast compressor: round-to-nearest-even truncation to 16 bits, half
/// the f32 wire bytes. Relative error ≤ 2⁻⁸ per finite entry, so it is
/// contractive in every entrywise-monotone norm. This is the snapshot/
/// broadcast wire format (`ParamBoard` in bf16 mode); as a gradient
/// compressor it is available as spec `bf16`.
pub struct Bf16Cast;

impl Compressor for Bf16Cast {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        let codes = x.data.iter().map(|v| bf16_encode(*v)).collect();
        Message { payload: Payload::Bf16 { rows: x.rows, cols: x.cols, codes } }
    }

    fn name(&self) -> String {
        "bf16".into()
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

/// 1-bit SGD: transmit sign bits + one f32 scale.
pub struct ScaledSign;

impl ScaledSign {
    /// The exact contraction parameter for input `x`.
    pub fn alpha(x: &Matrix) -> f64 {
        let l1: f64 = x.data.iter().map(|v| v.abs() as f64).sum();
        let l2sq = x.norm2_sq();
        if l2sq == 0.0 {
            1.0
        } else {
            (l1 * l1) / (x.numel() as f64 * l2sq)
        }
    }
}

impl Compressor for ScaledSign {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        let d = x.numel();
        let l1: f64 = x.data.iter().map(|v| v.abs() as f64).sum();
        let scale = (l1 / d.max(1) as f64) as f32;
        let mut bits = vec![0u8; (d + 7) / 8];
        for (i, v) in x.data.iter().enumerate() {
            if *v >= 0.0 {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        Message { payload: Payload::Sign { rows: x.rows, cols: x.cols, scale, bits } }
    }

    fn name(&self) -> String {
        "sign".into()
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

/// Deterministic L-level quantizer (contractive QSGD variant).
pub struct Qsgd {
    pub levels: u8,
}

impl Qsgd {
    pub fn new(levels: u8) -> Self {
        assert!(levels >= 1);
        Qsgd { levels }
    }
}

impl Compressor for Qsgd {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        let scale = x.max_abs();
        let l = self.levels as f32;
        let codes: Vec<u16> = if scale == 0.0 {
            vec![self.levels as u16; x.numel()]
        } else {
            x.data
                .iter()
                .map(|v| {
                    // nearest level in {-L..L}, stored shifted to 0..=2L
                    let q = (v / scale * l).round().clamp(-l, l);
                    (q + l) as u16
                })
                .collect()
        };
        Message {
            payload: Payload::Quant {
                rows: x.rows,
                cols: x.cols,
                scale,
                levels: self.levels,
                codes,
            },
        }
    }

    fn name(&self) -> String {
        format!("qsgd:{}", self.levels)
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::contraction_ratio;

    #[test]
    fn code_bits_values() {
        assert_eq!(code_bits(1), 2); // 3 states
        assert_eq!(code_bits(3), 3); // 7 states
        assert_eq!(code_bits(7), 4); // 15 states
        assert_eq!(code_bits(127), 8); // 255 states
    }

    #[test]
    fn bf16_exact_and_special_values() {
        // one rounding is idempotent: decode(encode(x)) is a fixed point
        for v in [0.0f32, 1.0, -2.0, 0.5, -0.09375, 3.5e38, 1e-40] {
            let d = bf16_decode(bf16_encode(v));
            assert_eq!(d.to_bits(), bf16_decode(bf16_encode(d)).to_bits(), "{v}");
        }
        assert_eq!(bf16_decode(bf16_encode(1.0)), 1.0);
        assert_eq!(bf16_encode(f32::INFINITY), 0x7F80);
        assert_eq!(bf16_encode(f32::NEG_INFINITY), 0xFF80);
        assert_eq!(bf16_encode(-0.0), 0x8000);
        assert!(bf16_decode(bf16_encode(-0.0)).is_sign_negative());
        // overflow rounds to inf (RTNE), like hardware bf16 casts
        assert_eq!(bf16_encode(f32::MAX), 0x7F80);
        // NaN stays NaN — the rounding carry must not produce inf
        assert!(bf16_decode(bf16_encode(f32::NAN)).is_nan());
        let payload_nan = f32::from_bits(0x7F80_0001); // all payload in low bits
        assert!(bf16_decode(bf16_encode(payload_nan)).is_nan());
        assert!(bf16_decode(bf16_encode(-f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_relative_error_bound() {
        let mut rng = Rng::new(76);
        let x = Matrix::randn(11, 13, 3.0, &mut rng);
        let y = Bf16Cast.compress(&x, &mut rng).decode();
        for (a, b) in x.data.iter().zip(&y.data) {
            assert!((a - b).abs() <= a.abs() / 256.0, "{a} vs {b}");
        }
        assert!(contraction_ratio(&x, &y) < 1e-4);
    }

    #[test]
    fn bf16_wire_is_half_f32() {
        let mut rng = Rng::new(77);
        let x = Matrix::randn(16, 16, 1.0, &mut rng);
        let msg = Bf16Cast.compress(&x, &mut rng);
        assert_eq!(msg.wire_bytes(), crate::compress::HEADER_BYTES + 2 * 256);
    }

    #[test]
    fn sign_contraction_matches_formula() {
        let mut rng = Rng::new(71);
        for _ in 0..20 {
            let x = Matrix::randn(7, 9, 2.0, &mut rng);
            let y = ScaledSign.compress(&x, &mut rng).decode();
            let ratio = contraction_ratio(&x, &y);
            let alpha = ScaledSign::alpha(&x);
            assert!((ratio - (1.0 - alpha)).abs() < 1e-5, "{ratio} vs {}", 1.0 - alpha);
            assert!(ratio < 1.0);
        }
    }

    #[test]
    fn sign_wire_is_one_bit_per_entry() {
        let mut rng = Rng::new(72);
        let x = Matrix::randn(16, 16, 1.0, &mut rng);
        let msg = ScaledSign.compress(&x, &mut rng);
        assert_eq!(msg.wire_bytes(), crate::compress::HEADER_BYTES + 4 + 256 / 8);
    }

    #[test]
    fn qsgd_error_bounded_by_half_step() {
        let mut rng = Rng::new(73);
        let x = Matrix::randn(10, 10, 1.0, &mut rng);
        let mut c = Qsgd::new(4);
        let y = c.compress(&x, &mut rng).decode();
        let scale = x.max_abs();
        let step = scale / 4.0;
        for (a, b) in x.data.iter().zip(&y.data) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6, "{a} vs {b}");
        }
        // contraction follows from the half-step bound
        assert!(contraction_ratio(&x, &y) < 1.0);
    }

    #[test]
    fn qsgd_zero_matrix() {
        let x = Matrix::zeros(3, 3);
        let mut rng = Rng::new(74);
        let y = Qsgd::new(2).compress(&x, &mut rng).decode();
        assert_eq!(y.data, vec![0.0; 9]);
    }

    #[test]
    fn more_levels_lower_error() {
        let mut rng = Rng::new(75);
        let x = Matrix::randn(12, 12, 1.0, &mut rng);
        let e2 = contraction_ratio(&x, &Qsgd::new(2).compress(&x, &mut rng).decode());
        let e16 = contraction_ratio(&x, &Qsgd::new(16).compress(&x, &mut rng).decode());
        assert!(e16 < e2 * 0.1, "{e16} vs {e2}");
    }
}
