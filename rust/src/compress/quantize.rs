//! Quantizing compressors from the error-feedback literature the paper
//! builds on (§A.1/§A.2):
//!
//! * **ScaledSign** — 1-bit SGD (Seide et al. 2014) / scaled signSGD
//!   (Karimireddy et al. 2019): `C(x) = (‖x‖₁/d)·sign(x)`. Exactly one bit
//!   per entry on the wire. Contractive with
//!   `α = ‖x‖₁² / (d·‖x‖₂²) ∈ (0, 1]` (tight by Cauchy–Schwarz).
//! * **Qsgd** — uniform L-level symmetric quantization (QSGD family,
//!   Alistarh et al. 2017), *deterministic* rounding so the operator is
//!   contractive (the classical unbiased variant is not): entries are
//!   mapped to `scale·j/L`, `j ∈ {−L..L}`, with `scale = ‖x‖∞`.
//!   ⌈log₂(2L+1)⌉ bits per entry.

use super::{Compressor, Message, NormFamily, Payload};
use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// Bits per code for an L-level symmetric quantizer (codes 0..=2L).
pub fn code_bits(levels: u8) -> usize {
    let states = 2 * levels as usize + 1;
    usize::BITS as usize - (states - 1).leading_zeros() as usize
}

/// 1-bit SGD: transmit sign bits + one f32 scale.
pub struct ScaledSign;

impl ScaledSign {
    /// The exact contraction parameter for input `x`.
    pub fn alpha(x: &Matrix) -> f64 {
        let l1: f64 = x.data.iter().map(|v| v.abs() as f64).sum();
        let l2sq = x.norm2_sq();
        if l2sq == 0.0 {
            1.0
        } else {
            (l1 * l1) / (x.numel() as f64 * l2sq)
        }
    }
}

impl Compressor for ScaledSign {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        let d = x.numel();
        let l1: f64 = x.data.iter().map(|v| v.abs() as f64).sum();
        let scale = (l1 / d.max(1) as f64) as f32;
        let mut bits = vec![0u8; (d + 7) / 8];
        for (i, v) in x.data.iter().enumerate() {
            if *v >= 0.0 {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        Message { payload: Payload::Sign { rows: x.rows, cols: x.cols, scale, bits } }
    }

    fn name(&self) -> String {
        "sign".into()
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

/// Deterministic L-level quantizer (contractive QSGD variant).
pub struct Qsgd {
    pub levels: u8,
}

impl Qsgd {
    pub fn new(levels: u8) -> Self {
        assert!(levels >= 1);
        Qsgd { levels }
    }
}

impl Compressor for Qsgd {
    fn compress(&mut self, x: &Matrix, _rng: &mut Rng) -> Message {
        let scale = x.max_abs();
        let l = self.levels as f32;
        let codes: Vec<u16> = if scale == 0.0 {
            vec![self.levels as u16; x.numel()]
        } else {
            x.data
                .iter()
                .map(|v| {
                    // nearest level in {-L..L}, stored shifted to 0..=2L
                    let q = (v / scale * l).round().clamp(-l, l);
                    (q + l) as u16
                })
                .collect()
        };
        Message {
            payload: Payload::Quant {
                rows: x.rows,
                cols: x.cols,
                scale,
                levels: self.levels,
                codes,
            },
        }
    }

    fn name(&self) -> String {
        format!("qsgd:{}", self.levels)
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::contraction_ratio;

    #[test]
    fn code_bits_values() {
        assert_eq!(code_bits(1), 2); // 3 states
        assert_eq!(code_bits(3), 3); // 7 states
        assert_eq!(code_bits(7), 4); // 15 states
        assert_eq!(code_bits(127), 8); // 255 states
    }

    #[test]
    fn sign_contraction_matches_formula() {
        let mut rng = Rng::new(71);
        for _ in 0..20 {
            let x = Matrix::randn(7, 9, 2.0, &mut rng);
            let y = ScaledSign.compress(&x, &mut rng).decode();
            let ratio = contraction_ratio(&x, &y);
            let alpha = ScaledSign::alpha(&x);
            assert!((ratio - (1.0 - alpha)).abs() < 1e-5, "{ratio} vs {}", 1.0 - alpha);
            assert!(ratio < 1.0);
        }
    }

    #[test]
    fn sign_wire_is_one_bit_per_entry() {
        let mut rng = Rng::new(72);
        let x = Matrix::randn(16, 16, 1.0, &mut rng);
        let msg = ScaledSign.compress(&x, &mut rng);
        assert_eq!(msg.wire_bytes(), crate::compress::HEADER_BYTES + 4 + 256 / 8);
    }

    #[test]
    fn qsgd_error_bounded_by_half_step() {
        let mut rng = Rng::new(73);
        let x = Matrix::randn(10, 10, 1.0, &mut rng);
        let mut c = Qsgd::new(4);
        let y = c.compress(&x, &mut rng).decode();
        let scale = x.max_abs();
        let step = scale / 4.0;
        for (a, b) in x.data.iter().zip(&y.data) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6, "{a} vs {b}");
        }
        // contraction follows from the half-step bound
        assert!(contraction_ratio(&x, &y) < 1.0);
    }

    #[test]
    fn qsgd_zero_matrix() {
        let x = Matrix::zeros(3, 3);
        let mut rng = Rng::new(74);
        let y = Qsgd::new(2).compress(&x, &mut rng).decode();
        assert_eq!(y.data, vec![0.0; 9]);
    }

    #[test]
    fn more_levels_lower_error() {
        let mut rng = Rng::new(75);
        let x = Matrix::randn(12, 12, 1.0, &mut rng);
        let e2 = contraction_ratio(&x, &Qsgd::new(2).compress(&x, &mut rng).decode());
        let e16 = contraction_ratio(&x, &Qsgd::new(16).compress(&x, &mut rng).decode());
        assert!(e16 < e2 * 0.1, "{e16} vs {e2}");
    }
}
