//! Natural compression (Horváth et al. 2022): unbiased randomized rounding
//! of each value to a signed power of two. Only the sign + exponent travel
//! (9 bits vs 32 — the mantissa is dropped), giving α = 8/9 w.r.t. ‖·‖₂.

use super::{Compressor, Message, NormFamily, Payload};
use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// Round one value to ±2^e, unbiased: x = ±(1+p)·2^e rounds up to 2^(e+1)
/// with probability p and down to 2^e with probability 1−p.
#[inline]
pub fn nat_round(x: f32, rng: &mut Rng) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return if x.is_finite() { 0.0 } else { x };
    }
    let bits = x.to_bits();
    let sign = bits & 0x8000_0000;
    let exp = (bits >> 23) & 0xff;
    let frac = bits & 0x007f_ffff;
    if exp == 0 {
        // subnormal: round to zero or the smallest normal, unbiased
        let p = frac as f64 / (1u32 << 23) as f64 / 2.0; // value / 2^-126 halved
        let up = rng.f64() < p;
        return if up {
            f32::from_bits(sign | (1 << 23))
        } else {
            0.0
        };
    }
    if exp == 0xfe && frac != 0 {
        // would overflow the exponent when rounding up; clamp down
        return f32::from_bits(sign | (exp << 23));
    }
    let p = frac as f64 / (1u32 << 23) as f64; // mantissa fraction in [0,1)
    let up = rng.f64() < p;
    let new_exp = if up { exp + 1 } else { exp };
    f32::from_bits(sign | (new_exp << 23))
}

/// Deterministic variant (round to nearest power of two in log space) —
/// biased, but useful for tests needing determinism.
#[inline]
pub fn nat_round_deterministic(x: f32) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return if x.is_finite() { 0.0 } else { x };
    }
    let bits = x.to_bits();
    let sign = bits & 0x8000_0000;
    let exp = (bits >> 23) & 0xff;
    let frac = bits & 0x007f_ffff;
    let up = frac as f64 / (1u32 << 23) as f64 >= 0.5;
    let new_exp = if up && exp < 0xfe { exp + 1 } else { exp };
    f32::from_bits(sign | (new_exp << 23))
}

/// Quantize a whole matrix in place; returns the quantized copy.
pub fn nat_quantize(x: &Matrix, rng: &mut Rng) -> Matrix {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        *v = nat_round(*v, rng);
    }
    out
}

/// 9-bit wire code (sign<<8 | exponent) of a Natural-quantized value.
#[inline]
pub fn nat_code(x: f32) -> u16 {
    if x == 0.0 {
        return 0;
    }
    let bits = x.to_bits();
    let sign = ((bits >> 31) & 1) as u16;
    let exp = ((bits >> 23) & 0xff) as u16;
    (sign << 8) | exp
}

/// Inverse of [`nat_code`].
#[inline]
pub fn nat_decode(code: u16) -> f32 {
    let exp = (code & 0xff) as u32;
    if exp == 0 {
        return 0.0;
    }
    let sign = ((code >> 8) & 1) as u32;
    f32::from_bits((sign << 31) | (exp << 23))
}

/// The Natural compressor as a standalone operator (dense payload).
pub struct NaturalCompressor;

impl NaturalCompressor {
    pub fn new() -> Self {
        NaturalCompressor
    }
}

impl Default for NaturalCompressor {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for NaturalCompressor {
    fn compress(&mut self, x: &Matrix, rng: &mut Rng) -> Message {
        Message { payload: Payload::Dense { m: nat_quantize(x, rng), nat: true } }
    }

    fn name(&self) -> String {
        "nat".into()
    }

    fn family(&self) -> NormFamily {
        NormFamily::Euclidean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_to_powers_of_two() {
        let mut rng = Rng::new(61);
        for _ in 0..200 {
            let x = rng.normal_f32() * 10.0;
            let y = nat_round(x, &mut rng);
            if y != 0.0 {
                let frac = y.to_bits() & 0x007f_ffff;
                assert_eq!(frac, 0, "mantissa must be zero, got {y} from {x}");
                assert_eq!(y.signum(), x.signum());
            }
        }
    }

    #[test]
    fn unbiased() {
        let mut rng = Rng::new(62);
        let x = 1.37f32;
        let n = 40_000;
        let mean: f64 = (0..n).map(|_| nat_round(x, &mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - x as f64).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn relative_error_bounded() {
        // |C(x) - x| <= |x| (the rounding never moves past a factor of 2)
        let mut rng = Rng::new(63);
        for _ in 0..500 {
            let x = (rng.f32() - 0.5) * 100.0;
            let y = nat_round(x, &mut rng);
            assert!((y - x).abs() <= x.abs() + 1e-12);
        }
    }

    #[test]
    fn code_roundtrip() {
        let mut rng = Rng::new(64);
        for _ in 0..200 {
            let x = nat_round(rng.normal_f32() * 3.0, &mut rng);
            assert_eq!(nat_decode(nat_code(x)), x);
        }
        assert_eq!(nat_decode(nat_code(0.0)), 0.0);
    }

    #[test]
    fn contraction_euclidean() {
        // Def. 1: E||C(x)-x||^2 <= (1-alpha)||x||^2 with alpha = 8/9 for
        // natural compression -> ratio <= 1/9 + slack
        let mut rng = Rng::new(65);
        let x = Matrix::randn(40, 40, 1.0, &mut rng);
        let mut ratios = Vec::new();
        for _ in 0..20 {
            let y = nat_quantize(&x, &mut rng);
            ratios.push(super::super::contraction_ratio(&x, &y));
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(mean <= 1.0 / 9.0 + 0.02, "mean contraction ratio {mean}");
    }
}
