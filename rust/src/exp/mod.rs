//! Experiment drivers — one function per paper table/figure (see DESIGN.md
//! per-experiment index). The bench targets in `rust/benches/` and the
//! `efmuon` CLI subcommands are thin wrappers around these.

use anyhow::Result;

use crate::compress::Message;
use crate::config::TrainConfig;
use crate::dist::cluster::Cluster;
use crate::dist::service::GradService;
use crate::dist::MeterSnapshot;
use crate::funcs::{CoshObjective, MatrixQuadratic, Objective, Quadratics, Stacked};
use crate::linalg::matrix::Matrix;
use crate::lmo::LmoKind;
use crate::metrics::render_table;
use crate::opt::ef21::Ef21MuonSeq;
use crate::opt::{LayerGeometry, Schedule, ScheduleKind};
use crate::spec::{CompSpec, RunBuilder, RunSpec};
use crate::train::{spawn_seq_driver, train, Driver, TrainReport};
use crate::util::rng::Rng;
use crate::util::stats::linfit;
use crate::util::timer::Timer;

/// The compressor configurations evaluated in the paper's Table 2 /
/// Figures 1–2. The typed table lives in [`crate::spec`] — one source of
/// truth the train path, the sweeps and the benches all share, so they
/// cannot drift.
pub fn paper_compressor_specs() -> &'static [CompSpec] {
    crate::spec::PAPER_COMPRESSOR_SPECS
}

/// A compact default sweep for the figures (most competitive configs, as
/// Figure 1 does). Typed table in [`crate::spec`].
pub fn figure_specs() -> &'static [CompSpec] {
    crate::spec::FIGURE_SPECS
}

// ---------------------------------------------------------------------------
// Table 2: communication cost per round, normalized to the identity
// ---------------------------------------------------------------------------

/// One Table 2 row.
#[derive(Debug, Clone)]
pub struct CostRow {
    pub spec: String,
    pub bytes_per_round: usize,
    pub relative: f64,
}

/// Exact per-round w2s bytes for each compressor over a set of layer
/// shapes (one message per layer, as in Algorithm 3). Takes the typed
/// descriptors — the same values the train path deploys, including the
/// RankK→TopK degenerate-shape fallback.
pub fn table2_rows(shapes: &[(usize, usize)], specs: &[CompSpec]) -> Result<Vec<CostRow>> {
    let mut rng = Rng::new(42);
    let layers: Vec<Matrix> = shapes
        .iter()
        .map(|&(m, n)| Matrix::randn(m, n, 1.0, &mut rng))
        .collect();
    let dense: usize = total_bytes(CompSpec::Id.build_layers(shapes), &layers, &mut rng);
    specs
        .iter()
        .map(|spec| {
            let bytes = total_bytes(spec.build_layers(shapes), &layers, &mut Rng::new(42));
            Ok(CostRow {
                spec: spec.spec(),
                bytes_per_round: bytes,
                relative: bytes as f64 / dense as f64,
            })
        })
        .collect()
}

fn total_bytes(
    mut cs: Vec<Box<dyn crate::compress::Compressor>>,
    layers: &[Matrix],
    rng: &mut Rng,
) -> usize {
    cs.iter_mut()
        .zip(layers)
        .map(|(c, l)| c.compress(l, rng).wire_bytes())
        .sum()
}

/// Render Table 2 as text.
pub fn table2_text(rows: &[CostRow]) -> String {
    render_table(
        &["Compressor", "Bytes/round", "Relative Cost"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.spec.clone(),
                    r.bytes_per_round.to_string(),
                    format!("{:.4}", r.relative),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

// ---------------------------------------------------------------------------
// Bidirectional compression: the EF21-P s2w sweep (objective backend)
// ---------------------------------------------------------------------------

/// Server-compressor specs worth sweeping for the s2w direction (typed
/// table in [`crate::spec`]).
pub fn s2w_specs() -> &'static [CompSpec] {
    crate::spec::S2W_SPECS
}

/// One row of the bidirectional-compression comparison.
#[derive(Debug, Clone)]
pub struct S2wRow {
    pub server_spec: String,
    /// Total s2w broadcast bytes over the run.
    pub s2w_bytes: u64,
    /// Total w2s bytes per worker over the run.
    pub w2s_bytes: u64,
    pub final_loss: f64,
    /// The typed spec this row ran (canonical JSON goes to the results
    /// store so a stored row is reproducible from its record alone).
    pub spec: RunSpec,
}

/// EF21-P server-to-worker sweep on the objective backend (offline, no
/// artifacts): fixed w2s compressor, varying s2w compressor, identical
/// seeds. The paper's deployment fixes s2w to `id`; this measures what the
/// bidirectional path buys — strictly fewer broadcast bytes at matched
/// final loss (the scenario harness asserts the same on the threaded
/// coordinator). Each run is one [`crate::spec::RunSpec`] driven through
/// the sequential reference [`Driver`] — no hand-wired optimizer.
pub fn s2w_savings(server_specs: &[CompSpec], rounds: usize, seed: u64) -> Result<Vec<S2wRow>> {
    let mut rows = Vec::new();
    for spec in server_specs {
        let mut rng = Rng::new(seed);
        let obj = Quadratics::new(4, 16, 0.6, 0.0, &mut rng);
        let geometry = vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }];
        let run = RunBuilder::new()
            .steps(rounds)
            .worker_comp(CompSpec::Top { frac: 0.3, nat: false })
            .server_comp(spec)
            .beta(1.0)
            .lr(0.05)
            .warmup(0)
            .min_lr_frac(0.02)
            .seed(seed)
            .build()?;
        let mut drv = spawn_seq_driver(&run, Box::new(obj), geometry)?;
        for _ in 0..rounds {
            drv.round()?;
        }
        rows.push(S2wRow {
            server_spec: spec.spec(),
            s2w_bytes: drv.s2w(),
            w2s_bytes: drv.w2s(),
            // full-precision, like the pre-driver sweep always reported
            final_loss: drv.loss_f64(),
            spec: run,
        });
    }
    Ok(rows)
}

/// Render the s2w sweep as text.
pub fn s2w_text(rows: &[S2wRow]) -> String {
    let dense = rows
        .iter()
        .find(|r| r.server_spec == "id")
        .map(|r| r.s2w_bytes)
        .unwrap_or(0);
    render_table(
        &["s2w compressor", "s2w bytes", "vs dense", "w2s bytes/worker", "final loss"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.server_spec.clone(),
                    r.s2w_bytes.to_string(),
                    if dense > 0 {
                        format!("{:.4}", r.s2w_bytes as f64 / dense as f64)
                    } else {
                        "-".into()
                    },
                    r.w2s_bytes.to_string(),
                    format!("{:.6}", r.final_loss),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

// ---------------------------------------------------------------------------
// Multi-coordinator shard scaling (dist::cluster) — the `efmuon shards`
// sweep
// ---------------------------------------------------------------------------

/// One row of the shard-scaling sweep.
#[derive(Debug, Clone)]
pub struct ShardScalingRow {
    pub shards: usize,
    /// Median-free mean wall time of one lock-step cluster round (ms).
    pub round_ms: f64,
    /// Speedup of this row's round time over the 1-shard row.
    pub speedup_vs_1: f64,
    pub final_loss: f32,
    /// Cluster totals over the run (sums over shards).
    pub w2s_bytes: u64,
    pub w2s_all_bytes: u64,
    pub s2w_bytes: u64,
    /// Full rolled-up meter (every counter, not just the byte totals).
    pub meter: MeterSnapshot,
    /// The typed spec this row ran.
    pub spec: RunSpec,
}

/// Shard-scaling sweep on a layer-separable synthetic workload: a
/// [`Stacked`] objective of `parts` grad-heavy [`MatrixQuadratic`] layers
/// (`dim`×`dim`, `workers` data workers), driven by a [`Cluster`] at each
/// shard count. Layer separability makes sharding *exact* here, so losses
/// and wire bytes are invariant in the shard count (deterministic `top`
/// compressors) while the per-round wall time drops toward the max over
/// shards. Shard counts exceeding the layer count are skipped.
pub fn shard_scaling_with(
    parts: usize,
    dim: usize,
    workers: usize,
    shard_counts: &[usize],
    rounds: usize,
    seed: u64,
) -> Result<Vec<ShardScalingRow>> {
    let mut rows = Vec::new();
    let mut base_ms = None;
    for &s in shard_counts {
        if s == 0 || s > parts {
            eprintln!("[shards] skipping shards={s} (workload has {parts} layers)");
            continue;
        }
        let mut rng = Rng::new(seed);
        let stack: Vec<Box<dyn Objective>> = (0..parts)
            .map(|_| {
                Box::new(MatrixQuadratic::new(workers, dim, dim, 0.0, &mut rng))
                    as Box<dyn Objective>
            })
            .collect();
        let obj = Stacked::new(stack).map_err(anyhow::Error::msg)?;
        let x0 = obj.init(&mut Rng::new(seed));
        let geometry =
            vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; parts];
        let svc = GradService::spawn_objective(Box::new(obj), seed);
        // one typed spec per shard count; warmup 0 + min_lr_frac 1.0 is
        // exactly the constant schedule the sweep always used
        let run = RunBuilder::new()
            .workers(workers)
            .shards(s)
            .steps(rounds)
            .worker_comp(CompSpec::Top { frac: 0.2, nat: false })
            .server_comp(CompSpec::Top { frac: 0.5, nat: false })
            .lr(0.02)
            .warmup(0)
            .min_lr_frac(1.0)
            .seed(seed)
            .use_ns_artifact(false)
            .build()?;
        let mut cluster = Cluster::spawn(x0, geometry, svc.handle(), run.cluster_cfg())?;
        for _ in 0..rounds.min(3) {
            cluster.round()?; // warmup: arenas, caches, thread ramp-up
        }
        let timer = Timer::start();
        for _ in 0..rounds {
            cluster.round()?;
        }
        let secs = timer.seconds();
        cluster.drain()?;
        let final_loss = cluster.eval()?;
        let m = cluster.meter();
        let round_ms = secs * 1e3 / rounds.max(1) as f64;
        let speedup_vs_1 = match base_ms {
            None => {
                base_ms = Some(round_ms);
                1.0
            }
            Some(b) => b / round_ms,
        };
        rows.push(ShardScalingRow {
            shards: s,
            round_ms,
            speedup_vs_1,
            final_loss,
            w2s_bytes: m.w2s(),
            w2s_all_bytes: m.w2s_all(),
            s2w_bytes: m.s2w(),
            meter: m.totals(),
            spec: run,
        });
    }
    Ok(rows)
}

/// The default `efmuon shards` workload: 4 layers of 192×192, 4 workers.
pub fn shard_scaling(shard_counts: &[usize], rounds: usize, seed: u64) -> Result<Vec<ShardScalingRow>> {
    shard_scaling_with(4, 192, 4, shard_counts, rounds, seed)
}

/// Render the shard-scaling sweep as text.
pub fn shards_text(rows: &[ShardScalingRow]) -> String {
    render_table(
        &["shards", "round ms", "speedup", "final loss", "w2s/worker", "w2s all", "s2w"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.shards.to_string(),
                    format!("{:.3}", r.round_ms),
                    format!("{:.2}x", r.speedup_vs_1),
                    format!("{:.6}", r.final_loss),
                    r.w2s_bytes.to_string(),
                    r.w2s_all_bytes.to_string(),
                    r.s2w_bytes.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

// ---------------------------------------------------------------------------
// Figures 1 & 2: loss vs tokens / bytes, and the trade-off scatter
// ---------------------------------------------------------------------------

/// Run the full compressor sweep (Figure 1 left+right, Figure 2 rows).
/// The sweep axis is typed ([`CompSpec`]) — each run's config carries the
/// canonical string form, parsed back exactly once at the train boundary.
pub fn figure_sweep(base: &TrainConfig, specs: &[CompSpec]) -> Result<Vec<TrainReport>> {
    let mut out = Vec::new();
    for spec in specs {
        let mut cfg = base.clone();
        cfg.worker_comp = spec.spec();
        eprintln!("[fig] training with {spec} ...");
        let report = train(&cfg)?;
        eprintln!(
            "[fig] {spec}: final eval loss {:.4} ({} steps, {:.1}s)",
            report.final_eval_loss, report.steps, report.wall_seconds
        );
        out.push(report);
    }
    Ok(out)
}

/// Figure 1-left rows: (spec, tokens, eval_loss) triples.
pub fn fig1_left_rows(reports: &[TrainReport]) -> Vec<(String, u64, f32)> {
    let mut rows = Vec::new();
    for r in reports {
        for p in &r.curve {
            rows.push((r.config_comp.clone(), p.tokens_processed, p.eval_loss));
        }
    }
    rows
}

/// Figure 1-right / Figure 2 rows: per-spec (tokens, relative bytes) to
/// reach the target loss.
#[derive(Debug, Clone)]
pub struct TradeoffRow {
    pub spec: String,
    pub reached: bool,
    pub tokens_to_target: u64,
    pub relative_bytes_to_target: f64,
    pub final_loss: f32,
}

pub fn tradeoff_rows(reports: &[TrainReport], target: f32) -> Vec<TradeoffRow> {
    reports
        .iter()
        .map(|r| TradeoffRow {
            spec: r.config_comp.clone(),
            reached: r.tokens_to_loss(target).is_some(),
            tokens_to_target: r.tokens_to_loss(target).unwrap_or(0),
            relative_bytes_to_target: r.relative_bytes_to_loss(target).unwrap_or(f64::NAN),
            final_loss: r.final_eval_loss,
        })
        .collect()
}

/// Communication savings vs the uncompressed baseline at the target loss
/// (the paper's headline "up to 7×" number).
pub fn savings_vs_id(rows: &[TradeoffRow]) -> Vec<(String, f64)> {
    let id_bytes = rows
        .iter()
        .find(|r| r.spec == "id" && r.reached)
        .map(|r| r.relative_bytes_to_target);
    match id_bytes {
        None => vec![],
        Some(base) => rows
            .iter()
            .filter(|r| r.reached && r.spec != "id")
            .map(|r| (r.spec.clone(), base / r.relative_bytes_to_target))
            .collect(),
    }
}

// ---------------------------------------------------------------------------
// Table 1: empirical convergence-rate validation
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct RateRow {
    pub setting: String,
    pub theory_slope: f64,
    pub fitted_slope: f64,
    pub r2: f64,
}

/// Fit `log(min-grad-dual-norm) ~ slope · log(K)` over a K-sweep of
/// EF21-Muon runs, deterministic (theory −1/2) and stochastic (−1/4).
pub fn rate_validation(seed: u64) -> Result<Vec<RateRow>> {
    let mut rows = Vec::new();
    let ks: Vec<usize> = vec![64, 128, 256, 512, 1024, 2048];

    // -- deterministic, smooth (Theorem 3): O(1/sqrt(K))
    {
        let mut rng = Rng::new(seed);
        let obj = Quadratics::new(4, 20, 0.8, 0.0, &mut rng);
        let pts = rate_points(&obj, &ks, 1.0, false, 0.35, seed)?;
        let (x, y): (Vec<f64>, Vec<f64>) = pts.into_iter().unzip();
        let (_, slope, r2) = linfit(&x, &y);
        rows.push(RateRow {
            setting: "deterministic smooth (Thm 3)".into(),
            theory_slope: -0.5,
            fitted_slope: slope,
            r2,
        });
    }

    // -- deterministic, (L0,L1)-smooth (Theorem 4): O(1/sqrt(K))
    {
        let mut rng = Rng::new(seed + 1);
        let obj = CoshObjective::new(4, 10, &mut rng);
        let pts = rate_points(&obj, &ks, 1.0, false, 0.6, seed)?;
        let (x, y): (Vec<f64>, Vec<f64>) = pts.into_iter().unzip();
        let (_, slope, r2) = linfit(&x, &y);
        rows.push(RateRow {
            setting: "deterministic (L0,L1)-smooth (Thm 4)".into(),
            theory_slope: -0.5,
            fitted_slope: slope,
            r2,
        });
    }

    // -- stochastic, smooth (Theorem 5): O(1/K^{1/4})
    {
        let mut rng = Rng::new(seed + 2);
        let obj = Quadratics::new(4, 20, 0.8, 0.4, &mut rng);
        let pts = rate_points(&obj, &ks, 0.35, true, 0.8, seed)?;
        let (x, y): (Vec<f64>, Vec<f64>) = pts.into_iter().unzip();
        let (_, slope, r2) = linfit(&x, &y);
        rows.push(RateRow {
            setting: "stochastic smooth (Thm 5)".into(),
            theory_slope: -0.25,
            fitted_slope: slope,
            r2,
        });
    }

    Ok(rows)
}

/// For each K, run EF21-Muon with the theory schedule (t ∝ K^-1/2, β ∝
/// K^-1/2 in the stochastic case) and return (ln K, ln min_k ‖∇f‖⋆).
fn rate_points(
    obj: &dyn Objective,
    ks: &[usize],
    eta: f64,
    stochastic: bool,
    beta_pow: f64,
    seed: u64,
) -> Result<Vec<(f64, f64)>> {
    let geometry =
        vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }; obj.layer_shapes().len()];
    let mut pts = Vec::new();
    for &k in ks {
        let beta = if stochastic {
            (1.0 / (k as f64).powf(beta_pow)).min(1.0) as f32
        } else {
            1.0
        };
        // the theory schedules are SchedulePlan shapes now, so this goes
        // through the same validated builder as every training run
        // (bit-identical to the former hand-built Schedule literal —
        // golden-tested in spec::run)
        let run = RunBuilder::new()
            .steps(k)
            .worker_comp("top:0.25")
            .server_comp("id")
            .beta(beta)
            .lr(eta)
            .warmup(0)
            .min_lr_frac(1.0)
            .schedule_kind(if stochastic {
                ScheduleKind::Theory34
            } else {
                ScheduleKind::InvSqrtTotal
            })
            .seed(seed)
            .build()
            .map_err(|e| anyhow::Error::msg(e.to_string()))?;
        let mut opt = Ef21MuonSeq::new(
            obj,
            geometry.clone(),
            run.worker_comp,
            run.server_comp,
            run.beta,
            run.schedule(),
            stochastic,
            run.seed,
        )
        .map_err(anyhow::Error::msg)?;
        let trace = opt.run(obj, k);
        let min_grad = trace
            .iter()
            .map(|s| s.grad_norm2.sqrt())
            .fold(f64::INFINITY, f64::min);
        pts.push(((k as f64).ln(), min_grad.max(1e-12).ln()));
    }
    Ok(pts)
}

pub fn rates_text(rows: &[RateRow]) -> String {
    render_table(
        &["Setting", "Theory slope", "Fitted slope", "R²"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.setting.clone(),
                    format!("{:+.2}", r.theory_slope),
                    format!("{:+.3}", r.fitted_slope),
                    format!("{:.3}", r.r2),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

// ---------------------------------------------------------------------------
// Layer-wise (L⁰,L¹)-smoothness probe (paper §B / Riabinin et al. evidence)
// ---------------------------------------------------------------------------

/// Estimated layer-wise smoothness constants along a trajectory.
#[derive(Debug, Clone)]
pub struct SmoothnessEstimate {
    pub layer: usize,
    /// intercept of ‖Δ∇ᵢf‖/‖ΔXᵢ‖ vs ‖∇ᵢf‖ — the L⁰ᵢ estimate
    pub l0: f64,
    /// slope — the L¹ᵢ estimate (>0 indicates generalized smoothness)
    pub l1: f64,
    pub r2: f64,
}

/// Probe Assumption 8 empirically: run EF21-Muon on `obj`, record
/// (‖∇ᵢf(X^k)‖, ‖∇ᵢf(X^{k+1})−∇ᵢf(X^k)‖/‖Xᵢ^{k+1}−Xᵢ^k‖) pairs per layer,
/// regress. The paper argues deep nets have L¹ᵢ > 0 (smoothness grows with
/// gradient norm); the cosh objective reproduces this, quadratics give
/// L¹ ≈ 0.
pub fn smoothness_probe(
    obj: &dyn Objective,
    kind: LmoKind,
    lr: f64,
    steps: usize,
    seed: u64,
) -> Result<Vec<SmoothnessEstimate>> {
    let p = obj.layer_shapes().len();
    let geometry = vec![LayerGeometry { lmo: kind, radius_mult: 1.0 }; p];
    let mut opt = Ef21MuonSeq::new(
        obj,
        geometry,
        "id",
        "id",
        1.0,
        Schedule::constant(lr),
        false,
        seed,
    )
    .map_err(anyhow::Error::msg)?;
    let mut xs: Vec<Vec<f64>> = vec![Vec::new(); p];
    let mut ys: Vec<Vec<f64>> = vec![Vec::new(); p];
    let mut prev_x = opt.params().clone();
    let mut prev_g = obj.grad(&prev_x);
    for _ in 0..steps {
        opt.step(obj);
        let x = opt.params().clone();
        let g = obj.grad(&x);
        for i in 0..p {
            let dx = x[i].sub(&prev_x[i]).norm2();
            let dg = g[i].sub(&prev_g[i]).norm2();
            if dx > 1e-12 {
                xs[i].push(prev_g[i].norm2());
                ys[i].push(dg / dx);
            }
        }
        prev_x = x;
        prev_g = g;
    }
    Ok((0..p)
        .map(|i| {
            let (l0, l1, r2) = linfit(&xs[i], &ys[i]);
            SmoothnessEstimate { layer: i, l0, l1, r2 }
        })
        .collect())
}

// ---------------------------------------------------------------------------
// Ablations (paper §G.3 learning rate, §G.4 compression level)
// ---------------------------------------------------------------------------

/// G.3: sweep the base radius for a fixed compressor; returns
/// (lr, final eval loss).
pub fn lr_ablation(base: &TrainConfig, lrs: &[f64]) -> Result<Vec<(f64, f32)>> {
    let mut out = Vec::new();
    for &lr in lrs {
        let mut cfg = base.clone();
        cfg.lr = lr;
        let r = train(&cfg)?;
        eprintln!("[G3] lr={lr}: final eval loss {:.4}", r.final_eval_loss);
        out.push((lr, r.final_eval_loss));
    }
    Ok(out)
}

/// G.4: sweep compression level for a compressor family ("top" or "rank");
/// returns (level, final loss, relative bytes per round).
pub fn level_ablation(
    base: &TrainConfig,
    family: &str,
    levels: &[f64],
) -> Result<Vec<(f64, f32, f64)>> {
    let manifest = crate::model::Manifest::load(&base.artifacts).map_err(anyhow::Error::msg)?;
    let shapes = manifest.layer_shapes();
    let mut out = Vec::new();
    for &lv in levels {
        // construct the typed descriptor directly — no string formatting
        // round-trip through the grammar
        let spec = match family {
            "top" => CompSpec::Top { frac: lv, nat: false },
            "rank" => CompSpec::Rank { frac: lv, nat: false },
            other => {
                return Err(anyhow::anyhow!(
                    "level ablation supports families top | rank (got {other:?})"
                ))
            }
        };
        spec.validate().map_err(anyhow::Error::msg)?;
        let rows = table2_rows(&shapes, &[spec])?;
        let mut cfg = base.clone();
        cfg.worker_comp = spec.spec();
        let r = train(&cfg)?;
        eprintln!("[G4] {spec}: final eval loss {:.4}", r.final_eval_loss);
        out.push((lv, r.final_eval_loss, rows[0].relative));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Report persistence (benches hand results to each other through results/)
// ---------------------------------------------------------------------------

/// Serialize sweep reports to JSON (consumed by [`load_reports`]).
pub fn save_reports(path: &str, reports: &[TrainReport]) -> Result<()> {
    use crate::util::json::Json;
    let arr: Vec<Json> = reports
        .iter()
        .map(|r| {
            let curve: Vec<Json> = r
                .curve
                .iter()
                .map(|p| {
                    Json::Obj(
                        [
                            ("step".to_string(), Json::Num(p.step as f64)),
                            ("tokens".to_string(), Json::Num(p.tokens_processed as f64)),
                            ("w2s".to_string(), Json::Num(p.w2s_bytes_per_worker as f64)),
                            ("loss".to_string(), Json::Num(p.eval_loss as f64)),
                        ]
                        .into_iter()
                        .collect(),
                    )
                })
                .collect();
            Json::Obj(
                [
                    ("comp".to_string(), Json::Str(r.config_comp.clone())),
                    ("steps".to_string(), Json::Num(r.steps as f64)),
                    ("final_loss".to_string(), Json::Num(r.final_eval_loss as f64)),
                    ("model_bytes".to_string(), Json::Num(r.model_bytes as f64)),
                    ("tokens_per_step".to_string(), Json::Num(r.tokens_per_step as f64)),
                    ("wall_seconds".to_string(), Json::Num(r.wall_seconds)),
                    ("curve".to_string(), Json::Arr(curve)),
                ]
                .into_iter()
                .collect(),
            )
        })
        .collect();
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, Json::Arr(arr).to_string())?;
    Ok(())
}

/// Load reports saved by [`save_reports`].
pub fn load_reports(path: &str) -> Result<Vec<TrainReport>> {
    use crate::train::EvalPoint;
    use crate::util::json::Json;
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(anyhow::Error::msg)?;
    let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("expected array"))?;
    arr.iter()
        .map(|r| {
            let get = |k: &str| -> Result<f64> {
                r.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow::anyhow!("missing {k}"))
            };
            let curve = r
                .get("curve")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("missing curve"))?
                .iter()
                .map(|p| EvalPoint {
                    step: p.get("step").and_then(|v| v.as_usize()).unwrap_or(0),
                    tokens_processed: p.get("tokens").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                    w2s_bytes_per_worker: p.get("w2s").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                    eval_loss: p.get("loss").and_then(|v| v.as_f64()).unwrap_or(f64::NAN) as f32,
                })
                .collect();
            Ok(TrainReport {
                config_comp: r
                    .get("comp")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string(),
                steps: get("steps")? as usize,
                final_eval_loss: get("final_loss")? as f32,
                curve,
                train_losses: vec![],
                total_w2s_bytes_per_worker: 0,
                total_s2w_bytes: 0,
                model_bytes: get("model_bytes")? as usize,
                tokens_per_step: get("tokens_per_step")? as usize,
                wall_seconds: get("wall_seconds")?,
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Divergence demo (paper §2 / §A.2, Beznosikov Example 1)
// ---------------------------------------------------------------------------

pub mod divergence {
    use std::io::Write;

    use anyhow::Result;

    use crate::funcs::{Objective, ThreeQuadratics};
    use crate::lmo::LmoKind;
    use crate::opt::dcgd::{Ef14, NaiveDcgd};
    use crate::opt::ef21::Ef21MuonSeq;
    use crate::opt::{LayerGeometry, Schedule};
    use crate::util::rng::Rng;

    /// Loss traces for (naive DCGD, EF14, EF21-Muon) on the
    /// three-quadratics, all with Top1 compression and the same stepsize.
    pub fn traces(steps: usize) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let obj = ThreeQuadratics::new();
        let mut rng = Rng::new(1);
        let x0 = obj.init(&mut rng);
        let lr = 0.1;
        let spec = "top:0.3"; // Top1 of 3 coordinates (k = ceil(0.9) = 1)

        let mut naive = NaiveDcgd::new(&obj, spec, lr, 5).map_err(anyhow::Error::msg)?;
        let mut x = x0.clone();
        let t_naive: Vec<f64> = (0..steps)
            .map(|_| {
                naive.step(&obj, &mut x);
                obj.loss(&x)
            })
            .collect();

        let mut ef14 = Ef14::new(&obj, spec, lr, 5).map_err(anyhow::Error::msg)?;
        let mut y = x0.clone();
        let t_ef14: Vec<f64> = (0..steps)
            .map(|_| {
                ef14.step(&obj, &mut y);
                obj.loss(&y)
            })
            .collect();

        let geometry = vec![LayerGeometry { lmo: LmoKind::Euclidean, radius_mult: 1.0 }];
        let mut ef21 = Ef21MuonSeq::new(
            &obj,
            geometry,
            spec,
            "id",
            1.0,
            Schedule::constant(lr),
            false,
            5,
        )
        .map_err(anyhow::Error::msg)?;
        let t_ef21: Vec<f64> = ef21.run(&obj, steps).iter().map(|s| s.loss).collect();

        Ok((t_naive, t_ef14, t_ef21))
    }

    /// Print the demo to `out`; returns (naive diverged, ef21 converged).
    pub fn run_demo(steps: usize, out: &mut impl Write) -> Result<(bool, bool)> {
        let (naive, ef14, ef21) = traces(steps)?;
        writeln!(out, "{:>6} {:>14} {:>14} {:>14}", "step", "naive-DCGD", "EF14", "EF21-Muon")?;
        for k in (0..steps).step_by((steps / 12).max(1)) {
            writeln!(
                out,
                "{k:>6} {:>14.4e} {:>14.4e} {:>14.4e}",
                naive[k], ef14[k], ef21[k]
            )?;
        }
        let f0 = 0.5; // loss at x0 = (1,1,1): (1/3)*3*(1/2 * 1) = 0.5
        let diverged = *naive.last().unwrap() > 1e3 * f0;
        let converged = *ef21.last().unwrap() < 0.1 * f0;
        writeln!(
            out,
            "\nnaive DCGD diverged: {diverged}; EF21-Muon converged: {converged} \
             (paper §2: biased compression without error feedback explodes)"
        )?;
        Ok((diverged, converged))
    }
}

/// Quick helper for benches: bytes of one dense round (id compressor).
pub fn dense_round_bytes(shapes: &[(usize, usize)]) -> usize {
    shapes
        .iter()
        .map(|&(m, n)| m * n * 4 + crate::compress::HEADER_BYTES)
        .sum()
}

/// Measured per-message overhead sanity check used in tests.
pub fn message_overhead(msg: &Message) -> usize {
    msg.wire_bytes().saturating_sub(match &msg.payload {
        crate::compress::Payload::Dense { m, .. } => m.numel() * 4,
        _ => 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Parse a list of spec strings (test-side boundary).
    fn specs(list: &[&str]) -> Vec<CompSpec> {
        list.iter().map(|s| CompSpec::parse(s).unwrap()).collect()
    }

    #[test]
    fn table2_id_is_one() {
        let shapes = vec![(64, 64), (64, 256), (64, 1)];
        let rows = table2_rows(&shapes, &specs(&["id", "nat", "top:0.1", "rank:0.1"])).unwrap();
        assert!((rows[0].relative - 1.0).abs() < 1e-12);
        // natural ~ 9/32
        assert!((rows[1].relative - 9.0 / 32.0).abs() < 0.02, "{}", rows[1].relative);
        // all compressed strictly cheaper than dense
        for r in &rows[1..] {
            assert!(r.relative < 1.0, "{}: {}", r.spec, r.relative);
        }
    }

    #[test]
    fn table2_ordering_matches_paper_shape() {
        // the paper's qualitative ordering: rank+nat < rank < top at the
        // same level; nat halves(ish) whatever it composes with
        let shapes = vec![(128, 384), (128, 128), (128, 512)];
        let rows = table2_rows(
            &shapes,
            &specs(&["rank:0.15", "rank:0.15+nat", "top:0.15", "top:0.15+nat"]),
        )
        .unwrap();
        let get = |s: &str| rows.iter().find(|r| r.spec == s).unwrap().relative;
        assert!(get("rank:0.15+nat") < get("rank:0.15"));
        assert!(get("top:0.15+nat") < get("top:0.15"));
        assert!(get("rank:0.15") < get("top:0.15"));
    }

    #[test]
    fn s2w_sweep_saves_bytes_at_matched_loss() {
        let rows = s2w_savings(&specs(&["id", "top:0.5"]), 600, 7).unwrap();
        let id = &rows[0];
        let top = &rows[1];
        // compressed broadcast is strictly cheaper...
        assert!(top.s2w_bytes < id.s2w_bytes, "{} vs {}", top.s2w_bytes, id.s2w_bytes);
        // ...at matched final loss (both runs decay the radius to ~0)
        assert!(
            (top.final_loss - id.final_loss).abs() < 1e-3,
            "{} vs {}",
            top.final_loss,
            id.final_loss
        );
        // w2s direction is unchanged by the server compressor choice:
        // top:0.3 on a 16-dim layer sends a fixed k per round
        assert_eq!(top.w2s_bytes, id.w2s_bytes);
    }

    #[test]
    fn shard_scaling_is_loss_and_byte_invariant() {
        // layer-separable workload + deterministic top compressors:
        // sharding repartitions the work without changing the algorithm, so
        // every shard count spends identical wire bytes and lands on the
        // same loss; counts beyond the layer count are skipped
        let rows = shard_scaling_with(3, 24, 2, &[1, 2, 3, 5], 6, 13).unwrap();
        assert_eq!(rows.len(), 3, "shards=5 must be skipped on a 3-layer stack");
        let base = &rows[0];
        assert_eq!(base.shards, 1);
        assert_eq!(base.w2s_all_bytes, 2 * base.w2s_bytes, "2 workers");
        for r in &rows[1..] {
            assert_eq!(r.w2s_bytes, base.w2s_bytes, "shards={}", r.shards);
            assert_eq!(r.s2w_bytes, base.s2w_bytes, "shards={}", r.shards);
            assert!(
                (r.final_loss - base.final_loss).abs() < 1e-6,
                "shards={}: loss {} vs {}",
                r.shards,
                r.final_loss,
                base.final_loss
            );
        }
    }

    #[test]
    fn rate_fits_match_theory() {
        let rows = rate_validation(123).unwrap();
        let det = &rows[0];
        // deterministic quadratics under the theory schedule: slope should
        // be ≈ -0.5 (generous tolerance: small-K effects)
        assert!(
            det.fitted_slope < -0.3 && det.fitted_slope > -0.9,
            "slope {}",
            det.fitted_slope
        );
        assert!(det.r2 > 0.8, "r2 {}", det.r2);
    }
}
