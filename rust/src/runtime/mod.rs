//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Text is the interchange format
//! because xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos.
//!
//! PJRT handles are not `Send`; the distributed coordinator therefore runs
//! all executions on a dedicated service thread (see [`crate::dist`]) — on
//! this 1-core testbed that also happens to be the fastest layout.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::matrix::{Layers, Matrix};
use crate::model::Manifest;

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled HLO module ready to execute.
pub struct LoadedFn {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<LoadedFn> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedFn {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl LoadedFn {
    /// Execute with the given inputs; the artifact was lowered with
    /// `return_tuple=True`, so the single output buffer is a tuple that we
    /// unpack into one literal per result.
    pub fn call(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(lit.to_tuple()?)
    }
}

// -- literal ↔ Matrix bridging ----------------------------------------------

/// Matrix → f32 literal. `rank1` emits shape `[rows]` (JAX vector params),
/// otherwise `[rows, cols]`.
pub fn matrix_to_literal(m: &Matrix, rank1: bool) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(m.data.as_ptr() as *const u8, m.data.len() * 4)
    };
    let dims: Vec<usize> = if rank1 {
        vec![m.rows]
    } else {
        vec![m.rows, m.cols]
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &dims,
        bytes,
    )?)
}

/// f32 literal → Matrix with the given (rows, cols).
pub fn literal_to_matrix(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v: Vec<f32> = lit.to_vec()?;
    if v.len() != rows * cols {
        bail!("literal has {} elements, expected {}x{}", v.len(), rows, cols);
    }
    Ok(Matrix::from_vec(rows, cols, v))
}

/// i32 token batch → literal of shape [batch, seq].
pub fn tokens_to_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    if tokens.len() != batch * seq {
        bail!("token buffer {} != {}x{}", tokens.len(), batch, seq);
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(tokens.as_ptr() as *const u8, tokens.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[batch, seq],
        bytes,
    )?)
}

// -- the model service ------------------------------------------------------

/// All compiled artifacts for one model: grad, eval, and the per-shape
/// Newton–Schulz orthogonalizers (the L1 Pallas kernels live inside these).
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub runtime: Runtime,
    grad: LoadedFn,
    eval: LoadedFn,
    ns: Vec<((usize, usize), LoadedFn)>,
}

impl ModelRuntime {
    /// Load every artifact referenced by `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelRuntime> {
        let manifest = Manifest::load(&dir).map_err(|e| anyhow!(e))?;
        let runtime = Runtime::cpu()?;
        let grad = runtime.load_hlo(&manifest.grad_hlo)?;
        let eval = runtime.load_hlo(&manifest.eval_hlo)?;
        let mut ns = Vec::new();
        for (shape, path) in &manifest.ns_hlo {
            ns.push((*shape, runtime.load_hlo(path)?));
        }
        Ok(ModelRuntime { manifest, runtime, grad, eval, ns })
    }

    fn pack_inputs(
        &self,
        params: &[Matrix],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<Vec<xla::Literal>> {
        let b = self.manifest.batch;
        let t = self.manifest.seq_len;
        let mut inputs = Vec::with_capacity(params.len() + 2);
        for (p, info) in params.iter().zip(&self.manifest.layers) {
            inputs.push(matrix_to_literal(p, info.rank1)?);
        }
        inputs.push(tokens_to_literal(tokens, b, t)?);
        inputs.push(tokens_to_literal(targets, b, t)?);
        Ok(inputs)
    }

    /// Loss + per-layer gradients at `params` on one microbatch — the
    /// worker-side hot call (L2 graph with the L1 Pallas matmuls inside).
    pub fn grad(
        &self,
        params: &[Matrix],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Layers)> {
        let outs = self.grad.call(&self.pack_inputs(params, tokens, targets)?)?;
        if outs.len() != self.manifest.layers.len() + 1 {
            bail!(
                "grad artifact returned {} outputs, expected {}",
                outs.len(),
                self.manifest.layers.len() + 1
            );
        }
        let loss: f32 = outs[0].to_vec::<f32>()?[0];
        let mut grads = Vec::with_capacity(self.manifest.layers.len());
        for (lit, info) in outs[1..].iter().zip(&self.manifest.layers) {
            grads.push(literal_to_matrix(lit, info.rows, info.cols)?);
        }
        Ok((loss, grads))
    }

    /// Evaluation loss on one batch.
    pub fn eval_loss(&self, params: &[Matrix], tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let outs = self.eval.call(&self.pack_inputs(params, tokens, targets)?)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }

    /// Newton–Schulz orthogonalization via the Pallas-kernel artifact for
    /// this exact shape; `None` if no artifact was compiled for it.
    pub fn ns_orthogonalize(&self, g: &Matrix) -> Option<Result<Matrix>> {
        let fnn = self
            .ns
            .iter()
            .find(|((m, n), _)| *m == g.rows && *n == g.cols)
            .map(|(_, f)| f)?;
        Some((|| {
            let lit = matrix_to_literal(g, false)?;
            let outs = fnn.call(&[lit])?;
            literal_to_matrix(&outs[0], g.rows, g.cols)
        })())
    }

    pub fn has_ns_for(&self, rows: usize, cols: usize) -> bool {
        self.ns.iter().any(|((m, n), _)| *m == rows && *n == cols)
    }
}
