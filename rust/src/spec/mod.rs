//! Typed run specification: parse-once algorithm descriptors, presets that
//! recover Muon/Scion/Gluon, and the unified run builder.
//!
//! The paper's family is ONE algorithm parameterized by (per-layer LMO
//! norm, w2s/s2w compressor pair, momentum, schedule). This module is the
//! typed form of that parameterization and the only place configuration
//! strings are parsed:
//!
//! ```text
//!   CLI flags / JSON  ──►  config::TrainConfig      (strings — the facade)
//!                               │  RunBuilder::from_config   (parse ONCE)
//!                               ▼
//!   Preset::{Muon,…} ──►  spec::RunSpec             (typed, validated)
//!          builder overrides    │  train::spawn_driver
//!                               ▼
//!          Coordinator / Cluster / Ef21MuonSeq  behind train::Driver
//! ```
//!
//! - [`CompSpec`] — the compressor descriptor. Parsed once, cloned per
//!   layer; the RankK→TopK degenerate-shape fallback is typed logic
//!   ([`CompSpec::for_shape`]) instead of string splicing.
//! - [`RoundSpec`] — round scheduling (re-export of [`crate::dist::RoundMode`],
//!   the one canonical enum; its string grammar is only invoked here and in
//!   tests).
//! - [`GeomSpec`] / [`SchedulePlan`] — per-group norm/radius choices and
//!   the schedule descriptor.
//! - [`RunSpec`] / [`RunBuilder`] — the whole run, validated eagerly with
//!   field-path error messages ([`SpecError`]); JSON round-trips losslessly.
//! - [`Preset`] — named members of the family (Muon, Scion, Gluon,
//!   EF21-Muon, EF21-P), golden-tested against their legacy string configs.
//!
//! Sweep tables ([`PAPER_COMPRESSOR_SPECS`], [`FIGURE_SPECS`],
//! [`S2W_SPECS`]) live here too, typed and `const`, so `exp` sweeps and
//! Table-2 rows cannot drift from what the train path accepts.
#![deny(clippy::wildcard_enum_match_arm, clippy::too_many_arguments)]

mod comp;
mod preset;
mod run;

pub use comp::{CompSpec, IntoCompSpec, FIGURE_SPECS, PAPER_COMPRESSOR_SPECS, S2W_SPECS};
pub use preset::Preset;
pub use run::{
    lmo_name, parse_lmo, parse_schedule_kind, schedule_kind_name, FieldError, GeomSpec, LinkSpec,
    RunBuilder, RunSpec, SchedulePlan, SpecError,
};

/// Round scheduling descriptor. [`crate::dist::RoundMode`] is already a
/// parsed, validated value type; the spec layer re-exports it as the
/// canonical name so every descriptor a [`RunSpec`] carries is importable
/// from one place.
pub use crate::dist::RoundMode as RoundSpec;
