//! `RunSpec` — the fully-typed, validated description of one run — and
//! `RunBuilder`, the single place a configuration is checked and turned
//! into a deployment.
//!
//! `config::TrainConfig` remains the serialization facade (CLI flags and
//! JSON files, all strings); [`RunBuilder::from_config`] parses each string
//! field exactly once into the typed form and [`RunBuilder::build`]
//! validates everything eagerly, reporting *all* problems with field-path
//! messages instead of panicking mid-run. `RunSpec::to_json` →
//! `RunSpec::from_json` is a lossless round trip (asserted in
//! `rust/tests/spec_api.rs`).

use crate::config::TrainConfig;
use crate::dist::cluster::ClusterCfg;
use crate::dist::coordinator::CoordinatorCfg;
use crate::dist::fault::FaultPolicy;
use crate::dist::sched::SchedSpec;
use crate::dist::{RoundMode, TransportMode};
use crate::lmo::LmoKind;
use crate::model::Group;
use crate::opt::{LayerGeometry, Schedule, ScheduleKind};
use crate::trace::Tracer;
use crate::util::json::{Json, JsonObj};

use super::comp::CompSpec;

// ---------------------------------------------------------------------------
// Field-path errors
// ---------------------------------------------------------------------------

/// One invalid configuration field: the field path plus what is wrong.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FieldError {
    pub path: String,
    pub msg: String,
}

/// Eager validation error: every invalid field of the spec, collected in
/// one pass so a config with three typos reports all three at build time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecError {
    pub fields: Vec<FieldError>,
}

impl SpecError {
    fn new() -> SpecError {
        SpecError { fields: Vec::new() }
    }

    fn push(&mut self, path: &str, msg: impl Into<String>) {
        self.fields.push(FieldError { path: path.to_string(), msg: msg.into() });
    }

    /// True when `path` is among the offending fields (test helper).
    pub fn mentions(&self, path: &str) -> bool {
        self.fields.iter().any(|f| f.path == path)
    }
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid run spec: ")?;
        for (i, e) in self.fields.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "{}: {}", e.path, e.msg)?;
        }
        Ok(())
    }
}

impl std::error::Error for SpecError {}

// ---------------------------------------------------------------------------
// LmoKind names (the serialization form of the per-group norm choice)
// ---------------------------------------------------------------------------

/// Canonical name of an LMO ball (round-trips through [`parse_lmo`]).
pub fn lmo_name(kind: LmoKind) -> &'static str {
    match kind {
        LmoKind::Spectral => "spectral",
        LmoKind::SignLInf => "sign",
        LmoKind::L1Top1 => "top1",
        LmoKind::Euclidean => "euclid",
        LmoKind::NuclearRank1 => "nuclear",
        LmoKind::ColNorm => "colnorm",
    }
}

/// Parse an LMO ball name (see [`lmo_name`]).
pub fn parse_lmo(s: &str) -> Result<LmoKind, String> {
    match s {
        "spectral" => Ok(LmoKind::Spectral),
        "sign" => Ok(LmoKind::SignLInf),
        "top1" => Ok(LmoKind::L1Top1),
        "euclid" => Ok(LmoKind::Euclidean),
        "nuclear" => Ok(LmoKind::NuclearRank1),
        "colnorm" => Ok(LmoKind::ColNorm),
        other => Err(format!(
            "unknown LMO {other:?} (expected spectral | sign | top1 | euclid | nuclear | colnorm)"
        )),
    }
}

/// Canonical name of a schedule shape (round-trips through
/// [`parse_schedule_kind`]).
pub fn schedule_kind_name(kind: ScheduleKind) -> &'static str {
    match kind {
        ScheduleKind::WarmupCosine => "warmup-cosine",
        ScheduleKind::Constant => "constant",
        ScheduleKind::InvSqrtTotal => "inv-sqrt-total",
        ScheduleKind::Theory34 => "theory34",
    }
}

/// Parse a schedule-shape name (see [`schedule_kind_name`]).
pub fn parse_schedule_kind(s: &str) -> Result<ScheduleKind, String> {
    match s {
        "warmup-cosine" => Ok(ScheduleKind::WarmupCosine),
        "constant" => Ok(ScheduleKind::Constant),
        "inv-sqrt-total" => Ok(ScheduleKind::InvSqrtTotal),
        "theory34" => Ok(ScheduleKind::Theory34),
        other => Err(format!(
            "unknown schedule {other:?} (expected warmup-cosine | constant | inv-sqrt-total | theory34)"
        )),
    }
}

// ---------------------------------------------------------------------------
// LinkSpec — the transport axis (in-process channels or the socket hop)
// ---------------------------------------------------------------------------

/// Transport of one deployment: the in-process channel pair (the default)
/// or the socket transport at `ADDR` (`dist::net`) — the leader listens
/// there and workers dial it. Loopback TCP is bit-identical to the channel
/// run for the same spec (the PR-9 golden anchor).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LinkSpec {
    Channel,
    Tcp(String),
}

impl LinkSpec {
    /// Parse the `--transport` grammar: `channel` or `tcp:ADDR`.
    pub fn parse(s: &str) -> Result<LinkSpec, String> {
        if s == "channel" {
            return Ok(LinkSpec::Channel);
        }
        match s.strip_prefix("tcp:") {
            Some(addr) if !addr.is_empty() => Ok(LinkSpec::Tcp(addr.to_string())),
            Some(_) => Err("tcp transport needs an address (tcp:HOST:PORT)".to_string()),
            None => Err(format!("unknown transport {s:?} (expected channel | tcp:ADDR)")),
        }
    }

    /// The canonical spec string (`LinkSpec::parse(s.spec()) == Ok(s)`).
    pub fn spec(&self) -> String {
        match self {
            LinkSpec::Channel => "channel".into(),
            LinkSpec::Tcp(addr) => format!("tcp:{addr}"),
        }
    }

    /// The listen/dial address, when this is the socket transport.
    pub fn tcp_addr(&self) -> Option<&str> {
        match self {
            LinkSpec::Channel => None,
            LinkSpec::Tcp(addr) => Some(addr.as_str()),
        }
    }
}

// ---------------------------------------------------------------------------
// GeomSpec — the per-group norm/radius parameterization (Gluon's knob)
// ---------------------------------------------------------------------------

/// Per-group optimizer geometry: which LMO ball each parameter group uses
/// and the relative radius multipliers on top of the global schedule. This
/// is the layer-wise parameterization Gluon formalizes — the presets pin it
/// to recover Muon/Scion (see [`super::Preset`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeomSpec {
    /// 2-D matmul weights. Paper assignment: spectral (Muon).
    pub hidden: LmoKind,
    /// Embeddings / tied output head. Paper assignment: ℓ∞ sign (Scion).
    pub embed: LmoKind,
    /// LayerNorm gains. Paper assignment: ℓ∞ sign at a small radius.
    pub vector: LmoKind,
    /// Radius multiplier for the embed group.
    pub embed_mult: f32,
    /// Radius multiplier for the vector group (the group's base multiplier
    /// is 0.1; the default reproduces it exactly).
    pub vector_mult: f32,
}

impl Default for GeomSpec {
    /// The paper's assignment (`model::Group::geometry` + the historical
    /// `TrainConfig` multiplier defaults).
    fn default() -> GeomSpec {
        GeomSpec {
            hidden: LmoKind::Spectral,
            embed: LmoKind::SignLInf,
            vector: LmoKind::SignLInf,
            embed_mult: 1.0,
            vector_mult: 0.1,
        }
    }
}

impl GeomSpec {
    /// Per-layer geometry for a model's group assignment. The radius
    /// arithmetic is bit-identical to the historical `train::geometry_for`
    /// (base group multiplier composed with the config multiplier), so
    /// existing trajectories are unchanged.
    pub fn for_groups<I: IntoIterator<Item = Group>>(&self, groups: I) -> Vec<LayerGeometry> {
        groups
            .into_iter()
            .map(|group| {
                let mut g = group.geometry();
                match group {
                    Group::Hidden => g.lmo = self.hidden,
                    Group::Embed => {
                        g.lmo = self.embed;
                        g.radius_mult *= self.embed_mult;
                    }
                    Group::Vector => {
                        g.lmo = self.vector;
                        // base is already 0.1 (Group::geometry)
                        g.radius_mult *= self.vector_mult / 0.1;
                    }
                }
                g
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// SchedulePlan — the schedule descriptor (materialized once steps are known)
// ---------------------------------------------------------------------------

/// Descriptor of a radius schedule. A plan is independent of the run
/// length; [`SchedulePlan::materialize`] pins it to a total step count.
/// Every [`ScheduleKind`] the optimizer knows is expressible — the theory
/// rates (`inv-sqrt-total`, `theory34`) used to be constructed by hand in
/// the rate benches and now go through `RunBuilder` like everything else.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedulePlan {
    /// Base radius / learning rate for hidden layers.
    pub lr: f64,
    /// Warmup steps (used by `warmup-cosine` only).
    pub warmup: usize,
    /// Final LR fraction of the cosine decay (used by `warmup-cosine`
    /// only).
    pub min_lr_frac: f64,
    /// Schedule shape (the default `warmup-cosine` reproduces the
    /// historical nanoGPT-style schedule exactly).
    pub kind: ScheduleKind,
}

impl SchedulePlan {
    pub fn materialize(&self, total_steps: usize) -> Schedule {
        match self.kind {
            ScheduleKind::WarmupCosine => {
                Schedule::warmup_cosine(self.lr, self.warmup, total_steps, self.min_lr_frac)
            }
            // these shapes read only base/total in Schedule::at, so the
            // materialized struct reproduces the legacy hand-built
            // schedules bit-for-bit (golden-tested below)
            ScheduleKind::Constant | ScheduleKind::InvSqrtTotal | ScheduleKind::Theory34 => {
                Schedule {
                    base: self.lr,
                    warmup: self.warmup,
                    total: total_steps,
                    min_frac: self.min_lr_frac,
                    kind: self.kind,
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// RunSpec
// ---------------------------------------------------------------------------

/// Fully-typed description of one training run: every compressor, norm and
/// schedule choice parsed and validated exactly once. Constructed by
/// [`RunBuilder`] (from a `TrainConfig`, a [`super::Preset`], or typed
/// setters); consumed by `train::train_spec` and the driver factory.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// Directory with `manifest.json` + HLO artifacts.
    pub artifacts: String,
    /// Number of workers `n` (per shard).
    pub workers: usize,
    /// Shard coordinators the model's layers are partitioned across.
    pub shards: usize,
    /// Optimizer steps.
    pub steps: usize,
    /// Worker (w2s) compressor.
    pub worker_comp: CompSpec,
    /// Server (s2w) compressor for the EF21-P broadcast.
    pub server_comp: CompSpec,
    /// Round scheduling (sync or bounded pipeline).
    pub round: RoundMode,
    /// Momentum β.
    pub beta: f32,
    /// Radius schedule descriptor.
    pub schedule: SchedulePlan,
    /// Per-group norm/radius geometry.
    pub geom: GeomSpec,
    /// Synthetic corpus size in tokens.
    pub corpus_tokens: usize,
    /// Evaluate every `eval_every` steps.
    pub eval_every: usize,
    /// Number of held-out eval batches.
    pub eval_batches: usize,
    /// Use the PJRT NS artifact for spectral LMOs when a shape matches.
    pub use_ns_artifact: bool,
    /// Run the real wire codec on every message.
    pub full_codec: bool,
    pub seed: u64,
    /// Optional JSONL metrics path.
    pub log_path: Option<String>,
    /// Optional round-phase trace path: the driver installs a live
    /// [`Tracer`](crate::trace::Tracer) and drains its ring to this JSONL
    /// file every round (`None` = the zero-cost `Noop` path, bit-identical
    /// to a traceless build).
    pub trace_path: Option<String>,
    /// Straggler / quorum / respawn policy ([`FaultPolicy::off`] =
    /// fail-stop lock-step, bit-identical to the policy-free deployment).
    pub fault: FaultPolicy,
    /// Save a checkpoint every this many steps (0 = never).
    pub checkpoint_every: usize,
    /// Directory checkpoints are saved to / resumed from.
    pub checkpoint_dir: Option<String>,
    /// Resume from the latest checkpoint in `checkpoint_dir` (fresh start
    /// with a notice when none exists yet).
    pub resume: bool,
    /// Transport the leader/worker hop runs over ([`LinkSpec::Channel`] =
    /// in-process, bit-identical to `tcp:` loopback for the same spec).
    pub link: LinkSpec,
    /// Bounded-epoch shard scheduling ([`SchedSpec::off`] = lock-step
    /// rounds, bit-identical to the scheduler-free cluster; see
    /// [`crate::dist::sched`]). Requires `shards >= 2`.
    pub sched: SchedSpec,
    /// Store `ParamBoard` epoch snapshots in bf16: half the snapshot
    /// memory and board bytes, a rounded (still deterministic) trajectory
    /// (see [`crate::dist::cluster::ClusterCfg::snap_bf16`]). Off is
    /// bit-identical to the f32 board.
    pub snap_bf16: bool,
}

impl Default for RunSpec {
    /// Mirrors `TrainConfig::default()` field for field.
    fn default() -> RunSpec {
        RunSpec {
            artifacts: "artifacts".into(),
            workers: 4,
            shards: 1,
            steps: 200,
            worker_comp: CompSpec::Id,
            server_comp: CompSpec::Id,
            round: RoundMode::Sync,
            beta: 0.9,
            schedule: SchedulePlan {
                lr: 0.02,
                warmup: 20,
                min_lr_frac: 0.1,
                kind: ScheduleKind::WarmupCosine,
            },
            geom: GeomSpec::default(),
            corpus_tokens: 2_000_000,
            eval_every: 25,
            eval_batches: 4,
            use_ns_artifact: true,
            full_codec: false,
            seed: 0,
            log_path: None,
            trace_path: None,
            fault: FaultPolicy::off(),
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
            link: LinkSpec::Channel,
            sched: SchedSpec::off(),
            snap_bf16: false,
        }
    }
}

impl RunSpec {
    /// Transport implied by `full_codec`.
    pub fn transport(&self) -> TransportMode {
        if self.full_codec {
            TransportMode::Encoded
        } else {
            TransportMode::Counted
        }
    }

    /// The schedule, materialized over this run's step count.
    pub fn schedule(&self) -> Schedule {
        self.schedule.materialize(self.steps)
    }

    /// The single-leader deployment config this spec describes. The
    /// fault-injection plan is always `None` (injection is a test/bench
    /// hook, never part of a spec) and `start_step` is 0 — the driver
    /// factory overrides it when resuming from a checkpoint.
    pub fn coordinator_cfg(&self) -> CoordinatorCfg {
        CoordinatorCfg {
            n_workers: self.workers,
            worker_comp: self.worker_comp,
            server_comp: self.server_comp,
            beta: self.beta,
            schedule: self.schedule(),
            transport: self.transport(),
            round_mode: self.round,
            seed: self.seed,
            use_ns_artifact: self.use_ns_artifact,
            fault: self.fault,
            fault_plan: None,
            start_step: 0,
            tracer: Tracer::Noop,
        }
    }

    /// The sharded deployment config this spec describes (same `fault_plan`
    /// / `start_step` conventions as [`RunSpec::coordinator_cfg`]).
    pub fn cluster_cfg(&self) -> ClusterCfg {
        ClusterCfg {
            shards: self.shards,
            workers_per_shard: self.workers,
            worker_comp: self.worker_comp,
            server_comp: self.server_comp,
            beta: self.beta,
            schedule: self.schedule(),
            transport: self.transport(),
            round_mode: self.round,
            seed: self.seed,
            use_ns_artifact: self.use_ns_artifact,
            fault: self.fault,
            fault_plan: None,
            start_step: 0,
            snap_bf16: self.snap_bf16,
            sched: self.sched,
            shard_delay: None,
            tracer: Tracer::Noop,
        }
    }

    /// The string-level facade form (CLI/JSON). Lossless: every `RunSpec`
    /// field has a `TrainConfig` representation, and
    /// `RunBuilder::from_config(&spec.to_train_config())` rebuilds an equal
    /// spec (asserted in tests).
    pub fn to_train_config(&self) -> TrainConfig {
        TrainConfig {
            artifacts: self.artifacts.clone(),
            workers: self.workers,
            shards: self.shards,
            steps: self.steps,
            worker_comp: self.worker_comp.spec(),
            server_comp: self.server_comp.spec(),
            round_mode: self.round.spec(),
            lmo_hidden: lmo_name(self.geom.hidden).to_string(),
            lmo_embed: lmo_name(self.geom.embed).to_string(),
            lmo_vector: lmo_name(self.geom.vector).to_string(),
            beta: self.beta,
            lr: self.schedule.lr,
            embed_mult: self.geom.embed_mult,
            vector_mult: self.geom.vector_mult,
            warmup: self.schedule.warmup,
            min_lr_frac: self.schedule.min_lr_frac,
            corpus_tokens: self.corpus_tokens,
            eval_every: self.eval_every,
            eval_batches: self.eval_batches,
            use_ns_artifact: self.use_ns_artifact,
            full_codec: self.full_codec,
            seed: self.seed,
            log_path: self.log_path.clone(),
            trace_path: self.trace_path.clone(),
            fault_policy: self.fault.spec(),
            checkpoint_every: self.checkpoint_every,
            checkpoint_dir: self.checkpoint_dir.clone(),
            resume: self.resume,
            schedule: schedule_kind_name(self.schedule.kind).to_string(),
            transport: self.link.spec(),
            sched: self.sched.spec(),
            snap_bf16: self.snap_bf16,
        }
    }

    /// Canonical JSON form — exactly the `TrainConfig` key set, so the
    /// output is a valid `--config` file (`efmuon config` round-trips
    /// through this; see `scripts/verify.sh`).
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new()
            .put("artifacts", self.artifacts.as_str())
            .put("workers", self.workers)
            .put("shards", self.shards)
            .put("steps", self.steps)
            .put("worker_comp", self.worker_comp.spec())
            .put("server_comp", self.server_comp.spec())
            .put("round_mode", self.round.spec())
            .put("lmo_hidden", lmo_name(self.geom.hidden))
            .put("lmo_embed", lmo_name(self.geom.embed))
            .put("lmo_vector", lmo_name(self.geom.vector))
            .put("beta", self.beta)
            .put("lr", self.schedule.lr)
            .put("embed_mult", self.geom.embed_mult)
            .put("vector_mult", self.geom.vector_mult)
            .put("warmup", self.schedule.warmup)
            .put("min_lr_frac", self.schedule.min_lr_frac)
            .put("corpus_tokens", self.corpus_tokens)
            .put("eval_every", self.eval_every)
            .put("eval_batches", self.eval_batches)
            .put("use_ns_artifact", self.use_ns_artifact)
            .put("full_codec", self.full_codec)
            .put("seed", self.seed)
            .put("fault_policy", self.fault.spec())
            .put("checkpoint_every", self.checkpoint_every)
            .put("resume", self.resume);
        if let Some(p) = &self.log_path {
            o = o.put("log_path", p.as_str());
        }
        if let Some(p) = &self.trace_path {
            o = o.put("trace_path", p.as_str());
        }
        if let Some(d) = &self.checkpoint_dir {
            o = o.put("checkpoint_dir", d.as_str());
        }
        // non-default axes only, so the default `efmuon config` output is
        // byte-identical to the pre-PR-9 form
        if self.schedule.kind != ScheduleKind::WarmupCosine {
            o = o.put("schedule", schedule_kind_name(self.schedule.kind));
        }
        if self.link != LinkSpec::Channel {
            o = o.put("transport", self.link.spec());
        }
        if !self.sched.is_off() {
            o = o.put("sched", self.sched.spec());
        }
        if self.snap_bf16 {
            o = o.put("snap_bf16", true);
        }
        o.build()
    }

    /// Parse the form emitted by [`RunSpec::to_json`] (any valid
    /// `TrainConfig` JSON, i.e. any `--config` file): the strings are
    /// parsed once and the result fully validated.
    pub fn from_json(text: &str) -> Result<RunSpec, SpecError> {
        let cfg = TrainConfig::from_json(text).map_err(|e| {
            let mut err = SpecError::new();
            err.push("config", e);
            err
        })?;
        RunBuilder::from_config(&cfg).build()
    }
}

// ---------------------------------------------------------------------------
// RunBuilder
// ---------------------------------------------------------------------------

/// Builder for a validated [`RunSpec`]. String fields are parsed the moment
/// they enter ([`RunBuilder::from_config`]); [`RunBuilder::build`] then
/// validates every numeric invariant and reports *all* failures as one
/// [`SpecError`] with field-path messages — a bad config can never make it
/// into a running deployment.
#[derive(Debug, Clone)]
pub struct RunBuilder {
    spec: RunSpec,
    errors: Vec<FieldError>,
}

impl Default for RunBuilder {
    fn default() -> Self {
        RunBuilder::new()
    }
}

impl RunBuilder {
    /// Start from the default spec (equivalent to `TrainConfig::default()`).
    pub fn new() -> RunBuilder {
        RunBuilder { spec: RunSpec::default(), errors: Vec::new() }
    }

    /// Start from an existing typed spec.
    pub fn from_spec(spec: RunSpec) -> RunBuilder {
        RunBuilder { spec, errors: Vec::new() }
    }

    /// Start from a named preset (see [`super::Preset`]).
    pub fn preset(p: super::Preset) -> RunBuilder {
        RunBuilder::from_spec(p.spec())
    }

    /// Parse a string-level `TrainConfig` — each spec string exactly once.
    /// Parse failures are recorded with their field path and surface at
    /// [`RunBuilder::build`] alongside any numeric validation errors.
    pub fn from_config(cfg: &TrainConfig) -> RunBuilder {
        let mut b = RunBuilder::new();
        b.spec.artifacts = cfg.artifacts.clone();
        b.spec.workers = cfg.workers;
        b.spec.shards = cfg.shards;
        b.spec.steps = cfg.steps;
        match CompSpec::parse(&cfg.worker_comp) {
            Ok(c) => b.spec.worker_comp = c,
            Err(e) => b.err("worker_comp", e),
        }
        match CompSpec::parse(&cfg.server_comp) {
            Ok(c) => b.spec.server_comp = c,
            Err(e) => b.err("server_comp", e),
        }
        match RoundMode::parse(&cfg.round_mode) {
            Ok(r) => b.spec.round = r,
            Err(e) => b.err("round_mode", e),
        }
        match parse_lmo(&cfg.lmo_hidden) {
            Ok(k) => b.spec.geom.hidden = k,
            Err(e) => b.err("lmo_hidden", e),
        }
        match parse_lmo(&cfg.lmo_embed) {
            Ok(k) => b.spec.geom.embed = k,
            Err(e) => b.err("lmo_embed", e),
        }
        match parse_lmo(&cfg.lmo_vector) {
            Ok(k) => b.spec.geom.vector = k,
            Err(e) => b.err("lmo_vector", e),
        }
        b.spec.beta = cfg.beta;
        b.spec.schedule = SchedulePlan {
            lr: cfg.lr,
            warmup: cfg.warmup,
            min_lr_frac: cfg.min_lr_frac,
            kind: ScheduleKind::WarmupCosine,
        };
        match parse_schedule_kind(&cfg.schedule) {
            Ok(k) => b.spec.schedule.kind = k,
            Err(e) => b.err("schedule", e),
        }
        b.spec.geom.embed_mult = cfg.embed_mult;
        b.spec.geom.vector_mult = cfg.vector_mult;
        b.spec.corpus_tokens = cfg.corpus_tokens;
        b.spec.eval_every = cfg.eval_every;
        b.spec.eval_batches = cfg.eval_batches;
        b.spec.use_ns_artifact = cfg.use_ns_artifact;
        b.spec.full_codec = cfg.full_codec;
        b.spec.seed = cfg.seed;
        b.spec.log_path = cfg.log_path.clone();
        b.spec.trace_path = cfg.trace_path.clone();
        match FaultPolicy::parse(&cfg.fault_policy) {
            Ok(p) => b.spec.fault = p,
            Err(e) => b.err("fault_policy", e),
        }
        b.spec.checkpoint_every = cfg.checkpoint_every;
        b.spec.checkpoint_dir = cfg.checkpoint_dir.clone();
        b.spec.resume = cfg.resume;
        match LinkSpec::parse(&cfg.transport) {
            Ok(l) => b.spec.link = l,
            Err(e) => b.err("transport", e),
        }
        match SchedSpec::parse(&cfg.sched) {
            Ok(s) => b.spec.sched = s,
            Err(e) => b.err("sched", e),
        }
        b.spec.snap_bf16 = cfg.snap_bf16;
        b
    }

    fn err(&mut self, path: &str, msg: impl Into<String>) {
        self.errors.push(FieldError { path: path.to_string(), msg: msg.into() });
    }

    // -- typed setters (fluent) --------------------------------------------

    pub fn artifacts(mut self, dir: impl Into<String>) -> Self {
        self.spec.artifacts = dir.into();
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.spec.workers = n;
        self
    }

    pub fn shards(mut self, s: usize) -> Self {
        self.spec.shards = s;
        self
    }

    pub fn steps(mut self, k: usize) -> Self {
        self.spec.steps = k;
        self
    }

    /// Worker (w2s) compressor — typed descriptor or spec string, parsed
    /// here if needed (errors surface at `build`).
    pub fn worker_comp(mut self, c: impl super::IntoCompSpec) -> Self {
        match c.into_comp_spec() {
            Ok(c) => self.spec.worker_comp = c,
            Err(e) => self.err("worker_comp", e),
        }
        self
    }

    /// Server (s2w) compressor — typed descriptor or spec string.
    pub fn server_comp(mut self, c: impl super::IntoCompSpec) -> Self {
        match c.into_comp_spec() {
            Ok(c) => self.spec.server_comp = c,
            Err(e) => self.err("server_comp", e),
        }
        self
    }

    pub fn round(mut self, r: RoundMode) -> Self {
        self.spec.round = r;
        self
    }

    pub fn beta(mut self, beta: f32) -> Self {
        self.spec.beta = beta;
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.spec.schedule.lr = lr;
        self
    }

    pub fn warmup(mut self, warmup: usize) -> Self {
        self.spec.schedule.warmup = warmup;
        self
    }

    pub fn min_lr_frac(mut self, frac: f64) -> Self {
        self.spec.schedule.min_lr_frac = frac;
        self
    }

    /// Schedule shape (default `warmup-cosine`; the theory rates are
    /// `inv-sqrt-total` / `theory34`).
    pub fn schedule_kind(mut self, kind: ScheduleKind) -> Self {
        self.spec.schedule.kind = kind;
        self
    }

    /// Transport of the leader/worker hop (typed; `tcp:` requires
    /// `shards == 1`, checked at `build`).
    pub fn link(mut self, link: LinkSpec) -> Self {
        self.spec.link = link;
        self
    }

    pub fn geom(mut self, geom: GeomSpec) -> Self {
        self.spec.geom = geom;
        self
    }

    pub fn corpus_tokens(mut self, t: usize) -> Self {
        self.spec.corpus_tokens = t;
        self
    }

    pub fn eval_every(mut self, e: usize) -> Self {
        self.spec.eval_every = e;
        self
    }

    pub fn eval_batches(mut self, e: usize) -> Self {
        self.spec.eval_batches = e;
        self
    }

    pub fn use_ns_artifact(mut self, on: bool) -> Self {
        self.spec.use_ns_artifact = on;
        self
    }

    pub fn full_codec(mut self, on: bool) -> Self {
        self.spec.full_codec = on;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = seed;
        self
    }

    pub fn log_path(mut self, p: impl Into<String>) -> Self {
        self.spec.log_path = Some(p.into());
        self
    }

    /// Drain round-phase trace events to this JSONL path.
    pub fn trace(mut self, p: impl Into<String>) -> Self {
        self.spec.trace_path = Some(p.into());
        self
    }

    /// Straggler / quorum / respawn policy (typed; validated at `build`).
    pub fn fault(mut self, p: FaultPolicy) -> Self {
        self.spec.fault = p;
        self
    }

    /// Save a checkpoint every `k` steps (0 = never).
    pub fn checkpoint_every(mut self, k: usize) -> Self {
        self.spec.checkpoint_every = k;
        self
    }

    /// Directory checkpoints are saved to / resumed from.
    pub fn checkpoint_dir(mut self, d: impl Into<String>) -> Self {
        self.spec.checkpoint_dir = Some(d.into());
        self
    }

    /// Resume from the latest checkpoint in `checkpoint_dir`.
    pub fn resume(mut self, on: bool) -> Self {
        self.spec.resume = on;
        self
    }

    /// Bounded-epoch shard scheduling (typed; validated at `build` —
    /// requires `shards >= 2`, and stealing requires the fault policy off).
    pub fn sched(mut self, s: SchedSpec) -> Self {
        self.spec.sched = s;
        self
    }

    /// Store `ParamBoard` epoch snapshots in bf16.
    pub fn snap_bf16(mut self, on: bool) -> Self {
        self.spec.snap_bf16 = on;
        self
    }

    /// Validate everything and return the spec, or *every* problem found.
    pub fn build(self) -> Result<RunSpec, SpecError> {
        let RunBuilder { spec, errors } = self;
        let mut err = SpecError { fields: errors };
        if spec.workers == 0 {
            err.push("workers", "must be >= 1 (got 0)");
        }
        if spec.shards == 0 {
            err.push(
                "shards",
                "must be >= 1 (got 0); use shards=1 for the single-leader deployment",
            );
        }
        if spec.steps == 0 {
            err.push("steps", "must be >= 1 (got 0)");
        }
        if spec.eval_every == 0 {
            err.push("eval_every", "must be >= 1 (got 0)");
        }
        if spec.eval_batches == 0 {
            err.push("eval_batches", "must be >= 1 (got 0)");
        }
        if spec.corpus_tokens == 0 {
            // the full bound (corpus >= workers * seq_len) needs the
            // manifest; reject the certain failure here, the rest at load
            err.push("corpus_tokens", "must be >= 1 (got 0)");
        }
        if !(0.0..=1.0).contains(&spec.schedule.min_lr_frac) {
            err.push(
                "min_lr_frac",
                format!("must be in [0, 1] (got {})", spec.schedule.min_lr_frac),
            );
        }
        if !spec.schedule.lr.is_finite() || spec.schedule.lr <= 0.0 {
            err.push("lr", format!("must be a finite positive radius (got {})", spec.schedule.lr));
        }
        if !(spec.beta > 0.0 && spec.beta <= 1.0) {
            err.push("beta", format!("momentum must be in (0, 1] (got {})", spec.beta));
        }
        if let Err(e) = spec.worker_comp.validate() {
            err.push("worker_comp", e);
        }
        if let Err(e) = spec.server_comp.validate() {
            err.push("server_comp", e);
        }
        if spec.round.lookahead() > RoundMode::MAX_LOOKAHEAD {
            err.push(
                "round_mode",
                format!("lookahead exceeds the max of {}", RoundMode::MAX_LOOKAHEAD),
            );
        }
        if let Err(e) = spec.fault.validate() {
            err.push("fault_policy", e);
        }
        if spec.checkpoint_every > 0 && spec.checkpoint_dir.is_none() {
            err.push(
                "checkpoint_every",
                "saving checkpoints requires checkpoint_dir",
            );
        }
        if spec.resume && spec.checkpoint_dir.is_none() {
            err.push("resume", "resuming requires checkpoint_dir");
        }
        if spec.trace_path.as_deref() == Some("") {
            err.push("trace_path", "must be a non-empty path (omit the key to disable tracing)");
        }
        if let Err(e) = spec.sched.validate() {
            err.push("sched", e);
        }
        if !spec.sched.is_off() && spec.shards < 2 {
            err.push(
                "sched",
                format!(
                    "a bounded-epoch window requires shards >= 2 (got {}); the \
                     single-leader deployment is always lock-step",
                    spec.shards
                ),
            );
        }
        if spec.sched.steal.is_some() && !spec.fault.is_off() {
            err.push(
                "sched",
                "work stealing requires fault_policy off (steal migration cannot \
                 coexist with straggler deadlines or respawns)",
            );
        }
        if spec.link.tcp_addr().is_some() && spec.shards != 1 {
            err.push(
                "transport",
                format!(
                    "transport tcp requires shards == 1 (got {}); sharded socket \
                     deployments are a ROADMAP item",
                    spec.shards
                ),
            );
        }
        if err.fields.is_empty() {
            Ok(spec)
        } else {
            Err(err)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_matches_default_config() {
        let from_cfg = RunBuilder::from_config(&TrainConfig::default()).build().unwrap();
        assert_eq!(from_cfg, RunSpec::default());
    }

    #[test]
    fn build_collects_every_error_with_field_paths() {
        let cfg = TrainConfig {
            workers: 0,
            steps: 0,
            eval_every: 0,
            min_lr_frac: 1.5,
            worker_comp: "top:0".into(),
            round_mode: "later".into(),
            ..TrainConfig::default()
        };
        let err = RunBuilder::from_config(&cfg).build().unwrap_err();
        for path in ["workers", "steps", "eval_every", "min_lr_frac", "worker_comp", "round_mode"] {
            assert!(err.mentions(path), "missing {path} in {err}");
        }
        let msg = err.to_string();
        assert!(msg.contains("workers: must be >= 1"), "{msg}");
        assert!(msg.contains("min_lr_frac: must be in [0, 1]"), "{msg}");
    }

    #[test]
    fn builder_setters_parse_strings_once() {
        let spec = RunBuilder::new()
            .workers(2)
            .steps(5)
            .worker_comp("top:0.3+nat")
            .server_comp(CompSpec::Natural)
            .round(RoundMode::Async { lookahead: 2 })
            .build()
            .unwrap();
        assert_eq!(spec.worker_comp, CompSpec::Top { frac: 0.3, nat: true });
        assert_eq!(spec.server_comp, CompSpec::Natural);
        let err = RunBuilder::new().worker_comp("bogus").build().unwrap_err();
        assert!(err.mentions("worker_comp"), "{err}");
    }

    #[test]
    fn geom_reproduces_legacy_radius_arithmetic() {
        let geom = GeomSpec::default();
        let g = geom.for_groups([Group::Hidden, Group::Embed, Group::Vector]);
        assert_eq!(g[0].lmo, LmoKind::Spectral);
        assert_eq!(g[0].radius_mult, 1.0);
        assert_eq!(g[1].lmo, LmoKind::SignLInf);
        assert_eq!(g[1].radius_mult, 1.0);
        assert_eq!(g[2].lmo, LmoKind::SignLInf);
        // the legacy formula: 0.1 (group base) * (vector_mult / 0.1)
        assert_eq!(g[2].radius_mult, 0.1 * (0.1 / 0.1));
        // overrides flow through
        let custom = GeomSpec { embed: LmoKind::Euclidean, embed_mult: 2.0, ..geom };
        let g = custom.for_groups([Group::Embed]);
        assert_eq!(g[0].lmo, LmoKind::Euclidean);
        assert_eq!(g[0].radius_mult, 2.0);
    }

    #[test]
    fn fault_and_checkpoint_fields_roundtrip_and_validate() {
        let spec = RunBuilder::new()
            .fault(FaultPolicy::parse("deadline:50,quorum:0.75,respawns:2,backoff:5").unwrap())
            .checkpoint_every(10)
            .checkpoint_dir("/tmp/ck")
            .build()
            .unwrap();
        let back = RunBuilder::from_config(&spec.to_train_config()).build().unwrap();
        assert_eq!(back, spec);
        // bad policy / orphan checkpoint knobs collect field-path errors
        let cfg = TrainConfig {
            fault_policy: "quorum:0.5".into(),
            checkpoint_every: 5,
            resume: true,
            ..TrainConfig::default()
        };
        let err = RunBuilder::from_config(&cfg).build().unwrap_err();
        for path in ["fault_policy", "checkpoint_every", "resume"] {
            assert!(err.mentions(path), "missing {path} in {err}");
        }
    }

    #[test]
    fn trace_path_roundtrips_and_validates() {
        let spec = RunBuilder::new().trace("/tmp/trace.jsonl").build().unwrap();
        assert_eq!(spec.trace_path.as_deref(), Some("/tmp/trace.jsonl"));
        let back = RunBuilder::from_config(&spec.to_train_config()).build().unwrap();
        assert_eq!(back, spec);
        let j = spec.to_json().to_string();
        assert!(j.contains("\"trace_path\""), "{j}");
        assert!(!RunSpec::default().to_json().to_string().contains("trace_path"));
        let err = RunBuilder::new().trace("").build().unwrap_err();
        assert!(err.mentions("trace_path"), "{err}");
    }

    #[test]
    fn schedule_kinds_materialize_bit_identical_to_legacy_literals() {
        // the rate benches used to hand-build these; RunBuilder must
        // reproduce them exactly (golden for the exp::rate_points reroute)
        for (kind, k) in [
            (ScheduleKind::InvSqrtTotal, 40usize),
            (ScheduleKind::Theory34, 120),
            (ScheduleKind::Constant, 7),
        ] {
            let spec = RunBuilder::new()
                .steps(k)
                .lr(0.05)
                .warmup(0)
                .min_lr_frac(1.0)
                .schedule_kind(kind)
                .build()
                .unwrap();
            let legacy = Schedule { base: 0.05, warmup: 0, total: k, min_frac: 1.0, kind };
            let got = spec.schedule();
            assert_eq!(got.base.to_bits(), legacy.base.to_bits());
            assert_eq!((got.warmup, got.total, got.kind), (legacy.warmup, legacy.total, kind));
            for step in 0..k {
                assert_eq!(
                    got.at(step).to_bits(),
                    legacy.at(step).to_bits(),
                    "{kind:?} step {step}"
                );
            }
        }
        // the default shape is untouched warmup-cosine
        let spec = RunSpec::default();
        assert_eq!(spec.schedule.kind, ScheduleKind::WarmupCosine);
        let legacy = Schedule::warmup_cosine(0.02, 20, spec.steps, 0.1);
        for step in [0, 10, 19, 20, 57, 199] {
            assert_eq!(spec.schedule().at(step).to_bits(), legacy.at(step).to_bits());
        }
    }

    #[test]
    fn schedule_and_transport_axes_roundtrip_losslessly() {
        for kind in [
            ScheduleKind::WarmupCosine,
            ScheduleKind::Constant,
            ScheduleKind::InvSqrtTotal,
            ScheduleKind::Theory34,
        ] {
            assert_eq!(parse_schedule_kind(schedule_kind_name(kind)).unwrap(), kind);
        }
        assert!(parse_schedule_kind("cosine?").is_err());
        for link in [LinkSpec::Channel, LinkSpec::Tcp("127.0.0.1:4310".into())] {
            assert_eq!(LinkSpec::parse(&link.spec()).unwrap(), link);
        }
        assert!(LinkSpec::parse("tcp:").is_err());
        assert!(LinkSpec::parse("udp:1.2.3.4:1").is_err());

        let spec = RunBuilder::new()
            .schedule_kind(ScheduleKind::Theory34)
            .link(LinkSpec::Tcp("127.0.0.1:4310".into()))
            .build()
            .unwrap();
        let back = RunBuilder::from_config(&spec.to_train_config()).build().unwrap();
        assert_eq!(back, spec);
        let back = RunSpec::from_json(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);
        // defaults stay out of the JSON so `efmuon config` bytes are stable
        let dflt = RunSpec::default().to_json().to_string();
        assert!(!dflt.contains("\"schedule\""), "{dflt}");
        assert!(!dflt.contains("\"transport\""), "{dflt}");
    }

    #[test]
    fn tcp_transport_rejects_sharded_deployments() {
        let err = RunBuilder::new()
            .shards(2)
            .link(LinkSpec::Tcp("127.0.0.1:4310".into()))
            .build()
            .unwrap_err();
        assert!(err.mentions("transport"), "{err}");
        assert!(err.to_string().contains("shards == 1"), "{err}");
    }

    #[test]
    fn sched_and_snap_bf16_roundtrip_losslessly() {
        let spec = RunBuilder::new()
            .shards(2)
            .sched(SchedSpec::parse("window:2,steal:1.5").unwrap())
            .snap_bf16(true)
            .build()
            .unwrap();
        let back = RunBuilder::from_config(&spec.to_train_config()).build().unwrap();
        assert_eq!(back, spec);
        let back = RunSpec::from_json(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);
        // defaults stay out of the JSON so `efmuon config` bytes are stable
        let dflt = RunSpec::default().to_json().to_string();
        assert!(!dflt.contains("\"sched\""), "{dflt}");
        assert!(!dflt.contains("snap_bf16"), "{dflt}");
    }

    #[test]
    fn sched_validation_pins_its_preconditions() {
        // a window needs a cluster to schedule
        let err = RunBuilder::new()
            .sched(SchedSpec::parse("window:1").unwrap())
            .build()
            .unwrap_err();
        assert!(err.mentions("sched"), "{err}");
        assert!(err.to_string().contains("shards >= 2"), "{err}");
        // stealing cannot coexist with the fault machinery
        let err = RunBuilder::new()
            .shards(2)
            .sched(SchedSpec::parse("window:1,steal:1.5").unwrap())
            .fault(FaultPolicy::parse("deadline:50,quorum:0.75,respawns:2,backoff:5").unwrap())
            .build()
            .unwrap_err();
        assert!(err.mentions("sched"), "{err}");
        assert!(err.to_string().contains("fault_policy off"), "{err}");
        // grammar errors arrive with the field path
        let cfg =
            TrainConfig { sched: "window:banana".into(), shards: 2, ..TrainConfig::default() };
        let err = RunBuilder::from_config(&cfg).build().unwrap_err();
        assert!(err.mentions("sched"), "{err}");
    }

    #[test]
    fn lmo_names_roundtrip() {
        for k in [
            LmoKind::Spectral,
            LmoKind::SignLInf,
            LmoKind::L1Top1,
            LmoKind::Euclidean,
            LmoKind::NuclearRank1,
            LmoKind::ColNorm,
        ] {
            assert_eq!(parse_lmo(lmo_name(k)).unwrap(), k);
        }
        assert!(parse_lmo("frobnicate").is_err());
    }
}
