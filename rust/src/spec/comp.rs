//! `CompSpec` — the parsed-once compressor descriptor.
//!
//! Every compressor the framework knows is described by one value of this
//! enum. The string grammar (`top:0.15+nat`, `rank:0.1`, …) is parsed
//! **exactly once** at a configuration boundary ([`CompSpec::parse`], the
//! `TrainConfig` facade, or the CLI); everything downstream — per-layer
//! compressor construction, the degenerate-shape fallback, sweep tables,
//! wire-format names — works on the typed value. The old
//! `opt::layer_compressors` re-parsed the same string once per layer and
//! rebuilt fallback specs by string splicing; both now live here as typed
//! logic ([`CompSpec::for_shape`], [`CompSpec::build_layers`]).

use crate::compress::{lowrank, natural, quantize, simple, sparse, Compressor};

/// A compressor descriptor: the typed form of one spec string.
///
/// Grammar (see [`CompSpec::parse`]):
///
/// ```text
/// spec := base ("+nat")?
/// base := "id" | "nat" | "sign" | "bf16" | "top:F" | "rank:F" | "drop:P"
///       | "damp:G" | "svdtop:K" | "coltop:F" | "randk:F" | "qsgd:L"
/// ```
///
/// `+nat` (Natural-quantized values) is supported for `top:`/`rank:` only.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompSpec {
    /// Identity (no compression).
    Id,
    /// Natural compression: exact powers of two, 9 bits per value.
    Natural,
    /// Scaled sign (1-bit SGD).
    Sign,
    /// TopK by magnitude at fraction `frac` of the entries.
    Top { frac: f64, nat: bool },
    /// Low-rank power-iteration sketch at rank fraction `frac`.
    Rank { frac: f64, nat: bool },
    /// Random dropout with keep-probability `keep`.
    Drop { keep: f64 },
    /// Deterministic damping by factor `gamma`.
    Damp { gamma: f32 },
    /// Exact truncated SVD at integer rank `k`.
    SvdTop { k: usize },
    /// Column-wise TopK at fraction `frac` of the columns.
    ColTop { frac: f64 },
    /// Uniform-random K at fraction `frac` of the entries.
    RandK { frac: f64 },
    /// QSGD uniform quantization at `levels` levels.
    Qsgd { levels: u8 },
    /// bf16 round-to-nearest-even cast: half the f32 bytes, relative error
    /// ≤ 2⁻⁸ per entry (the snapshot/broadcast wire format).
    Bf16,
}

impl CompSpec {
    /// Parse a spec string. The grammar and every validation rule are
    /// identical to what `compress::parse_spec` historically enforced
    /// (`compress::parse_spec` now delegates here), so error strings and
    /// accepted inputs are unchanged.
    pub fn parse(spec: &str) -> Result<CompSpec, String> {
        let (base, nat) = match spec.strip_suffix("+nat") {
            Some(b) => (b, true),
            None => (spec, false),
        };
        let mk_err = |m: &str| format!("bad compressor spec {spec:?}: {m}");
        let parse_f = |s: &str| -> Result<f64, String> {
            s.parse::<f64>().map_err(|_| mk_err("expected a number"))
        };
        let frac_in_unit = |s: &str, what: &str| -> Result<f64, String> {
            let frac = parse_f(s)?;
            if !(0.0..=1.0).contains(&frac) || frac == 0.0 {
                return Err(mk_err(&format!("{what} fraction must be in (0,1]")));
            }
            Ok(frac)
        };
        let parsed = match base.split_once(':') {
            None => match base {
                // "id+nat" degrades to Natural, as the legacy parser did
                "id" if nat => return Ok(CompSpec::Natural),
                "id" => CompSpec::Id,
                "nat" => CompSpec::Natural,
                "sign" => CompSpec::Sign,
                "bf16" => CompSpec::Bf16,
                _ => return Err(mk_err("unknown compressor")),
            },
            Some(("top", f)) => CompSpec::Top { frac: frac_in_unit(f, "top")?, nat },
            Some(("rank", f)) => CompSpec::Rank { frac: frac_in_unit(f, "rank")?, nat },
            Some(("drop", p)) => CompSpec::Drop { keep: parse_f(p)? },
            Some(("damp", g)) => CompSpec::Damp { gamma: parse_f(g)? as f32 },
            Some(("svdtop", k)) => CompSpec::SvdTop {
                k: k.parse().map_err(|_| mk_err("expected integer rank"))?,
            },
            Some(("coltop", f)) => CompSpec::ColTop { frac: parse_f(f)? },
            Some(("randk", f)) => CompSpec::RandK { frac: frac_in_unit(f, "randk")? },
            Some(("qsgd", l)) => {
                let levels: u8 = l.parse().map_err(|_| mk_err("expected integer levels"))?;
                if levels == 0 {
                    return Err(mk_err("qsgd levels must be >= 1"));
                }
                CompSpec::Qsgd { levels }
            }
            Some((_, _)) => return Err(mk_err("unknown compressor")),
        };
        if nat && !matches!(parsed, CompSpec::Top { .. } | CompSpec::Rank { .. }) {
            return Err(mk_err("+nat is supported for top:/rank: only"));
        }
        Ok(parsed)
    }

    /// The canonical spec string. Round-trips:
    /// `CompSpec::parse(s.spec()) == Ok(s)`, and the built compressor's
    /// `name()` equals `spec()`.
    pub fn spec(&self) -> String {
        match *self {
            CompSpec::Id => "id".into(),
            CompSpec::Natural => "nat".into(),
            CompSpec::Sign => "sign".into(),
            CompSpec::Top { frac, nat } => {
                format!("top:{frac}{}", if nat { "+nat" } else { "" })
            }
            CompSpec::Rank { frac, nat } => {
                format!("rank:{frac}{}", if nat { "+nat" } else { "" })
            }
            CompSpec::Drop { keep } => format!("drop:{keep}"),
            CompSpec::Damp { gamma } => format!("damp:{gamma}"),
            CompSpec::SvdTop { k } => format!("svdtop:{k}"),
            CompSpec::ColTop { frac } => format!("coltop:{frac}"),
            CompSpec::RandK { frac } => format!("randk:{frac}"),
            CompSpec::Qsgd { levels } => format!("qsgd:{levels}"),
            CompSpec::Bf16 => "bf16".into(),
        }
    }

    /// Validate the descriptor's numeric ranges. [`CompSpec::parse`] output
    /// is always valid; this guards descriptors constructed directly (the
    /// variants are public so sweep tables can be `const`).
    pub fn validate(&self) -> Result<(), String> {
        let unit = |frac: f64, what: &str| -> Result<(), String> {
            if !(0.0..=1.0).contains(&frac) || frac == 0.0 {
                return Err(format!("{what} fraction must be in (0,1] (got {frac})"));
            }
            Ok(())
        };
        match *self {
            CompSpec::Id | CompSpec::Natural | CompSpec::Sign | CompSpec::Bf16 => Ok(()),
            CompSpec::Top { frac, nat: _ } => unit(frac, "top"),
            CompSpec::Rank { frac, nat: _ } => unit(frac, "rank"),
            CompSpec::RandK { frac } => unit(frac, "randk"),
            CompSpec::Drop { keep } => {
                if !(0.0..=1.0).contains(&keep) {
                    return Err(format!("drop keep-probability must be in [0,1] (got {keep})"));
                }
                Ok(())
            }
            CompSpec::Damp { gamma } => {
                if !gamma.is_finite() {
                    return Err(format!("damp factor must be finite (got {gamma})"));
                }
                Ok(())
            }
            CompSpec::SvdTop { k } => {
                if k == 0 {
                    return Err("svdtop rank must be >= 1".into());
                }
                Ok(())
            }
            CompSpec::ColTop { frac } => {
                if !frac.is_finite() || frac <= 0.0 {
                    return Err(format!("coltop fraction must be > 0 (got {frac})"));
                }
                Ok(())
            }
            CompSpec::Qsgd { levels } => {
                if levels == 0 {
                    return Err("qsgd levels must be >= 1".into());
                }
                Ok(())
            }
        }
    }

    /// True for the identity descriptor (hot paths skip work on it).
    pub fn is_identity(&self) -> bool {
        matches!(self, CompSpec::Id)
    }

    /// The descriptor actually deployed on a layer of shape `(rows, cols)`:
    /// RankK on an effectively-1D layer (LayerNorm gain, single row/column)
    /// is no cheaper than dense, so those layers fall back to TopK at the
    /// same fraction — mirroring how the paper's DDP implementation only
    /// low-ranks genuine matrices. This is the typed replacement for the
    /// old `trim_start_matches`/`trim_end_matches` string splicing in
    /// `opt::layer_compressors`.
    pub fn for_shape(&self, rows: usize, cols: usize) -> CompSpec {
        if let CompSpec::Rank { frac, nat } = *self {
            if rows.min(cols) <= 2 {
                return CompSpec::Top { frac, nat };
            }
        }
        *self
    }

    /// Build one compressor instance (no string round-trip).
    pub fn build(&self) -> Box<dyn Compressor> {
        match *self {
            CompSpec::Id => Box::new(simple::Identity),
            CompSpec::Natural => Box::new(natural::NaturalCompressor::new()),
            CompSpec::Sign => Box::new(quantize::ScaledSign),
            CompSpec::Top { frac, nat } => Box::new(sparse::TopK::new(frac, nat)),
            CompSpec::Rank { frac, nat } => Box::new(lowrank::RankK::new(frac, nat)),
            CompSpec::Drop { keep } => Box::new(simple::RandomDropout::new(keep)),
            CompSpec::Damp { gamma } => Box::new(simple::Damping::new(gamma)),
            CompSpec::SvdTop { k } => Box::new(lowrank::SvdTopK::new(k)),
            CompSpec::ColTop { frac } => Box::new(sparse::ColTopK::new(frac)),
            CompSpec::RandK { frac } => Box::new(sparse::RandK::new(frac)),
            CompSpec::Qsgd { levels } => Box::new(quantize::Qsgd::new(levels)),
            CompSpec::Bf16 => Box::new(quantize::Bf16Cast),
        }
    }

    /// Build one compressor per layer, applying the degenerate-shape
    /// fallback ([`CompSpec::for_shape`]). The descriptor is parsed zero
    /// times here — it already is the parse.
    pub fn build_layers(&self, shapes: &[(usize, usize)]) -> Vec<Box<dyn Compressor>> {
        shapes.iter().map(|&(m, n)| self.for_shape(m, n).build()).collect()
    }
}

impl std::fmt::Display for CompSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.spec())
    }
}

impl std::str::FromStr for CompSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        CompSpec::parse(s)
    }
}

/// A compressor descriptor at an API boundary: either an already-typed
/// [`CompSpec`] (passed through untouched) or a spec string, parsed exactly
/// once *here* — so constructors like `Ef21MuonSeq::new` accept both typed
/// descriptors and the familiar string literals without any call site
/// re-implementing the grammar.
pub trait IntoCompSpec {
    fn into_comp_spec(self) -> Result<CompSpec, String>;
}

impl IntoCompSpec for CompSpec {
    fn into_comp_spec(self) -> Result<CompSpec, String> {
        Ok(self)
    }
}

impl IntoCompSpec for &CompSpec {
    fn into_comp_spec(self) -> Result<CompSpec, String> {
        Ok(*self)
    }
}

impl IntoCompSpec for &str {
    fn into_comp_spec(self) -> Result<CompSpec, String> {
        CompSpec::parse(self)
    }
}

impl IntoCompSpec for &String {
    fn into_comp_spec(self) -> Result<CompSpec, String> {
        CompSpec::parse(self)
    }
}

impl IntoCompSpec for String {
    fn into_comp_spec(self) -> Result<CompSpec, String> {
        CompSpec::parse(&self)
    }
}

// ---------------------------------------------------------------------------
// The paper's sweep tables — typed, const, and the single source of truth
// for `exp::{table2_rows, figure_sweep, s2w_savings}` and the benches, so
// the sweeps can never drift from what the train path accepts.
// ---------------------------------------------------------------------------

/// The compressor configurations evaluated in the paper's Table 2 /
/// Figures 1–2 (compression levels as reported there).
pub const PAPER_COMPRESSOR_SPECS: &[CompSpec] = &[
    CompSpec::Id,
    CompSpec::Natural,
    CompSpec::Rank { frac: 0.2, nat: false },
    CompSpec::Rank { frac: 0.15, nat: false },
    CompSpec::Rank { frac: 0.15, nat: true },
    CompSpec::Rank { frac: 0.1, nat: false },
    CompSpec::Rank { frac: 0.1, nat: true },
    CompSpec::Rank { frac: 0.05, nat: false },
    CompSpec::Top { frac: 0.2, nat: false },
    CompSpec::Top { frac: 0.15, nat: false },
    CompSpec::Top { frac: 0.15, nat: true },
    CompSpec::Top { frac: 0.1, nat: false },
    CompSpec::Top { frac: 0.1, nat: true },
    CompSpec::Top { frac: 0.05, nat: false },
];

/// The compact default sweep for the figures (most competitive configs, as
/// Figure 1 does).
pub const FIGURE_SPECS: &[CompSpec] = &[
    CompSpec::Id,
    CompSpec::Natural,
    CompSpec::Top { frac: 0.15, nat: false },
    CompSpec::Top { frac: 0.15, nat: true },
    CompSpec::Rank { frac: 0.15, nat: false },
    CompSpec::Rank { frac: 0.15, nat: true },
];

/// Server-compressor specs worth sweeping for the s2w direction.
pub const S2W_SPECS: &[CompSpec] = &[
    CompSpec::Id,
    CompSpec::Natural,
    CompSpec::Top { frac: 0.5, nat: false },
    CompSpec::Top { frac: 0.25, nat: false },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_build_name_roundtrip() {
        for s in ["id", "nat", "top:0.15", "top:0.1+nat", "rank:0.2",
                  "rank:0.05+nat", "drop:0.5", "damp:0.8", "svdtop:3",
                  "coltop:0.25", "sign", "qsgd:4", "randk:0.3", "bf16"] {
            let c = CompSpec::parse(s).unwrap();
            assert_eq!(c.spec(), s, "spec() roundtrip for {s}");
            assert_eq!(CompSpec::parse(&c.spec()).unwrap(), c, "parse(spec()) for {s}");
            assert_eq!(c.build().name(), s, "built name for {s}");
            c.validate().unwrap();
        }
    }

    #[test]
    fn parse_rejects_what_the_legacy_grammar_rejected() {
        for s in ["", "bogus", "top:0", "top:1.5", "top:x", "drop:", "nat+nat",
                  "qsgd:0", "randk:0", "sign+nat", "rank:0", "rank:-0.1",
                  "bf16+nat", "bf16:2"] {
            assert!(CompSpec::parse(s).is_err(), "{s} should fail");
        }
        // legacy quirk preserved: "id+nat" degrades to Natural
        assert_eq!(CompSpec::parse("id+nat").unwrap(), CompSpec::Natural);
    }

    #[test]
    fn validate_catches_hand_built_descriptors() {
        assert!(CompSpec::Top { frac: 0.0, nat: false }.validate().is_err());
        assert!(CompSpec::Rank { frac: 1.5, nat: true }.validate().is_err());
        assert!(CompSpec::Qsgd { levels: 0 }.validate().is_err());
        assert!(CompSpec::SvdTop { k: 0 }.validate().is_err());
        assert!(CompSpec::Drop { keep: -0.1 }.validate().is_err());
        CompSpec::Top { frac: 1.0, nat: true }.validate().unwrap();
    }

    #[test]
    fn compressor_fallback_for_vectors() {
        // the exact semantics the old `opt::layer_compressors` string
        // surgery implemented, now typed: RankK degrades to TopK at the
        // same fraction (and the same +nat) on effectively-1D layers
        let shapes = vec![(64, 64), (64, 1)];
        let cs = CompSpec::parse("rank:0.1+nat").unwrap().build_layers(&shapes);
        assert_eq!(cs[0].name(), "rank:0.1+nat");
        assert_eq!(cs[1].name(), "top:0.1+nat");
        let cs = CompSpec::parse("top:0.2").unwrap().build_layers(&shapes);
        assert_eq!(cs[1].name(), "top:0.2");
        // the fallback is shape-local, not spec-global
        let r = CompSpec::Rank { frac: 0.3, nat: false };
        assert_eq!(r.for_shape(2, 64), CompSpec::Top { frac: 0.3, nat: false });
        assert_eq!(r.for_shape(3, 64), r);
    }

    #[test]
    fn sweep_tables_are_valid_and_roundtrip() {
        for table in [PAPER_COMPRESSOR_SPECS, FIGURE_SPECS, S2W_SPECS] {
            for c in table {
                c.validate().unwrap();
                assert_eq!(CompSpec::parse(&c.spec()).unwrap(), *c);
                assert_eq!(c.build().name(), c.spec());
            }
        }
        assert_eq!(PAPER_COMPRESSOR_SPECS.len(), 14);
    }

    #[test]
    fn into_comp_spec_boundary() {
        fn take(x: impl IntoCompSpec) -> Result<CompSpec, String> {
            x.into_comp_spec()
        }
        assert_eq!(take("top:0.3").unwrap(), CompSpec::Top { frac: 0.3, nat: false });
        assert_eq!(take(CompSpec::Id).unwrap(), CompSpec::Id);
        assert_eq!(take(&CompSpec::Sign).unwrap(), CompSpec::Sign);
        assert_eq!(take(&format!("rank:{}", 0.2)).unwrap(), CompSpec::Rank { frac: 0.2, nat: false });
        assert!(take("bogus").is_err());
    }
}
