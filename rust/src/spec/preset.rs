//! Named presets pinning the paper's algorithm family members.
//!
//! EF21-Muon is one algorithm parameterized by (per-layer LMO norm,
//! w2s/s2w compressor pair, momentum, schedule). The paper's recovery
//! claims — *with compression off and specific norm choices, the method IS
//! Muon / Scion / Gluon* — become first-class here: each [`Preset`] is a
//! full [`RunSpec`] whose descriptor set pins exactly one family member,
//! and `rust/tests/spec_api.rs` golden-tests every preset against the
//! legacy string configuration it corresponds to (descriptor equality plus
//! bit-identical first training steps).
//!
//! | preset     | w2s comp      | s2w comp | β    | hidden   | embed    | vector   |
//! |------------|---------------|----------|------|----------|----------|----------|
//! | `muon`     | id            | id       | 0.95 | spectral | spectral | spectral |
//! | `scion`    | id            | id       | 0.9  | spectral | sign     | sign     |
//! | `gluon`    | id            | id       | 1.0  | spectral | sign     | sign     |
//! | `ef21-muon`| rank:0.15+nat | id       | 0.9  | spectral | sign     | sign     |
//! | `ef21-p`   | rank:0.15+nat | top:0.25 | 0.9  | spectral | sign     | sign     |
//!
//! Rationale: Muon orthogonalizes the momentum of every matrix it touches —
//! all-spectral norms with its canonical β = 0.95. Scion (Pethick et al.)
//! is the fully LMO-based deployment: spectral hidden layers plus
//! ℓ∞-scaled embeddings/gains — the paper's (and this repo's) default
//! assignment. Gluon is the general layer-wise LMO framework; its
//! deterministic Algorithm-2 form is β = 1 (the `opt::ef21` reduction test
//! pins exactly this). `ef21-muon` adds the paper's headline w2s compressor
//! (RankK 0.15 + Natural, the ~7× savings config); `ef21-p` additionally
//! compresses the broadcast (bidirectional error feedback).

use crate::config::TrainConfig;
use crate::lmo::LmoKind;

use super::comp::CompSpec;
use super::run::{GeomSpec, RunSpec};

/// A named algorithm-family member (see the module table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// Muon: all-spectral norms, momentum 0.95, no compression.
    Muon,
    /// Scion: spectral hidden + ℓ∞ embeddings/gains, no compression.
    Scion,
    /// Gluon: the general layer-wise form, deterministic (β = 1).
    Gluon,
    /// EF21-Muon: Scion geometry + the paper's RankK+Natural w2s compressor.
    Ef21Muon,
    /// EF21-P: EF21-Muon + a compressed (TopK) s2w broadcast.
    Ef21P,
}

impl Preset {
    pub const ALL: [Preset; 5] =
        [Preset::Muon, Preset::Scion, Preset::Gluon, Preset::Ef21Muon, Preset::Ef21P];

    /// Canonical name (round-trips through [`Preset::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Preset::Muon => "muon",
            Preset::Scion => "scion",
            Preset::Gluon => "gluon",
            Preset::Ef21Muon => "ef21-muon",
            Preset::Ef21P => "ef21-p",
        }
    }

    pub fn parse(s: &str) -> Result<Preset, String> {
        match s {
            "muon" => Ok(Preset::Muon),
            "scion" => Ok(Preset::Scion),
            "gluon" => Ok(Preset::Gluon),
            "ef21-muon" => Ok(Preset::Ef21Muon),
            "ef21-p" => Ok(Preset::Ef21P),
            other => Err(format!(
                "unknown preset {other:?} (expected muon | scion | gluon | ef21-muon | ef21-p)"
            )),
        }
    }

    /// The pinned run description. Everything not named in the module table
    /// (schedule, workers, eval cadence, …) keeps the [`RunSpec::default`]
    /// values, so presets compose with builder overrides:
    /// `RunBuilder::preset(Preset::Ef21P).steps(50).build()`.
    pub fn spec(self) -> RunSpec {
        let base = RunSpec::default();
        match self {
            Preset::Muon => RunSpec {
                beta: 0.95,
                geom: GeomSpec {
                    hidden: LmoKind::Spectral,
                    embed: LmoKind::Spectral,
                    vector: LmoKind::Spectral,
                    embed_mult: 1.0,
                    vector_mult: 0.1,
                },
                ..base
            },
            // the repo default *is* the Scion assignment
            Preset::Scion => base,
            Preset::Gluon => RunSpec { beta: 1.0, ..base },
            Preset::Ef21Muon => RunSpec {
                worker_comp: CompSpec::Rank { frac: 0.15, nat: true },
                ..base
            },
            Preset::Ef21P => RunSpec {
                worker_comp: CompSpec::Rank { frac: 0.15, nat: true },
                server_comp: CompSpec::Top { frac: 0.25, nat: false },
                ..base
            },
        }
    }

    /// The legacy string configuration this preset pins — what a user would
    /// have written before the typed API existed. The golden tests assert
    /// `RunBuilder::from_config(&p.legacy_config()).build() == p.spec()`
    /// and that both drive bit-identical training steps.
    pub fn legacy_config(self) -> TrainConfig {
        self.spec().to_train_config()
    }
}

impl std::fmt::Display for Preset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_names_roundtrip() {
        for p in Preset::ALL {
            assert_eq!(Preset::parse(p.name()).unwrap(), p);
        }
        assert!(Preset::parse("adamw").is_err());
    }

    #[test]
    fn presets_pin_the_module_table() {
        assert_eq!(Preset::Muon.spec().beta, 0.95);
        assert_eq!(Preset::Muon.spec().geom.embed, LmoKind::Spectral);
        assert_eq!(Preset::Scion.spec(), RunSpec::default());
        assert_eq!(Preset::Gluon.spec().beta, 1.0);
        assert_eq!(
            Preset::Ef21Muon.spec().worker_comp,
            CompSpec::Rank { frac: 0.15, nat: true }
        );
        assert!(Preset::Ef21Muon.spec().server_comp.is_identity());
        assert_eq!(
            Preset::Ef21P.spec().server_comp,
            CompSpec::Top { frac: 0.25, nat: false }
        );
        // compression off for the three recovered baselines
        for p in [Preset::Muon, Preset::Scion, Preset::Gluon] {
            assert!(p.spec().worker_comp.is_identity(), "{p}");
            assert!(p.spec().server_comp.is_identity(), "{p}");
        }
    }
}
