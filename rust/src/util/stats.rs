//! Small statistics helpers used by the bench harness and rate fitting.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (copies + sorts). NaN samples sort to the high end under
/// `total_cmp` instead of aborting the run — a single poisoned timing
/// sample must not panic the bench harness (DESIGN.md §Non-finite values
/// policy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread, used by the bench harness).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Ordinary least squares fit `y = a + b x`; returns `(a, b, r2)`.
///
/// Used to fit `log(error) = a + b log(k)` when validating the paper's
/// O(K^-1/2) / O(K^-1/4) rates (Table 1).
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| {
            let e = yi - (a + b * xi);
            e * e
        })
        .sum();
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    let _ = n;
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_survives_nan_samples() {
        // regression: partial_cmp().unwrap() aborted on the first NaN pair
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        // NaN sorts last under total_cmp: [1, 2, 3, NaN] -> 0.5*(2+3)
        assert_eq!(median(&xs), 2.5);
        assert!(median(&[f64::NAN]).is_nan());
        // mad: median [1, 1, NaN] = 1, deviations [0, 0, NaN] -> median 0
        assert_eq!(mad(&[1.0, f64::NAN, 1.0]), 0.0);
    }

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(mean(&xs), 22.0);
        assert_eq!(median(&xs), 3.0);
        assert!(mad(&xs) <= 2.0);
    }

    #[test]
    fn fit_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linfit(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fit_rate() {
        // y = C k^{-1/2}  => log y = log C - 0.5 log k
        let ks = [10.0, 100.0, 1000.0, 10000.0];
        let x: Vec<f64> = ks.iter().map(|k: &f64| k.ln()).collect();
        let y: Vec<f64> = ks.iter().map(|k| (3.0 * k.powf(-0.5f64)).ln()).collect();
        let (_, slope, r2) = linfit(&x, &y);
        assert!((slope + 0.5).abs() < 1e-9);
        assert!(r2 > 0.999);
    }
}
