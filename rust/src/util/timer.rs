//! Wall-clock timing + a minimal bench harness (criterion is unavailable
//! offline). `cargo bench` targets use `harness = false` and drive
//! [`bench_fn`] directly, reporting median ± MAD.

use std::time::Instant;

use super::stats;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
    pub fn micros(&self) -> f64 {
        self.seconds() * 1e6
    }
}

/// Timing summary over repeated runs.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} /iter (±{}, min {}, n={})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mad_s),
            fmt_time(self.min_s),
            self.iters
        )
    }
}

/// Human time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with warmup then `iters` timed repetitions; report median/MAD
/// over the outlier-trimmed samples (see [`summarize`]).
pub fn bench_fn(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    summarize(name, &samples)
}

/// Summarize raw timing samples: samples beyond `median + 3·MAD` —
/// scheduler hiccups (preemption, page faults, turbo transitions), not the
/// code under test — are discarded before the median/MAD are computed, so
/// the reported cost describes the steady state. `min_s` stays the raw
/// minimum and `iters` the raw sample count. When the MAD is 0 (over half
/// the samples tie) nothing is trimmed.
pub fn summarize(name: &str, samples: &[f64]) -> BenchResult {
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let med = stats::median(samples);
    let mad = stats::mad(samples);
    let kept: Vec<f64> = if mad > 0.0 {
        samples.iter().cloned().filter(|&s| s <= med + 3.0 * mad).collect()
    } else {
        samples.to_vec()
    };
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_s: stats::median(&kept),
        mad_s: stats::mad(&kept),
        min_s: min,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_smoke() {
        let r = bench_fn("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert!(r.median_s >= 0.0);
        assert!(!r.report().is_empty());
    }

    #[test]
    fn summarize_trims_scheduler_hiccups() {
        // one 50x outlier among tight samples must not drag the MAD; the
        // raw minimum and sample count survive untouched
        let samples = [1.0, 1.1, 0.9, 1.05, 0.95, 50.0];
        let r = summarize("trim", &samples);
        assert_eq!(r.iters, 6);
        assert_eq!(r.min_s, 0.9);
        assert!(r.median_s < 1.2, "outlier excluded from the median: {}", r.median_s);
        assert!(r.mad_s < 0.2, "outlier excluded from the MAD: {}", r.mad_s);
        // all-equal samples: MAD 0, nothing trimmed
        let r = summarize("flat", &[2.0, 2.0, 2.0]);
        assert_eq!(r.median_s, 2.0);
        assert_eq!(r.mad_s, 0.0);
    }

    #[test]
    fn fmt() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
    }
}
