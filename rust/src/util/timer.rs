//! Wall-clock timing + a minimal bench harness (criterion is unavailable
//! offline). `cargo bench` targets use `harness = false` and drive
//! [`bench_fn`] directly, reporting median ± MAD.

use std::time::Instant;

use super::stats;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
    pub fn micros(&self) -> f64 {
        self.seconds() * 1e6
    }
}

/// Timing summary over repeated runs.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} /iter (±{}, min {}, n={})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mad_s),
            fmt_time(self.min_s),
            self.iters
        )
    }
}

/// Human time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with warmup then `iters` timed repetitions; report median/MAD.
pub fn bench_fn(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters,
        median_s: stats::median(&samples),
        mad_s: stats::mad(&samples),
        min_s: min,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_smoke() {
        let r = bench_fn("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert!(r.median_s >= 0.0);
        assert!(!r.report().is_empty());
    }

    #[test]
    fn fmt() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
    }
}
