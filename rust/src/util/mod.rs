//! Shared utilities: deterministic RNG, JSON, CLI parsing, timing, stats,
//! and a lightweight property-testing harness (crates.io is unavailable in
//! this build environment, so these substrates are built in-tree).

pub mod rng;
pub mod json;
pub mod cli;
pub mod stats;
pub mod timer;
pub mod threads;
pub mod proptest;
