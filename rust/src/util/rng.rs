//! Deterministic PCG-XSH-RR 64/32 random number generator.
//!
//! All stochasticity in the system (data sampling, stochastic compressors,
//! synthetic gradient noise, randomized range-finders) flows through this
//! type so every experiment is exactly reproducible from a `u64` seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014) with Weyl-sequence stream selection.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal sample from Box–Muller
    spare: Option<f64>,
}

const MUL: u64 = 6364136223846793005;

impl Rng {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seeded generator on a specific stream (distinct streams never
    /// collide; used to give each worker an independent generator).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1, spare: None };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a child generator (e.g. per worker / per step).
    pub fn split(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Rng::with_stream(seed, tag.wrapping_add(1))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from unnormalized weights (linear scan; fine for vocab-size).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::with_stream(1, 1);
        let mut b = Rng::with_stream(1, 2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
