//! Hot-path thread-count policy, shared by the threaded matmul kernel and
//! the per-layer LMO fan-out. Resolution order: programmatic override
//! ([`set_threads`]) > `EFMUON_THREADS` env var > detected core count.
//!
//! The parallel kernels are bit-deterministic in the thread count (each
//! output row is reduced by exactly one thread in a fixed order), so this
//! knob trades wall-clock only — never results.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = no override.
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);
/// 0 = not yet detected.
static DETECTED: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Set while this thread is one lane of an efmuon fan-out (e.g. the
    /// per-layer LMO pass); nested kernels then stay single-threaded
    /// instead of oversubscribing nt × nt OS threads.
    static IN_FANOUT: Cell<bool> = Cell::new(false);
}

/// `true` when the current thread is already a parallel-fan-out lane.
pub fn in_parallel_region() -> bool {
    IN_FANOUT.with(|c| c.get())
}

/// Run `f` with this thread marked as a fan-out lane (auto-threaded
/// kernels inside run single-threaded).
pub fn mark_parallel_region<R>(f: impl FnOnce() -> R) -> R {
    IN_FANOUT.with(|c| c.set(true));
    let out = f();
    IN_FANOUT.with(|c| c.set(false));
    out
}

/// Number of worker threads hot-path kernels may fan out to (≥ 1).
pub fn num_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    let d = DETECTED.load(Ordering::Relaxed);
    if d > 0 {
        return d;
    }
    let n = std::env::var("EFMUON_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    DETECTED.store(n, Ordering::Relaxed);
    n
}

/// Override the thread count process-wide (`0` restores auto-detection).
/// Used by benches to pin single-thread baselines.
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn override_roundtrip() {
        // NOTE: process-global; keep all assertions in one test.
        let auto = num_threads();
        assert!(auto >= 1);
        set_threads(3);
        assert_eq!(num_threads(), 3);
        set_threads(0);
        assert_eq!(num_threads(), auto);
    }
}
