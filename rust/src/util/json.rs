//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by the artifact manifest, training
//! configs and metrics logging: objects, arrays, strings (with escapes),
//! numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys separated by '.'.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

// -- builder helpers -------------------------------------------------------

/// Ordered-key JSON object builder for serialization.
#[derive(Default)]
pub struct JsonObj(Vec<(String, Json)>);

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn put(mut self, k: &str, v: impl Into<Json>) -> Self {
        self.0.push((k.to_string(), v.into()));
        self
    }
    pub fn build(self) -> Json {
        Json::Obj(self.0.into_iter().collect())
    }
    /// Serialize directly with insertion order preserved (one line).
    pub fn to_line(&self) -> String {
        let mut s = String::from("{");
        for (i, (k, v)) in self.0.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{}:{}", quote(k), v));
        }
        s.push('}');
        s
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Self {
        Json::Arr(x)
    }
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write!(f, "{}", quote(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", quote(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

// -- parser ----------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of unescaped bytes
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("c.d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn manifest_like() {
        let src = r#"{"layers":[{"name":"wte","shape":[256,64],"group":"embed"}]}"#;
        let v = Json::parse(src).unwrap();
        let layers = v.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].get("name").unwrap().as_str(), Some("wte"));
        assert_eq!(
            layers[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(64)
        );
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("07a").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn builder() {
        let o = JsonObj::new().put("step", 3usize).put("loss", 1.5f64);
        assert_eq!(o.to_line(), "{\"step\":3,\"loss\":1.5}");
    }
}
