//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults; unknown-flag detection.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = iter.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(stripped.to_string(), v);
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { positional, flags, seen: Default::default() }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn note(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.note(key);
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.note(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.note(key);
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.note(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.note(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.note(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.note(key);
        self.flags
            .get(key)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// List of flags given on the command line that were never queried —
    /// almost certainly typos; callers print these as warnings.
    pub fn unknown(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parse_forms() {
        let a = args("train --steps 100 --lr=0.05 --verbose --name run-1 pos1");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.usize("steps", 0), 100);
        assert_eq!(a.f64("lr", 0.0), 0.05);
        assert!(a.bool("verbose", false));
        assert_eq!(a.str("name", ""), "run-1");
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn unknown_flags() {
        let a = args("--steps 10 --typo 3");
        let _ = a.usize("steps", 0);
        assert_eq!(a.unknown(), vec!["typo".to_string()]);
    }
}
