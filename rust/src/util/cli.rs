//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults; unknown-flag detection.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags given with no value (`--resume`, or a value-taking flag left
    /// dangling as the last argument). Numeric getters on these return the
    /// usage error "expects a value" instead of trying to parse `"true"`.
    bare: BTreeSet<String>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut bare = BTreeSet::new();
        let mut it = iter.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    bare.remove(k);
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    bare.remove(stripped);
                    flags.insert(stripped.to_string(), v);
                } else {
                    bare.insert(stripped.to_string());
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Args { positional, flags, bare, seen: Default::default() }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn note(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.note(key);
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.note(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.note(key);
        self.flags.get(key).cloned()
    }

    /// Shared typed-getter core: missing flag → default; a bare flag
    /// (`efmuon train --lr`, value-taking flag as last argument) or an
    /// unparsable value → the clean usage `Err` the entry points print,
    /// never a panic.
    fn numeric<T: std::str::FromStr>(&self, key: &str, default: T, kind: &str) -> Result<T, String> {
        self.note(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(_) if self.bare.contains(key) => {
                Err(format!("--{key} expects {kind}, but no value was given"))
            }
            Some(v) => v.parse().map_err(|_| format!("--{key} expects {kind}, got {v:?}")),
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize, String> {
        self.numeric(key, default, "an integer")
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64, String> {
        self.numeric(key, default, "an integer")
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64, String> {
        self.numeric(key, default, "a number")
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.note(key);
        self.flags
            .get(key)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// List of flags given on the command line that were never queried —
    /// almost certainly typos; callers print these as warnings.
    pub fn unknown(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parse_forms() {
        let a = args("train --steps 100 --lr=0.05 --verbose --name run-1 pos1");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert_eq!(a.f64("lr", 0.0).unwrap(), 0.05);
        assert!(a.bool("verbose", false));
        assert_eq!(a.str("name", ""), "run-1");
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_flags() {
        let a = args("--steps 10 --typo 3");
        let _ = a.usize("steps", 0);
        assert_eq!(a.unknown(), vec!["typo".to_string()]);
    }

    #[test]
    fn dangling_value_flag_is_a_clean_error() {
        // regression: `efmuon train --lr` stored "true" for --lr and the
        // numeric getter panicked trying to parse it; it must surface the
        // usage error the entry points print instead
        let a = args("train --lr");
        let err = a.f64("lr", 0.0).unwrap_err();
        assert!(err.contains("--lr") && err.contains("no value"), "{err}");
        // a later occurrence with a value rehabilitates the flag
        let a = args("--seed --seed 9");
        assert_eq!(a.u64("seed", 0).unwrap(), 9);
        // unparsable values are clean errors too, naming flag and value
        let a = args("--steps banana");
        let err = a.usize("steps", 0).unwrap_err();
        assert!(err.contains("--steps") && err.contains("banana"), "{err}");
        // boolean flags still read bare forms
        let a = args("--resume");
        assert!(a.bool("resume", false));
    }
}
