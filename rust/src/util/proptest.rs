//! Hand-rolled property-testing harness (proptest is unavailable offline).
//!
//! `check(cases, seed, |g| ...)` runs a property against `cases` randomly
//! generated inputs; on failure it reports the failing case index + seed so
//! the case can be replayed exactly. Generators produce matrices, shapes,
//! and scalars via [`Gen`].

use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// Random input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Random shape with dims in `[lo, hi]`.
    pub fn shape(&mut self, lo: usize, hi: usize) -> (usize, usize) {
        (self.usize_in(lo, hi), self.usize_in(lo, hi))
    }

    /// Gaussian matrix of a random shape.
    pub fn matrix(&mut self, lo: usize, hi: usize) -> Matrix {
        let (m, n) = self.shape(lo, hi);
        self.matrix_of(m, n)
    }

    /// Gaussian matrix of the given shape, occasionally spiced with zeros,
    /// large entries and exact duplicates (adversarial magnitude ties for
    /// TopK-style selection code).
    pub fn matrix_of(&mut self, m: usize, n: usize) -> Matrix {
        let mut a = Matrix::zeros(m, n);
        for v in a.data.iter_mut() {
            *v = self.rng.normal_f32();
        }
        match self.case % 5 {
            1 => {
                // sparse-ish input
                for v in a.data.iter_mut() {
                    if self.rng.bernoulli(0.7) {
                        *v = 0.0;
                    }
                }
            }
            2 => a.scale(1e4),
            3 => a.scale(1e-4),
            4 => {
                // duplicate magnitudes
                if a.data.len() >= 2 {
                    let x = a.data[0].abs();
                    for (i, v) in a.data.iter_mut().enumerate() {
                        if i % 3 == 0 {
                            *v = if i % 2 == 0 { x } else { -x };
                        }
                    }
                }
            }
            _ => {}
        }
        a
    }
}

/// Run `prop` against `cases` generated inputs. Panics with a replayable
/// message on the first failure (`prop` returns `Err(reason)` to fail).
pub fn check<F>(name: &str, cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let rng = Rng::with_stream(seed.wrapping_add(case as u64), 0x70_72_6f_70);
        let mut g = Gen { rng, case };
        if let Err(reason) = prop(&mut g) {
            panic!("property {name:?} failed at case {case} (seed {seed}): {reason}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes() {
        check("abs-nonneg", 50, 1, |g| {
            let m = g.matrix(1, 8);
            if m.data.iter().all(|v| v.abs() >= 0.0) {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn fails_with_case_info() {
        check("always-false", 3, 1, |_| Err("nope".into()));
    }
}
