//! Model description shared with the Python side: the layer table (name,
//! shape, parameter group) and manifest loading. `python/compile/aot.py`
//! writes `artifacts/manifest.json` + `init_params.bin`; this module is the
//! rust end of that contract.

use std::path::{Path, PathBuf};

use crate::linalg::matrix::{Layers, Matrix};
use crate::lmo::LmoKind;
use crate::opt::LayerGeometry;
use crate::util::json::Json;

/// Parameter groups (mirrors python/compile/model.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Group {
    /// 2-D matmul weights → spectral LMO (Muon).
    Hidden,
    /// embeddings / tied output head → ℓ∞ (sign) LMO, as in the paper.
    Embed,
    /// LayerNorm gains → sign LMO with a small radius multiplier.
    Vector,
}

impl Group {
    pub fn parse(s: &str) -> Result<Group, String> {
        match s {
            "hidden" => Ok(Group::Hidden),
            "embed" => Ok(Group::Embed),
            "vector" => Ok(Group::Vector),
            other => Err(format!("unknown parameter group {other:?}")),
        }
    }

    /// The paper's LMO assignment: spectral for hidden matrices, ℓ∞ for
    /// embedding/output (following Pethick et al. 2025b), sign for gains.
    pub fn geometry(self) -> LayerGeometry {
        match self {
            Group::Hidden => LayerGeometry { lmo: LmoKind::Spectral, radius_mult: 1.0 },
            Group::Embed => LayerGeometry { lmo: LmoKind::SignLInf, radius_mult: 1.0 },
            Group::Vector => LayerGeometry { lmo: LmoKind::SignLInf, radius_mult: 0.1 },
        }
    }
}

#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// true when the JAX-side parameter is rank-1 (shape `[rows]`) — the
    /// runtime must build rank-1 literals for these even though rust stores
    /// them as single-column matrices.
    pub rank1: bool,
    pub group: Group,
}

impl LayerInfo {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }
}

/// Parsed `manifest.json` + paths to the artifacts it indexes.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub batch: usize,
    pub param_count: usize,
    pub layers: Vec<LayerInfo>,
    pub grad_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    /// shape "MxN" → NS artifact path
    pub ns_hlo: Vec<((usize, usize), PathBuf)>,
    pub init_params: PathBuf,
    pub ns_steps: usize,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading manifest in {}: {e}", dir.display()))?;
        let j = Json::parse(&text)?;
        let get_usize = |path: &str| -> Result<usize, String> {
            j.path(path)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("manifest missing {path}"))
        };
        let layers = j
            .get("layers")
            .and_then(|l| l.as_arr())
            .ok_or("manifest missing layers")?
            .iter()
            .map(|l| -> Result<LayerInfo, String> {
                let name = l
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or("layer missing name")?
                    .to_string();
                let shape = l.get("shape").and_then(|v| v.as_arr()).ok_or("layer missing shape")?;
                let (rows, cols, rank1) = match shape.len() {
                    1 => (shape[0].as_usize().unwrap_or(0), 1, true),
                    2 => (
                        shape[0].as_usize().unwrap_or(0),
                        shape[1].as_usize().unwrap_or(0),
                        false,
                    ),
                    _ => return Err(format!("layer {name}: unsupported rank {}", shape.len())),
                };
                let group =
                    Group::parse(l.get("group").and_then(|v| v.as_str()).ok_or("layer missing group")?)?;
                Ok(LayerInfo { name, rows, cols, rank1, group })
            })
            .collect::<Result<Vec<_>, _>>()?;
        let arts = j.get("artifacts").ok_or("manifest missing artifacts")?;
        let art_path = |key: &str| -> Result<PathBuf, String> {
            Ok(dir.join(
                arts.get(key)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| format!("manifest missing artifacts.{key}"))?,
            ))
        };
        let mut ns_hlo = Vec::new();
        if let Some(ns) = arts.get("ns").and_then(|v| v.as_obj()) {
            for (shape, path) in ns {
                let (m, n) = shape
                    .split_once('x')
                    .ok_or_else(|| format!("bad ns shape key {shape}"))?;
                let m: usize = m.parse().map_err(|_| "bad ns shape")?;
                let n: usize = n.parse().map_err(|_| "bad ns shape")?;
                ns_hlo.push(((m, n), dir.join(path.as_str().ok_or("bad ns path")?)));
            }
        }
        Ok(Manifest {
            preset: j
                .get("preset")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown")
                .to_string(),
            vocab: get_usize("config.vocab")?,
            seq_len: get_usize("config.seq_len")?,
            d_model: get_usize("config.d_model")?,
            n_layer: get_usize("config.n_layer")?,
            batch: get_usize("batch")?,
            param_count: get_usize("param_count")?,
            layers,
            grad_hlo: art_path("grad")?,
            eval_hlo: art_path("eval")?,
            init_params: art_path("init_params")?,
            ns_steps: get_usize("ns_steps").unwrap_or(5),
            ns_hlo,
            dir,
        })
    }

    /// Load the initial parameters (f32 LE, layer-table order) into layer
    /// matrices — bit-exact with what JAX used at lowering time.
    pub fn load_init_params(&self) -> Result<Layers, String> {
        let bytes = std::fs::read(&self.init_params)
            .map_err(|e| format!("reading {}: {e}", self.init_params.display()))?;
        let expect = self.param_count * 4;
        if bytes.len() != expect {
            return Err(format!(
                "init_params.bin is {} bytes, expected {expect}",
                bytes.len()
            ));
        }
        let mut out = Vec::with_capacity(self.layers.len());
        let mut off = 0usize;
        for l in &self.layers {
            let n = l.numel();
            let end = off + 4 * n;
            // a manifest whose layer table outruns param_count used to slice
            // out of bounds here and panic; name the offending layer instead,
            // like the checkpoint loader's field errors
            if end > bytes.len() {
                return Err(format!(
                    "init_params.bin truncated at layer {:?} ({}x{}): needs bytes {off}..{end}, \
                     file has {}",
                    l.name,
                    l.rows,
                    l.cols,
                    bytes.len()
                ));
            }
            let data: Vec<f32> = bytes[off..end]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            off = end;
            out.push(Matrix::from_vec(l.rows, l.cols, data));
        }
        Ok(out)
    }

    /// Per-layer optimizer geometry (paper's LMO assignment).
    pub fn geometry(&self) -> Vec<LayerGeometry> {
        self.layers.iter().map(|l| l.group.geometry()).collect()
    }

    pub fn layer_shapes(&self) -> Vec<(usize, usize)> {
        self.layers.iter().map(|l| (l.rows, l.cols)).collect()
    }

    /// Total model bytes (f32) — the unit Figure 1-right normalizes by.
    pub fn model_bytes(&self) -> usize {
        self.param_count * 4
    }
}

/// Layer shapes of the `micro` preset (mirrors python/compile/model.py) —
/// used by Table 2 when no artifacts have been built yet.
pub fn micro_preset_shapes() -> Vec<(usize, usize)> {
    let (vocab, seq, d, ff, n_layer) = (256, 128, 128, 512, 2);
    let mut shapes = vec![(vocab, d), (seq, d)];
    for _ in 0..n_layer {
        shapes.extend_from_slice(&[(d, 1), (d, 3 * d), (d, d), (d, 1), (d, ff), (ff, d)]);
    }
    shapes.push((d, 1));
    shapes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_shapes_match_layer_count() {
        let s = micro_preset_shapes();
        assert_eq!(s.len(), 2 + 2 * 6 + 1);
    }

    #[test]
    fn group_parsing() {
        assert_eq!(Group::parse("hidden").unwrap(), Group::Hidden);
        assert_eq!(Group::parse("embed").unwrap(), Group::Embed);
        assert_eq!(Group::parse("vector").unwrap(), Group::Vector);
        assert!(Group::parse("other").is_err());
    }

    #[test]
    fn geometry_assignment() {
        assert_eq!(Group::Hidden.geometry().lmo, LmoKind::Spectral);
        assert_eq!(Group::Embed.geometry().lmo, LmoKind::SignLInf);
        assert!(Group::Vector.geometry().radius_mult < 1.0);
    }

    #[test]
    fn manifest_roundtrip_from_json() {
        let dir = std::env::temp_dir().join("efmuon_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
            "preset": "nano",
            "config": {"vocab": 256, "seq_len": 64, "d_model": 64,
                       "n_layer": 2, "n_head": 2, "d_ff": 256},
            "batch": 4, "param_count": 3,
            "layers": [
                {"name": "wte", "shape": [3, 1], "group": "embed"}
            ],
            "artifacts": {"grad": "grad.hlo.txt", "eval": "eval.hlo.txt",
                          "init_params": "init_params.bin",
                          "ns": {"64x256": "ns_64x256.hlo.txt"}},
            "ns_steps": 5
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let floats: [f32; 3] = [1.0, -2.0, 0.5];
        let mut bytes = Vec::new();
        for f in floats {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        std::fs::write(dir.join("init_params.bin"), &bytes).unwrap();

        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.layers.len(), 1);
        assert_eq!(m.layers[0].group, Group::Embed);
        assert_eq!(m.ns_hlo[0].0, (64, 256));
        let params = m.load_init_params().unwrap();
        assert_eq!(params[0].data, vec![1.0, -2.0, 0.5]);
        assert_eq!(m.model_bytes(), 12);
    }

    #[test]
    fn truncated_init_params_is_a_named_error() {
        let dir = std::env::temp_dir().join("efmuon_manifest_trunc_test");
        std::fs::create_dir_all(&dir).unwrap();
        // layer table (2x2 = 4 floats) outruns param_count (3): the byte
        // length check passes, the per-layer slice used to panic
        let manifest = r#"{
            "preset": "nano",
            "config": {"vocab": 256, "seq_len": 64, "d_model": 64,
                       "n_layer": 2, "n_head": 2, "d_ff": 256},
            "batch": 4, "param_count": 3,
            "layers": [
                {"name": "wte", "shape": [2, 2], "group": "embed"}
            ],
            "artifacts": {"grad": "grad.hlo.txt", "eval": "eval.hlo.txt",
                          "init_params": "init_params.bin"},
            "ns_steps": 5
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(dir.join("init_params.bin"), [0u8; 12]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let err = m.load_init_params().unwrap_err();
        assert!(err.contains("wte") && err.contains("truncated"), "{err}");

        // a short file still fails the up-front length check, with sizes
        std::fs::write(dir.join("init_params.bin"), [0u8; 7]).unwrap();
        let err = m.load_init_params().unwrap_err();
        assert!(err.contains("7") && err.contains("12"), "{err}");
    }
}
