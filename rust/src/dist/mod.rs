//! Distributed deployment of EF21-Muon: a threaded leader/worker runtime
//! that drives the *same* state machines as the sequential reference in
//! [`crate::opt::ef21`] — the protocol logic lives there, transport and
//! scheduling live here (DESIGN.md §Dist).
//!
//! Topology (one process, one OS thread per role):
//!
//! ```text
//!   caller thread ──► Coordinator::round()
//!        │   lmo_step (per-layer fan-out) + EF21-P broadcast (s2w comp)
//!        ├─ comm::Wire ─► worker thread 0 ─┐   apply_broadcast,
//!        ├─ comm::Wire ─► worker thread 1 ─┤   grad via GradHandle,
//!        ├─ ...                            │   local_step (w2s comp)
//!        └─ comm::Wire ─► worker thread n ─┘
//!        ◄───────── uplink Wire + loss ────┘   absorb, meter
//! ```
//!
//! Both directions are compressed: the w2s uplink by the workers' EF21
//! compressors, the s2w broadcast by the server's EF21-P compressor
//! (`CoordinatorCfg::server_comp`) — and both are metered symmetrically by
//! the same [`comm::Wire::pack`] in either [`TransportMode`].
//!
//! Round scheduling is a [`RoundMode`]: fully synchronous lock-step, or a
//! bounded pipeline (`Async { lookahead }`) where up to `lookahead`
//! broadcasts stay in flight, so the workers compute round `i` while the
//! leader is still absorbing round `i-1`'s stragglers. `lookahead = 0` is
//! bit-equal to the synchronous loop (asserted in `rust/tests/scenario.rs`).
//!
//! Gradients come from a [`service::GradService`]: either a synthetic
//! [`crate::funcs::Objective`] evaluated *inside* each worker thread (fully
//! parallel), or the PJRT model runtime on a dedicated service thread (PJRT
//! handles are not `Send`, so all executions serialize there — which is
//! also the fastest layout for a single XLA CPU client).
//!
//! Above the single leader sits [`cluster::Cluster`]: the model's layers are
//! partitioned across several shard coordinators (each the exact topology
//! above, with its own worker pool, [`Meter`] and [`RoundMode`] pipeline),
//! driven by a root reducer that advances all shards concurrently and rolls
//! their telemetry up into a [`cluster::ClusterMeter`]:
//!
//! ```text
//!   caller thread ──► Cluster::round()  (root reducer)
//!        ├─► shard thread 0: Coordinator over layers(0) ─► worker pool 0
//!        ├─► shard thread 1: Coordinator over layers(1) ─► worker pool 1
//!        └─► ...                                            (concurrent)
//!        ◄── per-shard RoundStats + Meter snapshots ── barrier + rollup
//! ```
//!
//! With one shard the cluster *is* the single-leader deployment above,
//! bit-for-bit (asserted in `rust/tests/scenario.rs`).

pub mod cluster;
pub mod comm;
pub mod coordinator;
pub mod fault;
pub mod net;
pub mod sched;
pub mod server;
pub mod service;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::{Json, JsonObj};

/// How compressed messages travel between leader and workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportMode {
    /// Pass decoded [`crate::compress::Message`]s through the channel and
    /// meter `wire_bytes()` analytically (fast; default).
    Counted,
    /// Run the real wire codec on every message (encode on send, decode on
    /// receive) — bit-exact transport simulation; byte meters count the
    /// actual encoded buffers. Lossless, so trajectories match `Counted`.
    Encoded,
}

/// Round scheduling policy of the [`coordinator::Coordinator`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Lock-step: broadcast, wait for every worker, absorb, repeat.
    Sync,
    /// Pipelined rounds: up to `lookahead` broadcasts stay in flight, so
    /// workers run ahead on the previous broadcast while the leader absorbs
    /// stragglers. The leader's LMO step then uses a gradient estimator
    /// that is up to `lookahead` rounds stale. `lookahead = 0` is bit-equal
    /// to [`RoundMode::Sync`].
    Async { lookahead: usize },
}

impl RoundMode {
    /// Number of rounds allowed to stay in flight after a broadcast.
    pub fn lookahead(self) -> usize {
        match self {
            RoundMode::Sync => 0,
            RoundMode::Async { lookahead } => lookahead,
        }
    }

    /// Largest accepted `async:N` lookahead. Every in-flight round pins one
    /// broadcast plus per-worker reply slots, so an absurd lookahead (say
    /// `async:18446744073709551615`) is always a typo, never a deployment —
    /// reject it with a clear error instead of OOMing rounds later.
    pub const MAX_LOOKAHEAD: usize = 1024;

    /// Parse a mode spec: `sync` | `async` (= `async:1`) | `async:N`
    /// (`N <= MAX_LOOKAHEAD`).
    pub fn parse(s: &str) -> Result<RoundMode, String> {
        match s {
            "sync" => Ok(RoundMode::Sync),
            "async" => Ok(RoundMode::Async { lookahead: 1 }),
            other => match other.strip_prefix("async:") {
                Some(n) => match n.parse::<usize>() {
                    Ok(lookahead) if lookahead > Self::MAX_LOOKAHEAD => Err(format!(
                        "bad round mode {other:?}: lookahead {lookahead} exceeds the \
                         max of {} (each in-flight round holds a broadcast plus \
                         per-worker reply slots)",
                        Self::MAX_LOOKAHEAD
                    )),
                    Ok(lookahead) => Ok(RoundMode::Async { lookahead }),
                    Err(_) => Err(format!("bad round mode {other:?}: expected async:<lookahead>")),
                },
                None => Err(format!("bad round mode {other:?}: expected sync | async | async:<n>")),
            },
        }
    }

    /// Round-trips through [`RoundMode::parse`].
    pub fn spec(self) -> String {
        match self {
            RoundMode::Sync => "sync".into(),
            RoundMode::Async { lookahead } => format!("async:{lookahead}"),
        }
    }
}

/// Cumulative communication meters for one coordinator (bytes). Both
/// directions are recorded by the same transport packer, so `Counted` and
/// `Encoded` runs agree on every counter (asserted in
/// `rust/tests/scenario.rs`). In async modes the broadcast counter leads
/// the uplink counters by up to `lookahead` rounds until the pipeline is
/// drained.
#[derive(Debug, Default)]
pub struct Meter {
    /// w2s bytes sent by ONE worker (the paper's reporting unit).
    pub w2s_per_worker: AtomicU64,
    /// w2s bytes summed over ALL workers.
    pub w2s_all: AtomicU64,
    /// s2w broadcast bytes (counted once per round, not per worker).
    pub s2w_total: AtomicU64,
    /// Rounds whose broadcast has been issued.
    pub rounds_issued: AtomicU64,
    /// Rounds whose uplinks have been fully absorbed.
    pub rounds_absorbed: AtomicU64,
    /// Worker replies skipped at a straggler deadline (one per skipped
    /// slot; [`fault::FaultPolicy`]). Zero in a fault-free run.
    pub stragglers: AtomicU64,
    /// Workers respawned by the supervisor after a failure.
    pub respawns: AtomicU64,
    /// Rounds absorbed over a partial quorum (at least one slot skipped).
    pub partial_rounds: AtomicU64,
    /// Socket links re-established: a worker re-dialing after a torn
    /// connection, or a late joiner claiming a freed id slot
    /// ([`net::NetHub`]). Zero on the in-memory channel transport and in
    /// every fault-free socket run.
    pub reconnects: AtomicU64,
    /// Heartbeat windows that elapsed with no frame from a connected
    /// worker ([`net::NetCfg::miss_threshold`] consecutive misses tear the
    /// link down). Zero in a healthy run.
    pub heartbeat_misses: AtomicU64,
}

impl Meter {
    pub fn new() -> Meter {
        Meter::default()
    }

    /// Per-worker uplink total.
    pub fn w2s(&self) -> u64 {
        self.w2s_per_worker.load(Ordering::Relaxed)
    }

    /// Uplink total summed over ALL workers.
    pub fn w2s_all(&self) -> u64 {
        self.w2s_all.load(Ordering::Relaxed)
    }

    /// Broadcast total.
    pub fn s2w(&self) -> u64 {
        self.s2w_total.load(Ordering::Relaxed)
    }

    /// Rounds issued so far.
    pub fn rounds_issued(&self) -> u64 {
        self.rounds_issued.load(Ordering::Relaxed)
    }

    /// Rounds fully absorbed so far (== issued once the pipeline drains).
    pub fn rounds_absorbed(&self) -> u64 {
        self.rounds_absorbed.load(Ordering::Relaxed)
    }

    /// Deadline-skipped worker replies so far.
    pub fn stragglers(&self) -> u64 {
        self.stragglers.load(Ordering::Relaxed)
    }

    /// Worker respawns so far.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Rounds absorbed with a partial quorum so far.
    pub fn partial_rounds(&self) -> u64 {
        self.partial_rounds.load(Ordering::Relaxed)
    }

    /// Socket links re-established so far.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Heartbeat misses observed so far.
    pub fn heartbeat_misses(&self) -> u64 {
        self.heartbeat_misses.load(Ordering::Relaxed)
    }

    /// Record one issued broadcast (s2w direction).
    pub(crate) fn record_broadcast(&self, s2w: u64) {
        self.s2w_total.fetch_add(s2w, Ordering::Relaxed);
        self.rounds_issued.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one fully-absorbed round of uplinks (w2s direction).
    pub(crate) fn record_uplinks(&self, w2s_per_worker: u64, w2s_all: u64) {
        self.w2s_per_worker.fetch_add(w2s_per_worker, Ordering::Relaxed);
        self.w2s_all.fetch_add(w2s_all, Ordering::Relaxed);
        self.rounds_absorbed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` slots skipped at a straggler deadline.
    pub(crate) fn record_stragglers(&self, n: u64) {
        self.stragglers.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one worker respawn.
    pub(crate) fn record_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one round absorbed over a partial quorum.
    pub(crate) fn record_partial_round(&self) {
        self.partial_rounds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one re-established socket link (re-dial or late join).
    pub(crate) fn record_reconnect(&self) {
        self.reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one elapsed heartbeat window without a frame.
    pub(crate) fn record_heartbeat_miss(&self) {
        self.heartbeat_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Late w2s bytes from a straggler whose round already absorbed (its
    /// residual still lands in the server estimator, so the wire traffic is
    /// real — count it in the all-workers total, without advancing the
    /// round counters or the single-worker reporting unit).
    pub(crate) fn record_late_uplink(&self, bytes: u64) {
        self.w2s_all.fetch_add(bytes, Ordering::Relaxed);
    }

    /// A point-in-time copy of every counter (plain integers — cheap to
    /// ship across threads; the cluster rollup aggregates these).
    pub fn snapshot(&self) -> MeterSnapshot {
        MeterSnapshot {
            w2s_per_worker: self.w2s(),
            w2s_all: self.w2s_all(),
            s2w_total: self.s2w(),
            rounds_issued: self.rounds_issued(),
            rounds_absorbed: self.rounds_absorbed(),
            stragglers: self.stragglers(),
            respawns: self.respawns(),
            partial_rounds: self.partial_rounds(),
            reconnects: self.reconnects(),
            heartbeat_misses: self.heartbeat_misses(),
            // host memory-traffic counters are overlaid by the cluster
            // layer; a lone coordinator assembles nothing
            ..MeterSnapshot::default()
        }
    }
}

/// Serializable point-in-time copy of a [`Meter`] (see [`Meter::snapshot`]),
/// plus the host memory-traffic counters the cluster layer overlays per
/// shard: snapshot-cache assemblies/reuses and bytes deep-copied on the
/// gradient path (`cluster::ClusterMeter`). Plain coordinator meters carry
/// zeros there — the single-leader hot path assembles nothing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MeterSnapshot {
    pub w2s_per_worker: u64,
    pub w2s_all: u64,
    pub s2w_total: u64,
    pub rounds_issued: u64,
    pub rounds_absorbed: u64,
    /// Full-model snapshots assembled (one per (shard, round) — the
    /// snapshot cache's miss count).
    pub snap_assembled: u64,
    /// Gradient requests served from an already-assembled snapshot.
    pub snap_reused: u64,
    /// Bytes deep-copied on the host gradient/snapshot path.
    pub bytes_cloned: u64,
    /// Bytes read from the cluster parameter board at its stored snapshot
    /// width while assembling foreign layers (2 B/entry under the bf16
    /// board, 4 B/entry under f32) — the cross-shard wire traffic.
    pub snap_bytes_shipped: u64,
    /// Deadline-skipped worker replies ([`fault::FaultPolicy`]).
    pub stragglers: u64,
    /// Worker respawns performed by the supervisor.
    pub respawns: u64,
    /// Rounds absorbed over a partial quorum.
    pub partial_rounds: u64,
    /// Socket links re-established ([`net::NetHub`]).
    pub reconnects: u64,
    /// Heartbeat windows elapsed without a frame ([`net::NetCfg`]).
    pub heartbeat_misses: u64,
    /// Layers migrated off persistently slow shards by the windowed root
    /// scheduler ([`sched::SchedSpec`]). Zero in lock-step and in every
    /// balanced run.
    pub steals: u64,
    /// Largest round lead any shard held over the window frontier
    /// (bounded by `SchedSpec::window`; exactly 0 in lock-step).
    pub epochs_ahead_max: u64,
}

impl MeterSnapshot {
    /// Accumulate another snapshot's byte counters; round counters take the
    /// minimum (the rounds *every* summed meter has completed).
    pub fn absorb_shard(&mut self, other: &MeterSnapshot, first: bool) {
        self.w2s_per_worker += other.w2s_per_worker;
        self.w2s_all += other.w2s_all;
        self.s2w_total += other.s2w_total;
        self.snap_assembled += other.snap_assembled;
        self.snap_reused += other.snap_reused;
        self.bytes_cloned += other.bytes_cloned;
        self.snap_bytes_shipped += other.snap_bytes_shipped;
        self.stragglers += other.stragglers;
        self.respawns += other.respawns;
        self.partial_rounds += other.partial_rounds;
        self.reconnects += other.reconnects;
        self.heartbeat_misses += other.heartbeat_misses;
        self.steals += other.steals;
        self.epochs_ahead_max = if first {
            other.epochs_ahead_max
        } else {
            self.epochs_ahead_max.max(other.epochs_ahead_max)
        };
        if first {
            self.rounds_issued = other.rounds_issued;
            self.rounds_absorbed = other.rounds_absorbed;
        } else {
            self.rounds_issued = self.rounds_issued.min(other.rounds_issued);
            self.rounds_absorbed = self.rounds_absorbed.min(other.rounds_absorbed);
        }
    }

    /// JSON form (metrics logs, `BENCH_hotpath.json` rollups).
    pub fn to_json(&self) -> Json {
        JsonObj::new()
            .put("w2s_per_worker", self.w2s_per_worker)
            .put("w2s_all", self.w2s_all)
            .put("s2w_total", self.s2w_total)
            .put("rounds_issued", self.rounds_issued)
            .put("rounds_absorbed", self.rounds_absorbed)
            .put("snap_assembled", self.snap_assembled)
            .put("snap_reused", self.snap_reused)
            .put("bytes_cloned", self.bytes_cloned)
            .put("snap_bytes_shipped", self.snap_bytes_shipped)
            .put("stragglers", self.stragglers)
            .put("respawns", self.respawns)
            .put("partial_rounds", self.partial_rounds)
            .put("reconnects", self.reconnects)
            .put("heartbeat_misses", self.heartbeat_misses)
            .put("steals", self.steals)
            .put("epochs_ahead_max", self.epochs_ahead_max)
            .build()
    }

    /// Parse the form emitted by [`MeterSnapshot::to_json`]. The traffic
    /// counters default to 0 when absent, so pre-cache snapshots (older
    /// logs and bench baselines) still parse.
    pub fn from_json(j: &Json) -> Result<MeterSnapshot, String> {
        let get = |k: &str| -> Result<u64, String> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .map(|v| v as u64)
                .ok_or_else(|| format!("meter snapshot: missing {k}"))
        };
        let opt = |k: &str| -> u64 {
            j.get(k).and_then(|v| v.as_f64()).map(|v| v as u64).unwrap_or(0)
        };
        Ok(MeterSnapshot {
            w2s_per_worker: get("w2s_per_worker")?,
            w2s_all: get("w2s_all")?,
            s2w_total: get("s2w_total")?,
            rounds_issued: get("rounds_issued")?,
            rounds_absorbed: get("rounds_absorbed")?,
            snap_assembled: opt("snap_assembled"),
            snap_reused: opt("snap_reused"),
            bytes_cloned: opt("bytes_cloned"),
            snap_bytes_shipped: opt("snap_bytes_shipped"),
            stragglers: opt("stragglers"),
            respawns: opt("respawns"),
            partial_rounds: opt("partial_rounds"),
            reconnects: opt("reconnects"),
            heartbeat_misses: opt("heartbeat_misses"),
            steals: opt("steals"),
            epochs_ahead_max: opt("epochs_ahead_max"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_mode_parse_roundtrip() {
        assert_eq!(RoundMode::parse("sync").unwrap(), RoundMode::Sync);
        assert_eq!(RoundMode::parse("async").unwrap(), RoundMode::Async { lookahead: 1 });
        assert_eq!(RoundMode::parse("async:0").unwrap(), RoundMode::Async { lookahead: 0 });
        assert_eq!(RoundMode::parse("async:3").unwrap(), RoundMode::Async { lookahead: 3 });
        for s in ["sync", "async:0", "async:2"] {
            assert_eq!(RoundMode::parse(s).unwrap().spec(), s);
        }
        for s in ["", "bogus", "async:", "async:x", "sync:1"] {
            assert!(RoundMode::parse(s).is_err(), "{s} should fail");
        }
        assert_eq!(RoundMode::Sync.lookahead(), 0);
        assert_eq!(RoundMode::Async { lookahead: 4 }.lookahead(), 4);
    }

    #[test]
    fn round_mode_parse_caps_lookahead() {
        // the cap itself is accepted; one past it (and absurd values that
        // would silently allocate unbounded pipeline state) are rejected
        // with an error that names the limit
        let max = RoundMode::MAX_LOOKAHEAD;
        assert_eq!(
            RoundMode::parse(&format!("async:{max}")).unwrap(),
            RoundMode::Async { lookahead: max }
        );
        for s in [format!("async:{}", max + 1), format!("async:{}", u64::MAX)] {
            let err = RoundMode::parse(&s).expect_err("absurd lookahead must fail");
            assert!(err.contains("exceeds the max"), "unhelpful error: {err}");
            assert!(err.contains("1024"), "error should name the limit: {err}");
        }
    }

    #[test]
    fn meter_counts_both_directions() {
        let m = Meter::new();
        m.record_broadcast(100);
        m.record_broadcast(100);
        m.record_uplinks(40, 120);
        assert_eq!(m.s2w(), 200);
        assert_eq!(m.w2s(), 40);
        assert_eq!(m.w2s_all(), 120);
        assert_eq!(m.rounds_issued(), 2);
        assert_eq!(m.rounds_absorbed(), 1);
    }

    #[test]
    fn meter_snapshot_roundtrips_through_json() {
        let m = Meter::new();
        m.record_broadcast(100);
        m.record_uplinks(40, 120);
        let snap = m.snapshot();
        assert_eq!(snap.w2s_per_worker, 40);
        assert_eq!(snap.w2s_all, 120);
        assert_eq!(snap.s2w_total, 100);
        assert_eq!(snap.rounds_issued, 1);
        assert_eq!(snap.rounds_absorbed, 1);
        let j = snap.to_json();
        let back = MeterSnapshot::from_json(&j).unwrap();
        assert_eq!(back, snap);
        assert!(MeterSnapshot::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn meter_snapshot_roundtrip_covers_every_field() {
        // every counter nonzero and pairwise distinct, so a swapped or
        // dropped key in to_json/from_json cannot cancel out
        let snap = MeterSnapshot {
            w2s_per_worker: 101,
            w2s_all: 202,
            s2w_total: 303,
            rounds_issued: 404,
            rounds_absorbed: 405,
            snap_assembled: 506,
            snap_reused: 607,
            bytes_cloned: 708,
            snap_bytes_shipped: 809,
            stragglers: 910,
            respawns: 911,
            partial_rounds: 912,
            reconnects: 913,
            heartbeat_misses: 914,
            steals: 915,
            epochs_ahead_max: 916,
        };
        let j = snap.to_json();
        let line = j.to_line();
        for key in [
            "w2s_per_worker",
            "w2s_all",
            "s2w_total",
            "rounds_issued",
            "rounds_absorbed",
            "snap_assembled",
            "snap_reused",
            "bytes_cloned",
            "snap_bytes_shipped",
            "stragglers",
            "respawns",
            "partial_rounds",
            "reconnects",
            "heartbeat_misses",
            "steals",
            "epochs_ahead_max",
        ] {
            assert!(line.contains(key), "serialized snapshot must carry {key}: {line}");
        }
        // text → Json → struct reproduces every field bit for bit
        let back = MeterSnapshot::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn meter_fault_counters_roundtrip_and_default_zero() {
        let m = Meter::new();
        m.record_stragglers(2);
        m.record_respawn();
        m.record_partial_round();
        m.record_late_uplink(64);
        m.record_reconnect();
        m.record_heartbeat_miss();
        m.record_heartbeat_miss();
        let snap = m.snapshot();
        assert_eq!(snap.stragglers, 2);
        assert_eq!(snap.respawns, 1);
        assert_eq!(snap.partial_rounds, 1);
        assert_eq!(snap.reconnects, 1);
        assert_eq!(snap.heartbeat_misses, 2);
        assert_eq!(snap.w2s_all, 64);
        assert_eq!(snap.w2s_per_worker, 0, "late bytes don't touch the per-worker unit");
        let back = MeterSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        // old snapshots without fault or net counters still parse, as zeros
        let legacy = Json::parse(
            r#"{"w2s_per_worker":1,"w2s_all":2,"s2w_total":3,
                "rounds_issued":4,"rounds_absorbed":4}"#,
        )
        .unwrap();
        let s = MeterSnapshot::from_json(&legacy).unwrap();
        assert_eq!((s.stragglers, s.respawns, s.partial_rounds), (0, 0, 0));
        assert_eq!((s.reconnects, s.heartbeat_misses), (0, 0));
        assert_eq!((s.steals, s.epochs_ahead_max), (0, 0));
    }
}
