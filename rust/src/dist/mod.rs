//! Distributed deployment of EF21-Muon: a threaded leader/worker runtime
//! that drives the *same* state machines as the sequential reference in
//! [`crate::opt::ef21`] — the protocol logic lives there, transport and
//! scheduling live here (DESIGN.md §Dist).
//!
//! Topology (one process, one OS thread per role):
//!
//! ```text
//!   caller thread ──► Coordinator::round()
//!        │   lmo_step (per-layer fan-out) + broadcast
//!        ├─ comm::Wire ─► worker thread 0 ─┐   apply_broadcast,
//!        ├─ comm::Wire ─► worker thread 1 ─┤   grad via GradHandle,
//!        ├─ ...                            │   local_step (compress)
//!        └─ comm::Wire ─► worker thread n ─┘
//!        ◄───────── uplink Wire + loss ────┘   absorb, meter
//! ```
//!
//! Gradients come from a [`service::GradService`]: either a synthetic
//! [`crate::funcs::Objective`] evaluated *inside* each worker thread (fully
//! parallel), or the PJRT model runtime on a dedicated service thread (PJRT
//! handles are not `Send`, so all executions serialize there — which is
//! also the fastest layout for a single XLA CPU client).

pub mod comm;
pub mod coordinator;
pub mod server;
pub mod service;

use std::sync::atomic::{AtomicU64, Ordering};

/// How compressed messages travel between leader and workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportMode {
    /// Pass decoded [`crate::compress::Message`]s through the channel and
    /// meter `wire_bytes()` analytically (fast; default).
    Counted,
    /// Run the real wire codec on every message (encode on send, decode on
    /// receive) — bit-exact transport simulation; byte meters count the
    /// actual encoded buffers. Lossless, so trajectories match `Counted`.
    Encoded,
}

/// Cumulative communication meters for one coordinator (bytes).
#[derive(Debug, Default)]
pub struct Meter {
    /// w2s bytes sent by ONE worker (the paper's reporting unit).
    pub w2s_per_worker: AtomicU64,
    /// w2s bytes summed over ALL workers.
    pub w2s_all: AtomicU64,
    /// s2w broadcast bytes (counted once per round, not per worker).
    pub s2w_total: AtomicU64,
}

impl Meter {
    pub fn new() -> Meter {
        Meter::default()
    }

    /// Per-worker uplink total.
    pub fn w2s(&self) -> u64 {
        self.w2s_per_worker.load(Ordering::Relaxed)
    }

    /// Broadcast total.
    pub fn s2w(&self) -> u64 {
        self.s2w_total.load(Ordering::Relaxed)
    }

    pub(crate) fn record_round(&self, w2s_per_worker: u64, w2s_all: u64, s2w: u64) {
        self.w2s_per_worker.fetch_add(w2s_per_worker, Ordering::Relaxed);
        self.w2s_all.fetch_add(w2s_all, Ordering::Relaxed);
        self.s2w_total.fetch_add(s2w, Ordering::Relaxed);
    }
}
