//! Distributed deployment of EF21-Muon: a threaded leader/worker runtime
//! that drives the *same* state machines as the sequential reference in
//! [`crate::opt::ef21`] — the protocol logic lives there, transport and
//! scheduling live here (DESIGN.md §Dist).
//!
//! Topology (one process, one OS thread per role):
//!
//! ```text
//!   caller thread ──► Coordinator::round()
//!        │   lmo_step (per-layer fan-out) + EF21-P broadcast (s2w comp)
//!        ├─ comm::Wire ─► worker thread 0 ─┐   apply_broadcast,
//!        ├─ comm::Wire ─► worker thread 1 ─┤   grad via GradHandle,
//!        ├─ ...                            │   local_step (w2s comp)
//!        └─ comm::Wire ─► worker thread n ─┘
//!        ◄───────── uplink Wire + loss ────┘   absorb, meter
//! ```
//!
//! Both directions are compressed: the w2s uplink by the workers' EF21
//! compressors, the s2w broadcast by the server's EF21-P compressor
//! (`CoordinatorCfg::server_comp`) — and both are metered symmetrically by
//! the same [`comm::Wire::pack`] in either [`TransportMode`].
//!
//! Round scheduling is a [`RoundMode`]: fully synchronous lock-step, or a
//! bounded pipeline (`Async { lookahead }`) where up to `lookahead`
//! broadcasts stay in flight, so the workers compute round `i` while the
//! leader is still absorbing round `i-1`'s stragglers. `lookahead = 0` is
//! bit-equal to the synchronous loop (asserted in `rust/tests/scenario.rs`).
//!
//! Gradients come from a [`service::GradService`]: either a synthetic
//! [`crate::funcs::Objective`] evaluated *inside* each worker thread (fully
//! parallel), or the PJRT model runtime on a dedicated service thread (PJRT
//! handles are not `Send`, so all executions serialize there — which is
//! also the fastest layout for a single XLA CPU client).

pub mod comm;
pub mod coordinator;
pub mod server;
pub mod service;

use std::sync::atomic::{AtomicU64, Ordering};

/// How compressed messages travel between leader and workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportMode {
    /// Pass decoded [`crate::compress::Message`]s through the channel and
    /// meter `wire_bytes()` analytically (fast; default).
    Counted,
    /// Run the real wire codec on every message (encode on send, decode on
    /// receive) — bit-exact transport simulation; byte meters count the
    /// actual encoded buffers. Lossless, so trajectories match `Counted`.
    Encoded,
}

/// Round scheduling policy of the [`coordinator::Coordinator`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Lock-step: broadcast, wait for every worker, absorb, repeat.
    Sync,
    /// Pipelined rounds: up to `lookahead` broadcasts stay in flight, so
    /// workers run ahead on the previous broadcast while the leader absorbs
    /// stragglers. The leader's LMO step then uses a gradient estimator
    /// that is up to `lookahead` rounds stale. `lookahead = 0` is bit-equal
    /// to [`RoundMode::Sync`].
    Async { lookahead: usize },
}

impl RoundMode {
    /// Number of rounds allowed to stay in flight after a broadcast.
    pub fn lookahead(self) -> usize {
        match self {
            RoundMode::Sync => 0,
            RoundMode::Async { lookahead } => lookahead,
        }
    }

    /// Parse a mode spec: `sync` | `async` (= `async:1`) | `async:N`.
    pub fn parse(s: &str) -> Result<RoundMode, String> {
        match s {
            "sync" => Ok(RoundMode::Sync),
            "async" => Ok(RoundMode::Async { lookahead: 1 }),
            other => match other.strip_prefix("async:") {
                Some(n) => n
                    .parse::<usize>()
                    .map(|lookahead| RoundMode::Async { lookahead })
                    .map_err(|_| format!("bad round mode {other:?}: expected async:<lookahead>")),
                None => Err(format!("bad round mode {other:?}: expected sync | async | async:<n>")),
            },
        }
    }

    /// Round-trips through [`RoundMode::parse`].
    pub fn spec(self) -> String {
        match self {
            RoundMode::Sync => "sync".into(),
            RoundMode::Async { lookahead } => format!("async:{lookahead}"),
        }
    }
}

/// Cumulative communication meters for one coordinator (bytes). Both
/// directions are recorded by the same transport packer, so `Counted` and
/// `Encoded` runs agree on every counter (asserted in
/// `rust/tests/scenario.rs`). In async modes the broadcast counter leads
/// the uplink counters by up to `lookahead` rounds until the pipeline is
/// drained.
#[derive(Debug, Default)]
pub struct Meter {
    /// w2s bytes sent by ONE worker (the paper's reporting unit).
    pub w2s_per_worker: AtomicU64,
    /// w2s bytes summed over ALL workers.
    pub w2s_all: AtomicU64,
    /// s2w broadcast bytes (counted once per round, not per worker).
    pub s2w_total: AtomicU64,
    /// Rounds whose broadcast has been issued.
    pub rounds_issued: AtomicU64,
    /// Rounds whose uplinks have been fully absorbed.
    pub rounds_absorbed: AtomicU64,
}

impl Meter {
    pub fn new() -> Meter {
        Meter::default()
    }

    /// Per-worker uplink total.
    pub fn w2s(&self) -> u64 {
        self.w2s_per_worker.load(Ordering::Relaxed)
    }

    /// Broadcast total.
    pub fn s2w(&self) -> u64 {
        self.s2w_total.load(Ordering::Relaxed)
    }

    /// Rounds issued so far.
    pub fn rounds_issued(&self) -> u64 {
        self.rounds_issued.load(Ordering::Relaxed)
    }

    /// Rounds fully absorbed so far (== issued once the pipeline drains).
    pub fn rounds_absorbed(&self) -> u64 {
        self.rounds_absorbed.load(Ordering::Relaxed)
    }

    /// Record one issued broadcast (s2w direction).
    pub(crate) fn record_broadcast(&self, s2w: u64) {
        self.s2w_total.fetch_add(s2w, Ordering::Relaxed);
        self.rounds_issued.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one fully-absorbed round of uplinks (w2s direction).
    pub(crate) fn record_uplinks(&self, w2s_per_worker: u64, w2s_all: u64) {
        self.w2s_per_worker.fetch_add(w2s_per_worker, Ordering::Relaxed);
        self.w2s_all.fetch_add(w2s_all, Ordering::Relaxed);
        self.rounds_absorbed.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_mode_parse_roundtrip() {
        assert_eq!(RoundMode::parse("sync").unwrap(), RoundMode::Sync);
        assert_eq!(RoundMode::parse("async").unwrap(), RoundMode::Async { lookahead: 1 });
        assert_eq!(RoundMode::parse("async:0").unwrap(), RoundMode::Async { lookahead: 0 });
        assert_eq!(RoundMode::parse("async:3").unwrap(), RoundMode::Async { lookahead: 3 });
        for s in ["sync", "async:0", "async:2"] {
            assert_eq!(RoundMode::parse(s).unwrap().spec(), s);
        }
        for s in ["", "bogus", "async:", "async:x", "sync:1"] {
            assert!(RoundMode::parse(s).is_err(), "{s} should fail");
        }
        assert_eq!(RoundMode::Sync.lookahead(), 0);
        assert_eq!(RoundMode::Async { lookahead: 4 }.lookahead(), 4);
    }

    #[test]
    fn meter_counts_both_directions() {
        let m = Meter::new();
        m.record_broadcast(100);
        m.record_broadcast(100);
        m.record_uplinks(40, 120);
        assert_eq!(m.s2w(), 200);
        assert_eq!(m.w2s(), 40);
        assert_eq!(m.w2s_all.load(Ordering::Relaxed), 120);
        assert_eq!(m.rounds_issued(), 2);
        assert_eq!(m.rounds_absorbed(), 1);
    }
}
