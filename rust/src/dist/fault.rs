//! Fault tolerance: the typed straggler/respawn policy and the
//! deterministic fault-injection plan.
//!
//! [`FaultPolicy`] is the spec-level knob carried by `RunSpec`,
//! `CoordinatorCfg` and `ClusterCfg`. The default (`off()`) reproduces the
//! fail-stop lock-step deployment bit for bit: every round blocks until all
//! workers reply and the first failure latches the coordinator. Turning the
//! policy on makes the absorb loop deadline-driven — workers past
//! `deadline_ms` are marked stragglers and the round aggregates over the
//! quorum that did reply (the EF21 server estimator for the missing ids is
//! simply left untouched; its compressed-difference state waits for the
//! next round the worker participates in) — and gives dead workers a
//! bounded respawn budget through the existing `INIT_STEP` re-init path.
//!
//! [`FaultPlan`] is a test/bench-only injection harness: a seeded, fully
//! deterministic schedule of `(worker, step) → FaultKind` events hooked
//! into the worker threads. It is deliberately *not* part of `RunSpec`
//! (never serialized into a config): faults are injected by tests, not
//! configured by runs.

use std::collections::HashMap;
use std::fmt;

use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// FaultPolicy
// ---------------------------------------------------------------------------

/// Straggler / quorum / respawn policy for a deployment.
///
/// Spec grammar (the `--fault-policy` flag and the `fault_policy` config
/// key): `off`, or a comma list of `key:value` pairs —
/// `deadline:50,quorum:0.75,respawns:2,backoff:10`. Omitted keys take the
/// field defaults below; [`FaultPolicy::spec`] always emits either `off`
/// or all four keys in that fixed order, so `parse(spec(p)) == p` exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPolicy {
    /// Per-round straggler deadline in milliseconds, measured from the
    /// round's broadcast. `0` disables the deadline: the absorb loop blocks
    /// exactly like the policy-off path.
    pub deadline_ms: u64,
    /// Minimum fraction of workers that must have replied before a
    /// deadline-expired round may absorb. In `(0, 1]`; `1.0` waits for
    /// everyone — the golden anchor, bit-identical to lock-step.
    pub quorum: f32,
    /// Respawn budget per worker id. A failed worker is relaunched through
    /// the `INIT_STEP` re-init path up to this many times before the
    /// coordinator returns a terminal `Err`. `0` keeps failures fail-stop.
    pub max_respawns: u32,
    /// Base backoff before a respawn; attempt `k` (1-based) sleeps
    /// `backoff_ms << (k - 1)` milliseconds.
    pub backoff_ms: u64,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy::off()
    }
}

impl FaultPolicy {
    /// The fail-stop default: no deadline, full quorum, no respawns.
    pub const fn off() -> Self {
        FaultPolicy { deadline_ms: 0, quorum: 1.0, max_respawns: 0, backoff_ms: 0 }
    }

    /// True when the policy changes nothing about the lock-step deployment.
    pub fn is_off(&self) -> bool {
        *self == FaultPolicy::off()
    }

    /// Minimum reply count implied by `quorum` for an `n`-worker pool
    /// (ceil, clamped into `[1, n]`).
    pub fn quorum_min(&self, n: usize) -> usize {
        let q = (self.quorum as f64 * n as f64).ceil() as usize;
        q.clamp(1, n)
    }

    /// Backoff before respawn `attempt` (1-based), capped at 30 s so a
    /// misconfigured exponent cannot wedge the supervisor.
    pub fn backoff_for(&self, attempt: u32) -> u64 {
        let shift = attempt.saturating_sub(1).min(20);
        (self.backoff_ms << shift).min(30_000)
    }

    /// Parse the spec grammar. Accepts `off` (or the empty string) and any
    /// subset of `deadline:MS,quorum:F,respawns:N,backoff:MS`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s.is_empty() || s == "off" {
            return Ok(FaultPolicy::off());
        }
        let mut p = FaultPolicy::off();
        for part in s.split(',') {
            let part = part.trim();
            let (key, val) = part
                .split_once(':')
                .ok_or_else(|| format!("fault policy: expected key:value, got {part:?}"))?;
            match key {
                "deadline" => {
                    p.deadline_ms = val
                        .parse()
                        .map_err(|_| format!("fault policy: bad deadline {val:?}"))?;
                }
                "quorum" => {
                    p.quorum = val
                        .parse()
                        .map_err(|_| format!("fault policy: bad quorum {val:?}"))?;
                }
                "respawns" => {
                    p.max_respawns = val
                        .parse()
                        .map_err(|_| format!("fault policy: bad respawns {val:?}"))?;
                }
                "backoff" => {
                    p.backoff_ms = val
                        .parse()
                        .map_err(|_| format!("fault policy: bad backoff {val:?}"))?;
                }
                other => {
                    return Err(format!(
                        "fault policy: unknown key {other:?} \
                         (expected deadline/quorum/respawns/backoff, or \"off\")"
                    ))
                }
            }
        }
        p.validate()?;
        Ok(p)
    }

    /// Canonical spec string; `parse` round-trips it losslessly.
    pub fn spec(&self) -> String {
        if self.is_off() {
            return "off".into();
        }
        format!(
            "deadline:{},quorum:{},respawns:{},backoff:{}",
            self.deadline_ms, self.quorum, self.max_respawns, self.backoff_ms
        )
    }

    /// Field-level validation (also run by `parse`).
    pub fn validate(&self) -> Result<(), String> {
        if !self.quorum.is_finite() || self.quorum <= 0.0 || self.quorum > 1.0 {
            return Err(format!(
                "fault policy: quorum must be in (0, 1] (got {})",
                self.quorum
            ));
        }
        if self.quorum < 1.0 && self.deadline_ms == 0 {
            return Err(
                "fault policy: quorum < 1 requires a deadline (deadline:MS)".into(),
            );
        }
        Ok(())
    }
}

impl fmt::Display for FaultPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec())
    }
}

// ---------------------------------------------------------------------------
// FaultPlan — deterministic injection
// ---------------------------------------------------------------------------

/// What a scheduled fault does to the worker that hits it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker thread panics at the scheduled step (a fail-stop crash;
    /// the panic guard converts it into a `Failed` reply).
    Panic,
    /// The worker sleeps this long before computing its gradient — a
    /// straggler the deadline should skip (and whose late reply the
    /// coordinator re-absorbs into the EF21 estimator when it lands).
    DelayMs(u64),
    /// The worker applies the broadcast (keeping its shift in sync) but
    /// skips its local step and reply entirely — federated
    /// non-participation; its slot is owed forever.
    Drop,
}

/// A deterministic schedule of faults keyed by `(worker, step)`.
///
/// Carried as `Option<Arc<FaultPlan>>` on the deployment cfgs and consulted
/// by each worker thread right after it receives a round's broadcast.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    events: HashMap<(usize, usize), FaultKind>,
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Schedule `kind` for `worker` at `step` (builder-style).
    pub fn with(mut self, worker: usize, step: usize, kind: FaultKind) -> Self {
        self.events.insert((worker, step), kind);
        self
    }

    /// The fault scheduled for `(worker, step)`, if any.
    pub fn at(&self, worker: usize, step: usize) -> Option<FaultKind> {
        self.events.get(&(worker, step)).copied()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// `(panics, delays, drops)` — the exact injected counts, for asserting
    /// meter totals against the plan.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for kind in self.events.values() {
            match kind {
                FaultKind::Panic => c.0 += 1,
                FaultKind::DelayMs(_) => c.1 += 1,
                FaultKind::Drop => c.2 += 1,
            }
        }
        c
    }

    /// A seeded random plan: `n_events` distinct `(worker, step)` slots
    /// drawn uniformly from `workers × [min_step, steps)` with kinds cycled
    /// panic → delay → drop. `min_step` keeps faults away from warmup
    /// rounds a test wants clean. Fully deterministic in `seed`.
    pub fn seeded(
        seed: u64,
        workers: usize,
        steps: usize,
        min_step: usize,
        n_events: usize,
        delay_ms: u64,
    ) -> Self {
        assert!(workers > 0 && steps > min_step, "empty fault domain");
        let mut rng = Rng::with_stream(seed, 0xfa_17);
        let mut plan = FaultPlan::new();
        let kinds = [FaultKind::Panic, FaultKind::DelayMs(delay_ms), FaultKind::Drop];
        let mut k = 0usize;
        let domain = workers * (steps - min_step);
        let target = n_events.min(domain);
        while plan.events.len() < target {
            let worker = rng.below(workers);
            let step = min_step + rng.below(steps - min_step);
            if plan.events.contains_key(&(worker, step)) {
                continue;
            }
            plan.events.insert((worker, step), kinds[k % kinds.len()]);
            k += 1;
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_default_is_off_and_roundtrips() {
        let p = FaultPolicy::default();
        assert!(p.is_off());
        assert_eq!(p.spec(), "off");
        assert_eq!(FaultPolicy::parse("off").unwrap(), p);
        assert_eq!(FaultPolicy::parse("").unwrap(), p);
    }

    #[test]
    fn policy_spec_parse_roundtrip() {
        for s in [
            "deadline:50,quorum:0.75,respawns:2,backoff:10",
            "deadline:1,quorum:1,respawns:0,backoff:0",
            "deadline:0,quorum:1,respawns:3,backoff:250",
        ] {
            let p = FaultPolicy::parse(s).unwrap();
            assert_eq!(FaultPolicy::parse(&p.spec()).unwrap(), p, "spec {s}");
        }
        // subset parses fill defaults, then canonicalize stably
        let p = FaultPolicy::parse("deadline:25").unwrap();
        assert_eq!(p.deadline_ms, 25);
        assert_eq!(p.quorum, 1.0);
        assert_eq!(FaultPolicy::parse(&p.spec()).unwrap(), p);
    }

    #[test]
    fn policy_rejects_bad_fields() {
        assert!(FaultPolicy::parse("quorum:0").is_err());
        assert!(FaultPolicy::parse("quorum:1.5,deadline:10").is_err());
        assert!(FaultPolicy::parse("quorum:nan,deadline:10").is_err());
        // quorum < 1 without a deadline can never absorb early
        assert!(FaultPolicy::parse("quorum:0.5").is_err());
        assert!(FaultPolicy::parse("deadline:ten").is_err());
        assert!(FaultPolicy::parse("pizza:1").is_err());
        assert!(FaultPolicy::parse("deadline=10").is_err());
    }

    #[test]
    fn quorum_min_is_ceil_clamped() {
        let mut p = FaultPolicy::off();
        p.deadline_ms = 10;
        p.quorum = 0.5;
        assert_eq!(p.quorum_min(4), 2);
        assert_eq!(p.quorum_min(5), 3);
        p.quorum = 0.01;
        assert_eq!(p.quorum_min(4), 1);
        p.quorum = 1.0;
        assert_eq!(p.quorum_min(4), 4);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = FaultPolicy { backoff_ms: 10, ..FaultPolicy::off() };
        assert_eq!(p.backoff_for(1), 10);
        assert_eq!(p.backoff_for(2), 20);
        assert_eq!(p.backoff_for(3), 40);
        assert!(p.backoff_for(64) <= 30_000);
    }

    #[test]
    fn plan_builder_and_lookup() {
        let plan = FaultPlan::new()
            .with(0, 3, FaultKind::Panic)
            .with(2, 5, FaultKind::DelayMs(40))
            .with(1, 7, FaultKind::Drop);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.at(0, 3), Some(FaultKind::Panic));
        assert_eq!(plan.at(2, 5), Some(FaultKind::DelayMs(40)));
        assert_eq!(plan.at(1, 7), Some(FaultKind::Drop));
        assert_eq!(plan.at(0, 4), None);
        assert_eq!(plan.counts(), (1, 1, 1));
    }

    #[test]
    fn seeded_plan_is_deterministic_and_in_domain() {
        let a = FaultPlan::seeded(9, 4, 30, 5, 6, 25);
        let b = FaultPlan::seeded(9, 4, 30, 5, 6, 25);
        assert_eq!(a.len(), 6);
        for (&(w, s), kind) in &a.events {
            assert!(w < 4 && (5..30).contains(&s));
            assert_eq!(b.at(w, s), Some(*kind), "same seed, same plan");
        }
        let c = FaultPlan::seeded(10, 4, 30, 5, 6, 25);
        let mut ka: Vec<_> = a.events.keys().copied().collect();
        let mut kc: Vec<_> = c.events.keys().copied().collect();
        ka.sort_unstable();
        kc.sort_unstable();
        assert_ne!(ka, kc, "different seeds should differ");
    }
}
