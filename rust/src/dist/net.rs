//! Socket transport: length-prefixed frames, per-worker connection
//! supervisors, heartbeats, and elastic id-slot membership.
//!
//! ## Frame format
//!
//! Every hop is one frame: a `u32` little-endian payload length (bounded
//! by [`MAX_FRAME`], checked *before* any allocation) followed by the
//! payload — a one-byte tag plus the tag's fields. Variable-size fields
//! (strings, codec buffers, layer tensors) carry their own length/shape
//! prefixes, and the decoder bounds-checks every count against the bytes
//! actually present before allocating, so a hostile length field yields a
//! named error, never a panic or an attacker-sized allocation. Round and
//! reply payloads carry the existing `codec::encode` buffers — the wire
//! format of the compressed hops is exactly the channel transport's
//! `Encoded` mode, which emits exactly `wire_bytes()` bytes and
//! round-trips losslessly. That is what makes the loopback ≡ channel
//! bitwise golden possible: same bytes, same decode, same trajectory.
//!
//! ## Supervision and heartbeats
//!
//! The leader runs two threads per connected worker: a writer that ships
//! `ToWorker` commands (and injects [`FlakyPlan`] faults deterministically)
//! and a reader that routes `Init`/`Reply` frames into the coordinator's
//! existing reply channel. The worker sends a heartbeat whenever it has
//! been idle for a heartbeat interval; the leader's reader counts
//! consecutive receive timeouts and, at [`NetCfg::miss_threshold`], tears
//! the link down. Heartbeats flow worker → leader only: the worker detects
//! a dead leader by EOF / write errors, which is enough because the worker
//! side is the one that redials.
//!
//! ## Failure model
//!
//! A dead link surfaces as the existing [`FromWorker::Failed`] path, so the
//! PR-6 deadline/quorum/respawn machinery absorbs it instead of hanging:
//! the coordinator skips the dead id's in-flight slots and asks the hub to
//! *reclaim* the slot, which parks it as free (seeded with the current
//! server shift `W`) until some connection — the old worker redialing, or
//! a brand-new late joiner — claims it through the `Hello`/`Assign`
//! handshake and re-runs the `INIT_STEP` re-init. An EF21-P worker that
//! missed a broadcast cannot resume mid-stream (its shift mirror would
//! desync), so reconnection is always re-initialization; determinism
//! survives because a worker's compute identity lives in the slot id
//! (RNG streams, batch sampling, estimator terms are all keyed on it),
//! making it irrelevant *which* physical connection holds the slot.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::compress::codec;
use crate::linalg::matrix::{Layers, Matrix};
use crate::opt::ef21::WorkerState;
use crate::spec::CompSpec;
use crate::trace::{Phase, Tracer};

use super::comm::{FromWorker, ToWorker, Wire};
use super::coordinator::worker_main;
use super::fault::{FaultPlan, FaultPolicy};
use super::service::GradHandle;
use super::Meter;

/// Upper bound on one frame's payload. Checked against the length prefix
/// *before* allocating, so a corrupt or hostile prefix can never trigger an
/// unbounded allocation. 256 MiB comfortably holds the dense `w0` layers of
/// an `Assign` for any model this crate runs.
pub const MAX_FRAME: usize = 1 << 28;

const TAG_HELLO: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_REJECT: u8 = 3;
const TAG_INIT: u8 = 4;
const TAG_ROUND: u8 = 5;
const TAG_REPLY: u8 = 6;
const TAG_FAILED: u8 = 7;
const TAG_HEARTBEAT: u8 = 8;
const TAG_STOP: u8 = 9;

/// `Failed` frames truncate their error text to this many bytes — the
/// message is diagnostic, and an unbounded string would let one failure
/// report balloon a control frame.
const MAX_ERR_BYTES: usize = 512;

/// One wire message. `Hello`/`Assign`/`Reject` are the membership
/// handshake; `Init`/`Round`/`Reply`/`Failed` mirror the channel
/// transport's [`ToWorker`]/[`FromWorker`]; `Heartbeat`/`Stop` are link
/// control.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Worker → leader, first frame on every connection. `prev` is the slot
    /// id a reconnecting worker held before its link died; the hub prefers
    /// to hand the same slot back when it is still free.
    Hello { prev: Option<usize> },
    /// Leader → worker: the claimed slot and everything a fresh
    /// [`WorkerState`] needs — the deployment seed, momentum β, the w2s
    /// compressor grammar, and the current server shift `W` to mirror.
    Assign { id: usize, seed: u64, beta: f32, comp: String, w0: Layers },
    /// Leader → worker: no free slot (the deployment is full).
    Reject,
    /// Worker → leader: the `INIT_STEP` gradient `G⁰ⱼ`.
    Init { id: usize, g0: Layers },
    /// Leader → worker: one round's broadcast as `codec::encode` buffers.
    Round { step: usize, bufs: Vec<Vec<u8>> },
    /// Worker → leader: one round's uplink as `codec::encode` buffers.
    /// `bytes` is the metered w2s byte count (identical to the buffer sum
    /// by the codec's exactness contract).
    Reply { id: usize, step: usize, loss: f32, bytes: usize, bufs: Vec<Vec<u8>> },
    /// Worker → leader: irrecoverable worker-side failure.
    Failed { id: usize, err: String },
    /// Worker → leader: alive, nothing to report.
    Heartbeat,
    /// Leader → worker: exit cleanly.
    Stop,
}

impl Frame {
    /// Compact tag name for error messages (a `Debug` render would dump
    /// whole layer tensors).
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::Assign { .. } => "assign",
            Frame::Reject => "reject",
            Frame::Init { .. } => "init",
            Frame::Round { .. } => "round",
            Frame::Reply { .. } => "reply",
            Frame::Failed { .. } => "failed",
            Frame::Heartbeat => "heartbeat",
            Frame::Stop => "stop",
        }
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_layers(out: &mut Vec<u8>, layers: &Layers) {
    put_u32(out, layers.len() as u32);
    for m in layers {
        put_u32(out, m.rows as u32);
        put_u32(out, m.cols as u32);
        for &x in &m.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn put_bufs(out: &mut Vec<u8>, bufs: &[Vec<u8>]) {
    put_u32(out, bufs.len() as u32);
    for b in bufs {
        put_u32(out, b.len() as u32);
        out.extend_from_slice(b);
    }
}

/// Serialize one frame's payload (everything after the length prefix).
pub fn encode_payload(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    match frame {
        Frame::Hello { prev } => {
            out.push(TAG_HELLO);
            match prev {
                None => out.push(0),
                Some(id) => {
                    out.push(1);
                    put_u64(&mut out, *id as u64);
                }
            }
        }
        Frame::Assign { id, seed, beta, comp, w0 } => {
            out.push(TAG_ASSIGN);
            put_u64(&mut out, *id as u64);
            put_u64(&mut out, *seed);
            put_u32(&mut out, beta.to_bits());
            put_str(&mut out, comp);
            put_layers(&mut out, w0);
        }
        Frame::Reject => out.push(TAG_REJECT),
        Frame::Init { id, g0 } => {
            out.push(TAG_INIT);
            put_u64(&mut out, *id as u64);
            put_layers(&mut out, g0);
        }
        Frame::Round { step, bufs } => {
            out.push(TAG_ROUND);
            put_u64(&mut out, *step as u64);
            put_bufs(&mut out, bufs);
        }
        Frame::Reply { id, step, loss, bytes, bufs } => {
            out.push(TAG_REPLY);
            put_u64(&mut out, *id as u64);
            put_u64(&mut out, *step as u64);
            put_u32(&mut out, loss.to_bits());
            put_u64(&mut out, *bytes as u64);
            put_bufs(&mut out, bufs);
        }
        Frame::Failed { id, err } => {
            out.push(TAG_FAILED);
            put_u64(&mut out, *id as u64);
            let b = err.as_bytes();
            let cut = b.len().min(MAX_ERR_BYTES);
            put_u32(&mut out, cut as u32);
            out.extend_from_slice(&b[..cut]);
        }
        Frame::Heartbeat => out.push(TAG_HEARTBEAT),
        Frame::Stop => out.push(TAG_STOP),
    }
    out
}

/// Bounds-checked payload cursor: every read names what it wanted and how
/// many bytes were actually left, and nothing is allocated from a declared
/// count before the bytes backing it are known to be present.
struct Take<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Take<'a> {
    fn new(buf: &'a [u8]) -> Take<'a> {
        Take { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        let rem = self.buf.len() - self.pos;
        if n > rem {
            return Err(format!(
                "frame: {what} needs {n} byte(s), {rem} left of a {}-byte payload",
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, String> {
        let b = self.bytes(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    /// A `u64` field used as an index/size on this machine.
    fn idx(&mut self, what: &str) -> Result<usize, String> {
        let v = self.u64(what)?;
        usize::try_from(v).map_err(|_| format!("frame: {what} {v} overflows usize"))
    }

    /// Reject trailing garbage: a valid payload is consumed exactly.
    fn done(self) -> Result<(), String> {
        let rem = self.buf.len() - self.pos;
        if rem != 0 {
            return Err(format!("frame: {rem} trailing byte(s) after the payload"));
        }
        Ok(())
    }
}

fn take_str(t: &mut Take, what: &str) -> Result<String, String> {
    let len = t.u32(what)? as usize;
    let b = t.bytes(len, what)?;
    Ok(String::from_utf8_lossy(b).into_owned())
}

fn take_bufs(t: &mut Take, what: &str) -> Result<Vec<Vec<u8>>, String> {
    let n = t.u32(what)? as usize;
    // grown buffer by buffer — never pre-sized from a claimed count
    let mut bufs = Vec::new();
    for _ in 0..n {
        let len = t.u32(what)? as usize;
        bufs.push(t.bytes(len, what)?.to_vec());
    }
    Ok(bufs)
}

fn take_layers(t: &mut Take, what: &str) -> Result<Layers, String> {
    let n = t.u32(what)? as usize;
    let mut layers = Vec::new();
    for _ in 0..n {
        let rows = t.u32(what)? as usize;
        let cols = t.u32(what)? as usize;
        let elems = rows
            .checked_mul(cols)
            .ok_or_else(|| format!("frame: {what} shape {rows}x{cols} overflows"))?;
        let nbytes = elems
            .checked_mul(4)
            .ok_or_else(|| format!("frame: {what} shape {rows}x{cols} overflows"))?;
        // bounds-checked before the f32 buffer is allocated
        let raw = t.bytes(nbytes, what)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        layers.push(Matrix::from_vec(rows, cols, data));
    }
    Ok(layers)
}

/// Deserialize one frame payload. Total: every truncated, bit-flipped, or
/// hostile-length input returns a named error (`rust/src/dist/net.rs`
/// tests fuzz this), and no allocation is sized by unvalidated input.
pub fn decode_payload(buf: &[u8]) -> Result<Frame, String> {
    let mut t = Take::new(buf);
    let tag = t.u8("frame tag")?;
    let frame = match tag {
        TAG_HELLO => {
            let flag = t.u8("hello prev flag")?;
            let prev = match flag {
                0 => None,
                1 => Some(t.idx("hello prev id")?),
                other => return Err(format!("frame: hello prev flag must be 0/1, got {other}")),
            };
            Frame::Hello { prev }
        }
        TAG_ASSIGN => Frame::Assign {
            id: t.idx("assign id")?,
            seed: t.u64("assign seed")?,
            beta: f32::from_bits(t.u32("assign beta")?),
            comp: take_str(&mut t, "assign comp spec")?,
            w0: take_layers(&mut t, "assign w0")?,
        },
        TAG_REJECT => Frame::Reject,
        TAG_INIT => Frame::Init {
            id: t.idx("init id")?,
            g0: take_layers(&mut t, "init g0")?,
        },
        TAG_ROUND => Frame::Round {
            step: t.idx("round step")?,
            bufs: take_bufs(&mut t, "round bufs")?,
        },
        TAG_REPLY => Frame::Reply {
            id: t.idx("reply id")?,
            step: t.idx("reply step")?,
            loss: f32::from_bits(t.u32("reply loss")?),
            bytes: t.idx("reply bytes")?,
            bufs: take_bufs(&mut t, "reply bufs")?,
        },
        TAG_FAILED => Frame::Failed {
            id: t.idx("failed id")?,
            err: take_str(&mut t, "failed err")?,
        },
        TAG_HEARTBEAT => Frame::Heartbeat,
        TAG_STOP => Frame::Stop,
        other => return Err(format!("frame: unknown tag {other}")),
    };
    t.done()?;
    Ok(frame)
}

/// Why a [`Link::recv`] produced no frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LinkErr {
    /// Nothing arrived within the read timeout *at a frame boundary* — the
    /// peer may just be idle; heartbeat accounting decides.
    Timeout,
    /// The link is gone (EOF, I/O error, or a stall in the middle of a
    /// frame — after which the stream can never be re-synchronized).
    Closed(String),
    /// The bytes arrived but are not a valid frame.
    Corrupt(String),
}

impl std::fmt::Display for LinkErr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinkErr::Timeout => write!(f, "link idle past the read timeout"),
            LinkErr::Closed(s) | LinkErr::Corrupt(s) => write!(f, "{s}"),
        }
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Write one frame: `u32` LE payload length, then the payload, one
/// `write_all` + flush.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    let payload = encode_payload(frame);
    debug_assert!(payload.len() <= MAX_FRAME, "oversized {} frame", frame.kind());
    let mut buf = Vec::with_capacity(4 + payload.len());
    put_u32(&mut buf, payload.len() as u32);
    buf.extend_from_slice(&payload);
    w.write_all(&buf)?;
    w.flush()
}

/// Fill `buf` completely. `at_boundary` marks the first bytes of a frame:
/// only there is a timeout with *zero bytes read* a clean [`LinkErr::Timeout`]
/// (peer idle). A timeout or EOF mid-frame is [`LinkErr::Closed`] — once a
/// frame is half-read the stream can never be re-aligned, so pretending the
/// link is merely idle would corrupt every later frame.
fn fill(r: &mut impl Read, buf: &mut [u8], at_boundary: bool) -> Result<(), LinkErr> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(LinkErr::Closed(if got == 0 && at_boundary {
                    "peer closed the connection".into()
                } else {
                    "peer closed mid-frame".into()
                }))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                if got == 0 && at_boundary {
                    return Err(LinkErr::Timeout);
                }
                return Err(LinkErr::Closed("stream stalled mid-frame".into()));
            }
            Err(e) => return Err(LinkErr::Closed(format!("read error: {e}"))),
        }
    }
    Ok(())
}

/// Read one frame. The length prefix is validated against [`MAX_FRAME`]
/// before the payload buffer is allocated.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, LinkErr> {
    let mut head = [0u8; 4];
    fill(r, &mut head, true)?;
    let len = u32::from_le_bytes(head) as usize;
    if len > MAX_FRAME {
        return Err(LinkErr::Corrupt(format!(
            "frame length {len} exceeds the {MAX_FRAME}-byte bound"
        )));
    }
    let mut payload = vec![0u8; len];
    fill(r, &mut payload, false)?;
    decode_payload(&payload).map_err(LinkErr::Corrupt)
}

/// One frame-granular duplex endpoint. Both the in-memory channel pair and
/// a TCP stream implement it, so everything above the frame layer is
/// transport-agnostic.
pub trait Link: Send {
    fn send(&mut self, frame: &Frame) -> Result<(), String>;
    fn recv(&mut self) -> Result<Frame, LinkErr>;
}

/// A [`Link`] over a TCP stream (timeouts are configured on the stream by
/// whoever dialed/accepted it).
pub struct TcpLink {
    stream: TcpStream,
}

impl TcpLink {
    pub fn new(stream: TcpStream) -> TcpLink {
        TcpLink { stream }
    }

    pub fn into_stream(self) -> TcpStream {
        self.stream
    }
}

impl Link for TcpLink {
    fn send(&mut self, frame: &Frame) -> Result<(), String> {
        write_frame(&mut self.stream, frame)
            .map_err(|e| format!("link write ({}): {e}", frame.kind()))
    }

    fn recv(&mut self) -> Result<Frame, LinkErr> {
        read_frame(&mut self.stream)
    }
}

/// A [`Link`] over in-process channels — the loopback reference the TCP
/// endpoint must be indistinguishable from at the frame layer.
pub struct ChannelLink {
    tx: Sender<Frame>,
    rx: Receiver<Frame>,
    idle: Duration,
}

impl ChannelLink {
    /// A crossed pair of endpoints; `idle` plays the role of the socket
    /// read timeout.
    pub fn pair(idle: Duration) -> (ChannelLink, ChannelLink) {
        let (a2b_tx, a2b_rx) = channel();
        let (b2a_tx, b2a_rx) = channel();
        (
            ChannelLink { tx: a2b_tx, rx: b2a_rx, idle },
            ChannelLink { tx: b2a_tx, rx: a2b_rx, idle },
        )
    }
}

impl Link for ChannelLink {
    fn send(&mut self, frame: &Frame) -> Result<(), String> {
        self.tx
            .send(frame.clone())
            .map_err(|_| "link peer dropped".to_string())
    }

    fn recv(&mut self) -> Result<Frame, LinkErr> {
        match self.rx.recv_timeout(self.idle) {
            Ok(f) => Ok(f),
            Err(RecvTimeoutError::Timeout) => Err(LinkErr::Timeout),
            Err(RecvTimeoutError::Disconnected) => {
                Err(LinkErr::Closed("link peer dropped".into()))
            }
        }
    }
}

/// Transport-level fault to inject on one broadcast frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlakyKind {
    /// Swallow the frame and sever the link (the worker sees EOF) — a
    /// dropped connection mid-broadcast.
    DropFrame,
    /// Hold the frame for this many milliseconds before sending.
    DelayFrameMs(u64),
    /// Send a frame whose length prefix promises the full payload but whose
    /// body stops halfway — the peer gets a named mid-frame decode error.
    TruncateFrame,
}

/// Deterministic transport-fault schedule, keyed by `(worker, step)` like
/// [`FaultPlan`] — every network failure mode is reproducible without a
/// real flaky network. Consulted by the leader-side writer when it ships
/// that worker's `Round { step }` frame.
#[derive(Debug, Clone, Default)]
pub struct FlakyPlan {
    at: HashMap<(usize, usize), FlakyKind>,
}

impl FlakyPlan {
    pub fn new() -> FlakyPlan {
        FlakyPlan::default()
    }

    /// Builder: inject `kind` on worker `worker`'s broadcast of round
    /// `step`.
    pub fn with(mut self, worker: usize, step: usize, kind: FlakyKind) -> FlakyPlan {
        self.at.insert((worker, step), kind);
        self
    }

    pub fn at(&self, worker: usize, step: usize) -> Option<FlakyKind> {
        self.at.get(&(worker, step)).copied()
    }

    pub fn len(&self) -> usize {
        self.at.len()
    }

    pub fn is_empty(&self) -> bool {
        self.at.is_empty()
    }
}

/// Configuration of the leader-side socket endpoint.
#[derive(Debug, Clone)]
pub struct NetCfg {
    /// Listen address, e.g. `"127.0.0.1:0"` (port 0 = kernel-assigned;
    /// read it back via [`NetHub::local_addr`]).
    pub listen: String,
    /// Worker heartbeat interval; the leader-side read timeout matches it.
    pub heartbeat_ms: u64,
    /// Consecutive silent heartbeat intervals before the leader declares
    /// the link dead.
    pub miss_threshold: u32,
    /// Connect-phase I/O timeout (handshake reads/writes).
    pub io_timeout_ms: u64,
    /// Unused by the hub itself; documented here so both ends share one
    /// config vocabulary.
    pub connect_timeout_ms: u64,
    /// How long [`NetHub::reclaim`] waits for some connection to claim a
    /// freed slot before the respawn is declared failed.
    pub claim_deadline_ms: u64,
    /// Deterministic transport-fault injection (tests/benches only).
    pub flaky: Option<Arc<FlakyPlan>>,
}

impl Default for NetCfg {
    fn default() -> NetCfg {
        NetCfg {
            listen: "127.0.0.1:0".into(),
            heartbeat_ms: 500,
            miss_threshold: 3,
            io_timeout_ms: 2_000,
            connect_timeout_ms: 1_000,
            claim_deadline_ms: 10_000,
            flaky: None,
        }
    }
}

/// One id slot in the hub's membership registry.
enum SlotState {
    /// Unclaimed. `w0` is the shift a claimant's [`WorkerState`] must
    /// mirror (X⁰ initially, the current server `W` after a reclaim);
    /// `reclaim` marks re-opened slots so the reconnect meter counts only
    /// genuine reconnections, not first joins.
    Free { w0: Layers, reclaim: bool },
    Claimed,
}

/// A successfully assigned connection, queued for the coordinator to
/// collect ([`NetHub::wait_initial`] / [`NetHub::reclaim`]).
pub(crate) struct Claim {
    pub(crate) id: usize,
    /// Command sender feeding the connection's writer thread.
    pub(crate) tx: Sender<ToWorker>,
    /// The connection's reader thread (joined on coordinator drop).
    pub(crate) reader: JoinHandle<()>,
}

/// Everything the hub needs to run handshakes for one deployment. Armed by
/// `Coordinator::spawn_net` once the reply channel and meter exist.
pub(crate) struct ArmSpec {
    pub(crate) n_workers: usize,
    pub(crate) w0: Layers,
    pub(crate) comp: CompSpec,
    pub(crate) beta: f32,
    pub(crate) seed: u64,
    pub(crate) reply_tx: Sender<FromWorker>,
    pub(crate) meter: Arc<Meter>,
    pub(crate) tracer: Tracer,
}

struct Armed {
    comp: CompSpec,
    beta: f32,
    seed: u64,
    reply_tx: Sender<FromWorker>,
    meter: Arc<Meter>,
    tracer: Tracer,
    slots: Vec<SlotState>,
    claims: VecDeque<Claim>,
}

#[derive(Default)]
struct HubState {
    armed: Option<Armed>,
    accept_join: Option<JoinHandle<()>>,
}

/// The leader-side socket endpoint: accepts connections, runs the
/// `Hello`/`Assign` membership handshake against an id-slot registry, and
/// supervises one writer + reader thread per claimed slot.
pub struct NetHub {
    cfg: NetCfg,
    local: SocketAddr,
    state: Mutex<HubState>,
    cv: Condvar,
    closing: AtomicBool,
}

impl NetHub {
    /// Bind the listen address and start accepting. Connections arriving
    /// before the hub is armed wait in their handshake; the address (with
    /// the kernel-assigned port resolved) is available immediately, so
    /// callers can bind port 0, read [`NetHub::local_addr`], and point
    /// workers at it before `Coordinator::spawn_net` runs.
    pub fn bind(cfg: NetCfg) -> Result<Arc<NetHub>> {
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| anyhow!("binding {}: {e}", cfg.listen))?;
        let local = listener
            .local_addr()
            .map_err(|e| anyhow!("reading bound address: {e}"))?;
        let hub = Arc::new(NetHub {
            cfg,
            local,
            state: Mutex::new(HubState::default()),
            cv: Condvar::new(),
            closing: AtomicBool::new(false),
        });
        let h = hub.clone();
        let join = std::thread::Builder::new()
            .name("efmuon-net-accept".into())
            .spawn(move || h.accept_loop(listener))
            .map_err(|e| anyhow!("spawning accept thread: {e}"))?;
        hub.lock().accept_join = Some(join);
        Ok(hub)
    }

    /// The bound address (kernel-assigned port resolved).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// A worker thread holding this lock may have panicked (injected
    /// faults do exactly that); the registry it protects is updated in
    /// full before any wait, so a poisoned guard's data is still coherent.
    fn lock(&self) -> MutexGuard<'_, HubState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    fn is_closing(&self) -> bool {
        self.closing.load(Ordering::SeqCst)
    }

    fn accept_loop(&self, listener: TcpListener) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.is_closing() {
                        return;
                    }
                    // a failed handshake abandons only that connection
                    let _ = self.handshake(stream);
                }
                Err(_) => {
                    if self.is_closing() {
                        return;
                    }
                }
            }
        }
    }

    /// Arm the hub for one deployment: open `n_workers` free slots seeded
    /// with X⁰ and store everything handshakes need.
    pub(crate) fn arm(&self, spec: ArmSpec) {
        let mut st = self.lock();
        st.armed = Some(Armed {
            comp: spec.comp,
            beta: spec.beta,
            seed: spec.seed,
            reply_tx: spec.reply_tx,
            meter: spec.meter,
            tracer: spec.tracer,
            slots: (0..spec.n_workers)
                .map(|_| SlotState::Free { w0: spec.w0.clone(), reclaim: false })
                .collect(),
            claims: VecDeque::new(),
        });
        drop(st);
        self.cv.notify_all();
    }

    /// Wait until `n` connections have claimed slots, then hand their
    /// claims over in id order.
    pub(crate) fn wait_initial(&self, n: usize) -> Result<Vec<Claim>> {
        let deadline = Instant::now() + Duration::from_millis(self.cfg.claim_deadline_ms);
        let mut st = self.lock();
        loop {
            let have = st.armed.as_ref().map_or(0, |a| a.claims.len());
            if have >= n {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(anyhow!(
                    "only {have} of {n} worker(s) connected to {} within {} ms",
                    self.local,
                    self.cfg.claim_deadline_ms
                ));
            }
            let (g, _) = match self.cv.wait_timeout(st, deadline - now) {
                Ok(v) => v,
                Err(p) => p.into_inner(),
            };
            st = g;
        }
        let armed = st.armed.as_mut().expect("armed with claims");
        let mut claims: Vec<Claim> = armed.claims.drain(..).collect();
        claims.sort_by_key(|c| c.id);
        Ok(claims)
    }

    /// Re-open slot `id` (its link died) seeded with the current server
    /// shift, and wait for some connection — the old worker redialing or a
    /// fresh late joiner — to claim it.
    pub(crate) fn reclaim(&self, id: usize, w0: &Layers) -> Result<(Sender<ToWorker>, JoinHandle<()>)> {
        let deadline = Instant::now() + Duration::from_millis(self.cfg.claim_deadline_ms);
        let mut st = self.lock();
        {
            let armed = st.armed.as_mut().expect("reclaim on an armed hub");
            armed.slots[id] = SlotState::Free { w0: w0.clone(), reclaim: true };
        }
        drop(st);
        self.cv.notify_all();
        let mut st = self.lock();
        loop {
            let armed = st.armed.as_mut().expect("reclaim on an armed hub");
            if let Some(pos) = armed.claims.iter().position(|c| c.id == id) {
                let claim = armed.claims.remove(pos).expect("position just found");
                return Ok((claim.tx, claim.reader));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(anyhow!(
                    "no worker claimed freed slot {id} within {} ms",
                    self.cfg.claim_deadline_ms
                ));
            }
            let (g, _) = match self.cv.wait_timeout(st, deadline - now) {
                Ok(v) => v,
                Err(p) => p.into_inner(),
            };
            st = g;
        }
    }

    /// Stop accepting and join the accept thread. Idempotent; the
    /// coordinator's `Drop` calls it, but callers whose `spawn_net` failed
    /// should call it themselves.
    pub fn close(&self) {
        self.closing.store(true, Ordering::SeqCst);
        // wake the accept loop out of its blocking accept()
        let _ = TcpStream::connect(self.local);
        self.cv.notify_all();
        let join = self.lock().accept_join.take();
        if let Some(j) = join {
            let _ = j.join();
        }
    }

    /// Run the membership handshake on one fresh connection: read `Hello`,
    /// wait for the hub to be armed, pick a free slot (preferring the
    /// claimant's previous id), send `Assign` (or `Reject` when full), and
    /// start the slot's writer/reader supervisor threads.
    fn handshake(&self, stream: TcpStream) -> Result<()> {
        let io = Duration::from_millis(self.cfg.io_timeout_ms);
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(io))
            .map_err(|e| anyhow!("handshake read timeout: {e}"))?;
        stream
            .set_write_timeout(Some(io))
            .map_err(|e| anyhow!("handshake write timeout: {e}"))?;
        let mut reader = stream;
        let mut writer = reader
            .try_clone()
            .map_err(|e| anyhow!("cloning handshake stream: {e}"))?;
        let prev = match read_frame(&mut reader) {
            Ok(Frame::Hello { prev }) => prev,
            Ok(f) => return Err(anyhow!("expected hello, got {} frame", f.kind())),
            Err(e) => return Err(anyhow!("reading hello: {e}")),
        };

        // wait for arm (bounded polls so close() can abort the wait)
        let mut st = self.lock();
        while st.armed.is_none() {
            if self.is_closing() {
                return Err(anyhow!("hub closing before arm"));
            }
            let (g, _) = match self.cv.wait_timeout(st, Duration::from_millis(50)) {
                Ok(v) => v,
                Err(p) => p.into_inner(),
            };
            st = g;
        }
        let armed = st.armed.as_mut().expect("armed checked above");

        // prefer the claimant's previous slot, else the lowest free one
        let free = |s: &SlotState| matches!(s, SlotState::Free { .. });
        let slot = prev
            .filter(|&p| p < armed.slots.len() && free(&armed.slots[p]))
            .or_else(|| armed.slots.iter().position(free));
        let id = match slot {
            Some(id) => id,
            None => {
                drop(st);
                write_frame(&mut writer, &Frame::Reject)
                    .map_err(|e| anyhow!("writing reject: {e}"))?;
                return Ok(());
            }
        };
        let taken = std::mem::replace(&mut armed.slots[id], SlotState::Claimed);
        let (w0, reclaimed) = match taken {
            SlotState::Free { w0, reclaim } => (w0, reclaim),
            SlotState::Claimed => unreachable!("slot was free"),
        };
        let assign = Frame::Assign {
            id,
            seed: armed.seed,
            beta: armed.beta,
            comp: armed.comp.spec(),
            w0,
        };
        let reply_tx = armed.reply_tx.clone();
        let meter = armed.meter.clone();
        let tracer = armed.tracer.clone();
        drop(st);

        if let Err(e) = write_frame(&mut writer, &assign) {
            // hand the slot back so another connection can claim it
            let w0 = match assign {
                Frame::Assign { w0, .. } => w0,
                _ => unreachable!("assign frame"),
            };
            let mut st = self.lock();
            if let Some(armed) = st.armed.as_mut() {
                armed.slots[id] = SlotState::Free { w0, reclaim: reclaimed };
            }
            return Err(anyhow!("writing assign to worker {id}: {e}"));
        }

        let (tx, rx) = channel::<ToWorker>();
        let link_closing = Arc::new(AtomicBool::new(false));
        let flaky = self.cfg.flaky.clone();
        let wclosing = link_closing.clone();
        std::thread::Builder::new()
            .name(format!("efmuon-net-send-{id}"))
            .spawn(move || writer_loop(writer, rx, id, flaky, wclosing))
            .map_err(|e| anyhow!("spawning writer for worker {id}: {e}"))?;
        let ctx = ReaderCtx {
            stream: reader,
            id,
            reply_tx,
            meter: meter.clone(),
            tracer: tracer.clone(),
            miss_threshold: self.cfg.miss_threshold,
            closing: link_closing,
        };
        let reader = std::thread::Builder::new()
            .name(format!("efmuon-net-recv-{id}"))
            .spawn(move || reader_loop(ctx))
            .map_err(|e| anyhow!("spawning reader for worker {id}: {e}"))?;

        let mut st = self.lock();
        if reclaimed {
            // counted only after a successful Assign: the meter reports
            // completed reconnections, not dial attempts
            meter.record_reconnect();
            tracer.stamp(Phase::NetReconnect, 0, Some(id));
        } else {
            tracer.stamp(Phase::NetConnect, 0, Some(id));
        }
        if let Some(armed) = st.armed.as_mut() {
            armed.claims.push_back(Claim { id, tx, reader });
        }
        drop(st);
        self.cv.notify_all();
        Ok(())
    }
}

/// `Wire` → codec buffers for the socket hop. `Encoded` passes through;
/// `Counted` (the in-memory analytic mode) is encoded here — the codec
/// emits exactly `wire_bytes()` bytes and round-trips losslessly, so byte
/// meters and trajectories stay bit-identical to the channel run in either
/// transport mode.
fn encode_wire(wire: Wire) -> Vec<Vec<u8>> {
    match wire {
        Wire::Encoded(bufs) => bufs,
        Wire::Counted(msgs) => msgs.iter().map(codec::encode).collect(),
    }
}

/// Leader-side per-link writer: ships `ToWorker` commands as frames,
/// injecting [`FlakyPlan`] faults deterministically. Exits on `Stop`, on a
/// write error (the reader will notice the dead link), or when the
/// coordinator replaces this link's sender (channel disconnect).
fn writer_loop(
    mut stream: TcpStream,
    rx: Receiver<ToWorker>,
    id: usize,
    flaky: Option<Arc<FlakyPlan>>,
    closing: Arc<AtomicBool>,
) {
    while let Ok(cmd) = rx.recv() {
        let (step, broadcast) = match cmd {
            ToWorker::Stop => {
                // mark the link as deliberately closing *before* the Stop
                // frame, so the reader treats the resulting EOF as clean
                closing.store(true, Ordering::SeqCst);
                let _ = write_frame(&mut stream, &Frame::Stop);
                return;
            }
            ToWorker::Release { .. } | ToWorker::Accept { .. } => {
                // layer migration is never sent over the socket transport
                // (stealing requires multiple shards; `--transport tcp:`
                // requires one) — sever the link so the coordinator gets a
                // clean Failed instead of a silently dropped command
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
            ToWorker::Round { step, broadcast } => (step, broadcast),
        };
        let fault = flaky.as_ref().and_then(|p| p.at(id, step));
        let bufs = encode_wire(broadcast);
        match fault {
            Some(FlakyKind::DropFrame) => {
                // swallow the frame and sever the link: the worker sees
                // EOF mid-round and redials; the reader sees EOF too and
                // routes the failure into the respawn path
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
            Some(FlakyKind::TruncateFrame) => {
                let payload = encode_payload(&Frame::Round { step, bufs });
                let cut = payload.len() / 2;
                let mut buf = Vec::with_capacity(4 + cut);
                put_u32(&mut buf, payload.len() as u32);
                buf.extend_from_slice(&payload[..cut]);
                // promise the full payload, deliver half: the peer gets a
                // named mid-frame error, never a desync
                let _ = stream.write_all(&buf).and_then(|_| stream.flush());
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
            Some(FlakyKind::DelayFrameMs(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            None => {}
        }
        if write_frame(&mut stream, &Frame::Round { step, bufs }).is_err() {
            return;
        }
    }
}

/// Everything the leader-side reader thread needs (bundled — the thread
/// outlives the handshake that configured it).
struct ReaderCtx {
    stream: TcpStream,
    id: usize,
    reply_tx: Sender<FromWorker>,
    meter: Arc<Meter>,
    tracer: Tracer,
    miss_threshold: u32,
    closing: Arc<AtomicBool>,
}

/// Route a dead link into the coordinator's existing failure path — unless
/// the link is deliberately closing (Stop sent), in which case the EOF is
/// the expected clean shutdown.
fn fail_link(ctx: &ReaderCtx, err: String) {
    if ctx.closing.load(Ordering::SeqCst) {
        return;
    }
    let _ = ctx.reply_tx.send(FromWorker::Failed { id: ctx.id, err });
}

/// Leader-side per-link reader: forwards worker frames into the reply
/// channel, counts heartbeat misses, and converts any link death into one
/// [`FromWorker::Failed`].
fn reader_loop(mut ctx: ReaderCtx) {
    let threshold = ctx.miss_threshold.max(1);
    let mut misses = 0u32;
    loop {
        match read_frame(&mut ctx.stream) {
            Ok(Frame::Heartbeat) => misses = 0,
            Ok(Frame::Init { id, g0 }) => {
                misses = 0;
                if id != ctx.id {
                    fail_link(&ctx, format!("init frame for id {id} on link {}", ctx.id));
                    return;
                }
                if ctx.reply_tx.send(FromWorker::Init { id, g0 }).is_err() {
                    return;
                }
            }
            Ok(Frame::Reply { id, step, loss, bytes, bufs }) => {
                misses = 0;
                if id != ctx.id {
                    fail_link(&ctx, format!("reply frame for id {id} on link {}", ctx.id));
                    return;
                }
                let uplink = Wire::Encoded(bufs);
                let msg = FromWorker::Round { id, step, loss, bytes, uplink };
                if ctx.reply_tx.send(msg).is_err() {
                    return;
                }
            }
            Ok(Frame::Failed { id: _, err }) => {
                // worker-reported failure (compute error or panic guard):
                // attribute it to this link's slot regardless of the tag
                fail_link(&ctx, err);
                return;
            }
            Ok(f) => {
                fail_link(&ctx, format!("unexpected {} frame from worker", f.kind()));
                return;
            }
            Err(LinkErr::Timeout) => {
                misses += 1;
                ctx.meter.record_heartbeat_miss();
                ctx.tracer.stamp(Phase::NetMiss, 0, Some(ctx.id));
                if misses >= threshold {
                    fail_link(&ctx, format!("worker missed {misses} heartbeat(s)"));
                    return;
                }
            }
            Err(e @ (LinkErr::Closed(_) | LinkErr::Corrupt(_))) => {
                fail_link(&ctx, format!("link lost: {e}"));
                return;
            }
        }
    }
}

/// Configuration of one worker process/thread dialing a leader.
#[derive(Debug, Clone)]
pub struct WorkerCfg {
    /// Leader address, e.g. `"127.0.0.1:4310"`.
    pub connect: String,
    /// Send a heartbeat after this long with nothing to report; also the
    /// worker-side read timeout.
    pub heartbeat_ms: u64,
    pub connect_timeout_ms: u64,
    /// How long to wait for a slot assignment after `Hello` (the leader may
    /// not be armed yet).
    pub assign_timeout_ms: u64,
    /// Redial budget for *failed* dials (refused, rejected, no assignment).
    /// A lost established link redials immediately with a fresh budget.
    pub redial_attempts: u32,
    /// Base of the exponential redial backoff
    /// ([`FaultPolicy::backoff_for`]).
    pub backoff_ms: u64,
}

impl Default for WorkerCfg {
    fn default() -> WorkerCfg {
        WorkerCfg {
            connect: "127.0.0.1:4310".into(),
            heartbeat_ms: 500,
            connect_timeout_ms: 1_000,
            assign_timeout_ms: 30_000,
            redial_attempts: 40,
            backoff_ms: 20,
        }
    }
}

/// How one dialed session ended without error.
enum SessionEnd {
    /// The leader sent `Stop`: the run is over.
    Stopped,
    /// The link died mid-run; redial immediately and ask for the same slot.
    LinkLost { id: usize },
}

/// Why one dial/session attempt failed.
enum SessionErr {
    /// Transient (connection refused, slot rejected, handshake timeout):
    /// retry with backoff, bounded by [`WorkerCfg::redial_attempts`].
    Retry(String),
    /// The worker itself is broken (compute error or panic, unusable
    /// assignment): redialing would re-fail, so the process dies — killed
    /// workers are the *coordinator's* respawn policy to absorb.
    Fatal(String),
}

/// Worker entry point: dial the leader, run EF21 rounds until `Stop`,
/// redialing with exponential backoff whenever the link (not the compute)
/// fails. Each reconnection re-runs the `INIT_STEP` handshake against the
/// leader's current shift — an EF21-P worker that missed a broadcast can
/// only rejoin by re-initializing.
pub fn worker_loop(
    cfg: &WorkerCfg,
    handle: &GradHandle,
    plan: Option<Arc<FaultPlan>>,
) -> Result<()> {
    let policy = FaultPolicy { backoff_ms: cfg.backoff_ms, ..FaultPolicy::off() };
    let mut prev: Option<usize> = None;
    let mut attempt: u32 = 0;
    loop {
        match dial_session(cfg, handle, plan.clone(), prev) {
            Ok(SessionEnd::Stopped) => return Ok(()),
            Ok(SessionEnd::LinkLost { id }) => {
                // an established link died: redial at once (the leader is
                // likely still there) and prefer the slot we held
                prev = Some(id);
                attempt = 0;
            }
            Err(SessionErr::Fatal(e)) => return Err(anyhow!(e)),
            Err(SessionErr::Retry(e)) => {
                attempt += 1;
                if attempt > cfg.redial_attempts {
                    return Err(anyhow!(
                        "giving up after {} dial attempt(s): {e}",
                        cfg.redial_attempts
                    ));
                }
                let backoff = policy.backoff_for(attempt);
                if backoff > 0 {
                    std::thread::sleep(Duration::from_millis(backoff));
                }
            }
        }
    }
}

/// A claimed slot assignment, parsed off the wire.
struct Session {
    id: usize,
    seed: u64,
    beta: f32,
    comp: String,
    w0: Layers,
}

/// One dial: connect, handshake (`Hello` → `Assign`/`Reject`), then run
/// the session until it ends.
fn dial_session(
    cfg: &WorkerCfg,
    handle: &GradHandle,
    plan: Option<Arc<FaultPlan>>,
    prev: Option<usize>,
) -> Result<SessionEnd, SessionErr> {
    let addr = cfg
        .connect
        .to_socket_addrs()
        .map_err(|e| SessionErr::Fatal(format!("resolving {}: {e}", cfg.connect)))?
        .next()
        .ok_or_else(|| SessionErr::Fatal(format!("{} resolves to no address", cfg.connect)))?;
    let connect_to = Duration::from_millis(cfg.connect_timeout_ms);
    let stream = TcpStream::connect_timeout(&addr, connect_to)
        .map_err(|e| SessionErr::Retry(format!("connecting {addr}: {e}")))?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(cfg.heartbeat_ms)))
        .map_err(|e| SessionErr::Retry(format!("setting read timeout: {e}")))?;
    stream
        .set_write_timeout(Some(connect_to))
        .map_err(|e| SessionErr::Retry(format!("setting write timeout: {e}")))?;
    let mut link = TcpLink::new(stream);
    link.send(&Frame::Hello { prev }).map_err(SessionErr::Retry)?;
    let deadline = Instant::now() + Duration::from_millis(cfg.assign_timeout_ms);
    let sess = loop {
        match link.recv() {
            Ok(Frame::Assign { id, seed, beta, comp, w0 }) => {
                break Session { id, seed, beta, comp, w0 }
            }
            Ok(Frame::Reject) => return Err(SessionErr::Retry("no free worker slot".into())),
            Ok(f) => {
                return Err(SessionErr::Retry(format!(
                    "expected assign, got {} frame",
                    f.kind()
                )))
            }
            Err(LinkErr::Timeout) => {
                if Instant::now() >= deadline {
                    return Err(SessionErr::Retry(format!(
                        "no slot assignment within {} ms",
                        cfg.assign_timeout_ms
                    )));
                }
            }
            Err(e) => return Err(SessionErr::Retry(format!("awaiting assignment: {e}"))),
        }
    };
    run_session(link.into_stream(), sess, handle, plan, cfg.heartbeat_ms)
}

/// Run one assigned session: the unchanged channel-transport
/// [`worker_main`] on a compute thread, an uplink pump that ships its
/// replies (heartbeating when idle), and the downlink read loop on this
/// thread. The compute loop is byte-for-byte the in-process worker — that
/// is the loopback ≡ channel determinism contract.
fn run_session(
    stream: TcpStream,
    sess: Session,
    handle: &GradHandle,
    plan: Option<Arc<FaultPlan>>,
    heartbeat_ms: u64,
) -> Result<SessionEnd, SessionErr> {
    let comp = CompSpec::parse(&sess.comp)
        .map_err(|e| SessionErr::Fatal(format!("leader sent a bad comp spec: {e}")))?;
    let id = sess.id;
    let state = WorkerState::new(id, &sess.w0, &comp, sess.beta, sess.seed);
    let h = handle.for_worker(id);
    let (to_tx, to_rx) = channel::<ToWorker>();
    let (from_tx, from_rx) = channel::<FromWorker>();
    let compute = std::thread::Builder::new()
        .name(format!("efmuon-net-compute-{id}"))
        .spawn(move || worker_main(state, to_rx, from_tx, h, plan))
        .map_err(|e| SessionErr::Fatal(format!("spawning compute thread: {e}")))?;

    let mut wstream = stream
        .try_clone()
        .map_err(|e| SessionErr::Fatal(format!("cloning session stream: {e}")))?;
    let hb = Duration::from_millis(heartbeat_ms);
    let writer = std::thread::Builder::new()
        .name(format!("efmuon-net-uplink-{id}"))
        .spawn(move || loop {
            let frame = match from_rx.recv_timeout(hb) {
                Ok(FromWorker::Init { id, g0 }) => Frame::Init { id, g0 },
                Ok(FromWorker::Round { id, step, loss, bytes, uplink }) => {
                    Frame::Reply { id, step, loss, bytes, bufs: encode_wire(uplink) }
                }
                Ok(FromWorker::Released { id, .. }) => Frame::Failed {
                    id,
                    err: "layer release is unsupported over the socket transport".into(),
                },
                Ok(FromWorker::Failed { id, err }) => Frame::Failed { id, err },
                Err(RecvTimeoutError::Timeout) => Frame::Heartbeat,
                Err(RecvTimeoutError::Disconnected) => return,
            };
            if write_frame(&mut wstream, &frame).is_err() {
                return;
            }
        })
        .map_err(|e| SessionErr::Fatal(format!("spawning uplink thread: {e}")))?;

    let mut rstream = stream;
    let mut end = loop {
        match read_frame(&mut rstream) {
            Ok(Frame::Round { step, bufs }) => {
                let cmd = ToWorker::Round { step, broadcast: Wire::Encoded(bufs) };
                if to_tx.send(cmd).is_err() {
                    break Err(SessionErr::Fatal("compute thread exited".into()));
                }
            }
            Ok(Frame::Stop) => {
                let _ = to_tx.send(ToWorker::Stop);
                break Ok(SessionEnd::Stopped);
            }
            Ok(Frame::Heartbeat) => {}
            Ok(_) => break Ok(SessionEnd::LinkLost { id }),
            Err(LinkErr::Timeout) => {
                // an idle downlink is legal (the leader may be evaluating);
                // only a dead compute thread makes waiting pointless — its
                // Failed reply has already been pumped upstream
                if compute.is_finished() {
                    break Err(SessionErr::Fatal("compute thread exited".into()));
                }
            }
            Err(_) => break Ok(SessionEnd::LinkLost { id }),
        }
    };
    let _ = rstream.shutdown(Shutdown::Both);
    drop(to_tx);
    if compute.join().is_err() {
        end = Err(SessionErr::Fatal("compute thread panicked".into()));
    }
    let _ = writer.join();
    end
}

/// Spawn `n` in-process worker threads dialing `addr` — the loopback
/// deployment used by the scenario goldens and the hotpath bench. The
/// `plan` injects *compute* faults worker-side (transport faults live in
/// [`NetCfg::flaky`] on the leader).
pub fn spawn_loopback_workers(
    n: usize,
    addr: SocketAddr,
    handle: &GradHandle,
    plan: Option<Arc<FaultPlan>>,
) -> Vec<JoinHandle<Result<()>>> {
    (0..n)
        .map(|i| {
            let h = handle.clone();
            let p = plan.clone();
            let cfg = WorkerCfg {
                connect: addr.to_string(),
                heartbeat_ms: 100,
                ..WorkerCfg::default()
            };
            std::thread::Builder::new()
                .name(format!("efmuon-net-worker-{i}"))
                .spawn(move || worker_loop(&cfg, &h, p))
                .expect("spawning loopback worker thread")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_layers() -> Layers {
        vec![
            Matrix::from_vec(2, 3, vec![1.0, -2.5, 0.0, -0.0, 3.4e38, 1.2e-38]),
            Matrix::from_vec(1, 1, vec![-7.25]),
        ]
    }

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { prev: None },
            Frame::Hello { prev: Some(3) },
            Frame::Assign {
                id: 2,
                seed: 99,
                beta: 0.9,
                comp: "top:0.3+nat".into(),
                w0: sample_layers(),
            },
            Frame::Reject,
            Frame::Init { id: 1, g0: sample_layers() },
            Frame::Round { step: 7, bufs: vec![vec![1, 2, 3], vec![], vec![255]] },
            Frame::Reply {
                id: 0,
                step: 12,
                loss: -0.125,
                bytes: 4096,
                bufs: vec![vec![9, 8, 7]],
            },
            Frame::Failed { id: 5, err: "worker thread panicked".into() },
            Frame::Heartbeat,
            Frame::Stop,
        ]
    }

    #[test]
    fn frame_roundtrip_every_variant() {
        for f in sample_frames() {
            let payload = encode_payload(&f);
            assert_eq!(decode_payload(&payload).unwrap(), f, "payload roundtrip {}", f.kind());
            let mut wire = Vec::new();
            write_frame(&mut wire, &f).unwrap();
            let mut cursor: &[u8] = &wire;
            assert_eq!(read_frame(&mut cursor).unwrap(), f, "stream roundtrip {}", f.kind());
            assert!(cursor.is_empty(), "stream consumed exactly for {}", f.kind());
        }
    }

    #[test]
    fn truncated_prefixes_decode_to_errors_never_panic() {
        for f in sample_frames() {
            let payload = encode_payload(&f);
            for cut in 0..payload.len() {
                let r = decode_payload(&payload[..cut]);
                assert!(r.is_err(), "{} truncated to {cut} bytes must not decode", f.kind());
            }
            // trailing garbage is rejected too
            let mut extended = payload;
            extended.push(0);
            let e = decode_payload(&extended).unwrap_err();
            assert!(e.contains("trailing"), "unexpected error: {e}");
        }
    }

    #[test]
    fn bit_flipped_payloads_never_panic_and_name_their_errors() {
        let payload = encode_payload(&Frame::Reply {
            id: 1,
            step: 4,
            loss: 0.5,
            bytes: 128,
            bufs: vec![vec![1, 2, 3, 4], vec![5, 6]],
        });
        for i in 0..payload.len() {
            for mask in [0x01u8, 0x80u8] {
                let mut mutated = payload.clone();
                mutated[i] ^= mask;
                // some flips still decode (e.g. in float bits) — the
                // property is: never a panic, and every failure is named
                if let Err(e) = decode_payload(&mutated) {
                    assert!(!e.is_empty());
                }
            }
        }
        let e = decode_payload(&[0xff]).unwrap_err();
        assert!(e.contains("unknown tag"), "unexpected error: {e}");
    }

    #[test]
    fn hostile_length_prefixes_error_without_allocating() {
        // frame length prefix beyond MAX_FRAME: rejected before any alloc
        let mut cursor: &[u8] = &[0xff, 0xff, 0xff, 0xff];
        match read_frame(&mut cursor) {
            Err(LinkErr::Corrupt(e)) => assert!(e.contains("exceeds"), "unexpected: {e}"),
            other => panic!("expected corrupt, got {other:?}"),
        }

        // buffer count claiming u32::MAX entries with an empty body
        let mut p = vec![TAG_ROUND];
        put_u64(&mut p, 0);
        put_u32(&mut p, u32::MAX);
        let e = decode_payload(&p).unwrap_err();
        assert!(e.contains("needs"), "unexpected: {e}");

        // layer shape whose element-byte count overflows usize
        let mut p = vec![TAG_INIT];
        put_u64(&mut p, 0);
        put_u32(&mut p, 1);
        put_u32(&mut p, u32::MAX);
        put_u32(&mut p, u32::MAX);
        let e = decode_payload(&p).unwrap_err();
        assert!(e.contains("overflows"), "unexpected: {e}");
    }

    #[test]
    fn failed_frame_truncates_oversized_error_text() {
        let f = Frame::Failed { id: 1, err: "x".repeat(10_000) };
        let payload = encode_payload(&f);
        assert!(payload.len() < 600);
        match decode_payload(&payload).unwrap() {
            Frame::Failed { err, .. } => assert_eq!(err.len(), MAX_ERR_BYTES),
            other => panic!("expected failed, got {}", other.kind()),
        }
    }

    #[test]
    fn channel_and_tcp_links_speak_the_same_frames() {
        let frames = sample_frames();

        let (mut a, mut b) = ChannelLink::pair(Duration::from_millis(200));
        for f in &frames {
            a.send(f).unwrap();
            assert_eq!(b.recv().unwrap(), *f);
        }
        assert_eq!(b.recv(), Err(LinkErr::Timeout));

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server
            .set_read_timeout(Some(Duration::from_millis(200)))
            .unwrap();
        let mut c = TcpLink::new(client);
        let mut s = TcpLink::new(server);
        for f in &frames {
            c.send(f).unwrap();
            assert_eq!(s.recv().unwrap(), *f);
        }
        assert_eq!(s.recv(), Err(LinkErr::Timeout));
    }

    #[test]
    fn reader_supervisor_counts_misses_and_fails_the_link() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server
            .set_read_timeout(Some(Duration::from_millis(50)))
            .unwrap();
        let meter = Arc::new(Meter::new());
        let (reply_tx, reply_rx) = channel();
        let ctx = ReaderCtx {
            stream: server,
            id: 3,
            reply_tx,
            meter: meter.clone(),
            tracer: Tracer::Noop,
            miss_threshold: 2,
            closing: Arc::new(AtomicBool::new(false)),
        };
        reader_loop(ctx); // the client never speaks: two misses, then death
        match reply_rx.recv().unwrap() {
            FromWorker::Failed { id, err } => {
                assert_eq!(id, 3);
                assert!(err.contains("missed 2"), "unexpected error: {err}");
            }
            FromWorker::Init { .. } | FromWorker::Round { .. } | FromWorker::Released { .. } => {
                panic!("expected a Failed reply")
            }
        }
        assert_eq!(meter.heartbeat_misses(), 2);
        drop(client);
    }

    #[test]
    fn flaky_plan_is_keyed_by_worker_and_step() {
        let plan = FlakyPlan::new()
            .with(1, 3, FlakyKind::DropFrame)
            .with(2, 5, FlakyKind::DelayFrameMs(40));
        assert_eq!(plan.at(1, 3), Some(FlakyKind::DropFrame));
        assert_eq!(plan.at(2, 5), Some(FlakyKind::DelayFrameMs(40)));
        assert_eq!(plan.at(1, 4), None);
        assert_eq!(plan.len(), 2);
        assert!(!plan.is_empty());
        assert!(FlakyPlan::new().is_empty());
    }

    #[test]
    fn hub_assigns_the_free_slot_and_rejects_when_full() {
        let hub = NetHub::bind(NetCfg {
            listen: "127.0.0.1:0".into(),
            heartbeat_ms: 100,
            miss_threshold: 1000,
            io_timeout_ms: 2_000,
            claim_deadline_ms: 5_000,
            ..NetCfg::default()
        })
        .unwrap();
        let addr = hub.local_addr();
        let (reply_tx, _reply_rx) = channel();
        hub.arm(ArmSpec {
            n_workers: 1,
            w0: sample_layers(),
            comp: CompSpec::parse("top:0.3").unwrap(),
            beta: 0.9,
            seed: 7,
            reply_tx,
            meter: Arc::new(Meter::new()),
            tracer: Tracer::Noop,
        });

        let dial = || {
            let s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(2_000))).unwrap();
            s
        };
        let mut first = TcpLink::new(dial());
        first.send(&Frame::Hello { prev: None }).unwrap();
        match first.recv().unwrap() {
            Frame::Assign { id, seed, beta, comp, w0 } => {
                assert_eq!(id, 0);
                assert_eq!(seed, 7);
                assert_eq!(beta, 0.9);
                assert_eq!(comp, "top:0.3");
                assert_eq!(w0, sample_layers());
            }
            other => panic!("expected assign, got {}", other.kind()),
        }

        let mut second = TcpLink::new(dial());
        second.send(&Frame::Hello { prev: None }).unwrap();
        match second.recv().unwrap() {
            Frame::Reject => {}
            other => panic!("expected reject, got {}", other.kind()),
        }
        hub.close();
    }
}
