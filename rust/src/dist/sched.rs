//! Bounded-epoch shard scheduling: the window manager that lets shards run
//! ahead of the slowest shard, plus online re-partitioning (work stealing).
//!
//! [`SchedSpec`] is the spec-level knob carried by `RunSpec` and
//! `ClusterCfg`. The default (`off()`) reproduces the lock-step root
//! reducer bit for bit: every root round blocks until all shards reply and
//! epochs seal at that barrier. Turning the window on (`window:N`) lets the
//! root issue up to `N` rounds beyond the slowest shard's last completed
//! round — the async worker `lookahead` machinery generalized one level up,
//! to shards. Replies then arrive out of order across shards, so the root
//! stages them per `(round, shard)` in an [`EpochWindow`] and seals
//! `ParamBoard` epochs as each round *completes* (all shards reported it)
//! rather than at a lock-step barrier.
//!
//! `steal:THRESH` adds work stealing on top: an [`EwmaBank`] tracks each
//! shard's issue→reply round time (sampled against the root's
//! [`RoundClock`], so a shard's queue backlog — the real symptom of being
//! slow under a window — amplifies its sample), and when the max/min EWMA
//! spread exceeds the threshold the root closes the window (a one-round
//! barrier), migrates the slow shard's lightest layer to the fastest shard
//! through a versioned [`PartitionPlan`], and resumes. Migration happens
//! only at such an epoch boundary with zero rounds in flight, which is what
//! keeps the EF21 state consistent: the layer's server shift, server error
//! state and every worker's `(W, M, G)` triple move *bitwise* to the new
//! owner, so the stolen layer's trajectory continues as if it had never
//! moved.
//!
//! [`ShardDelayPlan`] is the test/bench-only imbalance harness — the
//! shard-level sibling of `fault::FaultPlan`: a deterministic schedule of
//! `(shard, round) → sleep` injected into the shard threads, never
//! serialized into a config.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::time::Instant;

use crate::linalg::matrix::{Layers, Matrix};

use super::coordinator::RoundStats;

// ---------------------------------------------------------------------------
// SchedSpec
// ---------------------------------------------------------------------------

/// Shard scheduling policy for a cluster deployment.
///
/// Spec grammar (the `--sched` flag and the `sched` config key): `off`, or
/// a comma list of `key:value` pairs — `window:2,steal:1.5`. `steal:off`
/// disables stealing explicitly; [`SchedSpec::spec`] always emits either
/// `off` or both keys in that fixed order, so `parse(spec(s)) == s`
/// exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedSpec {
    /// How many rounds any shard may run ahead of the slowest shard. `0`
    /// is the lock-step golden anchor: the root blocks on every round.
    pub window: usize,
    /// EWMA round-time spread (max/min) that triggers a layer steal from
    /// the slowest shard; `None` disables stealing. Must be `> 1.0`.
    pub steal: Option<f64>,
}

impl Default for SchedSpec {
    fn default() -> Self {
        SchedSpec::off()
    }
}

impl SchedSpec {
    /// Cap on the epoch window: each in-flight round stages one shift per
    /// shard, so the window bounds root-side memory.
    pub const MAX_WINDOW: usize = 64;

    /// The lock-step default: no window, no stealing.
    pub const fn off() -> Self {
        SchedSpec { window: 0, steal: None }
    }

    /// True when the policy changes nothing about the lock-step cluster.
    pub fn is_off(&self) -> bool {
        *self == SchedSpec::off()
    }

    /// Parse the spec grammar. Accepts `off` (or the empty string) and any
    /// subset of `window:N,steal:THRESH` (with `steal:off` for `None`).
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s.is_empty() || s == "off" {
            return Ok(SchedSpec::off());
        }
        let mut p = SchedSpec::off();
        for part in s.split(',') {
            let part = part.trim();
            let (key, val) = part
                .split_once(':')
                .ok_or_else(|| format!("sched: expected key:value, got {part:?}"))?;
            match key {
                "window" => {
                    p.window = val
                        .parse()
                        .map_err(|_| format!("sched: bad window {val:?}"))?;
                }
                "steal" => {
                    p.steal = if val == "off" {
                        None
                    } else {
                        Some(
                            val.parse()
                                .map_err(|_| format!("sched: bad steal threshold {val:?}"))?,
                        )
                    };
                }
                other => {
                    return Err(format!(
                        "sched: unknown key {other:?} (expected window/steal, or \"off\")"
                    ))
                }
            }
        }
        p.validate()?;
        Ok(p)
    }

    /// Canonical spec string; `parse` round-trips it losslessly.
    pub fn spec(&self) -> String {
        if self.is_off() {
            return "off".into();
        }
        match self.steal {
            Some(t) => format!("window:{},steal:{}", self.window, t),
            None => format!("window:{},steal:off", self.window),
        }
    }

    /// Field-level validation (also run by `parse`).
    pub fn validate(&self) -> Result<(), String> {
        if self.window > SchedSpec::MAX_WINDOW {
            return Err(format!(
                "sched: window {} exceeds the cap {}",
                self.window,
                SchedSpec::MAX_WINDOW
            ));
        }
        if let Some(t) = self.steal {
            if !t.is_finite() || t <= 1.0 {
                return Err(format!(
                    "sched: steal threshold must be a finite ratio > 1 (got {t})"
                ));
            }
        }
        Ok(())
    }
}

impl fmt::Display for SchedSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec())
    }
}

// ---------------------------------------------------------------------------
// PartitionPlan — versioned layer ownership
// ---------------------------------------------------------------------------

/// The cluster's layer → shard assignment, versioned so a migration is an
/// explicit, observable transition rather than a mutation in place.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    version: u64,
    owned: Vec<Vec<usize>>,
}

impl PartitionPlan {
    /// Wrap an initial partition (version 0). Each shard's ids must be
    /// ascending — `partition_layers` already guarantees this.
    pub fn new(owned: Vec<Vec<usize>>) -> Self {
        debug_assert!(owned.iter().all(|ids| ids.windows(2).all(|w| w[0] < w[1])));
        PartitionPlan { version: 0, owned }
    }

    /// Bumped once per successful migration.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn owned(&self) -> &[Vec<usize>] {
        &self.owned
    }

    pub fn shard(&self, s: usize) -> &[usize] {
        &self.owned[s]
    }

    /// The shard currently owning `layer`, if any.
    pub fn owner_of(&self, layer: usize) -> Option<usize> {
        self.owned
            .iter()
            .position(|ids| ids.binary_search(&layer).is_ok())
    }

    /// Move `layer` from shard `from` to shard `to`, keeping both id lists
    /// ascending. Refuses to empty a shard (every coordinator must keep at
    /// least one layer). Returns the new version.
    pub fn migrate(&mut self, layer: usize, from: usize, to: usize) -> Result<u64, String> {
        if from == to || from >= self.owned.len() || to >= self.owned.len() {
            return Err(format!("partition: bad migration {from} -> {to}"));
        }
        if self.owned[from].len() < 2 {
            return Err(format!("partition: shard {from} cannot give up its last layer"));
        }
        let at = self.owned[from]
            .binary_search(&layer)
            .map_err(|_| format!("partition: shard {from} does not own layer {layer}"))?;
        self.owned[from].remove(at);
        match self.owned[to].binary_search(&layer) {
            Ok(_) => return Err(format!("partition: shard {to} already owns layer {layer}")),
            Err(i) => self.owned[to].insert(i, layer),
        }
        self.version += 1;
        Ok(self.version)
    }
}

// ---------------------------------------------------------------------------
// EpochWindow — per-epoch completeness tracking for out-of-order replies
// ---------------------------------------------------------------------------

/// One in-flight round's staging slot: which shards have reported it.
struct WindowSlot {
    stats: Vec<Option<RoundStats>>,
    shifts: Vec<Option<Layers>>,
    filled: usize,
}

impl WindowSlot {
    fn empty(shards: usize) -> Self {
        WindowSlot {
            stats: (0..shards).map(|_| None).collect(),
            shifts: (0..shards).map(|_| None).collect(),
            filled: 0,
        }
    }
}

/// Completeness tracker for windowed rounds. Shard replies arrive out of
/// order *across* shards (each shard's own replies stay ordered — the reply
/// channel is serial per sender), get staged per `(round, shard)`, and pop
/// in round order once every shard has reported the round. The pop is the
/// epoch-seal point.
pub struct EpochWindow {
    shards: usize,
    /// Oldest round not yet complete on all shards (== the frontier).
    base: usize,
    /// Absolute rounds completed per shard.
    done: Vec<usize>,
    staged: VecDeque<WindowSlot>,
}

impl EpochWindow {
    pub fn new(shards: usize, start_round: usize) -> Self {
        assert!(shards > 0, "epoch window needs at least one shard");
        EpochWindow { shards, base: start_round, done: vec![start_round; shards], staged: VecDeque::new() }
    }

    /// The slowest shard's completed-round count — no round below this is
    /// in flight anywhere, so epoch `frontier()` is (or is about to be)
    /// sealed.
    pub fn frontier(&self) -> usize {
        self.base
    }

    /// Absolute rounds completed by `shard`.
    pub fn done(&self, shard: usize) -> usize {
        self.done[shard]
    }

    /// True when no reply is outstanding below `issued`.
    pub fn caught_up(&self, issued: usize) -> bool {
        self.base >= issued
    }

    /// Stage `shard`'s next reply. Returns the absolute round it answers.
    pub fn record(
        &mut self,
        shard: usize,
        stats: RoundStats,
        shift: Layers,
    ) -> Result<usize, String> {
        if shard >= self.shards {
            return Err(format!("epoch window: shard {shard} out of range"));
        }
        let round = self.done[shard];
        if round < self.base {
            return Err(format!("epoch window: shard {shard} re-reported round {round}"));
        }
        let idx = round - self.base;
        while self.staged.len() <= idx {
            let slot = WindowSlot::empty(self.shards);
            self.staged.push_back(slot);
        }
        let slot = &mut self.staged[idx];
        if slot.stats[shard].is_some() {
            return Err(format!("epoch window: duplicate reply for round {round} shard {shard}"));
        }
        slot.stats[shard] = Some(stats);
        slot.shifts[shard] = Some(shift);
        slot.filled += 1;
        self.done[shard] += 1;
        Ok(round)
    }

    /// Pop the oldest round once every shard has reported it, advancing the
    /// frontier. Call in a loop after each `record` — a single reply can
    /// complete only the front slot, but the slot behind it may already be
    /// full.
    pub fn pop_complete(&mut self) -> Option<(usize, Vec<RoundStats>, Vec<Layers>)> {
        if self.staged.front()?.filled < self.shards {
            return None;
        }
        let slot = self.staged.pop_front().expect("checked front");
        let round = self.base;
        self.base += 1;
        let stats = slot.stats.into_iter().map(|s| s.expect("full slot")).collect();
        let shifts = slot.shifts.into_iter().map(|s| s.expect("full slot")).collect();
        Some((round, stats, shifts))
    }
}

// ---------------------------------------------------------------------------
// RoundClock — issue timestamps for round-time sampling
// ---------------------------------------------------------------------------

/// Issue times of in-flight rounds, so a reply's round time is measured
/// issue → reply. Inter-reply intervals would be useless under a window:
/// the root withholds issuance until the slowest shard catches up, which
/// paces *every* shard's replies to the slowest — whereas a slow shard's
/// queue backlog stretches its issue→reply sample and keeps the spread
/// detectable at any window, including 0.
#[derive(Default)]
pub struct RoundClock {
    base: usize,
    t: VecDeque<Instant>,
}

impl RoundClock {
    pub fn new(start_round: usize) -> Self {
        RoundClock { base: start_round, t: VecDeque::new() }
    }

    /// Record `round`'s issue time. Rounds are issued in order.
    pub fn issue(&mut self, round: usize, at: Instant) {
        debug_assert_eq!(round, self.base + self.t.len(), "rounds issue in order");
        self.t.push_back(at);
    }

    /// Seconds since `round` was issued (0 for an unknown round — only
    /// possible after an over-eager trim, never in the cluster loop).
    pub fn elapsed_s(&self, round: usize, now: Instant) -> f64 {
        match round.checked_sub(self.base).and_then(|i| self.t.get(i)) {
            Some(&t0) => now.duration_since(t0).as_secs_f64(),
            None => 0.0,
        }
    }

    /// Drop issue times below `frontier` — every shard has reported those
    /// rounds, so no further sample can reference them.
    pub fn trim(&mut self, frontier: usize) {
        while self.base < frontier && !self.t.is_empty() {
            self.t.pop_front();
            self.base += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }
}

// ---------------------------------------------------------------------------
// EwmaBank — per-shard round-time tracking
// ---------------------------------------------------------------------------

/// Per-shard EWMA of round-time samples; the steal trigger reads the
/// max/min spread. Reset after every migration so the next decision is
/// based purely on post-steal evidence.
pub struct EwmaBank {
    ewma: Vec<f64>,
    n: Vec<u64>,
}

impl EwmaBank {
    /// Smoothing factor: ~5 samples of memory, quick to notice a shard
    /// going slow without flapping on one noisy round.
    pub const ALPHA: f64 = 0.4;
    /// Samples every shard must have before the spread is trusted.
    pub const MIN_SAMPLES: u64 = 3;
    /// Floor guarding the max/min ratio against a degenerate ~0s EWMA.
    const FLOOR_S: f64 = 1e-9;

    pub fn new(shards: usize) -> Self {
        EwmaBank { ewma: vec![0.0; shards], n: vec![0; shards] }
    }

    pub fn record(&mut self, shard: usize, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        self.ewma[shard] = if self.n[shard] == 0 {
            secs
        } else {
            Self::ALPHA * secs + (1.0 - Self::ALPHA) * self.ewma[shard]
        };
        self.n[shard] += 1;
    }

    pub fn samples(&self, shard: usize) -> u64 {
        self.n[shard]
    }

    pub fn ewma_s(&self, shard: usize) -> f64 {
        self.ewma[shard]
    }

    /// True once every shard has at least [`EwmaBank::MIN_SAMPLES`].
    pub fn ready(&self) -> bool {
        self.n.iter().all(|&n| n >= Self::MIN_SAMPLES)
    }

    /// Max/min EWMA ratio across shards (1.0 when degenerate — fewer than
    /// two shards sampled, or everything at the floor).
    pub fn spread(&self) -> f64 {
        let sampled: Vec<f64> = self
            .n
            .iter()
            .zip(&self.ewma)
            .filter(|(&n, _)| n > 0)
            .map(|(_, &e)| e.max(Self::FLOOR_S))
            .collect();
        if sampled.len() < 2 {
            return 1.0;
        }
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for e in sampled {
            lo = lo.min(e);
            hi = hi.max(e);
        }
        hi / lo
    }

    /// Shard with the largest EWMA (lowest index on ties).
    pub fn slowest(&self) -> usize {
        let mut best = 0;
        for s in 1..self.ewma.len() {
            if self.ewma[s] > self.ewma[best] {
                best = s;
            }
        }
        best
    }

    /// Shard with the smallest EWMA (lowest index on ties).
    pub fn fastest(&self) -> usize {
        let mut best = 0;
        for s in 1..self.ewma.len() {
            if self.ewma[s] < self.ewma[best] {
                best = s;
            }
        }
        best
    }

    /// Forget everything — called after a steal so the changed partition
    /// re-earns its statistics.
    pub fn reset(&mut self) {
        self.ewma.iter_mut().for_each(|e| *e = 0.0);
        self.n.iter_mut().for_each(|n| *n = 0);
    }
}

// ---------------------------------------------------------------------------
// Layer migration payloads
// ---------------------------------------------------------------------------

/// One layer's server-side EF21 state: params `X`, shift `W`, gradient
/// estimator `G`.
pub struct ServerLayer {
    pub x: Matrix,
    pub w: Matrix,
    pub g: Matrix,
}

/// One layer's worker-side EF21 state: shift `W`, momentum `M`, local
/// estimator `G` (one per worker, in worker-id order).
pub struct WorkerLayer {
    pub w: Matrix,
    pub m: Matrix,
    pub g: Matrix,
}

// ---------------------------------------------------------------------------
// ShardDelayPlan — deterministic imbalance injection
// ---------------------------------------------------------------------------

/// A deterministic schedule of per-shard delays keyed by `(shard, round)` —
/// the imbalance harness for scheduler tests and the imbalanced bench.
/// Carried as `Option<Arc<ShardDelayPlan>>` on `ClusterCfg` and consulted
/// by each shard thread right before it runs a round. Never serialized
/// into a config: imbalance is injected by tests, not configured by runs.
#[derive(Debug, Clone, Default)]
pub struct ShardDelayPlan {
    delays: HashMap<(usize, usize), u64>,
}

impl ShardDelayPlan {
    pub fn new() -> Self {
        ShardDelayPlan::default()
    }

    /// Delay `shard` by `ms` before it runs `round` (builder-style).
    pub fn with(mut self, shard: usize, round: usize, ms: u64) -> Self {
        self.delays.insert((shard, round), ms);
        self
    }

    /// The delay scheduled for `(shard, round)`, if any.
    pub fn at(&self, shard: usize, round: usize) -> Option<u64> {
        self.delays.get(&(shard, round)).copied()
    }

    pub fn len(&self) -> usize {
        self.delays.len()
    }

    pub fn is_empty(&self) -> bool {
        self.delays.is_empty()
    }

    /// Rotating imbalance: round `r` delays shard `r % shards` by `ms`.
    /// With a window ≥ 1 each delay overlaps the previous victim's compute,
    /// so the windowed run beats lock-step wall-clock — a *constant*
    /// slow shard would self-serialize and show no win.
    pub fn alternating(shards: usize, rounds: usize, ms: u64) -> Self {
        let mut plan = ShardDelayPlan::new();
        for r in 0..rounds {
            plan.delays.insert((r % shards, r), ms);
        }
        plan
    }

    /// Persistent imbalance: `shard` is delayed by `ms` on every round in
    /// `[0, rounds)` — the steal trigger's target shape.
    pub fn constant(shard: usize, rounds: usize, ms: u64) -> Self {
        let mut plan = ShardDelayPlan::new();
        for r in 0..rounds {
            plan.delays.insert((shard, r), ms);
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn sched_default_is_off_and_roundtrips() {
        let s = SchedSpec::default();
        assert!(s.is_off());
        assert_eq!(s.spec(), "off");
        assert_eq!(SchedSpec::parse("off").unwrap(), s);
        assert_eq!(SchedSpec::parse("").unwrap(), s);
        // window:0,steal:off is semantically off and canonicalizes to "off"
        let z = SchedSpec::parse("window:0,steal:off").unwrap();
        assert!(z.is_off());
        assert_eq!(z.spec(), "off");
    }

    #[test]
    fn sched_spec_parse_roundtrip() {
        for s in ["window:2,steal:off", "window:1,steal:1.5", "window:0,steal:3", "window:64,steal:off"] {
            let p = SchedSpec::parse(s).unwrap();
            assert_eq!(SchedSpec::parse(&p.spec()).unwrap(), p, "spec {s}");
        }
        let p = SchedSpec::parse("window:3").unwrap();
        assert_eq!(p.window, 3);
        assert_eq!(p.steal, None);
        assert_eq!(p.spec(), "window:3,steal:off");
    }

    #[test]
    fn sched_rejects_bad_fields() {
        assert!(SchedSpec::parse("window:-1").is_err());
        assert!(SchedSpec::parse("window:two").is_err());
        assert!(SchedSpec::parse(&format!("window:{}", SchedSpec::MAX_WINDOW + 1)).is_err());
        assert!(SchedSpec::parse("steal:1.0").is_err(), "threshold must exceed 1");
        assert!(SchedSpec::parse("steal:0.5").is_err());
        assert!(SchedSpec::parse("steal:nan").is_err());
        assert!(SchedSpec::parse("steal:inf").is_err());
        assert!(SchedSpec::parse("pizza:1").is_err());
        assert!(SchedSpec::parse("window=1").is_err());
    }

    #[test]
    fn partition_plan_migrates_with_version_bump() {
        let mut plan = PartitionPlan::new(vec![vec![0, 4], vec![1, 5], vec![2, 6], vec![3, 7]]);
        assert_eq!(plan.version(), 0);
        assert_eq!(plan.owner_of(4), Some(0));
        assert_eq!(plan.migrate(4, 0, 2).unwrap(), 1);
        assert_eq!(plan.shard(0), &[0]);
        assert_eq!(plan.shard(2), &[2, 4, 6], "insertion keeps ids ascending");
        assert_eq!(plan.owner_of(4), Some(2));
        // the donor is down to one layer: the floor refuses a second steal
        assert!(plan.migrate(0, 0, 1).is_err());
        // errors: not the owner / same shard / out of range
        assert!(plan.migrate(3, 1, 2).is_err());
        assert!(plan.migrate(1, 1, 1).is_err());
        assert!(plan.migrate(1, 1, 9).is_err());
        assert_eq!(plan.version(), 1, "failed migrations must not bump");
    }

    fn stats(step: usize) -> RoundStats {
        RoundStats {
            step,
            absorbed_step: Some(step),
            train_loss: step as f32,
            radius: 0.1,
            w2s_bytes_per_worker: 8,
            s2w_bytes: 16,
        }
    }

    #[test]
    fn epoch_window_stages_out_of_order_and_pops_in_order() {
        let mut win = EpochWindow::new(3, 0);
        assert_eq!(win.frontier(), 0);
        // shard 1 races two rounds ahead; shard 2 one; shard 0 is slow
        assert_eq!(win.record(1, stats(0), vec![]).unwrap(), 0);
        assert_eq!(win.record(1, stats(1), vec![]).unwrap(), 1);
        assert_eq!(win.record(2, stats(0), vec![]).unwrap(), 0);
        assert!(win.pop_complete().is_none(), "round 0 still missing shard 0");
        assert_eq!(win.done(1), 2);
        assert_eq!(win.frontier(), 0);
        // the slow shard lands round 0: exactly one pop
        assert_eq!(win.record(0, stats(0), vec![]).unwrap(), 0);
        let (r, stats0, shifts) = win.pop_complete().unwrap();
        assert_eq!(r, 0);
        assert_eq!(stats0.len(), 3);
        assert_eq!(shifts.len(), 3);
        assert_eq!(stats0[1].step, 0, "per-shard stats in shard order");
        assert!(win.pop_complete().is_none());
        assert_eq!(win.frontier(), 1);
        assert!(win.caught_up(1));
        assert!(!win.caught_up(2));
        // rounds can complete back to back: two pops in round order
        assert_eq!(win.record(2, stats(1), vec![]).unwrap(), 1);
        assert_eq!(win.record(0, stats(1), vec![]).unwrap(), 1);
        assert_eq!(win.record(0, stats(2), vec![]).unwrap(), 2);
        assert_eq!(win.record(1, stats(2), vec![]).unwrap(), 2);
        assert_eq!(win.pop_complete().unwrap().0, 1);
        assert!(win.pop_complete().is_none(), "round 2 still missing shard 2");
        assert_eq!(win.record(2, stats(2), vec![]).unwrap(), 2);
        assert_eq!(win.pop_complete().unwrap().0, 2);
        assert_eq!(win.frontier(), 3);
    }

    #[test]
    fn epoch_window_rejects_bad_records() {
        let mut win = EpochWindow::new(2, 5);
        assert_eq!(win.frontier(), 5, "starts at the cluster's start step");
        assert!(win.record(7, stats(5), vec![]).is_err(), "shard out of range");
        assert_eq!(win.record(0, stats(5), vec![]).unwrap(), 5);
    }

    #[test]
    fn round_clock_measures_issue_to_reply_and_trims() {
        let t0 = Instant::now();
        let mut clock = RoundClock::new(0);
        clock.issue(0, t0);
        clock.issue(1, t0 + Duration::from_millis(10));
        assert_eq!(clock.len(), 2);
        let now = t0 + Duration::from_millis(30);
        assert!((clock.elapsed_s(0, now) - 0.030).abs() < 1e-9);
        assert!((clock.elapsed_s(1, now) - 0.020).abs() < 1e-9);
        clock.trim(1);
        assert_eq!(clock.len(), 1);
        assert_eq!(clock.elapsed_s(0, now), 0.0, "trimmed rounds read as 0");
        assert!((clock.elapsed_s(1, now) - 0.020).abs() < 1e-9);
        clock.trim(2);
        assert!(clock.is_empty());
    }

    #[test]
    fn ewma_bank_detects_slow_shard_and_resets() {
        let mut bank = EwmaBank::new(3);
        assert!(!bank.ready());
        assert_eq!(bank.spread(), 1.0, "degenerate spread is 1");
        for _ in 0..4 {
            bank.record(0, 0.010);
            bank.record(1, 0.012);
            bank.record(2, 0.050);
        }
        assert!(bank.ready());
        assert_eq!(bank.slowest(), 2);
        assert_eq!(bank.fastest(), 0);
        assert!(bank.spread() > 4.0, "50ms vs 10ms spreads ~5x");
        // non-finite and negative samples are ignored, not poisoning
        bank.record(0, f64::NAN);
        bank.record(0, -1.0);
        assert_eq!(bank.samples(0), 4);
        bank.reset();
        assert!(!bank.ready());
        assert_eq!(bank.samples(2), 0);
        assert_eq!(bank.spread(), 1.0);
    }

    #[test]
    fn shard_delay_plan_builders() {
        let plan = ShardDelayPlan::new().with(1, 3, 25).with(0, 0, 10);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.at(1, 3), Some(25));
        assert_eq!(plan.at(0, 0), Some(10));
        assert_eq!(plan.at(1, 4), None);

        let alt = ShardDelayPlan::alternating(4, 8, 30);
        assert_eq!(alt.len(), 8, "one delayed shard per round");
        for r in 0..8 {
            assert_eq!(alt.at(r % 4, r), Some(30));
            for s in 0..4 {
                if s != r % 4 {
                    assert_eq!(alt.at(s, r), None);
                }
            }
        }

        let cst = ShardDelayPlan::constant(2, 5, 40);
        assert_eq!(cst.len(), 5);
        for r in 0..5 {
            assert_eq!(cst.at(2, r), Some(40));
            assert_eq!(cst.at(0, r), None);
        }
        assert_eq!(cst.at(2, 5), None);
    }
}
