//! Channel transport between the leader and its worker threads.
//!
//! A [`Wire`] is one hop's worth of per-layer messages in either transport
//! mode. `Counted` ships the in-memory [`Message`]s and meters their
//! analytic `wire_bytes()`; `Encoded` runs the real codec both ways —
//! `codec::encode` produces *exactly* `wire_bytes()` bytes and round-trips
//! losslessly (asserted in `rust/tests/compressors.rs`), so the two modes
//! agree on both bytes and trajectory (asserted in `rust/tests/dist.rs`
//! and, per direction, in `rust/tests/scenario.rs`).
//!
//! Both hops — the EF21-P s2w broadcast and the EF21 w2s uplink — go
//! through the same [`Wire::pack`], so byte accounting is symmetric by
//! construction. Every `Round` hop carries its step number: with
//! [`super::RoundMode::Async`] several rounds are in flight at once and
//! replies from different rounds interleave on the shared reply channel,
//! so the leader routes them into per-round id-slots by `(step, id)`.

use crate::compress::{codec, Message};
use crate::compress::Compressor;

use super::sched::WorkerLayer;
use super::TransportMode;

/// One hop (broadcast or uplink) of per-layer messages on the wire.
#[derive(Debug, Clone)]
pub enum Wire {
    Counted(Vec<Message>),
    Encoded(Vec<Vec<u8>>),
}

impl Wire {
    /// Serialize (or wrap) messages for transport; returns the wire and the
    /// exact byte count it occupies. The hot path hands over ownership —
    /// `Counted` ships the messages as-is, `Encoded` encodes from the
    /// borrow and drops them; neither mode ever clones a message.
    pub fn pack(msgs: Vec<Message>, mode: TransportMode) -> (Wire, usize) {
        match mode {
            TransportMode::Counted => {
                let bytes = msgs.iter().map(|m| m.wire_bytes()).sum();
                (Wire::Counted(msgs), bytes)
            }
            TransportMode::Encoded => Self::pack_ref(&msgs, mode),
        }
    }

    /// [`Wire::pack`] from a borrowed slice — the measurement path, where
    /// one message set is packed under several transport modes for
    /// comparison. `Encoded` is copy-free (the codec reads the borrow);
    /// `Counted` must own what it ships, so it clones exactly once here —
    /// still strictly less copying than cloning per mode at the call site.
    pub fn pack_ref(msgs: &[Message], mode: TransportMode) -> (Wire, usize) {
        match mode {
            TransportMode::Counted => {
                let bytes = msgs.iter().map(|m| m.wire_bytes()).sum();
                (Wire::Counted(msgs.to_vec()), bytes)
            }
            TransportMode::Encoded => {
                let bufs: Vec<Vec<u8>> = msgs.iter().map(codec::encode).collect();
                let bytes = bufs.iter().map(|b| b.len()).sum();
                (Wire::Encoded(bufs), bytes)
            }
        }
    }

    /// Deserialize back into per-layer messages.
    pub fn unpack(self) -> Result<Vec<Message>, String> {
        match self {
            Wire::Counted(msgs) => Ok(msgs),
            Wire::Encoded(bufs) => bufs.iter().map(|b| codec::decode(b)).collect(),
        }
    }

    /// The transport mode this wire travels in (the uplink reuses the
    /// broadcast's mode).
    pub fn mode(&self) -> TransportMode {
        match self {
            Wire::Counted(_) => TransportMode::Counted,
            Wire::Encoded(_) => TransportMode::Encoded,
        }
    }
}

/// Leader → worker commands.
pub enum ToWorker {
    /// Run one EF21 round: apply this broadcast, compute, reply with the
    /// same `step` tag.
    Round { step: usize, broadcast: Wire },
    /// Hand the layer at local index `at` back to the leader (cluster work
    /// stealing): remove its `(W, M, G)` triple and reply `Released`. Only
    /// sent with zero rounds in flight, so the command queue's serial order
    /// guarantees the state is post-every-absorbed-round.
    Release { at: usize },
    /// Adopt a migrated layer at local index `at` with its EF21 state and a
    /// fresh compressor for its shape. No reply: the serial queue orders it
    /// before any subsequent `Round`.
    Accept { at: usize, state: WorkerLayer, comp: Box<dyn Compressor> },
    /// Exit the worker loop.
    Stop,
}

/// Worker → leader replies.
pub enum FromWorker {
    /// Initial local gradient estimator `G⁰ⱼ` (server averages these).
    Init { id: usize, g0: crate::linalg::matrix::Layers },
    /// One round's uplink: local train loss + compressed residuals, tagged
    /// with the round it answers. The `(step, id)` tag is also what marks a
    /// straggler: under a [`super::fault::FaultPolicy`] deadline the leader
    /// may absorb a round before every reply lands, and a reply tagged with
    /// an already-absorbed step is then recognized as that straggler's late
    /// uplink (folded into the server estimator) instead of a protocol
    /// error.
    Round { id: usize, step: usize, loss: f32, bytes: usize, uplink: Wire },
    /// Reply to [`ToWorker::Release`]: this worker's EF21 state for the
    /// released layer, bitwise as it stood after the last absorbed round.
    Released { id: usize, state: WorkerLayer },
    /// Irrecoverable worker-side failure (including panics: the worker's
    /// panic guard converts an unwind into this message so the leader
    /// returns a clean `Err` instead of hanging).
    Failed { id: usize, err: String },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::parse_spec;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn both_modes_roundtrip_and_agree_on_bytes() {
        let mut rng = Rng::new(8);
        let x = Matrix::randn(16, 32, 1.0, &mut rng);
        let msg = parse_spec("top:0.2+nat").unwrap().compress(&x, &mut rng);
        let analytic = msg.wire_bytes();

        // one borrowed slice measured under both transports — no per-mode
        // message clone at the call site
        let msgs = std::slice::from_ref(&msg);
        let (wc, bc) = Wire::pack_ref(msgs, TransportMode::Counted);
        let (we, be) = Wire::pack_ref(msgs, TransportMode::Encoded);
        assert_eq!(bc, analytic);
        assert_eq!(be, analytic, "codec must emit exactly wire_bytes()");
        assert_eq!(wc.mode(), TransportMode::Counted);
        assert_eq!(we.mode(), TransportMode::Encoded);
        assert_eq!(wc.unpack().unwrap()[0], msg);
        assert_eq!(we.unpack().unwrap()[0], msg, "codec must be lossless");

        // the owning hot-path entry agrees with the borrowed measurement
        let (wo, bo) = Wire::pack(vec![msg.clone()], TransportMode::Encoded);
        assert_eq!(bo, analytic);
        assert_eq!(wo.unpack().unwrap()[0], msg);
    }
}
