//! The gradient service: one logical endpoint the worker threads pull
//! losses/gradients from and the coordinator pulls eval + spectral-engine
//! results from. Two backends:
//!
//! - **Objective** ([`GradService::spawn_objective`]): a synthetic
//!   [`Objective`] shared via `Arc`. Gradients are computed *inline in the
//!   calling worker thread* (no service thread, no serialization), each
//!   worker with its own deterministic RNG stream for stochastic draws.
//! - **PJRT** ([`GradService::spawn_pjrt`]): the AOT-compiled model
//!   executed through the XLA runtime. PJRT handles are not `Send`, so a
//!   dedicated service thread owns the [`ModelRuntime`], the corpus and the
//!   per-worker data shards; requests serialize over an mpsc channel.
//!
//! Handles are cheap to clone; [`GradHandle::for_worker`] derives the
//! worker-specific gradient RNG stream.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::funcs::Objective;
use crate::linalg::matrix::{Layers, Matrix};
use crate::util::rng::Rng;

/// RNG stream tag base for worker `j`'s stochastic-gradient draws — shared
/// by every site that derives a worker gradient stream so the objective
/// backend (inline), the lazy-handle fallback and the PJRT service all
/// sample identically for the same seed.
const GRAD_STREAM_BASE: u64 = 0x6ead;

fn grad_stream(worker: usize) -> u64 {
    GRAD_STREAM_BASE + worker as u64
}

/// Requests served by the PJRT service thread.
enum Req {
    /// Local loss + gradient for `worker` at `params`.
    Grad {
        worker: usize,
        params: Layers,
        reply: Sender<Result<(f32, Layers), String>>,
    },
    /// Mean eval loss over the held-out batches at `params`.
    Eval {
        params: Layers,
        reply: Sender<Result<f32, String>>,
    },
    /// Newton–Schulz orthogonalization through the Pallas/PJRT artifact;
    /// `Ok(None)` when no artifact matches the shape.
    Ns {
        g: Matrix,
        reply: Sender<Result<Option<Matrix>, String>>,
    },
    Shutdown,
}

#[derive(Clone)]
enum HandleInner {
    Local {
        obj: Arc<dyn Objective>,
        seed: u64,
        /// (worker, stream) for that worker's stochastic-gradient draws,
        /// installed by [`GradHandle::for_worker`] (or lazily on first use)
        rng: Option<(usize, Rng)>,
    },
    Pjrt {
        tx: Sender<Req>,
    },
}

/// Cheap clonable handle to a [`GradService`].
#[derive(Clone)]
pub struct GradHandle {
    inner: HandleInner,
}

impl GradHandle {
    /// Derive the handle a specific worker thread should own: installs that
    /// worker's deterministic gradient RNG stream (objective backend; the
    /// PJRT backend keeps per-worker sampling state service-side).
    pub fn for_worker(&self, worker: usize) -> GradHandle {
        match &self.inner {
            HandleInner::Local { obj, seed, .. } => GradHandle {
                inner: HandleInner::Local {
                    obj: obj.clone(),
                    seed: *seed,
                    rng: Some((worker, Rng::with_stream(*seed, grad_stream(worker)))),
                },
            },
            HandleInner::Pjrt { tx } => GradHandle { inner: HandleInner::Pjrt { tx: tx.clone() } },
        }
    }

    /// Local train loss `f_j` + gradient for `worker` at `params`.
    /// Objective backend: computed inline in the calling thread (workers
    /// run fully in parallel). PJRT backend: proxied to the service thread.
    pub fn grad(&mut self, worker: usize, params: &Layers) -> Result<(f32, Layers)> {
        match &mut self.inner {
            HandleInner::Local { obj, seed, rng } => {
                // a handle caches one worker's stream; on a mismatch (handle
                // not specialized via for_worker, or reused across workers)
                // re-derive the requested worker's stream from the seed
                let seed = *seed;
                match rng {
                    Some((w, _)) if *w == worker => {}
                    _ => *rng = Some((worker, Rng::with_stream(seed, grad_stream(worker)))),
                }
                let (_, r) = rng.as_mut().expect("just installed");
                let g = obj.stoch_grad_j(worker, params, r);
                let loss = obj.loss_j(worker, params) as f32;
                Ok((loss, g))
            }
            HandleInner::Pjrt { tx } => {
                let (rtx, rrx) = channel();
                tx.send(Req::Grad { worker, params: params.clone(), reply: rtx })
                    .map_err(|_| anyhow!("grad service is down"))?;
                rrx.recv()
                    .map_err(|_| anyhow!("grad service dropped the request"))?
                    .map_err(anyhow::Error::msg)
            }
        }
    }

    /// Evaluation loss at `params` (deterministic given params).
    pub fn eval(&self, params: Layers) -> Result<f32> {
        match &self.inner {
            HandleInner::Local { obj, .. } => Ok(obj.loss(&params) as f32),
            HandleInner::Pjrt { tx } => {
                let (rtx, rrx) = channel();
                tx.send(Req::Eval { params, reply: rtx })
                    .map_err(|_| anyhow!("grad service is down"))?;
                rrx.recv()
                    .map_err(|_| anyhow!("grad service dropped the request"))?
                    .map_err(anyhow::Error::msg)
            }
        }
    }

    /// Orthogonalize through the PJRT NS artifact; `Ok(None)` when the
    /// backend has no artifact for this shape (callers fall back to the
    /// native Newton–Schulz).
    pub fn ns_orthogonalize(&self, g: &Matrix) -> Result<Option<Matrix>> {
        match &self.inner {
            HandleInner::Local { .. } => Ok(None),
            HandleInner::Pjrt { tx } => {
                let (rtx, rrx) = channel();
                tx.send(Req::Ns { g: g.clone(), reply: rtx })
                    .map_err(|_| anyhow!("grad service is down"))?;
                rrx.recv()
                    .map_err(|_| anyhow!("grad service dropped the request"))?
                    .map_err(anyhow::Error::msg)
            }
        }
    }
}

/// The gradient service (owns the backend; see module docs).
pub struct GradService {
    handle: GradHandle,
    /// PJRT backend only: request sender + service thread join handle.
    pjrt: Option<(Sender<Req>, JoinHandle<()>)>,
}

impl GradService {
    /// Synthetic backend: gradients evaluated inline in worker threads.
    pub fn spawn_objective(obj: Box<dyn Objective>, seed: u64) -> GradService {
        let obj: Arc<dyn Objective> = Arc::from(obj);
        GradService {
            handle: GradHandle { inner: HandleInner::Local { obj, seed, rng: None } },
            pjrt: None,
        }
    }

    /// PJRT backend: load the AOT artifacts from `artifacts`, build the
    /// synthetic corpus (`corpus_tokens` tokens) sharded over `workers`,
    /// pre-sample `eval_batches` held-out batches, and serve requests on a
    /// dedicated thread. Fails fast if the artifacts are missing or the XLA
    /// runtime is unavailable.
    pub fn spawn_pjrt(
        artifacts: String,
        workers: usize,
        corpus_tokens: usize,
        eval_batches: usize,
        seed: u64,
    ) -> Result<GradService> {
        let (tx, rx) = channel::<Req>();
        let (init_tx, init_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("efmuon-grad-svc".to_string())
            .spawn(move || pjrt_service_main(artifacts, workers, corpus_tokens, eval_batches, seed, rx, init_tx))
            .map_err(|e| anyhow!("spawning grad service: {e}"))?;
        match init_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = join.join();
                return Err(anyhow!(e));
            }
            Err(_) => {
                let _ = join.join();
                return Err(anyhow!("grad service died during init"));
            }
        }
        Ok(GradService {
            handle: GradHandle { inner: HandleInner::Pjrt { tx: tx.clone() } },
            pjrt: Some((tx, join)),
        })
    }

    /// A clonable handle onto this service.
    pub fn handle(&self) -> GradHandle {
        self.handle.clone()
    }
}

impl Drop for GradService {
    fn drop(&mut self) {
        if let Some((tx, join)) = self.pjrt.take() {
            let _ = tx.send(Req::Shutdown);
            let _ = join.join();
        }
    }
}

/// Service-thread main for the PJRT backend.
fn pjrt_service_main(
    artifacts: String,
    workers: usize,
    corpus_tokens: usize,
    eval_batches: usize,
    seed: u64,
    rx: Receiver<Req>,
    init_tx: Sender<Result<(), String>>,
) {
    let rt = match crate::runtime::ModelRuntime::load(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = init_tx.send(Err(format!("loading artifacts from {artifacts}: {e:#}")));
            return;
        }
    };
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq_len;
    let batch = rt.manifest.batch;
    let corpus = crate::data::Corpus::zipf_markov(corpus_tokens, vocab, seed);
    // held-out eval stream: disjoint seed, whole-corpus shard, pre-sampled
    // once so eval is a pure function of the parameters
    let mut eval_rng = Rng::with_stream(seed, 0xe7a1);
    let eval_shard = crate::data::Shard::new(&corpus, 0, 1, seq);
    let eval_set: Vec<(Vec<i32>, Vec<i32>)> = (0..eval_batches.max(1))
        .map(|_| eval_shard.sample_batch(batch, &mut eval_rng))
        .collect();
    let mut worker_rngs: Vec<Rng> = (0..workers.max(1))
        .map(|j| Rng::with_stream(seed, grad_stream(j)))
        .collect();
    let _ = init_tx.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Grad { worker, params, reply } => {
                let out = (|| -> Result<(f32, Layers), String> {
                    if worker >= worker_rngs.len() {
                        return Err(format!(
                            "worker {worker} out of range (service sized for {})",
                            worker_rngs.len()
                        ));
                    }
                    let shard = crate::data::Shard::new(&corpus, worker, worker_rngs.len(), seq);
                    let (toks, tgts) = shard.sample_batch(batch, &mut worker_rngs[worker]);
                    rt.grad(&params, &toks, &tgts).map_err(|e| format!("{e:#}"))
                })();
                let _ = reply.send(out);
            }
            Req::Eval { params, reply } => {
                let out = (|| -> Result<f32, String> {
                    let mut acc = 0.0f64;
                    for (toks, tgts) in &eval_set {
                        acc += rt
                            .eval_loss(&params, toks, tgts)
                            .map_err(|e| format!("{e:#}"))? as f64;
                    }
                    Ok((acc / eval_set.len() as f64) as f32)
                })();
                let _ = reply.send(out);
            }
            Req::Ns { g, reply } => {
                let out = match rt.ns_orthogonalize(&g) {
                    None => Ok(None),
                    Some(Ok(o)) => Ok(Some(o)),
                    Some(Err(e)) => Err(format!("{e:#}")),
                };
                let _ = reply.send(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::funcs::Quadratics;

    #[test]
    fn objective_backend_is_inline_and_deterministic() {
        let mut rng = Rng::new(70);
        let q = Quadratics::new(3, 6, 0.5, 0.0, &mut rng);
        let x0 = {
            let mut r = Rng::new(71);
            q.init(&mut r)
        };
        let svc = GradService::spawn_objective(Box::new(q), 9);
        let mut h0 = svc.handle().for_worker(0);
        let mut h0b = svc.handle().for_worker(0);
        let (l1, g1) = h0.grad(0, &x0).unwrap();
        let (l2, g2) = h0b.grad(0, &x0).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1[0].data, g2[0].data);
        let e1 = svc.handle().eval(x0.clone()).unwrap();
        let e2 = svc.handle().eval(x0.clone()).unwrap();
        assert_eq!(e1, e2);
        assert!(svc.handle().ns_orthogonalize(&x0[0]).unwrap().is_none());
    }

    #[test]
    fn pjrt_backend_fails_fast_without_artifacts() {
        let err = GradService::spawn_pjrt("definitely-missing-dir".into(), 1, 10_000, 1, 0)
            .err()
            .expect("must fail without artifacts");
        assert!(format!("{err:#}").contains("definitely-missing-dir"));
    }
}
