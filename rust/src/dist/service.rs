//! The gradient service: one logical endpoint the worker threads pull
//! losses/gradients from and the coordinator pulls eval + spectral-engine
//! results from. Two backends:
//!
//! - **Objective** ([`GradService::spawn_objective`]): a synthetic
//!   [`Objective`] shared via `Arc`. Gradients are computed *inline in the
//!   calling worker thread* (no service thread, no serialization), each
//!   worker with its own deterministic RNG stream for stochastic draws.
//! - **PJRT** ([`GradService::spawn_pjrt`]): the AOT-compiled model
//!   executed through the XLA runtime. PJRT handles are not `Send`, so a
//!   dedicated service thread owns the [`ModelRuntime`], the corpus and the
//!   per-worker data shards; requests serialize over an mpsc channel.
//!
//! Handles are cheap to clone; [`GradHandle::for_worker`] derives the
//! worker-specific gradient RNG stream, and [`GradHandle::for_shard`] wraps
//! any backend into a layer-sliced view for the multi-coordinator cluster
//! (`dist::cluster`): the sharded handle assembles full-model parameters
//! from the shard's own layers plus the cluster parameter board's sealed
//! per-round snapshot of every other shard, forwards the request, and
//! projects the returned gradient back onto the shard's layers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::funcs::Objective;
use crate::linalg::matrix::{Layers, Matrix};
use crate::trace::{Phase, Tracer};
use crate::util::rng::Rng;

use super::cluster::ParamBoard;

/// RNG stream tag base for worker `j`'s stochastic-gradient draws — shared
/// by every site that derives a worker gradient stream so the objective
/// backend (inline), the lazy-handle fallback and the PJRT service all
/// sample identically for the same seed.
const GRAD_STREAM_BASE: u64 = 0x6ead;

fn grad_stream(worker: usize) -> u64 {
    GRAD_STREAM_BASE + worker as u64
}

/// Round index passed by [`GradHandle::grad`] (the un-keyed entry point:
/// initialization and offline callers). Sharded handles read the *newest*
/// sealed board snapshot for it, and the PJRT service derives a batch
/// stream disjoint from every real round's.
const INIT_STEP: usize = usize::MAX;

/// PJRT batch stream for `(worker, step)`: batch sampling is a pure
/// function of (seed, worker, round), so replaying a round — or running the
/// same round from several shard coordinators of one cluster — draws the
/// same data.
fn batch_rng(seed: u64, worker: usize, step: usize) -> Rng {
    let step_mix = (step as u64).wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15);
    Rng::with_stream(seed.wrapping_add(step_mix), grad_stream(worker))
}

/// A shard's owned-layer id list (ascending global ids), swappable online:
/// the cluster's work-stealing scheduler re-partitions layers between
/// rounds ([`super::sched`]), and every clone of a shard's handle — the
/// coordinator's and each worker's — must observe the new ownership at its
/// next request. Reads snapshot the current `Arc` (no borrow held across a
/// request); swaps happen only with zero rounds in flight, so no request
/// ever straddles two partitions.
#[derive(Clone)]
pub struct SharedIds(Arc<Mutex<Arc<Vec<usize>>>>);

impl SharedIds {
    pub fn new(ids: Vec<usize>) -> SharedIds {
        SharedIds(Arc::new(Mutex::new(Arc::new(ids))))
    }

    /// The current id list (an `Arc` snapshot — stable for the request
    /// that grabbed it even if a swap lands meanwhile).
    pub fn get(&self) -> Arc<Vec<usize>> {
        self.0.lock().expect("shared ids lock").clone()
    }

    /// Replace the id list (cluster root, at a migration boundary).
    pub fn set(&self, ids: Vec<usize>) {
        *self.0.lock().expect("shared ids lock") = Arc::new(ids);
    }
}

/// Per-shard cache of assembled full-model snapshots, keyed by round.
///
/// Every worker of a shard assembles the *identical* full model for a given
/// round — its own layers' W coincides bit-for-bit across the shard's
/// workers (the total-ordered broadcast stream; see `opt::ef21`), and the
/// foreign layers come from the same sealed [`ParamBoard`] epoch. So the
/// first worker to request round `r` assembles once into an `Arc<Layers>`
/// and every later request reuses it, turning the per-round host copy cost
/// from `workers × model` into `model` per shard. Evicted snapshots whose
/// `Arc` is unshared donate their buffers back to a small pool, so
/// steady-state assembly is allocation-free (the workspace-arena pattern,
/// one level up).
///
/// The un-keyed entry points (`INIT_STEP`: worker init, offline eval) are
/// never cached — they read the board's *newest* snapshot, which moves
/// between calls.
pub struct SnapCache {
    inner: Mutex<SnapCacheInner>,
    /// How many trailing rounds to retain (≥ lookahead + 2, mirroring the
    /// board's retention so every in-flight round finds its entry).
    keep: usize,
    assembled: AtomicU64,
    reused: AtomicU64,
    bytes_assembled: AtomicU64,
    bytes_shipped: AtomicU64,
    fresh: AtomicU64,
    /// Stamps [`Phase::SnapAssemble`] on every from-scratch assembly
    /// (`Tracer::Noop` by default — cache hits and the off path stamp
    /// nothing).
    tracer: Tracer,
}

struct SnapCacheInner {
    /// (step, snapshot), steps strictly increasing.
    snaps: VecDeque<(usize, Arc<Layers>)>,
    /// Buffers reclaimed from evicted snapshots.
    pool: Vec<Layers>,
}

impl SnapCache {
    pub fn new(keep: usize) -> SnapCache {
        SnapCache {
            inner: Mutex::new(SnapCacheInner { snaps: VecDeque::new(), pool: Vec::new() }),
            keep: keep.max(2),
            assembled: AtomicU64::new(0),
            reused: AtomicU64::new(0),
            bytes_assembled: AtomicU64::new(0),
            bytes_shipped: AtomicU64::new(0),
            fresh: AtomicU64::new(0),
            tracer: Tracer::Noop,
        }
    }

    /// The same cache with a live tracer installed (builder form so the
    /// `SnapCache::new(keep)` construction sites stay unchanged).
    pub fn traced(mut self, tracer: Tracer) -> SnapCache {
        self.tracer = tracer;
        self
    }

    /// Rounds assembled from scratch (exactly one per (shard, round)).
    pub fn assembled(&self) -> u64 {
        self.assembled.load(Ordering::Relaxed)
    }

    /// Requests served from an already-assembled snapshot.
    pub fn reused(&self) -> u64 {
        self.reused.load(Ordering::Relaxed)
    }

    /// Bytes deep-copied by assemblies (board snapshot + own layers),
    /// counted at full f32 width — the host memory-traffic meter.
    pub fn bytes_assembled(&self) -> u64 {
        self.bytes_assembled.load(Ordering::Relaxed)
    }

    /// Bytes read from the parameter board at its *stored* width while
    /// assembling foreign layers: 2 B/entry under the bf16 board
    /// ([`super::cluster::ClusterCfg::snap_bf16`]), 4 B/entry under f32 —
    /// the cross-shard snapshot wire traffic `BENCH_hotpath.json` gates.
    pub fn bytes_shipped(&self) -> u64 {
        self.bytes_shipped.load(Ordering::Relaxed)
    }

    /// Genuine heap allocations (pool misses) — flat once warm.
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh.load(Ordering::Relaxed)
    }

    /// The assembled full model for `step`: board epoch `step` with the
    /// shard's own layers substituted. Assembles at most once per step;
    /// concurrent workers of the shard block briefly on the one assembly
    /// they all need anyway.
    fn get_or_assemble(
        &self,
        board: &ParamBoard,
        layer_ids: &[usize],
        own: &[Matrix],
        step: usize,
    ) -> Result<Arc<Layers>> {
        // validate before the hit lookup, so a malformed own-slice fails
        // deterministically instead of only when this worker loses the
        // assembly race
        check_own(board, layer_ids, own)?;
        let mut inner = self.inner.lock().expect("snap cache lock");
        if let Some((_, snap)) = inner.snaps.iter().find(|(s, _)| *s == step) {
            self.reused.fetch_add(1, Ordering::Relaxed);
            return Ok(snap.clone());
        }
        let src = board.read(step);
        // merge-copy each layer exactly once — own layers from the caller,
        // foreign layers expanded from the board epoch at its stored width
        // (f32 copy or bf16 widening; `BoardSnap::expand_layer_into` is the
        // round-trip expansion point). `layer_ids` is ascending; the
        // assembly buffer comes from the reclaim pool when one is
        // available (all entries are full-model shaped, so any fits)
        let mut shipped = 0u64;
        let mut k = 0;
        let full: Layers = match inner.pool.pop() {
            Some(mut buf) => {
                for (i, dst) in buf.iter_mut().enumerate() {
                    if k < layer_ids.len() && layer_ids[k] == i {
                        k += 1;
                        dst.data.copy_from_slice(&own[k - 1].data);
                    } else {
                        src.expand_layer_into(i, &mut dst.data);
                        shipped += src.layer_wire_bytes(i);
                    }
                }
                buf
            }
            None => {
                self.fresh.fetch_add(1, Ordering::Relaxed);
                (0..src.len())
                    .map(|i| {
                        if k < layer_ids.len() && layer_ids[k] == i {
                            k += 1;
                            own[k - 1].clone()
                        } else {
                            shipped += src.layer_wire_bytes(i);
                            src.layer_to_matrix(i)
                        }
                    })
                    .collect()
            }
        };
        let bytes: usize = full.iter().map(|m| m.numel() * 4).sum();
        self.bytes_assembled.fetch_add(bytes as u64, Ordering::Relaxed);
        self.bytes_shipped.fetch_add(shipped, Ordering::Relaxed);
        self.assembled.fetch_add(1, Ordering::Relaxed);
        self.tracer.stamp(Phase::SnapAssemble, step, None);
        let arc = Arc::new(full);
        debug_assert!(inner.snaps.back().map(|(s, _)| *s < step).unwrap_or(true));
        inner.snaps.push_back((step, arc.clone()));
        while inner.snaps.len() > self.keep {
            let (_, old) = inner.snaps.pop_front().expect("non-empty");
            // reclaim the buffers unless a straggler still borrows them
            if let Ok(buf) = Arc::try_unwrap(old) {
                if inner.pool.len() < 2 {
                    inner.pool.push(buf);
                }
            }
        }
        Ok(arc)
    }
}

/// Requests served by the PJRT service thread.
enum Req {
    /// Local loss + gradient for `worker` at `params` in round `step`.
    Grad {
        worker: usize,
        step: usize,
        params: Layers,
        reply: Sender<Result<(f32, Layers), String>>,
    },
    /// Mean eval loss over the held-out batches at `params`.
    Eval {
        params: Layers,
        reply: Sender<Result<f32, String>>,
    },
    /// Newton–Schulz orthogonalization through the Pallas/PJRT artifact;
    /// `Ok(None)` when no artifact matches the shape.
    Ns {
        g: Matrix,
        reply: Sender<Result<Option<Matrix>, String>>,
    },
    Shutdown,
}

#[derive(Clone)]
enum HandleInner {
    Local {
        obj: Arc<dyn Objective>,
        seed: u64,
        /// (worker, stream) for that worker's stochastic-gradient draws,
        /// installed by [`GradHandle::for_worker`] (or lazily on first use)
        rng: Option<(usize, Rng)>,
    },
    Pjrt {
        tx: Sender<Req>,
    },
    /// A layer-sliced view for one cluster shard (see [`GradHandle::for_shard`]).
    Sharded {
        inner: Box<GradHandle>,
        board: Arc<ParamBoard>,
        /// Global layer ids this shard owns (ascending; swappable online —
        /// the cluster scheduler migrates layers between shards).
        layer_ids: SharedIds,
        /// Shared by every worker-derived clone of this shard's handle:
        /// one snapshot assembly per (shard, round), not per worker.
        cache: Arc<SnapCache>,
    },
}

/// Cheap clonable handle to a [`GradService`].
#[derive(Clone)]
pub struct GradHandle {
    inner: HandleInner,
}

impl GradHandle {
    /// Derive the handle a specific worker thread should own: installs that
    /// worker's deterministic gradient RNG stream (objective backend; the
    /// PJRT backend keeps per-worker sampling state service-side).
    pub fn for_worker(&self, worker: usize) -> GradHandle {
        match &self.inner {
            HandleInner::Local { obj, seed, .. } => GradHandle {
                inner: HandleInner::Local {
                    obj: obj.clone(),
                    seed: *seed,
                    rng: Some((worker, Rng::with_stream(*seed, grad_stream(worker)))),
                },
            },
            HandleInner::Pjrt { tx } => GradHandle { inner: HandleInner::Pjrt { tx: tx.clone() } },
            HandleInner::Sharded { inner, board, layer_ids, cache } => GradHandle {
                inner: HandleInner::Sharded {
                    inner: Box::new(inner.for_worker(worker)),
                    board: board.clone(),
                    layer_ids: layer_ids.clone(),
                    cache: cache.clone(),
                },
            },
        }
    }

    /// Derive a layer-sliced handle for one cluster shard: `grad`/`eval`
    /// see only the layers in `layer_ids`, and the missing layers are
    /// filled in from `board`'s sealed per-round snapshots. Worker ids are
    /// global — shard `s`'s worker `j` is the *same* logical data worker
    /// `j` as every other shard's (one `f_j` per worker, sliced by layer),
    /// so its RNG/batch streams match the single-coordinator deployment.
    /// `cache` holds the shard's per-round assembled snapshots; the caller
    /// (the cluster root) keeps its own `Arc` to read the traffic counters.
    pub fn for_shard(
        &self,
        board: Arc<ParamBoard>,
        layer_ids: SharedIds,
        cache: Arc<SnapCache>,
    ) -> GradHandle {
        GradHandle {
            inner: HandleInner::Sharded {
                inner: Box::new(self.clone()),
                board,
                layer_ids,
                cache,
            },
        }
    }

    /// True when the underlying objective reports layer-separable local
    /// losses ([`Objective::loss_is_layer_separable`]): shard-sliced
    /// handles then return only the shard's own contribution from
    /// `grad_at`, and the cluster root *sums* per-shard train losses
    /// instead of averaging them.
    pub fn loss_is_layer_separable(&self) -> bool {
        match &self.inner {
            HandleInner::Local { obj, .. } => obj.loss_is_layer_separable(),
            HandleInner::Pjrt { .. } => false,
            HandleInner::Sharded { inner, .. } => inner.loss_is_layer_separable(),
        }
    }

    /// Local train loss `f_j` + gradient for `worker` at `params`, without
    /// a round index: initialization and offline callers. Sharded handles
    /// read the newest sealed board snapshot; the PJRT backend samples from
    /// a dedicated init batch stream.
    pub fn grad(&mut self, worker: usize, params: &[Matrix]) -> Result<(f32, Layers)> {
        self.grad_at(worker, params, INIT_STEP)
    }

    /// Local train loss `f_j` + gradient for `worker` at `params` in round
    /// `step`. Objective backend: computed inline in the calling thread
    /// (workers run fully in parallel; `step` does not perturb the RNG
    /// stream). PJRT backend: proxied to the service thread, batches keyed
    /// by `(worker, step)`. Sharded backend: borrows the full model from
    /// the shard's per-round snapshot cache — the first worker of the shard
    /// assembles `params` (own layers) + the board snapshot sealed for
    /// `step` (other shards' layers) once; everyone else reuses the `Arc` —
    /// forwards, and projects the gradient back.
    pub fn grad_at(&mut self, worker: usize, params: &[Matrix], step: usize) -> Result<(f32, Layers)> {
        match &mut self.inner {
            HandleInner::Local { obj, seed, rng } => {
                // a handle caches one worker's stream; on a mismatch (handle
                // not specialized via for_worker, or reused across workers)
                // re-derive the requested worker's stream from the seed
                let seed = *seed;
                match rng {
                    Some((w, _)) if *w == worker => {}
                    _ => *rng = Some((worker, Rng::with_stream(seed, grad_stream(worker)))),
                }
                let (_, r) = rng.as_mut().expect("just installed");
                let g = obj.stoch_grad_j(worker, params, r);
                let loss = obj.loss_j(worker, params) as f32;
                Ok((loss, g))
            }
            HandleInner::Pjrt { tx } => {
                let (rtx, rrx) = channel();
                tx.send(Req::Grad { worker, step, params: params.to_vec(), reply: rtx })
                    .map_err(|_| anyhow!("grad service is down"))?;
                rrx.recv()
                    .map_err(|_| anyhow!("grad service dropped the request"))?
                    .map_err(anyhow::Error::msg)
            }
            HandleInner::Sharded { inner, board, layer_ids, cache } => {
                let ids: Arc<Vec<usize>> = layer_ids.get();
                // a shard owning every layer (the 1-shard cluster) needs no
                // assembly: skip the snapshot entirely so the golden-matched
                // deployment is cost-identical to the unsharded one
                if ids.len() == board.layers() {
                    return inner.grad_layers_at(worker, params, ids.as_slice(), step);
                }
                if step == INIT_STEP {
                    // un-keyed entry (worker init): reads the *newest*
                    // snapshot, which moves between calls — never cached
                    let full = assemble(board.as_ref(), ids.as_slice(), params, step)?;
                    return inner.grad_layers_at(worker, &full, ids.as_slice(), step);
                }
                let full = cache.get_or_assemble(board.as_ref(), ids.as_slice(), params, step)?;
                inner.grad_layers_at(worker, &full, ids.as_slice(), step)
            }
        }
    }

    /// Loss + gradient restricted to `layer_ids`, at full-model `params`.
    /// Objective backend: routes through
    /// [`Objective::stoch_grad_j_layers`], so layer-separable objectives
    /// only pay for the requested layers (the cluster's per-shard gradient
    /// cost) — and through [`Objective::loss_j_layers`], so the reported
    /// train loss is the shard's own contribution (summed by the cluster
    /// root) instead of a full-model recomputation per shard. Other
    /// backends compute the full gradient and project.
    fn grad_layers_at(
        &mut self,
        worker: usize,
        params: &[Matrix],
        layer_ids: &[usize],
        step: usize,
    ) -> Result<(f32, Layers)> {
        if let HandleInner::Local { obj, seed, rng } = &mut self.inner {
            let seed = *seed;
            match rng {
                Some((w, _)) if *w == worker => {}
                _ => *rng = Some((worker, Rng::with_stream(seed, grad_stream(worker)))),
            }
            let (_, r) = rng.as_mut().expect("just installed");
            let g = obj.stoch_grad_j_layers(worker, params, layer_ids, r);
            let loss = obj.loss_j_layers(worker, params, layer_ids) as f32;
            return Ok((loss, g));
        }
        let (loss, g_full) = self.grad_at(worker, params, step)?;
        Ok((loss, layer_ids.iter().map(|&li| g_full[li].clone()).collect()))
    }

    /// Evaluation loss at `params` (deterministic given params). Sharded
    /// handles evaluate the full model with the newest board snapshot
    /// standing in for the other shards' layers.
    pub fn eval(&self, params: &[Matrix]) -> Result<f32> {
        match &self.inner {
            HandleInner::Local { obj, .. } => Ok(obj.loss(params) as f32),
            HandleInner::Pjrt { tx } => {
                let (rtx, rrx) = channel();
                tx.send(Req::Eval { params: params.to_vec(), reply: rtx })
                    .map_err(|_| anyhow!("grad service is down"))?;
                rrx.recv()
                    .map_err(|_| anyhow!("grad service dropped the request"))?
                    .map_err(anyhow::Error::msg)
            }
            HandleInner::Sharded { inner, board, layer_ids, .. } => {
                let ids = layer_ids.get();
                if ids.len() == board.layers() {
                    return inner.eval(params);
                }
                let full = assemble(board.as_ref(), ids.as_slice(), params, INIT_STEP)?;
                inner.eval(&full)
            }
        }
    }

    /// Orthogonalize through the PJRT NS artifact; `Ok(None)` when the
    /// backend has no artifact for this shape (callers fall back to the
    /// native Newton–Schulz).
    pub fn ns_orthogonalize(&self, g: &Matrix) -> Result<Option<Matrix>> {
        match &self.inner {
            HandleInner::Local { .. } => Ok(None),
            HandleInner::Pjrt { tx } => {
                let (rtx, rrx) = channel();
                tx.send(Req::Ns { g: g.clone(), reply: rtx })
                    .map_err(|_| anyhow!("grad service is down"))?;
                rrx.recv()
                    .map_err(|_| anyhow!("grad service dropped the request"))?
                    .map_err(anyhow::Error::msg)
            }
            HandleInner::Sharded { inner, .. } => inner.ns_orthogonalize(g),
        }
    }
}

/// Validate a shard's own-layer slice against its id list and the board.
fn check_own(board: &ParamBoard, layer_ids: &[usize], own: &[Matrix]) -> Result<()> {
    if own.len() != layer_ids.len() {
        return Err(anyhow!(
            "sharded handle: got {} layers for a {}-layer shard",
            own.len(),
            layer_ids.len()
        ));
    }
    if let Some(&li) = layer_ids.iter().find(|&&li| li >= board.layers()) {
        return Err(anyhow!("sharded handle: layer id {li} out of range"));
    }
    Ok(())
}

/// Substitute a shard's own layers into the board's full-model snapshot for
/// `step` (the newest sealed snapshot for `INIT_STEP`). The uncached path —
/// worker init and offline eval; round-keyed requests go through
/// [`SnapCache::get_or_assemble`].
fn assemble(
    board: &ParamBoard,
    layer_ids: &[usize],
    own: &[Matrix],
    step: usize,
) -> Result<Layers> {
    check_own(board, layer_ids, own)?;
    let snap = if step == INIT_STEP { board.read_latest() } else { board.read(step) };
    // foreign layers expand at the board's stored width (f32 copy or bf16
    // widening — see `cluster::BoardSnap::expand_layer_into`)
    let mut full: Layers = (0..snap.len()).map(|i| snap.layer_to_matrix(i)).collect();
    for (m, &li) in own.iter().zip(layer_ids) {
        full[li] = m.clone();
    }
    Ok(full)
}

/// The gradient service (owns the backend; see module docs).
pub struct GradService {
    handle: GradHandle,
    /// PJRT backend only: request sender + service thread join handle.
    pjrt: Option<(Sender<Req>, JoinHandle<()>)>,
}

impl GradService {
    /// Synthetic backend: gradients evaluated inline in worker threads.
    pub fn spawn_objective(obj: Box<dyn Objective>, seed: u64) -> GradService {
        let obj: Arc<dyn Objective> = Arc::from(obj);
        GradService {
            handle: GradHandle { inner: HandleInner::Local { obj, seed, rng: None } },
            pjrt: None,
        }
    }

    /// PJRT backend: load the AOT artifacts from `artifacts`, build the
    /// synthetic corpus (`corpus_tokens` tokens) sharded over `workers`,
    /// pre-sample `eval_batches` held-out batches, and serve requests on a
    /// dedicated thread. Fails fast if the artifacts are missing or the XLA
    /// runtime is unavailable.
    pub fn spawn_pjrt(
        artifacts: String,
        workers: usize,
        corpus_tokens: usize,
        eval_batches: usize,
        seed: u64,
    ) -> Result<GradService> {
        let (tx, rx) = channel::<Req>();
        let (init_tx, init_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("efmuon-grad-svc".to_string())
            .spawn(move || pjrt_service_main(artifacts, workers, corpus_tokens, eval_batches, seed, rx, init_tx))
            .map_err(|e| anyhow!("spawning grad service: {e}"))?;
        match init_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = join.join();
                return Err(anyhow!(e));
            }
            Err(_) => {
                let _ = join.join();
                return Err(anyhow!("grad service died during init"));
            }
        }
        Ok(GradService {
            handle: GradHandle { inner: HandleInner::Pjrt { tx: tx.clone() } },
            pjrt: Some((tx, join)),
        })
    }

    /// A clonable handle onto this service.
    pub fn handle(&self) -> GradHandle {
        self.handle.clone()
    }
}

impl Drop for GradService {
    fn drop(&mut self) {
        if let Some((tx, join)) = self.pjrt.take() {
            let _ = tx.send(Req::Shutdown);
            let _ = join.join();
        }
    }
}

/// Service-thread main for the PJRT backend.
fn pjrt_service_main(
    artifacts: String,
    workers: usize,
    corpus_tokens: usize,
    eval_batches: usize,
    seed: u64,
    rx: Receiver<Req>,
    init_tx: Sender<Result<(), String>>,
) {
    let rt = match crate::runtime::ModelRuntime::load(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = init_tx.send(Err(format!("loading artifacts from {artifacts}: {e:#}")));
            return;
        }
    };
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq_len;
    let batch = rt.manifest.batch;
    let corpus = crate::data::Corpus::zipf_markov(corpus_tokens, vocab, seed);
    // held-out eval stream: disjoint seed, whole-corpus shard, pre-sampled
    // once so eval is a pure function of the parameters
    let mut eval_rng = Rng::with_stream(seed, 0xe7a1);
    let eval_shard = crate::data::Shard::new(&corpus, 0, 1, seq);
    let eval_set: Vec<(Vec<i32>, Vec<i32>)> = (0..eval_batches.max(1))
        .map(|_| eval_shard.sample_batch(batch, &mut eval_rng))
        .collect();
    let workers = workers.max(1);
    let _ = init_tx.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Grad { worker, step, params, reply } => {
                let out = (|| -> Result<(f32, Layers), String> {
                    if worker >= workers {
                        return Err(format!(
                            "worker {worker} out of range (service sized for {workers})"
                        ));
                    }
                    // batches are a pure function of (seed, worker, step):
                    // every shard coordinator of a cluster replays the same
                    // data for the same logical round, and requests arriving
                    // in any order sample identically
                    let shard = crate::data::Shard::new(&corpus, worker, workers, seq);
                    let mut rng = batch_rng(seed, worker, step);
                    let (toks, tgts) = shard.sample_batch(batch, &mut rng);
                    rt.grad(&params, &toks, &tgts).map_err(|e| format!("{e:#}"))
                })();
                let _ = reply.send(out);
            }
            Req::Eval { params, reply } => {
                let out = (|| -> Result<f32, String> {
                    let mut acc = 0.0f64;
                    for (toks, tgts) in &eval_set {
                        acc += rt
                            .eval_loss(&params, toks, tgts)
                            .map_err(|e| format!("{e:#}"))? as f64;
                    }
                    Ok((acc / eval_set.len() as f64) as f32)
                })();
                let _ = reply.send(out);
            }
            Req::Ns { g, reply } => {
                let out = match rt.ns_orthogonalize(&g) {
                    None => Ok(None),
                    Some(Ok(o)) => Ok(Some(o)),
                    Some(Err(e)) => Err(format!("{e:#}")),
                };
                let _ = reply.send(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::funcs::Quadratics;

    #[test]
    fn objective_backend_is_inline_and_deterministic() {
        let mut rng = Rng::new(70);
        let q = Quadratics::new(3, 6, 0.5, 0.0, &mut rng);
        let x0 = {
            let mut r = Rng::new(71);
            q.init(&mut r)
        };
        let svc = GradService::spawn_objective(Box::new(q), 9);
        let mut h0 = svc.handle().for_worker(0);
        let mut h0b = svc.handle().for_worker(0);
        let (l1, g1) = h0.grad(0, &x0).unwrap();
        let (l2, g2) = h0b.grad(0, &x0).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1[0].data, g2[0].data);
        let e1 = svc.handle().eval(&x0).unwrap();
        let e2 = svc.handle().eval(&x0).unwrap();
        assert_eq!(e1, e2);
        assert!(svc.handle().ns_orthogonalize(&x0[0]).unwrap().is_none());
    }

    #[test]
    fn pjrt_backend_fails_fast_without_artifacts() {
        let err = GradService::spawn_pjrt("definitely-missing-dir".into(), 1, 10_000, 1, 0)
            .err()
            .expect("must fail without artifacts");
        assert!(format!("{err:#}").contains("definitely-missing-dir"));
    }
}
