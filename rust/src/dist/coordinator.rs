//! The leader: owns the EF21 server state, one OS thread per worker, and
//! the round loop. Exactly Algorithm 3 — the same [`ServerState`] /
//! [`WorkerState`] machines as the sequential reference driver, so
//! `rust/tests/dist.rs` and `rust/tests/scenario.rs` can assert bit-equal
//! trajectories.
//!
//! Rounds run under a [`RoundMode`]: synchronous lock-step, or a bounded
//! pipeline (`Async { lookahead }`) that keeps up to `lookahead` broadcasts
//! in flight — workers compute round `i` on the previous broadcast while
//! the leader absorbs round `i-1`'s stragglers. Replies are routed into
//! per-round id-indexed slots by `(step, id)` and absorbed oldest-round
//! first, in worker order, so `Async { lookahead: 0 }` is bit-equal to the
//! synchronous loop.
//!
//! Determinism: worker replies are collected into id-indexed slots and
//! absorbed in worker order; per-layer LMO RNG streams are pre-split; the
//! threaded matmul is bit-stable in the thread count. A distributed run is
//! therefore reproducible from its seed on any machine — in every round
//! mode, because reply *arrival* order never influences absorption order.
//!
//! Fault model ([`FaultPolicy`], default off = fail-stop):
//!
//! - **Fail-stop (default).** A worker that fails (gradient error, bad
//!   broadcast, or a panic anywhere in its round — converted to a
//!   [`FromWorker::Failed`] by the worker's panic guard) surfaces as a
//!   clean `Err` from [`Coordinator::round`] / [`Coordinator::run`]; the
//!   leader never hangs on a dead worker. With the policy off the absorb
//!   loop is the plain blocking `recv()` — bit-identical to every release
//!   before the policy existed.
//! - **Straggler deadline.** With `deadline_ms > 0` the front round may
//!   absorb once the deadline has passed and at least
//!   `quorum_min = ceil(quorum · n)` workers have replied: the missing
//!   slots are marked `Skipped`, counted as stragglers, and the round
//!   aggregates over the quorum via [`ServerState::absorb_quorum`] — the
//!   EF21 estimator terms of the missing workers are simply left in place.
//!   A straggler's late reply (tagged with the already-absorbed step) is
//!   recognized through the `owed` set and folded into the estimator by
//!   [`ServerState::absorb_late`], so the server catches back up to the
//!   full aggregate. `quorum = 1.0` makes `quorum_min = n`: the deadline
//!   can never fire early and the trajectory stays bit-identical to
//!   lock-step (the golden anchor, asserted in `rust/tests/scenario.rs`).
//! - **Respawn.** With `max_respawns > 0` a `Failed` worker is relaunched
//!   through the existing `INIT_STEP` re-init path (fresh [`WorkerState`]
//!   seeded from the *current* server shift `W`, which already includes
//!   every issued broadcast) after an exponential backoff; its unanswered
//!   slots in all in-flight rounds are skipped (not counted as
//!   stragglers). The respawned worker's `Init` gradient is discarded —
//!   the server keeps the dead incarnation's estimator term as an accepted
//!   constant bias (DESIGN.md §Fault tolerance). Once a worker exhausts
//!   its budget the coordinator latches a terminal `Err`.

use std::collections::{HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::compress::Message;
use crate::linalg::matrix::{layers, Layers};
use crate::opt::ef21::{ServerState, WorkerState};
use crate::opt::{LayerGeometry, Schedule};
use crate::spec::CompSpec;
use crate::trace::{Phase, Tracer};

use super::comm::{FromWorker, ToWorker, Wire};
use super::fault::{FaultKind, FaultPlan, FaultPolicy};
use super::net::{ArmSpec, NetHub};
use super::sched::{ServerLayer, WorkerLayer};
use super::server::SpectralServer;
use super::service::GradHandle;
use super::{Meter, RoundMode, TransportMode};

/// Straggler debts older than this many rounds are forgotten: a `Drop`
/// fault (federated non-participation) never replies, and remembering its
/// `(step, id)` forever would leak. Late replies beyond the window are
/// protocol errors again — matching the pipeline bound, which also caps
/// how stale an absorbable uplink can be.
const OWED_WINDOW: usize = RoundMode::MAX_LOOKAHEAD;

/// Configuration of one distributed EF21-Muon deployment.
#[derive(Debug, Clone)]
pub struct CoordinatorCfg {
    pub n_workers: usize,
    /// w2s compressor descriptor (applied per layer), e.g.
    /// `CompSpec::Top { frac: 0.1, nat: true }`. Typed — the string grammar
    /// is parsed once at the `spec`/`config` boundary, never here.
    pub worker_comp: CompSpec,
    /// s2w compressor descriptor (per layer) for the EF21-P broadcast. Any
    /// contractive descriptor works end to end — `CompSpec::Id` reproduces
    /// the paper's dense-broadcast deployment, anything else activates
    /// bidirectional compression (`rust/tests/scenario.rs` locks both down).
    pub server_comp: CompSpec,
    /// Momentum β.
    pub beta: f32,
    /// Radius / learning-rate schedule.
    pub schedule: Schedule,
    pub transport: TransportMode,
    /// Round scheduling: lock-step or pipelined (see [`RoundMode`]).
    pub round_mode: RoundMode,
    pub seed: u64,
    /// Route spectral LMOs through the PJRT NS artifact when available.
    pub use_ns_artifact: bool,
    /// Straggler / quorum / respawn policy. [`FaultPolicy::off`] (the
    /// default) is bit-identical to the fail-stop lock-step deployment.
    pub fault: FaultPolicy,
    /// Deterministic fault-injection schedule for tests and benches; never
    /// part of a serialized `RunSpec`.
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// First round index this deployment will issue — nonzero when resuming
    /// from a checkpoint, so the schedule position is restored along with
    /// the parameters.
    pub start_step: usize,
    /// Round-phase event stamping. [`Tracer::Noop`] (the default on every
    /// spec-built cfg) reads no clock and takes no lock — the tracer-off
    /// deployment is bit-identical to one without the field.
    pub tracer: Tracer,
}

/// Telemetry of one [`Coordinator::round`] call.
///
/// In sync mode (and async with `lookahead = 0`) the call issues round
/// `step` *and* absorbs it, so `absorbed_step == Some(step)`. With a
/// positive lookahead the absorbed round trails the issued one; the first
/// `lookahead` calls absorb nothing (`absorbed_step == None`,
/// `train_loss` is NaN, `w2s_bytes_per_worker` is 0).
#[derive(Debug, Clone)]
pub struct RoundStats {
    /// The round whose broadcast this call issued.
    pub step: usize,
    /// The round whose uplinks this call absorbed, if any.
    pub absorbed_step: Option<usize>,
    /// Mean of the workers' local train losses in the absorbed round
    /// (over the workers that replied, under a partial quorum).
    pub train_loss: f32,
    /// LMO radius of round `step` (the issued round for [`Coordinator::round`]
    /// entries, the absorbed round for [`Coordinator::drain`] entries — in
    /// both cases the radius belongs to `step`).
    pub radius: f64,
    /// w2s bytes sent by one worker in the absorbed round (the paper's
    /// reporting unit).
    pub w2s_bytes_per_worker: usize,
    /// s2w broadcast bytes of the issued round (counted once).
    pub s2w_bytes: usize,
}

/// One worker's reply slot in an in-flight round.
enum Slot {
    /// No reply yet.
    Empty,
    /// The worker's uplink: local train loss, wire bytes, payload.
    Filled(f32, usize, Wire),
    /// The round will absorb without this worker (straggler past the
    /// deadline, or a dead worker whose replacement never saw this
    /// round's broadcast).
    Skipped,
}

/// One round in flight: its schedule info plus id-indexed reply slots.
struct InFlight {
    step: usize,
    radius: f64,
    slots: Vec<Slot>,
    filled: usize,
    skipped: usize,
    /// When the broadcast went out — the straggler deadline is measured
    /// from here.
    issued_at: Instant,
}

/// Telemetry of one absorbed round (internal).
struct Absorbed {
    step: usize,
    radius: f64,
    train_loss: f32,
    w2s_bytes_per_worker: usize,
}

/// Everything needed to (re)launch a worker thread. Built once in
/// [`Coordinator::spawn`] and used for the initial pool; kept on the
/// coordinator only when the policy grants a respawn budget (its
/// reply-channel sender would otherwise keep the channel open and defeat
/// the fail-stop disconnect detection).
struct WorkerLauncher {
    worker_comp: CompSpec,
    beta: f32,
    seed: u64,
    handle: GradHandle,
    reply_tx: Sender<FromWorker>,
    plan: Option<Arc<FaultPlan>>,
}

impl WorkerLauncher {
    /// Launch worker `j` with its shift mirror initialized to `w0` (X⁰ at
    /// first spawn; the current server W on respawn — which already
    /// includes every issued broadcast, so the replacement is in sync with
    /// the next round it will see).
    fn launch(
        &self,
        j: usize,
        w0: &Layers,
        label: &str,
    ) -> Result<(Sender<ToWorker>, JoinHandle<()>)> {
        let state = WorkerState::new(j, w0, &self.worker_comp, self.beta, self.seed);
        let (tx, rx) = channel::<ToWorker>();
        let rtx = self.reply_tx.clone();
        let h = self.handle.for_worker(j);
        let plan = self.plan.clone();
        let join = std::thread::Builder::new()
            .name(format!("efmuon-worker-{label}"))
            .spawn(move || worker_main(state, rx, rtx, h, plan))
            .map_err(|e| anyhow!("spawning worker {j}: {e}"))?;
        Ok((tx, join))
    }
}

/// How a failed worker id comes back, per transport: relaunch an
/// in-process thread, or re-open the id slot on the socket hub and wait
/// for a connection — the old worker redialing, or a fresh late joiner —
/// to claim it (elastic membership). Either way the replacement re-runs
/// the `INIT_STEP` path against the current server shift.
enum Respawner {
    Thread(WorkerLauncher),
    Net(Arc<NetHub>),
}

impl Respawner {
    fn launch(
        &self,
        j: usize,
        w0: &Layers,
        label: &str,
    ) -> Result<(Sender<ToWorker>, JoinHandle<()>)> {
        match self {
            Respawner::Thread(l) => l.launch(j, w0, label),
            Respawner::Net(hub) => hub.reclaim(j, w0),
        }
    }
}

/// The leader of a threaded EF21-Muon deployment.
pub struct Coordinator {
    server: ServerState,
    schedule: Schedule,
    transport: TransportMode,
    mode: RoundMode,
    spectral: SpectralServer,
    handle: GradHandle,
    /// Shared with the socket hub's reader threads in net deployments
    /// (they count heartbeat misses), sole owner otherwise.
    meter: Arc<Meter>,
    step: usize,
    pending: VecDeque<InFlight>,
    to_workers: Vec<Sender<ToWorker>>,
    from_workers: Receiver<FromWorker>,
    joins: Vec<JoinHandle<()>>,
    fault: FaultPolicy,
    /// Present iff `fault.max_respawns > 0` (see [`WorkerLauncher`] /
    /// [`Respawner`]).
    launcher: Option<Respawner>,
    /// Respawns consumed per worker id.
    attempts: Vec<u32>,
    /// Worker ids whose replacement's `Init` reply is still expected (and
    /// discarded when it lands, instead of being a protocol error).
    respawning: HashSet<usize>,
    /// `(step, id)` slots absorbed without a reply whose late uplink is
    /// still welcome ([`ServerState::absorb_late`]); pruned by
    /// [`OWED_WINDOW`] and on respawn.
    owed: HashSet<(usize, usize)>,
    /// First fatal error, latched: once a worker fails terminally, every
    /// further `round`/`drain` call fails fast instead of re-entering the
    /// protocol (a dying worker's command channel may linger briefly during
    /// unwind, so without the latch a retry could block on a reply that
    /// never comes).
    failed: Option<String>,
    /// The socket hub backing a net deployment (closed on drop); `None`
    /// for the in-process channel transport.
    hub: Option<Arc<NetHub>>,
    tracer: Tracer,
    /// Compressor descriptors, kept to rebuild per-layer compressors for
    /// layers adopted mid-run ([`Coordinator::accept_layer`]).
    worker_comp: CompSpec,
    server_comp: CompSpec,
}

impl Coordinator {
    /// Spawn the worker threads, run the Algorithm-3 initialization
    /// (`G⁰ = (1/n) Σⱼ ∇fⱼ(X⁰)`), and return the ready leader.
    pub fn spawn(
        x0: Layers,
        geometry: Vec<LayerGeometry>,
        handle: GradHandle,
        cfg: CoordinatorCfg,
    ) -> Result<Coordinator> {
        if cfg.n_workers == 0 {
            return Err(anyhow!("n_workers must be >= 1"));
        }
        cfg.fault.validate().map_err(|e| anyhow!(e))?;
        let mut server = ServerState::new(
            x0.clone(),
            geometry,
            &cfg.server_comp,
            cfg.n_workers,
            cfg.seed,
        );

        let (reply_tx, reply_rx) = channel::<FromWorker>();
        let launcher = WorkerLauncher {
            worker_comp: cfg.worker_comp,
            beta: cfg.beta,
            seed: cfg.seed,
            handle: handle.clone(),
            reply_tx,
            plan: cfg.fault_plan,
        };
        let mut to_workers = Vec::with_capacity(cfg.n_workers);
        let mut joins = Vec::with_capacity(cfg.n_workers);
        for j in 0..cfg.n_workers {
            let (tx, join) = launcher.launch(j, &x0, &j.to_string())?;
            to_workers.push(tx);
            joins.push(join);
        }
        // keep the launcher (and its reply-channel sender) only when the
        // policy can respawn; otherwise drop it so `recv()` disconnects as
        // soon as every worker thread has exited (fail-stop detection)
        let launcher = (cfg.fault.max_respawns > 0).then_some(Respawner::Thread(launcher));

        server.set_g0(collect_g0(&reply_rx, cfg.n_workers, &x0)?);

        Ok(Coordinator {
            server,
            schedule: cfg.schedule,
            transport: cfg.transport,
            mode: cfg.round_mode,
            spectral: SpectralServer::new(handle.clone(), cfg.use_ns_artifact),
            handle,
            meter: Arc::new(Meter::new()),
            step: cfg.start_step,
            pending: VecDeque::new(),
            to_workers,
            from_workers: reply_rx,
            joins,
            fault: cfg.fault,
            launcher,
            attempts: vec![0; cfg.n_workers],
            respawning: HashSet::new(),
            owed: HashSet::new(),
            failed: None,
            hub: None,
            tracer: cfg.tracer,
            worker_comp: cfg.worker_comp,
            server_comp: cfg.server_comp,
        })
    }

    /// Spawn a deployment over the socket transport: arm `hub` for
    /// `cfg.n_workers` id slots, wait for that many connections to claim
    /// them, then run the same Algorithm-3 initialization as
    /// [`Coordinator::spawn`]. The hub's reader threads feed the same reply
    /// channel the in-process workers would, so everything from the round
    /// loop down is transport-agnostic — a loopback TCP run is bit-identical
    /// to the channel run for the same cfg (asserted in
    /// `rust/tests/scenario.rs`). `cfg.fault_plan` is ignored here: compute
    /// faults are injected worker-side (`net::worker_loop` takes the plan),
    /// transport faults via `net::NetCfg::flaky`.
    pub fn spawn_net(
        x0: Layers,
        geometry: Vec<LayerGeometry>,
        handle: GradHandle,
        cfg: CoordinatorCfg,
        hub: Arc<NetHub>,
    ) -> Result<Coordinator> {
        if cfg.n_workers == 0 {
            return Err(anyhow!("n_workers must be >= 1"));
        }
        cfg.fault.validate().map_err(|e| anyhow!(e))?;
        let mut server = ServerState::new(
            x0.clone(),
            geometry,
            &cfg.server_comp,
            cfg.n_workers,
            cfg.seed,
        );

        let meter = Arc::new(Meter::new());
        let (reply_tx, reply_rx) = channel::<FromWorker>();
        hub.arm(ArmSpec {
            n_workers: cfg.n_workers,
            w0: x0.clone(),
            comp: cfg.worker_comp,
            beta: cfg.beta,
            seed: cfg.seed,
            reply_tx,
            meter: meter.clone(),
            tracer: cfg.tracer.clone(),
        });
        let claims = hub.wait_initial(cfg.n_workers)?;
        let mut to_workers = Vec::with_capacity(cfg.n_workers);
        let mut joins = Vec::with_capacity(cfg.n_workers);
        for c in claims {
            to_workers.push(c.tx);
            joins.push(c.reader);
        }
        let launcher = (cfg.fault.max_respawns > 0).then_some(Respawner::Net(hub.clone()));

        server.set_g0(collect_g0(&reply_rx, cfg.n_workers, &x0)?);

        Ok(Coordinator {
            server,
            schedule: cfg.schedule,
            transport: cfg.transport,
            mode: cfg.round_mode,
            spectral: SpectralServer::new(handle.clone(), cfg.use_ns_artifact),
            handle,
            meter,
            step: cfg.start_step,
            pending: VecDeque::new(),
            to_workers,
            from_workers: reply_rx,
            joins,
            fault: cfg.fault,
            launcher,
            attempts: vec![0; cfg.n_workers],
            respawning: HashSet::new(),
            owed: HashSet::new(),
            failed: None,
            hub: Some(hub),
            tracer: cfg.tracer,
            worker_comp: cfg.worker_comp,
            server_comp: cfg.server_comp,
        })
    }

    /// One [`Coordinator::round`] call of Algorithm 3: issue this round's
    /// broadcast, then absorb completed rounds until at most
    /// `lookahead` remain in flight (sync: exactly this round). After a
    /// failure, this and every later call fail fast with the original
    /// error.
    pub fn round(&mut self) -> Result<RoundStats> {
        self.check_alive()?;
        let r = self.round_inner();
        self.latch(r)
    }

    fn round_inner(&mut self) -> Result<RoundStats> {
        let t = self.schedule.at(self.step);

        // server: LMO step on the current gradient estimator (per-layer
        // fan-out; PJRT NS artifact when hooked). With a positive lookahead
        // the estimator is up to `lookahead` rounds stale — that staleness
        // is the price of overlapping leader and worker work.
        if self.spectral.enabled() {
            let spectral = &self.spectral;
            let hook = move |g: &crate::linalg::Matrix| spectral.orthogonalize(g);
            self.server.lmo_step_with(t, Some(&hook));
        } else {
            self.server.lmo_step(t);
        }

        // server: compress the shifted model (EF21-P), advance W, broadcast
        let bcast = self.server.broadcast();
        let (wire, s2w_bytes) = Wire::pack(bcast, self.transport);
        for tx in &self.to_workers {
            // a failed send to a respawnable worker is tolerated: the
            // worker's `Failed` reply is already queued (it always sends
            // one before its command channel closes), and processing it
            // will skip this round's slot and relaunch
            if tx
                .send(ToWorker::Round { step: self.step, broadcast: wire.clone() })
                .is_err()
                && self.launcher.is_none()
            {
                return Err(anyhow!("a worker thread has exited"));
            }
        }
        self.meter.record_broadcast(s2w_bytes as u64);
        self.tracer.stamp(Phase::Broadcast, self.step, None);
        let n = self.to_workers.len();
        self.pending.push_back(InFlight {
            step: self.step,
            radius: t,
            slots: (0..n).map(|_| Slot::Empty).collect(),
            filled: 0,
            skipped: 0,
            issued_at: Instant::now(),
        });
        let issued = self.step;
        self.step += 1;

        // absorb until at most `lookahead` rounds stay in flight
        let lookahead = self.mode.lookahead();
        let mut absorbed = None;
        while self.pending.len() > lookahead {
            absorbed = Some(self.absorb_oldest()?);
        }
        Ok(match absorbed {
            Some(a) => RoundStats {
                step: issued,
                absorbed_step: Some(a.step),
                train_loss: a.train_loss,
                radius: t,
                w2s_bytes_per_worker: a.w2s_bytes_per_worker,
                s2w_bytes,
            },
            None => RoundStats {
                step: issued,
                absorbed_step: None,
                train_loss: f32::NAN,
                radius: t,
                w2s_bytes_per_worker: 0,
                s2w_bytes,
            },
        })
    }

    /// Absorb every still-in-flight round without issuing new broadcasts.
    /// No-op in sync mode; async callers invoke this before a final eval /
    /// checkpoint so all issued rounds have landed. Returns one stats entry
    /// per drained round (`s2w_bytes` is 0 — their broadcasts were metered
    /// when issued).
    pub fn drain(&mut self) -> Result<Vec<RoundStats>> {
        self.check_alive()?;
        let r = self.drain_inner();
        self.latch(r)
    }

    fn drain_inner(&mut self) -> Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(self.pending.len());
        while !self.pending.is_empty() {
            let a = self.absorb_oldest()?;
            out.push(RoundStats {
                step: a.step,
                absorbed_step: Some(a.step),
                train_loss: a.train_loss,
                radius: a.radius,
                w2s_bytes_per_worker: a.w2s_bytes_per_worker,
                s2w_bytes: 0,
            });
        }
        Ok(out)
    }

    /// Drive `rounds` full rounds and drain the pipeline, so every issued
    /// round has been absorbed on return. Stats are reported in absorption
    /// order: the `rounds` per-call entries, then any drained tail.
    pub fn run(&mut self, rounds: usize) -> Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(rounds + self.mode.lookahead());
        for _ in 0..rounds {
            out.push(self.round()?);
        }
        out.extend(self.drain()?);
        Ok(out)
    }

    /// Hand the layer at local index `at` over to the cluster root (work
    /// stealing): quiesce the pipeline, collect every worker's `(W, M, G)`
    /// triple for the layer, and splice the server-side `(X, W, G)` out of
    /// the EF21 state machines. The returned state is bitwise as it stood
    /// after the last absorbed round — the command queue's serial order
    /// guarantees the workers have applied every issued broadcast first.
    pub fn release_layer(&mut self, at: usize) -> Result<(ServerLayer, Vec<WorkerLayer>)> {
        self.check_alive()?;
        let r = self.release_layer_inner(at);
        self.latch(r)
    }

    fn release_layer_inner(&mut self, at: usize) -> Result<(ServerLayer, Vec<WorkerLayer>)> {
        // quiesce: every issued round must be absorbed before layer state
        // can move, or in-flight uplinks would straddle the layer-count
        // change (no-op in sync mode; the drained rounds' telemetry was
        // already metered when issued)
        self.drain_inner()?;
        let n = self.to_workers.len();
        for tx in &self.to_workers {
            if tx.send(ToWorker::Release { at }).is_err() {
                return Err(anyhow!("a worker thread has exited"));
            }
        }
        let mut slots: Vec<Option<WorkerLayer>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            match self.from_workers.recv() {
                Ok(FromWorker::Released { id, state }) => {
                    if id >= n || slots[id].is_some() {
                        return Err(anyhow!(
                            "duplicate or out-of-range release reply from worker {id}"
                        ));
                    }
                    slots[id] = Some(state);
                }
                Ok(FromWorker::Failed { id, err }) => {
                    return Err(anyhow!("worker {id} failed during layer release: {err}"))
                }
                Ok(_) => {
                    return Err(anyhow!("unexpected reply during layer release"))
                }
                Err(_) => {
                    return Err(anyhow!("worker channel closed during layer release"))
                }
            }
        }
        let workers = slots
            .into_iter()
            .map(|s| s.expect("all release slots filled"))
            .collect();
        let (x, w, g) = self.server.release_layer(at);
        Ok((ServerLayer { x, w, g }, workers))
    }

    /// Adopt a migrated layer at local index `at`: quiesce the pipeline,
    /// splice the server-side state in (with a fresh compressor for the
    /// layer's shape), and forward each worker its `(W, M, G)` triple. No
    /// worker ack — the serial command queue orders the adoption before any
    /// subsequent round.
    pub fn accept_layer(
        &mut self,
        at: usize,
        geometry: LayerGeometry,
        server: ServerLayer,
        workers: Vec<WorkerLayer>,
    ) -> Result<()> {
        self.check_alive()?;
        let r = self.accept_layer_inner(at, geometry, server, workers);
        self.latch(r)
    }

    fn accept_layer_inner(
        &mut self,
        at: usize,
        geometry: LayerGeometry,
        server: ServerLayer,
        workers: Vec<WorkerLayer>,
    ) -> Result<()> {
        self.drain_inner()?;
        if workers.len() != self.to_workers.len() {
            return Err(anyhow!(
                "migrated layer carries {} worker states for {} workers",
                workers.len(),
                self.to_workers.len()
            ));
        }
        let (rows, cols) = (server.x.rows, server.x.cols);
        let ServerLayer { x, w, g } = server;
        self.server.accept_layer(
            at,
            x,
            w,
            g,
            geometry,
            self.server_comp.for_shape(rows, cols).build(),
        );
        for (tx, state) in self.to_workers.iter().zip(workers) {
            let comp = self.worker_comp.for_shape(rows, cols).build();
            if tx.send(ToWorker::Accept { at, state, comp }).is_err() {
                return Err(anyhow!("a worker thread has exited"));
            }
        }
        Ok(())
    }

    /// Fail fast if a previous round already hit a fatal error.
    fn check_alive(&self) -> Result<()> {
        match &self.failed {
            Some(e) => Err(anyhow!("coordinator already failed: {e}")),
            None => Ok(()),
        }
    }

    /// Latch the first fatal error so later calls fail fast.
    fn latch<T>(&mut self, r: Result<T>) -> Result<T> {
        if let Err(e) = &r {
            if self.failed.is_none() {
                self.failed = Some(format!("{e:#}"));
            }
        }
        r
    }

    /// Receive replies until the oldest in-flight round is complete — every
    /// slot filled, or (past the straggler deadline, with quorum met) the
    /// missing slots skipped — then absorb it in worker-id order and return
    /// its telemetry. With the fault policy off this is the plain blocking
    /// loop, bit-identical to the fail-stop deployment.
    fn absorb_oldest(&mut self) -> Result<Absorbed> {
        loop {
            let (n, filled, skipped, elapsed) = match self.pending.front() {
                Some(p) => (p.slots.len(), p.filled, p.skipped, p.issued_at.elapsed()),
                None => return Err(anyhow!("no round in flight to absorb")),
            };
            if filled + skipped == n {
                break;
            }
            if self.fault.deadline_ms == 0 {
                // fail-stop absorb: block until the next reply
                match self.from_workers.recv() {
                    Ok(msg) => self.handle_reply(msg)?,
                    Err(_) => return Err(anyhow!("worker channel closed mid-round")),
                }
                continue;
            }
            let deadline = Duration::from_millis(self.fault.deadline_ms);
            if elapsed >= deadline {
                if filled >= self.fault.quorum_min(n) {
                    self.skip_stragglers();
                    break;
                }
                // deadline passed but quorum not met: keep waiting
                match self.from_workers.recv() {
                    Ok(msg) => self.handle_reply(msg)?,
                    Err(_) => return Err(anyhow!("worker channel closed mid-round")),
                }
            } else {
                match self.from_workers.recv_timeout(deadline - elapsed) {
                    Ok(msg) => self.handle_reply(msg)?,
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(anyhow!("worker channel closed mid-round"))
                    }
                }
            }
        }
        self.finalize_front()
    }

    /// Mark every empty slot of the front round `Skipped` and record the
    /// skipped workers as owed stragglers.
    fn skip_stragglers(&mut self) {
        let p = self.pending.front_mut().expect("pending non-empty");
        let mut newly = Vec::new();
        for (id, slot) in p.slots.iter_mut().enumerate() {
            if matches!(slot, Slot::Empty) {
                *slot = Slot::Skipped;
                newly.push(id);
            }
        }
        p.skipped += newly.len();
        let front_step = p.step;
        for &id in &newly {
            self.owed.insert((front_step, id));
            self.tracer.stamp(Phase::StragglerSkip, front_step, Some(id));
        }
        self.meter.record_stragglers(newly.len() as u64);
    }

    /// Route one worker message: a current reply into its round's id-slot,
    /// an owed straggler's late uplink into the server estimator, a failure
    /// into the respawn path (or a terminal error).
    fn handle_reply(&mut self, msg: FromWorker) -> Result<()> {
        match msg {
            FromWorker::Round { id, step, loss, bytes, uplink } => {
                let front_step = self.pending.front().expect("pending non-empty").step;
                if step < front_step {
                    if self.owed.remove(&(step, id)) {
                        // a straggler's late uplink: its round already
                        // absorbed without it — fold the residual into the
                        // estimator so the server catches back up
                        let msgs = uplink.unpack().map_err(anyhow::Error::msg)?;
                        self.server.absorb_late(&msgs);
                        self.meter.record_late_uplink(bytes as u64);
                        self.tracer.stamp(Phase::LateFold, step, Some(id));
                        return Ok(());
                    }
                    return Err(anyhow!(
                        "worker {id} replied for already-absorbed step {step}"
                    ));
                }
                let p = match self.pending.get_mut(step - front_step) {
                    Some(p) => p,
                    None => {
                        return Err(anyhow!("worker {id} replied for un-issued step {step}"))
                    }
                };
                if id >= p.slots.len() || !matches!(p.slots[id], Slot::Empty) {
                    return Err(anyhow!(
                        "duplicate or out-of-range reply from worker {id} at step {step}"
                    ));
                }
                p.slots[id] = Slot::Filled(loss, bytes, uplink);
                p.filled += 1;
                self.tracer.stamp(Phase::Uplink, step, Some(id));
                Ok(())
            }
            FromWorker::Failed { id, err } => self.handle_failure(id, &err),
            FromWorker::Released { id, .. } => {
                // legitimate only inside `release_layer`'s dedicated recv
                // loop, which runs with zero rounds in flight
                Err(anyhow!("unexpected layer release from worker {id}"))
            }
            FromWorker::Init { id, .. } => {
                // a respawned worker re-runs the INIT_STEP path; its fresh
                // G⁰ⱼ is discarded — the server keeps the dead
                // incarnation's estimator term (accepted constant bias)
                if self.respawning.remove(&id) {
                    Ok(())
                } else {
                    Err(anyhow!("unexpected re-init from worker {id}"))
                }
            }
        }
    }

    /// A worker reported failure: relaunch it if the policy still has
    /// budget for this id, else return the terminal error.
    fn handle_failure(&mut self, id: usize, err: &str) -> Result<()> {
        if self.launcher.is_none() {
            return Err(anyhow!("worker {id} failed: {err}"));
        }
        let attempt = self.attempts[id] + 1;
        if attempt > self.fault.max_respawns {
            return Err(anyhow!(
                "worker {id} failed after {} respawn(s): {err}",
                self.attempts[id]
            ));
        }
        self.attempts[id] = attempt;
        // the dead worker can no longer answer any in-flight round, and its
        // replacement never saw those broadcasts (sent on the old channel):
        // skip its slots so the rounds complete over the remaining workers.
        // Not counted as stragglers — these are failures, not slow replies.
        for p in self.pending.iter_mut() {
            if matches!(p.slots[id], Slot::Empty) {
                p.slots[id] = Slot::Skipped;
                p.skipped += 1;
            }
        }
        // any late uplink it owed will never come
        self.owed.retain(|&(_, w)| w != id);
        let backoff = self.fault.backoff_for(attempt);
        if backoff > 0 {
            std::thread::sleep(Duration::from_millis(backoff));
        }
        let launcher = self.launcher.as_ref().expect("respawn requires a launcher");
        let (tx, join) =
            launcher.launch(id, &self.server.w, &format!("{id}r{attempt}"))?;
        self.to_workers[id] = tx;
        self.joins.push(join);
        self.respawning.insert(id);
        self.meter.record_respawn();
        self.tracer.stamp(Phase::Respawn, self.step, Some(id));
        Ok(())
    }

    /// Pop the completed front round and absorb it in worker-id order.
    /// A fully-replied round takes the exact fail-stop path
    /// ([`ServerState::absorb`]); a round with skipped slots aggregates
    /// over its quorum ([`ServerState::absorb_quorum`]).
    fn finalize_front(&mut self) -> Result<Absorbed> {
        let p = self.pending.pop_front().expect("pending non-empty");
        // forget straggler debts the pipeline has left far behind
        let front_step = p.step;
        self.owed.retain(|&(s, _)| s + OWED_WINDOW >= front_step);
        let n = p.slots.len();
        if p.skipped == 0 {
            let mut all_msgs = Vec::with_capacity(n);
            let mut loss_acc = 0.0f64;
            let mut w2s_per_worker = 0usize;
            let mut w2s_all = 0u64;
            // decode + absorb in worker-id order (determinism contract)
            for slot in p.slots.into_iter() {
                let (loss, bytes, uplink) = match slot {
                    Slot::Filled(loss, bytes, uplink) => (loss, bytes, uplink),
                    _ => unreachable!("all round slots filled"),
                };
                loss_acc += loss as f64;
                w2s_per_worker = bytes;
                w2s_all += bytes as u64;
                all_msgs.push(uplink.unpack().map_err(anyhow::Error::msg)?);
            }
            self.server.absorb(&all_msgs);
            self.meter.record_uplinks(w2s_per_worker as u64, w2s_all);
            self.tracer.stamp(Phase::Absorb, p.step, None);
            Ok(Absorbed {
                step: p.step,
                radius: p.radius,
                train_loss: (loss_acc / n as f64) as f32,
                w2s_bytes_per_worker: w2s_per_worker,
            })
        } else {
            let mut quorum_msgs: Vec<Option<Vec<Message>>> = Vec::with_capacity(n);
            let mut loss_acc = 0.0f64;
            let mut replied = 0usize;
            let mut w2s_per_worker = 0usize;
            let mut w2s_all = 0u64;
            for slot in p.slots.into_iter() {
                match slot {
                    Slot::Filled(loss, bytes, uplink) => {
                        loss_acc += loss as f64;
                        replied += 1;
                        w2s_per_worker = bytes;
                        w2s_all += bytes as u64;
                        quorum_msgs.push(Some(uplink.unpack().map_err(anyhow::Error::msg)?));
                    }
                    Slot::Skipped => quorum_msgs.push(None),
                    Slot::Empty => unreachable!("front round completed"),
                }
            }
            self.server.absorb_quorum(&quorum_msgs);
            self.meter.record_uplinks(w2s_per_worker as u64, w2s_all);
            self.meter.record_partial_round();
            self.tracer.stamp(Phase::Quorum, p.step, None);
            Ok(Absorbed {
                step: p.step,
                radius: p.radius,
                // NaN when nobody replied (possible only via respawn skips)
                train_loss: (loss_acc / replied as f64) as f32,
                w2s_bytes_per_worker: w2s_per_worker,
            })
        }
    }

    /// Evaluation loss at the current server parameters (borrowed — the
    /// objective backend never copies the model to evaluate it). In async
    /// modes the parameters already include every *issued* LMO step;
    /// uplinks of still-in-flight rounds land only after
    /// [`Coordinator::drain`].
    pub fn eval(&self) -> Result<f32> {
        self.handle.eval(&self.server.x)
    }

    /// Current model parameters (server X).
    pub fn params(&self) -> &Layers {
        &self.server.x
    }

    /// Current broadcast shift (server W). Every worker's local W coincides
    /// with this bit-for-bit once it has applied the issued broadcasts; the
    /// cluster's parameter board publishes it as the cross-shard view.
    pub fn shift(&self) -> &Layers {
        &self.server.w
    }

    /// Cumulative communication meters.
    pub fn meter(&self) -> &Meter {
        &self.meter
    }

    /// Rounds issued (broadcast sent) so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Rounds currently in flight (0 in sync mode between calls).
    pub fn pending_rounds(&self) -> usize {
        self.pending.len()
    }
}

/// Algorithm-3 initialization: collect every worker's `G⁰ⱼ` into id-slots
/// and average in worker order (bit-identical to the sequential driver's
/// init loop) — shared by the channel and socket spawn paths, which feed
/// the same reply channel.
fn collect_g0(reply_rx: &Receiver<FromWorker>, n: usize, x0: &Layers) -> Result<Layers> {
    let mut g0: Vec<Option<Layers>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        match reply_rx.recv() {
            Ok(FromWorker::Init { id, g0: g }) => g0[id] = Some(g),
            Ok(FromWorker::Failed { id, err }) => {
                return Err(anyhow!("worker {id} failed during init: {err}"))
            }
            Ok(FromWorker::Round { id, .. }) => {
                return Err(anyhow!("worker {id} sent a round reply before init"))
            }
            Ok(FromWorker::Released { id, .. }) => {
                return Err(anyhow!("worker {id} sent a release reply before init"))
            }
            Err(_) => return Err(anyhow!("worker channel closed during init")),
        }
    }
    let mut g0_avg = layers::zeros_like(x0);
    let inv = 1.0 / n as f32;
    for g in g0.into_iter() {
        layers::axpy(&mut g0_avg, inv, &g.expect("all init slots filled"));
    }
    Ok(g0_avg)
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Stop);
        }
        // release the launcher's reply-channel sender with the rest
        self.launcher = None;
        // net mode: the writer threads forward Stop and exit; the reader
        // threads (these joins) see the clean EOF and exit silently
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        if let Some(hub) = self.hub.take() {
            hub.close();
        }
    }
}

/// Converts a worker-thread panic into a [`FromWorker::Failed`] reply: the
/// guard's `Drop` runs during unwinding while the reply channel is still
/// alive, so the leader gets a clean error instead of waiting forever for
/// a reply that will never come.
struct PanicGuard {
    id: usize,
    tx: Sender<FromWorker>,
}

impl Drop for PanicGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            let _ = self.tx.send(FromWorker::Failed {
                id: self.id,
                err: "worker thread panicked".into(),
            });
        }
    }
}

/// Worker-thread main loop: init, then one EF21 local step per command.
/// The `plan` hook injects deterministic faults for tests/benches. Also
/// the compute loop of a socket worker (`net::worker_loop` drives it over
/// channels bridged to the TCP link) — one loop, every transport, which is
/// what makes loopback ≡ channel a determinism contract rather than a
/// coincidence.
pub(crate) fn worker_main(
    mut state: WorkerState,
    rx: Receiver<ToWorker>,
    tx: Sender<FromWorker>,
    mut handle: GradHandle,
    plan: Option<Arc<FaultPlan>>,
) {
    let id = state.id;
    let _guard = PanicGuard { id, tx: tx.clone() };
    // theory init: M⁰ⱼ = G⁰ⱼ = ∇fⱼ(X⁰) (W starts at X⁰)
    match handle.grad(id, &state.w) {
        Ok((_, grad0)) => {
            let g0 = state.init_estimators(grad0);
            if tx.send(FromWorker::Init { id, g0 }).is_err() {
                return;
            }
        }
        Err(e) => {
            let _ = tx.send(FromWorker::Failed { id, err: format!("{e:#}") });
            return;
        }
    }
    while let Ok(cmd) = rx.recv() {
        let (step, broadcast) = match cmd {
            ToWorker::Stop => break,
            ToWorker::Release { at } => {
                // cluster work stealing: hand the layer's EF21 state back.
                // Arrives only with zero rounds in flight, so the triple is
                // post-every-absorbed-round by the queue's serial order.
                let (w, m, g) = state.release_layer(at);
                let layer = WorkerLayer { w, m, g };
                if tx.send(FromWorker::Released { id, state: layer }).is_err() {
                    break;
                }
                continue;
            }
            ToWorker::Accept { at, state: layer, comp } => {
                let WorkerLayer { w, m, g } = layer;
                state.accept_layer(at, w, m, g, comp);
                continue;
            }
            ToWorker::Round { step, broadcast } => (step, broadcast),
        };
        let fault = plan.as_ref().and_then(|p| p.at(id, step));
        if matches!(fault, Some(FaultKind::Panic)) {
            panic!("injected fault: worker {id} panics at step {step}");
        }
        let mode = broadcast.mode();
        let msgs = match broadcast.unpack() {
            Ok(m) => m,
            Err(e) => {
                let _ = tx.send(FromWorker::Failed { id, err: format!("bad broadcast: {e}") });
                break;
            }
        };
        state.apply_broadcast(&msgs);
        if matches!(fault, Some(FaultKind::Drop)) {
            // federated non-participation: shift stays in sync, but the
            // local step and reply are skipped — the slot stays owed
            continue;
        }
        if let Some(FaultKind::DelayMs(ms)) = fault {
            std::thread::sleep(Duration::from_millis(ms));
        }
        // the round index doubles as the data/board epoch: sharded handles
        // read the cross-shard parameter snapshot sealed for this round, and
        // the PJRT service keys batch sampling on (worker, step) so cluster
        // deployments replaying the same round sample the same data
        let (loss, grad) = match handle.grad_at(id, &state.w, step) {
            Ok(v) => v,
            Err(e) => {
                let _ = tx.send(FromWorker::Failed { id, err: format!("{e:#}") });
                break;
            }
        };
        let uplink_msgs = state.local_step(&grad);
        let (uplink, bytes) = Wire::pack(uplink_msgs, mode);
        if tx
            .send(FromWorker::Round { id, step, loss, bytes, uplink })
            .is_err()
        {
            break;
        }
    }
}
