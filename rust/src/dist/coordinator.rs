//! The leader: owns the EF21 server state, one OS thread per worker, and
//! the round loop. Exactly Algorithm 3 — the same [`ServerState`] /
//! [`WorkerState`] machines as the sequential reference driver, so
//! `rust/tests/dist.rs` and `rust/tests/scenario.rs` can assert bit-equal
//! trajectories.
//!
//! Rounds run under a [`RoundMode`]: synchronous lock-step, or a bounded
//! pipeline (`Async { lookahead }`) that keeps up to `lookahead` broadcasts
//! in flight — workers compute round `i` on the previous broadcast while
//! the leader absorbs round `i-1`'s stragglers. Replies are routed into
//! per-round id-indexed slots by `(step, id)` and absorbed oldest-round
//! first, in worker order, so `Async { lookahead: 0 }` is bit-equal to the
//! synchronous loop.
//!
//! Determinism: worker replies are collected into id-indexed slots and
//! absorbed in worker order; per-layer LMO RNG streams are pre-split; the
//! threaded matmul is bit-stable in the thread count. A distributed run is
//! therefore reproducible from its seed on any machine — in every round
//! mode, because reply *arrival* order never influences absorption order.
//!
//! Fault model: a worker that fails (gradient error, bad broadcast, or a
//! panic anywhere in its round — converted to a [`FromWorker::Failed`] by
//! the worker's panic guard) surfaces as a clean `Err` from
//! [`Coordinator::round`] / [`Coordinator::run`]; the leader never hangs
//! on a dead worker.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::linalg::matrix::{layers, Layers};
use crate::opt::ef21::{ServerState, WorkerState};
use crate::opt::{LayerGeometry, Schedule};
use crate::spec::CompSpec;

use super::comm::{FromWorker, ToWorker, Wire};
use super::server::SpectralServer;
use super::service::GradHandle;
use super::{Meter, RoundMode, TransportMode};

/// Configuration of one distributed EF21-Muon deployment.
#[derive(Debug, Clone)]
pub struct CoordinatorCfg {
    pub n_workers: usize,
    /// w2s compressor descriptor (applied per layer), e.g.
    /// `CompSpec::Top { frac: 0.1, nat: true }`. Typed — the string grammar
    /// is parsed once at the `spec`/`config` boundary, never here.
    pub worker_comp: CompSpec,
    /// s2w compressor descriptor (per layer) for the EF21-P broadcast. Any
    /// contractive descriptor works end to end — `CompSpec::Id` reproduces
    /// the paper's dense-broadcast deployment, anything else activates
    /// bidirectional compression (`rust/tests/scenario.rs` locks both down).
    pub server_comp: CompSpec,
    /// Momentum β.
    pub beta: f32,
    /// Radius / learning-rate schedule.
    pub schedule: Schedule,
    pub transport: TransportMode,
    /// Round scheduling: lock-step or pipelined (see [`RoundMode`]).
    pub round_mode: RoundMode,
    pub seed: u64,
    /// Route spectral LMOs through the PJRT NS artifact when available.
    pub use_ns_artifact: bool,
}

/// Telemetry of one [`Coordinator::round`] call.
///
/// In sync mode (and async with `lookahead = 0`) the call issues round
/// `step` *and* absorbs it, so `absorbed_step == Some(step)`. With a
/// positive lookahead the absorbed round trails the issued one; the first
/// `lookahead` calls absorb nothing (`absorbed_step == None`,
/// `train_loss` is NaN, `w2s_bytes_per_worker` is 0).
#[derive(Debug, Clone)]
pub struct RoundStats {
    /// The round whose broadcast this call issued.
    pub step: usize,
    /// The round whose uplinks this call absorbed, if any.
    pub absorbed_step: Option<usize>,
    /// Mean of the workers' local train losses in the absorbed round.
    pub train_loss: f32,
    /// LMO radius of round `step` (the issued round for [`Coordinator::round`]
    /// entries, the absorbed round for [`Coordinator::drain`] entries — in
    /// both cases the radius belongs to `step`).
    pub radius: f64,
    /// w2s bytes sent by one worker in the absorbed round (the paper's
    /// reporting unit).
    pub w2s_bytes_per_worker: usize,
    /// s2w broadcast bytes of the issued round (counted once).
    pub s2w_bytes: usize,
}

/// One round in flight: its schedule info plus id-indexed reply slots.
struct InFlight {
    step: usize,
    radius: f64,
    slots: Vec<Option<(f32, usize, Wire)>>,
    filled: usize,
}

/// Telemetry of one absorbed round (internal).
struct Absorbed {
    step: usize,
    radius: f64,
    train_loss: f32,
    w2s_bytes_per_worker: usize,
}

/// The leader of a threaded EF21-Muon deployment.
pub struct Coordinator {
    server: ServerState,
    schedule: Schedule,
    transport: TransportMode,
    mode: RoundMode,
    spectral: SpectralServer,
    handle: GradHandle,
    meter: Meter,
    step: usize,
    pending: VecDeque<InFlight>,
    to_workers: Vec<Sender<ToWorker>>,
    from_workers: Receiver<FromWorker>,
    joins: Vec<JoinHandle<()>>,
    /// First fatal error, latched: once a worker fails, every further
    /// `round`/`drain` call fails fast instead of re-entering the protocol
    /// (a dying worker's command channel may linger briefly during unwind,
    /// so without the latch a retry could block on a reply that never comes).
    failed: Option<String>,
}

impl Coordinator {
    /// Spawn the worker threads, run the Algorithm-3 initialization
    /// (`G⁰ = (1/n) Σⱼ ∇fⱼ(X⁰)`), and return the ready leader.
    pub fn spawn(
        x0: Layers,
        geometry: Vec<LayerGeometry>,
        handle: GradHandle,
        cfg: CoordinatorCfg,
    ) -> Result<Coordinator> {
        if cfg.n_workers == 0 {
            return Err(anyhow!("n_workers must be >= 1"));
        }
        let mut server = ServerState::new(
            x0.clone(),
            geometry,
            &cfg.server_comp,
            cfg.n_workers,
            cfg.seed,
        );

        let (reply_tx, reply_rx) = channel::<FromWorker>();
        let mut to_workers = Vec::with_capacity(cfg.n_workers);
        let mut joins = Vec::with_capacity(cfg.n_workers);
        for j in 0..cfg.n_workers {
            let state = WorkerState::new(j, &x0, &cfg.worker_comp, cfg.beta, cfg.seed);
            let (tx, rx) = channel::<ToWorker>();
            let rtx = reply_tx.clone();
            let h = handle.for_worker(j);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("efmuon-worker-{j}"))
                    .spawn(move || worker_main(state, rx, rtx, h))
                    .map_err(|e| anyhow!("spawning worker {j}: {e}"))?,
            );
            to_workers.push(tx);
        }
        drop(reply_tx);

        // initialization: collect G⁰ⱼ into id-slots, average in worker order
        // (bit-identical to the sequential driver's init loop)
        let mut g0: Vec<Option<Layers>> = (0..cfg.n_workers).map(|_| None).collect();
        for _ in 0..cfg.n_workers {
            match reply_rx.recv() {
                Ok(FromWorker::Init { id, g0: g }) => g0[id] = Some(g),
                Ok(FromWorker::Failed { id, err }) => {
                    return Err(anyhow!("worker {id} failed during init: {err}"))
                }
                Ok(FromWorker::Round { id, .. }) => {
                    return Err(anyhow!("worker {id} sent a round reply before init"))
                }
                Err(_) => return Err(anyhow!("worker channel closed during init")),
            }
        }
        let mut g0_avg = layers::zeros_like(&x0);
        let inv = 1.0 / cfg.n_workers as f32;
        for g in g0.into_iter() {
            layers::axpy(&mut g0_avg, inv, &g.expect("all init slots filled"));
        }
        server.set_g0(g0_avg);

        Ok(Coordinator {
            server,
            schedule: cfg.schedule,
            transport: cfg.transport,
            mode: cfg.round_mode,
            spectral: SpectralServer::new(handle.clone(), cfg.use_ns_artifact),
            handle,
            meter: Meter::new(),
            step: 0,
            pending: VecDeque::new(),
            to_workers,
            from_workers: reply_rx,
            joins,
            failed: None,
        })
    }

    /// One [`Coordinator::round`] call of Algorithm 3: issue this round's
    /// broadcast, then absorb completed rounds until at most
    /// `lookahead` remain in flight (sync: exactly this round). After a
    /// failure, this and every later call fail fast with the original
    /// error.
    pub fn round(&mut self) -> Result<RoundStats> {
        self.check_alive()?;
        let r = self.round_inner();
        self.latch(r)
    }

    fn round_inner(&mut self) -> Result<RoundStats> {
        let t = self.schedule.at(self.step);

        // server: LMO step on the current gradient estimator (per-layer
        // fan-out; PJRT NS artifact when hooked). With a positive lookahead
        // the estimator is up to `lookahead` rounds stale — that staleness
        // is the price of overlapping leader and worker work.
        if self.spectral.enabled() {
            let spectral = &self.spectral;
            let hook = move |g: &crate::linalg::Matrix| spectral.orthogonalize(g);
            self.server.lmo_step_with(t, Some(&hook));
        } else {
            self.server.lmo_step(t);
        }

        // server: compress the shifted model (EF21-P), advance W, broadcast
        let bcast = self.server.broadcast();
        let (wire, s2w_bytes) = Wire::pack(bcast, self.transport);
        for tx in &self.to_workers {
            tx.send(ToWorker::Round { step: self.step, broadcast: wire.clone() })
                .map_err(|_| anyhow!("a worker thread has exited"))?;
        }
        self.meter.record_broadcast(s2w_bytes as u64);
        let n = self.to_workers.len();
        self.pending.push_back(InFlight {
            step: self.step,
            radius: t,
            slots: (0..n).map(|_| None).collect(),
            filled: 0,
        });
        let issued = self.step;
        self.step += 1;

        // absorb until at most `lookahead` rounds stay in flight
        let lookahead = self.mode.lookahead();
        let mut absorbed = None;
        while self.pending.len() > lookahead {
            absorbed = Some(self.absorb_oldest()?);
        }
        Ok(match absorbed {
            Some(a) => RoundStats {
                step: issued,
                absorbed_step: Some(a.step),
                train_loss: a.train_loss,
                radius: t,
                w2s_bytes_per_worker: a.w2s_bytes_per_worker,
                s2w_bytes,
            },
            None => RoundStats {
                step: issued,
                absorbed_step: None,
                train_loss: f32::NAN,
                radius: t,
                w2s_bytes_per_worker: 0,
                s2w_bytes,
            },
        })
    }

    /// Absorb every still-in-flight round without issuing new broadcasts.
    /// No-op in sync mode; async callers invoke this before a final eval /
    /// checkpoint so all issued rounds have landed. Returns one stats entry
    /// per drained round (`s2w_bytes` is 0 — their broadcasts were metered
    /// when issued).
    pub fn drain(&mut self) -> Result<Vec<RoundStats>> {
        self.check_alive()?;
        let r = self.drain_inner();
        self.latch(r)
    }

    fn drain_inner(&mut self) -> Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(self.pending.len());
        while !self.pending.is_empty() {
            let a = self.absorb_oldest()?;
            out.push(RoundStats {
                step: a.step,
                absorbed_step: Some(a.step),
                train_loss: a.train_loss,
                radius: a.radius,
                w2s_bytes_per_worker: a.w2s_bytes_per_worker,
                s2w_bytes: 0,
            });
        }
        Ok(out)
    }

    /// Drive `rounds` full rounds and drain the pipeline, so every issued
    /// round has been absorbed on return. Stats are reported in absorption
    /// order: the `rounds` per-call entries, then any drained tail.
    pub fn run(&mut self, rounds: usize) -> Result<Vec<RoundStats>> {
        let mut out = Vec::with_capacity(rounds + self.mode.lookahead());
        for _ in 0..rounds {
            out.push(self.round()?);
        }
        out.extend(self.drain()?);
        Ok(out)
    }

    /// Fail fast if a previous round already hit a fatal error.
    fn check_alive(&self) -> Result<()> {
        match &self.failed {
            Some(e) => Err(anyhow!("coordinator already failed: {e}")),
            None => Ok(()),
        }
    }

    /// Latch the first fatal error so later calls fail fast.
    fn latch<T>(&mut self, r: Result<T>) -> Result<T> {
        if let Err(e) = &r {
            if self.failed.is_none() {
                self.failed = Some(format!("{e:#}"));
            }
        }
        r
    }

    /// Receive replies until the oldest in-flight round is complete, then
    /// absorb it in worker-id order and return its telemetry.
    fn absorb_oldest(&mut self) -> Result<Absorbed> {
        loop {
            let done = match self.pending.front() {
                Some(p) => p.filled == p.slots.len(),
                None => return Err(anyhow!("no round in flight to absorb")),
            };
            if done {
                break;
            }
            match self.from_workers.recv() {
                Ok(FromWorker::Round { id, step, loss, bytes, uplink }) => {
                    let front_step = self.pending.front().expect("pending non-empty").step;
                    if step < front_step {
                        return Err(anyhow!(
                            "worker {id} replied for already-absorbed step {step}"
                        ));
                    }
                    let p = match self.pending.get_mut(step - front_step) {
                        Some(p) => p,
                        None => {
                            return Err(anyhow!("worker {id} replied for un-issued step {step}"))
                        }
                    };
                    if id >= p.slots.len() || p.slots[id].is_some() {
                        return Err(anyhow!(
                            "duplicate or out-of-range reply from worker {id} at step {step}"
                        ));
                    }
                    p.slots[id] = Some((loss, bytes, uplink));
                    p.filled += 1;
                }
                Ok(FromWorker::Failed { id, err }) => {
                    return Err(anyhow!("worker {id} failed: {err}"))
                }
                Ok(FromWorker::Init { id, .. }) => {
                    return Err(anyhow!("unexpected re-init from worker {id}"))
                }
                Err(_) => return Err(anyhow!("worker channel closed mid-round")),
            }
        }

        let p = self.pending.pop_front().expect("pending non-empty");
        let n = p.slots.len();
        let mut all_msgs = Vec::with_capacity(n);
        let mut loss_acc = 0.0f64;
        let mut w2s_per_worker = 0usize;
        let mut w2s_all = 0u64;
        // decode + absorb in worker-id order (determinism contract)
        for slot in p.slots.into_iter() {
            let (loss, bytes, uplink) = slot.expect("all round slots filled");
            loss_acc += loss as f64;
            w2s_per_worker = bytes;
            w2s_all += bytes as u64;
            all_msgs.push(uplink.unpack().map_err(anyhow::Error::msg)?);
        }
        self.server.absorb(&all_msgs);
        self.meter.record_uplinks(w2s_per_worker as u64, w2s_all);
        Ok(Absorbed {
            step: p.step,
            radius: p.radius,
            train_loss: (loss_acc / n as f64) as f32,
            w2s_bytes_per_worker: w2s_per_worker,
        })
    }

    /// Evaluation loss at the current server parameters (borrowed — the
    /// objective backend never copies the model to evaluate it). In async
    /// modes the parameters already include every *issued* LMO step;
    /// uplinks of still-in-flight rounds land only after
    /// [`Coordinator::drain`].
    pub fn eval(&self) -> Result<f32> {
        self.handle.eval(&self.server.x)
    }

    /// Current model parameters (server X).
    pub fn params(&self) -> &Layers {
        &self.server.x
    }

    /// Current broadcast shift (server W). Every worker's local W coincides
    /// with this bit-for-bit once it has applied the issued broadcasts; the
    /// cluster's parameter board publishes it as the cross-shard view.
    pub fn shift(&self) -> &Layers {
        &self.server.w
    }

    /// Cumulative communication meters.
    pub fn meter(&self) -> &Meter {
        &self.meter
    }

    /// Rounds issued (broadcast sent) so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Rounds currently in flight (0 in sync mode between calls).
    pub fn pending_rounds(&self) -> usize {
        self.pending.len()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Stop);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Converts a worker-thread panic into a [`FromWorker::Failed`] reply: the
/// guard's `Drop` runs during unwinding while the reply channel is still
/// alive, so the leader gets a clean error instead of waiting forever for
/// a reply that will never come.
struct PanicGuard {
    id: usize,
    tx: Sender<FromWorker>,
}

impl Drop for PanicGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            let _ = self.tx.send(FromWorker::Failed {
                id: self.id,
                err: "worker thread panicked".into(),
            });
        }
    }
}

/// Worker-thread main loop: init, then one EF21 local step per command.
fn worker_main(
    mut state: WorkerState,
    rx: Receiver<ToWorker>,
    tx: Sender<FromWorker>,
    mut handle: GradHandle,
) {
    let id = state.id;
    let _guard = PanicGuard { id, tx: tx.clone() };
    // theory init: M⁰ⱼ = G⁰ⱼ = ∇fⱼ(X⁰) (W starts at X⁰)
    match handle.grad(id, &state.w) {
        Ok((_, grad0)) => {
            let g0 = state.init_estimators(grad0);
            if tx.send(FromWorker::Init { id, g0 }).is_err() {
                return;
            }
        }
        Err(e) => {
            let _ = tx.send(FromWorker::Failed { id, err: format!("{e:#}") });
            return;
        }
    }
    while let Ok(cmd) = rx.recv() {
        let (step, broadcast) = match cmd {
            ToWorker::Stop => break,
            ToWorker::Round { step, broadcast } => (step, broadcast),
        };
        let mode = broadcast.mode();
        let msgs = match broadcast.unpack() {
            Ok(m) => m,
            Err(e) => {
                let _ = tx.send(FromWorker::Failed { id, err: format!("bad broadcast: {e}") });
                break;
            }
        };
        state.apply_broadcast(&msgs);
        // the round index doubles as the data/board epoch: sharded handles
        // read the cross-shard parameter snapshot sealed for this round, and
        // the PJRT service keys batch sampling on (worker, step) so cluster
        // deployments replaying the same round sample the same data
        let (loss, grad) = match handle.grad_at(id, &state.w, step) {
            Ok(v) => v,
            Err(e) => {
                let _ = tx.send(FromWorker::Failed { id, err: format!("{e:#}") });
                break;
            }
        };
        let uplink_msgs = state.local_step(&grad);
        let (uplink, bytes) = Wire::pack(uplink_msgs, mode);
        if tx
            .send(FromWorker::Round { id, step, loss, bytes, uplink })
            .is_err()
        {
            break;
        }
    }
}
