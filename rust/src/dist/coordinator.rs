//! The leader: owns the EF21 server state, one OS thread per worker, and
//! the round loop. Exactly Algorithm 3 — the same [`ServerState`] /
//! [`WorkerState`] machines as the sequential reference driver, so
//! `rust/tests/dist.rs` can assert bit-equal trajectories.
//!
//! Determinism: worker replies are collected into id-indexed slots and
//! absorbed in worker order; per-layer LMO RNG streams are pre-split; the
//! threaded matmul is bit-stable in the thread count. A distributed run is
//! therefore reproducible from its seed on any machine.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::linalg::matrix::{layers, Layers};
use crate::opt::ef21::{ServerState, WorkerState};
use crate::opt::{LayerGeometry, Schedule};

use super::comm::{FromWorker, ToWorker, Wire};
use super::server::SpectralServer;
use super::service::GradHandle;
use super::{Meter, TransportMode};

/// Configuration of one distributed EF21-Muon deployment.
#[derive(Debug, Clone)]
pub struct CoordinatorCfg {
    pub n_workers: usize,
    /// w2s compressor spec (per layer), e.g. `top:0.1+nat`.
    pub worker_comp: String,
    /// s2w compressor spec (the paper fixes this to `id`).
    pub server_comp: String,
    /// Momentum β.
    pub beta: f32,
    /// Radius / learning-rate schedule.
    pub schedule: Schedule,
    pub transport: TransportMode,
    pub seed: u64,
    /// Route spectral LMOs through the PJRT NS artifact when available.
    pub use_ns_artifact: bool,
}

/// Telemetry of one distributed round.
#[derive(Debug, Clone)]
pub struct RoundStats {
    pub step: usize,
    /// Mean of the workers' local train losses this round.
    pub train_loss: f32,
    /// LMO radius used this round.
    pub radius: f64,
    /// w2s bytes sent by one worker (the paper's reporting unit).
    pub w2s_bytes_per_worker: usize,
    /// s2w broadcast bytes (counted once).
    pub s2w_bytes: usize,
}

/// The leader of a threaded EF21-Muon deployment.
pub struct Coordinator {
    server: ServerState,
    schedule: Schedule,
    transport: TransportMode,
    spectral: SpectralServer,
    handle: GradHandle,
    meter: Meter,
    step: usize,
    to_workers: Vec<Sender<ToWorker>>,
    from_workers: Receiver<FromWorker>,
    joins: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the worker threads, run the Algorithm-3 initialization
    /// (`G⁰ = (1/n) Σⱼ ∇fⱼ(X⁰)`), and return the ready leader.
    pub fn spawn(
        x0: Layers,
        geometry: Vec<LayerGeometry>,
        handle: GradHandle,
        cfg: CoordinatorCfg,
    ) -> Result<Coordinator> {
        if cfg.n_workers == 0 {
            return Err(anyhow!("n_workers must be >= 1"));
        }
        let mut server = ServerState::new(
            x0.clone(),
            geometry,
            &cfg.server_comp,
            cfg.n_workers,
            cfg.seed,
        )
        .map_err(anyhow::Error::msg)?;

        let (reply_tx, reply_rx) = channel::<FromWorker>();
        let mut to_workers = Vec::with_capacity(cfg.n_workers);
        let mut joins = Vec::with_capacity(cfg.n_workers);
        for j in 0..cfg.n_workers {
            let state = WorkerState::new(j, &x0, &cfg.worker_comp, cfg.beta, cfg.seed)
                .map_err(anyhow::Error::msg)?;
            let (tx, rx) = channel::<ToWorker>();
            let rtx = reply_tx.clone();
            let h = handle.for_worker(j);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("efmuon-worker-{j}"))
                    .spawn(move || worker_main(state, rx, rtx, h))
                    .map_err(|e| anyhow!("spawning worker {j}: {e}"))?,
            );
            to_workers.push(tx);
        }
        drop(reply_tx);

        // initialization: collect G⁰ⱼ into id-slots, average in worker order
        // (bit-identical to the sequential driver's init loop)
        let mut g0: Vec<Option<Layers>> = (0..cfg.n_workers).map(|_| None).collect();
        for _ in 0..cfg.n_workers {
            match reply_rx.recv() {
                Ok(FromWorker::Init { id, g0: g }) => g0[id] = Some(g),
                Ok(FromWorker::Failed { id, err }) => {
                    return Err(anyhow!("worker {id} failed during init: {err}"))
                }
                Ok(FromWorker::Round { id, .. }) => {
                    return Err(anyhow!("worker {id} sent a round reply before init"))
                }
                Err(_) => return Err(anyhow!("worker channel closed during init")),
            }
        }
        let mut g0_avg = layers::zeros_like(&x0);
        let inv = 1.0 / cfg.n_workers as f32;
        for g in g0.into_iter() {
            layers::axpy(&mut g0_avg, inv, &g.expect("all init slots filled"));
        }
        server.set_g0(g0_avg);

        Ok(Coordinator {
            server,
            schedule: cfg.schedule,
            transport: cfg.transport,
            spectral: SpectralServer::new(handle.clone(), cfg.use_ns_artifact),
            handle,
            meter: Meter::new(),
            step: 0,
            to_workers,
            from_workers: reply_rx,
            joins,
        })
    }

    /// One full round of Algorithm 3 across the worker threads.
    pub fn round(&mut self) -> Result<RoundStats> {
        let n = self.to_workers.len();
        let t = self.schedule.at(self.step);

        // server: LMO step (per-layer fan-out; PJRT NS artifact when hooked)
        if self.spectral.enabled() {
            let spectral = &self.spectral;
            let hook = move |g: &crate::linalg::Matrix| spectral.orthogonalize(g);
            self.server.lmo_step_with(t, Some(&hook));
        } else {
            self.server.lmo_step(t);
        }

        // server: compress the shifted model, advance W, broadcast
        let bcast = self.server.broadcast();
        let (wire, s2w_bytes) = Wire::pack(bcast, self.transport);
        for tx in &self.to_workers {
            tx.send(ToWorker::Round { broadcast: wire.clone() })
                .map_err(|_| anyhow!("a worker thread has exited"))?;
        }

        // workers: apply broadcast, grad, momentum, compress — in parallel.
        // Collect replies into id-slots so absorption order is fixed.
        let mut slots: Vec<Option<(f32, usize, Wire)>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            match self.from_workers.recv() {
                Ok(FromWorker::Round { id, loss, bytes, uplink }) => {
                    slots[id] = Some((loss, bytes, uplink))
                }
                Ok(FromWorker::Failed { id, err }) => {
                    return Err(anyhow!("worker {id} failed: {err}"))
                }
                Ok(FromWorker::Init { id, .. }) => {
                    return Err(anyhow!("unexpected re-init from worker {id}"))
                }
                Err(_) => return Err(anyhow!("worker channel closed mid-round")),
            }
        }
        let mut all_msgs = Vec::with_capacity(n);
        let mut loss_acc = 0.0f64;
        let mut w2s_per_worker = 0usize;
        let mut w2s_all = 0u64;
        for slot in slots.into_iter() {
            let (loss, bytes, uplink) = slot.expect("all round slots filled");
            loss_acc += loss as f64;
            w2s_per_worker = bytes;
            w2s_all += bytes as u64;
            all_msgs.push(uplink.unpack().map_err(anyhow::Error::msg)?);
        }

        // server: absorb the averaged residuals (worker order)
        self.server.absorb(&all_msgs);
        self.meter
            .record_round(w2s_per_worker as u64, w2s_all, s2w_bytes as u64);

        let stats = RoundStats {
            step: self.step,
            train_loss: (loss_acc / n as f64) as f32,
            radius: t,
            w2s_bytes_per_worker: w2s_per_worker,
            s2w_bytes,
        };
        self.step += 1;
        Ok(stats)
    }

    /// Evaluation loss at the current server parameters.
    pub fn eval(&self) -> Result<f32> {
        self.handle.eval(self.server.x.clone())
    }

    /// Current model parameters (server X).
    pub fn params(&self) -> &Layers {
        &self.server.x
    }

    /// Cumulative communication meters.
    pub fn meter(&self) -> &Meter {
        &self.meter
    }

    /// Rounds completed.
    pub fn steps_done(&self) -> usize {
        self.step
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.to_workers {
            let _ = tx.send(ToWorker::Stop);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Worker-thread main loop: init, then one EF21 local step per command.
fn worker_main(
    mut state: WorkerState,
    rx: Receiver<ToWorker>,
    tx: Sender<FromWorker>,
    mut handle: GradHandle,
) {
    let id = state.id;
    // theory init: M⁰ⱼ = G⁰ⱼ = ∇fⱼ(X⁰) (W starts at X⁰)
    match handle.grad(id, &state.w) {
        Ok((_, grad0)) => {
            let g0 = state.init_estimators(grad0);
            if tx.send(FromWorker::Init { id, g0 }).is_err() {
                return;
            }
        }
        Err(e) => {
            let _ = tx.send(FromWorker::Failed { id, err: format!("{e:#}") });
            return;
        }
    }
    while let Ok(cmd) = rx.recv() {
        let broadcast = match cmd {
            ToWorker::Stop => break,
            ToWorker::Round { broadcast } => broadcast,
        };
        let mode = wire_mode(&broadcast);
        let msgs = match broadcast.unpack() {
            Ok(m) => m,
            Err(e) => {
                let _ = tx.send(FromWorker::Failed { id, err: format!("bad broadcast: {e}") });
                break;
            }
        };
        state.apply_broadcast(&msgs);
        let (loss, grad) = match handle.grad(id, &state.w) {
            Ok(v) => v,
            Err(e) => {
                let _ = tx.send(FromWorker::Failed { id, err: format!("{e:#}") });
                break;
            }
        };
        let uplink_msgs = state.local_step(&grad);
        let (uplink, bytes) = Wire::pack(uplink_msgs, mode);
        if tx
            .send(FromWorker::Round { id, loss, bytes, uplink })
            .is_err()
        {
            break;
        }
    }
}

/// The uplink reuses the broadcast's transport mode.
fn wire_mode(w: &Wire) -> TransportMode {
    match w {
        Wire::Counted(_) => TransportMode::Counted,
        Wire::Encoded(_) => TransportMode::Encoded,
    }
}
