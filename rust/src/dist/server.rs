//! Server-side spectral-engine hook: routes the coordinator's spectral LMO
//! steps through the Pallas/PJRT Newton–Schulz artifact when one exists for
//! the layer's shape (see `lmo::SpectralEngine` — the native engine lives
//! there, this one needs a runtime handle, so it lives in `dist`).
//!
//! Per-shape support is learned lazily and cached, so on the synthetic
//! backend (or for shapes without an artifact) the hook costs one probe per
//! shape and then gets out of the way of the native NS path.

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::linalg::matrix::Matrix;

use super::service::GradHandle;

/// Spectral LMO engine backed by the grad service's NS artifacts.
pub struct SpectralServer {
    handle: GradHandle,
    enabled: bool,
    /// shape → whether the backend has an artifact for it
    supported: RefCell<BTreeMap<(usize, usize), bool>>,
}

impl SpectralServer {
    pub fn new(handle: GradHandle, enabled: bool) -> SpectralServer {
        SpectralServer { handle, enabled, supported: RefCell::new(BTreeMap::new()) }
    }

    /// Orthogonalize `g` via the artifact engine; `None` = caller should use
    /// the native Newton–Schulz (disabled, unsupported shape, or error —
    /// errors demote to the native path rather than failing the round).
    pub fn orthogonalize(&self, g: &Matrix) -> Option<Matrix> {
        if !self.enabled {
            return None;
        }
        let shape = (g.rows, g.cols);
        if self.supported.borrow().get(&shape) == Some(&false) {
            return None;
        }
        match self.handle.ns_orthogonalize(g) {
            Ok(Some(o)) => {
                self.supported.borrow_mut().insert(shape, true);
                Some(o)
            }
            Ok(None) | Err(_) => {
                self.supported.borrow_mut().insert(shape, false);
                None
            }
        }
    }

    /// Whether any call so far has actually hit the artifact engine.
    pub fn engaged(&self) -> bool {
        self.supported.borrow().values().any(|&v| v)
    }

    /// `true` when the hook is worth installing at all.
    pub fn enabled(&self) -> bool {
        self.enabled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::service::GradService;
    use crate::funcs::Quadratics;
    use crate::util::rng::Rng;

    #[test]
    fn objective_backend_demotes_to_native() {
        let mut rng = Rng::new(80);
        let q = Quadratics::new(2, 4, 0.5, 0.0, &mut rng);
        let svc = GradService::spawn_objective(Box::new(q), 1);
        let srv = SpectralServer::new(svc.handle(), true);
        let g = Matrix::randn(4, 4, 1.0, &mut rng);
        assert!(srv.orthogonalize(&g).is_none());
        assert!(!srv.engaged());
        // cached: second probe takes the fast path
        assert!(srv.orthogonalize(&g).is_none());
        let off = SpectralServer::new(svc.handle(), false);
        assert!(off.orthogonalize(&g).is_none());
    }
}
