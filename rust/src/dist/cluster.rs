//! Multi-coordinator layer sharding: a [`Cluster`] partitions the model's
//! layers across `S` shard [`Coordinator`]s — balanced by parameter count,
//! each shard with its own worker pool, [`Meter`] and [`RoundMode`]
//! pipeline — driven by a root reducer that advances every shard
//! concurrently and rolls per-shard losses, wire bytes and round counters
//! up into a [`ClusterMeter`] / [`ClusterRoundStats`].
//!
//! The EF21-Muon protocol is layer-wise by construction (per-layer LMOs,
//! compressor state and smoothness constants), so partitioning layers
//! across independent leaders changes the *schedule*, not the algorithm:
//! each shard runs the unmodified Algorithm-3 state machines over its
//! slice. Because the shards advance on their own OS threads, a cluster
//! round's wall time is the max over shards instead of the sum over layers
//! — the scaling win the `BENCH_hotpath.json` cluster entries measure.
//!
//! ```text
//!   caller ──► Cluster::round()            (root reducer, lock-step)
//!      ├─► shard thread 0 ─► Coordinator(layers₀) ─► workers 0..n
//!      ├─► shard thread 1 ─► Coordinator(layers₁) ─► workers 0..n
//!      └─► shard thread S-1 ──────────────────────► ...   (concurrent)
//!      ◄── RoundStats + shift W + Meter snapshot per shard ── barrier
//!      seal ParamBoard epoch k+1  ──► rollup ClusterRoundStats
//! ```
//!
//! **Cross-shard gradient coupling.** Worker `j` of shard `s` is the same
//! logical data worker `j` as every other shard's — one local function
//! `f_j` per worker, sliced by layer. Its gradient requests go through a
//! sharded [`GradHandle`](super::service::GradHandle) that assembles the
//! full model from the shard's own (fresh) layers plus the [`ParamBoard`]
//! snapshot of every other shard's broadcast shift W, sealed once per
//! round by the root reducer. For layer-separable objectives — the regime
//! the paper's layer-wise analysis covers — the board is inert and the
//! sharded run is *exact*; for coupled models (the PJRT transformer) the
//! cross-shard view lags by one round, the standard block-synchronous
//! approximation. Snapshots are keyed by round, so trajectories are
//! deterministic in every round mode: a worker still computing round `k`
//! reads epoch `k` even after the root has sealed `k+1`.
//!
//! With `shards = 1` the cluster is the single-leader deployment
//! bit-for-bit (the board is never consulted; asserted against the golden
//! trajectories in `rust/tests/scenario.rs`).
//!
//! **Bounded-epoch scheduling.** With a [`SchedSpec`] window the root stops
//! being a barrier: it keeps issuing rounds while any shard is at most
//! `window` rounds behind the fastest issue, staging out-of-order replies
//! in an [`EpochWindow`] and sealing board epochs as rounds complete
//! rather than at a lock-step rendezvous. A [`RoundClock`]/[`EwmaBank`]
//! pair tracks per-shard issue→reply times; when the EWMA spread crosses
//! the steal threshold, the root re-partitions online — migrating the slow
//! shard's lightest layer (server shift + EF21 error state, bitwise) to
//! the fastest shard at an epoch boundary through a versioned
//! [`PartitionPlan`]. `window:0,steal:off` (the default) never enters this
//! path at all: the lock-step code below is untouched and byte-identical
//! to every release before the scheduler existed (golden-anchored in
//! `rust/tests/scenario.rs`). See DESIGN.md §Shard scheduling.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::compress::quantize::{bf16_decode, bf16_encode};
use crate::linalg::matrix::{Layers, Matrix};
use crate::opt::{LayerGeometry, Schedule};
use crate::spec::CompSpec;
use crate::trace::{Phase, Tracer};
use crate::util::json::{Json, JsonObj};

use super::coordinator::{Coordinator, CoordinatorCfg, RoundStats};
use super::fault::{FaultPlan, FaultPolicy};
use super::sched::{
    EpochWindow, EwmaBank, PartitionPlan, RoundClock, SchedSpec, ServerLayer, ShardDelayPlan,
    WorkerLayer,
};
use super::service::{GradHandle, SharedIds, SnapCache};
use super::{MeterSnapshot, RoundMode, TransportMode};

// ---------------------------------------------------------------------------
// Layer partitioning
// ---------------------------------------------------------------------------

/// Partition `shapes` (layer shapes, by global index) across `shards`
/// leaders, balanced by parameter count: greedy longest-first assignment to
/// the least-loaded shard. Guarantees every layer is owned by exactly one
/// shard, every shard owns at least one layer, and the heaviest and
/// lightest shard loads differ by at most one max-layer's parameter count
/// (the property test in `rust/tests/cluster.rs` pins this on ragged shape
/// sets). Within a shard, layer ids are ascending.
pub fn partition_layers(
    shapes: &[(usize, usize)],
    shards: usize,
) -> Result<Vec<Vec<usize>>, String> {
    if shards == 0 {
        return Err("shards must be >= 1".into());
    }
    if shards > shapes.len() {
        return Err(format!(
            "cannot shard {} layer(s) across {shards} coordinators (at most one shard per layer)",
            shapes.len()
        ));
    }
    // longest-processing-time order: numel descending, index ascending for
    // deterministic ties
    let mut order: Vec<usize> = (0..shapes.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(shapes[i].0 * shapes[i].1), i));
    let mut owned: Vec<Vec<usize>> = vec![Vec::new(); shards];
    let mut load = vec![0usize; shards];
    for i in order {
        let s = (0..shards).min_by_key(|&s| (load[s], s)).expect("shards >= 1");
        load[s] += shapes[i].0 * shapes[i].1;
        owned[s].push(i);
    }
    for ids in owned.iter_mut() {
        ids.sort_unstable();
    }
    Ok(owned)
}

// ---------------------------------------------------------------------------
// The cross-shard parameter board
// ---------------------------------------------------------------------------

/// One layer of a bf16-encoded board snapshot: the round-to-nearest-even
/// high halves of the f32 entries
/// ([`bf16_encode`](crate::compress::quantize::bf16_encode)) — 2 bytes per
/// parameter instead of 4 on every board seal and snapshot assembly.
pub struct Bf16Mat {
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<u16>,
}

impl Bf16Mat {
    fn encode_from(m: &Matrix) -> Bf16Mat {
        Bf16Mat {
            rows: m.rows,
            cols: m.cols,
            codes: m.data.iter().map(|&v| bf16_encode(v)).collect(),
        }
    }

    /// Re-encode `m` into this buffer (the pooled-seal path; shapes match
    /// by construction — every board snapshot is full-model shaped).
    fn reencode_from(&mut self, m: &Matrix) {
        debug_assert_eq!(self.codes.len(), m.data.len());
        for (c, &v) in self.codes.iter_mut().zip(&m.data) {
            *c = bf16_encode(v);
        }
    }
}

/// A sealed board epoch at its stored width: full-precision f32, or the
/// bf16 wire form ([`ClusterCfg::snap_bf16`]). Readers expand layers
/// through [`BoardSnap::expand_layer_into`] / [`BoardSnap::layer_to_matrix`]
/// and meter the cross-shard traffic at [`BoardSnap::layer_wire_bytes`].
#[derive(Clone)]
pub enum BoardSnap {
    /// Byte-for-byte the sealed model (4 B/entry).
    F32(Arc<Layers>),
    /// bf16-cast snapshot (2 B/entry): the lossy half-width broadcast.
    Bf16(Arc<Vec<Bf16Mat>>),
}

impl BoardSnap {
    /// Layer count of the snapshot.
    pub fn len(&self) -> usize {
        match self {
            BoardSnap::F32(l) => l.len(),
            BoardSnap::Bf16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shape of layer `i`.
    pub fn shape(&self, i: usize) -> (usize, usize) {
        match self {
            BoardSnap::F32(l) => (l[i].rows, l[i].cols),
            BoardSnap::Bf16(v) => (v[i].rows, v[i].cols),
        }
    }

    /// **The round-trip expansion point.** Write layer `i` into `dst` at
    /// full f32 width: an exact copy from an f32 snapshot, or the exact
    /// widening `(code as u32) << 16` from a bf16 one
    /// ([`bf16_decode`](crate::compress::quantize::bf16_decode)). Every
    /// consumer of a board snapshot — the per-shard
    /// [`SnapCache`](super::service::SnapCache) assembly and the uncached
    /// init/eval assembly — goes through here, so the bf16 loss is applied
    /// exactly once per sealed value (encode at seal, widen at read) and
    /// never compounds. With `snap_bf16` off the path is bit-identical to
    /// the f32-only board (golden-tested in `rust/tests/cluster.rs`).
    pub fn expand_layer_into(&self, i: usize, dst: &mut [f32]) {
        match self {
            BoardSnap::F32(l) => dst.copy_from_slice(&l[i].data),
            BoardSnap::Bf16(v) => {
                for (d, &c) in dst.iter_mut().zip(&v[i].codes) {
                    *d = bf16_decode(c);
                }
            }
        }
    }

    /// Layer `i` expanded into a freshly allocated [`Matrix`].
    pub fn layer_to_matrix(&self, i: usize) -> Matrix {
        let (rows, cols) = self.shape(i);
        let mut m = Matrix::zeros(rows, cols);
        self.expand_layer_into(i, &mut m.data);
        m
    }

    /// Bytes layer `i` occupies at the snapshot's stored width (4 B/entry
    /// f32, 2 B/entry bf16) — what a cross-shard read actually moves.
    pub fn layer_wire_bytes(&self, i: usize) -> u64 {
        let (rows, cols) = self.shape(i);
        let width = match self {
            BoardSnap::F32(_) => 4,
            BoardSnap::Bf16(_) => 2,
        };
        (rows * cols) as u64 * width
    }

    /// Stored bytes of the whole snapshot.
    pub fn wire_bytes(&self) -> u64 {
        (0..self.len()).map(|i| self.layer_wire_bytes(i)).sum()
    }
}

/// Round-sealed snapshots of the full model's broadcast shift W, published
/// by the root reducer and read by each shard's sharded
/// [`GradHandle`](super::service::GradHandle) when it assembles full-model
/// parameters for a gradient request. Epoch `k` is sealed *before* any
/// round-`k` work starts and is immutable afterwards, so reads are
/// deterministic regardless of thread timing — including pipelined round
/// modes, where a worker may still be computing round `k` after the root
/// has sealed `k+1`.
///
/// A board constructed with [`ParamBoard::new_bf16`] stores every epoch in
/// bf16 ([`BoardSnap::Bf16`]): seals copy half the bytes and snapshot
/// assemblies read half the bytes, at ≤ 2⁻⁸ relative error per entry. For
/// layer-separable objectives the foreign layers are never read by a
/// shard's own gradient, so the cast provably cannot perturb the
/// trajectory; for coupled models it is a lossy approximation on top of
/// the one-round staleness the board already introduces.
pub struct ParamBoard {
    /// (epoch, snapshot) plus reclaimed buffers, epochs strictly increasing.
    snaps: Mutex<BoardInner>,
    /// How many trailing epochs to retain (≥ lookahead + 2, so the oldest
    /// possibly-in-flight round's snapshot is always available).
    keep: usize,
    /// Full-model layer count (shards owning every layer skip the board).
    layers: usize,
    /// Store epochs in bf16 (half-width snapshots).
    bf16: bool,
    /// Bounded-epoch mode ([`ParamBoard::windowed`]): reads for a not-yet-
    /// sealed epoch park on `cv` until the root seals it, instead of
    /// silently serving the newest older snapshot. Off (the lock-step
    /// default), `read` never waits and is byte-identical to the board
    /// before the scheduler existed.
    windowed: bool,
    /// Seal/close notifications for windowed readers.
    cv: Condvar,
}

struct BoardInner {
    snaps: VecDeque<(usize, BoardSnap)>,
    /// Buffers reclaimed from evicted unshared epochs, so steady-state
    /// sealing copies into a pooled buffer instead of allocating (one pool
    /// per storage width; only the board's own width is ever populated).
    pool_f32: Vec<Layers>,
    pool_bf16: Vec<Vec<Bf16Mat>>,
    /// Shutdown latch: wakes parked windowed readers so a failing cluster
    /// can join its shard threads instead of wedging on a seal that will
    /// never come.
    closed: bool,
}

impl ParamBoard {
    /// A board whose epoch 0 is `x0` (the init gradient's view).
    pub fn new(x0: Layers, keep: usize) -> ParamBoard {
        Self::with_mode(x0, keep, false)
    }

    /// A board storing every epoch in bf16 (see [`ClusterCfg::snap_bf16`]).
    pub fn new_bf16(x0: Layers, keep: usize) -> ParamBoard {
        Self::with_mode(x0, keep, true)
    }

    fn with_mode(x0: Layers, keep: usize, bf16: bool) -> ParamBoard {
        let layers = x0.len();
        let snap0 = if bf16 {
            BoardSnap::Bf16(Arc::new(x0.iter().map(Bf16Mat::encode_from).collect()))
        } else {
            BoardSnap::F32(Arc::new(x0))
        };
        ParamBoard {
            layers,
            snaps: Mutex::new(BoardInner {
                snaps: VecDeque::from([(0usize, snap0)]),
                pool_f32: Vec::new(),
                pool_bf16: Vec::new(),
                closed: false,
            }),
            keep: keep.max(2),
            bf16,
            windowed: false,
            cv: Condvar::new(),
        }
    }

    /// Switch the board into bounded-epoch mode (see the `windowed` field).
    /// Builder-style so the existing constructors stay untouched.
    pub fn windowed(mut self) -> ParamBoard {
        self.windowed = true;
        self
    }

    /// Wake every parked windowed reader and make all future reads
    /// non-blocking (they fall back to the newest sealed epoch). Called on
    /// cluster teardown and on any root-side error; a no-op for the
    /// lock-step board, whose reads never wait.
    pub fn close(&self) {
        let mut s = self.snaps.lock().expect("board lock");
        s.closed = true;
        self.cv.notify_all();
    }

    /// Layer count of the full model the board snapshots.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Seal `full` as the snapshot round `epoch` reads. Idempotent per
    /// epoch; epochs must be sealed in increasing order.
    pub fn seal(&self, epoch: usize, full: Layers) {
        let mut s = self.snaps.lock().expect("board lock");
        let snap = if self.bf16 {
            BoardSnap::Bf16(Arc::new(full.iter().map(Bf16Mat::encode_from).collect()))
        } else {
            BoardSnap::F32(Arc::new(full))
        };
        Self::seal_locked(&mut s, epoch, snap, self.keep);
        if self.windowed {
            self.cv.notify_all();
        }
    }

    /// [`ParamBoard::seal`] from a borrow: copies (f32 board) or encodes
    /// (bf16 board) `full` into a buffer reclaimed from an evicted epoch
    /// (allocating only until the retention window fills), so the
    /// steady-state root reducer never clones the model to seal. Returns
    /// the snapshot bytes written at the board's stored width — half as
    /// many under bf16 (0 when the epoch was already sealed).
    pub fn seal_from(&self, epoch: usize, full: &Layers) -> u64 {
        let mut s = self.snaps.lock().expect("board lock");
        if s.snaps.iter().any(|(e, _)| *e == epoch) {
            return 0;
        }
        let snap = if self.bf16 {
            let enc = match s.pool_bf16.pop() {
                Some(mut buf) => {
                    for (dst, src) in buf.iter_mut().zip(full.iter()) {
                        dst.reencode_from(src);
                    }
                    buf
                }
                None => full.iter().map(Bf16Mat::encode_from).collect(),
            };
            BoardSnap::Bf16(Arc::new(enc))
        } else {
            let copy = match s.pool_f32.pop() {
                Some(mut buf) => {
                    for (dst, src) in buf.iter_mut().zip(full.iter()) {
                        dst.data.copy_from_slice(&src.data);
                    }
                    buf
                }
                None => full.clone(),
            };
            BoardSnap::F32(Arc::new(copy))
        };
        let bytes = snap.wire_bytes();
        Self::seal_locked(&mut s, epoch, snap, self.keep);
        if self.windowed {
            self.cv.notify_all();
        }
        bytes
    }

    fn seal_locked(s: &mut BoardInner, epoch: usize, snap: BoardSnap, keep: usize) {
        if s.snaps.iter().any(|(e, _)| *e == epoch) {
            return;
        }
        debug_assert!(s.snaps.back().map(|(e, _)| *e < epoch).unwrap_or(true));
        s.snaps.push_back((epoch, snap));
        while s.snaps.len() > keep {
            let (_, old) = s.snaps.pop_front().expect("non-empty");
            match old {
                BoardSnap::F32(a) => {
                    if let Ok(buf) = Arc::try_unwrap(a) {
                        if s.pool_f32.len() < 2 {
                            s.pool_f32.push(buf);
                        }
                    }
                }
                BoardSnap::Bf16(a) => {
                    if let Ok(buf) = Arc::try_unwrap(a) {
                        if s.pool_bf16.len() < 2 {
                            s.pool_bf16.push(buf);
                        }
                    }
                }
            }
        }
    }

    /// The snapshot sealed for `epoch`: the newest sealed epoch `<= epoch`
    /// (the oldest retained one if `epoch` predates the retention window).
    /// Hands out an `Arc` share of the sealed snapshot — never a deep copy.
    ///
    /// On a windowed board, a read for an epoch newer than every sealed one
    /// parks until the root seals it (epochs seal consecutively, so
    /// `back >= epoch` means `epoch` itself is sealed) or the board closes.
    /// The root always drains shard replies inside its own wait loops, so
    /// the seal a parked reader needs is always forthcoming.
    pub fn read(&self, epoch: usize) -> BoardSnap {
        let mut s = self.snaps.lock().expect("board lock");
        if self.windowed {
            while !s.closed && s.snaps.back().map(|(e, _)| *e < epoch).unwrap_or(true) {
                s = self.cv.wait(s).expect("board lock");
            }
        }
        s.snaps
            .iter()
            .rev()
            .find(|(e, _)| *e <= epoch)
            .or_else(|| s.snaps.front())
            .map(|(_, a)| a.clone())
            .expect("board never empty")
    }

    /// The newest sealed snapshot (init / eval-time view).
    pub fn read_latest(&self) -> BoardSnap {
        let s = self.snaps.lock().expect("board lock");
        s.snaps.back().map(|(_, a)| a.clone()).expect("board never empty")
    }
}

// ---------------------------------------------------------------------------
// Configuration & rollups
// ---------------------------------------------------------------------------

/// Configuration of one multi-coordinator deployment. Everything except
/// `shards`/`workers_per_shard` mirrors [`CoordinatorCfg`] and is applied
/// uniformly to every shard.
#[derive(Debug, Clone)]
pub struct ClusterCfg {
    /// Number of shard coordinators `S` (1 = the single-leader deployment).
    pub shards: usize,
    /// Worker threads per shard. Worker `j` of every shard is the same
    /// logical data worker `j` (one `f_j` per worker, sliced by layer).
    pub workers_per_shard: usize,
    /// w2s compressor descriptor (typed; parsed once at the spec boundary).
    pub worker_comp: CompSpec,
    /// s2w (EF21-P broadcast) compressor descriptor.
    pub server_comp: CompSpec,
    pub beta: f32,
    pub schedule: Schedule,
    pub transport: TransportMode,
    pub round_mode: RoundMode,
    pub seed: u64,
    pub use_ns_artifact: bool,
    /// Straggler / quorum / respawn policy, applied per shard (each shard
    /// coordinator supervises its own worker pool independently).
    pub fault: FaultPolicy,
    /// Deterministic fault-injection schedule, shared by every shard's
    /// worker pool (worker `j` of every shard is the same logical worker,
    /// so an injected fault hits all of its per-shard threads).
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// First round index (nonzero when resuming from a checkpoint).
    pub start_step: usize,
    /// Store the cross-shard [`ParamBoard`] snapshots in bf16: every epoch
    /// seal copies half the bytes and every snapshot assembly reads half
    /// the bytes, at ≤ 2⁻⁸ relative error per foreign entry. Exact (bit-
    /// identical trajectories) for layer-separable objectives, a lossy
    /// approximation for coupled ones; off by default.
    pub snap_bf16: bool,
    /// Bounded-epoch scheduling: how far any shard may run ahead of the
    /// slowest one, and the work-stealing trigger. [`SchedSpec::off`] (the
    /// default) takes the lock-step code path untouched — the golden
    /// anchor.
    pub sched: SchedSpec,
    /// Deterministic per-`(shard, round)` delay injection for scheduler
    /// tests and benches; never part of a serialized `RunSpec`.
    pub shard_delay: Option<Arc<ShardDelayPlan>>,
    /// Round-phase tracer ([`Tracer::Noop`] = off, the bitwise golden
    /// anchor). Each shard coordinator gets a shard-tagged clone; the root
    /// reducer stamps [`Phase::BoardSeal`] under its own tag.
    pub tracer: Tracer,
}

impl ClusterCfg {
    fn coordinator_cfg(&self) -> CoordinatorCfg {
        CoordinatorCfg {
            n_workers: self.workers_per_shard,
            worker_comp: self.worker_comp,
            server_comp: self.server_comp,
            beta: self.beta,
            schedule: self.schedule.clone(),
            transport: self.transport,
            round_mode: self.round_mode,
            seed: self.seed,
            use_ns_artifact: self.use_ns_artifact,
            fault: self.fault,
            fault_plan: self.fault_plan.clone(),
            start_step: self.start_step,
            tracer: Tracer::Noop,
        }
    }
}

/// Root-reducer rollup of one cluster round: aggregated wire bytes (sums
/// over shards), the absorbed train loss, and the per-shard entries it was
/// reduced from.
#[derive(Debug, Clone)]
pub struct ClusterRoundStats {
    /// The round whose broadcasts this call issued (every shard's).
    pub step: usize,
    /// The round whose uplinks were absorbed, if any (lock-step drive: the
    /// same round on every shard).
    pub absorbed_step: Option<usize>,
    /// The absorbed full-model train loss: for layer-separable objectives
    /// the *sum* over shards of their own-layer contributions (each itself
    /// a mean over that shard's workers); for non-separable objectives the
    /// mean over shards of the full-model losses every shard reported. NaN
    /// while the pipelines fill.
    pub train_loss: f32,
    /// LMO radius of the issued round (shared schedule — same on every
    /// shard).
    pub radius: f64,
    /// w2s bytes one logical full-model worker sent in the absorbed round:
    /// the sum over shards of their per-worker uplink bytes.
    pub w2s_bytes_per_worker: usize,
    /// s2w broadcast bytes of the issued round, summed over shards.
    pub s2w_bytes: usize,
    /// The per-shard stats this rollup reduces.
    pub per_shard: Vec<RoundStats>,
}

/// Cluster-wide communication rollup: one [`MeterSnapshot`] per shard plus
/// aggregate views (byte counters sum; round counters take the min — the
/// rounds *every* shard has completed, which in lock-step drive is simply
/// the common value).
#[derive(Debug, Clone)]
pub struct ClusterMeter {
    pub per_shard: Vec<MeterSnapshot>,
    /// Bytes the root reducer deep-copied sealing board epochs (on top of
    /// the per-shard assembly bytes already in the shard snapshots).
    pub root_bytes_cloned: u64,
    /// Layers the root's scheduler migrated between shards (0 in lock-step
    /// and in balanced windowed runs — gated in CI).
    pub steals: u64,
    /// High-water mark of how many rounds any shard ran ahead of the
    /// frontier (0 in lock-step; ≤ the configured window otherwise).
    pub epochs_ahead_max: u64,
    /// Current EWMA round-time spread (slowest / fastest shard); 1.0 when
    /// the scheduler is off or has too few samples.
    pub round_ewma_spread: f64,
}

impl ClusterMeter {
    /// Aggregate of all shard meters (the root's seal copies fold into
    /// `bytes_cloned`; the root's scheduler counters fold into
    /// `steals` / `epochs_ahead_max`).
    pub fn totals(&self) -> MeterSnapshot {
        let mut t = MeterSnapshot::default();
        for (i, m) in self.per_shard.iter().enumerate() {
            t.absorb_shard(m, i == 0);
        }
        t.bytes_cloned += self.root_bytes_cloned;
        t.steals += self.steals;
        t.epochs_ahead_max = t.epochs_ahead_max.max(self.epochs_ahead_max);
        t
    }

    /// w2s bytes one logical full-model worker has sent (sum over shards).
    pub fn w2s(&self) -> u64 {
        self.totals().w2s_per_worker
    }

    /// w2s bytes summed over all workers of all shards.
    pub fn w2s_all(&self) -> u64 {
        self.totals().w2s_all
    }

    /// s2w broadcast bytes summed over shards.
    pub fn s2w(&self) -> u64 {
        self.totals().s2w_total
    }

    /// Rounds every shard has issued.
    pub fn rounds_issued(&self) -> u64 {
        self.totals().rounds_issued
    }

    /// Rounds every shard has fully absorbed.
    pub fn rounds_absorbed(&self) -> u64 {
        self.totals().rounds_absorbed
    }

    /// JSON form: totals plus the per-shard snapshots.
    pub fn to_json(&self) -> Json {
        JsonObj::new()
            .put("totals", self.totals().to_json())
            .put("root_bytes_cloned", self.root_bytes_cloned)
            .put("steals", self.steals)
            .put("epochs_ahead_max", self.epochs_ahead_max)
            .put("round_ewma_spread", self.round_ewma_spread)
            .put(
                "per_shard",
                Json::Arr(self.per_shard.iter().map(|m| m.to_json()).collect()),
            )
            .build()
    }
}

// ---------------------------------------------------------------------------
// The cluster
// ---------------------------------------------------------------------------

/// Commands the root reducer sends to a shard thread.
enum ToShard {
    Round,
    Drain,
    Params,
    /// Work stealing: give global layer `layer` back to the root. Sent only
    /// when the shard has no round in flight, so the released state is
    /// post-every-absorbed-round.
    Release { layer: usize },
    /// Work stealing: adopt global layer `layer` with its migrated EF21
    /// state. Same quiescence contract as `Release`.
    Accept {
        layer: usize,
        geometry: LayerGeometry,
        server: ServerLayer,
        workers: Vec<WorkerLayer>,
    },
    Stop,
}

/// Replies a shard thread sends to the root reducer.
enum FromShard {
    Ready {
        shard: usize,
    },
    Round {
        shard: usize,
        stats: Box<RoundStats>,
        /// The shard's broadcast shift W after this round's issue — the
        /// cross-shard view the root seals into the board.
        shift: Layers,
        meter: MeterSnapshot,
    },
    Drained {
        shard: usize,
        stats: Vec<RoundStats>,
        meter: MeterSnapshot,
    },
    Params {
        shard: usize,
        params: Layers,
    },
    /// Reply to [`ToShard::Release`]: the layer's server shift and per-
    /// worker EF21 state, bitwise as they stood after the last absorbed
    /// round.
    Released {
        shard: usize,
        layer: usize,
        server: ServerLayer,
        workers: Vec<WorkerLayer>,
    },
    /// Reply to [`ToShard::Accept`].
    Accepted {
        shard: usize,
    },
    Failed {
        shard: usize,
        err: String,
    },
}

/// The root reducer of a multi-coordinator deployment: owns one OS thread
/// per shard (each running a full [`Coordinator`] over its layer slice),
/// drives them lock-step (shard-internal [`RoundMode`] pipelines still
/// overlap leader and worker work *within* each shard), seals the
/// [`ParamBoard`] once per round, and reduces per-shard telemetry.
/// Root-side state of the bounded-epoch scheduler, present only when the
/// [`SchedSpec`] is not off. Lock-step clusters never allocate this.
struct WindowState {
    /// Out-of-order reply staging + the completed-round frontier.
    win: EpochWindow,
    /// Issue timestamps for issue→reply round-time sampling.
    clock: RoundClock,
    /// Per-shard EWMA round times (the steal trigger).
    bank: EwmaBank,
    /// Completed-round rollups not yet returned to the caller — one pops
    /// per `round()` call, so the completed-rollup stream matches the
    /// lock-step stream exactly (just `window` calls later).
    ready: VecDeque<ClusterRoundStats>,
    /// Layers migrated so far.
    steals: u64,
    /// High-water mark of rounds any shard ran ahead of the frontier.
    ahead_max: u64,
}

pub struct Cluster {
    /// Versioned layer → shard ownership; mutated only by a steal, at an
    /// epoch boundary with no round in flight.
    plan: PartitionPlan,
    /// Full-model layer geometry (migrations re-ship a layer's geometry to
    /// its new owner).
    geometry: Vec<LayerGeometry>,
    /// Full-model layer shapes (the steal picks the donor's lightest layer).
    shapes: Vec<(usize, usize)>,
    /// The shared radius schedule (windowed placeholder stats need the
    /// issued round's radius before any shard has replied).
    schedule: Schedule,
    sched: SchedSpec,
    /// `Some` iff `sched` is not off.
    win: Option<WindowState>,
    board: Arc<ParamBoard>,
    /// Full-model broadcast shift, incrementally overwritten from shard
    /// replies; copied into a pooled board buffer at each seal.
    shift_full: Layers,
    /// Latest meter snapshot per shard.
    meters: Vec<MeterSnapshot>,
    /// Per-shard snapshot caches (shared with the shards' sliced handles);
    /// read here for the memory-traffic rollup.
    caches: Vec<Arc<SnapCache>>,
    /// Bytes the root itself deep-copied sealing board epochs.
    seal_bytes: u64,
    /// Layer-separable objective: per-shard train losses are disjoint
    /// contributions and the rollup sums them; otherwise every shard
    /// reports the full-model loss and the rollup averages.
    sum_losses: bool,
    handle: GradHandle,
    to_shards: Vec<Sender<ToShard>>,
    from_shards: Receiver<FromShard>,
    joins: Vec<JoinHandle<()>>,
    step: usize,
    /// First fatal error, latched (same contract as [`Coordinator`]).
    failed: Option<String>,
    /// The root reducer's own stamp handle (board seals).
    tracer: Tracer,
}

impl Cluster {
    /// Partition the layers, spawn one shard coordinator per partition cell
    /// (each on its own OS thread, with its own worker pool), and wait for
    /// every shard's Algorithm-3 initialization to finish.
    pub fn spawn(
        x0: Layers,
        geometry: Vec<LayerGeometry>,
        handle: GradHandle,
        cfg: ClusterCfg,
    ) -> Result<Cluster> {
        if geometry.len() != x0.len() {
            return Err(anyhow!(
                "geometry has {} entries for {} layers",
                geometry.len(),
                x0.len()
            ));
        }
        let shapes: Vec<(usize, usize)> = x0.iter().map(|m| (m.rows, m.cols)).collect();
        let partition = partition_layers(&shapes, cfg.shards).map_err(anyhow::Error::msg)?;
        cfg.sched.validate().map_err(anyhow::Error::msg)?;
        if cfg.sched.steal.is_some() && !cfg.fault.is_off() {
            // a straggler's late reply would land in the steal's dedicated
            // Released/Accepted recv loops; keep the two protocols apart
            return Err(anyhow!(
                "work stealing requires the fault policy off (steal migration \
                 cannot coexist with straggler deadlines or respawns)"
            ));
        }
        // windowed runs keep `window` extra epochs: the frontier trails the
        // newest issued round by up to that many still-open rounds
        let keep = cfg.round_mode.lookahead() + cfg.sched.window + 3;
        let board = if cfg.snap_bf16 {
            ParamBoard::new_bf16(x0.clone(), keep)
        } else {
            ParamBoard::new(x0.clone(), keep)
        };
        let board = Arc::new(if cfg.sched.is_off() { board } else { board.windowed() });

        let (reply_tx, reply_rx) = channel::<FromShard>();
        let mut to_shards = Vec::with_capacity(cfg.shards);
        let mut joins = Vec::with_capacity(cfg.shards);
        let mut caches = Vec::with_capacity(cfg.shards);
        for (s, ids) in partition.iter().enumerate() {
            let x0_s: Layers = ids.iter().map(|&i| x0[i].clone()).collect();
            let geom_s: Vec<LayerGeometry> = ids.iter().map(|&i| geometry[i]).collect();
            let cache = Arc::new(
                SnapCache::new(cfg.round_mode.lookahead() + 3).traced(cfg.tracer.for_shard(s)),
            );
            caches.push(cache.clone());
            // the id list lives in a shared cell: a migration swaps it at an
            // epoch boundary and every sliced handle sees the new ownership
            let ids_cell = SharedIds::new(ids.clone());
            let shard_handle = handle.for_shard(board.clone(), ids_cell.clone(), cache);
            let mut ccfg = cfg.coordinator_cfg();
            ccfg.tracer = cfg.tracer.for_shard(s);
            let (tx, rx) = channel::<ToShard>();
            let rtx = reply_tx.clone();
            let delay = cfg.shard_delay.clone();
            // a lone shard's board is never read (the sharded handle's
            // owns-all-layers fast path skips it), so don't ship shifts
            let ship_shift = cfg.shards > 1;
            joins.push(
                std::thread::Builder::new()
                    .name(format!("efmuon-shard-{s}"))
                    .spawn(move || {
                        shard_main(
                            s, x0_s, geom_s, shard_handle, ccfg, ship_shift, ids_cell, delay,
                            rx, rtx,
                        )
                    })
                    .map_err(|e| anyhow!("spawning shard {s}: {e}"))?,
            );
            to_shards.push(tx);
        }
        drop(reply_tx);

        // barrier: every shard's Coordinator::spawn (worker init) must land
        for _ in 0..cfg.shards {
            match reply_rx.recv() {
                Ok(FromShard::Ready { .. }) => {}
                Ok(FromShard::Failed { shard, err }) => {
                    return Err(anyhow!("shard {shard} failed during init: {err}"))
                }
                Ok(_) => return Err(anyhow!("unexpected shard reply during init")),
                Err(_) => return Err(anyhow!("shard channel closed during init")),
            }
        }

        let shards = partition.len();
        Ok(Cluster {
            meters: vec![MeterSnapshot::default(); shards],
            plan: PartitionPlan::new(partition),
            geometry,
            shapes,
            schedule: cfg.schedule.clone(),
            sched: cfg.sched,
            win: (!cfg.sched.is_off()).then(|| WindowState {
                win: EpochWindow::new(shards, cfg.start_step),
                clock: RoundClock::new(cfg.start_step),
                bank: EwmaBank::new(shards),
                ready: VecDeque::new(),
                steals: 0,
                ahead_max: 0,
            }),
            board,
            shift_full: x0,
            caches,
            seal_bytes: 0,
            sum_losses: handle.loss_is_layer_separable(),
            handle,
            to_shards,
            from_shards: reply_rx,
            joins,
            step: cfg.start_step,
            failed: None,
            tracer: cfg.tracer,
        })
    }

    /// The layer partition: `partition()[s]` is the ascending list of
    /// global layer ids shard `s` owns (the current [`PartitionPlan`] —
    /// a steal re-partitions online).
    pub fn partition(&self) -> &[Vec<usize>] {
        self.plan.owned()
    }

    /// The partition plan's version: 0 at spawn, bumped once per steal.
    pub fn partition_version(&self) -> u64 {
        self.plan.version()
    }

    /// Number of shard coordinators.
    pub fn shards(&self) -> usize {
        self.plan.owned().len()
    }

    /// Rounds issued (every shard's broadcast sent) so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// One lock-step cluster round: every shard runs one
    /// [`Coordinator::round`] concurrently; the root waits for all of them,
    /// seals the next board epoch from the returned shifts, and reduces the
    /// per-shard stats. After a failure, this and every later call fail
    /// fast with the original error.
    pub fn round(&mut self) -> Result<ClusterRoundStats> {
        self.check_alive()?;
        let r = if self.win.is_some() {
            self.round_windowed()
        } else {
            self.round_inner()
        };
        self.latch_close(r)
    }

    fn round_inner(&mut self) -> Result<ClusterRoundStats> {
        self.send_all(|| ToShard::Round)?;
        let n = self.shards();
        let mut slots: Vec<Option<RoundStats>> = (0..n).map(|_| None).collect();
        let mut filled = 0;
        while filled < n {
            match self.from_shards.recv() {
                Ok(FromShard::Round { shard, stats, shift, meter }) => {
                    if shard >= n || slots[shard].is_some() {
                        return Err(anyhow!("duplicate or out-of-range reply from shard {shard}"));
                    }
                    for (m, &li) in shift.into_iter().zip(self.plan.shard(shard)) {
                        self.shift_full[li] = m;
                    }
                    self.meters[shard] = meter;
                    slots[shard] = Some(*stats);
                    filled += 1;
                }
                Ok(FromShard::Failed { shard, err }) => {
                    return Err(anyhow!("shard {shard} failed: {err}"))
                }
                Ok(_) => return Err(anyhow!("unexpected shard reply during round")),
                Err(_) => return Err(anyhow!("shard channel closed mid-round")),
            }
        }
        // every shard finished round `step`: seal the view round `step + 1`
        // reads (immutable afterwards — in-flight pipelined grads of older
        // rounds keep reading their own sealed epochs). A 1-shard cluster
        // skips the seal entirely: its board is never read, and the copy
        // would be pure overhead on the golden-matched deployment.
        if n > 1 {
            self.seal_bytes += self.board.seal_from(self.step + 1, &self.shift_full);
            self.tracer.stamp(Phase::BoardSeal, self.step, None);
        }
        let per_shard: Vec<RoundStats> = slots.into_iter().map(|s| s.expect("filled")).collect();
        let stats = rollup(self.step, per_shard, self.sum_losses);
        self.step += 1;
        Ok(stats)
    }

    /// One bounded-epoch round: steal if the EWMA spread warrants it, issue
    /// this round to every shard, then process replies only until the
    /// frontier is within `window` rounds of the issue — fast shards run
    /// ahead instead of waiting at a barrier. Completed-round rollups pop
    /// one per call (placeholders with `absorbed_step: None` while the
    /// window fills), so the completed-rollup stream is the lock-step
    /// stream, `window` calls later.
    fn round_windowed(&mut self) -> Result<ClusterRoundStats> {
        self.maybe_steal()?;
        let issued = self.step;
        self.send_all(|| ToShard::Round)?;
        self.win
            .as_mut()
            .expect("windowed")
            .clock
            .issue(issued, Instant::now());
        self.step += 1;
        // bounded epoch: at most `window` rounds may stay incomplete
        let window = self.sched.window;
        while self.win.as_ref().expect("windowed").win.frontier() + window <= issued {
            self.process_reply()?;
        }
        let ws = self.win.as_mut().expect("windowed");
        Ok(match ws.ready.pop_front() {
            Some(stats) => stats,
            None => ClusterRoundStats {
                step: issued,
                absorbed_step: None,
                train_loss: f32::NAN,
                radius: self.schedule.at(issued),
                w2s_bytes_per_worker: 0,
                s2w_bytes: 0,
                per_shard: Vec::new(),
            },
        })
    }

    /// Receive and stage exactly one shard reply (windowed drive).
    fn process_reply(&mut self) -> Result<()> {
        match self.from_shards.recv() {
            Ok(FromShard::Round { shard, stats, shift, meter }) => {
                self.stage_round(shard, *stats, shift, meter)
            }
            Ok(FromShard::Failed { shard, err }) => {
                Err(anyhow!("shard {shard} failed: {err}"))
            }
            Ok(_) => Err(anyhow!("unexpected shard reply during windowed round")),
            Err(_) => Err(anyhow!("shard channel closed mid-round")),
        }
    }

    /// Stage one shard's round reply: sample its round time, and for every
    /// round the reply completes, seal the next board epoch and queue the
    /// rollup. This is where epochs seal out of lock-step — as soon as the
    /// last shard reports a round, regardless of how far ahead the others
    /// already are.
    fn stage_round(
        &mut self,
        shard: usize,
        stats: RoundStats,
        shift: Layers,
        meter: MeterSnapshot,
    ) -> Result<()> {
        if shard >= self.shards() {
            return Err(anyhow!("out-of-range reply from shard {shard}"));
        }
        self.meters[shard] = meter;
        let now = Instant::now();
        let ws = self.win.as_mut().expect("windowed");
        let round = ws.win.record(shard, stats, shift).map_err(anyhow::Error::msg)?;
        ws.bank.record(shard, ws.clock.elapsed_s(round, now));
        let mut completed = Vec::new();
        while let Some(c) = ws.win.pop_complete() {
            completed.push(c);
        }
        let frontier = ws.win.frontier();
        let excess = ws.win.done(shard).saturating_sub(frontier + 1);
        ws.ahead_max = ws.ahead_max.max(excess as u64);
        ws.clock.trim(frontier);
        for (r, per_shard, shifts) in completed {
            // every staged shift predates any future steal (migration only
            // happens with zero rounds in flight), so the current plan is
            // the right decoder for all of them
            for (s, layers) in shifts.into_iter().enumerate() {
                for (m, &li) in layers.into_iter().zip(self.plan.shard(s)) {
                    self.shift_full[li] = m;
                }
            }
            if self.shards() > 1 {
                self.seal_bytes += self.board.seal_from(r + 1, &self.shift_full);
                self.tracer.stamp(Phase::EpochSeal, r, None);
            }
            let rolled = rollup(r, per_shard, self.sum_losses);
            self.win.as_mut().expect("windowed").ready.push_back(rolled);
        }
        if excess > 0 {
            self.tracer.for_shard(shard).stamp(Phase::ShardAhead, round, None);
        }
        Ok(())
    }

    /// Steal a layer from a persistently slow shard when the EWMA
    /// round-time spread crosses the threshold. Migration happens at an
    /// epoch boundary only: the root first catches up to every issued round
    /// (so no uplink, broadcast, or board read straddles the ownership
    /// change), then moves the donor's lightest layer — server shift plus
    /// every worker's EF21 error state, bitwise — to the fastest shard and
    /// bumps the [`PartitionPlan`] version.
    fn maybe_steal(&mut self) -> Result<()> {
        let Some(thresh) = self.sched.steal else { return Ok(()) };
        let (slow, fast) = {
            let ws = self.win.as_ref().expect("windowed");
            if !ws.bank.ready() || ws.bank.spread() < thresh {
                return Ok(());
            }
            (ws.bank.slowest(), ws.bank.fastest())
        };
        if slow == fast || self.plan.shard(slow).len() < 2 {
            // a 1-layer shard never donates (every shard keeps >= 1 layer)
            return Ok(());
        }
        // epoch boundary: no round in flight anywhere during the migration
        while !self.win.as_ref().expect("windowed").win.caught_up(self.step) {
            self.process_reply()?;
        }
        let layer = *self
            .plan
            .shard(slow)
            .iter()
            .min_by_key(|&&i| (self.shapes[i].0 * self.shapes[i].1, i))
            .expect("donor owns >= 2 layers");
        self.to_shards[slow]
            .send(ToShard::Release { layer })
            .map_err(|_| anyhow!("shard {slow} thread has exited"))?;
        let (server, workers) = match self.from_shards.recv() {
            Ok(FromShard::Released { shard, layer: l, server, workers })
                if shard == slow && l == layer =>
            {
                (server, workers)
            }
            Ok(FromShard::Failed { shard, err }) => {
                return Err(anyhow!("shard {shard} failed during steal: {err}"))
            }
            Ok(_) => return Err(anyhow!("unexpected shard reply during steal")),
            Err(_) => return Err(anyhow!("shard channel closed during steal")),
        };
        self.plan.migrate(layer, slow, fast).map_err(anyhow::Error::msg)?;
        self.to_shards[fast]
            .send(ToShard::Accept {
                layer,
                geometry: self.geometry[layer],
                server,
                workers,
            })
            .map_err(|_| anyhow!("shard {fast} thread has exited"))?;
        match self.from_shards.recv() {
            Ok(FromShard::Accepted { shard }) if shard == fast => {}
            Ok(FromShard::Failed { shard, err }) => {
                return Err(anyhow!("shard {shard} failed during steal: {err}"))
            }
            Ok(_) => return Err(anyhow!("unexpected shard reply during steal")),
            Err(_) => return Err(anyhow!("shard channel closed during steal")),
        }
        let ws = self.win.as_mut().expect("windowed");
        ws.steals += 1;
        // the donor just shrank and the thief grew: old round times are no
        // longer evidence about either, so re-learn before stealing again
        ws.bank.reset();
        self.tracer.stamp(Phase::LayerSteal, self.step, Some(layer));
        Ok(())
    }

    /// Windowed drain: catch up to every issued round (queueing their
    /// rollups), return the queued tail, then run the legacy drain so
    /// shard-internal pipelines land too.
    fn drain_windowed(&mut self) -> Result<Vec<ClusterRoundStats>> {
        while !self.win.as_ref().expect("windowed").win.caught_up(self.step) {
            self.process_reply()?;
        }
        let mut out: Vec<ClusterRoundStats> =
            self.win.as_mut().expect("windowed").ready.drain(..).collect();
        out.extend(self.drain_inner()?);
        Ok(out)
    }

    /// Drain every shard's pipeline (no-op in sync mode): all issued rounds
    /// land on every shard. Returns one rollup per drained round, in
    /// absorption order.
    pub fn drain(&mut self) -> Result<Vec<ClusterRoundStats>> {
        self.check_alive()?;
        let r = if self.win.is_some() {
            self.drain_windowed()
        } else {
            self.drain_inner()
        };
        self.latch_close(r)
    }

    fn drain_inner(&mut self) -> Result<Vec<ClusterRoundStats>> {
        self.send_all(|| ToShard::Drain)?;
        let n = self.shards();
        let mut slots: Vec<Option<Vec<RoundStats>>> = (0..n).map(|_| None).collect();
        let mut filled = 0;
        while filled < n {
            match self.from_shards.recv() {
                Ok(FromShard::Drained { shard, stats, meter }) => {
                    if shard >= n || slots[shard].is_some() {
                        return Err(anyhow!("duplicate or out-of-range reply from shard {shard}"));
                    }
                    self.meters[shard] = meter;
                    slots[shard] = Some(stats);
                    filled += 1;
                }
                Ok(FromShard::Failed { shard, err }) => {
                    return Err(anyhow!("shard {shard} failed: {err}"))
                }
                Ok(_) => return Err(anyhow!("unexpected shard reply during drain")),
                Err(_) => return Err(anyhow!("shard channel closed mid-drain")),
            }
        }
        let per_shard: Vec<Vec<RoundStats>> = slots.into_iter().map(|s| s.expect("filled")).collect();
        // lock-step drive: every shard drains the same number of rounds
        let len = per_shard[0].len();
        if per_shard.iter().any(|v| v.len() != len) {
            return Err(anyhow!("shards drained unequal round counts (pipeline skew)"));
        }
        Ok((0..len)
            .map(|k| {
                let entries: Vec<RoundStats> = per_shard.iter().map(|v| v[k].clone()).collect();
                let step = entries[0].step;
                rollup(step, entries, self.sum_losses)
            })
            .collect())
    }

    /// Drive `rounds` lock-step cluster rounds and drain every shard
    /// pipeline, so all issued rounds have been absorbed on return.
    pub fn run(&mut self, rounds: usize) -> Result<Vec<ClusterRoundStats>> {
        let mut out = Vec::with_capacity(rounds + 1);
        for _ in 0..rounds {
            out.push(self.round()?);
        }
        out.extend(self.drain()?);
        Ok(out)
    }

    /// Assembled full-model parameters (every shard's server X). Windowed
    /// drive catches up to every issued round first, so the assembled view
    /// is a consistent round boundary (the queued rollups stay queued for
    /// the next `round()`/`drain()` call).
    pub fn params(&mut self) -> Result<Layers> {
        self.check_alive()?;
        let r = self.params_catch_up();
        self.latch_close(r)
    }

    fn params_catch_up(&mut self) -> Result<Layers> {
        if self.win.is_some() {
            while !self.win.as_ref().expect("windowed").win.caught_up(self.step) {
                self.process_reply()?;
            }
        }
        self.params_inner()
    }

    fn params_inner(&mut self) -> Result<Layers> {
        self.send_all(|| ToShard::Params)?;
        let n = self.shards();
        let mut full = self.shift_full.clone();
        let mut filled = 0;
        while filled < n {
            match self.from_shards.recv() {
                Ok(FromShard::Params { shard, params }) => {
                    if shard >= n {
                        return Err(anyhow!("out-of-range params reply from shard {shard}"));
                    }
                    for (m, &li) in params.into_iter().zip(self.plan.shard(shard)) {
                        full[li] = m;
                    }
                    filled += 1;
                }
                Ok(FromShard::Failed { shard, err }) => {
                    return Err(anyhow!("shard {shard} failed: {err}"))
                }
                Ok(_) => return Err(anyhow!("unexpected shard reply during params")),
                Err(_) => return Err(anyhow!("shard channel closed during params")),
            }
        }
        Ok(full)
    }

    /// Evaluation loss at the assembled full-model parameters, through the
    /// root's own (un-sharded) gradient handle. Like [`Coordinator::eval`],
    /// does not drain the pipelines; `efmuon train` drains before the
    /// *final* eval so the reported loss reflects fully-absorbed rounds.
    pub fn eval(&mut self) -> Result<f32> {
        let params = self.params()?;
        self.handle.eval(&params)
    }

    /// Cluster-wide communication + memory-traffic rollup: the latest
    /// per-shard meter snapshots, overlaid with each shard's snapshot-cache
    /// counters, plus the root's own seal copies.
    pub fn meter(&self) -> ClusterMeter {
        let mut per_shard = self.meters.clone();
        for (m, c) in per_shard.iter_mut().zip(&self.caches) {
            m.snap_assembled = c.assembled();
            m.snap_reused = c.reused();
            m.bytes_cloned = c.bytes_assembled();
            m.snap_bytes_shipped = c.bytes_shipped();
        }
        let (steals, epochs_ahead_max, round_ewma_spread) = match &self.win {
            Some(ws) => (ws.steals, ws.ahead_max, ws.bank.spread()),
            None => (0, 0, 1.0),
        };
        ClusterMeter {
            per_shard,
            root_bytes_cloned: self.seal_bytes,
            steals,
            epochs_ahead_max,
            round_ewma_spread,
        }
    }

    fn send_all(&self, mut cmd: impl FnMut() -> ToShard) -> Result<()> {
        for (s, tx) in self.to_shards.iter().enumerate() {
            tx.send(cmd()).map_err(|_| anyhow!("shard {s} thread has exited"))?;
        }
        Ok(())
    }

    fn check_alive(&self) -> Result<()> {
        match &self.failed {
            Some(e) => Err(anyhow!("cluster already failed: {e}")),
            None => Ok(()),
        }
    }

    fn latch<T>(&mut self, r: Result<T>) -> Result<T> {
        if let Err(e) = &r {
            if self.failed.is_none() {
                self.failed = Some(format!("{e:#}"));
            }
        }
        r
    }

    /// [`Cluster::latch`], closing the board first on error: a windowed
    /// board may have fast-shard workers parked on an epoch the failed root
    /// will never seal. Harmless for the lock-step board (no read waits).
    fn latch_close<T>(&mut self, r: Result<T>) -> Result<T> {
        if r.is_err() {
            self.board.close();
        }
        self.latch(r)
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        // wake any worker parked on an unsealed windowed epoch *before*
        // joining the shard threads, or the join would wedge on a shard
        // whose round can no longer complete
        self.board.close();
        for tx in &self.to_shards {
            let _ = tx.send(ToShard::Stop);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Reduce one lock-step round's per-shard stats. `sum_losses` is true for
/// layer-separable objectives: each shard reported only its own layers'
/// loss contribution, so the full-model train loss is the *sum* over
/// shards; otherwise every shard reported the full-model loss and the
/// rollup averages (the legacy non-separable fallback).
fn rollup(step: usize, per_shard: Vec<RoundStats>, sum_losses: bool) -> ClusterRoundStats {
    let s2w_bytes = per_shard.iter().map(|s| s.s2w_bytes).sum();
    let w2s_bytes_per_worker = per_shard.iter().map(|s| s.w2s_bytes_per_worker).sum();
    let absorbed: Vec<&RoundStats> =
        per_shard.iter().filter(|s| s.absorbed_step.is_some()).collect();
    let train_loss = if absorbed.is_empty() {
        f32::NAN
    } else if sum_losses {
        absorbed.iter().map(|s| s.train_loss as f64).sum::<f64>() as f32
    } else {
        (absorbed.iter().map(|s| s.train_loss as f64).sum::<f64>() / absorbed.len() as f64) as f32
    };
    ClusterRoundStats {
        step,
        absorbed_step: per_shard[0].absorbed_step,
        train_loss,
        radius: per_shard[0].radius,
        w2s_bytes_per_worker,
        s2w_bytes,
        per_shard,
    }
}

/// Converts a shard-thread panic into a [`FromShard::Failed`] reply while
/// the channel is still open (same contract as the worker panic guard).
struct PanicGuard {
    shard: usize,
    tx: Sender<FromShard>,
}

impl Drop for PanicGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            let _ = self.tx.send(FromShard::Failed {
                shard: self.shard,
                err: "shard thread panicked".into(),
            });
        }
    }
}

/// Shard-thread main loop: spawn the shard's [`Coordinator`] (worker init
/// included), then serve root commands until `Stop` or a fatal error.
/// `ship_shift` is false on 1-shard clusters: no other shard will ever
/// read the board, so round replies carry an empty shift instead of a
/// full-model clone. `ids` is the shared global-layer-id cell the shard's
/// sliced grad handles read; a migration swaps it between rounds. `delay`
/// injects deterministic per-round slowdowns for scheduler tests/benches.
#[allow(clippy::too_many_arguments)]
fn shard_main(
    shard: usize,
    x0: Layers,
    geometry: Vec<LayerGeometry>,
    handle: GradHandle,
    cfg: CoordinatorCfg,
    ship_shift: bool,
    ids: SharedIds,
    delay: Option<Arc<ShardDelayPlan>>,
    rx: Receiver<ToShard>,
    tx: Sender<FromShard>,
) {
    let _guard = PanicGuard { shard, tx: tx.clone() };
    let mut coord = match Coordinator::spawn(x0, geometry, handle, cfg) {
        Ok(c) => c,
        Err(e) => {
            let _ = tx.send(FromShard::Failed { shard, err: format!("{e:#}") });
            return;
        }
    };
    if tx.send(FromShard::Ready { shard }).is_err() {
        return;
    }
    while let Ok(cmd) = rx.recv() {
        match cmd {
            ToShard::Stop => break,
            ToShard::Round => {
                if let Some(p) = &delay {
                    if let Some(ms) = p.at(shard, coord.steps_done()) {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                }
                match coord.round() {
                    Ok(stats) => {
                        let reply = FromShard::Round {
                            shard,
                            stats: Box::new(stats),
                            shift: if ship_shift { coord.shift().clone() } else { Vec::new() },
                            meter: coord.meter().snapshot(),
                        };
                        if tx.send(reply).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(FromShard::Failed { shard, err: format!("{e:#}") });
                        break;
                    }
                }
            }
            ToShard::Drain => match coord.drain() {
                Ok(stats) => {
                    let reply = FromShard::Drained {
                        shard,
                        stats,
                        meter: coord.meter().snapshot(),
                    };
                    if tx.send(reply).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(FromShard::Failed { shard, err: format!("{e:#}") });
                    break;
                }
            },
            ToShard::Params => {
                let reply = FromShard::Params { shard, params: coord.params().clone() };
                if tx.send(reply).is_err() {
                    break;
                }
            }
            ToShard::Release { layer } => {
                let cur = ids.get();
                let at = match cur.binary_search(&layer) {
                    Ok(at) => at,
                    Err(_) => {
                        let _ = tx.send(FromShard::Failed {
                            shard,
                            err: format!("asked to release unowned layer {layer}"),
                        });
                        break;
                    }
                };
                match coord.release_layer(at) {
                    Ok((server, workers)) => {
                        let mut next = (*cur).clone();
                        next.remove(at);
                        ids.set(next);
                        let reply = FromShard::Released { shard, layer, server, workers };
                        if tx.send(reply).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(FromShard::Failed { shard, err: format!("{e:#}") });
                        break;
                    }
                }
            }
            ToShard::Accept { layer, geometry, server, workers } => {
                let cur = ids.get();
                let at = match cur.binary_search(&layer) {
                    Err(at) => at,
                    Ok(_) => {
                        let _ = tx.send(FromShard::Failed {
                            shard,
                            err: format!("asked to adopt already-owned layer {layer}"),
                        });
                        break;
                    }
                };
                match coord.accept_layer(at, geometry, server, workers) {
                    Ok(()) => {
                        let mut next = (*cur).clone();
                        next.insert(at, layer);
                        ids.set(next);
                        if tx.send(FromShard::Accepted { shard }).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(FromShard::Failed { shard, err: format!("{e:#}") });
                        break;
                    }
                }
            }
        }
    }
}

/// Build a [`Meter`]-compatible rollup check (used in tests): true when the
/// cluster totals equal the sum/min composition of the given snapshots.
pub fn totals_consistent(meter: &ClusterMeter) -> bool {
    let t = meter.totals();
    let sum =
        |f: fn(&MeterSnapshot) -> u64| -> u64 { meter.per_shard.iter().map(f).sum() };
    let min = |f: fn(&MeterSnapshot) -> u64| -> u64 {
        meter.per_shard.iter().map(f).min().unwrap_or(0)
    };
    t.w2s_per_worker == sum(|m| m.w2s_per_worker)
        && t.w2s_all == sum(|m| m.w2s_all)
        && t.s2w_total == sum(|m| m.s2w_total)
        && t.rounds_issued == min(|m| m.rounds_issued)
        && t.rounds_absorbed == min(|m| m.rounds_absorbed)
        && t.snap_assembled == sum(|m| m.snap_assembled)
        && t.snap_reused == sum(|m| m.snap_reused)
        && t.snap_bytes_shipped == sum(|m| m.snap_bytes_shipped)
        && t.bytes_cloned == sum(|m| m.bytes_cloned) + meter.root_bytes_cloned
        && t.stragglers == sum(|m| m.stragglers)
        && t.respawns == sum(|m| m.respawns)
        && t.partial_rounds == sum(|m| m.partial_rounds)
        && t.reconnects == sum(|m| m.reconnects)
        && t.heartbeat_misses == sum(|m| m.heartbeat_misses)
        && t.steals == sum(|m| m.steals) + meter.steals
        && t.epochs_ahead_max
            == meter
                .per_shard
                .iter()
                .map(|m| m.epochs_ahead_max)
                .max()
                .unwrap_or(0)
                .max(meter.epochs_ahead_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    #[test]
    fn partition_single_shard_keeps_order() {
        let shapes = vec![(4, 4), (2, 2), (8, 1)];
        let p = partition_layers(&shapes, 1).unwrap();
        assert_eq!(p, vec![vec![0, 1, 2]]);
    }

    #[test]
    fn partition_balances_by_numel() {
        // loads: 16, 16, 4, 4 over two shards -> 20 / 20
        let shapes = vec![(4, 4), (4, 4), (2, 2), (2, 2)];
        let p = partition_layers(&shapes, 2).unwrap();
        let load = |ids: &Vec<usize>| -> usize {
            ids.iter().map(|&i| shapes[i].0 * shapes[i].1).sum()
        };
        assert_eq!(load(&p[0]), 20);
        assert_eq!(load(&p[1]), 20);
        // coverage: every layer exactly once
        let mut all: Vec<usize> = p.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
    }

    #[test]
    fn partition_rejects_degenerate_shard_counts() {
        let shapes = vec![(4, 4), (2, 2)];
        assert!(partition_layers(&shapes, 0).is_err());
        let err = partition_layers(&shapes, 3).unwrap_err();
        assert!(err.contains("2 layer"), "{err}");
    }

    #[test]
    fn board_seals_and_reads_by_epoch() {
        let mk = |v: f32| vec![Matrix::from_vec(1, 1, vec![v])];
        let at = |s: BoardSnap| s.layer_to_matrix(0).data;
        let b = ParamBoard::new(mk(0.0), 3);
        assert_eq!(at(b.read(0)), vec![0.0]);
        b.seal(1, mk(1.0));
        b.seal(2, mk(2.0));
        // epoch reads are exact; re-seals are idempotent
        b.seal(2, mk(99.0));
        assert_eq!(at(b.read(0)), vec![0.0]);
        assert_eq!(at(b.read(1)), vec![1.0]);
        assert_eq!(at(b.read(2)), vec![2.0]);
        // future epochs fall back to the newest sealed snapshot
        assert_eq!(at(b.read(7)), vec![2.0]);
        assert_eq!(at(b.read_latest()), vec![2.0]);
        // retention: keep=3 keeps {1,2,3} after sealing 3; epoch-0 reads
        // degrade to the oldest retained snapshot
        b.seal(3, mk(3.0));
        assert_eq!(at(b.read(0)), vec![1.0]);
    }

    #[test]
    fn board_seal_from_copies_and_pools() {
        let mk = |v: f32| vec![Matrix::from_vec(1, 1, vec![v])];
        let at = |s: BoardSnap| s.layer_to_matrix(0).data;
        let b = ParamBoard::new(mk(0.0), 2);
        assert_eq!(b.seal_from(1, &mk(1.0)), 4, "one f32 layer = 4 bytes copied");
        assert_eq!(b.seal_from(1, &mk(9.0)), 0, "re-seal is idempotent and free");
        assert_eq!(at(b.read(1)), vec![1.0]);
        // eviction reclaims unshared snapshots; later seals copy into the
        // pooled buffer and reads see the fresh content
        b.seal_from(2, &mk(2.0));
        b.seal_from(3, &mk(3.0));
        b.seal_from(4, &mk(4.0));
        assert_eq!(at(b.read(3)), vec![3.0]);
        assert_eq!(at(b.read_latest()), vec![4.0]);
    }

    #[test]
    fn bf16_board_halves_seal_bytes_and_widens_exactly() {
        let mk = |v: f32| vec![Matrix::from_vec(1, 2, vec![v, 1.5])];
        let b = ParamBoard::new_bf16(mk(0.0), 2);
        // 2 entries at 2 bytes each — exactly half the f32 board's 8
        assert_eq!(b.seal_from(1, &mk(3.0)), 4);
        assert_eq!(b.seal_from(1, &mk(9.0)), 0, "re-seal stays idempotent and free");
        // bf16-exact values survive the round trip bit for bit
        assert_eq!(b.read(1).layer_to_matrix(0).data, vec![3.0, 1.5]);
        // 1 + 2⁻⁸ is a round-to-nearest-even tie: rounds down to 1.0
        b.seal(2, vec![Matrix::from_vec(1, 2, vec![1.00390625, -0.0])]);
        let m = b.read(2).layer_to_matrix(0);
        assert_eq!(m.data[0], 1.0, "RTNE tie rounds to the even mantissa");
        assert_eq!(m.data[1].to_bits(), (-0.0f32).to_bits(), "-0.0 keeps its sign");
        // pooled re-encode path after eviction still reads fresh content
        b.seal_from(3, &mk(4.0));
        b.seal_from(4, &mk(5.0));
        let snap = b.read_latest();
        assert_eq!(snap.layer_to_matrix(0).data, vec![5.0, 1.5]);
        assert_eq!(snap.shape(0), (1, 2));
        assert_eq!(snap.layer_wire_bytes(0), 4);
        assert_eq!(snap.wire_bytes(), 4);
        let mut dst = [0.0f32; 2];
        snap.expand_layer_into(0, &mut dst);
        assert_eq!(dst, [5.0, 1.5]);
    }

    #[test]
    fn cluster_meter_rollup() {
        let m0 = MeterSnapshot {
            w2s_per_worker: 10,
            w2s_all: 30,
            s2w_total: 5,
            rounds_issued: 4,
            rounds_absorbed: 3,
            snap_assembled: 4,
            snap_reused: 8,
            bytes_cloned: 100,
            snap_bytes_shipped: 60,
            stragglers: 1,
            respawns: 0,
            partial_rounds: 1,
            reconnects: 0,
            heartbeat_misses: 2,
            steals: 0,
            epochs_ahead_max: 0,
        };
        let m1 = MeterSnapshot {
            w2s_per_worker: 7,
            w2s_all: 21,
            s2w_total: 9,
            rounds_issued: 4,
            rounds_absorbed: 4,
            snap_assembled: 4,
            snap_reused: 8,
            bytes_cloned: 100,
            snap_bytes_shipped: 70,
            stragglers: 2,
            respawns: 1,
            partial_rounds: 2,
            reconnects: 3,
            heartbeat_misses: 1,
            steals: 0,
            epochs_ahead_max: 0,
        };
        let cm = ClusterMeter {
            per_shard: vec![m0, m1],
            root_bytes_cloned: 40,
            steals: 2,
            epochs_ahead_max: 3,
            round_ewma_spread: 1.25,
        };
        let t = cm.totals();
        assert_eq!(t.w2s_per_worker, 17);
        assert_eq!(t.w2s_all, 51);
        assert_eq!(t.s2w_total, 14);
        assert_eq!(t.rounds_issued, 4);
        assert_eq!(t.rounds_absorbed, 3);
        assert_eq!(t.snap_assembled, 8);
        assert_eq!(t.snap_reused, 16);
        assert_eq!(t.bytes_cloned, 240, "per-shard assembly bytes + root seal bytes");
        assert_eq!(t.snap_bytes_shipped, 130, "board-read bytes sum over shards");
        assert_eq!(t.stragglers, 3);
        assert_eq!(t.respawns, 1);
        assert_eq!(t.partial_rounds, 3);
        assert_eq!(t.reconnects, 3);
        assert_eq!(t.heartbeat_misses, 3);
        assert_eq!(t.steals, 2, "root-level steal count folds into the totals");
        assert_eq!(t.epochs_ahead_max, 3, "window high-water mark is a max, not a sum");
        assert!(totals_consistent(&cm));
        let j = cm.to_json();
        assert!(j.get("totals").is_some());
        assert_eq!(j.get("per_shard").and_then(|v| v.as_arr()).unwrap().len(), 2);
        assert_eq!(j.get("steals").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("epochs_ahead_max").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(j.get("round_ewma_spread").and_then(|v| v.as_f64()), Some(1.25));
    }
}
