//! Round-phase tracing (DESIGN.md §Observability).
//!
//! A [`Tracer`] stamps monotonic point events — one per round phase — into
//! a bounded ring shared by every component of a deployment (coordinator,
//! cluster shards, snapshot caches, the root reducer). The ring is drained
//! by the train loop through a [`crate::metrics::JsonlWriter`] behind
//! `--trace PATH`, and summarized into per-phase aggregates for the
//! results store.
//!
//! The `Noop` variant is the golden anchor: `stamp` on it is a no-op that
//! reads no clock and takes no lock, so a tracer-off deployment is
//! *bitwise identical* to a build without the module — the scenario
//! harness asserts tracer-on ≡ tracer-off on params/bytes/eval, which only
//! holds because stamping never participates in the arithmetic.
//!
//! Overflow policy: the ring is bounded (`TraceRing::new(cap)`); when
//! full, the OLDEST event is dropped and a counter is bumped, so a stalled
//! drainer costs memory-bounded telemetry, never a blocked round.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::JsonObj;

/// Sentinel "this event has no shard" tag (single-coordinator deployments
/// and the cluster root reducer's seal events).
pub const NO_SHARD: usize = usize::MAX;

/// The span taxonomy: one variant per round phase that can consume wall
/// time. Names are stable — they are the `phase` strings in the drained
/// JSONL and the aggregate keys in the results store.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Coordinator issued a round's broadcast to all workers.
    Broadcast,
    /// One worker's uplink reply arrived and was slotted.
    Uplink,
    /// Full-participation absorb committed a round.
    Absorb,
    /// Quorum (partial) absorb committed a round.
    Quorum,
    /// A deadline expiry skipped one straggler's slot.
    StragglerSkip,
    /// A late uplink from a previously skipped slot folded into G.
    LateFold,
    /// A dead worker was respawned through the INIT_STEP path.
    Respawn,
    /// A shard's `SnapCache` assembled a full-model snapshot.
    SnapAssemble,
    /// The cluster root sealed a `ParamBoard` epoch.
    BoardSeal,
    /// A socket worker claimed a free id slot (initial connect).
    NetConnect,
    /// A re-dialing or late-joining socket worker re-claimed a freed slot.
    NetReconnect,
    /// A heartbeat window elapsed with no frame from a connected worker.
    NetMiss,
    /// The windowed root sealed a `ParamBoard` epoch as its round
    /// completed across shards (the out-of-order sibling of `BoardSeal`).
    EpochSeal,
    /// The root migrated a layer from a persistently slow shard (the
    /// `worker` field carries the layer id).
    LayerSteal,
    /// A shard's reply put it ahead of the window frontier.
    ShardAhead,
}

impl Phase {
    /// Stable wire name (JSONL `phase` key, aggregate key).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Broadcast => "broadcast",
            Phase::Uplink => "uplink",
            Phase::Absorb => "absorb",
            Phase::Quorum => "quorum",
            Phase::StragglerSkip => "straggler_skip",
            Phase::LateFold => "late_fold",
            Phase::Respawn => "respawn",
            Phase::SnapAssemble => "snap_assemble",
            Phase::BoardSeal => "board_seal",
            Phase::NetConnect => "net_connect",
            Phase::NetReconnect => "net_reconnect",
            Phase::NetMiss => "net_miss",
            Phase::EpochSeal => "epoch_seal",
            Phase::LayerSteal => "layer_steal",
            Phase::ShardAhead => "shard_ahead",
        }
    }

    /// Every phase, in taxonomy order (aggregation iterates this so the
    /// emitted key order is stable).
    pub fn all() -> &'static [Phase] {
        &[
            Phase::Broadcast,
            Phase::Uplink,
            Phase::Absorb,
            Phase::Quorum,
            Phase::StragglerSkip,
            Phase::LateFold,
            Phase::Respawn,
            Phase::SnapAssemble,
            Phase::BoardSeal,
            Phase::NetConnect,
            Phase::NetReconnect,
            Phase::NetMiss,
            Phase::EpochSeal,
            Phase::LayerSteal,
            Phase::ShardAhead,
        ]
    }
}

/// One stamped event: microseconds since the ring's epoch (monotonic, via
/// `Instant`), the phase, the round step it belongs to, and where it came
/// from.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub t_us: u64,
    pub phase: Phase,
    pub step: usize,
    /// Shard index, or [`NO_SHARD`].
    pub shard: usize,
    /// Worker id for per-worker phases (uplink, skip, fold, respawn).
    pub worker: Option<usize>,
}

impl TraceEvent {
    /// The drained JSONL row for this event.
    pub fn to_obj(&self) -> JsonObj {
        let mut o = JsonObj::new()
            .put("t_us", self.t_us)
            .put("phase", self.phase.name())
            .put("step", self.step);
        if self.shard != NO_SHARD {
            o = o.put("shard", self.shard);
        }
        if let Some(w) = self.worker {
            o = o.put("worker", w);
        }
        o
    }
}

/// The bounded event ring every live [`Tracer`] clone feeds. Drop-oldest
/// on overflow; the drop count is kept so truncation is visible in the
/// aggregates instead of silent.
#[derive(Debug)]
pub struct TraceRing {
    epoch: Instant,
    cap: usize,
    inner: Mutex<VecDeque<TraceEvent>>,
    dropped: AtomicU64,
    stamped: AtomicU64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing {
            epoch: Instant::now(),
            cap: cap.max(1),
            inner: Mutex::new(VecDeque::with_capacity(cap.max(1).min(4096))),
            dropped: AtomicU64::new(0),
            stamped: AtomicU64::new(0),
        }
    }

    fn push(&self, mut ev: TraceEvent) {
        ev.t_us = self.epoch.elapsed().as_micros() as u64;
        let mut q = match self.inner.lock() {
            Ok(q) => q,
            Err(poisoned) => poisoned.into_inner(),
        };
        if q.len() >= self.cap {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(ev);
        self.stamped.fetch_add(1, Ordering::Relaxed);
    }

    /// Take every buffered event (oldest first), leaving the ring empty.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut q = match self.inner.lock() {
            Ok(q) => q,
            Err(poisoned) => poisoned.into_inner(),
        };
        q.drain(..).collect()
    }

    /// Events lost to overflow since creation.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events stamped since creation (drained or not, including dropped).
    pub fn stamped(&self) -> u64 {
        self.stamped.load(Ordering::Relaxed)
    }
}

/// Running per-phase counts — the "trace aggregates" of a results-store
/// record. Fold drained events in with [`TraceAgg::absorb`].
#[derive(Debug, Default, Clone)]
pub struct TraceAgg {
    counts: [u64; 15],
    pub events: u64,
    pub dropped: u64,
}

impl TraceAgg {
    pub fn absorb(&mut self, events: &[TraceEvent]) {
        for ev in events {
            self.events += 1;
            let idx = Phase::all().iter().position(|p| *p == ev.phase).unwrap_or(0);
            self.counts[idx] += 1;
        }
    }

    pub fn count(&self, phase: Phase) -> u64 {
        let idx = Phase::all().iter().position(|p| *p == phase).unwrap_or(0);
        self.counts[idx]
    }

    /// `{"events": N, "dropped": M, "phases": {"broadcast": n, ...}}`,
    /// phases with zero events omitted.
    pub fn to_obj(&self) -> JsonObj {
        let mut phases = JsonObj::new();
        for (i, p) in Phase::all().iter().enumerate() {
            if self.counts[i] > 0 {
                phases = phases.put(p.name(), self.counts[i]);
            }
        }
        JsonObj::new()
            .put("events", self.events)
            .put("dropped", self.dropped)
            .put("phases", phases.build())
    }
}

/// The stamp handle threaded through the dist layer. `Noop` is the
/// default on every cfg: zero-cost, no clock, no lock — the bitwise
/// golden anchor. A live tracer is a clone of the same `Arc<TraceRing>`
/// tagged with the component's shard index.
#[derive(Debug, Clone, Default)]
pub enum Tracer {
    #[default]
    Noop,
    Ring { ring: Arc<TraceRing>, shard: usize },
}

impl Tracer {
    /// A live tracer (untagged: [`NO_SHARD`]) plus the ring to drain.
    pub fn ring(cap: usize) -> (Tracer, Arc<TraceRing>) {
        let ring = Arc::new(TraceRing::new(cap));
        (Tracer::Ring { ring: ring.clone(), shard: NO_SHARD }, ring)
    }

    /// The same ring re-tagged for one shard's events.
    pub fn for_shard(&self, shard: usize) -> Tracer {
        match self {
            Tracer::Noop => Tracer::Noop,
            Tracer::Ring { ring, .. } => Tracer::Ring { ring: ring.clone(), shard },
        }
    }

    pub fn is_noop(&self) -> bool {
        matches!(self, Tracer::Noop)
    }

    /// Stamp one event. On `Noop` this compiles to a branch on the
    /// discriminant and nothing else.
    #[inline]
    pub fn stamp(&self, phase: Phase, step: usize, worker: Option<usize>) {
        if let Tracer::Ring { ring, shard } = self {
            ring.push(TraceEvent { t_us: 0, phase, step, shard: *shard, worker });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_stamps_nothing_and_is_default() {
        let t = Tracer::default();
        assert!(t.is_noop());
        t.stamp(Phase::Broadcast, 0, None); // must not panic, must not allocate
        assert!(t.for_shard(3).is_noop());
    }

    #[test]
    fn ring_records_tags_and_drains_in_order() {
        let (t, ring) = Tracer::ring(16);
        t.stamp(Phase::Broadcast, 0, None);
        let s1 = t.for_shard(1);
        s1.stamp(Phase::Uplink, 0, Some(2));
        s1.stamp(Phase::Absorb, 0, None);
        let evs = ring.drain();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].phase, Phase::Broadcast);
        assert_eq!(evs[0].shard, NO_SHARD);
        assert_eq!(evs[1].phase, Phase::Uplink);
        assert_eq!(evs[1].shard, 1);
        assert_eq!(evs[1].worker, Some(2));
        // monotonic timestamps
        assert!(evs[0].t_us <= evs[1].t_us && evs[1].t_us <= evs[2].t_us);
        // drained: ring is empty, counters persist
        assert!(ring.drain().is_empty());
        assert_eq!(ring.stamped(), 3);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn ring_bounds_by_dropping_oldest() {
        let (t, ring) = Tracer::ring(2);
        t.stamp(Phase::Broadcast, 0, None);
        t.stamp(Phase::Uplink, 0, Some(0));
        t.stamp(Phase::Absorb, 0, None);
        let evs = ring.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].phase, Phase::Uplink, "oldest event dropped first");
        assert_eq!(ring.dropped(), 1);
        assert_eq!(ring.stamped(), 3);
    }

    #[test]
    fn aggregates_count_per_phase_and_serialize() {
        let (t, ring) = Tracer::ring(64);
        for k in 0..3 {
            t.stamp(Phase::Broadcast, k, None);
            t.stamp(Phase::Uplink, k, Some(0));
            t.stamp(Phase::Uplink, k, Some(1));
            t.stamp(Phase::Absorb, k, None);
        }
        let mut agg = TraceAgg::default();
        agg.absorb(&ring.drain());
        agg.dropped = ring.dropped();
        assert_eq!(agg.events, 12);
        assert_eq!(agg.count(Phase::Uplink), 6);
        assert_eq!(agg.count(Phase::Quorum), 0);
        let line = agg.to_obj().to_line();
        assert!(line.contains("\"uplink\":6"), "{line}");
        assert!(!line.contains("quorum"), "zero phases omitted: {line}");
    }

    #[test]
    fn event_json_omits_sentinel_shard_and_absent_worker() {
        let ev = TraceEvent { t_us: 5, phase: Phase::Broadcast, step: 2, shard: NO_SHARD, worker: None };
        let line = ev.to_obj().to_line();
        assert!(!line.contains("shard"), "{line}");
        assert!(!line.contains("worker"), "{line}");
        let ev = TraceEvent { t_us: 5, phase: Phase::Uplink, step: 2, shard: 1, worker: Some(3) };
        let line = ev.to_obj().to_line();
        assert!(line.contains("\"shard\":1"), "{line}");
        assert!(line.contains("\"worker\":3"), "{line}");
    }
}
