//! Experiment telemetry: JSONL event log + CSV emitters for the plots.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::JsonObj;

/// Append-only JSONL writer (one JSON object per line).
pub struct JsonlWriter {
    out: BufWriter<File>,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<JsonlWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(JsonlWriter { out: BufWriter::new(File::create(path)?) })
    }

    pub fn write(&mut self, obj: &JsonObj) -> std::io::Result<()> {
        writeln!(self.out, "{}", obj.to_line())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Minimal CSV writer (no quoting needs beyond our numeric tables).
pub struct CsvWriter {
    out: BufWriter<File>,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        writeln!(self.out, "{}", fields.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Render a text table (used by the bench harness to print paper tables).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("|")
    };
    let mut out = String::new();
    out.push_str(&fmt_row(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
    ));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_and_csv() {
        let dir = std::env::temp_dir().join("efmuon_metrics_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut w = JsonlWriter::create(dir.join("log.jsonl")).unwrap();
        w.write(&JsonObj::new().put("step", 1usize).put("loss", 2.5)).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(dir.join("log.jsonl")).unwrap();
        assert_eq!(text.trim(), "{\"step\":1,\"loss\":2.5}");

        let mut c = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        c.row(&["1".into(), "2".into()]).unwrap();
        c.flush().unwrap();
        assert_eq!(std::fs::read_to_string(dir.join("t.csv")).unwrap(), "a,b\n1,2\n");
    }

    #[test]
    fn table_render() {
        let t = render_table(&["name", "x"], &[vec!["aa".into(), "1".into()]]);
        assert!(t.contains("name"));
        assert!(t.contains("aa"));
        assert!(t.lines().count() == 3);
    }
}
