//! Synthetic distributed objectives — fast, PJRT-free gradient sources used
//! by optimizer unit tests, the Table 1 rate-validation bench, and the
//! divergence demo (paper §2 / §A.2).
//!
//! Everything implements [`Objective`]: `n` heterogeneous local functions
//! `f_j` over a layer-structured parameter space, with exact gradients and
//! bounded-variance stochastic gradients (Assumption 5).

use crate::linalg::matrix::{layers, Layers, Matrix};
use crate::util::rng::Rng;

/// A finite-sum objective `f = (1/n) Σ f_j` over layer-structured params.
/// `Sync` so the dist worker threads can evaluate their local gradients
/// concurrently through a shared handle (see `dist::service`).
///
/// Parameters are passed as borrowed `&[Matrix]` slices (not `&Layers`):
/// `&Layers` deref-coerces at every call site, and composite objectives
/// like [`Stacked`] can hand each part its sub-slice of the layer list
/// without materializing an owned copy per call — the zero-copy contract
/// the cluster gradient path relies on.
pub trait Objective: Send + Sync {
    fn num_workers(&self) -> usize;
    fn layer_shapes(&self) -> Vec<(usize, usize)>;
    /// Global loss `f(x)`.
    fn loss(&self, x: &[Matrix]) -> f64;
    /// Local loss `f_j(x)` (worker-side telemetry; the default falls back
    /// to the global loss for objectives without a cheap local form).
    fn loss_j(&self, _j: usize, x: &[Matrix]) -> f64 {
        self.loss(x)
    }
    /// Exact local gradient `∇f_j(x)`.
    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers;
    /// Stochastic local gradient (unbiased, bounded variance).
    fn stoch_grad_j(&self, j: usize, x: &[Matrix], _rng: &mut Rng) -> Layers {
        self.grad_j(j, x)
    }

    /// Stochastic local gradient restricted to the ascending `layer_ids` —
    /// the projection of [`Objective::stoch_grad_j`]. The default computes
    /// the full gradient and projects (always correct); layer-separable
    /// objectives override it to skip non-owned layers' work entirely,
    /// which is what makes the multi-coordinator cluster's per-shard
    /// gradient cost proportional to the shard, not the model. Overrides
    /// may consume the RNG differently from the full computation —
    /// per-stream determinism is the contract, not cross-method equality.
    fn stoch_grad_j_layers(
        &self,
        j: usize,
        x: &[Matrix],
        layer_ids: &[usize],
        rng: &mut Rng,
    ) -> Layers {
        let g = self.stoch_grad_j(j, x, rng);
        layer_ids.iter().map(|&i| g[i].clone()).collect()
    }

    /// Whether [`Objective::loss_j_layers`] returns a genuine *restricted*
    /// contribution (true for layer-separable objectives like [`Stacked`]).
    /// The cluster's root reducer uses this to decide whether per-shard
    /// train losses are summed (disjoint contributions) or averaged
    /// (every shard reported the same full-model loss). Override together
    /// with [`Objective::loss_j_layers`], never one without the other.
    fn loss_is_layer_separable(&self) -> bool {
        false
    }

    /// Local loss attributed to the ascending `layer_ids` slice of the
    /// model. Contract: over any disjoint cover of the layer ids the
    /// attributed losses must sum to exactly `loss_j` — that is what lets
    /// each cluster shard evaluate only its own layers' contribution and
    /// the root reducer sum, instead of every shard recomputing (and
    /// reporting) the full-model loss. The default is the full local loss
    /// (correct for the non-separable fallback, where the root averages).
    fn loss_j_layers(&self, j: usize, x: &[Matrix], _layer_ids: &[usize]) -> f64 {
        self.loss_j(j, x)
    }
    /// Known optimum value, if any (for convergence assertions).
    fn opt_value(&self) -> Option<f64> {
        None
    }
    /// A sensible starting point.
    fn init(&self, rng: &mut Rng) -> Layers {
        self.layer_shapes()
            .iter()
            .map(|&(m, n)| Matrix::randn(m, n, 1.0, rng))
            .collect()
    }

    /// Exact global gradient (averaged locals).
    fn grad(&self, x: &[Matrix]) -> Layers {
        let n = self.num_workers();
        let mut acc = self.grad_j(0, x);
        for j in 1..n {
            layers::axpy(&mut acc, 1.0, &self.grad_j(j, x));
        }
        for m in acc.iter_mut() {
            m.scale(1.0 / n as f32);
        }
        acc
    }
}

// ---------------------------------------------------------------------------

/// Heterogeneous diagonal quadratics:
/// `f_j(x) = ½ Σᵢ aᵢⱼ xᵢ² − bⱼᵀx`, strongly convex, known minimum.
pub struct Quadratics {
    pub a: Vec<Vec<f32>>, // per worker, per coord (positive)
    pub b: Vec<Vec<f32>>,
    pub noise: f32,
    dim: usize,
}

impl Quadratics {
    pub fn new(n_workers: usize, dim: usize, hetero: f32, noise: f32, rng: &mut Rng) -> Self {
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..n_workers {
            a.push((0..dim).map(|_| 0.5 + rng.f32() * (1.0 + hetero)).collect());
            b.push((0..dim).map(|_| rng.normal_f32() * hetero).collect());
        }
        Quadratics { a, b, noise, dim }
    }

    /// Coordinates of the global minimizer x* = (Σa)⁻¹ Σb.
    pub fn minimizer(&self) -> Vec<f32> {
        (0..self.dim)
            .map(|i| {
                let sa: f32 = self.a.iter().map(|aj| aj[i]).sum();
                let sb: f32 = self.b.iter().map(|bj| bj[i]).sum();
                sb / sa
            })
            .collect()
    }
}

impl Objective for Quadratics {
    fn num_workers(&self) -> usize {
        self.a.len()
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.dim, 1)]
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        let n = self.num_workers();
        (0..n).map(|j| self.loss_j(j, x)).sum::<f64>() / n as f64
    }

    fn loss_j(&self, j: usize, x: &[Matrix]) -> f64 {
        let xv = &x[0].data;
        let mut total = 0.0f64;
        for i in 0..self.dim {
            total += 0.5 * self.a[j][i] as f64 * (xv[i] as f64).powi(2)
                - self.b[j][i] as f64 * xv[i] as f64;
        }
        total
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        let xv = &x[0].data;
        let g: Vec<f32> = (0..self.dim)
            .map(|i| self.a[j][i] * xv[i] - self.b[j][i])
            .collect();
        vec![Matrix::col_vec(&g)]
    }

    fn stoch_grad_j(&self, j: usize, x: &[Matrix], rng: &mut Rng) -> Layers {
        let mut g = self.grad_j(j, x);
        for v in g[0].data.iter_mut() {
            *v += self.noise * rng.normal_f32();
        }
        g
    }

    fn opt_value(&self) -> Option<f64> {
        let xs = self.minimizer();
        Some(self.loss(&vec![Matrix::col_vec(&xs)]))
    }
}

// ---------------------------------------------------------------------------

/// Beznosikov et al. (2020) Example 1 — three strongly-convex quadratics on
/// which *naive* biased compression (Top1 DCGD) diverges exponentially while
/// error feedback converges. `f_j(x) = ⟨a_j, x⟩²/2` with
/// `a₁=(-3,2,2), a₂=(2,-3,2), a₃=(2,2,-3)`: at `x = t·(1,1,1)` each local
/// gradient's largest-magnitude coordinate points *away* from the optimum.
pub struct ThreeQuadratics {
    a: [[f32; 3]; 3],
}

impl ThreeQuadratics {
    pub fn new() -> Self {
        ThreeQuadratics {
            a: [[-3.0, 2.0, 2.0], [2.0, -3.0, 2.0], [2.0, 2.0, -3.0]],
        }
    }
}

impl Default for ThreeQuadratics {
    fn default() -> Self {
        Self::new()
    }
}

impl Objective for ThreeQuadratics {
    fn num_workers(&self) -> usize {
        3
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        vec![(3, 1)]
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        let xv = &x[0].data;
        let mut total = 0.0f64;
        for aj in &self.a {
            let dot: f64 = aj.iter().zip(xv).map(|(a, b)| *a as f64 * *b as f64).sum();
            total += 0.5 * dot * dot;
        }
        total / 3.0
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        let xv = &x[0].data;
        let aj = &self.a[j];
        let dot: f32 = aj.iter().zip(xv).map(|(a, b)| a * b).sum();
        vec![Matrix::col_vec(&[aj[0] * dot, aj[1] * dot, aj[2] * dot])]
    }

    fn opt_value(&self) -> Option<f64> {
        Some(0.0)
    }

    fn init(&self, _rng: &mut Rng) -> Layers {
        vec![Matrix::col_vec(&[1.0, 1.0, 1.0])]
    }
}

// ---------------------------------------------------------------------------

/// Synthetic heterogeneous logistic regression: each worker holds its own
/// sample set drawn around a shifted ground-truth separator; stochastic
/// gradients are minibatch gradients.
pub struct Logistic {
    pub xs: Vec<Matrix>,   // per worker: samples × dim
    pub ys: Vec<Vec<f32>>, // labels ±1
    pub l2: f32,
    pub minibatch: usize,
    dim: usize,
}

impl Logistic {
    pub fn new(
        n_workers: usize,
        samples_per: usize,
        dim: usize,
        hetero: f32,
        l2: f32,
        rng: &mut Rng,
    ) -> Self {
        let truth: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n_workers {
            let shift: Vec<f32> = (0..dim).map(|_| rng.normal_f32() * hetero).collect();
            let mut x = Matrix::zeros(samples_per, dim);
            let mut y = Vec::with_capacity(samples_per);
            for s in 0..samples_per {
                let mut dot = 0.0f32;
                for d in 0..dim {
                    let v = rng.normal_f32() + shift[d];
                    x.set(s, d, v);
                    dot += v * truth[d];
                }
                let label = if dot + 0.3 * rng.normal_f32() > 0.0 { 1.0 } else { -1.0 };
                y.push(label);
            }
            xs.push(x);
            ys.push(y);
        }
        Logistic { xs, ys, l2, minibatch: samples_per.max(4) / 4, dim }
    }

    fn grad_over(&self, j: usize, x: &[Matrix], rows: &[usize]) -> Layers {
        let w = &x[0].data;
        let mut g = vec![0.0f32; self.dim];
        for &s in rows {
            let row = self.xs[j].row(s);
            let dot: f32 = row.iter().zip(w.iter()).map(|(a, b)| a * b).sum();
            let y = self.ys[j][s];
            // d/dw log(1+exp(-y w.x)) = -y sigmoid(-y w.x) x
            let z = (-y * dot) as f64;
            let sig = 1.0 / (1.0 + (-z).exp());
            let coef = (-y as f64 * sig) as f32;
            for d in 0..self.dim {
                g[d] += coef * row[d];
            }
        }
        let scale = 1.0 / rows.len() as f32;
        for (d, gv) in g.iter_mut().enumerate() {
            *gv = *gv * scale + self.l2 * w[d];
        }
        vec![Matrix::col_vec(&g)]
    }
}

impl Objective for Logistic {
    fn num_workers(&self) -> usize {
        self.xs.len()
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.dim, 1)]
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        let w = &x[0].data;
        let mut total = 0.0f64;
        let mut count = 0usize;
        for j in 0..self.xs.len() {
            for s in 0..self.ys[j].len() {
                let row = self.xs[j].row(s);
                let dot: f64 = row
                    .iter()
                    .zip(w.iter())
                    .map(|(a, b)| (*a as f64) * (*b as f64))
                    .sum();
                let y = self.ys[j][s] as f64;
                total += (1.0 + (-y * dot).exp()).ln();
                count += 1;
            }
        }
        let reg: f64 =
            0.5 * self.l2 as f64 * w.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        total / count as f64 + reg
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        let rows: Vec<usize> = (0..self.ys[j].len()).collect();
        self.grad_over(j, x, &rows)
    }

    fn stoch_grad_j(&self, j: usize, x: &[Matrix], rng: &mut Rng) -> Layers {
        let n = self.ys[j].len();
        let rows: Vec<usize> = (0..self.minibatch.max(1)).map(|_| rng.below(n)).collect();
        self.grad_over(j, x, &rows)
    }
}

// ---------------------------------------------------------------------------

/// `f_j(x) = Σᵢ cosh(cⱼ·xᵢ)/cⱼ` — the classic (L⁰,L¹)-smooth family
/// (Hessian grows with ‖∇f‖, violating global L-smoothness; Zhang et al.
/// 2020). Used to validate the generalized-smooth theorems (4/6/17/24).
pub struct CoshObjective {
    pub c: Vec<f32>,
    dim: usize,
}

impl CoshObjective {
    pub fn new(n_workers: usize, dim: usize, rng: &mut Rng) -> Self {
        CoshObjective {
            c: (0..n_workers).map(|_| 0.5 + rng.f32()).collect(),
            dim,
        }
    }
}

impl Objective for CoshObjective {
    fn num_workers(&self) -> usize {
        self.c.len()
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.dim, 1)]
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        let n = self.c.len() as f64;
        self.c
            .iter()
            .map(|&c| {
                x[0].data
                    .iter()
                    .map(|&v| ((c as f64) * v as f64).cosh() / c as f64)
                    .sum::<f64>()
            })
            .sum::<f64>()
            / n
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        let c = self.c[j];
        let g: Vec<f32> = x[0]
            .data
            .iter()
            .map(|&v| ((c as f64 * v as f64).sinh()) as f32)
            .collect();
        vec![Matrix::col_vec(&g)]
    }

    fn opt_value(&self) -> Option<f64> {
        // min at x = 0: (1/n) Σ d/c_j
        Some(
            self.c.iter().map(|&c| self.dim as f64 / c as f64).sum::<f64>()
                / self.c.len() as f64,
        )
    }

    fn init(&self, _rng: &mut Rng) -> Layers {
        vec![Matrix::col_vec(&vec![1.5; self.dim])]
    }
}

/// Matrix-valued quadratic `f_j(X) = ½‖AⱼX − Bⱼ‖_F²` over an (m×n) layer —
/// exercises the *matrix* LMO geometry (spectral/NS path) with cheap exact
/// gradients `Aⱼᵀ(AⱼX − Bⱼ)`.
pub struct MatrixQuadratic {
    pub a: Vec<Matrix>,
    pub b: Vec<Matrix>,
    pub noise: f32,
    shape: (usize, usize),
}

impl MatrixQuadratic {
    pub fn new(n_workers: usize, m: usize, n: usize, noise: f32, rng: &mut Rng) -> Self {
        let a: Vec<Matrix> = (0..n_workers)
            .map(|_| {
                // well-conditioned: I + small random
                let mut r = Matrix::randn(m, m, 0.2 / (m as f32).sqrt(), rng);
                for i in 0..m {
                    r.set(i, i, r.at(i, i) + 1.0);
                }
                r
            })
            .collect();
        let b = (0..n_workers).map(|_| Matrix::randn(m, n, 1.0, rng)).collect();
        MatrixQuadratic { a, b, noise, shape: (m, n) }
    }
}

impl Objective for MatrixQuadratic {
    fn num_workers(&self) -> usize {
        self.a.len()
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        vec![self.shape]
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        let n = self.a.len() as f64;
        (0..self.a.len()).map(|j| self.loss_j(j, x)).sum::<f64>() / n
    }

    fn loss_j(&self, j: usize, x: &[Matrix]) -> f64 {
        let r = crate::linalg::matmul::matmul(&self.a[j], &x[0]).sub(&self.b[j]);
        0.5 * r.norm2_sq()
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        let r = crate::linalg::matmul::matmul(&self.a[j], &x[0]).sub(&self.b[j]);
        vec![crate::linalg::matmul::matmul_at(&self.a[j], &r)]
    }

    fn stoch_grad_j(&self, j: usize, x: &[Matrix], rng: &mut Rng) -> Layers {
        let mut g = self.grad_j(j, x);
        for v in g[0].data.iter_mut() {
            *v += self.noise * rng.normal_f32();
        }
        g
    }
}

// ---------------------------------------------------------------------------

/// A layer-separable stack of independent objectives: `f = Σₚ fₚ`, with the
/// parts' layers concatenated into one layer list. Gradients of one part's
/// layers never depend on another part's — exactly the layer-wise regime
/// the paper's analysis covers, and the workload the multi-coordinator
/// cluster (`dist::cluster`) shards without approximation: a cluster run
/// over a `Stacked` objective matches independent per-part coordinators
/// bit-for-bit (asserted in `rust/tests/scenario.rs`).
pub struct Stacked {
    parts: Vec<Box<dyn Objective>>,
    /// Layer offset of each part in the concatenated layer list.
    offsets: Vec<usize>,
    n_workers: usize,
}

impl Stacked {
    /// Stack `parts` (all must agree on the worker count).
    pub fn new(parts: Vec<Box<dyn Objective>>) -> Result<Self, String> {
        let n_workers = match parts.first() {
            Some(p) => p.num_workers(),
            None => return Err("Stacked needs at least one part".into()),
        };
        if parts.iter().any(|p| p.num_workers() != n_workers) {
            return Err("Stacked parts must agree on the worker count".into());
        }
        let mut offsets = Vec::with_capacity(parts.len());
        let mut off = 0;
        for p in &parts {
            offsets.push(off);
            off += p.layer_shapes().len();
        }
        Ok(Stacked { parts, offsets, n_workers })
    }

    /// The slice of `x` belonging to part `p` — a free borrow, handed
    /// straight to the part's `&[Matrix]` [`Objective`] methods.
    fn slice<'a>(&self, p: usize, x: &'a [Matrix]) -> &'a [Matrix] {
        let lo = self.offsets[p];
        let hi = lo + self.parts[p].layer_shapes().len();
        &x[lo..hi]
    }
}

impl Objective for Stacked {
    fn num_workers(&self) -> usize {
        self.n_workers
    }

    fn layer_shapes(&self) -> Vec<(usize, usize)> {
        self.parts.iter().flat_map(|p| p.layer_shapes()).collect()
    }

    fn loss(&self, x: &[Matrix]) -> f64 {
        (0..self.parts.len())
            .map(|p| self.parts[p].loss(self.slice(p, x)))
            .sum()
    }

    fn loss_j(&self, j: usize, x: &[Matrix]) -> f64 {
        (0..self.parts.len())
            .map(|p| self.parts[p].loss_j(j, self.slice(p, x)))
            .sum()
    }

    fn grad_j(&self, j: usize, x: &[Matrix]) -> Layers {
        (0..self.parts.len())
            .flat_map(|p| self.parts[p].grad_j(j, self.slice(p, x)))
            .collect()
    }

    fn stoch_grad_j(&self, j: usize, x: &[Matrix], rng: &mut Rng) -> Layers {
        (0..self.parts.len())
            .flat_map(|p| self.parts[p].stoch_grad_j(j, self.slice(p, x), rng))
            .collect()
    }

    fn loss_is_layer_separable(&self) -> bool {
        true
    }

    /// Each part is attributed to the caller owning the part's *first*
    /// layer: any disjoint cover of the layer ids counts every part exactly
    /// once, so the per-shard contributions sum to `loss_j` — part losses
    /// in ascending part order, the same accumulation `loss_j` itself runs,
    /// so the all-ids call is bit-identical to it (the shards=1 golden
    /// contract).
    fn loss_j_layers(&self, j: usize, x: &[Matrix], layer_ids: &[usize]) -> f64 {
        // the binary_search below relies on the documented ascending-ids
        // contract; a violation would silently drop parts from the sum
        debug_assert!(
            layer_ids.windows(2).all(|w| w[0] < w[1]),
            "layer_ids must be ascending"
        );
        (0..self.parts.len())
            .filter(|&p| layer_ids.binary_search(&self.offsets[p]).is_ok())
            .map(|p| self.parts[p].loss_j(j, self.slice(p, x)))
            .sum()
    }

    fn stoch_grad_j_layers(
        &self,
        j: usize,
        x: &[Matrix],
        layer_ids: &[usize],
        rng: &mut Rng,
    ) -> Layers {
        // separability: only evaluate the parts owning a requested layer —
        // the point of layer sharding (a shard's gradient cost is its own
        // layers', not the model's)
        let mut out = Vec::with_capacity(layer_ids.len());
        let mut k = 0;
        for p in 0..self.parts.len() {
            let lo = self.offsets[p];
            let hi = lo + self.parts[p].layer_shapes().len();
            let start = k;
            while k < layer_ids.len() && layer_ids[k] < hi {
                debug_assert!(layer_ids[k] >= lo, "layer_ids must be ascending");
                k += 1;
            }
            if k > start {
                let g = self.parts[p].stoch_grad_j(j, self.slice(p, x), rng);
                for &id in &layer_ids[start..k] {
                    out.push(g[id - lo].clone());
                }
            }
        }
        out
    }

    fn opt_value(&self) -> Option<f64> {
        self.parts.iter().map(|p| p.opt_value()).sum()
    }

    fn init(&self, rng: &mut Rng) -> Layers {
        self.parts.iter().flat_map(|p| p.init(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check(obj: &dyn Objective, x: &[Matrix], tol: f64) {
        let g = obj.grad(x);
        let eps = 1e-3f32;
        for li in 0..x.len() {
            for e in [0, x[li].numel() - 1] {
                let mut xp = x.to_vec();
                xp[li].data[e] += eps;
                let mut xm = x.to_vec();
                xm[li].data[e] -= eps;
                let fd = (obj.loss(&xp) - obj.loss(&xm)) / (2.0 * eps as f64);
                let an = g[li].data[e] as f64;
                assert!(
                    (fd - an).abs() < tol * (1.0 + an.abs()),
                    "layer {li} elem {e}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn quadratics_gradient() {
        let mut rng = Rng::new(201);
        let q = Quadratics::new(4, 10, 1.0, 0.0, &mut rng);
        let x = q.init(&mut rng);
        finite_diff_check(&q, &x, 1e-3);
        // minimum is a stationary point
        let xs = vec![Matrix::col_vec(&q.minimizer())];
        let g = q.grad(&xs);
        assert!(g[0].norm2() < 1e-4);
    }

    #[test]
    fn three_quadratics_geometry() {
        let t = ThreeQuadratics::new();
        let x = vec![Matrix::col_vec(&[1.0, 1.0, 1.0])];
        // each local gradient = a_j * <a_j, 1> = a_j (since <a_j, 1> = 1)
        let g0 = t.grad_j(0, &x);
        assert_eq!(g0[0].data, vec![-3.0, 2.0, 2.0]);
        // largest-magnitude coordinate is the NEGATIVE one -> Top1 points
        // away from the optimum; this is what breaks naive DCGD
        finite_diff_check(&t, &x, 1e-3);
        assert_eq!(t.opt_value(), Some(0.0));
    }

    #[test]
    fn logistic_gradient() {
        let mut rng = Rng::new(202);
        let l = Logistic::new(3, 20, 6, 0.5, 0.01, &mut rng);
        let x = l.init(&mut rng);
        finite_diff_check(&l, &x, 1e-2);
    }

    #[test]
    fn cosh_gradient_and_min() {
        let mut rng = Rng::new(203);
        let c = CoshObjective::new(3, 5, &mut rng);
        let x = c.init(&mut rng);
        finite_diff_check(&c, &x, 1e-2);
        let zero = vec![Matrix::zeros(5, 1)];
        assert!((c.loss(&zero) - c.opt_value().unwrap()).abs() < 1e-9);
    }

    #[test]
    fn matrix_quadratic_gradient() {
        let mut rng = Rng::new(204);
        let mq = MatrixQuadratic::new(2, 6, 4, 0.0, &mut rng);
        let x = mq.init(&mut rng);
        finite_diff_check(&mq, &x, 1e-2);
    }

    #[test]
    fn stacked_concatenates_and_separates() {
        let mut rng = Rng::new(206);
        let a = Quadratics::new(3, 5, 0.5, 0.0, &mut rng);
        let b = MatrixQuadratic::new(3, 4, 2, 0.0, &mut rng);
        let s = Stacked::new(vec![Box::new(a) as Box<dyn Objective>, Box::new(b)]).unwrap();
        assert_eq!(s.num_workers(), 3);
        assert_eq!(s.layer_shapes(), vec![(5, 1), (4, 2)]);
        let x = s.init(&mut rng);
        assert_eq!(x.len(), 2);
        finite_diff_check(&s, &x, 1e-2);
        // separability: perturbing part B's layer leaves part A's gradient
        // bit-identical
        let g = s.grad_j(1, &x);
        let mut x2 = x.clone();
        x2[1].data[0] += 10.0;
        let g2 = s.grad_j(1, &x2);
        assert_eq!(g[0].data, g2[0].data);
        assert_ne!(g[1].data, g2[1].data);
        // worker-count mismatch is rejected
        let c = Quadratics::new(2, 4, 0.5, 0.0, &mut rng);
        let d = Quadratics::new(3, 4, 0.5, 0.0, &mut rng);
        assert!(Stacked::new(vec![Box::new(c) as Box<dyn Objective>, Box::new(d)]).is_err());
        assert!(Stacked::new(vec![]).is_err());
    }

    #[test]
    fn stacked_layer_loss_partitions_sum_to_full() {
        let mut rng = Rng::new(207);
        let a = Quadratics::new(2, 5, 0.5, 0.0, &mut rng);
        let b = MatrixQuadratic::new(2, 4, 2, 0.0, &mut rng);
        let c = Quadratics::new(2, 3, 0.5, 0.0, &mut rng);
        let s =
            Stacked::new(vec![Box::new(a) as Box<dyn Objective>, Box::new(b), Box::new(c)])
                .unwrap();
        assert!(s.loss_is_layer_separable());
        let x = s.init(&mut rng);
        let full = s.loss_j(1, &x);
        // any disjoint cover of the layer ids sums to the full local loss
        // (each part is attributed to the cell owning its first layer)
        let covers: Vec<Vec<Vec<usize>>> = vec![
            vec![vec![0], vec![1], vec![2]],
            vec![vec![0, 2], vec![1]],
            vec![vec![0, 1, 2]],
        ];
        for cover in &covers {
            let sum: f64 = cover.iter().map(|ids| s.loss_j_layers(1, &x, ids)).sum();
            assert!(
                (sum - full).abs() < 1e-9 * (1.0 + full.abs()),
                "{cover:?}: {sum} vs {full}"
            );
        }
        // the all-ids call runs the same accumulation as loss_j itself, so
        // it is bit-identical (the shards=1 golden contract)
        assert_eq!(s.loss_j_layers(1, &x, &[0, 1, 2]), full);
        // the non-separable default reports the full local loss
        let mut rng2 = Rng::new(208);
        let q = Quadratics::new(2, 4, 0.5, 0.0, &mut rng2);
        let xq = q.init(&mut rng2);
        assert!(!q.loss_is_layer_separable());
        assert_eq!(q.loss_j_layers(0, &xq, &[0]), q.loss_j(0, &xq));
    }

    #[test]
    fn stoch_grad_unbiased() {
        let mut rng = Rng::new(205);
        let q = Quadratics::new(2, 4, 0.5, 0.3, &mut rng);
        let x = q.init(&mut rng);
        let exact = q.grad_j(0, &x);
        let n = 5000;
        let mut acc = Matrix::zeros(4, 1);
        for _ in 0..n {
            acc.axpy(1.0 / n as f32, &q.stoch_grad_j(0, &x, &mut rng)[0]);
        }
        assert!(acc.max_abs_diff(&exact[0]) < 0.05);
    }
}
