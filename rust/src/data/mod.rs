//! Synthetic byte-level corpus + sharded batch pipeline.
//!
//! The paper trains on FineWeb-10B. We substitute a **seeded Zipf–Markov
//! corpus**: a first-order Markov chain over 256 byte states whose rows are
//! Zipf-distributed permutations. It has (i) a known, non-trivial entropy
//! rate (so "loss threshold reached" is meaningful, as in Figure 1/2) and
//! (ii) enough sequential structure that a transformer beats the unigram
//! baseline only by actually learning — loss curves have the familiar LM
//! shape. See DESIGN.md §Substitutions.

use crate::util::rng::Rng;

/// Corpus generator + container.
pub struct Corpus {
    pub tokens: Vec<u8>,
    pub vocab: usize,
    /// transition matrix (row-stochastic), kept for entropy computation
    trans: Vec<Vec<f64>>,
}

impl Corpus {
    /// Generate `n_tokens` from a Zipf–Markov chain: row `s` of the
    /// transition matrix is a Zipf(1.2) distribution over a permutation
    /// that depends on `s`, mixed with a global Zipf unigram.
    pub fn zipf_markov(n_tokens: usize, vocab: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        let zipf: Vec<f64> = (0..vocab).map(|i| 1.0 / (1.0 + i as f64).powf(1.2)).collect();
        // per-state permuted Zipf rows, 70% Markov / 30% global unigram mix
        let mut trans = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let mut perm: Vec<usize> = (0..vocab).collect();
            rng.shuffle(&mut perm);
            let mut row = vec![0.0f64; vocab];
            for (rank, &tok) in perm.iter().enumerate() {
                row[tok] = 0.7 * zipf[rank];
            }
            for (tok, z) in zipf.iter().enumerate() {
                row[tok] += 0.3 * z;
            }
            let total: f64 = row.iter().sum();
            for v in row.iter_mut() {
                *v /= total;
            }
            trans.push(row);
        }
        let mut tokens = Vec::with_capacity(n_tokens);
        let mut state = rng.below(vocab);
        for _ in 0..n_tokens {
            state = rng.weighted(&trans[state]);
            tokens.push(state as u8);
        }
        Corpus { tokens, vocab, trans }
    }

    /// Entropy rate of the chain in nats/token (the loss floor a perfect
    /// model converges to): H = Σ_s π(s) H(row_s), π estimated empirically.
    pub fn entropy_rate(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let total = self.tokens.len() as f64;
        let mut h = 0.0;
        for s in 0..self.vocab {
            let pi = counts[s] as f64 / total;
            if pi == 0.0 {
                continue;
            }
            let row_h: f64 = self.trans[s]
                .iter()
                .filter(|&&p| p > 0.0)
                .map(|&p| -p * p.ln())
                .sum();
            h += pi * row_h;
        }
        h
    }

    /// Unigram entropy (what a context-free model converges to) — strictly
    /// above the entropy rate; the gap is what context learning buys.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let total = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                -p * p.ln()
            })
            .sum()
    }
}

/// Deterministic contiguous shard of a corpus assigned to one worker (the
/// paper partitions the dataset evenly across DDP workers).
pub struct Shard<'a> {
    pub tokens: &'a [u8],
    pub seq_len: usize,
}

impl<'a> Shard<'a> {
    pub fn new(corpus: &'a Corpus, worker: usize, n_workers: usize, seq_len: usize) -> Self {
        let n = corpus.tokens.len();
        let per = n / n_workers;
        let start = worker * per;
        let end = if worker + 1 == n_workers { n } else { start + per };
        Shard { tokens: &corpus.tokens[start..end], seq_len }
    }

    /// Sample a batch: `tokens[b][t]` input ids and `targets[b][t]` (the
    /// next token), drawn uniformly from the shard.
    pub fn sample_batch(&self, batch: usize, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
        let t = self.seq_len;
        assert!(self.tokens.len() > t + 1, "shard shorter than seq_len");
        let mut toks = Vec::with_capacity(batch * t);
        let mut tgts = Vec::with_capacity(batch * t);
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - t - 1);
            for k in 0..t {
                toks.push(self.tokens[start + k] as i32);
                tgts.push(self.tokens[start + k + 1] as i32);
            }
        }
        (toks, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let a = Corpus::zipf_markov(2000, 64, 9);
        let b = Corpus::zipf_markov(2000, 64, 9);
        assert_eq!(a.tokens, b.tokens);
        let c = Corpus::zipf_markov(2000, 64, 10);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn entropy_ordering() {
        let c = Corpus::zipf_markov(50_000, 128, 3);
        let rate = c.entropy_rate();
        let uni = c.unigram_entropy();
        let max_h = (128f64).ln();
        assert!(rate > 0.5, "rate={rate}");
        assert!(rate < uni, "markov structure must reduce entropy: {rate} vs {uni}");
        assert!(uni < max_h, "zipf skew must reduce entropy below log V");
    }

    #[test]
    fn shards_partition_disjointly() {
        let c = Corpus::zipf_markov(10_000, 64, 4);
        let total: usize = (0..4)
            .map(|w| Shard::new(&c, w, 4, 16).tokens.len())
            .sum();
        assert_eq!(total, 10_000);
    }

    #[test]
    fn batches_are_next_token_shifted() {
        let c = Corpus::zipf_markov(5_000, 64, 5);
        let shard = Shard::new(&c, 0, 1, 8);
        let mut rng = Rng::new(1);
        let (toks, tgts) = shard.sample_batch(3, &mut rng);
        assert_eq!(toks.len(), 24);
        assert_eq!(tgts.len(), 24);
        // within each row, target[t] == token[t+1]
        for b in 0..3 {
            for t in 0..7 {
                assert_eq!(tgts[b * 8 + t], toks[b * 8 + t + 1]);
            }
        }
    }
}
