//! Newton–Schulz orthogonalization — the native-rust twin of the L1 Pallas
//! kernel (`python/compile/kernels/ns.py`). Same quintic coefficients, same
//! normalization, so the two implementations agree to float tolerance and
//! are cross-checked in `rust/tests/runtime.rs`.

use super::matmul::{matmul_bt, matmul_bt_into_ws, matmul_into};
use super::matrix::Matrix;
use super::workspace::{with_thread_workspace, Workspace};

/// Quintic NS coefficients from the Muon reference implementation.
pub const NS_COEFFS: (f32, f32, f32) = (3.4445, -4.7750, 2.0315);
/// Default iteration count (paper §5: "5 Newton–Schulz iterations").
pub const NS_STEPS: usize = 5;

/// Approximate `U Vᵀ` of `g` via the quintic Newton–Schulz iteration.
///
/// Tall inputs are transposed first so the Gram matrix is the small square.
/// Temporaries come from this thread's shared workspace; hot loops that own
/// an arena should call [`newton_schulz_ws`] directly.
pub fn newton_schulz(g: &Matrix, steps: usize) -> Matrix {
    with_thread_workspace(|ws| newton_schulz_ws(g, steps, ws))
}

/// [`newton_schulz`] with caller-provided scratch: the 5-iteration quintic
/// loop performs **zero heap allocations** once `ws` is warm (the returned
/// matrix itself is drawn from — and can be given back to — the arena).
/// Results are bit-identical for every thread count and every workspace
/// state; `rust/tests/parallel.rs` asserts both.
pub fn newton_schulz_ws(g: &Matrix, steps: usize, ws: &mut Workspace) -> Matrix {
    let (a, b, c) = NS_COEFFS;
    let transpose = g.rows > g.cols;
    let mut x = ws.take(if transpose { g.cols } else { g.rows }, if transpose { g.rows } else { g.cols });
    if transpose {
        g.transpose_into(&mut x);
    } else {
        x.data.copy_from_slice(&g.data);
    }
    let nrm = x.norm2() as f32 + 1e-7;
    x.scale(1.0 / nrm);
    let k = x.rows;
    let n = x.cols;
    let mut gram = ws.take(k, k); // A = X Xᵀ (k×k)
    let mut gram2 = ws.take(k, k); // A²
    let mut poly = ws.take(k, k); // b·A + c·A²
    let mut px = ws.take(k, n); // poly·X
    for _ in 0..steps {
        matmul_bt_into_ws(&x, &x, &mut gram, ws);
        matmul_into(&gram, &gram, &mut gram2);
        poly.data.copy_from_slice(&gram.data);
        poly.axpby(b, c, &gram2);
        matmul_into(&poly, &x, &mut px);
        x.axpby(a, 1.0, &px); // X = a·X + poly·X
    }
    ws.give(gram);
    ws.give(gram2);
    ws.give(poly);
    ws.give(px);
    if transpose {
        let mut out = ws.take(g.rows, g.cols);
        x.transpose_into(&mut out);
        ws.give(x);
        out
    } else {
        x
    }
}

/// Orthogonality residual `‖XXᵀ − I‖_F / √k` — a quality metric for NS
/// (exactly orthogonal rows give 0; Muon's quintic plateaus ≈ 0.2).
pub fn orthogonality_residual(x: &Matrix) -> f64 {
    let wide = if x.rows > x.cols { x.transpose() } else { x.clone() };
    let gram = matmul_bt(&wide, &wide);
    let k = gram.rows;
    let mut acc = 0.0f64;
    for i in 0..k {
        for j in 0..k {
            let target = if i == j { 1.0 } else { 0.0 };
            let d = gram.at(i, j) as f64 - target;
            acc += d * d;
        }
    }
    (acc / k as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::jacobi_svd;
    use crate::util::rng::Rng;

    #[test]
    fn singular_values_near_one() {
        let mut rng = Rng::new(31);
        for &(m, n) in &[(16, 16), (8, 24), (24, 8)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let o = newton_schulz(&g, NS_STEPS);
            let (_, s, _) = jacobi_svd(&o);
            for &sv in &s {
                // Muon's quintic pushes singular values into ~[0.7, 1.2]
                assert!(sv > 0.55 && sv < 1.35, "{m}x{n}: sv={sv}");
            }
        }
    }

    #[test]
    fn approximates_exact_polar_direction() {
        // NS(g) should align with UVᵀ: <NS(g), UVᵀ> / (‖·‖‖·‖) ≈ 1
        let mut rng = Rng::new(32);
        let g = Matrix::randn(12, 10, 1.0, &mut rng);
        let (u, s, v) = jacobi_svd(&g);
        let k = s.len();
        let uvt = crate::linalg::svd::truncated_reconstruct(&u, &vec![1.0; k], &v, k);
        let o = newton_schulz(&g, NS_STEPS);
        let cos = o.dot(&uvt) / (o.norm2() * uvt.norm2());
        assert!(cos > 0.98, "cos={cos}");
    }

    #[test]
    fn zero_input_is_safe() {
        let g = Matrix::zeros(4, 6);
        let o = newton_schulz(&g, NS_STEPS);
        assert!(o.is_finite());
        assert!(o.norm2() < 1e-3);
    }

    #[test]
    fn residual_metric() {
        let eye = Matrix::identity(5);
        assert!(orthogonality_residual(&eye) < 1e-6);
        let mut rng = Rng::new(33);
        let g = Matrix::randn(10, 10, 1.0, &mut rng);
        // Muon's quintic pushes singular values into ~[0.7, 1.2] rather than
        // exactly 1, so the residual plateaus well below a random matrix's
        // but does not vanish.
        let o = newton_schulz(&g, NS_STEPS);
        assert!(orthogonality_residual(&o) < 0.6);
        assert!(orthogonality_residual(&o) < 0.5 * orthogonality_residual(&g));
    }
}
