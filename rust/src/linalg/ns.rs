//! Newton–Schulz orthogonalization — the native-rust twin of the L1 Pallas
//! kernel (`python/compile/kernels/ns.py`). Same quintic coefficients, same
//! normalization, so the two implementations agree to float tolerance and
//! are cross-checked in `rust/tests/runtime.rs`.

use super::matmul::{matmul, matmul_bt};
use super::matrix::Matrix;

/// Quintic NS coefficients from the Muon reference implementation.
pub const NS_COEFFS: (f32, f32, f32) = (3.4445, -4.7750, 2.0315);
/// Default iteration count (paper §5: "5 Newton–Schulz iterations").
pub const NS_STEPS: usize = 5;

/// Approximate `U Vᵀ` of `g` via the quintic Newton–Schulz iteration.
///
/// Tall inputs are transposed first so the Gram matrix is the small square.
pub fn newton_schulz(g: &Matrix, steps: usize) -> Matrix {
    let (a, b, c) = NS_COEFFS;
    let transpose = g.rows > g.cols;
    let mut x = if transpose { g.transpose() } else { g.clone() };
    let nrm = x.norm2() as f32 + 1e-7;
    x.scale(1.0 / nrm);
    let mut scratch_poly: Option<Matrix> = None;
    for _ in 0..steps {
        let gram = matmul_bt(&x, &x); // A = X Xᵀ (k×k)
        let gram2 = matmul(&gram, &gram); // A²
        // poly = b·A + c·A²  (reuse buffer across iterations)
        let poly = match scratch_poly.take() {
            Some(mut p) if p.rows == gram.rows => {
                p.data.copy_from_slice(&gram.data);
                p.axpby(b, c, &gram2);
                p
            }
            _ => {
                let mut p = gram.clone();
                p.axpby(b, c, &gram2);
                p
            }
        };
        let px = matmul(&poly, &x);
        x.axpby(a, 1.0, &px); // X = a·X + poly·X
        scratch_poly = Some(poly);
    }
    if transpose {
        x.transpose()
    } else {
        x
    }
}

/// Orthogonality residual `‖XXᵀ − I‖_F / √k` — a quality metric for NS
/// (exactly orthogonal rows give 0; Muon's quintic plateaus ≈ 0.2).
pub fn orthogonality_residual(x: &Matrix) -> f64 {
    let wide = if x.rows > x.cols { x.transpose() } else { x.clone() };
    let gram = matmul_bt(&wide, &wide);
    let k = gram.rows;
    let mut acc = 0.0f64;
    for i in 0..k {
        for j in 0..k {
            let target = if i == j { 1.0 } else { 0.0 };
            let d = gram.at(i, j) as f64 - target;
            acc += d * d;
        }
    }
    (acc / k as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::jacobi_svd;
    use crate::util::rng::Rng;

    #[test]
    fn singular_values_near_one() {
        let mut rng = Rng::new(31);
        for &(m, n) in &[(16, 16), (8, 24), (24, 8)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let o = newton_schulz(&g, NS_STEPS);
            let (_, s, _) = jacobi_svd(&o);
            for &sv in &s {
                // Muon's quintic pushes singular values into ~[0.7, 1.2]
                assert!(sv > 0.55 && sv < 1.35, "{m}x{n}: sv={sv}");
            }
        }
    }

    #[test]
    fn approximates_exact_polar_direction() {
        // NS(g) should align with UVᵀ: <NS(g), UVᵀ> / (‖·‖‖·‖) ≈ 1
        let mut rng = Rng::new(32);
        let g = Matrix::randn(12, 10, 1.0, &mut rng);
        let (u, s, v) = jacobi_svd(&g);
        let k = s.len();
        let uvt = crate::linalg::svd::truncated_reconstruct(&u, &vec![1.0; k], &v, k);
        let o = newton_schulz(&g, NS_STEPS);
        let cos = o.dot(&uvt) / (o.norm2() * uvt.norm2());
        assert!(cos > 0.98, "cos={cos}");
    }

    #[test]
    fn zero_input_is_safe() {
        let g = Matrix::zeros(4, 6);
        let o = newton_schulz(&g, NS_STEPS);
        assert!(o.is_finite());
        assert!(o.norm2() < 1e-3);
    }

    #[test]
    fn residual_metric() {
        let eye = Matrix::identity(5);
        assert!(orthogonality_residual(&eye) < 1e-6);
        let mut rng = Rng::new(33);
        let g = Matrix::randn(10, 10, 1.0, &mut rng);
        // Muon's quintic pushes singular values into ~[0.7, 1.2] rather than
        // exactly 1, so the residual plateaus well below a random matrix's
        // but does not vanish.
        let o = newton_schulz(&g, NS_STEPS);
        assert!(orthogonality_residual(&o) < 0.6);
        assert!(orthogonality_residual(&o) < 0.5 * orthogonality_residual(&g));
    }
}
