//! Blocked dense matmul — the L3-native analogue of the L1 Pallas kernel.
//!
//! The kernel computes `C = A · B` with the `ikj` loop order over
//! cache-blocked tiles: the inner loop runs contiguously over a row of `B`
//! and a row of `C`, which auto-vectorizes well. This mirrors the Pallas
//! BlockSpec schedule at L1 (see DESIGN.md §Hardware-Adaptation): the block
//! sizes play the role of the VMEM tiles.
//!
//! Used by the server hot path: Newton–Schulz spectral LMOs and RankK
//! power-iteration compressors.

use super::matrix::Matrix;

/// Tile sizes (tuned in the §Perf pass; see EXPERIMENTS.md).
const BM: usize = 32;
const BK: usize = 64;
const BN: usize = 256;

/// `C = A · B` into a fresh matrix.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B`, writing into a caller-provided buffer (no allocation).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul inner dim: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul out shape");
    c.fill(0.0);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let ad = &a.data;
    let bd = &b.data;
    let cd = &mut c.data;
    for i0 in (0..m).step_by(BM) {
        let i1 = (i0 + BM).min(m);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for j0 in (0..n).step_by(BN) {
                let j1 = (j0 + BN).min(n);
                // §Perf note: a 4-way k-unroll was tried here and REVERTED
                // (bounds-check noise beat the ILP win; see EXPERIMENTS.md
                // §Perf iteration log). The simple ikj form vectorizes
                // cleanly under target-cpu=native.
                for i in i0..i1 {
                    let crow = &mut cd[i * n + j0..i * n + j1];
                    for kk in k0..k1 {
                        let aik = ad[i * k + kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[kk * n + j0..kk * n + j1];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `C = A · Bᵀ` without materializing the transpose (rows of `B` are
/// contiguous, so this is a sequence of dot products).
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_bt_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` into a caller-provided buffer.
///
/// §Perf: for sizeable inputs the dot-product form (horizontal adds) loses
/// badly to the vectorized `ikj` kernel, so we pay one explicit transpose
/// and dispatch to [`matmul_into`] — 2-3× faster on NS-sized Gram matrices.
pub fn matmul_bt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_bt inner dim");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_bt out shape");
    let k = a.cols;
    if a.rows * b.rows * k >= 32 * 32 * 32 {
        let bt = b.transpose();
        matmul_into(a, &bt, c);
        return;
    }
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..b.rows {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            // simple 4-way unrolled dot product
            let mut idx = 0;
            while idx + 4 <= k {
                acc += arow[idx] * brow[idx]
                    + arow[idx + 1] * brow[idx + 1]
                    + arow[idx + 2] * brow[idx + 2]
                    + arow[idx + 3] * brow[idx + 3];
                idx += 4;
            }
            while idx < k {
                acc += arow[idx] * brow[idx];
                idx += 1;
            }
            c.data[i * b.rows + j] = acc;
        }
    }
}

/// `C = Aᵀ · B` without materializing the transpose.
pub fn matmul_at(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_at inner dim");
    let (m, n) = (a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for kk in 0..a.rows {
        let arow = &a.data[kk * a.cols..(kk + 1) * a.cols];
        let brow = &b.data[kk * b.cols..(kk + 1) * b.cols];
        for i in 0..m {
            let aik = arow[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// Matrix–vector product `A·x` (x as column-major slice).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    (0..a.rows)
        .map(|i| {
            a.row(i)
                .iter()
                .zip(x)
                .map(|(u, v)| u * v)
                .sum::<f32>()
        })
        .collect()
}

/// `Aᵀ·x`.
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows, x.len());
    let mut out = vec![0.0f32; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        for (o, v) in out.iter_mut().zip(a.row(i)) {
            *o += xi * v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(5);
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (33, 65, 17), (70, 40, 90)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn bt_at_variants() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(9, 13, 1.0, &mut rng);
        let b = Matrix::randn(11, 13, 1.0, &mut rng);
        assert!(matmul_bt(&a, &b).max_abs_diff(&matmul(&a, &b.transpose())) < 1e-4);
        let c = Matrix::randn(9, 4, 1.0, &mut rng);
        assert!(matmul_at(&a, &c).max_abs_diff(&matmul(&a.transpose(), &c)) < 1e-4);
    }

    #[test]
    fn matvec_variants() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let y = matvec(&a, &x);
        let expect = matmul(&a, &Matrix::col_vec(&x));
        for i in 0..6 {
            assert!((y[i] - expect.at(i, 0)).abs() < 1e-5);
        }
        let z = matvec_t(&a, &matvec(&a, &x));
        let expect2 = matmul_at(&a, &expect);
        for i in 0..4 {
            assert!((z[i] - expect2.at(i, 0)).abs() < 1e-4);
        }
    }
}
