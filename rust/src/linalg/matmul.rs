//! Blocked dense matmul — the L3-native analogue of the L1 Pallas kernel.
//!
//! The kernel computes `C = A · B` with a packed register-tiled microkernel:
//! `MR x NR` output tiles held in registers, accumulated over the full inner
//! dimension in ascending `k` order, inside a `BN`-wide column panel so the
//! streamed rows of `B` stay cache-resident. The fixed-size `[f32; NR]` lanes
//! auto-vectorize to full-width FMA-free SIMD (no `mul_add`: contraction
//! would change the rounding chain). This mirrors the Pallas BlockSpec
//! schedule at L1 (see DESIGN.md §Hardware-Adaptation): the register tile
//! plays the role of the VMEM tile. Tuning sweep: EXPERIMENTS.md §Microkernel.
//!
//! **Determinism contract.** Every output element is the chain
//! `((0 + a[i,0]·b[0,j]) + a[i,1]·b[1,j]) + …` in ascending `k` with a single
//! f32 accumulator — in the register tile, in the edge loops, and in
//! [`matmul_into_reference`]. f32 stores/loads are lossless, so accumulating
//! in a register tile vs. streaming into pre-zeroed memory is the *same*
//! chain, and the blocked kernel is **bitwise** equal to the scalar
//! reference — including `inf`/`NaN`/`-0.0` inputs. There is deliberately no
//! `a[i,k] == 0.0` skip: it would drop `0·inf = NaN` and diverge from the
//! reference on non-finite inputs (and it blocks vectorization). See
//! DESIGN.md §Non-finite values policy.
//!
//! Large products are additionally **row-partitioned across scoped OS
//! threads** (DESIGN.md §Hot-path threading): each thread owns a contiguous
//! band of `C` rows, so the result is bit-identical for every thread count
//! — for any output element the contributions over `k` are reduced by
//! exactly one thread in ascending order. `rust/tests/parallel.rs` asserts
//! this.
//!
//! Used by the server hot path: Newton–Schulz spectral LMOs and RankK
//! power-iteration compressors.

use super::matrix::Matrix;
use super::workspace::{with_thread_workspace, Workspace};
use crate::util::threads::num_threads;

/// Column-panel width: rows of `B` are streamed `BN` floats at a time so a
/// `k x BN` panel of `B` (256 KiB at k = 256) stays L2-resident while the
/// `i` loop sweeps over it (tuned in the §Perf pass; see EXPERIMENTS.md).
const BN: usize = 256;
/// Register tile height: rows of `C` accumulated concurrently. 4 rows of
/// 16-lane accumulators = 8 x 256-bit (or 4 x 512-bit) registers live.
const MR: usize = 4;
/// Register tile width: one cache line of `C` per row, two 8-lane AVX2
/// vectors — enough independent accumulator chains to hide FMA latency.
const NR: usize = 16;

/// Minimum FLOP count (2·m·k·n) before the kernel fans out across threads —
/// below this, thread-spawn latency beats the parallel win.
const PAR_MIN_FLOPS: usize = 8 << 20;

/// `MR x NR` register-tiled microkernel: computes the full-`k` products for
/// output rows `i..i+MR`, columns `j..j+NR`, and stores them. `cd` is the
/// caller's row band (rows `row0..row0+rows` of `C`); `i` is band-relative.
/// The accumulators start at 0.0 and run ascending in `k`, exactly like the
/// pre-zeroed streaming edge loop, so both paths produce identical bits.
#[inline(always)]
fn mm_tile(ad: &[f32], bd: &[f32], cd: &mut [f32], row0: usize, i: usize, j: usize, k: usize, n: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..k {
        // fixed-size reborrow: lets the compiler keep the B strip in two
        // vector registers with the bounds check hoisted out of the lanes
        let brow: &[f32; NR] = bd[kk * n + j..kk * n + j + NR].try_into().unwrap();
        for (mi, lane) in acc.iter_mut().enumerate() {
            let aik = ad[(row0 + i + mi) * k + kk];
            for (av, bv) in lane.iter_mut().zip(brow) {
                *av += aik * *bv;
            }
        }
    }
    for (mi, lane) in acc.iter().enumerate() {
        cd[(i + mi) * n + j..(i + mi) * n + j + NR].copy_from_slice(lane);
    }
}

/// Streaming `ikj` edge loop for the row/column remainders that don't fill
/// an `MR x NR` tile. `cd` is pre-zeroed, so the per-element accumulation
/// chain matches the register tile bit for bit. No `aik == 0.0` skip — see
/// the module docs (non-finite divergence).
fn mm_edge(
    ad: &[f32],
    bd: &[f32],
    cd: &mut [f32],
    row0: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
) {
    for i in i0..i1 {
        let crow = &mut cd[i * n + j0..i * n + j1];
        for kk in 0..k {
            let aik = ad[(row0 + i) * k + kk];
            let brow = &bd[kk * n + j0..kk * n + j1];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// Inner kernel: compute `rows` rows of `C` starting at absolute row `row0`
/// of `A`. `cd` holds exactly those rows (caller pre-zeroed). The
/// per-element accumulation order over `k` is independent of `row0`/`rows`
/// and of which path (tile vs. edge) computes it, which is what makes the
/// row-partitioned parallel variant bit-exact.
fn mm_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], row0: usize, rows: usize, k: usize, n: usize) {
    for j0 in (0..n).step_by(BN) {
        let j1 = (j0 + BN).min(n);
        let mut i = 0;
        while i + MR <= rows {
            let mut j = j0;
            while j + NR <= j1 {
                mm_tile(ad, bd, cd, row0, i, j, k, n);
                j += NR;
            }
            if j < j1 {
                mm_edge(ad, bd, cd, row0, i, i + MR, j, j1, k, n);
            }
            i += MR;
        }
        if i < rows {
            mm_edge(ad, bd, cd, row0, i, rows, j0, j1, k, n);
        }
    }
}

/// `C = A · B` into a fresh matrix.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B`, writing into a caller-provided buffer (no allocation).
/// Fans out across OS threads when the product is large enough; results
/// are bit-identical at every thread count. Threads already running as a
/// fan-out lane (per-layer LMO pass) keep nested products single-threaded
/// so an nt-lane round never oversubscribes to nt × nt threads.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let small = 2 * a.rows * a.cols * b.cols < PAR_MIN_FLOPS;
    let threads = if small || crate::util::threads::in_parallel_region() {
        1
    } else {
        num_threads()
    };
    matmul_into_with_threads(a, b, c, threads);
}

/// `C = A · B` with an explicit thread count (benches pin `threads = 1`
/// for the single-core baseline; tests sweep counts to assert bit-equality).
pub fn matmul_into_with_threads(a: &Matrix, b: &Matrix, c: &mut Matrix, threads: usize) {
    assert_eq!(a.cols, b.rows, "matmul inner dim: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul out shape");
    c.fill(0.0);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if m * k * n == 0 {
        return;
    }
    let ad = &a.data;
    let bd = &b.data;
    let cd = &mut c.data;
    let nt = threads.max(1).min(m);
    if nt == 1 {
        mm_rows(ad, bd, cd, 0, m, k, n);
        return;
    }
    let rows_per = (m + nt - 1) / nt;
    std::thread::scope(|s| {
        for (ci, chunk) in cd.chunks_mut(rows_per * n).enumerate() {
            let rows = chunk.len() / n;
            let row0 = ci * rows_per;
            s.spawn(move || mm_rows(ad, bd, chunk, row0, rows, k, n));
        }
    });
}

/// Scalar `ikj` reference: the bit-exactness oracle for the microkernel
/// (and the single-thread bench baseline — EXPERIMENTS.md §Microkernel).
/// One f32 accumulator per element, ascending `k`, no skips, no blocking:
/// the blocked/threaded kernel must reproduce this **bitwise**, including
/// on `inf`/`NaN`/`-0.0` inputs (`tests/nonfinite.rs`).
pub fn matmul_into_reference(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul inner dim: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul out shape");
    c.fill(0.0);
    let (k, n) = (a.cols, b.cols);
    for i in 0..a.rows {
        let crow = &mut c.data[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a.data[i * k + kk];
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// `C = A · Bᵀ` without materializing the transpose (rows of `B` are
/// contiguous, so this is a sequence of dot products).
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_bt_into(a, b, &mut c);
    c
}

/// `C = A · Bᵀ` into a caller-provided buffer. The transpose scratch for
/// the large-input path comes from this thread's shared workspace; callers
/// already holding an arena should use [`matmul_bt_into_ws`] instead.
pub fn matmul_bt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    with_thread_workspace(|ws| matmul_bt_into_ws(a, b, c, ws));
}

/// `C = A · Bᵀ` with caller-provided scratch (zero allocations after the
/// workspace warms up).
///
/// §Perf: for sizeable inputs the dot-product form (horizontal adds) loses
/// badly to the register-tiled kernel, so we pay one explicit transpose
/// — served from the workspace arena, not the allocator — and dispatch to
/// [`matmul_into`]: 2-3× faster on NS-sized Gram matrices.
pub fn matmul_bt_into_ws(a: &Matrix, b: &Matrix, c: &mut Matrix, ws: &mut Workspace) {
    assert_eq!(a.cols, b.cols, "matmul_bt inner dim");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_bt out shape");
    let k = a.cols;
    if a.rows * b.rows * k >= 32 * 32 * 32 {
        let mut bt = ws.take(b.cols, b.rows);
        b.transpose_into(&mut bt);
        matmul_into(a, &bt, c);
        ws.give(bt);
        return;
    }
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..b.rows {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            // simple 4-way unrolled dot product
            let mut idx = 0;
            while idx + 4 <= k {
                acc += arow[idx] * brow[idx]
                    + arow[idx + 1] * brow[idx + 1]
                    + arow[idx + 2] * brow[idx + 2]
                    + arow[idx + 3] * brow[idx + 3];
                idx += 4;
            }
            while idx < k {
                acc += arow[idx] * brow[idx];
                idx += 1;
            }
            c.data[i * b.rows + j] = acc;
        }
    }
}

/// `C = Aᵀ · B` without materializing the transpose.
pub fn matmul_at(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols, b.cols);
    matmul_at_into(a, b, &mut c);
    c
}

/// `C = Aᵀ · B` into a caller-provided buffer (no allocation). `kij` order:
/// per-element accumulation still runs ascending in the inner dimension
/// (rows of `A`). No `aik == 0.0` skip — it would drop `0·inf = NaN`
/// propagation (DESIGN.md §Non-finite values policy).
pub fn matmul_at_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_at inner dim");
    assert_eq!((c.rows, c.cols), (a.cols, b.cols), "matmul_at out shape");
    c.fill(0.0);
    let (m, n) = (a.cols, b.cols);
    for kk in 0..a.rows {
        let arow = &a.data[kk * a.cols..(kk + 1) * a.cols];
        let brow = &b.data[kk * b.cols..(kk + 1) * b.cols];
        for i in 0..m {
            let aik = arow[i];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// Matrix–vector product `A·x` (x as column-major slice).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    (0..a.rows)
        .map(|i| {
            a.row(i)
                .iter()
                .zip(x)
                .map(|(u, v)| u * v)
                .sum::<f32>()
        })
        .collect()
}

/// `Aᵀ·x`.
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows, x.len());
    let mut out = vec![0.0f32; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        for (o, v) in out.iter_mut().zip(a.row(i)) {
            *o += xi * v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(5);
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (33, 65, 17), (70, 40, 90)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn blocked_bitwise_equals_reference() {
        // the microkernel contract: tile + edge paths reproduce the scalar
        // ikj chain exactly, across tile-boundary shapes
        let mut rng = Rng::new(51);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 7, 5),
            (4, 9, 16),
            (5, 9, 17),
            (33, 65, 255),
            (70, 40, 257),
            (64, 128, 272),
        ] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let mut r = Matrix::zeros(m, n);
            matmul_into_reference(&a, &b, &mut r);
            assert_eq!(bits(&c), bits(&r), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn nonfinite_inputs_match_reference_bitwise() {
        // regression: the old `aik == 0.0 { continue }` skip dropped the
        // 0·inf = NaN contribution, silently diverging from the reference
        let mut rng = Rng::new(52);
        let mut a = Matrix::randn(21, 37, 1.0, &mut rng);
        let mut b = Matrix::randn(37, 33, 1.0, &mut rng);
        a.data[0] = 0.0;
        a.data[38] = -0.0;
        a.data[40] = f32::NAN;
        b.data[0] = f32::INFINITY;
        b.data[1] = f32::NEG_INFINITY;
        b.data[33] = f32::NAN;
        b.data[34] = -0.0;
        let c = matmul(&a, &b);
        let mut r = Matrix::zeros(21, 33);
        matmul_into_reference(&a, &b, &mut r);
        assert_eq!(bits(&c), bits(&r));
        // a zero row against an inf column MUST produce NaN, not 0
        assert!(c.at(0, 0).is_nan(), "0·inf must propagate NaN");
    }

    #[test]
    fn matmul_at_propagates_nonfinite() {
        // Aᵀ·B with a zero in A lined up against inf in B: the element is NaN
        let mut a = Matrix::zeros(2, 3);
        let mut b = Matrix::zeros(2, 2);
        a.set(0, 0, 0.0);
        a.set(1, 0, 1.0);
        b.set(0, 0, f32::INFINITY);
        b.set(1, 0, 2.0);
        let c = matmul_at(&a, &b);
        assert!(c.at(0, 0).is_nan(), "0·inf + 1·2 must be NaN, got {}", c.at(0, 0));
    }

    #[test]
    fn threaded_bit_identical_to_serial() {
        let mut rng = Rng::new(15);
        for &(m, k, n) in &[(70, 40, 90), (257, 63, 31), (5, 301, 2)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let mut base = Matrix::zeros(m, n);
            matmul_into_with_threads(&a, &b, &mut base, 1);
            for nt in [2, 3, 7, 64] {
                let mut c = Matrix::zeros(m, n);
                matmul_into_with_threads(&a, &b, &mut c, nt);
                assert_eq!(c.data, base.data, "{m}x{k}x{n} with {nt} threads");
            }
        }
    }

    #[test]
    fn bt_at_variants() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(9, 13, 1.0, &mut rng);
        let b = Matrix::randn(11, 13, 1.0, &mut rng);
        assert!(matmul_bt(&a, &b).max_abs_diff(&matmul(&a, &b.transpose())) < 1e-4);
        let c = Matrix::randn(9, 4, 1.0, &mut rng);
        assert!(matmul_at(&a, &c).max_abs_diff(&matmul(&a.transpose(), &c)) < 1e-4);
    }

    #[test]
    fn bt_workspace_path_is_allocation_free_when_warm(){
        let mut rng = Rng::new(7);
        // large enough for the transpose path (>= 32^3 products)
        let a = Matrix::randn(40, 40, 1.0, &mut rng);
        let b = Matrix::randn(40, 40, 1.0, &mut rng);
        let mut c = Matrix::zeros(40, 40);
        let mut ws = crate::linalg::workspace::Workspace::new();
        matmul_bt_into_ws(&a, &b, &mut c, &mut ws);
        let warm = ws.fresh_allocs();
        assert!(warm >= 1);
        for _ in 0..5 {
            matmul_bt_into_ws(&a, &b, &mut c, &mut ws);
        }
        assert_eq!(ws.fresh_allocs(), warm, "bt transpose must reuse the arena");
        assert!(c.max_abs_diff(&matmul(&a, &b.transpose())) < 1e-3);
    }

    #[test]
    fn matvec_variants() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let y = matvec(&a, &x);
        let expect = matmul(&a, &Matrix::col_vec(&x));
        for i in 0..6 {
            assert!((y[i] - expect.at(i, 0)).abs() < 1e-5);
        }
        let z = matvec_t(&a, &matvec(&a, &x));
        let expect2 = matmul_at(&a, &expect);
        for i in 0..4 {
            assert!((z[i] - expect2.at(i, 0)).abs() < 1e-4);
        }
    }
}
