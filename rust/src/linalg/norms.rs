//! The paper's norm geometry: primal norms ‖·‖, dual norms ‖·‖⋆, and the
//! norm-equivalence constants ρ, ρ̄ with ρ‖X‖ ≤ ‖X‖₂ ≤ ρ̄‖X‖ (paper §B).
//!
//! Operator norms `‖A‖_{α→β}` are covered for the cases the paper uses:
//! spectral (2→2), `1→∞` (max |entry|… actually max abs entry = ℓ∞ on the
//! flattened matrix), `∞→∞` (max row sum), `1→2` (max column ℓ2 norm), and
//! the Schatten family via exact small-matrix SVD.

use super::matrix::Matrix;
use super::svd::{jacobi_svd, top_singular};
use crate::util::rng::Rng;

/// The norms assigned to layer groups (paper Table 3 / §B.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    /// ‖·‖₂→₂ spectral — Muon's choice for hidden layers.
    Spectral,
    /// element-wise ℓ∞ — the paper's choice for embedding/output layers.
    LInf,
    /// element-wise ℓ1 (dual of ℓ∞).
    L1,
    /// Frobenius / Euclidean.
    Frobenius,
    /// nuclear (Schatten-1, dual of spectral).
    Nuclear,
    /// 1→2 operator norm: max column ℓ2 norm (column-wise Gluon/ colwise
    /// normalization in Glentis et al.).
    MaxColL2,
    /// ∞→∞ operator norm: max row ℓ1 sum (paper §D.1).
    MaxRowL1,
}

impl NormKind {
    /// Dual norm pairing used for gradients (LMO arguments live in the dual).
    pub fn dual(self) -> NormKind {
        match self {
            NormKind::Spectral => NormKind::Nuclear,
            NormKind::Nuclear => NormKind::Spectral,
            NormKind::LInf => NormKind::L1,
            NormKind::L1 => NormKind::LInf,
            NormKind::Frobenius => NormKind::Frobenius,
            // duals of the mixed operator norms are the corresponding
            // ℓ_{p,q} norms; only needed for diagnostics here:
            NormKind::MaxColL2 => NormKind::MaxColL2,
            NormKind::MaxRowL1 => NormKind::MaxRowL1,
        }
    }
}

/// Exact ℓ∞ (max abs entry).
pub fn linf(a: &Matrix) -> f64 {
    a.max_abs() as f64
}

/// Exact ℓ1 (sum of abs entries).
pub fn l1(a: &Matrix) -> f64 {
    a.data.iter().map(|x| x.abs() as f64).sum()
}

/// Frobenius.
pub fn fro(a: &Matrix) -> f64 {
    a.norm2()
}

/// Spectral norm via power iteration (iters=100 gives ~1e-3 relative).
pub fn spectral(a: &Matrix, rng: &mut Rng) -> f64 {
    top_singular(a, 100, rng).0 as f64
}

/// Exact spectral norm via Jacobi SVD (small matrices / tests).
pub fn spectral_exact(a: &Matrix) -> f64 {
    jacobi_svd(a).1.first().copied().unwrap_or(0.0) as f64
}

/// Exact nuclear norm (sum of singular values) via Jacobi SVD.
pub fn nuclear_exact(a: &Matrix) -> f64 {
    jacobi_svd(a).1.iter().map(|s| *s as f64).sum()
}

/// Schatten-p norm via exact SVD.
pub fn schatten(a: &Matrix, p: f64) -> f64 {
    let (_, s, _) = jacobi_svd(a);
    s.iter().map(|x| (*x as f64).powf(p)).sum::<f64>().powf(1.0 / p)
}

/// max column ℓ2 norm (operator 1→2).
pub fn max_col_l2(a: &Matrix) -> f64 {
    (0..a.cols)
        .map(|j| {
            (0..a.rows)
                .map(|i| (a.at(i, j) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        })
        .fold(0.0, f64::max)
}

/// max row ℓ1 sum (operator ∞→∞).
pub fn max_row_l1(a: &Matrix) -> f64 {
    (0..a.rows)
        .map(|i| a.row(i).iter().map(|x| x.abs() as f64).sum())
        .fold(0.0, f64::max)
}

/// ℓ_{p,q} mixed column norm (paper Definition 13 support).
pub fn lpq(a: &Matrix, p: f64, q: f64) -> f64 {
    (0..a.cols)
        .map(|j| {
            (0..a.rows)
                .map(|i| (a.at(i, j).abs() as f64).powf(p))
                .sum::<f64>()
                .powf(1.0 / p)
                .powf(q)
        })
        .sum::<f64>()
        .powf(1.0 / q)
}

/// Evaluate a [`NormKind`] (exact variants; power iteration where noted).
pub fn eval(kind: NormKind, a: &Matrix) -> f64 {
    match kind {
        NormKind::Spectral => spectral_exact(a),
        NormKind::LInf => linf(a),
        NormKind::L1 => l1(a),
        NormKind::Frobenius => fro(a),
        NormKind::Nuclear => nuclear_exact(a),
        NormKind::MaxColL2 => max_col_l2(a),
        NormKind::MaxRowL1 => max_row_l1(a),
    }
}

/// Norm-equivalence constants (ρ, ρ̄) with ρ‖X‖ ≤ ‖X‖₂ ≤ ρ̄‖X‖ for an
/// m×n matrix (paper Remark 7: for spectral, ρ=1, ρ̄=√rank ≤ √min(m,n)).
pub fn equivalence_constants(kind: NormKind, m: usize, n: usize) -> (f64, f64) {
    let r = m.min(n) as f64;
    let d = (m * n) as f64;
    match kind {
        NormKind::Spectral => (1.0, r.sqrt()),
        NormKind::Nuclear => (1.0 / r.sqrt(), 1.0),
        NormKind::LInf => (1.0, d.sqrt()),
        NormKind::L1 => (1.0 / d.sqrt(), 1.0),
        NormKind::Frobenius => (1.0, 1.0),
        NormKind::MaxColL2 => (1.0, (n as f64).sqrt()),
        NormKind::MaxRowL1 => (1.0 / (n as f64).sqrt(), (m as f64).sqrt()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, -2.0, 3.0, 0.0, 4.0, -1.0])
    }

    #[test]
    fn elementwise_norms() {
        let a = sample();
        assert_eq!(linf(&a), 4.0);
        assert_eq!(l1(&a), 11.0);
        assert!((fro(&a) - (31.0f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn operator_norms() {
        let a = sample();
        assert!((max_row_l1(&a) - 6.0).abs() < 1e-6); // row 0: 1+2+3
        let col1 = (4.0f64 + 16.0).sqrt();
        assert!((max_col_l2(&a) - col1).abs() < 1e-6);
    }

    #[test]
    fn schatten_consistency() {
        let a = sample();
        assert!((schatten(&a, 2.0) - fro(&a)).abs() < 1e-4);
        assert!((schatten(&a, 1.0) - nuclear_exact(&a)).abs() < 1e-4);
        // spectral <= fro <= nuclear
        assert!(spectral_exact(&a) <= fro(&a) + 1e-6);
        assert!(fro(&a) <= nuclear_exact(&a) + 1e-6);
    }

    #[test]
    fn power_iter_matches_exact() {
        let mut rng = Rng::new(44);
        let a = Matrix::randn(10, 14, 1.0, &mut rng);
        let s1 = spectral(&a, &mut rng);
        let s2 = spectral_exact(&a);
        assert!((s1 - s2).abs() / s2 < 5e-3, "{s1} vs {s2}");
    }

    #[test]
    fn equivalence_bounds_hold() {
        let mut rng = Rng::new(45);
        for kind in [
            NormKind::Spectral,
            NormKind::Nuclear,
            NormKind::LInf,
            NormKind::L1,
            NormKind::Frobenius,
            NormKind::MaxColL2,
        ] {
            let a = Matrix::randn(6, 9, 1.0, &mut rng);
            let (lo, hi) = equivalence_constants(kind, 6, 9);
            let nk = eval(kind, &a);
            let n2 = fro(&a);
            assert!(lo * nk <= n2 * (1.0 + 1e-4), "{kind:?}: lo");
            assert!(n2 <= hi * nk * (1.0 + 1e-4), "{kind:?}: hi");
        }
    }

    #[test]
    fn duality_pairs() {
        assert_eq!(NormKind::Spectral.dual(), NormKind::Nuclear);
        assert_eq!(NormKind::Nuclear.dual(), NormKind::Spectral);
        assert_eq!(NormKind::LInf.dual(), NormKind::L1);
        assert_eq!(NormKind::Frobenius.dual(), NormKind::Frobenius);
    }
}
