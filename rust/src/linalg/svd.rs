//! Spectral machinery:
//! - power iteration for the leading singular triple (spectral norm, rank-1
//!   nuclear-norm LMO),
//! - randomized range finder (Halko–Martinsson–Tropp) for the RankK
//!   compressor (paper §D, Remark 11 explicitly allows approximate SVD),
//! - one-sided Jacobi SVD for small matrices (exact singular values for the
//!   TopK-SVD compressor of Definition 10 and for test oracles).

use super::matmul::{matmul, matmul_at, matmul_bt, matvec, matvec_t};
use super::matrix::Matrix;
use super::qr::orthonormalize;
use crate::util::rng::Rng;

/// Leading singular triple `(sigma, u, v)` of `a` via power iteration on
/// `AᵀA` (deterministic start + random restart safeguard).
pub fn top_singular(a: &Matrix, iters: usize, rng: &mut Rng) -> (f32, Vec<f32>, Vec<f32>) {
    let n = a.cols;
    let mut v: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    normalize(&mut v);
    let mut sigma = 0.0f32;
    for _ in 0..iters.max(1) {
        let u = matvec(a, &v);
        let mut w = matvec_t(a, &u);
        let nrm = norm(&w);
        if nrm < 1e-20 {
            return (0.0, vec![0.0; a.rows], vec![0.0; a.cols]);
        }
        w.iter_mut().for_each(|x| *x /= nrm);
        v = w;
        sigma = nrm.sqrt();
    }
    let mut u = matvec(a, &v);
    let un = norm(&u);
    if un > 1e-20 {
        u.iter_mut().for_each(|x| *x /= un);
    }
    (sigma, u, v)
}

fn norm(x: &[f32]) -> f32 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
}

fn normalize(x: &mut [f32]) {
    let n = norm(x);
    if n > 1e-20 {
        x.iter_mut().for_each(|v| *v /= n);
    }
}

/// Randomized range finder: orthonormal `Q` (m×r) approximately spanning the
/// dominant column space of `a`, with `power` subspace iterations.
pub fn range_finder(a: &Matrix, rank: usize, power: usize, rng: &mut Rng) -> Matrix {
    let r = rank.min(a.rows).min(a.cols).max(1);
    let omega = Matrix::randn(a.cols, r, 1.0, rng);
    let mut y = matmul(a, &omega); // m×r
    let mut q = orthonormalize(&y);
    for _ in 0..power {
        let z = matmul_at(a, &q); // n×r = Aᵀ Q
        let zq = orthonormalize(&z);
        y = matmul(a, &zq);
        q = orthonormalize(&y);
    }
    q
}

/// Low-rank factors `(Q, B)` with `a ≈ Q·B`, `Q` m×r orthonormal, `B` r×n.
/// This is exactly what the RankK compressor transmits.
pub fn low_rank_approx(a: &Matrix, rank: usize, power: usize, rng: &mut Rng) -> (Matrix, Matrix) {
    let q = range_finder(a, rank, power, rng);
    let b = matmul_at(&q, a); // r×n
    (q, b)
}

/// Full SVD of a small matrix via one-sided Jacobi on columns:
/// returns `(u, s, v)` with `a = u · diag(s) · vᵀ`, singular values
/// descending. O(n² m) per sweep — fine for the ≤ few-hundred-dim layers
/// where exact spectra are needed.
pub fn jacobi_svd(a: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    let transpose = a.rows < a.cols;
    let work = if transpose { a.transpose() } else { a.clone() };
    let (m, n) = (work.rows, work.cols);
    let mut u = work; // will become U * diag(s)
    let mut v = Matrix::identity(n);
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 Gram entries
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let x = u.data[i * n + p] as f64;
                    let y = u.data[i * n + q] as f64;
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off += apq * apq;
                if apq.abs() < 1e-15 * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let x = u.data[i * n + p];
                    let y = u.data[i * n + q];
                    u.data[i * n + p] = cf * x - sf * y;
                    u.data[i * n + q] = sf * x + cf * y;
                }
                for i in 0..n {
                    let x = v.data[i * n + p];
                    let y = v.data[i * n + q];
                    v.data[i * n + p] = cf * x - sf * y;
                    v.data[i * n + q] = sf * x + cf * y;
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }
    // extract singular values = column norms of u
    let mut sv: Vec<(f32, usize)> = (0..n)
        .map(|j| {
            let mut s = 0.0f64;
            for i in 0..m {
                let x = u.data[i * n + j] as f64;
                s += x * x;
            }
            (s.sqrt() as f32, j)
        })
        .collect();
    // total_cmp: a NaN singular value (non-finite input) sorts
    // deterministically (above +inf in the descending order) instead of
    // panicking; the Jacobi sweep itself is NaN-tolerant (all rotation
    // predicates compare false). DESIGN.md §Non-finite values policy.
    sv.sort_by(|a, b| b.0.total_cmp(&a.0));
    let s: Vec<f32> = sv.iter().map(|(x, _)| *x).collect();
    let mut uu = Matrix::zeros(m, n);
    let mut vv = Matrix::zeros(n, n);
    for (newj, (sigma, oldj)) in sv.iter().enumerate() {
        let inv = if *sigma > 1e-20 { 1.0 / sigma } else { 0.0 };
        for i in 0..m {
            uu.data[i * n + newj] = u.data[i * n + oldj] * inv;
        }
        for i in 0..n {
            vv.data[i * n + newj] = v.data[i * n + oldj];
        }
    }
    if transpose {
        (vv, s, uu)
    } else {
        (uu, s, vv)
    }
}

/// Reconstruct `u[:, :k] * diag(s[:k]) * v[:, :k]ᵀ` — the TopK-SVD
/// compressor's decompressed value.
pub fn truncated_reconstruct(u: &Matrix, s: &[f32], v: &Matrix, k: usize) -> Matrix {
    let k = k.min(s.len());
    let mut us = Matrix::zeros(u.rows, k);
    for i in 0..u.rows {
        for j in 0..k {
            us.data[i * k + j] = u.at(i, j) * s[j];
        }
    }
    let mut vk = Matrix::zeros(v.rows, k);
    for i in 0..v.rows {
        for j in 0..k {
            vk.data[i * k + j] = v.at(i, j);
        }
    }
    matmul_bt(&us, &vk)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reconstruct(u: &Matrix, s: &[f32], v: &Matrix) -> Matrix {
        truncated_reconstruct(u, s, v, s.len())
    }

    #[test]
    fn jacobi_reconstructs() {
        let mut rng = Rng::new(21);
        for &(m, n) in &[(6, 4), (4, 6), (5, 5), (1, 3)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (u, s, v) = jacobi_svd(&a);
            let r = reconstruct(&u, &s, &v);
            assert!(r.max_abs_diff(&a) < 1e-3, "{m}x{n}: {}", r.max_abs_diff(&a));
            // descending
            for w in s.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
        }
    }

    #[test]
    fn jacobi_survives_nan_input() {
        // regression: the descending singular-value sort used
        // partial_cmp().unwrap() and aborted on the first NaN — a single
        // poisoned matrix entry must degrade, not panic
        let mut rng = Rng::new(25);
        let mut a = Matrix::randn(5, 4, 1.0, &mut rng);
        a.data[3] = f32::NAN;
        let (u, s, v) = jacobi_svd(&a);
        assert_eq!(s.len(), 4);
        assert_eq!((u.rows, u.cols), (5, 4));
        assert_eq!((v.rows, v.cols), (4, 4));
    }

    #[test]
    fn power_iteration_matches_jacobi() {
        let mut rng = Rng::new(22);
        let a = Matrix::randn(12, 8, 1.0, &mut rng);
        let (_, s, _) = jacobi_svd(&a);
        let (sigma, _, _) = top_singular(&a, 200, &mut rng);
        assert!((sigma - s[0]).abs() / s[0] < 1e-3, "{} vs {}", sigma, s[0]);
    }

    #[test]
    fn low_rank_exact_when_rank_full() {
        let mut rng = Rng::new(23);
        // build an exactly rank-3 matrix
        let l = Matrix::randn(10, 3, 1.0, &mut rng);
        let r = Matrix::randn(3, 7, 1.0, &mut rng);
        let a = matmul(&l, &r);
        let (q, b) = low_rank_approx(&a, 3, 2, &mut rng);
        let rec = matmul(&q, &b);
        assert!(rec.max_abs_diff(&a) < 1e-3);
    }

    #[test]
    fn low_rank_is_contraction() {
        let mut rng = Rng::new(24);
        let a = Matrix::randn(20, 20, 1.0, &mut rng);
        let (q, b) = low_rank_approx(&a, 5, 2, &mut rng);
        let rec = matmul(&q, &b);
        let err = rec.sub(&a).norm2_sq();
        assert!(err < a.norm2_sq()); // projection never expands
    }
}
