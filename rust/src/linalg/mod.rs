//! Dense linear algebra substrate (built from scratch — no ndarray/BLAS
//! offline): matrix type, blocked matmul, QR, power iteration, randomized
//! range finder, one-sided Jacobi SVD, Newton–Schulz orthogonalization, and
//! the non-Euclidean norm library the paper's geometry lives in.

pub mod matrix;
pub mod workspace;
pub mod matmul;
pub mod qr;
pub mod svd;
pub mod ns;
pub mod norms;

pub use matrix::Matrix;
pub use workspace::Workspace;
