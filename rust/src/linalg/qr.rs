//! Thin QR via modified Gram–Schmidt (with one re-orthogonalization pass —
//! "twice is enough"). Used by the randomized range finder behind the RankK
//! compressor.

use super::matrix::Matrix;

/// Orthonormalize the columns of `a` in place (thin Q, m×n with m ≥ n
/// expected; rank-deficient columns are replaced by zeros).
pub fn mgs_inplace(a: &mut Matrix) {
    let (m, n) = (a.rows, a.cols);
    for j in 0..n {
        // original column norm: used to detect rank deficiency (a column
        // whose post-orthogonalization norm collapses relative to its input
        // lies in the span of earlier columns and is zeroed, not normalized)
        let mut orig = 0.0f64;
        for row in 0..m {
            let v = a.data[row * n + j] as f64;
            orig += v * v;
        }
        let orig = orig.sqrt();
        for _pass in 0..2 {
            for i in 0..j {
                // r = q_i . a_j
                let mut r = 0.0f64;
                for row in 0..m {
                    r += a.data[row * n + i] as f64 * a.data[row * n + j] as f64;
                }
                let r = r as f32;
                for row in 0..m {
                    let qi = a.data[row * n + i];
                    a.data[row * n + j] -= r * qi;
                }
            }
        }
        let mut nrm = 0.0f64;
        for row in 0..m {
            let v = a.data[row * n + j] as f64;
            nrm += v * v;
        }
        let nrm = nrm.sqrt() as f32;
        if nrm as f64 > 1e-7 * orig.max(1e-30) && nrm > 1e-20 {
            let inv = 1.0 / nrm;
            for row in 0..m {
                a.data[row * n + j] *= inv;
            }
        } else {
            for row in 0..m {
                a.data[row * n + j] = 0.0;
            }
        }
    }
}

/// Thin QR returning fresh Q.
pub fn orthonormalize(a: &Matrix) -> Matrix {
    let mut q = a.clone();
    mgs_inplace(&mut q);
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul_at;
    use crate::util::rng::Rng;

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(11);
        let a = Matrix::randn(40, 8, 1.0, &mut rng);
        let q = orthonormalize(&a);
        let qtq = matmul_at(&q, &q);
        let eye = Matrix::identity(8);
        assert!(qtq.max_abs_diff(&eye) < 1e-4);
    }

    #[test]
    fn preserves_span() {
        // Q Qᵀ a_j == a_j for columns in the span
        let mut rng = Rng::new(12);
        let a = Matrix::randn(20, 5, 1.0, &mut rng);
        let q = orthonormalize(&a);
        let proj = crate::linalg::matmul::matmul(&q, &matmul_at(&q, &a));
        assert!(proj.max_abs_diff(&a) < 1e-3);
    }

    #[test]
    fn rank_deficient() {
        // duplicate column -> second copy zeroed, no NaNs
        let a = Matrix::from_vec(3, 2, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        let q = orthonormalize(&a);
        assert!(q.is_finite());
        let col1: f32 = (0..3).map(|i| q.at(i, 1).abs()).sum();
        assert!(col1 < 1e-6);
    }
}
