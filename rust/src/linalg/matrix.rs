//! Row-major f32 matrix. Vectors (LayerNorm gains etc.) are represented as
//! single-column matrices so every parameter group flows through one type.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries (optionally scaled).
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal_f32() * scale;
        }
        m
    }

    pub fn identity(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Column vector from a slice.
    pub fn col_vec(data: &[f32]) -> Self {
        Matrix { rows: data.len(), cols: 1, data: data.to_vec() }
    }

    #[inline]
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into a caller-provided buffer (hot path: no allocation).
    pub fn transpose_into(&self, t: &mut Matrix) {
        debug_assert_eq!((t.rows, t.cols), (self.cols, self.rows));
        // blocked transpose for cache friendliness on big layers
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    // -- in-place arithmetic (hot path: no allocation) ----------------------

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    pub fn scale(&mut self, a: f32) {
        self.data.iter_mut().for_each(|x| *x *= a);
    }

    /// `self += a * other`
    pub fn axpy(&mut self, a: f32, other: &Matrix) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += a * y;
        }
    }

    /// `self = a*self + b*other` (fused polynomial-combine, mirrors the L1
    /// axpby Pallas kernel).
    pub fn axpby(&mut self, a: f32, b: f32, other: &Matrix) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x = a * *x + b * y;
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    pub fn scaled(&self, a: f32) -> Matrix {
        let mut out = self.clone();
        out.scale(a);
        out
    }

    /// Frobenius / trace inner product `<self, other>`.
    pub fn dot(&self, other: &Matrix) -> f64 {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum()
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn norm2_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum()
    }

    pub fn norm2(&self) -> f64 {
        self.norm2_sq().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Max |a-b| between two matrices.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

/// Layer-structured parameter collection X = [X_1, …, X_p] (product space S).
pub type Layers = Vec<Matrix>;

/// Element-wise helpers over whole layer collections.
pub mod layers {
    use super::{Layers, Matrix};

    pub fn zeros_like(xs: &Layers) -> Layers {
        xs.iter().map(|x| Matrix::zeros(x.rows, x.cols)).collect()
    }

    pub fn clone_all(xs: &Layers) -> Layers {
        xs.to_vec()
    }

    pub fn axpy(dst: &mut Layers, a: f32, src: &Layers) {
        for (d, s) in dst.iter_mut().zip(src) {
            d.axpy(a, s);
        }
    }

    pub fn sub(a: &Layers, b: &Layers) -> Layers {
        a.iter().zip(b).map(|(x, y)| x.sub(y)).collect()
    }

    pub fn norm2_sq(xs: &Layers) -> f64 {
        xs.iter().map(|x| x.norm2_sq()).sum()
    }

    pub fn numel(xs: &Layers) -> usize {
        xs.iter().map(|x| x.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(13, 29, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(5, 7), a.at(7, 5));
    }

    #[test]
    fn axpby_matches_manual() {
        let mut rng = Rng::new(2);
        let mut a = Matrix::randn(4, 5, 1.0, &mut rng);
        let b = Matrix::randn(4, 5, 1.0, &mut rng);
        let expect = a.scaled(2.0).add(&b.scaled(-3.0));
        a.axpby(2.0, -3.0, &b);
        assert!(a.max_abs_diff(&expect) < 1e-6);
    }

    #[test]
    fn dot_and_norm() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.dot(&a), 30.0);
        assert!((a.norm2() - 30f64.sqrt()).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn layer_helpers() {
        let a = vec![Matrix::identity(2), Matrix::zeros(3, 1)];
        let z = layers::zeros_like(&a);
        assert_eq!(layers::numel(&a), 7);
        assert_eq!(layers::norm2_sq(&z), 0.0);
        let d = layers::sub(&a, &z);
        assert_eq!(layers::norm2_sq(&d), 2.0);
    }
}
