//! Reusable f32-buffer arena for the request hot path.
//!
//! The Newton–Schulz quintic loop, the LMO steps and the EF21 server/worker
//! state machines all need short-lived matrix temporaries every round.
//! Instead of hitting the allocator per step, they [`take`](Workspace::take)
//! buffers from a [`Workspace`] and [`give`](Workspace::give) them back;
//! after the first round every temporary is served from the pool
//! (asserted by `rust/tests/parallel.rs` via [`Workspace::fresh_allocs`]).
//!
//! Each OS thread in the leader/worker deployment owns its workspaces
//! (`ServerState` keeps one per LMO lane, `WorkerState` one), so no
//! synchronization is needed. Free functions that predate the arena
//! (`matmul_bt_into`, `newton_schulz`, `Lmo::step`) route through a
//! re-entrancy-safe thread-local pool via [`with_thread_workspace`].

use std::cell::RefCell;

use super::matrix::Matrix;

/// A pool of reusable `Vec<f32>` buffers.
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
    fresh: usize,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Check out a zero-filled `rows × cols` matrix, reusing the smallest
    /// pooled buffer whose capacity fits (allocating only on pool miss).
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let n = rows * cols;
        let mut best: Option<usize> = None;
        for (i, b) in self.pool.iter().enumerate() {
            if b.capacity() >= n {
                match best {
                    Some(j) if self.pool[j].capacity() <= b.capacity() => {}
                    _ => best = Some(i),
                }
            }
        }
        let mut data = match best {
            Some(i) => self.pool.swap_remove(i),
            None => {
                self.fresh += 1;
                Vec::with_capacity(n)
            }
        };
        data.clear();
        data.resize(n, 0.0);
        Matrix { rows, cols, data }
    }

    /// Return a matrix's buffer to the pool.
    pub fn give(&mut self, m: Matrix) {
        self.pool.push(m.data);
    }

    /// Number of genuine heap allocations this workspace has performed —
    /// stays flat once the hot loop is warmed up.
    pub fn fresh_allocs(&self) -> usize {
        self.fresh
    }

    /// Number of buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }

    /// Merge another workspace's buffers into this pool (used by the
    /// thread-local wrapper; `fresh` counts stay with their origin).
    fn absorb(&mut self, other: Workspace) {
        self.pool.extend(other.pool);
    }
}

thread_local! {
    static THREAD_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

/// Run `f` with this thread's shared workspace. Re-entrancy safe: the pool
/// is moved out for the duration of `f`, so a nested call simply starts
/// from an empty pool and both pools are merged afterwards (no `RefCell`
/// double-borrow is possible).
pub fn with_thread_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    let mut ws = THREAD_WS.with(|cell| cell.take());
    let out = f(&mut ws);
    THREAD_WS.with(|cell| cell.borrow_mut().absorb(ws));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reused() {
        let mut ws = Workspace::new();
        let mut a = ws.take(3, 4);
        assert!(a.data.iter().all(|&v| v == 0.0));
        a.fill(7.0);
        ws.give(a);
        assert_eq!(ws.fresh_allocs(), 1);
        // same-size re-take must reuse the buffer and re-zero it
        let b = ws.take(4, 3);
        assert_eq!(ws.fresh_allocs(), 1);
        assert!(b.data.iter().all(|&v| v == 0.0));
        ws.give(b);
        // a smaller request also reuses
        let c = ws.take(2, 2);
        assert_eq!(ws.fresh_allocs(), 1);
        ws.give(c);
        // a larger one allocates
        let d = ws.take(10, 10);
        assert_eq!(ws.fresh_allocs(), 2);
        ws.give(d);
    }

    #[test]
    fn best_fit_prefers_smallest_adequate() {
        let mut ws = Workspace::new();
        let small = ws.take(2, 2);
        let big = ws.take(8, 8);
        ws.give(big);
        ws.give(small);
        let again = ws.take(2, 2);
        assert!(again.data.capacity() < 64, "should pick the 4-elem buffer");
        assert_eq!(ws.fresh_allocs(), 2);
    }

    #[test]
    fn thread_local_is_reentrant() {
        let x = with_thread_workspace(|ws| {
            let a = ws.take(4, 4);
            // nested call while the outer workspace is checked out
            let inner = with_thread_workspace(|ws2| ws2.take(2, 2).numel());
            ws.give(a);
            inner
        });
        assert_eq!(x, 4);
    }
}
