//! The cautionary baselines from the paper's motivation (§2, §A.2):
//!
//! * **Naive DCGD** — distributed GD with biased compression and *no* error
//!   feedback: `x ← x − γ·(1/n)Σⱼ Cⱼ(∇fⱼ(x))`. Diverges exponentially on
//!   the Beznosikov three-quadratics (Example 1) — the whole reason error
//!   feedback exists.
//! * **EF14** (Seide et al. 2014) — the classic error-feedback fix.

use crate::compress::Compressor;
use crate::funcs::Objective;
use crate::linalg::matrix::{layers, Layers};
use crate::spec::IntoCompSpec;
use crate::util::rng::Rng;

/// Distributed compressed GD with NO error feedback.
pub struct NaiveDcgd {
    pub lr: f64,
    pub compressors: Vec<Vec<Box<dyn Compressor>>>, // [worker][layer]
    pub rng: Rng,
}

impl NaiveDcgd {
    pub fn new(
        obj: &dyn Objective,
        spec: impl IntoCompSpec,
        lr: f64,
        seed: u64,
    ) -> Result<Self, String> {
        let spec = spec.into_comp_spec()?;
        let shapes = obj.layer_shapes();
        let compressors = (0..obj.num_workers())
            .map(|_| spec.build_layers(&shapes))
            .collect();
        Ok(NaiveDcgd { lr, compressors, rng: Rng::new(seed) })
    }

    pub fn step(&mut self, obj: &dyn Objective, x: &mut Layers) {
        let n = obj.num_workers();
        let mut agg = layers::zeros_like(x);
        for j in 0..n {
            let g = obj.grad_j(j, x);
            for (i, gi) in g.iter().enumerate() {
                let msg = self.compressors[j][i].compress(gi, &mut self.rng);
                msg.add_into(&mut agg[i]);
            }
        }
        for (xi, ai) in x.iter_mut().zip(&agg) {
            xi.axpy(-(self.lr as f32) / n as f32, ai);
        }
    }
}

/// EF14 (classic error feedback): each worker accumulates the compression
/// error `eⱼ` and compresses `eⱼ + γ∇fⱼ`, transmitting the compressed
/// correction.
pub struct Ef14 {
    pub lr: f64,
    pub compressors: Vec<Vec<Box<dyn Compressor>>>,
    pub errors: Vec<Layers>,
    pub rng: Rng,
}

impl Ef14 {
    pub fn new(
        obj: &dyn Objective,
        spec: impl IntoCompSpec,
        lr: f64,
        seed: u64,
    ) -> Result<Self, String> {
        let spec = spec.into_comp_spec()?;
        let shapes = obj.layer_shapes();
        let n = obj.num_workers();
        let zeros: Layers = shapes
            .iter()
            .map(|&(m, nn)| crate::linalg::matrix::Matrix::zeros(m, nn))
            .collect();
        Ok(Ef14 {
            lr,
            compressors: (0..n).map(|_| spec.build_layers(&shapes)).collect(),
            errors: vec![zeros; n],
            rng: Rng::new(seed),
        })
    }

    pub fn step(&mut self, obj: &dyn Objective, x: &mut Layers) {
        let n = obj.num_workers();
        let mut agg = layers::zeros_like(x);
        for j in 0..n {
            let g = obj.grad_j(j, x);
            for (i, gi) in g.iter().enumerate() {
                // p = e + lr * g
                let mut p = self.errors[j][i].clone();
                p.axpy(self.lr as f32, gi);
                let msg = self.compressors[j][i].compress(&p, &mut self.rng);
                let sent = msg.decode();
                // e = p - sent
                p.axpy(-1.0, &sent);
                self.errors[j][i] = p;
                agg[i].axpy(1.0, &sent);
            }
        }
        for (xi, ai) in x.iter_mut().zip(&agg) {
            xi.axpy(-1.0 / n as f32, ai);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::funcs::ThreeQuadratics;

    /// The paper's §2 story in one test: Top1 DCGD explodes on the
    /// three-quadratics, EF14 and EF21 do not.
    #[test]
    fn naive_dcgd_diverges_ef_fixes_it() {
        let obj = ThreeQuadratics::new();
        let mut rng = Rng::new(1);
        let x0 = obj.init(&mut rng);

        // naive DCGD with Top1 (= top fraction 1/3 of 3 elements)
        let mut naive = NaiveDcgd::new(&obj, "top:0.3", 0.1, 5).unwrap();
        let mut x = x0.clone();
        for _ in 0..60 {
            naive.step(&obj, &mut x);
        }
        let naive_final = obj.loss(&x);

        let mut ef = Ef14::new(&obj, "top:0.3", 0.1, 5).unwrap();
        let mut y = x0.clone();
        for _ in 0..60 {
            ef.step(&obj, &mut y);
        }
        let ef_final = obj.loss(&y);

        let f0 = obj.loss(&x0);
        assert!(naive_final > 1e3 * f0, "naive should explode: {naive_final} vs {f0}");
        assert!(ef_final < f0, "EF14 should make progress: {ef_final} vs {f0}");
    }
}
