//! Optimizers: the paper's EF21-Muon (Algorithms 1–3) plus the baselines it
//! is measured against (uncompressed Gluon/Muon/Scion, AdamW, naive DCGD,
//! EF14, signSGD).

pub mod ef21;
pub mod baselines;
pub mod dcgd;

use crate::lmo::{Lmo, LmoKind};

/// Per-layer optimizer geometry: which LMO ball, and a relative radius
/// multiplier applied on top of the global schedule (the paper tunes
/// per-group learning rates; Table 4).
#[derive(Debug, Clone, Copy)]
pub struct LayerGeometry {
    pub lmo: LmoKind,
    pub radius_mult: f32,
}

impl LayerGeometry {
    pub fn lmo_for(&self) -> Lmo {
        Lmo::new(self.lmo)
    }
}

/// Learning-rate / radius schedule (nanoGPT-style warmup + cosine decay,
/// the same scheduler the paper adopts from Karpathy 2023).
#[derive(Debug, Clone)]
pub struct Schedule {
    pub base: f64,
    pub warmup: usize,
    pub total: usize,
    pub min_frac: f64,
    pub kind: ScheduleKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    Constant,
    WarmupCosine,
    /// `t_k = base / sqrt(K+1)` — the theory schedule of Theorems 4/17.
    InvSqrtTotal,
    /// `t_k = base / (K+1)^{3/4}` — the stochastic theory schedule (Thm 6).
    Theory34,
}

impl Schedule {
    pub fn constant(base: f64) -> Self {
        Schedule { base, warmup: 0, total: 0, min_frac: 1.0, kind: ScheduleKind::Constant }
    }

    pub fn warmup_cosine(base: f64, warmup: usize, total: usize, min_frac: f64) -> Self {
        Schedule { base, warmup, total, min_frac, kind: ScheduleKind::WarmupCosine }
    }

    pub fn at(&self, step: usize) -> f64 {
        match self.kind {
            ScheduleKind::Constant => self.base,
            ScheduleKind::InvSqrtTotal => self.base / ((self.total + 1) as f64).sqrt(),
            ScheduleKind::Theory34 => self.base / ((self.total + 1) as f64).powf(0.75),
            ScheduleKind::WarmupCosine => {
                if self.warmup > 0 && step < self.warmup {
                    return self.base * (step + 1) as f64 / self.warmup as f64;
                }
                if self.total <= self.warmup {
                    return self.base;
                }
                let t = (step - self.warmup) as f64 / (self.total - self.warmup) as f64;
                let t = t.clamp(0.0, 1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                self.base * (self.min_frac + (1.0 - self.min_frac) * cos)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_shapes() {
        let s = Schedule::warmup_cosine(1.0, 10, 110, 0.1);
        assert!(s.at(0) < s.at(9)); // warming up
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(109) < 0.12); // decayed to ~min_frac
        assert!(s.at(50) < s.at(20)); // monotone decay after warmup
        let c = Schedule::constant(0.5);
        assert_eq!(c.at(0), 0.5);
        assert_eq!(c.at(1000), 0.5);
    }

    // NOTE: the per-layer compressor construction (and its degenerate-shape
    // fallback, locked by `compressor_fallback_for_vectors`) moved to the
    // typed `crate::spec::CompSpec` — see `spec::comp` and its tests.
}
