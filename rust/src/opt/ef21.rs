//! EF21-Muon (paper Algorithms 1–3): the layer-wise server and worker state
//! machines, plus a sequential single-process driver used by tests, the
//! rate benches and the divergence demo. The threaded leader/worker
//! deployment in [`crate::dist`] runs *these same state machines* over
//! channels — the protocol logic lives here, transport lives there.
//!
//! Server state:  X (model), W (EF21-P shift), G = (1/n)ΣGⱼ (gradient
//!                estimator), per-layer LMOs.
//! Worker state:  W (mirror of the shift), Mⱼ (momentum), Gⱼ (local
//!                gradient estimator), per-layer compressors.
//!
//! Compression is **bidirectional**: the w2s uplink is compressed by each
//! worker's EF21 compressor, the s2w broadcast by the server's EF21-P
//! compressor (`server_spec` — any contractive spec, not just `id`). The
//! per-worker s2w error-feedback state is the shift Wⱼ each worker holds;
//! because the broadcast stream is total-ordered and every worker applies
//! every message, all Wⱼ coincide bit-for-bit with the server's W, so the
//! server stores that state once ([`state_consistency`] asserts this).
//!
//! One iteration (Algorithm 3):
//!   server:  Xᵢ ← LMO_{B(Xᵢ,tᵢ)}(Gᵢ);  Sᵢ = C(Xᵢ−Wᵢ);  Wᵢ += Sᵢ;  bcast S
//!   worker:  Wᵢ += Sᵢ;  Mᵢⱼ ← (1−β)Mᵢⱼ + β∇ᵢf_j(W;ξ);
//!            Rᵢⱼ = Cⱼ(Mᵢⱼ−Gᵢⱼ);  Gᵢⱼ += Rᵢⱼ;  send R
//!   server:  Gᵢ += (1/n)ΣⱼRᵢⱼ
//!
//! With identity compressors and n=1 this reduces exactly to Gluon
//! (→ Muon/Scion under spectral/ℓ∞ norms) — asserted in tests.

use crate::compress::{Compressor, Message};
use crate::funcs::Objective;
use crate::linalg::matrix::{layers, Layers, Matrix};
use crate::linalg::workspace::Workspace;
use crate::lmo::{Lmo, LmoKind, SpectralEngine};
use crate::opt::{LayerGeometry, Schedule};
use crate::spec::{CompSpec, IntoCompSpec};
use crate::util::rng::Rng;

/// Layer collections below this total element count run the LMO pass
/// sequentially — thread-spawn latency beats the fan-out win.
const PAR_LAYER_MIN_NUMEL: usize = 1 << 15;

/// Spectral-engine hook: given a layer gradient, optionally return its
/// orthogonalization from an external engine (the PJRT NS artifact served
/// by `dist::server`); `None` falls back to the native Newton–Schulz.
pub type SpectralHook<'a> = &'a dyn Fn(&Matrix) -> Option<Matrix>;

/// Server half of EF21-Muon.
pub struct ServerState {
    pub x: Layers,
    pub w: Layers,
    pub g: Layers,
    pub lmos: Vec<Lmo>,
    pub geometry: Vec<LayerGeometry>,
    pub compressors: Vec<Box<dyn Compressor>>,
    pub n_workers: usize,
    pub rng: Rng,
    /// scratch: decoded aggregate per layer (avoids per-step allocation)
    agg: Layers,
    /// per-lane buffer arenas: lane 0 also serves the broadcast scratch;
    /// the parallel LMO fan-out hands one lane to each worker thread so
    /// warmed buffers persist across rounds.
    ws: Vec<Workspace>,
}

impl ServerState {
    pub fn new(
        x0: Layers,
        geometry: Vec<LayerGeometry>,
        server_spec: &CompSpec,
        n_workers: usize,
        seed: u64,
    ) -> Self {
        let shapes: Vec<(usize, usize)> = x0.iter().map(|m| (m.rows, m.cols)).collect();
        let compressors = server_spec.build_layers(&shapes);
        let lmos = geometry.iter().map(|g| g.lmo_for()).collect();
        let agg = layers::zeros_like(&x0);
        let lanes = crate::util::threads::num_threads().max(1);
        ServerState {
            w: x0.clone(),
            g: layers::zeros_like(&x0),
            x: x0,
            lmos,
            geometry,
            compressors,
            n_workers,
            rng: Rng::with_stream(seed, 0x5e7),
            agg,
            ws: (0..lanes).map(|_| Workspace::new()).collect(),
        }
    }

    /// Override the initial gradient estimator G⁰ (the theory initializes
    /// it to the average of worker G⁰ⱼ; callers pass that average here).
    pub fn set_g0(&mut self, g0: Layers) {
        self.g = g0;
    }

    /// Algorithm line 4: the LMO-type step `Xᵢ ← LMO_{B(Xᵢ, tᵢ)}(Gᵢ)` with
    /// per-layer radii `t · radius_mult`. Layers are fanned out across OS
    /// threads when the model is large enough; per-layer RNG streams are
    /// pre-split deterministically, so the trajectory is bit-identical at
    /// every thread count.
    pub fn lmo_step(&mut self, t: f64) {
        self.lmo_step_with(t, None);
    }

    /// [`ServerState::lmo_step`] with an optional external spectral engine
    /// (the PJRT Newton–Schulz artifact; see `dist::server`). Hooked runs
    /// stay sequential — the engine serializes on its service thread anyway.
    pub fn lmo_step_with(&mut self, t: f64, hook: Option<SpectralHook<'_>>) {
        let p = self.x.len();
        // derive one RNG per layer up front: consumption is independent of
        // the threading layout, keeping distributed runs reproducible
        let mut rngs: Vec<Rng> = (0..p).map(|i| self.rng.split(0x1a0 + i as u64)).collect();
        let radii: Vec<f32> = (0..p)
            .map(|i| (t * self.geometry[i].radius_mult as f64) as f32)
            .collect();
        let numel: usize = self.x.iter().map(|m| m.numel()).sum();
        let nt = crate::util::threads::num_threads().min(self.ws.len()).min(p).max(1);
        if hook.is_some() || nt == 1 || numel < PAR_LAYER_MIN_NUMEL {
            let ws = &mut self.ws[0];
            for i in 0..p {
                let g = &self.g[i];
                let lmo = &self.lmos[i];
                let external = match hook {
                    Some(h)
                        if lmo.kind == LmoKind::Spectral
                            && lmo.engine == SpectralEngine::Native =>
                    {
                        h(g)
                    }
                    _ => None,
                };
                let step = match external {
                    Some(mut o) => {
                        o.scale(-radii[i]);
                        o
                    }
                    None => lmo.step_ws(g, radii[i], &mut rngs[i], ws),
                };
                self.x[i].axpy(1.0, &step);
                ws.give(step);
            }
            return;
        }
        // parallel fan-out: contiguous layer chunks, one arena lane each
        let chunk = (p + nt - 1) / nt;
        let xs = self.x.chunks_mut(chunk);
        let gs = self.g.chunks(chunk);
        let ls = self.lmos.chunks(chunk);
        let ts = radii.chunks(chunk);
        let rs = rngs.chunks_mut(chunk);
        let wss = self.ws.iter_mut();
        std::thread::scope(|s| {
            for ((((x, g), l), (ti, r)), ws) in xs.zip(gs).zip(ls).zip(ts.zip(rs)).zip(wss) {
                s.spawn(move || {
                    // lanes keep nested matmuls single-threaded (no nt×nt
                    // oversubscription)
                    crate::util::threads::mark_parallel_region(|| {
                        for i in 0..x.len() {
                            let step = l[i].step_ws(&g[i], ti[i], &mut r[i], ws);
                            x[i].axpy(1.0, &step);
                            ws.give(step);
                        }
                    });
                });
            }
        });
    }

    /// Algorithm lines 5–7 (the EF21-P s2w half): compress the shifted
    /// model `C(X − W)`, advance the shift `W += C(X − W)`, return the
    /// broadcast messages (one per layer). With a non-`id` server
    /// compressor the broadcast is strictly cheaper than dense and the
    /// compression error is re-absorbed next round through the shift —
    /// the same error-feedback recursion as the uplink, mirrored. The
    /// `X − W` residual scratch is served from the lane-0 arena (no
    /// per-round allocation).
    pub fn broadcast(&mut self) -> Vec<Message> {
        let mut msgs = Vec::with_capacity(self.x.len());
        let ws = &mut self.ws[0];
        for i in 0..self.x.len() {
            let mut diff = ws.take(self.x[i].rows, self.x[i].cols);
            diff.data.copy_from_slice(&self.x[i].data);
            diff.axpy(-1.0, &self.w[i]);
            let msg = self.compressors[i].compress(&diff, &mut self.rng);
            ws.give(diff);
            msg.add_into(&mut self.w[i]);
            msgs.push(msg);
        }
        msgs
    }

    /// Algorithm line 19: absorb the workers' compressed gradient residuals
    /// `Gᵢ += (1/n) Σⱼ Rᵢⱼ`.
    pub fn absorb(&mut self, worker_msgs: &[Vec<Message>]) {
        assert_eq!(worker_msgs.len(), self.n_workers);
        let inv = 1.0 / self.n_workers as f32;
        for i in 0..self.g.len() {
            let agg = &mut self.agg[i];
            agg.fill(0.0);
            for msgs in worker_msgs {
                msgs[i].add_into(agg);
            }
            self.g[i].axpy(inv, agg);
        }
    }

    /// [`ServerState::absorb`] over a partial quorum: slot `j` is `None`
    /// for a worker whose reply was skipped at a straggler deadline. Only
    /// the replies that landed are summed — the missing workers' share of
    /// the estimator is simply left untouched (their local Gⱼ did not
    /// advance either, if they dropped the round; if they merely straggled,
    /// [`ServerState::absorb_late`] folds their residual in when it
    /// arrives). With every slot `Some` the summation order is identical to
    /// `absorb`, so a full quorum is bit-identical to the lock-step path.
    pub fn absorb_quorum(&mut self, worker_msgs: &[Option<Vec<Message>>]) {
        assert_eq!(worker_msgs.len(), self.n_workers);
        let inv = 1.0 / self.n_workers as f32;
        for i in 0..self.g.len() {
            let agg = &mut self.agg[i];
            agg.fill(0.0);
            for msgs in worker_msgs.iter().flatten() {
                msgs[i].add_into(agg);
            }
            self.g[i].axpy(inv, agg);
        }
    }

    /// Fold one straggler's late residual into the estimator:
    /// `Gᵢ += (1/n) Rⱼ`. The worker advanced its local Gⱼ when it computed
    /// the reply, so this restores the `G = (1/n) Σⱼ Gⱼ` invariant its
    /// skipped round left one term short.
    pub fn absorb_late(&mut self, msgs: &[Message]) {
        let inv = 1.0 / self.n_workers as f32;
        for i in 0..self.g.len() {
            let agg = &mut self.agg[i];
            agg.fill(0.0);
            msgs[i].add_into(agg);
            self.g[i].axpy(inv, agg);
        }
    }

    /// Remove layer `i` from every parallel per-layer vector and return its
    /// `(X, W, G)` triple — the server half of a cluster layer migration.
    /// The caller guarantees no round is in flight, so the triple is the
    /// layer's exact post-round state; re-inserting it bitwise via
    /// [`ServerState::accept_layer`] on another server continues the
    /// layer's trajectory unchanged.
    pub fn release_layer(&mut self, i: usize) -> (Matrix, Matrix, Matrix) {
        self.lmos.remove(i);
        self.geometry.remove(i);
        self.compressors.remove(i);
        self.agg.remove(i);
        (self.x.remove(i), self.w.remove(i), self.g.remove(i))
    }

    /// Insert a migrated layer at index `i` with its EF21 state, geometry
    /// and a fresh compressor for its shape (compressors are stateless
    /// across layers, so a rebuilt one is exact; the LMO is rebuilt from
    /// the geometry). Inverse of [`ServerState::release_layer`].
    pub fn accept_layer(
        &mut self,
        i: usize,
        x: Matrix,
        w: Matrix,
        g: Matrix,
        geom: LayerGeometry,
        comp: Box<dyn Compressor>,
    ) {
        self.lmos.insert(i, geom.lmo_for());
        self.geometry.insert(i, geom);
        self.compressors.insert(i, comp);
        self.agg.insert(i, Matrix::zeros(x.rows, x.cols));
        self.x.insert(i, x);
        self.w.insert(i, w);
        self.g.insert(i, g);
    }

    /// ‖G‖ dual-norm diagnostics (per layer).
    pub fn grad_estimator_norms(&mut self) -> Vec<f64> {
        let mut rng = self.rng.split(0xd1a6);
        (0..self.g.len())
            .map(|i| self.lmos[i].dual_norm(&self.g[i], &mut rng))
            .collect()
    }
}

/// Worker half of EF21-Muon.
pub struct WorkerState {
    pub id: usize,
    pub w: Layers,
    pub m: Layers,
    pub g: Layers,
    pub beta: f32,
    pub compressors: Vec<Box<dyn Compressor>>,
    pub rng: Rng,
    /// per-worker buffer arena (residual scratch in the round loop)
    ws: Workspace,
}

impl WorkerState {
    pub fn new(id: usize, x0: &Layers, worker_spec: &CompSpec, beta: f32, seed: u64) -> Self {
        let shapes: Vec<(usize, usize)> = x0.iter().map(|m| (m.rows, m.cols)).collect();
        WorkerState {
            id,
            w: x0.clone(),
            m: layers::zeros_like(x0),
            g: layers::zeros_like(x0),
            beta,
            compressors: worker_spec.build_layers(&shapes),
            rng: Rng::with_stream(seed, 0x1000 + id as u64),
            ws: Workspace::new(),
        }
    }

    /// Initialization per the theorems: M⁰ⱼ = G⁰ⱼ = ∇fⱼ(X⁰;ξ⁰). Returns the
    /// initial Gⱼ for the server to average into G⁰.
    pub fn init_estimators(&mut self, grad0: Layers) -> Layers {
        self.m = grad0.clone();
        self.g = grad0.clone();
        grad0
    }

    /// Algorithm line 11: apply the server broadcast to the local shift.
    pub fn apply_broadcast(&mut self, msgs: &[Message]) {
        for (wi, msg) in self.w.iter_mut().zip(msgs) {
            msg.add_into(wi);
        }
    }

    /// Algorithm lines 12–14: momentum update with the fresh stochastic
    /// gradient (computed *at the updated* W), compress the shifted
    /// momentum, advance Gⱼ, return the uplink messages.
    pub fn local_step(&mut self, grad_at_w: &Layers) -> Vec<Message> {
        let beta = self.beta;
        let mut msgs = Vec::with_capacity(self.w.len());
        for i in 0..self.w.len() {
            self.m[i].axpby(1.0 - beta, beta, &grad_at_w[i]);
            let mut resid = self.ws.take(self.m[i].rows, self.m[i].cols);
            resid.data.copy_from_slice(&self.m[i].data);
            resid.axpy(-1.0, &self.g[i]);
            let msg = self.compressors[i].compress(&resid, &mut self.rng);
            self.ws.give(resid);
            msg.add_into(&mut self.g[i]);
            msgs.push(msg);
        }
        msgs
    }

    /// Remove layer `i` and return its `(W, M, G)` triple — the worker half
    /// of a cluster layer migration (see [`ServerState::release_layer`]).
    pub fn release_layer(&mut self, i: usize) -> (Matrix, Matrix, Matrix) {
        self.compressors.remove(i);
        (self.w.remove(i), self.m.remove(i), self.g.remove(i))
    }

    /// Insert a migrated layer at index `i` with its EF21 state and a fresh
    /// compressor. Inverse of [`WorkerState::release_layer`].
    pub fn accept_layer(
        &mut self,
        i: usize,
        w: Matrix,
        m: Matrix,
        g: Matrix,
        comp: Box<dyn Compressor>,
    ) {
        self.compressors.insert(i, comp);
        self.w.insert(i, w);
        self.m.insert(i, m);
        self.g.insert(i, g);
    }
}

/// Per-step telemetry from the sequential driver.
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    pub grad_norm2: f64,
    /// w2s bytes sent by ONE worker this step (paper reports per-worker).
    pub w2s_bytes: usize,
    /// s2w bytes broadcast this step.
    pub s2w_bytes: usize,
    pub radius: f64,
}

/// Sequential single-process EF21-Muon over an [`Objective`] — Algorithm 3
/// verbatim (Algorithm 2 = `beta == 1.0` + `stochastic == false`).
pub struct Ef21MuonSeq {
    pub server: ServerState,
    pub workers: Vec<WorkerState>,
    pub schedule: Schedule,
    pub stochastic: bool,
    pub step: usize,
    pub total_w2s_bytes: u64,
    pub total_s2w_bytes: u64,
}

impl Ef21MuonSeq {
    /// Build the sequential driver. The compressor arguments accept either
    /// typed [`CompSpec`] descriptors or spec strings — strings are parsed
    /// exactly once here (the [`IntoCompSpec`] boundary), never per layer.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        obj: &dyn Objective,
        geometry: Vec<LayerGeometry>,
        worker_spec: impl IntoCompSpec,
        server_spec: impl IntoCompSpec,
        beta: f32,
        schedule: Schedule,
        stochastic: bool,
        seed: u64,
    ) -> Result<Self, String> {
        let worker_spec = worker_spec.into_comp_spec()?;
        let server_spec = server_spec.into_comp_spec()?;
        let mut rng = Rng::new(seed);
        let x0 = obj.init(&mut rng);
        let n = obj.num_workers();
        let mut server = ServerState::new(x0.clone(), geometry, &server_spec, n, seed);
        let mut workers = Vec::with_capacity(n);
        let mut g0_avg = layers::zeros_like(&x0);
        for j in 0..n {
            let mut wkr = WorkerState::new(j, &x0, &worker_spec, beta, seed);
            let grad0 = if stochastic {
                obj.stoch_grad_j(j, &x0, &mut wkr.rng)
            } else {
                obj.grad_j(j, &x0)
            };
            let gj = wkr.init_estimators(grad0);
            layers::axpy(&mut g0_avg, 1.0 / n as f32, &gj);
            workers.push(wkr);
        }
        server.set_g0(g0_avg);
        Ok(Ef21MuonSeq {
            server,
            workers,
            schedule,
            stochastic,
            step: 0,
            total_w2s_bytes: 0,
            total_s2w_bytes: 0,
        })
    }

    /// One full round of Algorithm 3. Returns telemetry.
    pub fn step(&mut self, obj: &dyn Objective) -> StepStats {
        let t = self.schedule.at(self.step);
        self.server.lmo_step(t);
        let bcast = self.server.broadcast();
        let s2w: usize = bcast.iter().map(|m| m.wire_bytes()).sum();

        let mut all_msgs = Vec::with_capacity(self.workers.len());
        let mut w2s_per_worker = 0usize;
        for wkr in self.workers.iter_mut() {
            wkr.apply_broadcast(&bcast);
            let grad = if self.stochastic {
                obj.stoch_grad_j(wkr.id, &wkr.w, &mut wkr.rng)
            } else {
                obj.grad_j(wkr.id, &wkr.w)
            };
            let msgs = wkr.local_step(&grad);
            w2s_per_worker = msgs.iter().map(|m| m.wire_bytes()).sum();
            all_msgs.push(msgs);
        }
        self.server.absorb(&all_msgs);

        self.total_w2s_bytes += w2s_per_worker as u64;
        self.total_s2w_bytes += s2w as u64;
        let loss = obj.loss(&self.server.x);
        let grad_norm2 = layers::norm2_sq(&obj.grad(&self.server.x));
        let stats = StepStats {
            step: self.step,
            loss,
            grad_norm2,
            w2s_bytes: w2s_per_worker,
            s2w_bytes: s2w,
            radius: t,
        };
        self.step += 1;
        stats
    }

    /// Run `k` steps, returning the telemetry trace.
    pub fn run(&mut self, obj: &dyn Objective, k: usize) -> Vec<StepStats> {
        (0..k).map(|_| self.step(obj)).collect()
    }

    pub fn params(&self) -> &Layers {
        &self.server.x
    }
}

/// Invariant check used by property tests: after a full round, every
/// worker's shift W must equal the server's W bit-for-bit (they apply the
/// same compressed messages), and likewise the server's G must equal the
/// average of worker Gⱼ.
pub fn state_consistency(seq: &Ef21MuonSeq) -> Result<(), String> {
    for wkr in &seq.workers {
        for (i, (sw, ww)) in seq.server.w.iter().zip(&wkr.w).enumerate() {
            if sw.max_abs_diff(ww) > 0.0 {
                return Err(format!("worker {} layer {i}: W mismatch", wkr.id));
            }
        }
    }
    let n = seq.workers.len() as f32;
    for i in 0..seq.server.g.len() {
        let mut avg = Matrix::zeros(seq.server.g[i].rows, seq.server.g[i].cols);
        for wkr in &seq.workers {
            avg.axpy(1.0 / n, &wkr.g[i]);
        }
        if avg.max_abs_diff(&seq.server.g[i]) > 1e-5 {
            return Err(format!(
                "layer {i}: server G != avg worker G (diff {})",
                avg.max_abs_diff(&seq.server.g[i])
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::funcs::Quadratics;
    use crate::lmo::LmoKind;

    fn geom(n_layers: usize, kind: LmoKind) -> Vec<LayerGeometry> {
        vec![LayerGeometry { lmo: kind, radius_mult: 1.0 }; n_layers]
    }

    #[test]
    fn uncompressed_deterministic_converges() {
        let mut rng = Rng::new(301);
        let q = Quadratics::new(4, 12, 1.0, 0.0, &mut rng);
        let mut opt = Ef21MuonSeq::new(
            &q,
            geom(1, LmoKind::Euclidean),
            "id",
            "id",
            1.0,
            Schedule::constant(0.05),
            false,
            7,
        )
        .unwrap();
        let trace = opt.run(&q, 400);
        let f0 = trace[0].grad_norm2;
        let fk = trace.last().unwrap().grad_norm2;
        assert!(fk < 1e-3 * f0, "grad_norm2 {f0} -> {fk}");
    }

    #[test]
    fn compressed_matches_uncompressed_eventually() {
        let mut rng = Rng::new(302);
        let q = Quadratics::new(3, 10, 0.5, 0.0, &mut rng);
        let mut opt = Ef21MuonSeq::new(
            &q,
            geom(1, LmoKind::Euclidean),
            "top:0.3",
            "id",
            1.0,
            Schedule::constant(0.03),
            false,
            7,
        )
        .unwrap();
        let trace = opt.run(&q, 1200);
        assert!(trace.last().unwrap().grad_norm2 < 2e-3, "{}", trace.last().unwrap().grad_norm2);
        // compressed uplink must actually be smaller than dense
        let dense = 10 * 4 + crate::compress::HEADER_BYTES;
        assert!(trace[0].w2s_bytes < dense);
    }

    #[test]
    fn state_stays_consistent() {
        let mut rng = Rng::new(303);
        let q = Quadratics::new(3, 8, 1.0, 0.1, &mut rng);
        let mut opt = Ef21MuonSeq::new(
            &q,
            geom(1, LmoKind::SignLInf),
            "top:0.25",
            "top:0.5",
            0.9,
            Schedule::constant(0.01),
            true,
            11,
        )
        .unwrap();
        for _ in 0..25 {
            opt.step(&q);
            state_consistency(&opt).unwrap();
        }
    }

    /// Run one round's LMO/broadcast/local-step phases by hand so the test
    /// controls the absorb call.
    fn drive_round_collect(opt: &mut Ef21MuonSeq, q: &Quadratics) -> Vec<Vec<Message>> {
        let t = opt.schedule.at(opt.step);
        opt.server.lmo_step(t);
        let bcast = opt.server.broadcast();
        let mut all = Vec::with_capacity(opt.workers.len());
        for wkr in opt.workers.iter_mut() {
            wkr.apply_broadcast(&bcast);
            let grad = q.grad_j(wkr.id, &wkr.w);
            all.push(wkr.local_step(&grad));
        }
        opt.step += 1;
        all
    }

    #[test]
    fn absorb_quorum_full_set_is_bitwise_absorb() {
        let mut rng = Rng::new(305);
        let q = Quadratics::new(3, 8, 1.0, 0.1, &mut rng);
        let mk = || {
            Ef21MuonSeq::new(
                &q,
                geom(1, LmoKind::Euclidean),
                "top:0.5",
                "id",
                0.9,
                Schedule::constant(0.02),
                false,
                21,
            )
            .unwrap()
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..5 {
            let all_a = drive_round_collect(&mut a, &q);
            let all_b = drive_round_collect(&mut b, &q);
            a.server.absorb(&all_a);
            let full: Vec<Option<Vec<Message>>> = all_b.into_iter().map(Some).collect();
            b.server.absorb_quorum(&full);
            for i in 0..a.server.g.len() {
                assert_eq!(
                    a.server.g[i].max_abs_diff(&b.server.g[i]),
                    0.0,
                    "full quorum must be bit-identical to absorb (layer {i})"
                );
            }
        }
    }

    #[test]
    fn absorb_quorum_plus_late_reconstructs_full_absorb() {
        let mut rng = Rng::new(306);
        let q = Quadratics::new(3, 8, 1.0, 0.1, &mut rng);
        let mk = || {
            Ef21MuonSeq::new(
                &q,
                geom(1, LmoKind::Euclidean),
                "top:0.5",
                "id",
                0.9,
                Schedule::constant(0.02),
                false,
                22,
            )
            .unwrap()
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..4 {
            let all_a = drive_round_collect(&mut a, &q);
            let all_b = drive_round_collect(&mut b, &q);
            a.server.absorb(&all_a);
            // b's worker 2 straggles: its round absorbs without it, then
            // its residual lands late — the estimator must catch back up
            let quorum: Vec<Option<Vec<Message>>> = all_b
                .iter()
                .enumerate()
                .map(|(j, m)| if j == 2 { None } else { Some(m.clone()) })
                .collect();
            b.server.absorb_quorum(&quorum);
            b.server.absorb_late(&all_b[2]);
            for i in 0..a.server.g.len() {
                assert!(
                    a.server.g[i].max_abs_diff(&b.server.g[i]) < 1e-5,
                    "quorum + late must reconstruct the full absorb (layer {i})"
                );
            }
        }
    }

    #[test]
    fn reduces_to_gluon_when_uncompressed_single_node() {
        // EF21-Muon with ID compressors, n=1, beta=1, deterministic ==
        // Gluon: X^{k+1} = LMO_{B(X^k,t)}(∇f(W^k)) with W == X.
        let mut rng = Rng::new(304);
        let q = Quadratics::new(1, 6, 0.0, 0.0, &mut rng);
        let mut opt = Ef21MuonSeq::new(
            &q,
            geom(1, LmoKind::SignLInf),
            "id",
            "id",
            1.0,
            Schedule::constant(0.02),
            false,
            3,
        )
        .unwrap();
        // manual Gluon replay
        let mut x = opt.server.x.clone();
        let mut g_prev = q.grad_j(0, &x); // G^0 = grad at X^0
        for _ in 0..5 {
            opt.step(&q);
            // Gluon step uses G^k (gradient at previous W = X before step)
            for v in g_prev[0].data.iter_mut() {
                *v = -0.02 * v.signum();
            }
            x[0].axpy(1.0, &g_prev[0]);
            assert!(x[0].max_abs_diff(&opt.server.x[0]) < 1e-6);
            g_prev = q.grad_j(0, &x);
        }
    }
}
