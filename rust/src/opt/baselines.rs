//! Baseline optimizers the paper compares against (AdamW for the first/last
//! layers in Muon's standard recipe, SGD-momentum and signSGD for the rate
//! benches). All operate on layer collections.

use crate::linalg::matrix::{layers, Layers};

/// Plain distributed GD / SGD with heavy-ball momentum.
pub struct Sgdm {
    pub lr: f64,
    pub beta: f32,
    m: Layers,
}

impl Sgdm {
    pub fn new(x0: &Layers, lr: f64, beta: f32) -> Self {
        Sgdm { lr, beta, m: layers::zeros_like(x0) }
    }

    pub fn step(&mut self, x: &mut Layers, grads: &Layers) {
        for i in 0..x.len() {
            self.m[i].axpby(self.beta, 1.0 - self.beta, &grads[i]);
            x[i].axpy(-(self.lr as f32), &self.m[i]);
        }
    }
}

/// signSGD (Bernstein et al. 2018) = ℓ∞ LMO steps without error feedback.
pub struct SignSgd {
    pub lr: f64,
}

impl SignSgd {
    pub fn new(lr: f64) -> Self {
        SignSgd { lr }
    }

    pub fn step(&mut self, x: &mut Layers, grads: &Layers) {
        let t = self.lr as f32;
        for (xi, gi) in x.iter_mut().zip(grads) {
            for (xv, gv) in xi.data.iter_mut().zip(&gi.data) {
                *xv -= t * gv.signum();
            }
        }
    }
}

/// AdamW (Loshchilov & Hutter 2019) — the paper's optimizer for the
/// embedding/output layers in the standard Muon recipe, and the classical
/// baseline the Muon family displaces.
pub struct AdamW {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    m: Layers,
    v: Layers,
    t: usize,
}

impl AdamW {
    pub fn new(x0: &Layers, lr: f64) -> Self {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.95, // nanoGPT convention
            eps: 1e-8,
            weight_decay: 0.0,
            m: layers::zeros_like(x0),
            v: layers::zeros_like(x0),
            t: 0,
        }
    }

    pub fn step(&mut self, x: &mut Layers, grads: &Layers) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..x.len() {
            let (m, v) = (&mut self.m[i], &mut self.v[i]);
            for ((xv, gv), (mv, vv)) in x[i]
                .data
                .iter_mut()
                .zip(&grads[i].data)
                .zip(m.data.iter_mut().zip(v.data.iter_mut()))
            {
                let g = *gv as f64;
                let mm = self.beta1 * *mv as f64 + (1.0 - self.beta1) * g;
                let vvv = self.beta2 * *vv as f64 + (1.0 - self.beta2) * g * g;
                *mv = mm as f32;
                *vv = vvv as f32;
                let mhat = mm / bc1;
                let vhat = vvv / bc2;
                let upd = self.lr * (mhat / (vhat.sqrt() + self.eps))
                    + self.lr * self.weight_decay * *xv as f64;
                *xv -= upd as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::funcs::{Objective, Quadratics};
    use crate::util::rng::Rng;

    fn run_to_convergence(stepper: &mut dyn FnMut(&mut Layers, &Layers), steps: usize) -> f64 {
        let mut rng = Rng::new(401);
        let q = Quadratics::new(2, 8, 0.5, 0.0, &mut rng);
        let mut x = q.init(&mut rng);
        for _ in 0..steps {
            let g = q.grad(&x);
            stepper(&mut x, &g);
        }
        layers::norm2_sq(&q.grad(&x))
    }

    #[test]
    fn sgdm_converges() {
        let mut rng = Rng::new(402);
        let q = Quadratics::new(2, 8, 0.5, 0.0, &mut rng);
        let x0 = q.init(&mut rng);
        let mut opt = Sgdm::new(&x0, 0.1, 0.9);
        let g2 = run_to_convergence(&mut |x, g| opt.step(x, g), 500);
        assert!(g2 < 1e-6, "{g2}");
    }

    #[test]
    fn adamw_converges() {
        let mut rng = Rng::new(403);
        let q = Quadratics::new(2, 8, 0.5, 0.0, &mut rng);
        let x0 = q.init(&mut rng);
        let mut opt = AdamW::new(&x0, 0.05);
        let g2 = run_to_convergence(&mut |x, g| opt.step(x, g), 800);
        assert!(g2 < 1e-4, "{g2}");
    }

    #[test]
    fn signsgd_reaches_neighborhood() {
        let mut opt = SignSgd::new(0.01);
        let g2 = run_to_convergence(&mut |x, g| opt.step(x, g), 500);
        // constant-stepsize signSGD stalls in an O(lr·d) neighborhood
        assert!(g2 < 0.1, "{g2}");
    }
}
