//! `efmuon` — CLI for the EF21-Muon distributed training framework.
//!
//! Subcommands:
//!   train            run distributed EF21-Muon pretraining on the AOT model
//!   serve            train over the socket transport: listen on --listen,
//!                    wait for `efmuon worker` processes to dial in
//!   worker           join a serving leader: dial --connect, claim a slot,
//!                    compute rounds until the leader sends stop
//!   config           validate the resolved config, print it as canonical
//!                    JSON (lossless round trip; presets via --preset)
//!   eval             evaluate the loaded init params (artifact smoke test)
//!   info             print manifest / layer table / geometry
//!   table2           reproduce Table 2 (per-round communication cost)
//!   rates            reproduce Table 1 empirically (rate fits)
//!   s2w              bidirectional compression: EF21-P broadcast sweep
//!   shards           multi-coordinator layer sharding: scaling sweep
//!   fig1 / fig2      reproduce Figures 1–2 (compressor sweep)
//!   divergence       the §2 divergence demo (naive DCGD vs EF)
//!   results          render the experiment history (list/status/table/
//!                    dat/gnuplot/latex/compact over results/results.jsonl)
//!   help             print the flag reference
//!
//! Every flag of `TrainConfig` is a `--flag value` override; see
//! `efmuon help`.

use anyhow::{anyhow, Result};

use efmuon::config::TrainConfig;
use efmuon::exp;
use efmuon::metrics::render_table;
use efmuon::model::Manifest;
use efmuon::results;
use efmuon::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".into());
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "worker" => cmd_worker(args),
        "config" => cmd_config(args),
        "eval" => cmd_eval(args),
        "info" => cmd_info(args),
        "table2" => cmd_table2(args),
        "rates" => cmd_rates(args),
        "s2w" => cmd_s2w(args),
        "shards" => cmd_shards(args),
        "fig1" | "fig2" => cmd_figures(args),
        "divergence" => cmd_divergence(args),
        "results" => cmd_results(args),
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}; try `efmuon help`")),
    }
}

const HELP: &str = "\
efmuon — EF21-Muon: communication-efficient distributed LMO optimization

USAGE: efmuon <command> [--flag value ...]

COMMANDS:
  train        distributed EF21-Muon pretraining on the AOT-compiled model
               flags: --artifacts DIR --workers N --shards S --steps K
                      --comp SPEC --server-comp SPEC
                      --round-mode sync|async:N --beta B --lr LR --warmup W
                      --eval-every E --seed S --log out.jsonl --full-codec
                      --lmo-hidden|--lmo-embed|--lmo-vector NORM
                      --fault-policy off|deadline:MS,quorum:F,respawns:R,backoff:MS
                      --checkpoint-every K --checkpoint-dir DIR --resume
                      --trace out/trace.jsonl (round-phase span events)
                      --schedule warmup-cosine|constant|inv-sqrt-total|theory34
                      --transport channel|tcp:ADDR
                      --sched off|window:N,steal:T --snap-bf16
  serve        `train` over the socket transport: bind --listen ADDR
               (default 127.0.0.1:4310), wait for `workers` efmuon worker
               processes to dial in, then run the identical round loop.
               Loopback TCP is bit-identical to the channel deployment.
  worker       join a serving leader: --connect ADDR plus the train flags
               that shape the local gradient service (--artifacts, --seed,
               ...). Claims a free id slot, computes rounds, heartbeats,
               and redials with exponential backoff if the link drops.
  config       resolve (--config/--preset/flags), validate eagerly with
               field-path errors, and print the canonical JSON spec — its
               output is itself a valid --config file (lossless round trip)
  eval         load artifacts, run one eval pass (smoke test)
  info         print the manifest: layers, shapes, groups, LMO geometry
  table2       Table 2 — per-round communication cost per compressor
  rates        Table 1 — empirical convergence-rate validation
  s2w          bidirectional compression — EF21-P server-to-worker sweep on
               the objective backend (flags: --rounds K --seed S)
  shards       multi-coordinator layer sharding — scaling sweep of the
               cluster root reducer on the objective backend
               (flags: --max-shards M --rounds K --seed S)
  fig1/fig2    Figures 1-2 — compressor sweep (loss vs tokens/bytes)
               flags: --steps K --target LOSS plus all train flags
  divergence   naive biased compression diverges; EF fixes it (paper §2)
  results      render the experiment history appended by the sweeps and
               `cargo bench --bench hotpath` (results/results.jsonl):
                 results list                all experiment keys
                 results status              latest record per key
                 results table <key>         full per-run history
                 results dat <key>           gnuplot-ready columns
                 results gnuplot <key>       plotting script
                 results latex               LaTeX tables (one/experiment)
                 results compact [--keep N]  drop superseded records, keeping
                                             the best per commit + last N
               (--store PATH overrides the store location)

COMPRESSOR SPECS (both directions: --comp for w2s, --server-comp for s2w):
  id | nat | top:F | top:F+nat | rank:F | rank:F+nat | drop:P | damp:G
  | svdtop:K | coltop:F      (F = fraction, e.g. top:0.15+nat)

PRESETS (--preset, `config`/`train`): pinned members of the algorithm
  family — the paper's recovery claims as named configs:
  muon | scion | gluon | ef21-muon | ef21-p
  (e.g. `efmuon train --preset ef21-p --steps 100`; explicit flags win)

LMO NORMS (--lmo-hidden / --lmo-embed / --lmo-vector):
  spectral | sign | top1 | euclid | nuclear | colnorm

ROUND MODES:
  sync      lock-step rounds (default)
  async:N   pipelined: up to N broadcasts in flight; workers run ahead on
            the previous broadcast (async:0 is bit-equal to sync)

SHARDING:
  --shards S partitions the model's layers across S concurrent shard
  coordinators (balanced by parameter count), each with its own worker
  pool, reduced by a root coordinator; --shards 1 is bit-identical to the
  single-leader deployment.

SHARD SCHEDULING (--shards >= 2):
  --sched window:N[,steal:T]
    bounded-epoch rounds: shards run up to N rounds ahead of the slowest
    shard, sealing board epochs as they complete instead of at a lock-step
    barrier. window:0 (and the default, off) is bit-identical to lock-step.
    steal:T migrates the lightest layer off a shard whose EWMA round time
    exceeds T x the fastest shard's (T > 1.0; requires --fault-policy off);
    the migrated layer's trajectory is preserved bitwise.
  --snap-bf16
    store parameter-board epoch snapshots in bf16: half the snapshot
    memory and board bytes; readers expand back to f32.

FAULT TOLERANCE:
  --fault-policy deadline:MS,quorum:F,respawns:R,backoff:MS
    rounds absorb once a quorum (fraction F of workers) has replied and MS
    milliseconds have elapsed; stragglers are skipped (their EF21 server
    term stays in place), dead workers are respawned up to R times with
    exponential backoff. quorum:1.0 is bit-identical to lock-step rounds;
    the default (off) is the fail-stop behavior of prior versions.
  --checkpoint-every K --checkpoint-dir DIR
    atomically save params + run metadata every K steps; --resume restores
    the latest checkpoint (params, step count, schedule position) and
    continues. A missing checkpoint under --resume starts fresh.
";

fn warn_unknown(args: &Args) {
    for f in args.unknown() {
        eprintln!("warning: unused flag --{f}");
    }
}

/// Resolve the layered configuration: `--preset NAME` or `--config FILE`
/// as the base (mutually exclusive), CLI flags winning over either.
fn base_config(args: &Args) -> Result<TrainConfig> {
    if let Some(p) = args.opt_str("preset") {
        if args.opt_str("config").is_some() {
            return Err(anyhow!("--preset and --config are mutually exclusive"));
        }
        let preset = efmuon::spec::Preset::parse(&p).map_err(anyhow::Error::msg)?;
        return preset.spec().to_train_config().override_from_args(args).map_err(anyhow::Error::msg);
    }
    TrainConfig::from_args(args).map_err(anyhow::Error::msg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    warn_unknown(args);
    run_train(&cfg)
}

/// `efmuon serve --listen ADDR`: exactly `train`, with the transport forced
/// to the socket deployment. The leader binds `ADDR`, waits for `workers`
/// `efmuon worker` processes to dial in, and runs the identical round loop
/// (loopback TCP is bit-identical to the in-process channel run).
fn cmd_serve(args: &Args) -> Result<()> {
    let listen = args.str("listen", "127.0.0.1:4310");
    let mut cfg = base_config(args)?;
    warn_unknown(args);
    cfg.transport = format!("tcp:{listen}");
    println!("serving on {listen}: waiting for {} worker(s) to dial in", cfg.workers);
    run_train(&cfg)
}

/// `efmuon worker --connect ADDR`: dial a serving leader, claim a free id
/// slot via the init handshake, and run the worker compute loop over the
/// socket until the leader sends stop. Reconnects with exponential backoff
/// if the link drops; the leader re-initializes us against its current
/// shift.
fn cmd_worker(args: &Args) -> Result<()> {
    let connect = args.str("connect", "127.0.0.1:4310");
    let cfg = base_config(args)?;
    warn_unknown(args);
    let spec = cfg.validate()?;
    let svc = efmuon::dist::service::GradService::spawn_pjrt(
        spec.artifacts.clone(),
        spec.workers,
        spec.corpus_tokens,
        spec.eval_batches,
        spec.seed,
    )?;
    let handle = svc.handle();
    println!("worker: dialing {connect} (artifacts {}, seed {})", spec.artifacts, spec.seed);
    let wcfg = efmuon::dist::net::WorkerCfg { connect, ..Default::default() };
    efmuon::dist::net::worker_loop(&wcfg, &handle, None)?;
    println!("worker: leader sent stop; exiting");
    Ok(())
}

fn run_train(cfg: &TrainConfig) -> Result<()> {
    println!(
        "training: {} workers, {} shard(s), {} steps, w2s={}, s2w={}, rounds={}, lr={}, beta={}",
        cfg.workers, cfg.shards, cfg.steps, cfg.worker_comp, cfg.server_comp, cfg.round_mode,
        cfg.lr, cfg.beta
    );
    let report = efmuon::train::train(cfg)?;
    println!(
        "final eval loss {:.4} after {} steps ({:.1}s, {:.2} s/step)",
        report.final_eval_loss,
        report.steps,
        report.wall_seconds,
        report.wall_seconds / report.steps.max(1) as f64
    );
    println!(
        "w2s bytes/worker: {} ({:.3}x model), s2w: {}",
        report.total_w2s_bytes_per_worker,
        report.total_w2s_bytes_per_worker as f64 / report.model_bytes as f64,
        report.total_s2w_bytes
    );
    for p in &report.curve {
        println!(
            "  step {:>5}  tokens {:>10}  eval_loss {:.4}",
            p.step, p.tokens_processed, p.eval_loss
        );
    }
    Ok(())
}

/// `efmuon config`: resolve the configuration exactly like `train` would,
/// validate it eagerly through the typed `RunBuilder` (all errors at once,
/// field-named), and print the canonical JSON. The output parses back
/// identically through `--config` — the lossless `RunSpec → Json → RunSpec`
/// round trip `scripts/verify.sh` smoke-checks.
fn cmd_config(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    warn_unknown(args);
    let spec = cfg.validate()?;
    println!("{}", spec.to_json());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    let svc = efmuon::dist::service::GradService::spawn_pjrt(
        cfg.artifacts.clone(),
        1,
        200_000,
        cfg.eval_batches,
        cfg.seed,
    )?;
    let manifest = Manifest::load(&cfg.artifacts).map_err(anyhow::Error::msg)?;
    let x0 = manifest.load_init_params().map_err(anyhow::Error::msg)?;
    let loss = svc.handle().eval(&x0)?;
    println!(
        "eval loss at init: {loss:.4} (ln V = {:.4})",
        (manifest.vocab as f64).ln()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    let m = Manifest::load(&cfg.artifacts).map_err(anyhow::Error::msg)?;
    println!(
        "preset {}  vocab {}  seq {}  d_model {}  layers {}  batch {}  params {}",
        m.preset, m.vocab, m.seq_len, m.d_model, m.n_layer, m.batch, m.param_count
    );
    let rows: Vec<Vec<String>> = m
        .layers
        .iter()
        .map(|l| {
            vec![
                l.name.clone(),
                format!("{}x{}", l.rows, l.cols),
                format!("{:?}", l.group),
                format!("{:?}", l.group.geometry().lmo),
            ]
        })
        .collect();
    println!("{}", render_table(&["layer", "shape", "group", "lmo"], &rows));
    println!("NS artifacts: {:?}", m.ns_hlo.iter().map(|(s, _)| s).collect::<Vec<_>>());
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    let shapes = match Manifest::load(&cfg.artifacts) {
        Ok(m) => m.layer_shapes(),
        Err(_) => {
            eprintln!("(no artifacts found; using the micro preset layer table)");
            efmuon::model::micro_preset_shapes()
        }
    };
    let rows = exp::table2_rows(&shapes, exp::paper_compressor_specs())?;
    println!("{}", exp::table2_text(&rows));
    Ok(())
}

fn cmd_rates(args: &Args) -> Result<()> {
    let seed = args.u64("seed", 123).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    let rows = exp::rate_validation(seed)?;
    println!("{}", exp::rates_text(&rows));
    Ok(())
}

fn cmd_s2w(args: &Args) -> Result<()> {
    let rounds = args.usize("rounds", 600).map_err(anyhow::Error::msg)?;
    let seed = args.u64("seed", 7).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    let rows = exp::s2w_savings(exp::s2w_specs(), rounds, seed)?;
    println!("{}", exp::s2w_text(&rows));
    let recs: Vec<results::Record> = rows
        .iter()
        .map(|r| {
            results::Record::new("s2w").spec(&r.spec).meter(efmuon::dist::MeterSnapshot {
                w2s_per_worker: r.w2s_bytes,
                s2w_total: r.s2w_bytes,
                ..Default::default()
            })
        })
        .collect();
    append_results(&recs);
    Ok(())
}

fn cmd_shards(args: &Args) -> Result<()> {
    let rounds = args.usize("rounds", 40).map_err(anyhow::Error::msg)?;
    let seed = args.u64("seed", 11).map_err(anyhow::Error::msg)?;
    let max = args.usize("max-shards", 4).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    let counts: Vec<usize> = [1usize, 2, 3, 4, 6, 8]
        .into_iter()
        .filter(|&s| s <= max)
        .collect();
    let rows = exp::shard_scaling(&counts, rounds, seed)?;
    println!("{}", exp::shards_text(&rows));
    println!(
        "\n(layer-separable workload: bytes and losses are invariant in the shard\n\
         count; `round ms` falling toward max-over-shards is the scaling win)"
    );
    let recs: Vec<results::Record> = rows
        .iter()
        .map(|r| {
            results::Record::new("shards").spec(&r.spec).meter(r.meter).timing(
                &efmuon::util::timer::BenchResult {
                    name: format!("cluster round ({} shard(s))", r.shards),
                    iters: rounds,
                    median_s: r.round_ms / 1e3,
                    mad_s: 0.0,
                    min_s: r.round_ms / 1e3,
                },
            )
        })
        .collect();
    append_results(&recs);
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let target = args.f64("target", 0.0).map_err(anyhow::Error::msg)? as f32;
    warn_unknown(args);
    let reports = exp::figure_sweep(&cfg, exp::figure_specs())?;
    println!("== Figure 1 (left): eval loss vs tokens ==");
    for (spec, tokens, loss) in exp::fig1_left_rows(&reports) {
        println!("{spec:>16} {tokens:>12} {loss:.4}");
    }
    let target = if target > 0.0 {
        target
    } else {
        let best = reports
            .iter()
            .map(|r| r.final_eval_loss)
            .fold(f32::INFINITY, f32::min);
        best * 1.02
    };
    println!("\n== Figures 1 (right) & 2: cost to reach loss {target:.4} ==");
    let rows = exp::tradeoff_rows(&reports, target);
    for r in &rows {
        println!(
            "{:>16} reached={} tokens={} rel_bytes={:.4} final={:.4}",
            r.spec, r.reached, r.tokens_to_target, r.relative_bytes_to_target, r.final_loss
        );
    }
    println!("\n== communication savings vs uncompressed ==");
    for (spec, x) in exp::savings_vs_id(&rows) {
        println!("{spec:>16}  {x:.2}x");
    }
    Ok(())
}

fn cmd_divergence(args: &Args) -> Result<()> {
    let steps = args.usize("steps", 60).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    efmuon::exp::divergence::run_demo(steps, &mut std::io::stdout())?;
    Ok(())
}

/// `efmuon results {list,status,table,dat,gnuplot,latex,compact}`: render
/// (or retire) the experiment history the sweeps and the hotpath bench
/// append to `results/results.jsonl` (see EXPERIMENTS.md §Results store).
fn cmd_results(args: &Args) -> Result<()> {
    let action = args.positional.get(1).cloned().unwrap_or_else(|| "list".into());
    let store = match args.opt_str("store") {
        Some(p) => results::Store::new(p),
        None => results::Store::open_default(),
    };
    let keep = args.usize("keep", 10).map_err(anyhow::Error::msg)?;
    warn_unknown(args);
    let recs = store.load().map_err(|e| anyhow!(e))?;
    let key = || -> Result<&str> {
        args.positional
            .get(2)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("usage: efmuon results {action} <experiment>"))
    };
    match action.as_str() {
        "list" => println!("{}", results::render_list(&recs)),
        "status" => println!("{}", results::render_status(&recs)),
        "table" => println!("{}", results::render_history(&recs, key()?)),
        "dat" => print!("{}", results::render_dat(&recs, key()?)),
        "gnuplot" => print!("{}", results::render_gnuplot(key()?)),
        "latex" => print!("{}", results::render_latex(&recs)),
        "compact" => {
            let st = store.compact(keep).map_err(|e| anyhow!(e))?;
            println!(
                "compacted {}: kept {} of {} record(s)",
                store.path().display(),
                st.kept,
                st.kept + st.dropped
            );
        }
        other => {
            return Err(anyhow!(
                "unknown results action {other:?}; try list | status | table | dat | gnuplot | latex | compact"
            ))
        }
    }
    Ok(())
}

/// Best-effort history append: a read-only checkout must not break the
/// sweep output, so store failures are warnings.
fn append_results(recs: &[results::Record]) {
    let store = results::Store::open_default();
    for rec in recs {
        if let Err(e) = store.append(rec) {
            eprintln!("warning: could not append to {}: {e}", store.path().display());
            return;
        }
    }
    if !recs.is_empty() {
        eprintln!("(appended {} record(s) to {})", recs.len(), store.path().display());
    }
}
