//! # EF21-Muon
//!
//! Production-grade reproduction of *"Error Feedback for Muon and Friends"*
//! (Gruntkowska, Tovmasyan, Gaponov, Richtárik, 2025): the first
//! communication-efficient, non-Euclidean, LMO-based distributed optimizer
//! with convergence guarantees.
//!
//! Three-layer architecture (Python never on the request path):
//! - **L3 (this crate)** — distributed coordinator: leader/worker protocol,
//!   EF21 (w2s) + EF21-P (s2w) error-feedback state machines, compressor
//!   zoo with exact wire-byte accounting, LMO engines, data pipeline,
//!   metrics, CLI.
//! - **L2 (JAX)** — MicroGPT forward/backward, AOT-lowered once to HLO text
//!   (`python/compile/aot.py`).
//! - **L1 (Pallas)** — tiled matmul + Newton–Schulz kernels inside the L2
//!   graphs; executed through the PJRT CPU client by [`runtime`].
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a bench target.

pub mod util;
pub mod linalg;
pub mod lmo;
pub mod compress;
pub mod opt;
pub mod funcs;
pub mod model;
pub mod data;
pub mod runtime;
pub mod dist;
pub mod spec;
pub mod train;
pub mod config;
pub mod metrics;
pub mod trace;
pub mod results;
pub mod exp;

pub use linalg::matrix::Matrix;
pub use util::rng::Rng;
