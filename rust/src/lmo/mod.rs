//! Linear minimization oracles over norm balls — the heart of the
//! Muon/Scion/Gluon family (paper §2, §C).
//!
//! `LMO_{B(0,t)}(G) = argmin_{‖Z‖≤t} ⟨G, Z⟩` satisfies
//! `⟨G, LMO(G)⟩ = −t‖G‖⋆` and relates to the sharp operator via
//! `‖G‖⋆ · LMO_{B(0,1)}(G) = −G♯` (paper eq. (4)); both identities are
//! enforced by tests in `rust/tests/lmo.rs`.

use crate::linalg::matrix::Matrix;
use crate::linalg::ns::{newton_schulz_ws, NS_STEPS};
use crate::linalg::svd::top_singular;
use crate::linalg::workspace::{with_thread_workspace, Workspace};
use crate::util::rng::Rng;

/// Which norm ball the LMO minimizes over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LmoKind {
    /// Spectral ball → `−t·UVᵀ` (Muon). Approximated by Newton–Schulz.
    Spectral,
    /// ℓ∞ ball → `−t·sign(G)` (the paper's embedding/output choice; signSGD
    /// direction).
    SignLInf,
    /// ℓ1 ball → `−t·‖G‖∞·e_{i*j*}` scaled: Top1 direction (paper §D.1).
    L1Top1,
    /// Euclidean ball → `−t·G/‖G‖_F` (normalized steepest descent).
    Euclidean,
    /// Nuclear ball → `−t·u₁v₁ᵀ` rank-1 direction (paper §D.1).
    NuclearRank1,
    /// 1→2 operator-norm ball → column-wise normalization
    /// (column-wise Gluon; Glentis et al. 2025).
    ColNorm,
}

/// How spectral LMOs are computed. `Native` = rust Newton–Schulz;
/// the PJRT-artifact engine lives in `dist::server` (it needs a runtime
/// handle) and produces identical numbers — cross-checked in tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpectralEngine {
    Native,
    /// exact polar factor via Jacobi SVD (tests/small layers)
    ExactSvd,
}

/// Full LMO configuration for one layer.
#[derive(Debug, Clone, Copy)]
pub struct Lmo {
    pub kind: LmoKind,
    pub ns_steps: usize,
    pub engine: SpectralEngine,
}

impl Lmo {
    pub fn new(kind: LmoKind) -> Self {
        Lmo { kind, ns_steps: NS_STEPS, engine: SpectralEngine::Native }
    }

    /// `LMO_{B(0,t)}(g)`: the feasible step of radius `t` most aligned with
    /// `−g`. Returns zeros when `g = 0` (any feasible point is optimal).
    /// Temporaries come from this thread's shared workspace; per-round hot
    /// loops that own an arena should call [`Lmo::step_ws`].
    pub fn step(&self, g: &Matrix, t: f32, rng: &mut Rng) -> Matrix {
        with_thread_workspace(|ws| self.step_ws(g, t, rng, ws))
    }

    /// [`Lmo::step`] with caller-provided scratch. The returned matrix is
    /// drawn from the arena, so callers can `ws.give(step)` after applying
    /// it and the round loop performs no heap allocation once warm.
    pub fn step_ws(&self, g: &Matrix, t: f32, rng: &mut Rng, ws: &mut Workspace) -> Matrix {
        match self.kind {
            LmoKind::Spectral => {
                let mut o = match self.engine {
                    SpectralEngine::Native => newton_schulz_ws(g, self.ns_steps, ws),
                    SpectralEngine::ExactSvd => {
                        let (u, s, v) = crate::linalg::svd::jacobi_svd(g);
                        let k = s.len();
                        crate::linalg::svd::truncated_reconstruct(&u, &vec![1.0; k], &v, k)
                    }
                };
                o.scale(-t);
                o
            }
            LmoKind::SignLInf => {
                let mut out = ws.take(g.rows, g.cols);
                for (o, v) in out.data.iter_mut().zip(&g.data) {
                    *o = if *v > 0.0 {
                        -t
                    } else if *v < 0.0 {
                        t
                    } else {
                        0.0
                    };
                }
                out
            }
            LmoKind::L1Top1 => {
                let mut best = 0usize;
                let mut bestv = 0.0f32;
                for (i, v) in g.data.iter().enumerate() {
                    if v.abs() > bestv {
                        bestv = v.abs();
                        best = i;
                    }
                }
                let mut out = ws.take(g.rows, g.cols);
                if bestv > 0.0 {
                    out.data[best] = -t * g.data[best].signum();
                }
                out
            }
            LmoKind::Euclidean => {
                let n = g.norm2() as f32;
                let mut out = ws.take(g.rows, g.cols);
                if n > 1e-20 {
                    out.data.copy_from_slice(&g.data);
                    out.scale(-t / n);
                }
                out
            }
            LmoKind::NuclearRank1 => {
                let (sigma, u, v) = top_singular(g, 100, rng);
                let mut out = ws.take(g.rows, g.cols);
                if sigma > 0.0 {
                    for i in 0..g.rows {
                        for j in 0..g.cols {
                            out.data[i * g.cols + j] = -t * u[i] * v[j];
                        }
                    }
                }
                out
            }
            LmoKind::ColNorm => {
                // minimize <G,Z> over max-col-l2 ball: each column z_j =
                // -t * g_j / ||g_j||_2
                let mut out = ws.take(g.rows, g.cols);
                for j in 0..g.cols {
                    let mut nrm = 0.0f64;
                    for i in 0..g.rows {
                        nrm += (g.at(i, j) as f64).powi(2);
                    }
                    let nrm = nrm.sqrt() as f32;
                    if nrm > 1e-20 {
                        for i in 0..g.rows {
                            out.set(i, j, -t * g.at(i, j) / nrm);
                        }
                    }
                }
                out
            }
        }
    }

    /// Dual norm ‖g‖⋆ consistent with this LMO's ball: satisfies
    /// `⟨g, step(g,t)⟩ = −t‖g‖⋆` exactly for the exact oracles and
    /// approximately for the NS engine.
    pub fn dual_norm(&self, g: &Matrix, rng: &mut Rng) -> f64 {
        match self.kind {
            // ball: spectral  ⇒ dual of spectral = nuclear
            LmoKind::Spectral => crate::linalg::norms::nuclear_exact(g),
            // ball: ℓ∞ ⇒ dual = ℓ1
            LmoKind::SignLInf => crate::linalg::norms::l1(g),
            // ball: ℓ1 ⇒ dual = ℓ∞
            LmoKind::L1Top1 => crate::linalg::norms::linf(g),
            LmoKind::Euclidean => g.norm2(),
            // ball: nuclear ⇒ dual = spectral
            LmoKind::NuclearRank1 => top_singular(g, 100, rng).0 as f64,
            // ball: max-col-l2 ⇒ dual = sum of column l2 norms
            LmoKind::ColNorm => (0..g.cols)
                .map(|j| {
                    (0..g.rows)
                        .map(|i| (g.at(i, j) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt()
                })
                .sum(),
        }
    }

    /// Sharp operator `g♯ = ‖g‖⋆ · (−LMO_{B(0,1)}(g))` (paper §C).
    pub fn sharp(&self, g: &Matrix, rng: &mut Rng) -> Matrix {
        let d = self.dual_norm(g, rng) as f32;
        self.step(g, 1.0, rng).scaled(-d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_lmo_alignment() {
        let mut rng = Rng::new(51);
        let g = Matrix::randn(5, 7, 1.0, &mut rng);
        let lmo = Lmo::new(LmoKind::SignLInf);
        let z = lmo.step(&g, 2.0, &mut rng);
        // <g, z> = -t * ||g||_1
        let lhs = g.dot(&z);
        assert!((lhs + 2.0 * crate::linalg::norms::l1(&g)).abs() < 1e-3);
        assert!(z.max_abs() <= 2.0 + 1e-6);
    }

    #[test]
    fn top1_lmo() {
        let g = Matrix::from_vec(2, 2, vec![1.0, -5.0, 2.0, 0.5]);
        let lmo = Lmo::new(LmoKind::L1Top1);
        let mut rng = Rng::new(0);
        let z = lmo.step(&g, 3.0, &mut rng);
        assert_eq!(z.data, vec![0.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn euclidean_is_normalized_gd() {
        let mut rng = Rng::new(52);
        let g = Matrix::randn(3, 3, 2.0, &mut rng);
        let lmo = Lmo::new(LmoKind::Euclidean);
        let z = lmo.step(&g, 0.7, &mut rng);
        assert!((z.norm2() - 0.7).abs() < 1e-5);
        let cos = g.dot(&z) / (g.norm2() * z.norm2());
        assert!((cos + 1.0).abs() < 1e-5);
    }

    #[test]
    fn colnorm_columns_unit() {
        let mut rng = Rng::new(53);
        let g = Matrix::randn(6, 4, 1.0, &mut rng);
        let lmo = Lmo::new(LmoKind::ColNorm);
        let z = lmo.step(&g, 1.5, &mut rng);
        for j in 0..4 {
            let n: f64 = (0..6).map(|i| (z.at(i, j) as f64).powi(2)).sum::<f64>().sqrt();
            assert!((n - 1.5).abs() < 1e-4);
        }
    }
}
